//! END-TO-END SCALE-OUT DRIVER: the fleet layer over N DRIM devices —
//! topology, admission control, the shared FIFO scheduler with work
//! stealing, per-device `DrimService`s — under a mixed workload, with
//! every response golden-checked against the single-device serving path
//! (and a PJRT artifact check on top when artifacts exist).
//!
//! ```sh
//! cargo run --release --example e2e_cluster -- --devices 4 --requests 96
//! ```

use drim::cluster::{AdmissionConfig, ClusterConfig, DrimCluster};
use drim::coordinator::{
    BatchPolicy, BulkRequest, DrimService, Payload, ServiceConfig,
};
use drim::isa::program::BulkOp;
use drim::runtime::{golden, Runtime};
use drim::util::bitrow::BitRow;
use drim::util::cli::Args;
use drim::util::rng::Rng;
use drim::util::stats::fmt_ns;

fn main() {
    let args = Args::from_env();
    let devices = args.usize("devices", 4);
    let n_requests = args.usize("requests", 96);
    let seed = args.u64("seed", 0xC105);

    // Per-device config: the paper-scale geometry, but few intra-device
    // workers so devices × workers stays reasonable on laptop CPUs.
    let per_device = ServiceConfig {
        workers: 2,
        policy: BatchPolicy::Coalesce,
        ..ServiceConfig::default()
    };
    let cluster = DrimCluster::new(ClusterConfig {
        admission: AdmissionConfig {
            max_inflight_per_device: args.usize("queue-cap", 64),
        },
        steal: true,
        ..ClusterConfig::uniform(devices, per_device.clone())
    });
    println!(
        "fleet: {devices} devices × ({} banks × {} sub-arrays × {} bit-lines), \
         {} fleet wave slots\n",
        per_device.geometry.banks,
        per_device.geometry.subarrays_per_bank,
        per_device.geometry.cols,
        cluster.config().topology.total_wave_slots()
    );

    // mixed bit-wise workload, sizes log-uniform 4 Kb..4 Mb
    let mut rng = Rng::new(seed);
    let mut inputs: Vec<(BulkOp, Vec<BitRow>)> = Vec::new();
    for i in 0..n_requests {
        let op = match i % 10 {
            0..=4 => BulkOp::Xnor2,
            5..=6 => BulkOp::Xor2,
            7..=8 => BulkOp::Not,
            _ => BulkOp::Maj3,
        };
        let bits = 1usize << (12 + rng.below(11) as usize);
        let ops: Vec<BitRow> = (0..op.arity())
            .map(|_| BitRow::random(bits, &mut rng))
            .collect();
        inputs.push((op, ops));
    }

    // fire everything at the fleet, then collect
    let t0 = std::time::Instant::now();
    let pending: Vec<_> = inputs
        .iter()
        .map(|(op, ops)| cluster.submit_blocking(BulkRequest::bitwise(*op, ops.clone())))
        .collect();
    let responses: Vec<_> = pending
        .into_iter()
        .map(|p| p.recv().expect("fleet response"))
        .collect();
    let fleet_wall = t0.elapsed();

    // golden path 1: the single-device serving layer on the same requests
    let reference = DrimService::new(per_device);
    // golden path 2: the PJRT artifacts, when present
    let mut rt = Runtime::load_default()
        .map_err(|e| eprintln!("(PJRT golden checks skipped — {e})"))
        .ok();
    let mut golden_checked = 0usize;
    for (i, ((op, ops), resp)) in inputs.iter().zip(&responses).enumerate() {
        let got = match &resp.inner.result {
            Payload::Bits(b) => b,
            _ => panic!("payload kind mismatch"),
        };
        let single = reference.run(BulkRequest::bitwise(*op, ops.clone()));
        let want = match single.result {
            Payload::Bits(b) => b,
            _ => unreachable!(),
        };
        assert_eq!(
            *got, want,
            "request {i} ({}) diverged from the single-device path",
            op.name()
        );
        if let Some(rt) = rt.as_mut() {
            if i % 25 == 0 {
                let refs: Vec<&BitRow> = ops.iter().collect();
                golden::verify_bulk(rt, op.name(), &refs, got)
                    .expect("golden check failed");
                golden_checked += 1;
            }
        }
    }

    let snap = cluster.shutdown();
    println!("--- results ---");
    println!(
        "{n_requests} requests over {devices} devices in {fleet_wall:?} (host)"
    );
    println!(
        "all {} responses match the single-device path; \
         {golden_checked} PJRT golden-checked",
        responses.len()
    );
    assert_eq!(snap.completed as usize, n_requests);
    assert_eq!(snap.merged.requests as usize, n_requests);
    let busiest = snap
        .per_device
        .iter()
        .map(|d| d.requests)
        .max()
        .unwrap_or(0);
    let idlest = snap
        .per_device
        .iter()
        .map(|d| d.requests)
        .min()
        .unwrap_or(0);
    println!(
        "balance: busiest device ran {busiest} requests, idlest {idlest}; \
         {} stolen batches; mean queue wait {}",
        snap.steals,
        fmt_ns(snap.mean_queue_wait_ns)
    );
    if idlest == 0 {
        // possible only if one worker's entire queue was stolen before it
        // woke — worth seeing, not worth failing the driver over
        println!("(note: one device executed nothing; its queue was stolen)");
    }
    println!("\n{}", snap.report());
    println!("\ne2e_cluster OK");
}
