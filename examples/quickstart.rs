//! Quickstart: five minutes with the DRIM service.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```
//!
//! Shows the three things a user does: run a bulk bit-wise op, run an
//! element-wise add, and read the cost model (simulated DRAM latency and
//! energy) off the response.

use drim::coordinator::{BulkRequest, DrimService, Payload, ServiceConfig};
use drim::isa::program::BulkOp;
use drim::util::bitrow::BitRow;
use drim::util::rng::Rng;

fn main() {
    // a full-size DRIM device: 8 banks × 64 sub-arrays × 512 rows × 8 Kb
    let service = DrimService::new(ServiceConfig::default());
    let mut rng = Rng::new(42);

    // --- 1. bulk XNOR over a million bits --------------------------------
    let bits = 1 << 20;
    let a = BitRow::random(bits, &mut rng);
    let b = BitRow::random(bits, &mut rng);
    let resp = service.run(BulkRequest::bitwise(BulkOp::Xnor2, vec![a.clone(), b.clone()]));
    let xnor = match &resp.result {
        Payload::Bits(r) => r,
        _ => unreachable!(),
    };
    // spot-check against the host
    assert_eq!(xnor.get(12345), a.get(12345) == b.get(12345));
    println!(
        "XNOR2 over {bits} bits: {} AAPs, {:.2} µs simulated, {:.2} µJ DRAM energy",
        resp.stats.aaps,
        resp.sim_latency_ns / 1e3,
        resp.stats.energy_pj / 1e6
    );

    // --- 2. element-wise 32-bit addition ---------------------------------
    let n = 100_000;
    let x: Vec<u32> = (0..n as u32).collect();
    let y: Vec<u32> = (0..n as u32).map(|v| v * 7).collect();
    let resp = service.run(BulkRequest::add32(x, y));
    let sums = match &resp.result {
        Payload::U32(v) => v,
        _ => unreachable!(),
    };
    assert_eq!(sums[1000], 1000 * 8);
    println!(
        "ADD32 over {n} elements: {} AAPs, {:.2} µs simulated",
        resp.stats.aaps,
        resp.sim_latency_ns / 1e3
    );

    // --- 3. service metrics ----------------------------------------------
    println!("\n{}", service.metrics.snapshot().report());
    println!("\nquickstart OK");
}
