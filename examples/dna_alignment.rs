//! DNA short-read alignment on DRIM — the paper's first motivating
//! workload (§1: "X(N)OR- or addition operations ... such as DNA
//! alignment").
//!
//! ```sh
//! cargo run --release --example dna_alignment -- [--genome 200000] [--reads 32]
//! ```
//!
//! Generates a synthetic genome, plants mutated reads, and scans every
//! read against every window with in-memory XNOR, reporting recall and the
//! simulated in-DRAM cost vs the CPU roofline.

use drim::apps::dna;
use drim::coordinator::{DrimService, ServiceConfig};
use drim::isa::program::BulkOp;
use drim::platforms::by_name;
use drim::util::cli::Args;
use drim::util::rng::Rng;
use drim::util::stats::fmt_rate;

fn main() {
    let args = Args::from_env();
    let genome_len = args.usize("genome", 50_000);
    let n_reads = args.usize("reads", 16);
    let read_len = args.usize("read-len", 24);
    let mutations = args.usize("mutations", 2);

    let mut rng = Rng::new(args.u64("seed", 0xD7A));
    let service = DrimService::new(ServiceConfig::default());

    println!("genome: {genome_len} bases, {n_reads} reads × {read_len} bases, {mutations} mutations each\n");
    let mut genome = dna::random_genome(genome_len, &mut rng);

    // plant reads at random positions, then mutate copies of them
    let mut truth = Vec::new();
    let mut reads = Vec::new();
    for _ in 0..n_reads {
        let pos = rng.below((genome_len - read_len) as u64) as usize;
        let read = dna::random_genome(read_len, &mut rng);
        genome.replace_range(pos..pos + read.len(), &read);
        // mutated copy (what the sequencer "produced")
        let mut mutated: Vec<char> = read.chars().collect();
        for _ in 0..mutations {
            let i = rng.below(read_len as u64) as usize;
            mutated[i] = dna::BASES[rng.below(4) as usize];
        }
        truth.push(pos);
        reads.push(mutated.into_iter().collect::<String>());
    }

    let min_match = read_len - mutations;
    let mut found = 0;
    let t0 = std::time::Instant::now();
    for (read, &pos) in reads.iter().zip(&truth) {
        let hits = dna::align(&service, &genome, read, min_match);
        if hits.iter().any(|h| h.position == pos) {
            found += 1;
        }
    }
    let wall = t0.elapsed();

    let snap = service.metrics.snapshot();
    println!("recall: {found}/{n_reads} planted reads recovered");
    println!("host wall time: {wall:?}");
    println!("\nin-DRAM cost (simulated):");
    println!("{}", snap.report());

    // paper framing: the same scan on the CPU roofline
    let cpu = by_name("CPU").unwrap();
    let cpu_rate = cpu.throughput_bits_per_sec(BulkOp::Xnor2, snap.result_bits.max(1));
    let cpu_ns = snap.result_bits as f64 / cpu_rate * 1e9;
    println!(
        "\nXNOR phase: DRIM simulated {} vs CPU roofline {} ({}bit/s) → {:.0}x",
        drim::util::stats::fmt_ns(snap.sim_ns as f64),
        drim::util::stats::fmt_ns(cpu_ns),
        fmt_rate(cpu_rate),
        cpu_ns / snap.sim_ns.max(1) as f64
    );
    assert_eq!(found, n_reads, "all planted reads must be recovered");
    println!("\ndna_alignment OK");
}
