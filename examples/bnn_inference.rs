//! Binarized-NN inference on DRIM — the DNN workload family the paper's
//! related work (DRISA, Dracc) accelerates, expressed through DRIM's
//! headline XNOR primitive.
//!
//! ```sh
//! cargo run --release --example bnn_inference -- [--batch 64]
//! ```
//!
//! Builds a random 3-layer binary MLP, generates prototype-based inputs
//! (class prototype + bit noise), and classifies them with every XNOR in
//! memory, reporting agreement with the host reference and the simulated
//! in-DRAM cost per inference.

use drim::apps::bnn::BinaryMlp;
use drim::coordinator::{DrimService, ServiceConfig};
use drim::util::bitrow::BitRow;
use drim::util::cli::Args;
use drim::util::rng::Rng;
use drim::util::stats::fmt_ns;

fn main() {
    let args = Args::from_env();
    let batch = args.usize("batch", 64);
    let dims = [512usize, 256, 64, 16];

    let mut rng = Rng::new(args.u64("seed", 0xB44));
    let service = DrimService::new(ServiceConfig::default());
    let net = BinaryMlp::random(&dims, &mut rng);
    println!(
        "binary MLP {:?}: {} XNOR bit-ops per inference\n",
        dims,
        net.ops_per_inference()
    );

    let mut agree = 0;
    let t0 = std::time::Instant::now();
    for _ in 0..batch {
        let x = BitRow::random(dims[0], &mut rng);
        let y_mem = net.forward(&service, &x);
        let y_host = net.forward_host(&x);
        if y_mem == y_host {
            agree += 1;
        }
    }
    let wall = t0.elapsed();
    assert_eq!(agree, batch, "in-memory and host inference must agree");

    let snap = service.metrics.snapshot();
    println!("{batch} inferences, all bit-exact vs host reference");
    println!("host wall: {wall:?}\n");
    println!("{}", snap.report());
    println!(
        "\nsimulated in-DRAM time per inference: {}",
        fmt_ns(snap.sim_ns as f64 / batch as f64)
    );
    println!("\nbnn_inference OK");
}
