//! In-memory data encryption — the paper's second motivating workload.
//!
//! ```sh
//! cargo run --release --example encryption -- [--mbytes 4]
//! ```
//!
//! XOR-stream-encrypts a payload inside the DRAM array, verifies the
//! round-trip, and compares the in-DRAM energy against moving the data out
//! over the DDR4 interface to encrypt on the CPU.

use drim::apps::cipher;
use drim::coordinator::{DrimService, ServiceConfig};
use drim::energy::EnergyModel;
use drim::util::bitrow::BitRow;
use drim::util::cli::Args;
use drim::util::rng::Rng;

fn main() {
    let args = Args::from_env();
    let mbytes = args.usize("mbytes", 1);
    let bits = mbytes * 8 * 1024 * 1024;
    let key = args.u64("key", 0x0BAD_5EED);

    let service = DrimService::new(ServiceConfig::default());
    let mut rng = Rng::new(9);
    let plaintext = BitRow::random(bits, &mut rng);

    println!("encrypting {mbytes} MiB in-memory (XOR stream, row-parallel)\n");
    let t0 = std::time::Instant::now();
    let ciphertext = cipher::apply(&service, &plaintext, key);
    let enc_wall = t0.elapsed();
    assert_ne!(ciphertext, plaintext);

    let decrypted = cipher::apply(&service, &ciphertext, key);
    assert_eq!(decrypted, plaintext, "round-trip failed");

    let snap = service.metrics.snapshot();
    println!("round-trip verified ({} bits)", bits);
    println!("host wall: {enc_wall:?} (encrypt only)\n{}", snap.report());

    // energy comparison: in-DRAM XOR vs shipping data to the CPU and back
    let m = EnergyModel::default();
    let in_dram_pj = snap.aaps as f64 / 2.0 // encrypt half of the AAPs
        * m.aap_pj(drim::dram::command::AapKind::Copy, 8192); // ≈ per-AAP
    let offchip_pj = 2.0 * m.offchip_pj(bits as f64); // out + back
    println!(
        "\nenergy: in-DRAM ≈ {:.1} µJ vs off-chip round trip ≈ {:.1} µJ ({:.0}x)",
        in_dram_pj / 1e6,
        offchip_pj / 1e6,
        offchip_pj / in_dram_pj
    );
    println!("\nencryption OK");
}
