//! END-TO-END DRIVER (DESIGN.md experiment E2E): the full system — router,
//! batcher, worker banks, functional sub-array simulation, metrics — under
//! a realistic mixed workload, with results golden-checked against the
//! AOT-lowered JAX kernels through the PJRT runtime when artifacts exist.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_serve
//! ```
//!
//! The run is recorded in EXPERIMENTS.md §E2E.

use drim::coordinator::{
    BatchPolicy, BulkRequest, DrimService, Payload, ServiceConfig,
};
use drim::isa::program::BulkOp;
use drim::runtime::{golden, Runtime};
use drim::util::bitrow::BitRow;
use drim::util::cli::Args;
use drim::util::rng::Rng;
use drim::util::stats::{fmt_ns, percentile};

fn main() {
    let args = Args::from_env();
    let n_requests = args.usize("requests", 200);
    let seed = args.u64("seed", 0xE2E);

    let cfg = ServiceConfig {
        policy: BatchPolicy::Coalesce,
        ..ServiceConfig::default()
    };
    println!(
        "device: {} banks × {} sub-arrays × {} bit-lines, {} workers, {:?} batching\n",
        cfg.geometry.banks,
        cfg.geometry.subarrays_per_bank,
        cfg.geometry.cols,
        cfg.workers,
        cfg.policy
    );
    let service = DrimService::new(cfg);
    let mut rng = Rng::new(seed);

    // mixed workload: 50% xnor2 (the headline op), 20% xor2, 15% not,
    // 10% and2, 5% add32; sizes log-uniform 4 Kb..4 Mb
    let mut inputs: Vec<(BulkOp, Vec<BitRow>)> = Vec::new();
    let mut adds: Vec<(Vec<u32>, Vec<u32>)> = Vec::new();
    let mut order: Vec<(bool, usize)> = Vec::new(); // (is_add, idx)
    for _ in 0..n_requests {
        let dice = rng.below(100);
        let bits = 1usize << (12 + rng.below(11) as usize);
        if dice < 95 {
            let op = match dice {
                0..=49 => BulkOp::Xnor2,
                50..=69 => BulkOp::Xor2,
                70..=84 => BulkOp::Not,
                _ => BulkOp::And2,
            };
            let ops: Vec<BitRow> = (0..op.arity())
                .map(|_| BitRow::random(bits, &mut rng))
                .collect();
            order.push((false, inputs.len()));
            inputs.push((op, ops));
        } else {
            let n = bits / 32;
            let a: Vec<u32> = (0..n).map(|_| rng.next_u64() as u32).collect();
            let b: Vec<u32> = (0..n).map(|_| rng.next_u64() as u32).collect();
            order.push((true, adds.len()));
            adds.push((a, b));
        }
    }

    // fire everything (the router coalesces), then collect
    let t0 = std::time::Instant::now();
    let mut pending = Vec::new();
    for (is_add, idx) in &order {
        let req = if *is_add {
            let (a, b) = &adds[*idx];
            BulkRequest::add32(a.clone(), b.clone())
        } else {
            let (op, ops) = &inputs[*idx];
            BulkRequest::bitwise(*op, ops.clone())
        };
        pending.push(service.submit(req));
    }
    let mut latencies = Vec::new();
    let mut responses = Vec::new();
    for p in pending {
        let r = p.recv().expect("response");
        latencies.push(r.sim_latency_ns);
        responses.push(r);
    }
    let wall = t0.elapsed();

    // verify every result on the host; golden-check a sample via PJRT
    let mut rt = Runtime::load_default()
        .map_err(|e| eprintln!("(PJRT golden checks skipped — {e})"))
        .ok();
    let mut golden_checked = 0usize;
    for (i, (is_add, idx)) in order.iter().enumerate() {
        match (&responses[i].result, is_add) {
            (Payload::U32(got), true) => {
                let (a, b) = &adds[*idx];
                for e in 0..a.len() {
                    assert_eq!(got[e], a[e].wrapping_add(b[e]), "add req {i}");
                }
            }
            (Payload::Bits(got), false) => {
                let (op, ops) = &inputs[*idx];
                let mut want = BitRow::zeros(got.len());
                match op {
                    BulkOp::Xnor2 => want.apply2(&ops[0], &ops[1], |x, y| !(x ^ y)),
                    BulkOp::Xor2 => want.apply2(&ops[0], &ops[1], |x, y| x ^ y),
                    BulkOp::And2 => want.apply2(&ops[0], &ops[1], |x, y| x & y),
                    BulkOp::Not => want.not_from(&ops[0]),
                    _ => unreachable!(),
                }
                assert_eq!(*got, want, "bitwise req {i}");
                if let Some(rt) = rt.as_mut() {
                    if i % 25 == 0 {
                        let refs: Vec<&BitRow> = ops.iter().collect();
                        golden::verify_bulk(rt, op.name(), &refs, got)
                            .expect("golden check failed");
                        golden_checked += 1;
                    }
                }
            }
            _ => panic!("payload kind mismatch"),
        }
    }

    let snap = service.metrics.snapshot();
    println!("--- results ---");
    println!("{} requests completed in {wall:?} (host)", n_requests);
    println!("all host-verified; {golden_checked} golden-checked via PJRT");
    println!("\n{}", snap.report());
    println!(
        "\nsimulated latency: p50 {}  p95 {}  p99 {}",
        fmt_ns(percentile(&mut latencies, 50.0)),
        fmt_ns(percentile(&mut latencies, 95.0)),
        fmt_ns(percentile(&mut latencies, 99.0)),
    );
    println!("\ne2e_serve OK");
}
