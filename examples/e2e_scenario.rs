//! END-TO-END SCENARIO HARNESS DRIVER: a declarative multi-tenant
//! benchmark embedded as a TOML string, parsed with `ScenarioSpec`,
//! executed twice with `run_scenario`, and diffed for byte-identical
//! deterministic snapshots — the same contract CI's determinism job
//! enforces on the checked-in `scenarios/*.toml` files.
//!
//! ```sh
//! cargo run --release --example e2e_scenario
//! ```

use drim::scenario::{
    generate, offered_wave_units, run_scenario, stream_digest, ScenarioSpec,
};
use drim::util::stats::fmt_ns;

/// Two tenants share a two-device fleet: a light XNOR2 tenant and a
/// heavier one at 4x the operand size and 3x the weight, arriving
/// open-loop Poisson. Stealing stays off and coalescing strict, so the
/// run sits inside the deterministic envelope.
const SCENARIO: &str = r#"
name = "e2e_scenario"
description = "two-tenant Poisson mix, coalescing on vs off"
seed = 0xE2E

[fleet]
devices = 2
workers = 2

[arrival]
requests = 48
process = "poisson"
rate = 2_000_000.0
window = 8

[[tenants]]
name = "light"
op = "xnor2"
bits = 65_536

[[tenants]]
name = "heavy"
weight = 3.0
op = "xnor2"
bits = 262_144

[[cases]]
name = "baseline"

[[cases]]
name = "coalesced"
coalesce = "strict"

[[gates]]
name = "results_identical"
left = "coalesced.results_digest"
op = "eq"
right = "baseline.results_digest"

[[gates]]
name = "no_request_lost"
left = "coalesced.completed"
op = "eq"
right = 48
"#;

fn main() {
    let spec = ScenarioSpec::parse_str(SCENARIO).expect("embedded scenario parses");
    println!(
        "scenario `{}` — {} ({} cases, {} gates)\n",
        spec.name,
        spec.description,
        spec.resolved_cases().len(),
        spec.gates.len()
    );

    // the arrival stream is a pure function of the spec: same seed, same
    // events, same declared load
    for case in &spec.resolved_cases() {
        let events = generate(case);
        assert_eq!(stream_digest(&events), stream_digest(&generate(case)));
        assert_eq!(offered_wave_units(case, &events), case.declared_wave_units());
        println!(
            "case `{}`: {} arrivals over {}, stream digest {:#018x}",
            case.name,
            events.len(),
            fmt_ns(events.last().map(|e| e.vtime_ns as f64).unwrap_or(0.0)),
            stream_digest(&events)
        );
    }

    // execute twice; every simulated metric must agree byte-for-byte
    let first = run_scenario(&spec);
    let second = run_scenario(&spec);
    println!();
    for (a, b) in first.cases.iter().zip(&second.cases) {
        let fingerprint = a.snapshot.to_deterministic_json().to_string_compact();
        assert_eq!(
            fingerprint,
            b.snapshot.to_deterministic_json().to_string_compact(),
            "case `{}` diverged between identical runs",
            a.name
        );
        println!(
            "case `{}`: completed {} of {} offered, {} waves, sim makespan {}",
            a.name,
            a.metric_f64("completed").unwrap_or(0.0),
            a.metric_f64("offered").unwrap_or(0.0),
            a.metric_f64("waves").unwrap_or(0.0),
            fmt_ns(a.metric_f64("sim_makespan_ns").unwrap_or(0.0)),
        );
    }

    println!();
    for gate in &first.gates {
        println!(
            "  {} {}: {}",
            if gate.pass { "PASS" } else { "FAIL" },
            gate.name,
            gate.detail
        );
    }
    assert!(first.ok(), "scenario gates failed");
    println!("\ne2e_scenario OK (two runs byte-identical)");
}
