"""AOT compiler: lower every L2 graph to HLO *text* + write a manifest.

HLO text (NOT ``lowered.compiler_ir('hlo')`` protos, NOT ``.serialize()``) is
the interchange format: jax ≥ 0.5 emits protos with 64-bit instruction ids
which the Rust side's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly.  See
/opt/xla-example/README.md.

Run from ``python/`` as ``python -m compile.aot --out-dir ../artifacts``
(the Makefile does).  Python never runs again after this: the Rust binary
loads the artifacts through PJRT and is self-contained.
"""

import argparse
import hashlib
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from . import model
from . import params as P


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec_str(s) -> str:
    return f"{s.dtype}[{','.join(str(d) for d in s.shape)}]"


def artifact_table():
    """name → (fn, example_arg_specs, output_spec_strings)."""
    table = {}
    for op in model.bitwise.OPS:
        fn, specs = model.make_bulk(op)
        table[f"bulk_{op}"] = (fn, specs)
    table["bitplane_add"] = (model.bitplane_add_fn, model.BITPLANE_ADD_SPECS)
    table["mc_variation"] = (model.mc_variation, model.MC_SPECS)
    table["transient"] = (model.transient_waveforms, model.TRANSIENT_SPECS)
    return table


def lower_all(out_dir: str, only=None) -> list[str]:
    os.makedirs(out_dir, exist_ok=True)
    manifest_lines = [
        "# DRIM AOT artifact manifest — parsed by rust/src/runtime/manifest.rs",
        "# name <tab> file <tab> in=<specs> <tab> out=<specs> <tab> sha256=<hash>",
        f"# vdd={P.VDD} cp_ratio={P.CP_RATIO} cb_ratio={P.CB_RATIO} "
        f"noise_lin={P.NOISE_LIN} noise_quad={P.NOISE_QUAD} "
        f"trials={P.MC_TRIALS} "
        f"transient_steps={P.TRANSIENT_STEPS} dt_ns={P.DT_NS}",
    ]
    names = []
    for name, (fn, specs) in sorted(artifact_table().items()):
        if only and name not in only:
            continue
        lowered = jax.jit(fn).lower(*specs)
        outs = jax.eval_shape(fn, *specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        path = os.path.join(out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:16]
        in_s = ",".join(_spec_str(s) for s in specs)
        out_s = ",".join(_spec_str(s) for s in outs)
        manifest_lines.append(
            f"{name}\t{fname}\tin={in_s}\tout={out_s}\tsha256={digest}"
        )
        names.append(name)
        print(f"  {name:18s} -> {fname} ({len(text) / 1024:.0f} KiB)")
    with open(os.path.join(out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    return names


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", nargs="*", help="subset of artifact names")
    args = ap.parse_args()
    names = lower_all(args.out_dir, set(args.only) if args.only else None)
    print(f"wrote {len(names)} artifacts + manifest to {args.out_dir}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
