"""L2: the jax compute graphs that get AOT-lowered to artifacts/*.hlo.txt.

Three families, each calling the L1 Pallas kernels:

  * ``bulk_<op>``        — golden bulk bit-wise ops, used by the Rust side to
                           verify in-DRAM results and as the CPU-roofline
                           compute payload (Fig. 8 baselines).
  * ``mc_variation``     — one Monte-Carlo batch of Table 3: samples the
                           varied circuit instances, evaluates DRA and TRA
                           through the L1 sense kernels, counts errors.
  * ``transient_waveforms`` — Fig. 6 trajectory generator.

Everything here must stay shape-static (AOT) and jit-able.
"""

import jax
import jax.numpy as jnp

from . import params as P
from .kernels import bitwise, dra_analog, ref, transient

# --------------------------------------------------------------------------
# bulk bit-wise golden ops
# --------------------------------------------------------------------------

BULK_SHAPE = (P.BITWISE_ROWS, P.BITWISE_LANES)
ADD_SHAPE = (P.ADD_BITS, P.ADD_WORDS)


def make_bulk(op: str):
    """(fn, example_args) for a named elementwise bulk op at artifact shape."""
    arity, _ = bitwise.OPS[op]
    run = bitwise.bulk(op)

    def fn(*operands):
        return (run(*operands),)

    spec = jax.ShapeDtypeStruct(BULK_SHAPE, jnp.int32)
    return fn, (spec,) * arity


def bitplane_add_fn(a_planes, b_planes, carry_in):
    s, c = bitwise.bitplane_add(a_planes, b_planes, carry_in)
    return (s, c)


BITPLANE_ADD_SPECS = (
    jax.ShapeDtypeStruct(ADD_SHAPE, jnp.int32),
    jax.ShapeDtypeStruct(ADD_SHAPE, jnp.int32),
    jax.ShapeDtypeStruct((P.ADD_WORDS,), jnp.int32),
)

# --------------------------------------------------------------------------
# Table 3 Monte-Carlo
# --------------------------------------------------------------------------


def _trunc_normal(key, shape, rel_bound):
    """Gaussian with σ = rel_bound/3, truncated at the ±rel_bound spec
    corner (samples outside the corner are clamped, as fab binning would)."""
    sigma = rel_bound * P.SIGMA_FRACTION
    x = jax.random.normal(key, shape) * sigma
    return jnp.clip(x, -rel_bound, rel_bound)


def mc_variation(key, variation):
    """One full Table-3 cell: error percentages under ±``variation``.

    ``key``: uint32[2] PRNG key data.  ``variation``: f32 scalar, e.g. 0.10
    for ±10 %.  Returns (dra_errors, tra_errors, dra_evals, tra_evals) as
    int32 scalars over MC_TRIALS trials × all input cases.
    """
    key = jax.random.wrap_key_data(key.astype(jnp.uint32), impl="threefry2x32")
    t = P.MC_TRIALS

    # Enumerate input cases: DRA (Di,Dj), TRA (Di,Dj,Dk).
    dra_in = jnp.array([[0, 0], [0, 1], [1, 0], [1, 1]], jnp.float32)
    tra_in = jnp.array(
        [[(n >> 2) & 1, (n >> 1) & 1, n & 1] for n in range(P.TRA_CASES)],
        jnp.float32,
    )

    ks = jax.random.split(key, 12)

    # --- DRA instances: trials × 4 cases --------------------------------
    shape_d = (t, P.DRA_CASES)
    ci = 1.0 + _trunc_normal(ks[0], shape_d, variation)
    cj = 1.0 + _trunc_normal(ks[1], shape_d, variation)
    cp = P.CP_RATIO * (1.0 + _trunc_normal(ks[2], shape_d, variation))
    vsl = P.VS_LOW * (1.0 + _trunc_normal(ks[3], shape_d, variation))
    vsh = P.VS_HIGH * (1.0 + _trunc_normal(ks[4], shape_d, variation))
    vn = jax.random.normal(ks[5], shape_d) * P.noise_sigma(variation)

    di = jnp.broadcast_to(dra_in[:, 0], shape_d)
    dj = jnp.broadcast_to(dra_in[:, 1], shape_d)
    xnor, _ = dra_analog.dra_sense(
        ci * di * P.VDD, cj * dj * P.VDD, ci, cj, cp, vsl, vsh, vn
    )
    want = 1.0 - jnp.abs(di - dj)  # XNOR truth
    dra_errors = jnp.sum((xnor != want).astype(jnp.int32))

    # --- TRA instances: trials × 8 cases --------------------------------
    shape_t = (t, P.TRA_CASES)
    c1 = 1.0 + _trunc_normal(ks[6], shape_t, variation)
    c2 = 1.0 + _trunc_normal(ks[7], shape_t, variation)
    c3 = 1.0 + _trunc_normal(ks[8], shape_t, variation)
    cb = P.CB_RATIO * (1.0 + _trunc_normal(ks[9], shape_t, variation))
    vsa = P.VSA * (1.0 + _trunc_normal(ks[10], shape_t, variation))
    vnt = jax.random.normal(ks[11], shape_t) * P.noise_sigma(variation)

    e1 = jnp.broadcast_to(tra_in[:, 0], shape_t)
    e2 = jnp.broadcast_to(tra_in[:, 1], shape_t)
    e3 = jnp.broadcast_to(tra_in[:, 2], shape_t)
    maj = dra_analog.tra_sense(
        c1 * e1 * P.VDD, c2 * e2 * P.VDD, c3 * e3 * P.VDD,
        c1, c2, c3, cb, vsa, vnt,
    )
    want_maj = ((e1 + e2 + e3) >= 2.0).astype(jnp.float32)
    tra_errors = jnp.sum((maj != want_maj).astype(jnp.int32))

    return (
        dra_errors,
        tra_errors,
        jnp.int32(t * P.DRA_CASES),
        jnp.int32(t * P.TRA_CASES),
    )


MC_SPECS = (
    jax.ShapeDtypeStruct((2,), jnp.uint32),
    jax.ShapeDtypeStruct((), jnp.float32),
)

# --------------------------------------------------------------------------
# Fig. 6 transient
# --------------------------------------------------------------------------


def transient_waveforms(cases):
    return (transient.waveforms(cases),)


TRANSIENT_SPECS = (jax.ShapeDtypeStruct((4, 2), jnp.float32),)

# --------------------------------------------------------------------------
# reference (non-pallas) twins used by pytest to cross-check the kernels
# --------------------------------------------------------------------------


def mc_variation_ref(key, variation):
    """Same as ``mc_variation`` but through the pure-jnp ref sense models —
    used by tests to prove the Pallas kernels don't change the statistics."""
    import unittest.mock as _mock

    with _mock.patch.object(
        dra_analog, "dra_sense", ref.dra_sense
    ), _mock.patch.object(dra_analog, "tra_sense", ref.tra_sense):
        return mc_variation(key, variation)
