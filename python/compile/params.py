"""Shared physical / architectural constants for the DRIM analog models.

These constants are mirrored on the Rust side in ``rust/src/analog/params.rs``
(cross-checked by the ``it_runtime_golden`` integration test): the JAX/Pallas
artifacts and the Rust behavioural models must agree on the circuit they
simulate.

Circuit model (paper §3.1, Fig. 4/5):

* DRA isolates the two selected cell capacitors onto the sense node of the
  reconfigurable SA (``En_C=1``, ``En_M=0``).  Ideal shared voltage is
  ``V = n·Vdd / C`` with ``C = 2`` unit capacitors (n = number of cells
  storing '1'), i.e. levels {0, Vdd/2, Vdd}.
* A *parasitic* capacitance ``CP_RATIO`` (in unit-cell-capacitor units,
  precharged to Vdd/2) loads the sense node; with ``CP_RATIO = 0.6`` the
  realized levels are {0.138, 0.600, 1.062} V at Vdd = 1.2 V, which leaves a
  worst-case margin of ~0.16 V against the shifted inverter thresholds at
  Vdd/4 and 3·Vdd/4 — the margin geometry that drives Table 3.
* TRA shares three cells onto the full bit-line (``CB_RATIO = 3`` unit
  capacitors precharged to Vdd/2, per Ambit's Cb/Cc ratio), giving levels
  {0.3, 0.5, 0.7, 0.9} V against the SA threshold Vdd/2 — a 0.1 V margin,
  smaller than DRA's, hence TRA's strictly higher error rate.
* Process variation "±X%" is modelled as (a) relative Gaussian variation of
  every capacitor and inverter/SA switching threshold with σ = X/3
  (the customary 3σ = bound mapping), and (b) an additive sense-node noise
  term ``noise_sigma(X)`` lumping the Fig. 7 noise sources (WL-BL coupling
  C_wbl, BL-substrate C_s, BL-BL cross-talk C_cross) plus SA offset, which
  scale with the same technology variation (see the inline note at
  NOISE_LIN/NOISE_QUAD for the quadratic term's physical origin).
"""

# ---- supply / thresholds -------------------------------------------------
VDD = 1.2                 # volts (45 nm NCSU PDK class)
VS_LOW = VDD / 4.0        # low-Vs inverter switching threshold (NOR2 detector)
VS_HIGH = 3.0 * VDD / 4.0 # high-Vs inverter switching threshold (NAND2 detector)
VSA = VDD / 2.0           # conventional SA switching threshold (TRA / read)

# ---- capacitor network (unit = one DRAM cell capacitor, ~20 fF) ----------
CP_RATIO = 0.6   # DRA sense-node parasitic, in cell-capacitor units
CB_RATIO = 3.0   # TRA bit-line capacitance, in cell-capacitor units

# ---- variation model -----------------------------------------------------
SIGMA_FRACTION = 1.0 / 3.0     # "±X%" → relative Gaussian σ = X/3
# Additive sense-node noise σ(X) = (NOISE_LIN + NOISE_QUAD·X)·X volts at
# variation ±X.  The quadratic term models the interaction of the Fig. 7
# coupling capacitances (C_wbl, C_s, C_cross) with device variation: both the
# coupled aggressor swing and the victim's susceptibility scale with the
# variation corner, so their product grows ~quadratically.  Calibrated
# against Table 3 (see EXPERIMENTS.md §Table3).
NOISE_LIN = 0.05
NOISE_QUAD = 2.5


def noise_sigma(variation):
    return (NOISE_LIN + NOISE_QUAD * variation) * variation

# ---- Monte-Carlo configuration (Table 3) ---------------------------------
MC_TRIALS = 10_000
DRA_CASES = 4    # (Di,Dj) ∈ {00,01,10,11}
TRA_CASES = 8    # (Di,Dj,Dk) ∈ {000..111}

# ---- transient model (Fig. 6) --------------------------------------------
DT_NS = 0.05              # Euler step
T_PRECHARGE_NS = 10.0     # P.S.   : bit-line precharged, cells hold data
T_SHARE_NS = 10.0         # C.S.S. : WLx1+WLx2 raised, charge sharing
T_SENSE_NS = 40.0         # S.A.S. : enables raised, regenerative amplify
TAU_SHARE_NS = 1.5        # RC constant of cell↔sense-node sharing
TAU_SENSE_NS = 3.0        # regenerative SA time constant
TAU_CELL_NS = 4.0         # cell restore through access transistor
TRANSIENT_STEPS = int(round((T_PRECHARGE_NS + T_SHARE_NS + T_SENSE_NS) / DT_NS))

# ---- AOT artifact shapes (static; the Rust runtime chunks to these) ------
BITWISE_ROWS = 512        # i32 words
BITWISE_LANES = 128       # → 512*128 = 65 536 words = 2 Mbit per operand
ADD_BITS = 32             # bit-planes per operand
ADD_WORDS = 2048          # packed i32 words per plane (65 536 elements)


def transient_phase_bounds():
    """(end of P.S., end of C.S.S.) as step indices."""
    p = int(round(T_PRECHARGE_NS / DT_NS))
    s = int(round((T_PRECHARGE_NS + T_SHARE_NS) / DT_NS))
    return p, s
