"""L1 Pallas kernels: behavioural sense-amplification under process variation.

These kernels evaluate the *analog* step of DRIM's DRA and Ambit's TRA for a
(trials × cases) tile of independently-varied circuit instances — the
Monte-Carlo engine behind Table 3.  Each matrix element is one bit-line's
sense amplification: fully lane-parallel, no cross-lane reduction, mirroring
the physical independence of bit-lines in the array (DESIGN.md
§Hardware-Adaptation).

The circuit model (levels, margins, noise lumping) is documented in
``params.py``; the pure-jnp specification lives in ``ref.py``.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import params as P


def _dra_kernel(qi, qj, ci, cj, cp, vsl, vsh, vn, xnor_o, xor_o):
    v = (qi[...] + qj[...] + cp[...] * (P.VDD / 2.0)) / (
        ci[...] + cj[...] + cp[...]
    ) + vn[...]
    nor_out = (v < vsl[...]).astype(jnp.float32)   # low-Vs inverter → NOR2
    nand_out = (v < vsh[...]).astype(jnp.float32)  # high-Vs inverter → NAND2
    xor = nand_out * (1.0 - nor_out)               # CMOS AND gate
    xor_o[...] = xor                               # BL̄  (Eq. 1)
    xnor_o[...] = 1.0 - xor                        # BL


def dra_sense(qi, qj, ci, cj, cp, vsl, vsh, vnoise):
    """Pallas evaluation of the reconfigurable SA. All inputs f32[T, C]."""
    shape = qi.shape
    spec = pl.BlockSpec(shape, lambda: (0,) * len(shape))
    out = jax.ShapeDtypeStruct(shape, jnp.float32)
    return pl.pallas_call(
        _dra_kernel,
        grid=(),
        in_specs=[spec] * 8,
        out_specs=[spec, spec],
        out_shape=[out, out],
        interpret=True,
    )(qi, qj, ci, cj, cp, vsl, vsh, vnoise)


def _tra_kernel(q1, q2, q3, c1, c2, c3, cb, vsa, vn, maj_o):
    v = (q1[...] + q2[...] + q3[...] + cb[...] * (P.VDD / 2.0)) / (
        c1[...] + c2[...] + c3[...] + cb[...]
    ) + vn[...]
    maj_o[...] = (v > vsa[...]).astype(jnp.float32)


def tra_sense(q1, q2, q3, c1, c2, c3, cb, vsa, vnoise):
    """Pallas evaluation of Ambit's TRA on a conventional SA. f32[T, C]."""
    shape = q1.shape
    spec = pl.BlockSpec(shape, lambda: (0,) * len(shape))
    return pl.pallas_call(
        _tra_kernel,
        grid=(),
        in_specs=[spec] * 9,
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(shape, jnp.float32),
        interpret=True,
    )(q1, q2, q3, c1, c2, c3, cb, vsa, vnoise)
