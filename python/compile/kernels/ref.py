"""Pure-jnp oracles for every L1 kernel.

Each function here is the *specification*: the Pallas kernels in
``bitwise.py`` and ``dra_analog.py`` must match these bit-for-bit
(``test_kernel.py`` / ``test_analog.py`` assert it), and the Rust functional
simulator is validated against the AOT-lowered versions of the same graphs.
"""

import jax.numpy as jnp

from .. import params as P

# --------------------------------------------------------------------------
# Bulk bit-wise ops over packed int32 words (one lane = 32 bit-lines)
# --------------------------------------------------------------------------


def xnor2(a, b):
    return ~(a ^ b)


def xor2(a, b):
    return a ^ b


def and2(a, b):
    return a & b


def or2(a, b):
    return a | b


def nand2(a, b):
    return ~(a & b)


def nor2(a, b):
    return ~(a | b)


def not1(a):
    return ~a


def maj3(a, b, c):
    """Bit-wise 3-input majority — the TRA primitive (carry of a full adder)."""
    return (a & b) | (a & c) | (b & c)


def min3(a, b, c):
    return ~maj3(a, b, c)


def bitplane_add(a_planes, b_planes, carry_in=None):
    """Ripple-carry addition over bit-planes (paper §3.1 In-Memory Adder).

    ``a_planes[i]``/``b_planes[i]`` hold bit ``i`` (LSB first) of many
    elements, packed 32 per int32 word.  Per plane: ``sum = a ^ b ^ c`` (two
    back-to-back DRA XOR2s) and ``c' = MAJ3(a, b, c)`` (one TRA).  Returns
    ``(sum_planes, carry_out_plane)``.
    """
    bits = a_planes.shape[0]
    c = jnp.zeros_like(a_planes[0]) if carry_in is None else carry_in
    sums = []
    for i in range(bits):
        ai, bi = a_planes[i], b_planes[i]
        sums.append(ai ^ bi ^ c)
        c = maj3(ai, bi, c)
    return jnp.stack(sums), c


# --------------------------------------------------------------------------
# Analog sense amplification (behavioural; see params.py for the circuit)
# --------------------------------------------------------------------------


def dra_sense(qi, qj, ci, cj, cp, vsl, vsh, vnoise):
    """Reconfigurable-SA evaluation of the DRA charge-sharing state.

    All arguments broadcast elementwise (trials × cases in the MC sweep).
      qi/qj  — cell charges (C·V, unit-capacitor units × volts)
      ci/cj  — cell capacitances (unit-capacitor units)
      cp     — sense-node parasitic capacitance (precharged to Vdd/2)
      vsl/vsh— low-/high-Vs inverter switching thresholds
      vnoise — additive sense-node noise (volts)
    Returns (xnor_bl, xor_blbar) as float 0/1 arrays.
    """
    v = (qi + qj + cp * (P.VDD / 2.0)) / (ci + cj + cp) + vnoise
    nor_out = (v < vsl).astype(jnp.float32)   # low-Vs inverter: NOR2
    nand_out = (v < vsh).astype(jnp.float32)  # high-Vs inverter: NAND2
    xor_out = nand_out * (1.0 - nor_out)      # AND(NAND, OR)  → XOR2 on BL̄
    return 1.0 - xor_out, xor_out             # XNOR2 on BL, XOR2 on BL̄


def tra_sense(q1, q2, q3, c1, c2, c3, cb, vsa, vnoise):
    """Conventional-SA evaluation of Ambit's triple-row activation.

    The bit-line (capacitance ``cb``, precharged to Vdd/2) shares charge
    with three cells; the SA resolves against threshold ``vsa`` → MAJ3.
    """
    v = (q1 + q2 + q3 + cb * (P.VDD / 2.0)) / (c1 + c2 + c3 + cb) + vnoise
    return (v > vsa).astype(jnp.float32)


def dra_ideal_levels():
    """Ideal DRA sense-node voltages for n = 0, 1, 2 cells storing '1'."""
    c = 2.0 + P.CP_RATIO
    return [(n * P.VDD + P.CP_RATIO * P.VDD / 2.0) / c for n in range(3)]


def tra_ideal_levels():
    """Ideal TRA bit-line voltages for n = 0..3 cells storing '1'."""
    c = 3.0 + P.CB_RATIO
    return [(n * P.VDD + P.CB_RATIO * P.VDD / 2.0) / c for n in range(4)]
