# L1: Pallas kernels for the paper's compute hot-spots.
from . import bitwise, dra_analog, ref, transient  # noqa: F401
