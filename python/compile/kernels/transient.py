"""Fig. 6 transient model: DRA waveforms through P.S. → C.S.S. → S.A.S.

Behavioural replacement for the paper's Cadence Spectre transient simulation
(substitution ledger in DESIGN.md): a forward-Euler RC network integrated
with ``lax.scan``.  State per input case:

    v_bl    — sense-node / bit-line voltage (what Fig. 6 plots as BL)
    v_blb   — complement bit-line
    v_ci    — voltage across Di's cell capacitor (Vcap-Di)
    v_cj    — voltage across Dj's cell capacitor (Vcap-Dj)

Phases (params.py):
  P.S.   : BL/BL̄ held at Vdd/2 by the precharge unit; cells hold their data.
  C.S.S. : WLx1+WLx2 raised — cells and sense node relax toward the common
           charge-sharing voltage (charge-conserving RC exchange).
  S.A.S. : En_x/En_C raised — the reconfigurable SA regenerates BL to the
           XNOR2 rail (Vdd when Di⊙Dj=1, GND otherwise), BL̄ to the XOR2
           rail, and the open word-lines restore the cells to BL's value —
           this is the write-back visible in Fig. 6.

The per-step update is a small closed-form dataflow, so it stays at L2
(pure jnp inside ``lax.scan``); the per-element analog *decision* model it
shares with the MC kernels lives in L1 (``dra_analog.py``).
"""

import jax
import jax.numpy as jnp

from .. import params as P


def _share_target(v_ci, v_cj, v_node):
    """Charge-conserving equilibrium of {Ci, Cj, Cp} connected together."""
    csum = 2.0 + P.CP_RATIO
    return (v_ci + v_cj + P.CP_RATIO * v_node) / csum


def _xnor_rail(di, dj):
    """Ideal SA decision: the rail BL regenerates to during S.A.S."""
    same = jnp.equal(di > 0.5, dj > 0.5)
    return jnp.where(same, P.VDD, 0.0)


def waveforms(cases):
    """Integrate the DRA transient for a batch of input cases.

    ``cases``: f32[N, 2] of (Di, Dj) logic values (0.0 / 1.0).
    Returns f32[N, TRANSIENT_STEPS, 4]: (BL, BL̄, Vcap-Di, Vcap-Dj) per step.
    """
    n = cases.shape[0]
    di, dj = cases[:, 0], cases[:, 1]
    p_end, s_end = P.transient_phase_bounds()

    rail = _xnor_rail(di, dj)

    state0 = {
        "v_bl": jnp.full((n,), P.VDD / 2.0),
        "v_blb": jnp.full((n,), P.VDD / 2.0),
        "v_ci": di * P.VDD,
        "v_cj": dj * P.VDD,
    }

    a_share = P.DT_NS / P.TAU_SHARE_NS
    a_sense = P.DT_NS / P.TAU_SENSE_NS
    a_cell = P.DT_NS / P.TAU_CELL_NS

    def step(state, t):
        in_share = jnp.logical_and(t >= p_end, t < s_end)
        in_sense = t >= s_end

        veq = _share_target(state["v_ci"], state["v_cj"], state["v_bl"])

        # C.S.S.: everything relaxes toward the charge-sharing equilibrium.
        bl_share = state["v_bl"] + a_share * (veq - state["v_bl"])
        ci_share = state["v_ci"] + a_share * (veq - state["v_ci"])
        cj_share = state["v_cj"] + a_share * (veq - state["v_cj"])

        # S.A.S.: BL regenerates to the XNOR rail, BL̄ to its complement,
        # cells restore through the (still-open) access transistors.
        bl_sense = state["v_bl"] + a_sense * (rail - state["v_bl"])
        blb_sense = state["v_blb"] + a_sense * ((P.VDD - rail) - state["v_blb"])
        ci_sense = state["v_ci"] + a_cell * (state["v_bl"] - state["v_ci"])
        cj_sense = state["v_cj"] + a_cell * (state["v_bl"] - state["v_cj"])

        new = {
            "v_bl": jnp.where(
                in_sense, bl_sense, jnp.where(in_share, bl_share, state["v_bl"])
            ),
            "v_blb": jnp.where(in_sense, blb_sense, state["v_blb"]),
            "v_ci": jnp.where(
                in_sense, ci_sense, jnp.where(in_share, ci_share, state["v_ci"])
            ),
            "v_cj": jnp.where(
                in_sense, cj_sense, jnp.where(in_share, cj_share, state["v_cj"])
            ),
        }
        out = jnp.stack(
            [new["v_bl"], new["v_blb"], new["v_ci"], new["v_cj"]], axis=-1
        )
        return new, out

    _, traj = jax.lax.scan(step, state0, jnp.arange(P.TRANSIENT_STEPS))
    return jnp.transpose(traj, (1, 0, 2))  # → [N, T, 4]
