"""L1 Pallas kernels: bulk bit-wise ops over packed int32 lanes.

This is the compute hot-spot of the paper expressed for the TPU-style memory
hierarchy (DESIGN.md §Hardware-Adaptation): a DRAM row maps onto a
VMEM-resident tile of packed int32 lanes, sub-array-level parallelism maps
onto the Pallas grid.  Every kernel is lowered with ``interpret=True`` so the
resulting HLO runs on the CPU PJRT client that the Rust runtime embeds
(real-TPU lowering emits Mosaic custom-calls the CPU plugin cannot execute).

Kernels:
  * ``bulk(op)``         — elementwise 1/2/3-operand bit-ops on (R, L) i32
  * ``bitplane_add``     — ripple-carry adder over bit-planes: the paper's
                           Sum = XOR2∘XOR2 (DRA), Carry = MAJ3 (TRA) schedule
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# --------------------------------------------------------------------------
# elementwise bulk ops
# --------------------------------------------------------------------------

#: op name → (arity, lane function).  The lane functions mirror ref.py and,
#: on the Rust side, ``subarray``'s digital charge-sharing model.
OPS = {
    "xnor2": (2, lambda a, b: ~(a ^ b)),
    "xor2": (2, lambda a, b: a ^ b),
    "and2": (2, lambda a, b: a & b),
    "or2": (2, lambda a, b: a | b),
    "nand2": (2, lambda a, b: ~(a & b)),
    "nor2": (2, lambda a, b: ~(a | b)),
    "not1": (1, lambda a: ~a),
    "maj3": (3, lambda a, b, c: (a & b) | (a & c) | (b & c)),
    "min3": (3, lambda a, b, c: ~((a & b) | (a & c) | (b & c))),
}


def _elementwise_kernel(fn, *refs):
    *in_refs, o_ref = refs
    o_ref[...] = fn(*(r[...] for r in in_refs))


def _row_block(rows, lanes):
    """Block over full lanes, tiling the row axis — the VMEM-friendly shape
    ((sub-)array rows stream through the on-chip buffer row-block at a
    time, all bit-lines of a row in parallel)."""
    block_rows = min(rows, 64)
    if rows % block_rows != 0:  # odd shapes (tests): single block
        block_rows = rows
    grid = (rows // block_rows,)
    spec = pl.BlockSpec((block_rows, lanes), lambda i: (i, 0))
    return grid, spec


def bulk(op: str):
    """Return a jit-able ``f(*operands) -> result`` for a named bulk op.

    Operands are int32 arrays of identical shape ``(rows, lanes)``; every
    int32 packs 32 bit-lines.
    """
    arity, fn = OPS[op]

    def run(*operands):
        assert len(operands) == arity, (op, arity, len(operands))
        a = operands[0]
        rows, lanes = a.shape
        grid, spec = _row_block(rows, lanes)
        return pl.pallas_call(
            functools.partial(_elementwise_kernel, fn),
            grid=grid,
            in_specs=[spec] * arity,
            out_specs=spec,
            out_shape=jax.ShapeDtypeStruct((rows, lanes), jnp.int32),
            interpret=True,
        )(*operands)

    run.__name__ = f"bulk_{op}"
    return run


# --------------------------------------------------------------------------
# bit-plane ripple-carry adder
# --------------------------------------------------------------------------


def _add_kernel(a_ref, b_ref, cin_ref, sum_ref, cout_ref):
    """DRIM's in-memory adder schedule over one block of packed words.

    Bit-plane i of the sum needs two DRA XOR2s (a⊕b, then ⊕carry) and the
    next carry needs one TRA MAJ3 — exactly the AAP sequence of Table 2,
    executed here per 32-bit-packed lane.  The carry ripples across planes
    (rows), all lanes in parallel, matching the row-parallel / bit-serial
    split of the DRAM array.
    """
    bits = a_ref.shape[0]
    carry = cin_ref[...]

    def body(i, carry):
        ai = a_ref[i, :]
        bi = b_ref[i, :]
        axb = ai ^ bi                      # DRA #1
        sum_ref[i, :] = axb ^ carry        # DRA #2
        return (ai & bi) | (carry & axb)   # TRA (MAJ3, factored form)

    carry = jax.lax.fori_loop(0, bits, body, carry)
    cout_ref[...] = carry


def bitplane_add(a_planes, b_planes, carry_in=None):
    """``(sum_planes, carry_out)`` for bit-plane-major packed operands.

    ``a_planes``/``b_planes``: int32[BITS, WORDS], LSB plane first.
    """
    bits, words = a_planes.shape
    if carry_in is None:
        carry_in = jnp.zeros((words,), jnp.int32)
    plane_spec = pl.BlockSpec((bits, words), lambda: (0, 0))
    word_spec = pl.BlockSpec((words,), lambda: (0,))
    return pl.pallas_call(
        _add_kernel,
        grid=(),
        in_specs=[plane_spec, plane_spec, word_spec],
        out_specs=[plane_spec, word_spec],
        out_shape=[
            jax.ShapeDtypeStruct((bits, words), jnp.int32),
            jax.ShapeDtypeStruct((words,), jnp.int32),
        ],
        interpret=True,
    )(a_planes, b_planes, carry_in)
