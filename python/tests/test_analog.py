"""DRA/TRA analog sense kernels: Pallas vs ref, margin geometry, Table-3
statistical properties."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile import model, params as P
from compile.kernels import dra_analog, ref


def test_dra_ideal_levels_margins():
    """The circuit's margin geometry (DESIGN.md): DRA worst margin > TRA's."""
    lv = ref.dra_ideal_levels()
    assert lv[1] == pytest.approx(P.VDD / 2, abs=1e-9)  # midpoint preserved
    dra_margins = [
        abs(lv[0] - P.VS_LOW),
        abs(lv[1] - P.VS_LOW),
        abs(lv[1] - P.VS_HIGH),
        abs(lv[2] - P.VS_HIGH),
    ]
    tv = ref.tra_ideal_levels()
    tra_margins = [abs(v - P.VSA) for v in tv]
    assert min(dra_margins) > min(tra_margins), (dra_margins, tra_margins)


def test_dra_truth_table_noiseless():
    """With no variation, the reconfigurable SA computes exact XNOR/XOR."""
    di = np.array([[0.0, 0.0, 1.0, 1.0]], np.float32)
    dj = np.array([[0.0, 1.0, 0.0, 1.0]], np.float32)
    one = np.ones_like(di)
    zero = np.zeros_like(di)
    xnor, xor = dra_analog.dra_sense(
        di * P.VDD, dj * P.VDD, one, one, P.CP_RATIO * one,
        P.VS_LOW * one, P.VS_HIGH * one, zero,
    )
    np.testing.assert_array_equal(np.asarray(xnor), [[1, 0, 0, 1]])
    np.testing.assert_array_equal(np.asarray(xor), [[0, 1, 1, 0]])


def test_tra_truth_table_noiseless():
    cases = [(n >> 2 & 1, n >> 1 & 1, n & 1) for n in range(8)]
    e = np.array(cases, np.float32).T.reshape(3, 1, 8)
    one = np.ones((1, 8), np.float32)
    maj = dra_analog.tra_sense(
        e[0, 0] * P.VDD * one, e[1, 0] * P.VDD * one, e[2, 0] * P.VDD * one,
        one, one, one, P.CB_RATIO * one, P.VSA * one, np.zeros_like(one),
    )
    want = [[int(a + b + c >= 2) for a, b, c in cases]]
    np.testing.assert_array_equal(np.asarray(maj), want)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), trials=st.integers(1, 64))
def test_pallas_sense_matches_ref(seed, trials):
    """The Pallas kernels and the jnp oracle agree on arbitrary instances."""
    rng = np.random.default_rng(seed)
    s = (trials, 4)
    f32 = lambda lo, hi: rng.uniform(lo, hi, size=s).astype(np.float32)
    ci, cj = f32(0.7, 1.3), f32(0.7, 1.3)
    di, dj = rng.integers(0, 2, size=s).astype(np.float32), rng.integers(
        0, 2, size=s
    ).astype(np.float32)
    qi, qj = ci * di * P.VDD, cj * dj * P.VDD
    cp = f32(0.3, 0.9)
    vsl, vsh = f32(0.2, 0.4), f32(0.8, 1.0)
    vn = f32(-0.2, 0.2)
    got = dra_analog.dra_sense(qi, qj, ci, cj, cp, vsl, vsh, vn)
    want = ref.dra_sense(*(jnp.asarray(x) for x in (qi, qj, ci, cj, cp, vsl, vsh, vn)))
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(want[1]))

    cb, vsa = f32(2.0, 4.0), f32(0.5, 0.7)
    dk = rng.integers(0, 2, size=s).astype(np.float32)
    ck = f32(0.7, 1.3)
    qk = ck * dk * P.VDD
    got_t = dra_analog.tra_sense(qi, qj, qk, ci, cj, ck, cb, vsa, vn)
    want_t = ref.tra_sense(
        *(jnp.asarray(x) for x in (qi, qj, qk, ci, cj, ck, cb, vsa, vn))
    )
    np.testing.assert_array_equal(np.asarray(got_t), np.asarray(want_t))


# --------------------------------------------------------------------------
# Table-3 statistics
# --------------------------------------------------------------------------

KEY = np.array([7, 9], np.uint32)


def rates(variation):
    d, t, nd, nt = model.mc_variation(KEY, jnp.float32(variation))
    return float(d) / float(nd) * 100.0, float(t) / float(nt) * 100.0


def test_mc_zero_variation_is_error_free():
    d, t = rates(0.0)
    assert d == 0.0 and t == 0.0


def test_mc_dra_below_tra_at_all_levels():
    """Paper Table 3: DRA is strictly more robust than TRA everywhere."""
    for v in (0.05, 0.10, 0.15, 0.20, 0.30):
        d, t = rates(v)
        assert d <= t, (v, d, t)


def test_mc_dra_clean_at_ten_percent():
    """The headline reliability claim: DRA error ≈ 0 % at ±10 %."""
    d, _ = rates(0.10)
    assert d < 0.05


def test_mc_tra_nonzero_at_ten_percent():
    _, t = rates(0.10)
    assert 0.02 < t < 1.5  # paper: 0.18 %


def test_mc_monotone_in_variation():
    seq = [rates(v) for v in (0.05, 0.10, 0.15, 0.20, 0.30)]
    dra = [d for d, _ in seq]
    tra = [t for _, t in seq]
    assert dra == sorted(dra)
    assert tra == sorted(tra)


def test_mc_pallas_and_ref_paths_agree():
    """Swapping the Pallas sense kernels for the jnp oracle must not change
    the sampled statistics at all (same PRNG stream, same decisions)."""
    for v in (0.10, 0.20):
        a = model.mc_variation(KEY, jnp.float32(v))
        b = model.mc_variation_ref(KEY, jnp.float32(v))
        assert [int(x) for x in a] == [int(x) for x in b]
