"""Pallas bulk-op kernels vs the pure-jnp oracle — the CORE L1 correctness
signal.  Hypothesis sweeps shapes and operand patterns."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import bitwise, ref

RNG = np.random.default_rng(0xD21)


def rand_words(shape):
    return RNG.integers(-(2**31), 2**31 - 1, size=shape, dtype=np.int32)


REF = {
    "xnor2": ref.xnor2,
    "xor2": ref.xor2,
    "and2": ref.and2,
    "or2": ref.or2,
    "nand2": ref.nand2,
    "nor2": ref.nor2,
    "not1": ref.not1,
    "maj3": ref.maj3,
    "min3": ref.min3,
}


@pytest.mark.parametrize("op", sorted(bitwise.OPS))
def test_bulk_matches_ref_at_artifact_shape(op):
    arity, _ = bitwise.OPS[op]
    ops = [rand_words((512, 128)) for _ in range(arity)]
    got = np.asarray(bitwise.bulk(op)(*ops))
    want = np.asarray(REF[op](*(jnp.asarray(o) for o in ops)))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=25, deadline=None)
@given(
    rows=st.integers(1, 96),
    lanes=st.sampled_from([1, 2, 8, 128]),
    op=st.sampled_from(sorted(bitwise.OPS)),
    seed=st.integers(0, 2**31 - 1),
)
def test_bulk_matches_ref_any_shape(rows, lanes, op, seed):
    rng = np.random.default_rng(seed)
    arity, _ = bitwise.OPS[op]
    ops = [
        rng.integers(-(2**31), 2**31 - 1, size=(rows, lanes), dtype=np.int32)
        for _ in range(arity)
    ]
    got = np.asarray(bitwise.bulk(op)(*ops))
    want = np.asarray(REF[op](*(jnp.asarray(o) for o in ops)))
    np.testing.assert_array_equal(got, want)


def test_bulk_truth_tables_exhaustive():
    """Exhaustive 1-bit truth table for every op, checked against python ints."""
    cases2 = [(0, 0), (0, 1), (1, 0), (1, 1)]
    tt = {
        "xnor2": lambda a, b: 1 - (a ^ b),
        "xor2": lambda a, b: a ^ b,
        "and2": lambda a, b: a & b,
        "or2": lambda a, b: a | b,
        "nand2": lambda a, b: 1 - (a & b),
        "nor2": lambda a, b: 1 - (a | b),
    }
    for op, fn in tt.items():
        a = np.array([[c[0] for c in cases2]], np.int32)
        b = np.array([[c[1] for c in cases2]], np.int32)
        got = np.asarray(bitwise.bulk(op)(a, b)) & 1
        want = np.array([[fn(*c) for c in cases2]], np.int32)
        np.testing.assert_array_equal(got, want, err_msg=op)
    cases3 = [(i >> 2 & 1, i >> 1 & 1, i & 1) for i in range(8)]
    a = np.array([[c[0] for c in cases3]], np.int32)
    b = np.array([[c[1] for c in cases3]], np.int32)
    c = np.array([[c[2] for c in cases3]], np.int32)
    got = np.asarray(bitwise.bulk("maj3")(a, b, c)) & 1
    want = np.array([[int(x + y + z >= 2) for x, y, z in cases3]], np.int32)
    np.testing.assert_array_equal(got, want)


# --------------------------------------------------------------------------
# bit-plane adder
# --------------------------------------------------------------------------


def unpack_planes(planes):
    """int32[BITS, W] bit-planes → uint64[W*32] element values."""
    bits, w = planes.shape
    u = planes.astype(np.uint32)
    elems = np.zeros(w * 32, dtype=np.uint64)
    for i in range(bits):
        plane_bits = np.unpackbits(
            u[i].view(np.uint8).reshape(w, 4)[:, ::-1], axis=1, bitorder="big"
        ).reshape(-1)[::-1]  # little-endian bit order across the word
        # simpler: bit j of word k = (u[i,k] >> j) & 1
        for k in range(w):
            word = int(u[i, k])
            for j in range(32):
                if (word >> j) & 1:
                    elems[k * 32 + j] |= np.uint64(1 << i)
    return elems


def pack_planes(values, bits, w):
    planes = np.zeros((bits, w), dtype=np.uint32)
    for i in range(bits):
        for k in range(w):
            word = 0
            for j in range(32):
                if (int(values[k * 32 + j]) >> i) & 1:
                    word |= 1 << j
            planes[i, k] = word
    return planes.astype(np.int32)


@pytest.mark.parametrize("bits,w", [(4, 2), (8, 4), (16, 2)])
def test_bitplane_add_matches_integer_add(bits, w):
    rng = np.random.default_rng(bits * 100 + w)
    av = rng.integers(0, 2**bits, size=w * 32).astype(np.uint64)
    bv = rng.integers(0, 2**bits, size=w * 32).astype(np.uint64)
    ap = pack_planes(av, bits, w)
    bp = pack_planes(bv, bits, w)
    s, cout = bitwise.bitplane_add(ap, bp)
    sv = unpack_planes(np.asarray(s))
    want = (av + bv) % (1 << bits)
    want_c = ((av + bv) >> bits) & 1
    np.testing.assert_array_equal(sv, want)
    got_c = np.array(
        [(int(np.asarray(cout).view(np.uint32)[k]) >> j) & 1 for k in range(w) for j in range(32)],
        dtype=np.uint64,
    )
    np.testing.assert_array_equal(got_c, want_c)


def test_bitplane_add_matches_ref_oracle():
    rng = np.random.default_rng(42)
    ap = rng.integers(-(2**31), 2**31 - 1, size=(32, 64), dtype=np.int32)
    bp = rng.integers(-(2**31), 2**31 - 1, size=(32, 64), dtype=np.int32)
    s, c = bitwise.bitplane_add(ap, bp)
    rs, rc = ref.bitplane_add(jnp.asarray(ap), jnp.asarray(bp))
    np.testing.assert_array_equal(np.asarray(s), np.asarray(rs))
    np.testing.assert_array_equal(np.asarray(c), np.asarray(rc))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), bits=st.integers(1, 32))
def test_bitplane_add_carry_in_chains(seed, bits):
    """Adding with carry_in=carry_out of a previous add == wider addition —
    the invariant DRIM's multi-word adds rely on."""
    rng = np.random.default_rng(seed)
    w = 2
    ap = rng.integers(-(2**31), 2**31 - 1, size=(bits, w), dtype=np.int32)
    bp = rng.integers(-(2**31), 2**31 - 1, size=(bits, w), dtype=np.int32)
    s1, c1 = bitwise.bitplane_add(ap, bp)
    rs, rc = ref.bitplane_add(jnp.asarray(ap), jnp.asarray(bp))
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(rs))
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(rc))
    # chain: (a+b) + (a+b) with carry in
    s2, c2 = bitwise.bitplane_add(np.asarray(s1), np.asarray(s1), np.asarray(c1))
    rs2, rc2 = ref.bitplane_add(rs, rs, rc)
    np.testing.assert_array_equal(np.asarray(s2), np.asarray(rs2))
    np.testing.assert_array_equal(np.asarray(c2), np.asarray(rc2))
