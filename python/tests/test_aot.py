"""AOT pipeline: every artifact lowers to parseable HLO text with a correct
manifest, and the lowered modules contain no dynamic shapes."""

import os
import re

import pytest

from compile import aot, model, params as P


@pytest.fixture(scope="module")
def out(tmp_path_factory):
    d = str(tmp_path_factory.mktemp("artifacts"))
    names = aot.lower_all(d)
    return d, names


def test_all_artifacts_written(out):
    d, names = out
    assert len(names) == len(aot.artifact_table())
    for n in names:
        p = os.path.join(d, f"{n}.hlo.txt")
        assert os.path.exists(p) and os.path.getsize(p) > 0


def test_hlo_text_is_hlo(out):
    d, names = out
    for n in names:
        with open(os.path.join(d, f"{n}.hlo.txt")) as f:
            text = f.read()
        assert text.startswith("HloModule"), n
        assert "ENTRY" in text, n
        # 0.5.1-incompatible 64-bit ids never appear in text form, but
        # guard against accidental proto dumps:
        assert "\x00" not in text, n


def test_manifest_lines_parse(out):
    d, names = out
    spec_re = re.compile(
        r"^(\w+)\t([\w.]+)\tin=([\w\[\],]+)\tout=([\w\[\],]+)\tsha256=([0-9a-f]{16})$"
    )
    with open(os.path.join(d, "manifest.txt")) as f:
        lines = [l for l in f.read().splitlines() if l and not l.startswith("#")]
    assert len(lines) == len(names)
    for line in lines:
        m = spec_re.match(line)
        assert m, line
        assert m.group(1) in names


def test_manifest_params_header(out):
    """The Rust analog mirror reads its constants from this header line."""
    d, _ = out
    with open(os.path.join(d, "manifest.txt")) as f:
        header = f.read().splitlines()[2]
    for k, v in (
        ("vdd", P.VDD),
        ("cp_ratio", P.CP_RATIO),
        ("cb_ratio", P.CB_RATIO),
        ("noise_lin", P.NOISE_LIN),
        ("noise_quad", P.NOISE_QUAD),
        ("trials", P.MC_TRIALS),
    ):
        assert f"{k}={v}" in header, (k, header)


def test_bulk_artifact_shapes_match_params(out):
    d, _ = out
    with open(os.path.join(d, "bulk_xnor2.hlo.txt")) as f:
        text = f.read()
    assert f"s32[{P.BITWISE_ROWS},{P.BITWISE_LANES}]" in text


def test_mc_artifact_declares_scalar_inputs(out):
    d, _ = out
    with open(os.path.join(d, "mc_variation.hlo.txt")) as f:
        text = f.read()
    assert "u32[2]" in text and "f32[]" in text
