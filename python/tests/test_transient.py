"""Fig. 6 transient model: phase behaviour and end states."""

import numpy as np
import pytest

from compile import model, params as P

CASES = np.array([[0, 0], [0, 1], [1, 0], [1, 1]], np.float32)


@pytest.fixture(scope="module")
def traj():
    return np.asarray(model.transient_waveforms(CASES)[0])  # [4, T, 4]


def test_shapes(traj):
    assert traj.shape == (4, P.TRANSIENT_STEPS, 4)


def test_precharge_state_holds(traj):
    """During P.S. the bit-lines sit at Vdd/2 and cells hold their data."""
    p_end, _ = P.transient_phase_bounds()
    ps = traj[:, : p_end - 1, :]
    np.testing.assert_allclose(ps[:, :, 0], P.VDD / 2, atol=1e-6)  # BL
    np.testing.assert_allclose(ps[:, :, 1], P.VDD / 2, atol=1e-6)  # BL̄
    for c, (di, dj) in enumerate(CASES):
        np.testing.assert_allclose(ps[c, :, 2], di * P.VDD, atol=1e-6)
        np.testing.assert_allclose(ps[c, :, 3], dj * P.VDD, atol=1e-6)


def test_charge_sharing_moves_toward_equilibrium(traj):
    """During C.S.S. the BL approaches n·Vdd/C (paper Eq. for V_i)."""
    _, s_end = P.transient_phase_bounds()
    csum = 2.0 + P.CP_RATIO
    for c, (di, dj) in enumerate(CASES):
        veq = (di * P.VDD + dj * P.VDD + P.CP_RATIO * P.VDD / 2) / csum
        v_end_share = traj[c, s_end - 1, 0]
        # moved at least 85 % of the way from Vdd/2 to the equilibrium
        assert abs(v_end_share - veq) < 0.15 * abs(P.VDD / 2 - veq) + 1e-3, (
            c, v_end_share, veq,
        )


def test_sense_amplification_reaches_xnor_rail(traj):
    """Fig. 6's money shot: BL → Vdd for Di⊙Dj=1 (00/11), → GND for 01/10,
    and the cell capacitors are overwritten with the result (write-back)."""
    for c, (di, dj) in enumerate(CASES):
        want = P.VDD if di == dj else 0.0
        assert abs(traj[c, -1, 0] - want) < 0.01, (c, traj[c, -1, 0], want)
        assert abs(traj[c, -1, 1] - (P.VDD - want)) < 0.01  # BL̄ complement
        assert abs(traj[c, -1, 2] - want) < 0.05  # Vcap-Di restored
        assert abs(traj[c, -1, 3] - want) < 0.05  # Vcap-Dj restored


def test_rails_are_monotone_in_sense_phase(traj):
    """After S.A.S. begins, BL moves monotonically to its rail."""
    _, s_end = P.transient_phase_bounds()
    for c, (di, dj) in enumerate(CASES):
        bl = traj[c, s_end:, 0]
        d = np.diff(bl)
        if di == dj:
            assert (d >= -1e-6).all()
        else:
            assert (d <= 1e-6).all()


def test_voltages_bounded(traj):
    assert (traj >= -1e-6).all() and (traj <= P.VDD + 1e-6).all()
