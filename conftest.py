# Make `pytest python/tests/ -q` work from the repo root: the test-suite
# imports the build-time `compile` package relative to python/.
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "python"))
