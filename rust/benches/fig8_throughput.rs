//! Fig. 8 regeneration: raw throughput of all 8 platforms × {NOT, XNOR2,
//! ADD} × {2^27, 2^28, 2^29}-bit vectors, printed as the paper's series
//! plus the headline speedup ratios. Also *executes* a scaled-down DRIM
//! workload on the functional simulator to verify the model's command
//! counts against real execution.

use drim::coordinator::{BulkRequest, DrimService, Payload, ServiceConfig};
use drim::isa::program::BulkOp;
use drim::platforms::{all_platforms, by_name, FIG8_OPS};
use drim::util::bitrow::BitRow;
use drim::util::rng::Rng;
use drim::util::stats::fmt_rate;
use drim::util::table::Table;

fn main() {
    println!("=== Fig. 8: throughput of different platforms (result bits/s) ===\n");
    for log2 in [27u32, 28, 29] {
        let bits = 1u64 << log2;
        println!("-- vector length 2^{log2} bits --");
        let mut t = Table::new(&["platform", "NOT", "XNOR2", "ADD"]);
        for p in all_platforms() {
            t.row(&[
                p.name().to_string(),
                fmt_rate(p.throughput_bits_per_sec(BulkOp::Not, bits)),
                fmt_rate(p.throughput_bits_per_sec(BulkOp::Xnor2, bits)),
                fmt_rate(p.throughput_bits_per_sec(BulkOp::Add, bits)),
            ]);
        }
        t.print();
        println!();
    }

    let bits = 1u64 << 29;
    let tp = |n: &str, op: BulkOp| by_name(n).unwrap().throughput_bits_per_sec(op, bits);
    let avg = |a: &str, b: &str| {
        FIG8_OPS
            .iter()
            .map(|&op| tp(a, op) / tp(b, op))
            .sum::<f64>()
            / FIG8_OPS.len() as f64
    };
    println!("headline ratios (measured | paper):");
    println!("  DRIM-R/CPU avg      {:7.1}x | 71x", avg("DRIM-R", "CPU"));
    println!("  DRIM-R/GPU avg      {:7.1}x | 8.4x", avg("DRIM-R", "GPU"));
    println!("  HMC/CPU avg         {:7.1}x | ~25x", avg("HMC", "CPU"));
    println!("  HMC/GPU avg         {:7.1}x | ~6.5x", avg("HMC", "GPU"));
    println!(
        "  DRIM-R/Ambit xnor   {:7.1}x | 2.3x",
        tp("DRIM-R", BulkOp::Xnor2) / tp("Ambit", BulkOp::Xnor2)
    );
    println!(
        "  DRIM-R/1T1C xnor    {:7.1}x | 1.9x",
        tp("DRIM-R", BulkOp::Xnor2) / tp("DRISA-1T1C", BulkOp::Xnor2)
    );
    println!(
        "  DRIM-R/3T1C xnor    {:7.1}x | 3.7x",
        tp("DRIM-R", BulkOp::Xnor2) / tp("DRISA-3T1C", BulkOp::Xnor2)
    );
    println!("  DRIM-S/HMC avg      {:7.1}x | 13.5x", avg("DRIM-S", "HMC"));

    // ---- model-vs-execution cross check --------------------------------
    println!("\n=== functional-simulator cross-check (scaled workload) ===");
    let service = DrimService::new(ServiceConfig::default());
    let mut rng = Rng::new(1);
    let payload_bits = 1usize << 22; // 4 Mbit — real execution, same math
    for op in [BulkOp::Not, BulkOp::Xnor2] {
        let operands: Vec<BitRow> = (0..op.arity())
            .map(|_| BitRow::random(payload_bits, &mut rng))
            .collect();
        let resp = service.run(BulkRequest::bitwise(op, operands));
        assert!(matches!(resp.result, Payload::Bits(_)));
        let model = by_name("DRIM-R")
            .unwrap()
            .throughput_bits_per_sec(op, payload_bits as u64);
        let sim = payload_bits as f64 / (resp.sim_latency_ns * 1e-9);
        println!(
            "  {:6}: simulated {}bit/s vs model {}bit/s (ratio {:.2})",
            op.name(),
            fmt_rate(sim),
            fmt_rate(model),
            sim / model
        );
        assert!(
            (0.5..2.0).contains(&(sim / model)),
            "simulated and modeled throughput diverge"
        );
    }
    println!("\nfig8 bench OK");
}
