//! Tracing-overhead bench: the fleet serving workload with the tracer
//! idle (sampling off), and — when the `trace` feature is compiled in —
//! with full sampling, to price what recording actually costs.
//!
//! The CI overhead gate builds this binary twice, with default features
//! (`trace` on) and with `--no-default-features` (`trace` compiled out),
//! and compares the `pump_idle` min_ns across the two artifacts
//! (`BENCH_obs_overhead.json` vs `BENCH_obs_overhead_untraced.json`):
//! the trace feature with sampling off must stay within 5% of the
//! compiled-out baseline — the hot-path cost of an idle tracer is one
//! relaxed atomic load per event site.

use drim::cluster::{ClusterConfig, DrimCluster};
use drim::coordinator::{BulkRequest, ServiceConfig};
use drim::dram::geometry::DramGeometry;
use drim::isa::program::BulkOp;
use drim::scenario::{run_scenario, ScenarioSpec};
use drim::util::bench::{section, BenchReport, Bencher};
use drim::util::bitrow::BitRow;
use drim::util::rng::Rng;

const DEVICES: usize = 4;
const REQUESTS: usize = 256;
/// small requests so per-request pipeline overhead dominates the run
const BITS: usize = 4096;
const SEED: u64 = 0x0B5EA7;

/// Bench-sized device (same geometry as the ablation benches).
fn bench_service() -> ServiceConfig {
    ServiceConfig {
        geometry: DramGeometry {
            banks: 4,
            subarrays_per_bank: 8,
            cols: 1024,
            active_subarrays: 4,
        },
        workers: 2,
        ..ServiceConfig::default()
    }
}

/// Pump the serving mix through a fresh fleet with the given sampling
/// interval (0 = tracer idle).
fn pump(sampling: u32) {
    let cluster = DrimCluster::new(ClusterConfig {
        steal: false,
        ..ClusterConfig::uniform(DEVICES, bench_service())
    });
    cluster.tracer().set_sampling(sampling);
    let mut rng = Rng::new(SEED);
    let pending: Vec<_> = (0..REQUESTS)
        .map(|i| {
            let op = [BulkOp::Xnor2, BulkOp::Xor2, BulkOp::And2, BulkOp::Not][i % 4];
            let operands: Vec<BitRow> = (0..op.arity())
                .map(|_| BitRow::random(BITS, &mut rng))
                .collect();
            cluster.submit_blocking(BulkRequest::bitwise(op, operands))
        })
        .collect();
    for p in pending {
        p.recv().expect("response");
    }
    cluster.shutdown();
}

fn main() {
    let traced = cfg!(feature = "trace");
    section(if traced {
        "tracing overhead — `trace` feature ON"
    } else {
        "tracing overhead — `trace` feature compiled OUT"
    });
    println!("{REQUESTS} requests × {BITS} bits over {DEVICES} devices (steal off)\n");
    let b = Bencher {
        warmup_iters: 1,
        iters: 5,
    };
    // two artifact names so the CI gate can diff the feature-on and
    // feature-off builds side by side
    let mut report = BenchReport::new(if traced {
        "obs_overhead"
    } else {
        "obs_overhead_untraced"
    });
    report
        .config("devices", DEVICES)
        .config("requests", REQUESTS)
        .config("bits", BITS)
        .config("seed", SEED)
        .config("trace_feature", traced);

    let idle = b.run("pump_idle", REQUESTS as f64, || pump(0));
    report.measurement(&idle);

    // the in-artifact overhead gates: observed ratio, threshold, and
    // verdict all recorded so the BENCH artifact carries the verdicts
    // (`drim perf check` treats a pass→fail gate as a regression). The
    // gates are recorded rather than asserted — min-of-5 ratios are
    // noise-tolerant but not noise-free, and the artifact is the place
    // a borderline run should surface, not a bench panic.
    const OVERHEAD_THRESHOLD: f64 = 1.05;

    if traced {
        let sampled = b.run("pump_sampled", REQUESTS as f64, || pump(1));
        report.measurement(&sampled);
        let ratio = sampled.min_ns / idle.min_ns.max(1.0);
        report.metric("sampled_over_idle_ratio", ratio);
        report.metric("sampled_over_idle_threshold", OVERHEAD_THRESHOLD);
        report.gate(
            "sampled_over_idle_within_5pct",
            ratio <= OVERHEAD_THRESHOLD,
        );
    }

    // continuous-telemetry recorder overhead: the same scenario with the
    // virtual-clock time-series recorder off vs on at the default
    // sampling interval. The recorder is feature-independent (it rides
    // the scenario executor, not the tracer), so this gate runs in both
    // builds.
    section("telemetry recorder overhead (scenario executor)");
    let plain = ScenarioSpec::parse_str(SCENARIO_PLAIN).expect("plain probe scenario");
    let telem = ScenarioSpec::parse_str(SCENARIO_TELEMETRY).expect("telemetry probe scenario");
    let base = b.run("scenario_plain", REQUESTS as f64, || run_scenario(&plain));
    let with = b.run("scenario_telemetry", REQUESTS as f64, || run_scenario(&telem));
    let ratio = with.min_ns / base.min_ns.max(1.0);
    report.measurement(&base);
    report.measurement(&with);
    report.metric("telemetry_over_idle_ratio", ratio);
    report.metric("telemetry_over_idle_threshold", OVERHEAD_THRESHOLD);
    report.gate("telemetry_over_idle_within_5pct", ratio <= OVERHEAD_THRESHOLD);

    report.write();
    println!(
        "\nobs_overhead bench {} (telemetry ratio {ratio:.4})",
        if report.ok() { "OK" } else { "GATE FAILED" }
    );
}

/// The telemetry-overhead probe scenario: the serving mix re-expressed as
/// a scenario so the run goes through the executor (where the recorder
/// lives). Same fleet shape and request count as the pump above.
const SCENARIO_PLAIN: &str = r#"
name = "obs_overhead_probe"
description = "telemetry recorder overhead probe"
seed = 7

[fleet]
devices = 4
workers = 2

[arrival]
requests = 256

[[tenants]]
name = "t"
op = "xnor2"
bits = 4096
"#;

/// The same scenario with the time-series recorder on at its default
/// interval and capacity.
const SCENARIO_TELEMETRY: &str = r#"
name = "obs_overhead_probe"
description = "telemetry recorder overhead probe"
seed = 7

[fleet]
devices = 4
workers = 2

[arrival]
requests = 256

[telemetry]

[[tenants]]
name = "t"
op = "xnor2"
bits = 4096
"#;
