//! Fig. 9 regeneration: DRAM-side energy per KB for {copy, NOT, XNOR2,
//! ADD} across DRIM, Ambit, DRISA-1T1C and the CPU/DDR4 path, with the
//! paper's quoted ratios, plus an executed-energy cross-check from the
//! controller's per-AAP accounting.

use drim::controller::Controller;
use drim::dram::command::RowId::*;
use drim::dram::geometry::DramGeometry;
use drim::energy::EnergyModel;
use drim::isa::program::BulkOp;
use drim::platforms::by_name;
use drim::util::bitrow::BitRow;
use drim::util::rng::Rng;
use drim::util::table::Table;

fn main() {
    println!("=== Fig. 9: energy per KB of result (nJ) ===\n");
    let mut t = Table::new(&["platform", "copy", "NOT", "XNOR2", "ADD"]);
    for name in ["CPU", "Ambit", "DRISA-1T1C", "DRIM-R"] {
        let p = by_name(name).unwrap();
        let cell = |op: BulkOp| {
            p.energy_pj_per_kb(op)
                .map(|e| format!("{:.1}", e / 1e3))
                .unwrap_or("-".into())
        };
        t.row(&[
            name.to_string(),
            cell(BulkOp::Copy),
            cell(BulkOp::Not),
            cell(BulkOp::Xnor2),
            cell(BulkOp::Add),
        ]);
    }
    t.print();

    let e = |n: &str, op: BulkOp| by_name(n).unwrap().energy_pj_per_kb(op).unwrap();
    println!("\nratios (measured | paper):");
    println!(
        "  Ambit/DRIM xnor2      {:5.2}x | 2.4x",
        e("Ambit", BulkOp::Xnor2) / e("DRIM-R", BulkOp::Xnor2)
    );
    println!(
        "  DRISA-1T1C/DRIM xnor2 {:5.2}x | 1.6x",
        e("DRISA-1T1C", BulkOp::Xnor2) / e("DRIM-R", BulkOp::Xnor2)
    );
    println!(
        "  Ambit/DRIM add        {:5.2}x | ~2x",
        e("Ambit", BulkOp::Add) / e("DRIM-R", BulkOp::Add)
    );
    println!(
        "  DRISA-1T1C/DRIM add   {:5.2}x | 1.7x",
        e("DRISA-1T1C", BulkOp::Add) / e("DRIM-R", BulkOp::Add)
    );
    println!(
        "  CPU/DRIM add          {:5.1}x | 27x",
        e("CPU", BulkOp::Add) / e("DRIM-R", BulkOp::Add)
    );
    let m = EnergyModel::default();
    println!(
        "  DDR4-copy/DRIM-copy   {:5.1}x | 69x",
        m.ddr4_copy_pj(8192.0) / m.aap_pj(drim::dram::command::AapKind::Copy, 8192)
    );

    // ---- executed-energy cross-check -----------------------------------
    println!("\n=== controller accounting cross-check ===");
    let mut c = Controller::new(DramGeometry::default());
    let mut rng = Rng::new(2);
    let a = BitRow::random(8192, &mut rng);
    let b = BitRow::random(8192, &mut rng);
    c.write_row(0, 0, Data(0), &a);
    c.write_row(0, 0, Data(1), &b);
    let stats = c.exec_op(BulkOp::Xnor2, 0, 0, &[Data(0), Data(1)], Data(2));
    let model = e("DRIM-R", BulkOp::Xnor2);
    println!(
        "  executed XNOR2 on one 8Kb row: {:.1} nJ (model {:.1} nJ)",
        stats.energy_pj / 1e3,
        model / 1e3
    );
    assert!(
        (stats.energy_pj - model).abs() / model < 1e-6,
        "controller accounting and platform model must agree exactly"
    );
    println!("\nfig9 bench OK");
}
