//! L3 hot-path micro-benchmarks: the simulator code the whole Fig. 8 sweep
//! and the serving loop sit on. Used by the §Perf pass (EXPERIMENTS.md).
//!
//! Units: "ops" are bit-operations (bit-lines processed).

use drim::controller::Controller;
use drim::coordinator::{BulkRequest, DrimService, Payload, ServiceConfig};
use drim::dram::command::{AapKind, RowId::*};
use drim::dram::geometry::DramGeometry;
use drim::isa::program::BulkOp;
use drim::subarray::SubArray;
use drim::util::bench::{section, Bencher};
use drim::util::bitrow::BitRow;
use drim::util::rng::Rng;

fn main() {
    let b = Bencher::default();
    let mut rng = Rng::new(0xBE6C);

    section("sub-array primitive (8 Kb row)");
    let cols = 8192;
    let mut sa = SubArray::new(cols);
    sa.write_row(X(1), &BitRow::random(cols, &mut rng));
    sa.write_row(X(2), &BitRow::random(cols, &mut rng));
    sa.write_row(X(3), &BitRow::random(cols, &mut rng));
    b.run("dra_aap (XNOR, 8192 bits)", cols as f64, || {
        sa.execute_aap(AapKind::Dra, &[X(1), X(2)], &[Data(0)])
    });
    b.run("tra_aap (MAJ3, 8192 bits)", cols as f64, || {
        sa.execute_aap(AapKind::Tra, &[X(1), X(2), X(3)], &[Data(1)])
    });
    b.run("copy_aap (8192 bits)", cols as f64, || {
        sa.execute_aap(AapKind::Copy, &[Data(1)], &[X(4)])
    });

    section("controller sequences (8 Kb row)");
    let mut c = Controller::new(DramGeometry::default());
    c.write_row(0, 0, Data(0), &BitRow::random(cols, &mut rng));
    c.write_row(0, 0, Data(1), &BitRow::random(cols, &mut rng));
    b.run("xnor2 program (3 AAPs)", cols as f64, || {
        c.exec_op(BulkOp::Xnor2, 0, 0, &[Data(0), Data(1)], Data(2))
    });
    let ar: Vec<_> = (0..32).map(|i| Data(10 + i as u16)).collect();
    let br: Vec<_> = (0..32).map(|i| Data(50 + i as u16)).collect();
    let sr: Vec<_> = (0..32).map(|i| Data(100 + i as u16)).collect();
    for r in ar.iter().chain(&br) {
        c.write_row(0, 0, *r, &BitRow::random(cols, &mut rng));
    }
    b.run("add_planes 32-bit (224 AAPs)", (cols * 32) as f64, || {
        c.add_planes(0, 0, &ar, &br, &sr, Data(200))
    });

    section("service end-to-end (functional sim, wall time)");
    let service = DrimService::new(ServiceConfig::default());
    for bits in [1 << 16, 1 << 20, 1 << 23] {
        let a = BitRow::random(bits, &mut rng);
        let bb = BitRow::random(bits, &mut rng);
        b.run(
            &format!("service xnor2 {} bits", bits),
            bits as f64,
            || {
                let resp = service.run(BulkRequest::bitwise(
                    BulkOp::Xnor2,
                    vec![a.clone(), bb.clone()],
                ));
                assert!(matches!(resp.result, Payload::Bits(_)));
            },
        );
    }

    section("analog engines");
    b.run("montecarlo 10k trials ±20%", 120_000.0, || {
        drim::analog::montecarlo::run_montecarlo(0.2, 10_000, 3)
    });
    b.run("transient 4 cases × 1200 steps", 4.0 * 1200.0, || {
        drim::analog::transient::all_cases()
    });

    println!("\nhotpath bench OK");
}
