//! L3 hot-path micro-benchmarks: the simulator code the whole Fig. 8 sweep
//! and the serving loop sit on. Used by the §Perf pass (EXPERIMENTS.md).
//!
//! Units: "ops" are bit-operations (bit-lines processed), except the fleet
//! scaling section where units are requests.
//!
//! Writes `BENCH_hotpath.json` at the repo root. The fleet section is the
//! gate for the sharded-residency / zero-alloc submission work: routed
//! resident submission under weak scaling (fixed requests *per device*,
//! one submitter thread per device) must reach ≥ 2× the single-device
//! admission throughput at 8 devices — a fleet whose submit→route→
//! coalesce path serializes on one registry lock fails this.

use drim::cluster::{
    ClusterConfig, ClusterRequest, DeviceId, DrimCluster, RegionId,
};
use drim::controller::Controller;
use drim::coordinator::{BulkRequest, DrimService, Payload, ServiceConfig};
use drim::dram::command::{AapKind, RowId::*};
use drim::dram::geometry::DramGeometry;
use drim::isa::program::BulkOp;
use drim::subarray::SubArray;
use drim::util::bench::{section, BenchReport, Bencher};
use drim::util::bitrow::BitRow;
use drim::util::rng::Rng;

/// Routed requests per device in the scaling section (weak scaling: total
/// load grows with the fleet, per-device load is constant).
const SCALE_REQ_PER_DEVICE: usize = 64;
/// Resident ranks per device; each rank is one XNOR2 operand pair.
const SCALE_REGIONS_PER_DEVICE: usize = 4;
/// Operand size: small enough that the submission pipeline (admission,
/// routing, residency resolve, coalescer staging) is a visible share of
/// the request, not drowned by functional simulation.
const SCALE_BITS: usize = 4096;
const SEED: u64 = 0xBE6C;

/// Scaling-section device: small geometry, one service worker — device-
/// internal parallelism is not what this section measures.
fn scale_service() -> ServiceConfig {
    ServiceConfig {
        geometry: DramGeometry {
            banks: 4,
            subarrays_per_bank: 8,
            cols: 1024,
            active_subarrays: 4,
        },
        workers: 1,
        ..ServiceConfig::default()
    }
}

/// One weak-scaling run: fresh fleet of `devices`, resident rank pool
/// registered round-robin, one submitter thread per device driving
/// blocking routed submits over the shared registry, then drain.
fn pump_routed(devices: usize, requests: usize) {
    let cluster = DrimCluster::new(ClusterConfig {
        steal: false,
        ..ClusterConfig::uniform(devices, scale_service())
    });
    let mut rng = Rng::new(SEED);
    let ranks: Vec<Vec<RegionId>> = (0..devices * SCALE_REGIONS_PER_DEVICE)
        .map(|r| {
            let owner = DeviceId(r % devices);
            (0..2)
                .map(|_| {
                    cluster.register_resident(
                        owner,
                        Payload::Bits(BitRow::random(SCALE_BITS, &mut rng)),
                    )
                })
                .collect()
        })
        .collect();
    let per_thread = requests / devices;
    std::thread::scope(|s| {
        for t in 0..devices {
            let cluster = &cluster;
            let ranks = &ranks;
            s.spawn(move || {
                let mut pending = Vec::with_capacity(per_thread);
                for i in 0..per_thread {
                    // stride by the fleet size so every submitter sweeps
                    // the whole rank pool (all registry shards, all homes)
                    let ids = &ranks[(t + i * devices) % ranks.len()];
                    let req = ClusterRequest::resident(BulkOp::Xnor2, ids.clone());
                    pending.push(
                        cluster
                            .submit_routed_blocking(req)
                            .expect("resident ranks always resolve"),
                    );
                }
                for p in pending {
                    p.recv().expect("cluster response");
                }
            });
        }
    });
    cluster.shutdown();
}

fn main() {
    let b = Bencher::default();
    let mut rng = Rng::new(SEED);
    let mut report = BenchReport::new("hotpath");
    report
        .config("scale_req_per_device", SCALE_REQ_PER_DEVICE)
        .config("scale_regions_per_device", SCALE_REGIONS_PER_DEVICE)
        .config("scale_bits", SCALE_BITS)
        .config("seed", SEED);

    section("sub-array primitive (8 Kb row)");
    let cols = 8192;
    let mut sa = SubArray::new(cols);
    sa.write_row(X(1), &BitRow::random(cols, &mut rng));
    sa.write_row(X(2), &BitRow::random(cols, &mut rng));
    sa.write_row(X(3), &BitRow::random(cols, &mut rng));
    let m = b.run("dra_aap_xnor_8192", cols as f64, || {
        sa.execute_aap(AapKind::Dra, &[X(1), X(2)], &[Data(0)])
    });
    report.measurement(&m);
    let m = b.run("tra_aap_maj3_8192", cols as f64, || {
        sa.execute_aap(AapKind::Tra, &[X(1), X(2), X(3)], &[Data(1)])
    });
    report.measurement(&m);
    let m = b.run("copy_aap_8192", cols as f64, || {
        sa.execute_aap(AapKind::Copy, &[Data(1)], &[X(4)])
    });
    report.measurement(&m);

    section("controller sequences (8 Kb row)");
    let mut c = Controller::new(DramGeometry::default());
    c.write_row(0, 0, Data(0), &BitRow::random(cols, &mut rng));
    c.write_row(0, 0, Data(1), &BitRow::random(cols, &mut rng));
    let m = b.run("xnor2_program_3aap", cols as f64, || {
        c.exec_op(BulkOp::Xnor2, 0, 0, &[Data(0), Data(1)], Data(2))
    });
    report.measurement(&m);
    let ar: Vec<_> = (0..32).map(|i| Data(10 + i as u16)).collect();
    let br: Vec<_> = (0..32).map(|i| Data(50 + i as u16)).collect();
    let sr: Vec<_> = (0..32).map(|i| Data(100 + i as u16)).collect();
    for r in ar.iter().chain(&br) {
        c.write_row(0, 0, *r, &BitRow::random(cols, &mut rng));
    }
    let m = b.run("add_planes_32bit_224aap", (cols * 32) as f64, || {
        c.add_planes(0, 0, &ar, &br, &sr, Data(200))
    });
    report.measurement(&m);

    section("service end-to-end (functional sim, wall time)");
    let service = DrimService::new(ServiceConfig::default());
    for bits in [1 << 16, 1 << 20, 1 << 23] {
        let a = BitRow::random(bits, &mut rng);
        let bb = BitRow::random(bits, &mut rng);
        let m = b.run(&format!("service_xnor2_{bits}_bits"), bits as f64, || {
            let resp = service.run(BulkRequest::bitwise(
                BulkOp::Xnor2,
                vec![a.clone(), bb.clone()],
            ));
            assert!(matches!(resp.result, Payload::Bits(_)));
        });
        report.measurement(&m);
    }

    section("fleet routed-submit scaling (weak scaling, resident operands)");
    println!(
        "{SCALE_REQ_PER_DEVICE} requests/device × {SCALE_BITS} bits, \
         one submitter thread per device, steal off\n"
    );
    let scale_b = Bencher {
        warmup_iters: 1,
        iters: 5,
    };
    let mut base_rate = 0.0f64;
    let mut top_rate = 0.0f64;
    for devices in [1usize, 2, 4, 8] {
        let requests = SCALE_REQ_PER_DEVICE * devices;
        let m = scale_b.run(
            &format!("routed_submit_{devices}dev"),
            requests as f64,
            || pump_routed(devices, requests),
        );
        if devices == 1 {
            base_rate = m.rate();
        }
        top_rate = m.rate();
        report.measurement(&m);
    }
    let scaling = top_rate / base_rate.max(f64::MIN_POSITIVE);
    report.metric("routed_submit_scaling_8dev_over_1dev", scaling);
    println!("\nrouted-submit scaling at 8 devices: {scaling:.2}x over 1 device");
    let pass = scaling >= 2.0;
    report.gate("routed_submit_scaling_ge_2x_at_8_devices", pass);

    section("analog engines");
    let m = b.run("montecarlo_10k_pm20", 120_000.0, || {
        drim::analog::montecarlo::run_montecarlo(0.2, 10_000, 3)
    });
    report.measurement(&m);
    let m = b.run("transient_4x1200", 4.0 * 1200.0, || {
        drim::analog::transient::all_cases()
    });
    report.measurement(&m);

    report.write();
    assert!(
        pass,
        "routed-submit admission throughput scaled only {scaling:.2}x at 8 \
         devices (gate: >= 2x) — the submission hot path is serializing"
    );
    println!("\nhotpath bench OK");
}
