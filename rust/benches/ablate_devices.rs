//! Device-scaling ablation: the same serving workload over 1/2/4/8 DRIM
//! devices through the fleet layer.
//!
//! Reported per fleet size:
//!   * simulated makespan — busiest device's accumulated wave time (the
//!     fleet finishes when its slowest device does);
//!   * fleet simulated throughput — total result bits / makespan;
//!   * host wall time — what the simulator itself cost.
//!
//! Stealing is disabled so the ablation measures pure round-robin
//! sharding (the deterministic quantity the it_cluster scaling gate also
//! checks); a second pass with stealing on shows the scheduler recovering
//! imbalance when request sizes are skewed.

use drim::cluster::{ClusterConfig, DrimCluster};
use drim::coordinator::{BulkRequest, ServiceConfig};
use drim::dram::geometry::DramGeometry;
use drim::isa::program::BulkOp;
use drim::util::bench::section;
use drim::util::bitrow::BitRow;
use drim::util::rng::Rng;
use drim::util::stats::fmt_rate;
use drim::util::table::Table;

/// Bench-sized device: big enough to shard, small enough to sweep fast.
fn bench_service() -> ServiceConfig {
    ServiceConfig {
        geometry: DramGeometry {
            banks: 4,
            subarrays_per_bank: 8,
            cols: 1024,
            active_subarrays: 4,
        },
        workers: 2,
        ..ServiceConfig::default()
    }
}

fn run_fleet(devices: usize, steal: bool, skewed: bool, seed: u64) -> (f64, f64, std::time::Duration) {
    let cluster = DrimCluster::new(ClusterConfig {
        steal,
        ..ClusterConfig::uniform(devices, bench_service())
    });
    let mut rng = Rng::new(seed);
    let requests = 64usize;
    let t0 = std::time::Instant::now();
    let pending: Vec<_> = (0..requests)
        .map(|i| {
            // uniform: every request 256 Kb. skewed: every 8th request is
            // 16× larger, creating the imbalance stealing should absorb.
            let bits = if skewed && i % 8 == 0 { 1 << 22 } else { 1 << 18 };
            let a = BitRow::random(bits, &mut rng);
            let b = BitRow::random(bits, &mut rng);
            cluster.submit_blocking(BulkRequest::bitwise(BulkOp::Xnor2, vec![a, b]))
        })
        .collect();
    for p in pending {
        p.recv().expect("response");
    }
    let wall = t0.elapsed();
    let snap = cluster.shutdown();
    (
        snap.merged.sim_ns as f64,
        snap.sim_throughput_bits_per_sec(),
        wall,
    )
}

fn sweep(steal: bool, skewed: bool) {
    let mut t = Table::new(&[
        "devices",
        "sim makespan",
        "fleet throughput",
        "scaling",
        "host wall",
    ]);
    let mut base = 0.0;
    for devices in [1usize, 2, 4, 8] {
        let (sim_ns, tp, wall) = run_fleet(devices, steal, skewed, 0xAB1A7E);
        if base == 0.0 {
            base = tp;
        }
        t.row(&[
            format!("{devices}"),
            format!("{:.2} µs", sim_ns / 1e3),
            format!("{}bit/s", fmt_rate(tp)),
            if base > 0.0 {
                format!("{:.2}x", tp / base)
            } else {
                "-".to_string()
            },
            format!("{wall:?}"),
        ]);
    }
    t.print();
}

fn main() {
    section("device scaling — uniform requests, steal off (pure sharding)");
    sweep(false, false);
    println!(
        "→ round-robin sharding: makespan divides by the device count \
         while payloads keep every wave full"
    );

    section("device scaling — skewed requests, steal off vs on");
    println!("steal off (stragglers bound the makespan):");
    sweep(false, true);
    println!("steal on (idle workers drain the straggler's queue):");
    sweep(true, true);
    println!(
        "→ stealing narrows the gap between busiest and idlest device \
         when request sizes are skewed"
    );

    println!("\nablate_devices bench OK");
}
