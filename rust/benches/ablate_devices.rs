//! Device-scaling ablation: the same serving workload over 1/2/4/8 DRIM
//! devices through the fleet layer.
//!
//! Reported per fleet size:
//!   * simulated makespan — busiest device's accumulated wave time (the
//!     fleet finishes when its slowest device does);
//!   * fleet simulated throughput — total result bits / makespan;
//!   * host wall time — what the simulator itself cost.
//!
//! Stealing is disabled so the ablation measures pure round-robin
//! sharding (the deterministic quantity the it_cluster scaling gate also
//! checks); a second pass with stealing on shows the scheduler recovering
//! imbalance when request sizes are skewed.

use drim::cluster::{ClusterConfig, DrimCluster};
use drim::coordinator::{BulkRequest, ServiceConfig};
use drim::dram::geometry::DramGeometry;
use drim::isa::program::BulkOp;
use drim::util::bench::{section, BenchReport};
use drim::util::bitrow::BitRow;
use drim::util::rng::Rng;
use drim::util::stats::fmt_rate;
use drim::util::table::Table;

/// Bench-sized device: big enough to shard, small enough to sweep fast.
fn bench_service() -> ServiceConfig {
    ServiceConfig {
        geometry: DramGeometry {
            banks: 4,
            subarrays_per_bank: 8,
            cols: 1024,
            active_subarrays: 4,
        },
        workers: 2,
        ..ServiceConfig::default()
    }
}

fn run_fleet(devices: usize, steal: bool, skewed: bool, seed: u64) -> (f64, f64, std::time::Duration) {
    let cluster = DrimCluster::new(ClusterConfig {
        steal,
        ..ClusterConfig::uniform(devices, bench_service())
    });
    let mut rng = Rng::new(seed);
    let requests = 64usize;
    let t0 = std::time::Instant::now();
    let pending: Vec<_> = (0..requests)
        .map(|i| {
            // uniform: every request 256 Kb. skewed: every 8th request is
            // 16× larger, creating the imbalance stealing should absorb.
            let bits = if skewed && i % 8 == 0 { 1 << 22 } else { 1 << 18 };
            let a = BitRow::random(bits, &mut rng);
            let b = BitRow::random(bits, &mut rng);
            cluster.submit_blocking(BulkRequest::bitwise(BulkOp::Xnor2, vec![a, b]))
        })
        .collect();
    for p in pending {
        p.recv().expect("response");
    }
    let wall = t0.elapsed();
    let snap = cluster.shutdown();
    (
        snap.merged.sim_ns as f64,
        snap.sim_throughput_bits_per_sec(),
        wall,
    )
}

/// Run the 1/2/4/8 sweep, printing the table and recording each point's
/// simulated makespan and throughput into the report under `tag`.
/// Returns `(devices, sim_ns, throughput)` per point.
fn sweep(steal: bool, skewed: bool, report: &mut BenchReport, tag: &str) -> Vec<(usize, f64, f64)> {
    let mut t = Table::new(&[
        "devices",
        "sim makespan",
        "fleet throughput",
        "scaling",
        "host wall",
    ]);
    let mut base = 0.0;
    let mut out = Vec::new();
    for devices in [1usize, 2, 4, 8] {
        let (sim_ns, tp, wall) = run_fleet(devices, steal, skewed, 0xAB1A7E);
        if base == 0.0 {
            base = tp;
        }
        t.row(&[
            format!("{devices}"),
            format!("{:.2} µs", sim_ns / 1e3),
            format!("{}bit/s", fmt_rate(tp)),
            if base > 0.0 {
                format!("{:.2}x", tp / base)
            } else {
                "-".to_string()
            },
            format!("{wall:?}"),
        ]);
        report.metric(&format!("{tag}_dev{devices}_sim_makespan_ns"), sim_ns);
        report.metric(&format!("{tag}_dev{devices}_throughput_bits_per_sec"), tp);
        out.push((devices, sim_ns, tp));
    }
    t.print();
    out
}

fn main() {
    let mut report = BenchReport::new("ablate_devices");
    report
        .config("requests", 64u64)
        .config("device_counts", "1/2/4/8")
        .config("uniform_bits", 1u64 << 18)
        .config("skewed_bits", 1u64 << 22)
        .config("seed", 0xAB1A7Eu64);

    section("device scaling — uniform requests, steal off (pure sharding)");
    let uniform = sweep(false, false, &mut report, "uniform");
    println!(
        "→ round-robin sharding: makespan divides by the device count \
         while payloads keep every wave full"
    );

    section("device scaling — skewed requests, steal off vs on");
    println!("steal off (stragglers bound the makespan):");
    let skew_off = sweep(false, true, &mut report, "skew_nosteal");
    println!("steal on (idle workers drain the straggler's queue):");
    let skew_on = sweep(true, true, &mut report, "skew_steal");
    println!(
        "→ stealing narrows the gap between busiest and idlest device \
         when request sizes are skewed"
    );

    // --- gates (recorded first so a failing run still leaves the artifact)
    // uniform round-robin with full waves is deterministic: 8 devices
    // must scale well past 2× over 1 device
    let scaling_8x = uniform[3].2 / uniform[0].2.max(f64::MIN_POSITIVE);
    report.metric("uniform_scaling_8x", scaling_8x);
    let scales = scaling_8x >= 2.0;
    report.gate("uniform_scaling_improves", scales);
    // stealing is timing-dependent, so the gate has 10% slack: it must
    // not make the skewed 8-device makespan meaningfully worse
    let steal_ok = skew_on[3].1 <= skew_off[3].1 * 1.10;
    report.metric("skew_dev8_makespan_ratio", skew_on[3].1 / skew_off[3].1.max(1.0));
    report.gate("steal_not_worse_under_skew", steal_ok);
    report.write();
    assert!(scales, "8-device scaling only {scaling_8x:.2}x");
    assert!(
        steal_ok,
        "stealing degraded the skewed makespan: {} vs {}",
        skew_on[3].1, skew_off[3].1
    );

    println!("\nablate_devices bench OK");
}
