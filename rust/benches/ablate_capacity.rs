//! Capacity ablation: per-device footprint enforcement, eviction, and
//! cost-driven hot-region replication under a Zipf-skewed popularity
//! workload (the shared driver is `DrimCluster::pump_capacity`, also
//! behind `drim cluster --capacity`).
//!
//! Gates (the CI bench-gate step runs this binary):
//!   (a) hot-region replication beats single-copy placement on makespan
//!       including copy under skewed popularity — spreading the hot
//!       region's replicas across channels outweighs the one-time stream;
//!   (b) registration beyond capacity either evicts (LRU) or fails fast
//!       (fail-fast policy) — footprint on every device stays within its
//!       `DeviceCapacity`, and the fleet degrades gracefully (every
//!       request still completes) as footprint approaches capacity.

use drim::cluster::{
    CapacityConfig, ClusterConfig, DeviceCapacity, DeviceId, DrimCluster,
    EvictionPolicy, FleetSnapshot, ReplicationConfig, ReplicationPolicy,
};
use drim::coordinator::ServiceConfig;
use drim::dram::geometry::DramGeometry;
use drim::util::bench::{section, BenchReport};
use drim::util::stats::fmt_ns;
use drim::util::table::Table;

const DEVICES: usize = 4; // two DDR channels × two ranks
const REGIONS: usize = 12;
const REQUESTS: usize = 64;
const BITS: usize = 1 << 16;
const THETA: f64 = 1.5;
const SEED: u64 = 0xCA9AC17;

/// Bench-sized device (same geometry as ablate_devices/ablate_locality).
fn bench_service() -> ServiceConfig {
    ServiceConfig {
        geometry: DramGeometry {
            banks: 4,
            subarrays_per_bank: 8,
            cols: 1024,
            active_subarrays: 4,
        },
        workers: 2,
        ..ServiceConfig::default()
    }
}

/// Per-device share of the working set (REGIONS regions of BITS each,
/// owners round-robin over DEVICES).
fn share_bits() -> u64 {
    (REGIONS / DEVICES * BITS) as u64
}

fn run(capacity: DeviceCapacity, policy: EvictionPolicy, replicate: bool) -> (FleetSnapshot, u64) {
    let cluster = DrimCluster::new(ClusterConfig {
        steal: false,
        capacity: CapacityConfig { capacity, policy },
        ..ClusterConfig::uniform(DEVICES, bench_service())
    });
    let rep = ReplicationPolicy::new(ReplicationConfig {
        hot_uses: 3,
        amortize_factor: 1.0,
        ..ReplicationConfig::default()
    });
    let rebalance = replicate.then_some((&rep, 16));
    let requeues = cluster.pump_capacity(REGIONS, REQUESTS, BITS, THETA, rebalance, SEED);
    // gate (b): the footprint bound holds on every device, and the
    // registry's own bookkeeping (which asserts footprint ≤ capacity)
    // is internally consistent
    for d in 0..DEVICES {
        let resident = cluster.registry().resident_bits_on(DeviceId(d));
        assert!(
            resident <= capacity.resident_bits,
            "dev{d} footprint {resident} exceeds capacity {}",
            capacity.resident_bits
        );
    }
    cluster.registry().check_invariants().expect("registry invariants");
    (cluster.shutdown(), requeues)
}

fn main() {
    section("capacity — footprint enforcement, eviction, hot-region replication");
    println!(
        "{REQUESTS} requests over {REGIONS} Zipf({THETA}) regions × {BITS} bits, \
         {DEVICES} devices (per-device share {} KB, steal off)\n",
        share_bits() / 8192
    );
    let share = share_bits();
    let cases: &[(&str, &str, DeviceCapacity, EvictionPolicy, bool)] = &[
        (
            "unbounded",
            "single-copy",
            DeviceCapacity::unbounded(),
            EvictionPolicy::FailFast,
            false,
        ),
        (
            "unbounded",
            "replicate",
            DeviceCapacity::unbounded(),
            EvictionPolicy::FailFast,
            true,
        ),
        (
            "1.0x share",
            "lru evict",
            DeviceCapacity::of_bits(share),
            EvictionPolicy::Lru,
            false,
        ),
        (
            "0.5x share",
            "lru evict",
            DeviceCapacity::of_bits(share / 2),
            EvictionPolicy::Lru,
            false,
        ),
        (
            "0.8x share",
            "fail fast",
            DeviceCapacity::of_bits(share * 4 / 5),
            EvictionPolicy::FailFast,
            false,
        ),
    ];
    let mut t = Table::new(&[
        "capacity",
        "policy",
        "evictions",
        "refusals",
        "requeues",
        "hits",
        "misses",
        "copied KB",
        "makespan (+copy)",
    ]);
    let mut report = BenchReport::new("ablate_capacity");
    report
        .config("devices", DEVICES)
        .config("regions", REGIONS)
        .config("requests", REQUESTS)
        .config("bits", BITS)
        .config("theta", THETA)
        .config("seed", SEED);
    let tags = ["single", "replicated", "lru_full", "lru_half", "fail_fast"];
    let mut snaps = Vec::new();
    for (i, &(cap_label, policy_label, capacity, policy, replicate)) in
        cases.iter().enumerate()
    {
        let (snap, requeues) = run(capacity, policy, replicate);
        t.row(&[
            cap_label.to_string(),
            policy_label.to_string(),
            format!("{}", snap.evictions),
            format!("{}", snap.capacity_refusals),
            format!("{requeues}"),
            format!("{}", snap.resident_hits),
            format!("{}", snap.resident_misses),
            format!("{:.1}", snap.copied_bytes as f64 / 1024.0),
            fmt_ns(snap.makespan_with_copy_ns() as f64),
        ]);
        let tag = tags[i];
        report.metric(&format!("{tag}_evictions"), snap.evictions);
        report.metric(&format!("{tag}_requeues"), requeues);
        report.metric(
            &format!("{tag}_makespan_with_copy_ns"),
            snap.makespan_with_copy_ns(),
        );
        snaps.push((snap, requeues));
    }
    t.print();

    let (single, _) = &snaps[0];
    let (replicated, _) = &snaps[1];
    let (lru_full, _) = &snaps[2];
    let (lru_half, lru_half_requeues) = &snaps[3];
    let (fail_fast, _) = &snaps[4];

    // --- gates (recorded first so a failing run still leaves the artifact)
    let rep_happened = replicated.replications >= 1;
    let rep_faster =
        replicated.makespan_with_copy_ns() < single.makespan_with_copy_ns();
    let all_completed = snaps
        .iter()
        .all(|(s, _)| s.completed as usize == REQUESTS);
    let half_evicts = lru_half.evictions > 0 && *lru_half_requeues > 0;
    let full_steady = lru_full.evictions == 0;
    let fail_fast_ok = fail_fast.capacity_refusals > 0
        && fail_fast.evictions == 0
        && fail_fast.resident_misses > 0;
    report
        .gate("replication_happens", rep_happened)
        .gate("replication_beats_single_copy", rep_faster)
        .gate("no_request_lost", all_completed)
        .gate("half_share_evicts_and_requeues", half_evicts)
        .gate("full_share_steady_state", full_steady)
        .gate("fail_fast_refuses_without_evicting", fail_fast_ok);
    report.write();

    // --- gate (a): replication beats single-copy under skew -------------
    assert!(rep_happened, "the hot region must replicate");
    assert!(
        rep_faster,
        "makespan incl copy: replicated {} vs single-copy {}",
        replicated.makespan_with_copy_ns(),
        single.makespan_with_copy_ns()
    );
    // the win comes from spreading load, not from dropping work
    assert_eq!(single.completed as usize, REQUESTS);
    assert_eq!(replicated.completed as usize, REQUESTS);
    assert_eq!(single.evictions, 0, "unbounded fleets never evict");

    // --- gate (b): enforcement + graceful degradation -------------------
    // every bounded run completed the full workload (no collapse) —
    // the per-device footprint bound itself is asserted inside run()
    assert!(all_completed, "no request may be lost");
    // 3 regions per device against a 1-region (0.5x) budget must evict
    // and requeue the evicted regions' traffic
    assert!(lru_half.evictions > 0, "0.5x share must evict");
    assert!(*lru_half_requeues > 0, "evicted hot regions must requeue");
    // 1.0x share fits the whole working set: steady state, no thrash
    assert_eq!(lru_full.evictions, 0, "1.0x share fits without eviction");
    // fail-fast refuses instead of evicting; refused slots degrade to
    // carried payloads (which count as misses, not failures)
    assert!(fail_fast.capacity_refusals > 0, "fail-fast must refuse");
    assert_eq!(fail_fast.evictions, 0, "fail-fast never evicts");
    assert!(fail_fast.resident_misses > 0, "refused slots run carried");

    println!(
        "\n→ replication: makespan {} vs single-copy {} ({} replicas, {} KB streamed); \
         0.5x capacity: {} evictions, {} requeues, all {} requests served",
        fmt_ns(replicated.makespan_with_copy_ns() as f64),
        fmt_ns(single.makespan_with_copy_ns() as f64),
        replicated.replications,
        replicated.copied_bytes as f64 / 1024.0,
        lru_half.evictions,
        lru_half_requeues,
        REQUESTS,
    );
    println!("\nablate_capacity bench OK");
}
