//! Table 3 regeneration: Monte-Carlo process-variation analysis at the
//! paper's five corners, 10 000 trials, via BOTH engines — the Rust mirror
//! and (when artifacts exist) the AOT-lowered JAX kernel through PJRT —
//! printed side-by-side with the paper's numbers.

use drim::analog::montecarlo::{run_montecarlo, TABLE3_CORNERS, TABLE3_PAPER};
use drim::analog::params as P;
use drim::runtime::Runtime;
use drim::util::bench::Bencher;
use drim::util::table::Table;

fn main() {
    println!("=== Table 3: process variation (10 000 trials/corner) ===\n");
    let mut rt = Runtime::load_default()
        .map_err(|e| eprintln!("(JAX column disabled — {e})"))
        .ok();

    let mut t = Table::new(&[
        "variation",
        "TRA paper",
        "TRA rust",
        "TRA jax",
        "DRA paper",
        "DRA rust",
        "DRA jax",
    ]);
    for (i, &v) in TABLE3_CORNERS.iter().enumerate() {
        let r = run_montecarlo(v, P::MC_TRIALS, 7 + i as u64);
        let (jd, jt) = match rt.as_mut() {
            Some(rt) => {
                let (de, te, dn, tn) =
                    rt.mc_variation([7, i as u32], v as f32).expect("mc artifact");
                (
                    format!("{:.2}", 100.0 * de as f64 / dn as f64),
                    format!("{:.2}", 100.0 * te as f64 / tn as f64),
                )
            }
            None => ("-".into(), "-".into()),
        };
        let (pd, pt) = TABLE3_PAPER[i];
        t.row(&[
            format!("±{:.0}%", v * 100.0),
            format!("{pt}"),
            format!("{:.2}", r.tra_pct()),
            jt,
            format!("{pd}"),
            format!("{:.2}", r.dra_pct()),
            jd,
        ]);
    }
    t.print();

    println!("\n=== engine timing ===");
    let b = Bencher::default();
    b.run("rust mirror, 10k trials, ±20%", (P::MC_TRIALS * 12) as f64, || {
        run_montecarlo(0.20, P::MC_TRIALS, 11)
    });
    if let Some(rt) = rt.as_mut() {
        let b = Bencher::quick();
        b.run("jax artifact, 10k trials, ±20%", (P::MC_TRIALS * 12) as f64, || {
            rt.mc_variation([3, 3], 0.20).unwrap()
        });
    }
    println!("\ntable3 bench OK");
}
