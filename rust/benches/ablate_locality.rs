//! Locality ablation: resident (placement-routed) vs. carried
//! (payload-carrying round-robin) operand placement on the same fleet and
//! workload.
//!
//! Reported per placement policy:
//!   * resident hits / misses — requests whose operands were / were not
//!     already on the executing device;
//!   * copied bytes and DDR bus copy cycles — the operand movement the
//!     copy-cost model charges (host→device for carried payloads,
//!     device→device for resident misses, serialized 2× on a shared
//!     channel);
//!   * compute makespan vs. makespan including copy — the busiest device
//!     with and without the movement charged to it.
//!
//! Stealing is disabled and the miss pattern is deterministic, so the
//! gates below are exact: locality-aware routing at ≥80 % resident hits
//! must beat payload-carrying round-robin on both simulated makespan
//! (incl. copy) and copy cycles.

use drim::cluster::{ClusterConfig, DrimCluster, FleetSnapshot};
use drim::coordinator::ServiceConfig;
use drim::dram::geometry::DramGeometry;
use drim::util::bench::{section, BenchReport};
use drim::util::stats::fmt_ns;
use drim::util::table::Table;

const DEVICES: usize = 4;
const REQUESTS: usize = 48;
const BITS: usize = 1 << 18;

/// Bench-sized device (same geometry as ablate_devices).
fn bench_service() -> ServiceConfig {
    ServiceConfig {
        geometry: DramGeometry {
            banks: 4,
            subarrays_per_bank: 8,
            cols: 1024,
            active_subarrays: 4,
        },
        workers: 2,
        ..ServiceConfig::default()
    }
}

/// Placement policy in `DrimCluster::pump_locality`'s convention:
/// `None` → carried inline; `Some(k)` → resident, every `k`-th request a
/// forced miss; `Some(0)` → fully resident.
#[derive(Clone, Copy)]
struct Strategy(Option<usize>);

impl Strategy {
    fn label(self) -> String {
        match self.0 {
            None => "carried (round-robin)".into(),
            Some(0) => "resident 100%".into(),
            Some(miss_every) => {
                format!("resident {:.0}%", 100.0 * (1.0 - 1.0 / miss_every as f64))
            }
        }
    }
}

fn run(strategy: Strategy, seed: u64) -> FleetSnapshot {
    let cluster = DrimCluster::new(ClusterConfig {
        steal: false,
        ..ClusterConfig::uniform(DEVICES, bench_service())
    });
    // the workload driver is shared with `drim cluster --locality`
    cluster.pump_locality(REQUESTS, BITS, strategy.0, seed);
    cluster.shutdown()
}

fn main() {
    section("operand placement — resident routing vs. carried round-robin");
    println!(
        "{REQUESTS} requests × 2 × {BITS} bits over {DEVICES} devices \
         (steal off, deterministic miss pattern)\n"
    );
    let mut t = Table::new(&[
        "placement",
        "hits",
        "misses",
        "copied KB",
        "copy cycles",
        "makespan (compute)",
        "makespan (+copy)",
    ]);
    let strategies = [
        Strategy(None),
        Strategy(Some(2)),
        Strategy(Some(5)),
        Strategy(Some(0)),
    ];
    let mut report = BenchReport::new("ablate_locality");
    report
        .config("devices", DEVICES)
        .config("requests", REQUESTS)
        .config("bits", BITS)
        .config("seed", 0x10CA117u64);
    let mut snaps = Vec::new();
    for (i, s) in strategies.into_iter().enumerate() {
        let snap = run(s, 0x10CA117);
        t.row(&[
            s.label(),
            format!("{}", snap.resident_hits),
            format!("{}", snap.resident_misses),
            format!("{:.1}", snap.copied_bytes as f64 / 1024.0),
            format!("{}", snap.copy_cycles),
            fmt_ns(snap.merged.sim_ns as f64),
            fmt_ns(snap.makespan_with_copy_ns() as f64),
        ]);
        let tag = ["carried", "resident50", "resident80", "resident100"][i];
        report.metric(&format!("{tag}_copied_bytes"), snap.copied_bytes);
        report.metric(&format!("{tag}_copy_cycles"), snap.copy_cycles);
        report.metric(
            &format!("{tag}_makespan_with_copy_ns"),
            snap.makespan_with_copy_ns(),
        );
        snaps.push(snap);
    }
    t.print();

    let (carried, r80, r100) = (&snaps[0], &snaps[2], &snaps[3]);

    // --- gates (recorded first so a failing run still leaves the artifact)
    let total = r80.resident_hits + r80.resident_misses;
    let zero_copy = r100.copied_bytes == 0
        && r100.copy_cycles == 0
        && r100.makespan_with_copy_ns() == r100.merged.sim_ns;
    let hit_rate = r80.resident_hits * 5 >= total * 4;
    let fewer_cycles = r80.copy_cycles < carried.copy_cycles;
    let faster = r80.makespan_with_copy_ns() < carried.makespan_with_copy_ns();
    let carried_all_miss =
        carried.resident_hits == 0 && carried.resident_misses as usize == REQUESTS;
    report
        .gate("resident100_zero_copy", zero_copy)
        .gate("resident80_hit_rate", hit_rate)
        .gate("resident80_fewer_copy_cycles", fewer_cycles)
        .gate("resident80_faster_with_copy", faster)
        .gate("carried_pays_every_request", carried_all_miss);
    report.write();

    // fully resident placement moves nothing
    assert!(zero_copy, "resident 100% must be zero-copy");
    // the 80%-hit run really is ≥80% hits
    assert!(hit_rate, "hit rate below 80%: {}/{total}", r80.resident_hits);
    // locality-aware routing beats payload-carrying round-robin
    assert!(
        fewer_cycles,
        "copy cycles: resident80 {} vs carried {}",
        r80.copy_cycles, carried.copy_cycles
    );
    assert!(
        faster,
        "makespan incl copy: resident80 {} vs carried {}",
        r80.makespan_with_copy_ns(),
        carried.makespan_with_copy_ns()
    );
    // both policies do the same compute on the same fleet — the win is
    // operand movement, and carried pays it on every single request
    assert!(carried_all_miss);

    println!(
        "\n→ resident routing at ≥80% hits: {} copy cycles vs carried {} \
         ({}% of the traffic), makespan {} vs {}",
        r80.copy_cycles,
        carried.copy_cycles,
        100 * r80.copy_cycles / carried.copy_cycles.max(1),
        fmt_ns(r80.makespan_with_copy_ns() as f64),
        fmt_ns(carried.makespan_with_copy_ns() as f64),
    );
    println!("\nablate_locality bench OK");
}
