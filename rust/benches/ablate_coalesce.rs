//! Coalescing ablation: fleet-wide wave packing of sub-wave requests,
//! ON vs OFF, on the same fleet and workload.
//!
//! The wave model charges one full wave per `ceil(chunks / wave_slots)`
//! no matter how empty the wave is, so a burst of one-chunk requests
//! dispatched individually burns `requests` waves while filling
//! `requests / wave_slots` waves' worth of slots. The coalescer packs
//! compatible sub-wave requests into shared waves before dispatch;
//! this bench gates that the packing actually pays:
//!
//!   * **sub-wave-heavy workload, 4 devices**: coalescing ON must
//!     achieve *strictly lower* simulated makespan and *strictly
//!     higher* slot occupancy than OFF, while per-request results stay
//!     byte-identical;
//!   * **wave-filling workload**: coalescing ON must be a no-op — same
//!     makespan, same occupancy, nothing coalesced (wave-filling
//!     requests bypass staging entirely).
//!
//! Stealing is off and the coalescer runs in strict mode with the
//! burst driver flushing at the end, so group membership — and with it
//! every gated number — depends only on submission order.

use drim::cluster::{ClusterConfig, CoalesceConfig, DrimCluster, FleetSnapshot};
use drim::coordinator::{Payload, ServiceConfig};
use drim::dram::geometry::DramGeometry;
use drim::util::bench::{section, BenchReport};
use drim::util::stats::fmt_ns;
use drim::util::table::Table;

const DEVICES: usize = 4;
const SEED: u64 = 0xC0A1E5CE;
/// sub-wave burst: one chunk per request (cols = 1024 bits)
const SUBWAVE_REQUESTS: usize = 128;
const SUBWAVE_BITS: usize = 1024;
/// wave-filling burst: exactly one full wave per request (16 chunks)
const WAVEFILL_REQUESTS: usize = 16;
const WAVEFILL_BITS: usize = 16 * 1024;

/// Bench-sized device (same geometry as ablate_devices/ablate_locality):
/// 4 banks × 4 active sub-arrays = 16 wave slots, 1024-bit rows.
fn bench_service() -> ServiceConfig {
    ServiceConfig {
        geometry: DramGeometry {
            banks: 4,
            subarrays_per_bank: 8,
            cols: 1024,
            active_subarrays: 4,
        },
        workers: 2,
        ..ServiceConfig::default()
    }
}

fn run(coalesce: CoalesceConfig, requests: usize, bits: usize) -> (FleetSnapshot, Vec<Payload>) {
    let cluster = DrimCluster::new(ClusterConfig {
        steal: false,
        coalesce,
        ..ClusterConfig::uniform(DEVICES, bench_service())
    });
    // the workload driver is shared with `drim cluster --coalesce`
    let results = cluster.pump_coalesce(requests, bits, SEED);
    (cluster.shutdown(), results)
}

fn main() {
    section("fleet wave coalescing — packed vs private wave sets");
    println!(
        "{SUBWAVE_REQUESTS} sub-wave requests × 2 × {SUBWAVE_BITS} bits and \
         {WAVEFILL_REQUESTS} wave-filling requests × 2 × {WAVEFILL_BITS} bits \
         over {DEVICES} devices (steal off, strict staging, burst driver)\n"
    );
    let strict = CoalesceConfig::strict(u64::MAX);
    let (sub_off, sub_off_results) =
        run(CoalesceConfig::off(), SUBWAVE_REQUESTS, SUBWAVE_BITS);
    let (sub_on, sub_on_results) = run(strict, SUBWAVE_REQUESTS, SUBWAVE_BITS);
    let (fill_off, fill_off_results) =
        run(CoalesceConfig::off(), WAVEFILL_REQUESTS, WAVEFILL_BITS);
    let (fill_on, fill_on_results) = run(strict, WAVEFILL_REQUESTS, WAVEFILL_BITS);

    let mut t = Table::new(&[
        "workload",
        "mode",
        "waves",
        "occupancy",
        "coalesced",
        "waves saved",
        "makespan",
    ]);
    for (workload, mode, snap) in [
        ("sub-wave", "off", &sub_off),
        ("sub-wave", "on", &sub_on),
        ("wave-filling", "off", &fill_off),
        ("wave-filling", "on", &fill_on),
    ] {
        t.row(&[
            workload.to_string(),
            mode.to_string(),
            format!("{}", snap.merged.waves),
            format!("{:.1}%", 100.0 * snap.slot_occupancy()),
            format!("{}", snap.coalesced_requests),
            format!("{}", snap.waves_saved),
            fmt_ns(snap.merged.sim_ns as f64),
        ]);
    }
    t.print();

    let mut report = BenchReport::new("ablate_coalesce");
    report
        .config("devices", DEVICES)
        .config("subwave_requests", SUBWAVE_REQUESTS)
        .config("subwave_bits", SUBWAVE_BITS)
        .config("wavefill_requests", WAVEFILL_REQUESTS)
        .config("wavefill_bits", WAVEFILL_BITS)
        .config("seed", SEED);
    for (tag, snap) in [
        ("subwave_off", &sub_off),
        ("subwave_on", &sub_on),
        ("wavefill_off", &fill_off),
        ("wavefill_on", &fill_on),
    ] {
        report.metric(&format!("{tag}_waves"), snap.merged.waves);
        report.metric(&format!("{tag}_slot_occupancy"), snap.slot_occupancy());
        report.metric(&format!("{tag}_sim_makespan_ns"), snap.merged.sim_ns);
        report.metric(&format!("{tag}_waves_saved"), snap.waves_saved);
    }

    // --- gates (recorded first so a failing run still leaves the artifact)
    let results_identical =
        sub_on_results == sub_off_results && fill_on_results == fill_off_results;
    let subwave_faster = sub_on.merged.sim_ns < sub_off.merged.sim_ns;
    let subwave_denser = sub_on.slot_occupancy() > sub_off.slot_occupancy();
    let subwave_packs = sub_on.coalesced_requests > 0
        && sub_on.waves_saved > 0
        && sub_off.coalesced_requests == 0;
    let all_completed = sub_on.completed as usize == SUBWAVE_REQUESTS
        && sub_off.completed as usize == SUBWAVE_REQUESTS;
    let wavefill_noop = fill_on.merged.waves == fill_off.merged.waves
        && fill_on.merged.sim_ns == fill_off.merged.sim_ns
        && fill_on.coalesced_requests == 0
        && fill_on.waves_saved == 0
        && (fill_on.slot_occupancy() - fill_off.slot_occupancy()).abs() < 1e-12;
    report
        .gate("results_byte_identical", results_identical)
        .gate("subwave_on_faster", subwave_faster)
        .gate("subwave_on_denser", subwave_denser)
        .gate("subwave_on_packs", subwave_packs)
        .gate("no_request_lost", all_completed)
        .gate("wavefill_noop", wavefill_noop);
    report.write();

    // byte-exact results: packing must never change what a request computes
    assert_eq!(
        sub_on_results, sub_off_results,
        "coalescing changed sub-wave results"
    );
    assert_eq!(
        fill_on_results, fill_off_results,
        "coalescing changed wave-filling results"
    );
    // sub-wave: ON beats OFF on makespan AND slot occupancy, strictly
    assert!(
        subwave_faster,
        "makespan: on {} vs off {}",
        sub_on.merged.sim_ns,
        sub_off.merged.sim_ns
    );
    assert!(
        subwave_denser,
        "occupancy: on {} vs off {}",
        sub_on.slot_occupancy(),
        sub_off.slot_occupancy()
    );
    assert!(subwave_packs, "coalescing packed nothing");
    // every request completed in both modes
    assert!(all_completed, "requests lost");
    // wave-filling: coalescing is a no-op — identical wave economy
    assert!(wavefill_noop, "wave-filling run was not a no-op");

    println!(
        "\n→ coalescing ON: {} waves ({:.1}% occupancy) vs OFF {} waves \
         ({:.1}%), makespan {} vs {}, {} waves saved, results byte-identical",
        sub_on.merged.waves,
        100.0 * sub_on.slot_occupancy(),
        sub_off.merged.waves,
        100.0 * sub_off.slot_occupancy(),
        fmt_ns(sub_on.merged.sim_ns as f64),
        fmt_ns(sub_off.merged.sim_ns as f64),
        sub_on.waves_saved,
    );
    println!("\nablate_coalesce bench OK");
}
