//! Ablations for the design choices DESIGN.md calls out:
//!
//! 1. DRA vs TRA-composed XNOR on the same substrate — how much of the
//!    2.3× over Ambit is the single-cycle mechanism vs init elimination.
//! 2. Sub-array parallelism sweep — banks × active sub-arrays saturation.
//! 3. Batching policy — Immediate vs Coalesce wave utilization.
//! 4. Row allocator — co-located vs naive placement (inter-sub-array
//!    copies through the host path).

use drim::coordinator::{BatchPolicy, Router, ServiceConfig};
use drim::dram::geometry::DramGeometry;
use drim::dram::timing::TimingParams;
use drim::isa::program::BulkOp;
use drim::platforms::{pim, Platform};
use drim::util::stats::fmt_rate;
use drim::util::table::Table;

fn main() {
    ablate_dra();
    ablate_parallelism();
    ablate_batching();
    ablate_alloc();
    println!("\nablations bench OK");
}

/// 1. XNOR mechanisms on identical geometry/timing.
fn ablate_dra() {
    println!("=== ablation 1: XNOR2 mechanism (same substrate) ===\n");
    let t = TimingParams::default();
    // DRA (DRIM): 2 copies + 1 DRA
    let dra_aaps = 3.0;
    // TRA-composed (Ambit-style on DRIM hardware): 5 copies/init + 2 TRA
    let tra_aaps = 7.0;
    // TRA-composed if row-initialization were free (hypothetical):
    let tra_no_init = 5.0;
    let mut tab = Table::new(&["mechanism", "AAPs", "latency", "speedup vs TRA"]);
    for (name, aaps) in [
        ("TRA-composed (Ambit)", tra_aaps),
        ("TRA w/o init (hypo)", tra_no_init),
        ("DRA (DRIM)", dra_aaps),
    ] {
        tab.row(&[
            name.to_string(),
            format!("{aaps}"),
            format!("{:.0} ns", aaps * t.t_aap_ns),
            format!("{:.2}x", tra_aaps / aaps),
        ]);
    }
    tab.print();
    println!(
        "→ of the {:.2}x total, {:.2}x comes from eliminating row init, \
         {:.2}x from the single-cycle DRA itself\n",
        tra_aaps / dra_aaps,
        tra_aaps / tra_no_init,
        tra_no_init / dra_aaps
    );
}

/// 2. Throughput vs active sub-arrays per bank.
fn ablate_parallelism() {
    println!("=== ablation 2: sub-array-level parallelism (XNOR2, 2^29 bits) ===\n");
    let mut tab = Table::new(&["active sub-arrays/bank", "throughput", "scaling"]);
    let mut base = 0.0;
    for active in [1usize, 2, 4, 8, 16, 32, 64] {
        let p = pim_with_active(active);
        let tp = p.throughput_bits_per_sec(BulkOp::Xnor2, 1 << 29);
        if base == 0.0 {
            base = tp;
        }
        tab.row(&[
            format!("{active}"),
            format!("{}bit/s", fmt_rate(tp)),
            format!("{:.1}x", tp / base),
        ]);
    }
    tab.print();
    println!("→ linear until the vector no longer fills a wave\n");
}

fn pim_with_active(active: usize) -> pim::PimPlatform {
    // drim_r with a modified power budget
    let mut g = DramGeometry::default();
    g.active_subarrays = active;
    pim::drim_r_with_geometry(g)
}

/// 3. Wave utilization under the two batching policies.
fn ablate_batching() {
    println!("=== ablation 3: batching policy (wave utilization) ===\n");
    let mk = |policy| {
        Router::new(ServiceConfig {
            geometry: DramGeometry::default(),
            workers: 1,
            policy,
        })
    };
    let im = mk(BatchPolicy::Immediate);
    let co = mk(BatchPolicy::Coalesce);
    let mut tab = Table::new(&[
        "queue (chunks/request)",
        "util immediate",
        "util coalesce",
        "latency ratio",
    ]);
    for queue in [
        vec![1usize; 16],
        vec![10; 16],
        vec![100; 16],
        vec![300; 4],
        vec![64; 8],
    ] {
        let ui = im.utilization(&queue);
        let uc = co.utilization(&queue);
        let li = im.sim_latency_ns(BulkOp::Xnor2, &queue);
        let lc = co.sim_latency_ns(BulkOp::Xnor2, &queue);
        tab.row(&[
            format!("{}×{}", queue.len(), queue[0]),
            format!("{:.1}%", ui * 100.0),
            format!("{:.1}%", uc * 100.0),
            format!("{:.2}x", li / lc),
        ]);
    }
    tab.print();
    println!("→ coalescing recovers the partial-wave waste of small requests\n");
}

/// 4. Allocator placement policy: co-located operands need 0 extra moves;
/// naive placement pays host-path copies (DDR4 interface energy + latency).
fn ablate_alloc() {
    println!("=== ablation 4: operand placement ===\n");
    let t = TimingParams::default();
    let m = drim::energy::EnergyModel::default();
    let xnor_aaps = 3.0;
    // naive placement: 2 operands must first migrate across sub-arrays
    // through the global row buffer (read + write per row, ~2 bursts/row
    // of latency dominated by the off-chip-class path)
    let migrate_ns_per_row = 2.0 * (t.t_ras_ns + t.t_rp_ns) + 128.0 * t.t_burst_ns;
    let migrate_pj = 2.0 * m.offchip_pj(8192.0);
    let xnor_pj = pim::drim_r().seq_pj(BulkOp::Xnor2);
    let mut tab = Table::new(&["placement", "latency/row", "energy/row"]);
    tab.row(&[
        "co-located (allocator)".into(),
        format!("{:.0} ns", xnor_aaps * t.t_aap_ns),
        format!("{:.1} nJ", xnor_pj / 1e3),
    ]);
    tab.row(&[
        "naive (2 migrations)".into(),
        format!("{:.0} ns", xnor_aaps * t.t_aap_ns + 2.0 * migrate_ns_per_row),
        format!("{:.1} nJ", (xnor_pj + 2.0 * migrate_pj) / 1e3),
    ]);
    tab.print();
    println!("→ same-sub-array placement is mandatory, not an optimization\n");
}
