//! Fig. 6 regeneration: the DRA transient waveforms for all four input
//! cases, dumped to CSV (plot-ready) and summarized; cross-checks the JAX
//! artifact against the Rust mirror when artifacts are present.

use drim::analog::params as P;
use drim::analog::transient;
use drim::runtime::Runtime;
use drim::util::bench::Bencher;

fn main() {
    println!("=== Fig. 6: DRA transient (P.S. → C.S.S. → S.A.S.) ===\n");
    let steps = P::transient_steps();
    let cases = transient::all_cases();

    // CSV for plotting
    let path = "target/fig6_transient.csv";
    let mut out = String::from(
        "t_ns,bl_00,blb_00,ci_00,cj_00,bl_01,blb_01,ci_01,cj_01,\
         bl_10,blb_10,ci_10,cj_10,bl_11,blb_11,ci_11,cj_11\n",
    );
    for t in 0..steps {
        let mut row = vec![format!("{:.3}", t as f64 * P::DT_NS)];
        for (_, _, w) in &cases {
            for k in 0..4 {
                row.push(format!("{:.5}", w[t][k]));
            }
        }
        out.push_str(&row.join(","));
        out.push('\n');
    }
    std::fs::create_dir_all("target").ok();
    std::fs::write(path, &out).expect("write csv");
    println!("wrote {} steps × 4 cases to {path}\n", steps);

    // phase summary (the paper's visual)
    let (p_end, s_end) = (
        (P::T_PRECHARGE_NS / P::DT_NS) as usize,
        ((P::T_PRECHARGE_NS + P::T_SHARE_NS) / P::DT_NS) as usize,
    );
    println!("case   V(BL) @P.S.  @C.S.S.end  @S.A.S.end   XNOR");
    for (di, dj, w) in &cases {
        println!(
            "Di={} Dj={}   {:.3} V     {:.3} V     {:.3} V      {}",
            *di as u8,
            *dj as u8,
            w[p_end - 1][0],
            w[s_end - 1][0],
            w[steps - 1][0],
            (w[steps - 1][0] > P::VDD / 2.0) as u8
        );
    }

    // JAX cross-check
    match Runtime::load_default() {
        Ok(mut rt) => {
            let flat = rt
                .transient([[0., 0.], [0., 1.], [1., 0.], [1., 1.]])
                .expect("transient artifact");
            let mut max_err = 0.0f64;
            for (ci, (_, _, w)) in cases.iter().enumerate() {
                for (t, s) in w.iter().enumerate() {
                    for k in 0..4 {
                        let jax = flat[(ci * steps + t) * 4 + k] as f64;
                        max_err = max_err.max((jax - s[k]).abs());
                    }
                }
            }
            println!("\nmax |jax - rust| over all 4×{steps}×4 samples: {max_err:.2e} V");
            assert!(max_err < 2e-3, "transient mirrors diverged");
        }
        Err(e) => eprintln!("\n(JAX cross-check skipped — {e})"),
    }

    println!("\n=== integrator timing ===");
    Bencher::default().run("rust transient, 4 cases", (4 * steps) as f64, || {
        transient::all_cases()
    });
    println!("\nfig6 bench OK");
}
