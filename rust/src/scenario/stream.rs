//! Deterministic arrival-stream generation.
//!
//! A scenario case compiles to a flat, pre-materialized list of
//! [`ArrivalEvent`]s before anything touches the cluster: tenant
//! interleaving is **stride scheduling** over exact largest-remainder
//! quotas (not weighted sampling — offered load matches the declared
//! load *exactly*), arrival times come from the configured process on a
//! simulated clock (never the host clock), and resident tenants sample
//! their region rank from a Zipf law. Everything is driven by one
//! explicitly seeded [`Rng`], so the same `(scenario, seed)` pair always
//! yields the same byte-identical stream — the replay contract the
//! determinism property test and the CI determinism job pin.

use crate::util::rng::{zipf_cdf, Rng};

use super::spec::{ArrivalProcess, PlacementMode, ResolvedCase};

/// One generated request arrival.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArrivalEvent {
    /// position in the stream (submission order)
    pub index: usize,
    /// simulated arrival time
    pub vtime_ns: u64,
    /// index into the case's tenant list
    pub tenant: usize,
    /// this tenant's per-tenant sequence number (0-based)
    pub tenant_seq: usize,
    /// resident region rank the request targets (0 for carried tenants)
    pub rank: usize,
    /// route to `owner + 1` instead of the rank's owner — a forced
    /// locality miss (`miss_every`)
    pub forced_miss: bool,
}

/// Generate the full arrival stream for one resolved case.
///
/// RNG draw order per event is fixed (arrival gap first, then region
/// rank) so streams are reproducible and insensitive to refactors of the
/// executor.
pub fn generate(case: &ResolvedCase) -> Vec<ArrivalEvent> {
    let counts = case.tenant_requests();
    let mut remaining = counts;
    // stride scheduling: every tenant starts at pass 0, each grant
    // advances its pass by 1/weight; ties resolve to the lowest tenant
    // index. A 1:7 two-tenant mix therefore yields the classic
    // every-8th-request minority pattern.
    let mut pass: Vec<f64> = vec![0.0; case.tenants.len()];
    let mut seq: Vec<usize> = vec![0; case.tenants.len()];
    let cdfs: Vec<Option<Vec<f64>>> = case
        .tenants
        .iter()
        .map(|t| {
            (t.placement == PlacementMode::Resident && t.regions > 0)
                .then(|| zipf_cdf(t.regions, t.zipf_theta))
        })
        .collect();

    let mut rng = Rng::new(case.seed);
    let mut clock_ns = 0.0f64;
    let mut events = Vec::with_capacity(case.requests);
    for index in 0..case.requests {
        let scale = phase_scale(case, index);
        match case.process {
            ArrivalProcess::Sequential => {}
            ArrivalProcess::Poisson { rate_per_sec } => {
                // exponential inter-arrival gap at the phase-scaled rate;
                // 1 - f64() is in (0, 1], so ln() is finite. The spec
                // layer rejects non-positive rates and phase scales, so a
                // zero/NaN effective rate here is a bug upstream — assert
                // rather than let the virtual clock go infinite/NaN and
                // spin the open-loop pacer forever.
                let eff = rate_per_sec * scale;
                assert!(
                    eff > 0.0 && eff.is_finite(),
                    "non-positive effective poisson rate {eff} \
                     (rate_per_sec={rate_per_sec}, phase scale={scale}); \
                     scenario validation should have rejected this spec"
                );
                let u = 1.0 - rng.f64();
                clock_ns += -u.ln() / eff * 1e9;
            }
            ArrivalProcess::Burst { size, gap_ns } => {
                if index > 0 && index % size == 0 {
                    clock_ns += gap_ns as f64 / scale;
                }
            }
        }

        // grant the stream slot to the lowest-pass tenant with quota left
        let tenant = (0..case.tenants.len())
            .filter(|&t| remaining[t] > 0)
            .min_by(|&a, &b| pass[a].partial_cmp(&pass[b]).unwrap_or(std::cmp::Ordering::Equal))
            .expect("stream shorter than total quota");
        remaining[tenant] -= 1;
        pass[tenant] += 1.0 / case.tenants[tenant].weight;
        let tenant_seq = seq[tenant];
        seq[tenant] += 1;

        let rank = match &cdfs[tenant] {
            Some(cdf) => rng.sample_cdf(cdf),
            None => 0,
        };
        let k = case.tenants[tenant].miss_every;
        let forced_miss = k > 0 && tenant_seq % k == k - 1;
        events.push(ArrivalEvent {
            index,
            vtime_ns: clock_ns.round() as u64,
            tenant,
            tenant_seq,
            rank,
            forced_miss,
        });
    }
    events
}

/// The diurnal rate multiplier in effect at stream position `index`:
/// phases partition the request stream by their (normalized) `frac`
/// weights, each scaling the base rate.
fn phase_scale(case: &ResolvedCase, index: usize) -> f64 {
    if case.phases.is_empty() {
        return 1.0;
    }
    let total: f64 = case.phases.iter().map(|p| p.frac).sum();
    let progress = index as f64 / case.requests as f64;
    let mut acc = 0.0;
    for p in &case.phases {
        acc += p.frac / total;
        if progress < acc {
            return p.rate_scale;
        }
    }
    case.phases.last().map(|p| p.rate_scale).unwrap_or(1.0)
}

/// FNV-1a 64 digest of the stream — two identically-seeded generations
/// must agree on every field of every event.
pub fn stream_digest(events: &[ArrivalEvent]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    let mut mix = |v: u64| {
        h ^= v;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    };
    for e in events {
        mix(e.index as u64);
        mix(e.vtime_ns);
        mix(e.tenant as u64);
        mix(e.tenant_seq as u64);
        mix(e.rank as u64);
        mix(e.forced_miss as u64);
    }
    h
}

/// Total offered load of a stream in wave units — must equal
/// [`ResolvedCase::declared_wave_units`] exactly.
pub fn offered_wave_units(case: &ResolvedCase, events: &[ArrivalEvent]) -> u64 {
    let cols = case.geometry.cols;
    events
        .iter()
        .map(|e| case.tenants[e.tenant].bits.div_ceil(cols) as u64)
        .sum()
}
