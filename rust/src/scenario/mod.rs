//! Trace-driven scenario harness: declarative multi-tenant fleet
//! benchmarks with deterministic replay and CI-gated fairness.
//!
//! A scenario is a small TOML (or JSON) document describing the device
//! fleet, the tenant mix (per-tenant op / operand-size / region
//! distributions, Zipf skew, quotas), the arrival process (sequential
//! burst, open-loop Poisson, bursty, with diurnal phases), runtime knobs
//! (coalescing, residency capacity/eviction, the rebalancer), named
//! cases overriding any axis, and structured metric gates. `drim bench
//! --scenario <file>` validates it ([`spec`]), materializes a seeded
//! deterministic arrival stream ([`stream`]), drives a [`DrimCluster`]
//! through it ([`exec`]), and emits the verdicts as a `BENCH_<name>.json`
//! artifact via [`crate::util::bench::BenchReport`].
//!
//! The checked-in scenarios under `scenarios/` are the repo's canonical
//! ablation matrix — CI runs all of them and additionally replays one
//! twice to diff the artifacts byte-for-byte (the determinism contract;
//! see `docs/ARCHITECTURE.md` § Scenario harness).
//!
//! [`DrimCluster`]: crate::cluster::DrimCluster

pub mod exec;
pub mod spec;
pub mod stream;
pub mod toml;

pub use exec::{run_case, run_scenario, CaseOutcome, GateOutcome, ScenarioOutcome};
pub use spec::{ResolvedCase, ScenarioError, ScenarioSpec, SloSpec, TelemetrySpec};
pub use stream::{generate, offered_wave_units, stream_digest, ArrivalEvent};
pub use toml::{parse_source, parse_toml, ScenarioDoc};
