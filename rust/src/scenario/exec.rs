//! Scenario executor: drive a [`DrimCluster`] from a pre-materialized
//! arrival stream and collect deterministic metrics.
//!
//! # Determinism contract
//!
//! Everything recorded here derives from the simulated timeline: request
//! payloads and arrival times come from seeded RNG streams, responses are
//! harvested in FIFO submission order, and per-tenant sojourn is computed
//! on a **virtual clock** (per-device `max(ready, arrival) + service`)
//! rather than the host clock. Within the deterministic envelope
//! (`steal = false`, strict-or-off coalescing, in-flight below the
//! admission cap) the same `(scenario, seed)` pair produces byte-identical
//! metrics — the replay contract the CI determinism job diffs. Host
//! wall-clock quantities never enter scenario metrics.
//!
//! # Tenant semantics
//!
//! *Carried* tenants stream fresh random operands with every request.
//! *Resident* tenants pre-register a pool of `regions` ranks (each rank =
//! `op.arity()` co-resident rows, owner = `rank % devices`), sample ranks
//! by their Zipf law, and pin every `miss_every`-th request one device
//! past the owner (a forced locality miss). A request whose rank was
//! evicted observes [`RouteError::Evicted`] and is requeued —
//! re-registered and resubmitted, degrading to a carried payload after
//! repeated evictions or a capacity refusal (degrade, don't collapse:
//! the same discipline as `DrimCluster::pump_capacity`).

use std::collections::VecDeque;
use std::sync::mpsc::Receiver;

use crate::cluster::{
    ClusterRequest, ClusterResponse, DeviceId, DrimCluster, FleetSnapshot, RegionId,
    RouteError, TenantBreakdown,
};
use crate::coordinator::{BulkRequest, Payload};
use crate::obs::slo::{self, SloOutcome};
use crate::obs::timeseries::TimeSeriesRecorder;
use crate::obs::Json;
use crate::util::bitrow::BitRow;
use crate::util::rng::Rng;

use super::spec::{
    CoalesceMode, GateOp, GateOperand, GateSpec, PlacementMode, ResolvedCase, ScenarioSpec,
};
use super::stream::{self, ArrivalEvent};

/// Seed offset separating the payload RNG from the arrival-stream RNG —
/// regenerating one stream must not perturb the other.
const PAYLOAD_SEED_SALT: u64 = 0x9E37_79B9_7F4A_7C15;

/// One executed case: the fleet snapshot (fairness attached) plus the
/// flat deterministic metric list the gates and `BENCH_*.json` consume.
#[derive(Clone, Debug)]
pub struct CaseOutcome {
    pub name: String,
    pub snapshot: FleetSnapshot,
    /// insertion-ordered `metric → value` pairs, deterministic within the
    /// envelope (see module docs)
    pub metrics: Vec<(String, Json)>,
    /// SLOs bound to this case, evaluated over the recorded virtual-clock
    /// series (empty when the scenario declares none); `run_scenario`
    /// surfaces these as first-class gates
    pub slos: Vec<SloOutcome>,
}

impl CaseOutcome {
    /// Metric value as f64 (gate arithmetic).
    pub fn metric_f64(&self, key: &str) -> Option<f64> {
        self.metrics
            .iter()
            .find(|(k, _)| k == key)
            .and_then(|(_, v)| v.as_f64())
    }
}

/// One evaluated gate.
#[derive(Clone, Debug)]
pub struct GateOutcome {
    pub name: String,
    pub pass: bool,
    /// human-readable `left op right` rendering with the observed values
    pub detail: String,
}

/// A full scenario run: every case executed in declaration order, every
/// gate evaluated.
#[derive(Clone, Debug)]
pub struct ScenarioOutcome {
    pub cases: Vec<CaseOutcome>,
    pub gates: Vec<GateOutcome>,
}

impl ScenarioOutcome {
    pub fn ok(&self) -> bool {
        self.gates.iter().all(|g| g.pass)
    }
}

/// Execute every case of a validated scenario and evaluate its gates.
/// Evaluated SLOs join the gate list as `slo.<name>` entries — an SLO
/// burn-rate breach fails the scenario exactly like a metric gate.
pub fn run_scenario(spec: &ScenarioSpec) -> ScenarioOutcome {
    let cases: Vec<CaseOutcome> = spec.resolved_cases().iter().map(run_case).collect();
    let mut gates: Vec<GateOutcome> = spec
        .gates
        .iter()
        .map(|g| evaluate_gate(g, &cases))
        .collect();
    for case in &cases {
        for o in &case.slos {
            gates.push(GateOutcome {
                name: format!("slo.{}", o.name),
                pass: o.pass,
                detail: format!("case {}: {}", case.name, o.detail),
            });
        }
    }
    ScenarioOutcome { cases, gates }
}

/// A resident tenant's rank pool: the registered region handles (None
/// after a capacity refusal or repeated eviction — degraded to carried)
/// and the operand rows backing them (kept for requeue and degrade).
struct RankPool {
    slots: Vec<Option<Vec<RegionId>>>,
    rows: Vec<Vec<BitRow>>,
}

struct PendingReq {
    tenant: usize,
    arrival_ns: f64,
    rx: Receiver<ClusterResponse>,
}

/// Per-tenant accounting on the virtual clock.
#[derive(Clone, Default)]
struct TenantAcct {
    offered: u64,
    shed: u64,
    completed: u64,
    requeues: u64,
    /// submissions that fell through to the degrade-to-carried arm (their
    /// resident slot was capacity-refused or kept getting evicted); the
    /// request still completes, so `degraded <= completed`
    degraded: u64,
    outstanding: usize,
    sum_service_ns: f64,
    sum_sojourn_ns: f64,
    max_sojourn_ns: f64,
}

/// Execute one resolved case against a fresh fleet.
pub fn run_case(case: &ResolvedCase) -> CaseOutcome {
    let events = stream::generate(case);
    let cluster = DrimCluster::new(case.cluster_config());
    let mut payload_rng = Rng::new(case.seed ^ PAYLOAD_SEED_SALT);
    let coalescing = case.coalesce != CoalesceMode::Off;
    let policy = case.replication_policy();

    // resident rank pools, registered before any traffic flows (tenant
    // order, rank order — deterministic registration sequence)
    let mut pools: Vec<Option<RankPool>> = Vec::with_capacity(case.tenants.len());
    for t in &case.tenants {
        if t.placement != PlacementMode::Resident {
            pools.push(None);
            continue;
        }
        let mut slots = Vec::with_capacity(t.regions);
        let mut rows = Vec::with_capacity(t.regions);
        for rank in 0..t.regions {
            let owner = DeviceId(rank % case.devices);
            let operands: Vec<BitRow> = (0..t.op.arity())
                .map(|_| BitRow::random(t.bits, &mut payload_rng))
                .collect();
            let ids: Option<Vec<RegionId>> = operands
                .iter()
                .map(|row| {
                    cluster
                        .try_register_resident(owner, Payload::Bits(row.clone()))
                        .ok()
                })
                .collect();
            slots.push(ids);
            rows.push(operands);
        }
        pools.push(Some(RankPool { slots, rows }));
    }

    let mut acct: Vec<TenantAcct> = vec![TenantAcct::default(); case.tenants.len()];
    let mut vclock: Vec<f64> = vec![0.0; case.devices];
    let mut pending: VecDeque<PendingReq> = VecDeque::new();
    let mut digest = Fnv::new();
    let mut completed_total = 0u64;
    // continuous telemetry: one lane per tenant, every observation
    // stamped on the virtual clock (see obs::timeseries module docs for
    // the determinism contract)
    let mut recorder: Option<TimeSeriesRecorder> = case.telemetry.map(|t| {
        TimeSeriesRecorder::new(
            t.interval_ns,
            t.capacity,
            case.devices,
            case.tenants.iter().map(|t| t.name.clone()).collect(),
        )
    });

    let mut harvest_one = |pending: &mut VecDeque<PendingReq>,
                           acct: &mut [TenantAcct],
                           vclock: &mut [f64],
                           digest: &mut Fnv,
                           completed_total: &mut u64,
                           recorder: &mut Option<TimeSeriesRecorder>| {
        // a strict coalescer may still be holding the response we are
        // about to block on — flush staged waves before any recv
        if coalescing {
            cluster.flush_coalesced();
        }
        let p = pending.pop_front().expect("harvest with empty pending");
        let resp = p.rx.recv().expect("cluster response");
        let inner = &resp.inner;
        digest.payload(&inner.result);
        // virtual-clock sojourn: the executing device serves harvested
        // requests in order; a coalesced group charges each member its
        // share of the shared wave set's latency
        let service = inner.sim_latency_ns / inner.batched_with.max(1) as f64;
        let dev = resp.device.0;
        let start = vclock[dev].max(p.arrival_ns);
        vclock[dev] = start + service;
        let sojourn = vclock[dev] - p.arrival_ns;
        let a = &mut acct[p.tenant];
        a.completed += 1;
        a.outstanding -= 1;
        a.sum_service_ns += service;
        a.sum_sojourn_ns += sojourn;
        a.max_sojourn_ns = a.max_sojourn_ns.max(sojourn);
        if let Some(rec) = recorder.as_mut() {
            let now = vclock[dev] as u64;
            rec.record_completion(now, p.tenant, sojourn as u64, service as u64);
            rec.record_queue_depth(now, pending.len());
        }
        *completed_total += 1;
        if case.rebalance_every > 0 && *completed_total % case.rebalance_every as u64 == 0 {
            cluster.rebalance(&policy);
        }
    };

    for ev in &events {
        let tspec = &case.tenants[ev.tenant];
        acct[ev.tenant].offered += 1;
        // per-tenant quota: shed arrivals beyond the inflight budget
        // (deterministic — the window slides in submission order)
        if tspec.max_inflight > 0 && acct[ev.tenant].outstanding >= tspec.max_inflight {
            acct[ev.tenant].shed += 1;
            if let Some(rec) = recorder.as_mut() {
                rec.record_arrival(ev.vtime_ns, false);
            }
            continue;
        }
        let rx = submit_event(
            case,
            &cluster,
            ev,
            pools[ev.tenant].as_mut(),
            &mut payload_rng,
            &mut acct[ev.tenant],
        );
        acct[ev.tenant].outstanding += 1;
        pending.push_back(PendingReq {
            tenant: ev.tenant,
            arrival_ns: ev.vtime_ns as f64,
            rx,
        });
        if let Some(rec) = recorder.as_mut() {
            rec.record_arrival(ev.vtime_ns, true);
            rec.record_queue_depth(ev.vtime_ns, pending.len());
        }
        if case.window > 0 && pending.len() >= case.window {
            harvest_one(
                &mut pending,
                &mut acct,
                &mut vclock,
                &mut digest,
                &mut completed_total,
                &mut recorder,
            );
        }
    }
    while !pending.is_empty() {
        harvest_one(
            &mut pending,
            &mut acct,
            &mut vclock,
            &mut digest,
            &mut completed_total,
            &mut recorder,
        );
    }

    // capacity-bounded fleets must end the run within budget, with a
    // coherent registry — an overdraft is a harness/registry bug
    if let Some(bound) = case.capacity_bits() {
        for d in 0..case.devices {
            let resident = cluster.registry().resident_bits_on(DeviceId(d));
            assert!(
                resident <= bound,
                "case `{}`: device {d} resident {resident} bits exceeds the \
                 {bound}-bit capacity",
                case.name
            );
        }
        cluster
            .registry()
            .check_invariants()
            .expect("residency registry invariants");
    }

    let fairness: Vec<TenantBreakdown> = case
        .tenants
        .iter()
        .zip(acct.iter())
        .map(|(t, a)| TenantBreakdown {
            tenant: t.name.clone(),
            offered: a.offered,
            admitted: a.offered - a.shed,
            shed: a.shed,
            completed: a.completed,
            requeues: a.requeues,
            degraded: a.degraded,
            mean_service_ns: ratio(a.sum_service_ns, a.completed),
            mean_sojourn_ns: ratio(a.sum_sojourn_ns, a.completed),
            max_sojourn_ns: a.max_sojourn_ns,
            sojourn_inflation: if a.sum_service_ns > 0.0 {
                a.sum_sojourn_ns / a.sum_service_ns
            } else {
                1.0
            },
        })
        .collect();

    let telemetry = recorder
        .as_ref()
        .map(|r| r.summary())
        .unwrap_or_default();
    let snapshot = cluster
        .shutdown()
        .with_fairness(fairness)
        .with_telemetry(telemetry);
    let mut metrics = flatten_metrics(case, &events, &snapshot, &vclock, digest.finish());

    // SLO verdicts, evaluated over the recorded series (deterministic:
    // both the series and the evaluation are virtual-clock-only)
    let slos: Vec<SloOutcome> = match recorder.as_ref() {
        Some(rec) => case.slos.iter().map(|s| slo::evaluate(s, rec)).collect(),
        None => Vec::new(),
    };
    for o in &slos {
        let p = format!("slo.{}", o.name);
        metrics.push((format!("{p}.pass"), Json::U64(o.pass as u64)));
        metrics.push((format!("{p}.max_burn"), Json::F64(o.max_burn)));
        metrics.push((format!("{p}.overall_burn"), Json::F64(o.overall_burn)));
        metrics.push((format!("{p}.bad"), Json::U64(o.bad)));
        metrics.push((format!("{p}.total"), Json::U64(o.total)));
    }

    CaseOutcome {
        name: case.name.clone(),
        snapshot,
        metrics,
        slos,
    }
}

/// Build and submit one arrival, navigating the resident requeue/degrade
/// state machine. Returns the response receiver.
fn submit_event(
    case: &ResolvedCase,
    cluster: &DrimCluster,
    ev: &ArrivalEvent,
    pool: Option<&mut RankPool>,
    payload_rng: &mut Rng,
    acct: &mut TenantAcct,
) -> Receiver<ClusterResponse> {
    let tspec = &case.tenants[ev.tenant];
    let pool = match pool {
        Some(p) => p,
        None => {
            // carried tenant: fresh random operands every request
            let rows: Vec<BitRow> = (0..tspec.op.arity())
                .map(|_| BitRow::random(tspec.bits, payload_rng))
                .collect();
            let req = ClusterRequest::carried(BulkRequest::bitwise(tspec.op, rows));
            return cluster
                .submit_routed_blocking(req)
                .expect("carried requests always resolve");
        }
    };
    let rank = ev.rank;
    let owner = DeviceId(rank % case.devices);
    let mut attempts = 0;
    loop {
        match &pool.slots[rank] {
            Some(ids) if attempts < 3 => {
                let req = ClusterRequest::resident(tspec.op, ids.clone());
                let sent = if ev.forced_miss {
                    let elsewhere = DeviceId((owner.0 + 1) % case.devices);
                    cluster.submit_routed_blocking_to(elsewhere, req)
                } else {
                    cluster.submit_routed_blocking(req)
                };
                match sent {
                    Ok(rx) => return rx,
                    Err(RouteError::Evicted(_) | RouteError::UnknownRegion(_)) => {
                        // the defined shed/requeue path: re-register the
                        // rank's rows and resubmit
                        acct.requeues += 1;
                        attempts += 1;
                        // restage (not plain register): the movement
                        // fabric prices the landing hop back into the
                        // rank's pinned rows — warm-up the prefetch mode
                        // overlaps with execution
                        pool.slots[rank] = pool.rows[rank]
                            .iter()
                            .map(|row| {
                                cluster
                                    .try_restage_resident(owner, Payload::Bits(row.clone()))
                                    .ok()
                            })
                            .collect();
                    }
                    Err(RouteError::Admission(_)) => {
                        unreachable!("blocking routed submit never sheds")
                    }
                }
            }
            // no resident slot (capacity refused it, or it keeps getting
            // evicted): degrade to carried payloads of the same rows
            _ => {
                acct.degraded += 1;
                let req = ClusterRequest::carried(BulkRequest::bitwise(
                    tspec.op,
                    pool.rows[rank].clone(),
                ));
                return cluster
                    .submit_routed_blocking(req)
                    .expect("carried requests always resolve");
            }
        }
    }
}

/// The flat metric list: fleet counters + derived quantities + per-tenant
/// fairness, every value simulated/deterministic (no wall clock).
fn flatten_metrics(
    case: &ResolvedCase,
    events: &[ArrivalEvent],
    snap: &FleetSnapshot,
    vclock: &[f64],
    results_digest: u64,
) -> Vec<(String, Json)> {
    let mut m: Vec<(String, Json)> = Vec::new();
    let mut put = |k: &str, v: Json| m.push((k.to_string(), v));
    let offered = events.len() as u64;
    let shed: u64 = snap.fairness.iter().map(|t| t.shed).sum();
    put("offered", Json::U64(offered));
    put("admitted", Json::U64(offered - shed));
    put("shed", Json::U64(shed));
    put("completed", Json::U64(snap.completed));
    put(
        "requeues",
        Json::U64(snap.fairness.iter().map(|t| t.requeues).sum()),
    );
    put(
        "degraded",
        Json::U64(snap.fairness.iter().map(|t| t.degraded).sum()),
    );
    put(
        "offered_wave_units",
        Json::U64(stream::offered_wave_units(case, events)),
    );
    put(
        "declared_wave_units",
        Json::U64(case.declared_wave_units()),
    );
    put("stream_digest", Json::U64(stream::stream_digest(events)));
    put("results_digest", Json::U64(results_digest));
    put("sim_makespan_ns", Json::U64(snap.merged.sim_ns));
    put(
        "makespan_with_copy_ns",
        Json::U64(snap.makespan_with_copy_ns()),
    );
    put(
        "throughput_bits_per_sec",
        Json::F64(snap.sim_throughput_bits_per_sec()),
    );
    put(
        "vclock_makespan_ns",
        Json::F64(vclock.iter().cloned().fold(0.0, f64::max)),
    );
    put("waves", Json::U64(snap.merged.waves));
    put("slot_occupancy", Json::F64(snap.slot_occupancy()));
    put("coalesced_requests", Json::U64(snap.coalesced_requests));
    put("waves_saved", Json::U64(snap.waves_saved));
    put("steals", Json::U64(snap.steals));
    put("resident_hits", Json::U64(snap.resident_hits));
    put("resident_misses", Json::U64(snap.resident_misses));
    put("copied_bytes", Json::U64(snap.copied_bytes));
    put("copy_cycles", Json::U64(snap.copy_cycles));
    put("evictions", Json::U64(snap.evictions));
    put("capacity_refusals", Json::U64(snap.capacity_refusals));
    put("replications", Json::U64(snap.replications));
    put("migrations", Json::U64(snap.migrations));
    put("movement_moves", Json::U64(snap.movement.total_moves()));
    put(
        "movement_in_dram_moves",
        Json::U64(snap.movement.in_dram_moves()),
    );
    put(
        "movement_in_dram_bytes",
        Json::U64(snap.movement.in_dram_bytes()),
    );
    put(
        "prefetch_hidden_ns",
        Json::U64(snap.movement.prefetch_hidden_ns),
    );
    put("telemetry.samples", Json::U64(snap.telemetry.samples));
    put("telemetry.dropped", Json::U64(snap.telemetry.dropped));
    put(
        "telemetry.interval_ns",
        Json::U64(snap.telemetry.interval_ns),
    );
    put(
        "telemetry.last_sample_ns",
        Json::U64(snap.telemetry.last_sample_ns),
    );
    for t in &snap.fairness {
        let p = format!("tenant.{}", t.tenant);
        let mut tput = |k: &str, v: Json| m.push((format!("{p}.{k}"), v));
        tput("offered", Json::U64(t.offered));
        tput("admitted", Json::U64(t.admitted));
        tput("shed", Json::U64(t.shed));
        tput("completed", Json::U64(t.completed));
        tput("requeues", Json::U64(t.requeues));
        tput("degraded", Json::U64(t.degraded));
        tput("mean_service_ns", Json::F64(t.mean_service_ns));
        tput("mean_sojourn_ns", Json::F64(t.mean_sojourn_ns));
        tput("max_sojourn_ns", Json::F64(t.max_sojourn_ns));
        tput("sojourn_inflation", Json::F64(t.sojourn_inflation));
    }
    m
}

/// Evaluate one gate against the executed cases.
pub fn evaluate_gate(gate: &GateSpec, cases: &[CaseOutcome]) -> GateOutcome {
    let resolve = |r: &str| -> Result<f64, String> {
        let (case, metric) = r
            .split_once('.')
            .ok_or_else(|| format!("bad reference `{r}`"))?;
        let c = cases
            .iter()
            .find(|c| c.name == case)
            .ok_or_else(|| format!("unknown case `{case}`"))?;
        c.metric_f64(metric)
            .ok_or_else(|| format!("unknown metric `{metric}` in case `{case}`"))
    };
    let left = resolve(&gate.left);
    let right = match &gate.right {
        GateOperand::Metric(r) => resolve(r),
        GateOperand::Value(v) => Ok(*v),
    };
    match (left, right) {
        (Ok(l), Ok(r)) => {
            let r = r * gate.scale;
            let pass = match gate.op {
                GateOp::Lt => l < r,
                GateOp::Le => l <= r,
                GateOp::Gt => l > r,
                GateOp::Ge => l >= r,
                GateOp::Eq => (l - r).abs() <= gate.tol,
                GateOp::Ne => (l - r).abs() > gate.tol,
            };
            let detail = format!("{} = {l} {} {r}", gate.left, gate.op.symbol());
            GateOutcome {
                name: gate.name.clone(),
                pass,
                detail,
            }
        }
        (Err(e), _) | (_, Err(e)) => GateOutcome {
            name: gate.name.clone(),
            pass: false,
            detail: e,
        },
    }
}

fn ratio(sum: f64, n: u64) -> f64 {
    if n > 0 {
        sum / n as f64
    } else {
        0.0
    }
}

/// FNV-1a 64 over result payload words in harvest (= submission) order —
/// the byte-exactness signal the coalescing gates compare across modes.
struct Fnv(u64);

impl Fnv {
    fn new() -> Self {
        Fnv(0xcbf2_9ce4_8422_2325)
    }

    fn word(&mut self, v: u64) {
        self.0 ^= v;
        self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
    }

    fn payload(&mut self, p: &Payload) {
        match p {
            Payload::Bits(b) => {
                for &w in b.words() {
                    self.word(w);
                }
            }
            Payload::U32(v) => {
                for &x in v {
                    self.word(x as u64);
                }
            }
        }
    }

    fn finish(&self) -> u64 {
        self.0
    }
}
