//! Scenario schema + validation.
//!
//! A scenario describes — declaratively — everything a multi-tenant fleet
//! benchmark needs: the device fleet and geometry, the tenant mix
//! (per-tenant op / size / region-popularity distributions and quotas),
//! the arrival process (sequential burst, open-loop Poisson, bursty, with
//! optional diurnal phases), runtime knobs (coalescing, residency
//! capacity/eviction, the rebalancer), named **cases** overriding any of
//! those axes, and structured **gates** comparing case metrics.
//!
//! Validation consumes the [`ScenarioDoc`] tree and rejects unknown keys,
//! out-of-range values, and dangling references with **line-anchored**
//! errors (the TOML reader records where each key was defined).

use crate::cluster::{
    CapacityConfig, ClusterConfig, CoalesceConfig, MovementConfig, ReplicationConfig,
    ReplicationPolicy,
};
use crate::coordinator::ServiceConfig;
use crate::dram::geometry::{DeviceCapacity, DramGeometry};
use crate::isa::program::BulkOp;
use crate::obs::slo::{SloConfig, SloKind};
use crate::obs::timeseries;
use crate::obs::Json;

use super::toml::ScenarioDoc;

/// A validation failure, anchored to the source line that caused it when
/// the document came from TOML.
#[derive(Debug, Clone)]
pub struct ScenarioError {
    /// key path, e.g. `tenants[0].weight`
    pub path: String,
    /// 1-based source line, when known
    pub line: Option<usize>,
    pub msg: String,
}

impl std::fmt::Display for ScenarioError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self.line {
            Some(n) => write!(f, "line {n}: {}: {}", self.path, self.msg),
            None => write!(f, "{}: {}", self.path, self.msg),
        }
    }
}

/// How a tenant's operands reach the device.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlacementMode {
    /// payloads carried inline with every request (host→device stream)
    Carried,
    /// operands pre-registered as resident regions, requests routed to
    /// their owner
    Resident,
}

/// One traffic class in the mix.
#[derive(Clone, Debug)]
pub struct TenantSpec {
    pub name: String,
    /// share of the request stream (apportioned exactly, then interleaved
    /// by stride scheduling — deterministic, not sampled)
    pub weight: f64,
    pub op: BulkOp,
    /// operand bits per request
    pub bits: usize,
    pub placement: PlacementMode,
    /// resident region *ranks* (each rank holds `op.arity()` co-resident
    /// rows); requests sample a rank from the Zipf law below
    pub regions: usize,
    /// Zipf exponent over the rank pool (0 = uniform)
    pub zipf_theta: f64,
    /// every k-th request of this tenant is pinned one device past its
    /// rank's owner — a forced locality miss (0 = never)
    pub miss_every: usize,
    /// executor-level quota: arrivals beyond this many outstanding
    /// requests are shed (0 = unlimited)
    pub max_inflight: usize,
}

/// A named alternative tenant mix (cases switch mixes wholesale).
#[derive(Clone, Debug)]
pub struct MixSpec {
    pub name: String,
    pub tenants: Vec<TenantSpec>,
}

/// Arrival process for the open-loop stream.
#[derive(Clone, Debug)]
pub enum ArrivalProcess {
    /// every request arrives at t=0 (the closed burst the ablations use)
    Sequential,
    /// exponential inter-arrival gaps at `rate_per_sec` (simulated time)
    Poisson { rate_per_sec: f64 },
    /// groups of `size` arrivals separated by `gap_ns`
    Burst { size: usize, gap_ns: u64 },
}

/// One diurnal phase: `frac` of the request stream at `rate_scale` × the
/// base rate.
#[derive(Clone, Debug)]
pub struct PhaseSpec {
    pub frac: f64,
    pub rate_scale: f64,
}

#[derive(Clone, Debug)]
pub struct ArrivalSpec {
    /// total requests generated (before per-tenant quota shedding)
    pub requests: usize,
    pub process: ArrivalProcess,
    /// max outstanding responses before the executor harvests the oldest
    /// (0 = unbounded: submit everything, then harvest)
    pub window: usize,
    pub phases: Vec<PhaseSpec>,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CoalesceMode {
    Off,
    Strict,
    Opportunistic,
}

impl CoalesceMode {
    pub fn config(self, max_hold: u64) -> CoalesceConfig {
        let hold = if max_hold == 0 { u64::MAX } else { max_hold };
        match self {
            CoalesceMode::Off => CoalesceConfig::off(),
            CoalesceMode::Strict => CoalesceConfig::strict(hold),
            CoalesceMode::Opportunistic => CoalesceConfig {
                max_hold_submissions: hold,
                ..CoalesceConfig::opportunistic()
            },
        }
    }
}

/// Per-device residency budget.
#[derive(Clone, Copy, Debug)]
pub enum CapacitySpec {
    Unbounded,
    /// absolute resident bits per device
    Bits(u64),
    /// fraction of the per-device share of the declared resident working
    /// set (1.0 = the working set exactly fits when spread evenly)
    Share(f64),
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EvictionMode {
    FailFast,
    Lru,
    CostAware,
}

#[derive(Clone, Debug)]
pub struct ReplicationSpec {
    pub hot_uses: u64,
    pub amortize_factor: f64,
}

#[derive(Clone, Debug)]
pub struct RuntimeSpec {
    pub coalesce: CoalesceMode,
    /// strict-mode hold budget in submissions (0 = unlimited)
    pub max_hold: u64,
    pub capacity: CapacitySpec,
    pub eviction: EvictionMode,
    /// executor-driven rebalance sweep every N completions (0 = off)
    pub rebalance_every: usize,
    pub replication: ReplicationSpec,
    /// how placement movement's landing hops are priced and scheduled
    /// (`off` | `external` | `in_dram` | `prefetch`)
    pub movement: MovementConfig,
}

#[derive(Clone, Debug)]
pub struct FleetSpec {
    pub devices: usize,
    pub workers: usize,
    pub steal: bool,
    pub queue_cap: usize,
    pub geometry: DramGeometry,
}

/// One named case: the base scenario with any subset of axes overridden.
#[derive(Clone, Debug, Default)]
pub struct CaseSpec {
    pub name: String,
    pub mix: Option<String>,
    pub devices: Option<usize>,
    pub workers: Option<usize>,
    pub steal: Option<bool>,
    pub queue_cap: Option<usize>,
    pub coalesce: Option<CoalesceMode>,
    pub max_hold: Option<u64>,
    pub capacity: Option<CapacitySpec>,
    pub eviction: Option<EvictionMode>,
    pub rebalance_every: Option<usize>,
    pub movement: Option<MovementConfig>,
    pub requests: Option<usize>,
    pub window: Option<usize>,
    pub seed: Option<u64>,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GateOp {
    Lt,
    Le,
    Gt,
    Ge,
    Eq,
    Ne,
}

impl GateOp {
    pub fn symbol(self) -> &'static str {
        match self {
            GateOp::Lt => "<",
            GateOp::Le => "<=",
            GateOp::Gt => ">",
            GateOp::Ge => ">=",
            GateOp::Eq => "==",
            GateOp::Ne => "!=",
        }
    }
}

/// Right-hand side of a gate comparison.
#[derive(Clone, Debug)]
pub enum GateOperand {
    /// `case.metric` reference
    Metric(String),
    /// literal
    Value(f64),
}

/// A CI gate: `left op right × scale` (± `tol` for equality forms).
#[derive(Clone, Debug)]
pub struct GateSpec {
    pub name: String,
    pub left: String,
    pub op: GateOp,
    pub right: GateOperand,
    pub scale: f64,
    pub tol: f64,
}

/// Continuous-telemetry knobs (`[telemetry]` block): the virtual-clock
/// sampling interval and the bounded ring capacity the executor's
/// [`crate::obs::TimeSeriesRecorder`] runs with.
#[derive(Clone, Copy, Debug)]
pub struct TelemetrySpec {
    /// sampling interval in virtual nanoseconds
    pub interval_ns: u64,
    /// ring capacity in samples (oldest buckets fold into an evicted
    /// prefix beyond this)
    pub capacity: usize,
}

impl Default for TelemetrySpec {
    fn default() -> Self {
        TelemetrySpec {
            interval_ns: timeseries::DEFAULT_INTERVAL_NS,
            capacity: timeseries::DEFAULT_CAPACITY,
        }
    }
}

/// One `[[slo]]` block: a declarative SLO bound to a case, evaluated by
/// [`crate::obs::slo::evaluate`] over the recorded time-series and
/// reported as a first-class gate.
#[derive(Clone, Debug)]
pub struct SloSpec {
    /// the case whose series this SLO is evaluated against
    pub case: String,
    pub config: SloConfig,
}

/// A fully validated scenario.
#[derive(Clone, Debug)]
pub struct ScenarioSpec {
    pub name: String,
    pub description: String,
    pub seed: u64,
    pub fleet: FleetSpec,
    pub arrival: ArrivalSpec,
    pub runtime: RuntimeSpec,
    /// the default tenant mix
    pub tenants: Vec<TenantSpec>,
    /// named alternative mixes cases may select
    pub mixes: Vec<MixSpec>,
    /// named cases (empty scenario files get one implicit `default` case)
    pub cases: Vec<CaseSpec>,
    pub gates: Vec<GateSpec>,
    /// continuous-telemetry knobs; `None` still records when `slos` is
    /// non-empty (defaults apply), otherwise telemetry stays off
    pub telemetry: Option<TelemetrySpec>,
    /// declarative SLOs evaluated over the recorded series
    pub slos: Vec<SloSpec>,
}

/// The base scenario with one case's overrides applied — everything the
/// executor needs to drive a fleet.
#[derive(Clone, Debug)]
pub struct ResolvedCase {
    pub name: String,
    pub seed: u64,
    pub devices: usize,
    pub workers: usize,
    pub steal: bool,
    pub queue_cap: usize,
    pub geometry: DramGeometry,
    pub coalesce: CoalesceMode,
    pub max_hold: u64,
    pub capacity: CapacitySpec,
    pub eviction: EvictionMode,
    pub rebalance_every: usize,
    pub replication: ReplicationSpec,
    pub movement: MovementConfig,
    pub requests: usize,
    pub window: usize,
    pub process: ArrivalProcess,
    pub phases: Vec<PhaseSpec>,
    pub tenants: Vec<TenantSpec>,
    /// telemetry knobs when recording is on for this case (`Some`
    /// whenever the scenario declares `[telemetry]` or any SLO binds to
    /// this case)
    pub telemetry: Option<TelemetrySpec>,
    /// SLOs bound to this case, evaluated after execution
    pub slos: Vec<SloConfig>,
}

impl ResolvedCase {
    /// Declared resident working set in bits: every resident tenant's
    /// rank pool, all operand rows counted.
    pub fn declared_resident_bits(&self) -> u64 {
        self.tenants
            .iter()
            .filter(|t| t.placement == PlacementMode::Resident)
            .map(|t| (t.regions * t.op.arity() * t.bits) as u64)
            .sum()
    }

    /// The per-device capacity bound, `None` when unbounded.
    pub fn capacity_bits(&self) -> Option<u64> {
        match self.capacity {
            CapacitySpec::Unbounded => None,
            CapacitySpec::Bits(b) => Some(b),
            CapacitySpec::Share(f) => {
                let share = self.declared_resident_bits() as f64 / self.devices.max(1) as f64;
                Some((share * f).round() as u64)
            }
        }
    }

    /// Exact per-tenant request counts: largest-remainder apportionment
    /// of `requests` over tenant weights (deterministic; ties broken by
    /// tenant order).
    pub fn tenant_requests(&self) -> Vec<usize> {
        apportion(
            &self.tenants.iter().map(|t| t.weight).collect::<Vec<_>>(),
            self.requests,
        )
    }

    /// The scenario's declared offered load in wave units: each tenant's
    /// apportioned request count × its per-request wave units. The
    /// executor's measured `offered_wave_units` must equal this exactly
    /// (the prop_invariants determinism property).
    pub fn declared_wave_units(&self) -> u64 {
        let cols = self.geometry.cols;
        self.tenant_requests()
            .iter()
            .zip(self.tenants.iter())
            .map(|(&n, t)| n as u64 * t.bits.div_ceil(cols) as u64)
            .sum()
    }

    /// Build the fleet configuration this case runs under.
    pub fn cluster_config(&self) -> ClusterConfig {
        let service = ServiceConfig {
            geometry: self.geometry.clone(),
            workers: self.workers,
            ..ServiceConfig::default()
        };
        let capacity = match self.capacity_bits() {
            None => DeviceCapacity::unbounded(),
            Some(bits) => DeviceCapacity::of_bits(bits),
        };
        let policy = match self.eviction {
            EvictionMode::FailFast => crate::cluster::EvictionPolicy::FailFast,
            EvictionMode::Lru => crate::cluster::EvictionPolicy::Lru,
            EvictionMode::CostAware => crate::cluster::EvictionPolicy::CostAware {
                rent_ns_per_tick: 2.0,
            },
        };
        let mut cfg = ClusterConfig::uniform(self.devices, service);
        cfg.steal = self.steal;
        cfg.admission.max_inflight_per_device = self.queue_cap;
        cfg.capacity = CapacityConfig { capacity, policy };
        cfg.coalesce = self.coalesce.config(self.max_hold);
        cfg.movement = self.movement;
        cfg
    }

    /// The replication policy the executor's rebalance sweeps plan with.
    pub fn replication_policy(&self) -> ReplicationPolicy {
        ReplicationPolicy::new(ReplicationConfig {
            hot_uses: self.replication.hot_uses,
            amortize_factor: self.replication.amortize_factor,
            ..ReplicationConfig::default()
        })
    }
}

impl ScenarioSpec {
    /// Parse + validate scenario source (TOML, or JSON when the document
    /// starts with `{`).
    pub fn parse_str(src: &str) -> Result<ScenarioSpec, ScenarioError> {
        let doc = super::toml::parse_source(src).map_err(|msg| ScenarioError {
            path: String::new(),
            line: None,
            msg,
        })?;
        Self::from_doc(&doc)
    }

    /// Validate a parsed document.
    pub fn from_doc(doc: &ScenarioDoc) -> Result<ScenarioSpec, ScenarioError> {
        Validator { doc }.scenario()
    }

    /// Look up a tenant mix by name (`None` = the default mix).
    pub fn mix(&self, name: Option<&str>) -> &[TenantSpec] {
        match name {
            None => &self.tenants,
            Some(n) => self
                .mixes
                .iter()
                .find(|m| m.name == n)
                .map(|m| m.tenants.as_slice())
                .expect("validated mix reference"),
        }
    }

    /// Apply one case's overrides to the base scenario.
    pub fn resolve(&self, case: &CaseSpec) -> ResolvedCase {
        let slos: Vec<SloConfig> = self
            .slos
            .iter()
            .filter(|s| s.case == case.name)
            .map(|s| s.config.clone())
            .collect();
        // an SLO binding implies recording even without a [telemetry]
        // block — the defaults apply
        let telemetry = match (self.telemetry, slos.is_empty()) {
            (Some(t), _) => Some(t),
            (None, false) => Some(TelemetrySpec::default()),
            (None, true) => None,
        };
        ResolvedCase {
            name: case.name.clone(),
            seed: case.seed.unwrap_or(self.seed),
            devices: case.devices.unwrap_or(self.fleet.devices),
            workers: case.workers.unwrap_or(self.fleet.workers),
            steal: case.steal.unwrap_or(self.fleet.steal),
            queue_cap: case.queue_cap.unwrap_or(self.fleet.queue_cap),
            geometry: self.fleet.geometry.clone(),
            coalesce: case.coalesce.unwrap_or(self.runtime.coalesce),
            max_hold: case.max_hold.unwrap_or(self.runtime.max_hold),
            capacity: case.capacity.unwrap_or(self.runtime.capacity),
            eviction: case.eviction.unwrap_or(self.runtime.eviction),
            rebalance_every: case.rebalance_every.unwrap_or(self.runtime.rebalance_every),
            replication: self.runtime.replication.clone(),
            movement: case.movement.unwrap_or(self.runtime.movement),
            requests: case.requests.unwrap_or(self.arrival.requests),
            window: case.window.unwrap_or(self.arrival.window),
            process: self.arrival.process.clone(),
            phases: self.arrival.phases.clone(),
            tenants: self.mix(case.mix.as_deref()).to_vec(),
            telemetry,
            slos,
        }
    }

    /// Every case, resolved in declaration order (the implicit `default`
    /// case when the file declares none).
    pub fn resolved_cases(&self) -> Vec<ResolvedCase> {
        if self.cases.is_empty() {
            vec![self.resolve(&CaseSpec {
                name: "default".to_string(),
                ..CaseSpec::default()
            })]
        } else {
            self.cases.iter().map(|c| self.resolve(c)).collect()
        }
    }

    /// Declared case names (`default` for case-less scenarios).
    pub fn case_names(&self) -> Vec<String> {
        if self.cases.is_empty() {
            vec!["default".to_string()]
        } else {
            self.cases.iter().map(|c| c.name.clone()).collect()
        }
    }
}

/// Largest-remainder apportionment of `total` over `weights` — exact,
/// deterministic (remainder ties broken by index order).
pub fn apportion(weights: &[f64], total: usize) -> Vec<usize> {
    let sum: f64 = weights.iter().sum();
    if weights.is_empty() || sum <= 0.0 {
        return vec![0; weights.len()];
    }
    let mut counts: Vec<usize> = Vec::with_capacity(weights.len());
    let mut remainders: Vec<(usize, f64)> = Vec::with_capacity(weights.len());
    let mut assigned = 0usize;
    for (i, w) in weights.iter().enumerate() {
        let exact = w / sum * total as f64;
        let floor = exact.floor() as usize;
        counts.push(floor);
        assigned += floor;
        remainders.push((i, exact - floor as f64));
    }
    // stable sort: biggest remainder first, ties by index (stable sort
    // preserves the original order among equals)
    remainders.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap_or(std::cmp::Ordering::Equal));
    for (i, _) in remainders.into_iter().take(total - assigned) {
        counts[i] += 1;
    }
    counts
}

// ---------------------------------------------------------------------------
// validation
// ---------------------------------------------------------------------------

struct Validator<'a> {
    doc: &'a ScenarioDoc,
}

impl<'a> Validator<'a> {
    fn err<T>(&self, path: &str, msg: impl Into<String>) -> Result<T, ScenarioError> {
        Err(ScenarioError {
            path: path.to_string(),
            line: self.doc.nearest_line(path),
            msg: msg.into(),
        })
    }

    /// Reject keys the schema does not know (typo protection).
    fn check_keys(&self, node: &Json, path: &str, allowed: &[&str]) -> Result<(), ScenarioError> {
        if let Json::Obj(fields) = node {
            for (k, _) in fields {
                if !allowed.contains(&k.as_str()) {
                    let kp = join(path, k);
                    return self.err(&kp, format!("unknown key `{k}`"));
                }
            }
        }
        Ok(())
    }

    fn str_field(
        &self,
        node: &Json,
        path: &str,
        key: &str,
        default: Option<&str>,
    ) -> Result<String, ScenarioError> {
        match node.get(key) {
            None => match default {
                Some(d) => Ok(d.to_string()),
                None => self.err(&join(path, key), "required string is missing"),
            },
            Some(Json::Str(s)) => Ok(s.clone()),
            Some(_) => self.err(&join(path, key), "expected a string"),
        }
    }

    fn f64_field(
        &self,
        node: &Json,
        path: &str,
        key: &str,
        default: Option<f64>,
    ) -> Result<f64, ScenarioError> {
        match node.get(key) {
            None => match default {
                Some(d) => Ok(d),
                None => self.err(&join(path, key), "required number is missing"),
            },
            Some(v) => v
                .as_f64()
                .ok_or(())
                .or_else(|_| self.err(&join(path, key), "expected a number")),
        }
    }

    fn u64_field(
        &self,
        node: &Json,
        path: &str,
        key: &str,
        default: Option<u64>,
    ) -> Result<u64, ScenarioError> {
        match node.get(key) {
            None => match default {
                Some(d) => Ok(d),
                None => self.err(&join(path, key), "required integer is missing"),
            },
            Some(Json::U64(u)) => Ok(*u),
            Some(_) => self.err(&join(path, key), "expected a non-negative integer"),
        }
    }

    fn usize_field(
        &self,
        node: &Json,
        path: &str,
        key: &str,
        default: Option<usize>,
    ) -> Result<usize, ScenarioError> {
        self.u64_field(node, path, key, default.map(|d| d as u64))
            .map(|u| u as usize)
    }

    fn bool_field(
        &self,
        node: &Json,
        path: &str,
        key: &str,
        default: bool,
    ) -> Result<bool, ScenarioError> {
        match node.get(key) {
            None => Ok(default),
            Some(Json::Bool(b)) => Ok(*b),
            Some(_) => self.err(&join(path, key), "expected true or false"),
        }
    }

    fn positive(&self, v: f64, path: &str) -> Result<f64, ScenarioError> {
        if v > 0.0 && v.is_finite() {
            Ok(v)
        } else {
            self.err(path, "must be a positive number")
        }
    }

    fn scenario(&self) -> Result<ScenarioSpec, ScenarioError> {
        let root = &self.doc.root;
        self.check_keys(
            root,
            "",
            &[
                "schema",
                "name",
                "description",
                "seed",
                "fleet",
                "arrival",
                "runtime",
                "tenants",
                "mixes",
                "cases",
                "gates",
                "telemetry",
                "slo",
            ],
        )?;
        let schema = self.u64_field(root, "", "schema", Some(1))?;
        if schema != 1 {
            return self.err("schema", format!("unsupported scenario schema {schema}"));
        }
        let name = self.str_field(root, "", "name", None)?;
        if name.is_empty() || !name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_') {
            return self.err("name", "must be a non-empty [A-Za-z0-9_] identifier");
        }
        let description = self.str_field(root, "", "description", Some(""))?;
        let seed = self.u64_field(root, "", "seed", Some(0))?;

        let fleet = self.fleet(root.get("fleet"))?;
        let arrival = self.arrival(root.get("arrival"))?;
        let runtime = self.runtime(root.get("runtime"))?;
        let tenants = self.tenants(root.get("tenants"), "tenants")?;
        if tenants.is_empty() {
            return self.err("tenants", "at least one [[tenants]] entry is required");
        }
        let mixes = self.mixes(root.get("mixes"))?;
        let cases = self.cases(root.get("cases"), &mixes)?;
        let case_names: Vec<String> = if cases.is_empty() {
            vec!["default".to_string()]
        } else {
            cases.iter().map(|c| c.name.clone()).collect()
        };
        let gates = self.gates(root.get("gates"), &case_names)?;
        let telemetry = self.telemetry(root.get("telemetry"))?;
        let slos = self.slos(root.get("slo"), &case_names, &tenants, &mixes, &cases)?;
        Ok(ScenarioSpec {
            name,
            description,
            seed,
            fleet,
            arrival,
            runtime,
            tenants,
            mixes,
            cases,
            gates,
            telemetry,
            slos,
        })
    }

    fn telemetry(&self, node: Option<&Json>) -> Result<Option<TelemetrySpec>, ScenarioError> {
        let node = match node {
            None => return Ok(None),
            Some(n) => n,
        };
        let p = "telemetry";
        self.check_keys(node, p, &["interval_ns", "capacity"])?;
        let interval_ns =
            self.u64_field(node, p, "interval_ns", Some(timeseries::DEFAULT_INTERVAL_NS))?;
        if interval_ns == 0 {
            return self.err(&join(p, "interval_ns"), "must be >= 1");
        }
        let capacity = self.usize_field(node, p, "capacity", Some(timeseries::DEFAULT_CAPACITY))?;
        if capacity == 0 {
            return self.err(&join(p, "capacity"), "must be >= 1");
        }
        Ok(Some(TelemetrySpec {
            interval_ns,
            capacity,
        }))
    }

    fn slos(
        &self,
        node: Option<&Json>,
        case_names: &[String],
        tenants: &[TenantSpec],
        mixes: &[MixSpec],
        cases: &[CaseSpec],
    ) -> Result<Vec<SloSpec>, ScenarioError> {
        let items = match node {
            None => return Ok(Vec::new()),
            Some(v) => match v.as_arr() {
                Some(items) => items,
                None => return self.err("slo", "expected an array of [[slo]]"),
            },
        };
        // the tenant mix a case's series records lanes for (case overrides
        // pick a [[mixes]] entry; the implicit `default` case keeps the
        // base mix)
        let mix_of = |case_name: &str| -> &[TenantSpec] {
            cases
                .iter()
                .find(|c| c.name == case_name)
                .and_then(|c| c.mix.as_deref())
                .and_then(|m| mixes.iter().find(|x| x.name == m))
                .map(|m| m.tenants.as_slice())
                .unwrap_or(tenants)
        };
        let mut out: Vec<SloSpec> = Vec::new();
        for (i, s) in items.iter().enumerate() {
            let sp = format!("slo[{i}]");
            self.check_keys(
                s,
                &sp,
                &[
                    "name",
                    "case",
                    "metric",
                    "tenant",
                    "percentile",
                    "budget_ns",
                    "min_per_sec",
                    "window",
                    "max_burn",
                ],
            )?;
            let name = self.str_field(s, &sp, "name", None)?;
            if name.is_empty() || !name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_') {
                return self.err(&join(&sp, "name"), "must be a [A-Za-z0-9_] identifier");
            }
            if out.iter().any(|e| e.config.name == name) {
                return self.err(&join(&sp, "name"), format!("duplicate slo `{name}`"));
            }
            let case = self.str_field(s, &sp, "case", Some("default"))?;
            if !case_names.iter().any(|c| c == &case) {
                return self.err(&join(&sp, "case"), format!("unknown case `{case}`"));
            }
            let percentile = self.f64_field(s, &sp, "percentile", Some(99.0))?;
            if !(percentile > 0.0 && percentile < 100.0) {
                return self.err(
                    &join(&sp, "percentile"),
                    "must be strictly between 0 and 100",
                );
            }
            let window = self.usize_field(s, &sp, "window", Some(4))?;
            if window == 0 {
                return self.err(&join(&sp, "window"), "must be >= 1");
            }
            let max_burn = self.f64_field(s, &sp, "max_burn", Some(1.0))?;
            if !(max_burn >= 0.0 && max_burn.is_finite()) {
                return self.err(&join(&sp, "max_burn"), "must be a non-negative number");
            }
            let metric = self.str_field(s, &sp, "metric", Some("sojourn"))?;
            let kind = match metric.as_str() {
                "sojourn" => {
                    let budget_ns = self.u64_field(s, &sp, "budget_ns", None)?;
                    if budget_ns == 0 {
                        return self.err(&join(&sp, "budget_ns"), "must be >= 1");
                    }
                    let lane = match s.get("tenant") {
                        None => None,
                        Some(Json::Str(t)) => {
                            if !mix_of(&case).iter().any(|x| &x.name == t) {
                                return self.err(
                                    &join(&sp, "tenant"),
                                    format!("unknown tenant `{t}` in case `{case}`'s mix"),
                                );
                            }
                            Some(t.clone())
                        }
                        Some(_) => {
                            return self.err(&join(&sp, "tenant"), "expected a tenant name")
                        }
                    };
                    if s.get("min_per_sec").is_some() {
                        return self.err(
                            &join(&sp, "min_per_sec"),
                            "only valid for metric = \"admission_rate\"",
                        );
                    }
                    SloKind::Sojourn { budget_ns, lane }
                }
                "admission_rate" => {
                    let min_per_sec = self.f64_field(s, &sp, "min_per_sec", None)?;
                    self.positive(min_per_sec, &join(&sp, "min_per_sec"))?;
                    if s.get("budget_ns").is_some() || s.get("tenant").is_some() {
                        return self.err(
                            &sp,
                            "budget_ns/tenant are only valid for metric = \"sojourn\"",
                        );
                    }
                    SloKind::AdmissionRate { min_per_sec }
                }
                other => {
                    return self.err(
                        &join(&sp, "metric"),
                        format!("unknown slo metric `{other}` (sojourn|admission_rate)"),
                    )
                }
            };
            out.push(SloSpec {
                case,
                config: SloConfig {
                    name,
                    kind,
                    objective_pct: percentile,
                    window,
                    max_burn,
                },
            });
        }
        Ok(out)
    }

    fn fleet(&self, node: Option<&Json>) -> Result<FleetSpec, ScenarioError> {
        let empty = Json::obj();
        let node = node.unwrap_or(&empty);
        self.check_keys(
            node,
            "fleet",
            &["devices", "workers", "steal", "queue_cap", "geometry"],
        )?;
        let devices = self.usize_field(node, "fleet", "devices", Some(1))?;
        if devices == 0 {
            return self.err("fleet.devices", "must be >= 1");
        }
        let workers = self.usize_field(node, "fleet", "workers", Some(2))?;
        if workers == 0 {
            return self.err("fleet.workers", "must be >= 1");
        }
        let steal = self.bool_field(node, "fleet", "steal", false)?;
        let queue_cap = self.usize_field(node, "fleet", "queue_cap", Some(64))?;
        if queue_cap == 0 {
            return self.err("fleet.queue_cap", "must be >= 1");
        }
        let geometry = self.geometry(node.get("geometry"))?;
        Ok(FleetSpec {
            devices,
            workers,
            steal,
            queue_cap,
            geometry,
        })
    }

    fn geometry(&self, node: Option<&Json>) -> Result<DramGeometry, ScenarioError> {
        let empty = Json::obj();
        let node = node.unwrap_or(&empty);
        let p = "fleet.geometry";
        self.check_keys(
            node,
            p,
            &["banks", "subarrays_per_bank", "cols", "active_subarrays"],
        )?;
        let g = DramGeometry {
            banks: self.usize_field(node, p, "banks", Some(4))?,
            subarrays_per_bank: self.usize_field(node, p, "subarrays_per_bank", Some(8))?,
            cols: self.usize_field(node, p, "cols", Some(1024))?,
            active_subarrays: self.usize_field(node, p, "active_subarrays", Some(4))?,
        };
        if g.banks == 0 || g.subarrays_per_bank == 0 || g.cols == 0 || g.active_subarrays == 0 {
            return self.err(p, "geometry dimensions must all be >= 1");
        }
        if g.active_subarrays > g.subarrays_per_bank {
            return self.err(
                &join(p, "active_subarrays"),
                "cannot exceed subarrays_per_bank",
            );
        }
        Ok(g)
    }

    fn arrival(&self, node: Option<&Json>) -> Result<ArrivalSpec, ScenarioError> {
        let empty = Json::obj();
        let node = node.unwrap_or(&empty);
        let p = "arrival";
        self.check_keys(
            node,
            p,
            &[
                "requests",
                "process",
                "rate",
                "burst_size",
                "burst_gap_ns",
                "window",
                "phases",
            ],
        )?;
        let requests = self.usize_field(node, p, "requests", Some(32))?;
        if requests == 0 {
            return self.err("arrival.requests", "must be >= 1");
        }
        let window = self.usize_field(node, p, "window", Some(0))?;
        let process = match self.str_field(node, p, "process", Some("sequential"))?.as_str() {
            "sequential" => {
                for k in ["rate", "burst_size", "burst_gap_ns", "phases"] {
                    if node.get(k).is_some() {
                        return self.err(
                            &join(p, k),
                            "only meaningful for poisson/burst arrival processes",
                        );
                    }
                }
                ArrivalProcess::Sequential
            }
            "poisson" => {
                let rate = self.f64_field(node, p, "rate", None)?;
                self.positive(rate, "arrival.rate")?;
                ArrivalProcess::Poisson { rate_per_sec: rate }
            }
            "burst" => {
                let size = self.usize_field(node, p, "burst_size", Some(8))?;
                if size == 0 {
                    return self.err("arrival.burst_size", "must be >= 1");
                }
                let gap_ns = self.u64_field(node, p, "burst_gap_ns", Some(0))?;
                ArrivalProcess::Burst { size, gap_ns }
            }
            other => {
                return self.err(
                    "arrival.process",
                    format!("unknown arrival process `{other}` (sequential|poisson|burst)"),
                )
            }
        };
        let mut phases = Vec::new();
        if let Some(arr) = node.get("phases") {
            let items = match arr.as_arr() {
                Some(items) => items,
                None => return self.err("arrival.phases", "expected an array of [[phases]]"),
            };
            // an explicitly empty `phases = []` is a spec mistake, not
            // "no phases": the author wrote the key expecting diurnal
            // scaling, so silently behaving like an unscaled stream would
            // hide the error
            if items.is_empty() {
                return self.err(
                    "arrival.phases",
                    "must contain at least one [[arrival.phases]] entry (omit the key for an unscaled stream)",
                );
            }
            for (i, ph) in items.iter().enumerate() {
                let pp = format!("arrival.phases[{i}]");
                self.check_keys(ph, &pp, &["frac", "rate_scale"])?;
                let frac = self.f64_field(ph, &pp, "frac", None)?;
                self.positive(frac, &join(&pp, "frac"))?;
                let rate_scale = self.f64_field(ph, &pp, "rate_scale", Some(1.0))?;
                self.positive(rate_scale, &join(&pp, "rate_scale"))?;
                phases.push(PhaseSpec { frac, rate_scale });
            }
        }
        Ok(ArrivalSpec {
            requests,
            process,
            window,
            phases,
        })
    }

    fn coalesce_mode(&self, s: &str, path: &str) -> Result<CoalesceMode, ScenarioError> {
        match s {
            "off" => Ok(CoalesceMode::Off),
            "strict" => Ok(CoalesceMode::Strict),
            "opportunistic" => Ok(CoalesceMode::Opportunistic),
            other => self.err(
                path,
                format!("unknown coalesce mode `{other}` (off|strict|opportunistic)"),
            ),
        }
    }

    fn movement_mode(&self, s: &str, path: &str) -> Result<MovementConfig, ScenarioError> {
        match s {
            "off" => Ok(MovementConfig::Off),
            "external" => Ok(MovementConfig::External),
            "in_dram" => Ok(MovementConfig::InDram),
            "prefetch" => Ok(MovementConfig::Prefetch),
            other => self.err(
                path,
                format!("unknown movement mode `{other}` (off|external|in_dram|prefetch)"),
            ),
        }
    }

    fn eviction_mode(&self, s: &str, path: &str) -> Result<EvictionMode, ScenarioError> {
        match s {
            "fail_fast" => Ok(EvictionMode::FailFast),
            "lru" => Ok(EvictionMode::Lru),
            "cost_aware" => Ok(EvictionMode::CostAware),
            other => self.err(
                path,
                format!("unknown eviction policy `{other}` (fail_fast|lru|cost_aware)"),
            ),
        }
    }

    /// `capacity = "unbounded"` | `capacity_bits = N` | `capacity_share = F`
    fn capacity_of(&self, node: &Json, path: &str) -> Result<Option<CapacitySpec>, ScenarioError> {
        let named = node.get("capacity").is_some();
        let bits = node.get("capacity_bits").is_some();
        let share = node.get("capacity_share").is_some();
        if (named as u8 + bits as u8 + share as u8) > 1 {
            return self.err(
                path,
                "capacity, capacity_bits, and capacity_share are mutually exclusive",
            );
        }
        if named {
            let s = self.str_field(node, path, "capacity", None)?;
            if s != "unbounded" {
                return self.err(
                    &join(path, "capacity"),
                    "only \"unbounded\" is accepted (use capacity_bits / capacity_share)",
                );
            }
            return Ok(Some(CapacitySpec::Unbounded));
        }
        if bits {
            let b = self.u64_field(node, path, "capacity_bits", None)?;
            if b == 0 {
                return self.err(&join(path, "capacity_bits"), "must be >= 1");
            }
            return Ok(Some(CapacitySpec::Bits(b)));
        }
        if share {
            let f = self.f64_field(node, path, "capacity_share", None)?;
            self.positive(f, &join(path, "capacity_share"))?;
            return Ok(Some(CapacitySpec::Share(f)));
        }
        Ok(None)
    }

    fn runtime(&self, node: Option<&Json>) -> Result<RuntimeSpec, ScenarioError> {
        let empty = Json::obj();
        let node = node.unwrap_or(&empty);
        let p = "runtime";
        self.check_keys(
            node,
            p,
            &[
                "coalesce",
                "max_hold",
                "capacity",
                "capacity_bits",
                "capacity_share",
                "eviction",
                "rebalance_every",
                "replication",
                "movement",
            ],
        )?;
        let coalesce = self.coalesce_mode(
            &self.str_field(node, p, "coalesce", Some("off"))?,
            "runtime.coalesce",
        )?;
        let max_hold = self.u64_field(node, p, "max_hold", Some(0))?;
        let capacity = self.capacity_of(node, p)?.unwrap_or(CapacitySpec::Unbounded);
        let eviction = self.eviction_mode(
            &self.str_field(node, p, "eviction", Some("fail_fast"))?,
            "runtime.eviction",
        )?;
        let rebalance_every = self.usize_field(node, p, "rebalance_every", Some(0))?;
        let movement = self.movement_mode(
            &self.str_field(node, p, "movement", Some("off"))?,
            "runtime.movement",
        )?;
        let rp = "runtime.replication";
        let empty_rep = Json::obj();
        let rep = node.get("replication").unwrap_or(&empty_rep);
        self.check_keys(rep, rp, &["hot_uses", "amortize_factor"])?;
        let replication = ReplicationSpec {
            hot_uses: self.u64_field(rep, rp, "hot_uses", Some(3))?,
            amortize_factor: self.f64_field(rep, rp, "amortize_factor", Some(1.0))?,
        };
        Ok(RuntimeSpec {
            coalesce,
            max_hold,
            capacity,
            eviction,
            rebalance_every,
            replication,
            movement,
        })
    }

    fn tenants(&self, node: Option<&Json>, path: &str) -> Result<Vec<TenantSpec>, ScenarioError> {
        let items = match node {
            None => return Ok(Vec::new()),
            Some(v) => match v.as_arr() {
                Some(items) => items,
                None => return self.err(path, "expected an array of [[tenants]]"),
            },
        };
        let mut out = Vec::new();
        for (i, t) in items.iter().enumerate() {
            let tp = format!("{path}[{i}]");
            self.check_keys(
                t,
                &tp,
                &[
                    "name",
                    "weight",
                    "op",
                    "bits",
                    "placement",
                    "regions",
                    "zipf_theta",
                    "miss_every",
                    "max_inflight",
                ],
            )?;
            let name = self.str_field(t, &tp, "name", None)?;
            if name.is_empty() {
                return self.err(&join(&tp, "name"), "must be non-empty");
            }
            if out.iter().any(|e: &TenantSpec| e.name == name) {
                return self.err(&join(&tp, "name"), format!("duplicate tenant `{name}`"));
            }
            let weight = self.f64_field(t, &tp, "weight", Some(1.0))?;
            self.positive(weight, &join(&tp, "weight"))?;
            let op_name = self.str_field(t, &tp, "op", Some("xnor2"))?;
            let op = match BulkOp::parse(&op_name) {
                Some(op) if !matches!(op, BulkOp::Add | BulkOp::Sub) => op,
                Some(_) => {
                    return self.err(
                        &join(&tp, "op"),
                        format!("`{op_name}` is not a bulk bit-wise op"),
                    )
                }
                None => return self.err(&join(&tp, "op"), format!("unknown op `{op_name}`")),
            };
            let bits = self.usize_field(t, &tp, "bits", None)?;
            if bits == 0 {
                return self.err(&join(&tp, "bits"), "must be >= 1");
            }
            let placement = match self.str_field(t, &tp, "placement", Some("carried"))?.as_str() {
                "carried" => PlacementMode::Carried,
                "resident" => PlacementMode::Resident,
                other => {
                    return self.err(
                        &join(&tp, "placement"),
                        format!("unknown placement `{other}` (carried|resident)"),
                    )
                }
            };
            let regions = self.usize_field(t, &tp, "regions", Some(0))?;
            if placement == PlacementMode::Resident && regions == 0 {
                return self.err(&join(&tp, "regions"), "resident tenants need regions >= 1");
            }
            let zipf_theta = self.f64_field(t, &tp, "zipf_theta", Some(0.0))?;
            if zipf_theta < 0.0 {
                return self.err(&join(&tp, "zipf_theta"), "must be >= 0");
            }
            let miss_every = self.usize_field(t, &tp, "miss_every", Some(0))?;
            if miss_every > 0 && placement != PlacementMode::Resident {
                return self.err(
                    &join(&tp, "miss_every"),
                    "forced misses only apply to resident tenants",
                );
            }
            let max_inflight = self.usize_field(t, &tp, "max_inflight", Some(0))?;
            out.push(TenantSpec {
                name,
                weight,
                op,
                bits,
                placement,
                regions,
                zipf_theta,
                miss_every,
                max_inflight,
            });
        }
        Ok(out)
    }

    fn mixes(&self, node: Option<&Json>) -> Result<Vec<MixSpec>, ScenarioError> {
        let items = match node {
            None => return Ok(Vec::new()),
            Some(v) => match v.as_arr() {
                Some(items) => items,
                None => return self.err("mixes", "expected an array of [[mixes]]"),
            },
        };
        let mut out: Vec<MixSpec> = Vec::new();
        for (i, m) in items.iter().enumerate() {
            let mp = format!("mixes[{i}]");
            self.check_keys(m, &mp, &["name", "tenants"])?;
            let name = self.str_field(m, &mp, "name", None)?;
            if out.iter().any(|e| e.name == name) {
                return self.err(&join(&mp, "name"), format!("duplicate mix `{name}`"));
            }
            let tenants = self.tenants(m.get("tenants"), &join(&mp, "tenants"))?;
            if tenants.is_empty() {
                return self.err(
                    &join(&mp, "tenants"),
                    "a mix needs at least one [[mixes.tenants]] entry",
                );
            }
            out.push(MixSpec { name, tenants });
        }
        Ok(out)
    }

    fn cases(
        &self,
        node: Option<&Json>,
        mixes: &[MixSpec],
    ) -> Result<Vec<CaseSpec>, ScenarioError> {
        let items = match node {
            None => return Ok(Vec::new()),
            Some(v) => match v.as_arr() {
                Some(items) => items,
                None => return self.err("cases", "expected an array of [[cases]]"),
            },
        };
        let mut out: Vec<CaseSpec> = Vec::new();
        for (i, c) in items.iter().enumerate() {
            let cp = format!("cases[{i}]");
            self.check_keys(
                c,
                &cp,
                &[
                    "name",
                    "mix",
                    "devices",
                    "workers",
                    "steal",
                    "queue_cap",
                    "coalesce",
                    "max_hold",
                    "capacity",
                    "capacity_bits",
                    "capacity_share",
                    "eviction",
                    "rebalance_every",
                    "movement",
                    "requests",
                    "window",
                    "seed",
                ],
            )?;
            let name = self.str_field(c, &cp, "name", None)?;
            if name.is_empty() || !name.chars().all(|c| c.is_ascii_alphanumeric() || c == '_') {
                return self.err(&join(&cp, "name"), "must be a [A-Za-z0-9_] identifier");
            }
            if out.iter().any(|e| e.name == name) {
                return self.err(&join(&cp, "name"), format!("duplicate case `{name}`"));
            }
            let mix = match c.get("mix") {
                None => None,
                Some(Json::Str(m)) => {
                    if !mixes.iter().any(|x| &x.name == m) {
                        return self.err(
                            &join(&cp, "mix"),
                            format!("unknown tenant mix `{m}` (no such [[mixes]] entry)"),
                        );
                    }
                    Some(m.clone())
                }
                Some(_) => return self.err(&join(&cp, "mix"), "expected a mix name"),
            };
            let opt_usize = |key: &str| -> Result<Option<usize>, ScenarioError> {
                match c.get(key) {
                    None => Ok(None),
                    Some(_) => self.usize_field(c, &cp, key, None).map(Some),
                }
            };
            let devices = opt_usize("devices")?;
            if devices == Some(0) {
                return self.err(&join(&cp, "devices"), "must be >= 1");
            }
            let requests = opt_usize("requests")?;
            if requests == Some(0) {
                return self.err(&join(&cp, "requests"), "must be >= 1");
            }
            let coalesce = match c.get("coalesce") {
                None => None,
                Some(Json::Str(s)) => Some(self.coalesce_mode(s, &join(&cp, "coalesce"))?),
                Some(_) => return self.err(&join(&cp, "coalesce"), "expected a coalesce mode"),
            };
            let eviction = match c.get("eviction") {
                None => None,
                Some(Json::Str(s)) => Some(self.eviction_mode(s, &join(&cp, "eviction"))?),
                Some(_) => return self.err(&join(&cp, "eviction"), "expected an eviction policy"),
            };
            let movement = match c.get("movement") {
                None => None,
                Some(Json::Str(s)) => Some(self.movement_mode(s, &join(&cp, "movement"))?),
                Some(_) => return self.err(&join(&cp, "movement"), "expected a movement mode"),
            };
            let steal = match c.get("steal") {
                None => None,
                Some(Json::Bool(b)) => Some(*b),
                Some(_) => return self.err(&join(&cp, "steal"), "expected true or false"),
            };
            let seed = match c.get("seed") {
                None => None,
                Some(_) => Some(self.u64_field(c, &cp, "seed", None)?),
            };
            let max_hold = match c.get("max_hold") {
                None => None,
                Some(_) => Some(self.u64_field(c, &cp, "max_hold", None)?),
            };
            out.push(CaseSpec {
                name,
                mix,
                devices,
                workers: opt_usize("workers")?,
                steal,
                queue_cap: opt_usize("queue_cap")?,
                coalesce,
                max_hold,
                capacity: self.capacity_of(c, &cp)?,
                eviction,
                rebalance_every: opt_usize("rebalance_every")?,
                movement,
                requests,
                window: opt_usize("window")?,
                seed,
            });
        }
        Ok(out)
    }

    fn gates(
        &self,
        node: Option<&Json>,
        case_names: &[String],
    ) -> Result<Vec<GateSpec>, ScenarioError> {
        let items = match node {
            None => return Ok(Vec::new()),
            Some(v) => match v.as_arr() {
                Some(items) => items,
                None => return self.err("gates", "expected an array of [[gates]]"),
            },
        };
        let check_ref = |vref: &str, path: &str| -> Result<(), ScenarioError> {
            let case = match vref.split_once('.') {
                Some((case, metric)) if !metric.is_empty() => case,
                _ => {
                    return self.err(
                        path,
                        format!("bad metric reference `{vref}` (want `case.metric`)"),
                    )
                }
            };
            if !case_names.iter().any(|c| c == case) {
                return self.err(path, format!("unknown case `{case}` in metric reference"));
            }
            Ok(())
        };
        let mut out: Vec<GateSpec> = Vec::new();
        for (i, g) in items.iter().enumerate() {
            let gp = format!("gates[{i}]");
            self.check_keys(g, &gp, &["name", "left", "op", "right", "scale", "tol"])?;
            let name = self.str_field(g, &gp, "name", None)?;
            if out.iter().any(|e| e.name == name) {
                return self.err(&join(&gp, "name"), format!("duplicate gate `{name}`"));
            }
            let left = self.str_field(g, &gp, "left", None)?;
            check_ref(&left, &join(&gp, "left"))?;
            let op = match self.str_field(g, &gp, "op", None)?.as_str() {
                "lt" => GateOp::Lt,
                "le" => GateOp::Le,
                "gt" => GateOp::Gt,
                "ge" => GateOp::Ge,
                "eq" => GateOp::Eq,
                "ne" => GateOp::Ne,
                other => {
                    return self.err(
                        &join(&gp, "op"),
                        format!("unknown gate op `{other}` (lt|le|gt|ge|eq|ne)"),
                    )
                }
            };
            let right = match g.get("right") {
                None => return self.err(&join(&gp, "right"), "required operand is missing"),
                Some(Json::Str(s)) => {
                    check_ref(s, &join(&gp, "right"))?;
                    GateOperand::Metric(s.clone())
                }
                Some(v) => match v.as_f64() {
                    Some(x) => GateOperand::Value(x),
                    None => {
                        return self.err(
                            &join(&gp, "right"),
                            "expected a metric reference or a number",
                        )
                    }
                },
            };
            let scale = self.f64_field(g, &gp, "scale", Some(1.0))?;
            self.positive(scale, &join(&gp, "scale"))?;
            let tol = self.f64_field(g, &gp, "tol", Some(0.0))?;
            if tol < 0.0 {
                return self.err(&join(&gp, "tol"), "must be >= 0");
            }
            out.push(GateSpec {
                name,
                left,
                op,
                right,
                scale,
                tol,
            });
        }
        Ok(out)
    }
}

fn join(path: &str, key: &str) -> String {
    if path.is_empty() {
        key.to_string()
    } else {
        format!("{path}.{key}")
    }
}
