//! Minimal TOML reader for scenario files (TOML crates are not vendored).
//!
//! Parses the subset scenario files use — `[table]` and `[[array-of-table]]`
//! headers (arbitrarily nested), `key = value` pairs with dotted keys,
//! strings, integers (decimal / `0x` hex / `_` separators), floats,
//! booleans, and single-line arrays — into the same [`Json`] tree the rest
//! of the observability layer speaks, so scenario validation, `--param`
//! overrides, and the JSON scenario form all share one document model.
//!
//! Every parse error is **line-anchored** (`line N: …`), and the returned
//! [`ScenarioDoc`] keeps a key-path → line map so post-parse *validation*
//! errors can point at the offending line too (`scenario.toml:12:
//! tenants[0].weight: must be > 0`).

use std::collections::BTreeMap;

use crate::obs::Json;

/// A parsed scenario document: the value tree plus the source line each
/// key path was defined on (empty for documents parsed from plain JSON).
#[derive(Debug, Clone)]
pub struct ScenarioDoc {
    pub root: Json,
    lines: BTreeMap<String, usize>,
}

impl ScenarioDoc {
    /// Wrap an already-built JSON tree (no line anchors).
    pub fn from_json(root: Json) -> Self {
        ScenarioDoc {
            root,
            lines: BTreeMap::new(),
        }
    }

    /// Source line (1-based) where `path` (e.g. `tenants[0].weight`) was
    /// last assigned, if the document came from TOML.
    pub fn line_of(&self, path: &str) -> Option<usize> {
        self.lines.get(path).copied()
    }

    /// Nearest known line for `path`: the path itself, else its closest
    /// recorded ancestor (so a *missing* required key still anchors to
    /// the table that should have held it).
    pub fn nearest_line(&self, path: &str) -> Option<usize> {
        let mut p = path;
        loop {
            if let Some(n) = self.lines.get(p) {
                return Some(*n);
            }
            match p.rfind(['.', '[']) {
                Some(cut) => p = &p[..cut],
                None => return None,
            }
        }
    }

    /// Set a (dotted) key path to a scalar value — the `--param key=value`
    /// override hook. Intermediate objects are created as needed; array
    /// segments use the `tenants[0]` form and must already exist.
    pub fn set_path(&mut self, path: &str, value: Json) -> Result<(), String> {
        let segs = parse_path(path)?;
        set_in(&mut self.root, &segs, path, value)?;
        self.lines.remove(path);
        Ok(())
    }
}

#[derive(Debug)]
enum Seg {
    Key(String),
    Index(usize),
}

fn parse_path(path: &str) -> Result<Vec<Seg>, String> {
    let mut segs = Vec::new();
    for part in path.split('.') {
        if part.is_empty() {
            return Err(format!("bad override path `{path}`"));
        }
        match part.split_once('[') {
            None => segs.push(Seg::Key(part.to_string())),
            Some((key, rest)) => {
                if !key.is_empty() {
                    segs.push(Seg::Key(key.to_string()));
                }
                for idx in rest.split('[') {
                    let idx = idx
                        .strip_suffix(']')
                        .ok_or_else(|| format!("bad override path `{path}`"))?;
                    let n: usize = idx
                        .parse()
                        .map_err(|_| format!("bad override path `{path}`"))?;
                    segs.push(Seg::Index(n));
                }
            }
        }
    }
    Ok(segs)
}

fn set_in(node: &mut Json, segs: &[Seg], path: &str, value: Json) -> Result<(), String> {
    match segs {
        [] => {
            *node = value;
            Ok(())
        }
        [Seg::Key(k), rest @ ..] => {
            let obj = match node {
                Json::Obj(fields) => fields,
                _ => return Err(format!("override path `{path}`: `{k}` is not a table")),
            };
            if !obj.iter().any(|(key, _)| key == k) {
                obj.push((k.clone(), Json::obj()));
            }
            let slot = obj.iter_mut().find(|(key, _)| key == k).unwrap();
            set_in(&mut slot.1, rest, path, value)
        }
        [Seg::Index(i), rest @ ..] => match node {
            Json::Arr(items) => match items.get_mut(*i) {
                Some(item) => set_in(item, rest, path, value),
                None => Err(format!("override path `{path}`: index {i} out of range")),
            },
            _ => Err(format!("override path `{path}`: not an array")),
        },
    }
}

/// Parse scenario source: TOML by default, JSON when the document starts
/// with `{` (the two forms build the same tree).
pub fn parse_source(src: &str) -> Result<ScenarioDoc, String> {
    if src.trim_start().starts_with('{') {
        Json::parse(src).map(ScenarioDoc::from_json)
    } else {
        parse_toml(src)
    }
}

/// Parse TOML into a [`ScenarioDoc`]. Errors are `line N: …` strings.
pub fn parse_toml(src: &str) -> Result<ScenarioDoc, String> {
    let mut doc = ScenarioDoc {
        root: Json::obj(),
        lines: BTreeMap::new(),
    };
    // current table: path segments + rendered path-string prefix
    let mut table: Vec<Seg> = Vec::new();
    let mut table_str = String::new();
    for (i, raw) in src.lines().enumerate() {
        let n = i + 1;
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(header) = line.strip_prefix("[[") {
            let header = header
                .strip_suffix("]]")
                .ok_or_else(|| format!("line {n}: unterminated [[table]] header"))?;
            let keys = header_keys(header, n)?;
            (table, table_str) = enter_array_of_tables(&mut doc, &keys, n)?;
        } else if let Some(header) = line.strip_prefix('[') {
            let header = header
                .strip_suffix(']')
                .ok_or_else(|| format!("line {n}: unterminated [table] header"))?;
            let keys = header_keys(header, n)?;
            (table, table_str) = enter_table(&mut doc, &keys, n)?;
        } else {
            let (key, rest) = line
                .split_once('=')
                .ok_or_else(|| format!("line {n}: expected `key = value`, got `{line}`"))?;
            let keys = header_keys(key.trim(), n)?;
            let value = parse_value(rest.trim(), n)?;
            let mut segs: Vec<Seg> = Vec::new();
            let mut path = table_str.clone();
            for k in &keys {
                push_path(&mut path, k);
                segs.push(Seg::Key(k.clone()));
            }
            let node = navigate(&mut doc.root, &table, n)?;
            assign(node, &segs, value, &path, n)?;
            doc.lines.insert(path, n);
        }
    }
    Ok(doc)
}

/// Cut a `#` comment (respecting string literals).
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn valid_key(k: &str) -> bool {
    !k.is_empty()
        && k.chars()
            .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-')
}

fn header_keys(header: &str, n: usize) -> Result<Vec<String>, String> {
    header
        .split('.')
        .map(|k| {
            let k = k.trim();
            if valid_key(k) {
                Ok(k.to_string())
            } else {
                Err(format!("line {n}: invalid key `{k}`"))
            }
        })
        .collect()
}

fn push_path(path: &mut String, key: &str) {
    if !path.is_empty() {
        path.push('.');
    }
    path.push_str(key);
}

/// Walk `segs` from the root, creating nothing (segments must exist).
fn navigate<'a>(root: &'a mut Json, segs: &[Seg], n: usize) -> Result<&'a mut Json, String> {
    let mut node = root;
    for seg in segs {
        node = match seg {
            Seg::Key(k) => match node {
                Json::Obj(fields) => {
                    &mut fields
                        .iter_mut()
                        .find(|(key, _)| key == k)
                        .ok_or_else(|| format!("line {n}: internal: lost table `{k}`"))?
                        .1
                }
                _ => return Err(format!("line {n}: `{k}` is not a table")),
            },
            Seg::Index(i) => match node {
                Json::Arr(items) => items
                    .get_mut(*i)
                    .ok_or_else(|| format!("line {n}: internal: lost table index {i}"))?,
                _ => return Err(format!("line {n}: not an array of tables")),
            },
        };
    }
    Ok(node)
}

/// `[a.b]`: create/enter nested tables. Returns the new current-table path.
fn enter_table(
    doc: &mut ScenarioDoc,
    keys: &[String],
    n: usize,
) -> Result<(Vec<Seg>, String), String> {
    let mut segs: Vec<Seg> = Vec::new();
    let mut path = String::new();
    for k in keys {
        let node = navigate(&mut doc.root, &segs, n)?;
        match node {
            Json::Obj(fields) => {
                if !fields.iter().any(|(key, _)| key == k) {
                    fields.push((k.clone(), Json::obj()));
                }
            }
            _ => return Err(format!("line {n}: `{k}` is not a table")),
        }
        push_path(&mut path, k);
        segs.push(Seg::Key(k.clone()));
        // an intermediate segment may be an array of tables: descend into
        // its most recent element
        let node = navigate(&mut doc.root, &segs, n)?;
        if let Json::Arr(items) = node {
            if items.is_empty() {
                return Err(format!("line {n}: `{k}` is an empty array of tables"));
            }
            let idx = items.len() - 1;
            path.push_str(&format!("[{idx}]"));
            segs.push(Seg::Index(idx));
        }
    }
    doc.lines.entry(path.clone()).or_insert(n);
    Ok((segs, path))
}

/// `[[a.b]]`: append a fresh table to the array at `a.b` (creating it),
/// entering parent tables/arrays like [`enter_table`] does.
fn enter_array_of_tables(
    doc: &mut ScenarioDoc,
    keys: &[String],
    n: usize,
) -> Result<(Vec<Seg>, String), String> {
    let (parent, last) = keys.split_at(keys.len() - 1);
    let (mut segs, mut path) = if parent.is_empty() {
        (Vec::new(), String::new())
    } else {
        enter_table(doc, parent, n)?
    };
    let k = &last[0];
    let node = navigate(&mut doc.root, &segs, n)?;
    let idx = match node {
        Json::Obj(fields) => {
            if !fields.iter().any(|(key, _)| key == k) {
                fields.push((k.clone(), Json::Arr(Vec::new())));
            }
            let slot = &mut fields.iter_mut().find(|(key, _)| key == k).unwrap().1;
            match slot {
                Json::Arr(items) => {
                    items.push(Json::obj());
                    items.len() - 1
                }
                _ => return Err(format!("line {n}: `{k}` is not an array of tables")),
            }
        }
        _ => return Err(format!("line {n}: `{k}` is not a table")),
    };
    push_path(&mut path, k);
    path.push_str(&format!("[{idx}]"));
    segs.push(Seg::Key(k.clone()));
    segs.push(Seg::Index(idx));
    doc.lines.insert(path.clone(), n);
    Ok((segs, path))
}

/// Assign a (possibly dotted) key inside the current table node.
fn assign(node: &mut Json, segs: &[Seg], value: Json, path: &str, n: usize) -> Result<(), String> {
    match segs {
        [Seg::Key(k)] => match node {
            Json::Obj(fields) => {
                if fields.iter().any(|(key, _)| key == k) {
                    return Err(format!("line {n}: duplicate key `{path}`"));
                }
                fields.push((k.clone(), value));
                Ok(())
            }
            _ => Err(format!("line {n}: `{k}` is not assignable")),
        },
        [Seg::Key(k), rest @ ..] => match node {
            Json::Obj(fields) => {
                if !fields.iter().any(|(key, _)| key == k) {
                    fields.push((k.clone(), Json::obj()));
                }
                let slot = &mut fields.iter_mut().find(|(key, _)| key == k).unwrap().1;
                assign(slot, rest, value, path, n)
            }
            _ => Err(format!("line {n}: `{k}` is not a table")),
        },
        _ => Err(format!("line {n}: bad key `{path}`")),
    }
}

/// Parse one TOML value (scalar or single-line array).
fn parse_value(raw: &str, n: usize) -> Result<Json, String> {
    let raw = raw.trim();
    if raw.is_empty() {
        return Err(format!("line {n}: missing value"));
    }
    if let Some(rest) = raw.strip_prefix('"') {
        let body = rest
            .strip_suffix('"')
            .ok_or_else(|| format!("line {n}: unterminated string"))?;
        if body.contains('"') {
            return Err(format!("line {n}: stray quote in string"));
        }
        return Ok(Json::Str(unescape(body)));
    }
    if raw == "true" {
        return Ok(Json::Bool(true));
    }
    if raw == "false" {
        return Ok(Json::Bool(false));
    }
    if let Some(body) = raw.strip_prefix('[') {
        let body = body
            .strip_suffix(']')
            .ok_or_else(|| format!("line {n}: unterminated array (arrays must be single-line)"))?;
        let mut items = Vec::new();
        for part in split_top_level(body) {
            let part = part.trim();
            if !part.is_empty() {
                items.push(parse_value(part, n)?);
            }
        }
        return Ok(Json::Arr(items));
    }
    if raw.starts_with('{') {
        return Err(format!(
            "line {n}: inline tables are not supported — use a [table] header"
        ));
    }
    parse_number(raw, n)
}

fn parse_number(raw: &str, n: usize) -> Result<Json, String> {
    let clean: String = raw.chars().filter(|&c| c != '_').collect();
    if let Some(hex) = clean.strip_prefix("0x").or_else(|| clean.strip_prefix("0X")) {
        return u64::from_str_radix(hex, 16)
            .map(Json::U64)
            .map_err(|_| format!("line {n}: bad hex integer `{raw}`"));
    }
    if !clean.contains(['.', 'e', 'E']) {
        if let Ok(u) = clean.parse::<u64>() {
            return Ok(Json::U64(u));
        }
    }
    clean
        .parse::<f64>()
        .map(Json::F64)
        .map_err(|_| format!("line {n}: expected a value, got `{raw}`"))
}

/// Split an array body on top-level commas (not inside nested `[...]` or
/// strings).
fn split_top_level(body: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let (mut depth, mut in_str, mut start) = (0usize, false, 0usize);
    for (i, c) in body.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                parts.push(&body[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&body[start..]);
    parts
}

fn unescape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    let mut chars = s.chars();
    while let Some(c) = chars.next() {
        if c == '\\' {
            match chars.next() {
                Some('n') => out.push('\n'),
                Some('t') => out.push('\t'),
                Some('\\') => out.push('\\'),
                Some('"') => out.push('"'),
                Some(other) => {
                    out.push('\\');
                    out.push(other);
                }
                None => out.push('\\'),
            }
        } else {
            out.push(c);
        }
    }
    out
}
