//! Bit-plane ⇄ element-vector conversion via 32×32 bit-matrix transpose
//! (Hacker's Delight §7-3). This is the hot conversion on the add32 path:
//! naive per-bit loops cost 32 operations per element; the transpose does
//! a 32-element block in ~5·32 word operations.

use crate::util::bitrow::BitRow;

/// Transpose a 32×32 bit matrix held as 32 u32 rows, in place.
/// LSB-first indexing: entry (r, c) is bit `c` of `a[r]`; after the call,
/// bit `c` of `a[r]` is the old bit `r` of `a[c]` (main-diagonal
/// transpose — the Hacker's Delight variant swaps about the
/// anti-diagonal in this indexing, hence the mirrored shift pattern).
pub fn transpose32(a: &mut [u32; 32]) {
    let mut j = 16;
    let mut m = 0x0000_FFFFu32;
    while j != 0 {
        let mut k = 0;
        while k < 32 {
            let t = ((a[k] >> j) ^ a[k + j]) & m;
            a[k] ^= t << j;
            a[k + j] ^= t;
            k = (k + j + 1) & !j;
        }
        j >>= 1;
        m ^= m << j;
    }
}

/// Pack `elems` (32-bit values) into 32 bit-planes of `cols` bit-lines
/// each: plane `b`, position `e` = bit `b` of `elems[e]`.
pub fn pack_planes(elems: &[u32], cols: usize) -> Vec<BitRow> {
    assert!(elems.len() <= cols);
    let mut planes: Vec<Vec<u32>> = vec![vec![0u32; cols.div_ceil(32)]; 32];
    let mut block = [0u32; 32];
    for (blk, chunk) in elems.chunks(32).enumerate() {
        block[..chunk.len()].copy_from_slice(chunk);
        block[chunk.len()..].fill(0);
        // element e of this block is row e; after transpose, row b holds
        // bit b of all 32 elements (element 0 in bit 0)
        transpose32(&mut block);
        for b in 0..32 {
            planes[b][blk] = block[b];
        }
    }
    planes
        .into_iter()
        .map(|lanes| BitRow::from_u32_lanes(cols, &lanes))
        .collect()
}

/// Inverse of `pack_planes`: planes (32 × cols bits) → `n` element values.
pub fn unpack_planes(planes: &[BitRow], n: usize) -> Vec<u32> {
    assert_eq!(planes.len(), 32);
    let lanes: Vec<Vec<u32>> = planes.iter().map(|p| p.to_u32_lanes()).collect();
    let mut out = vec![0u32; n];
    let mut block = [0u32; 32];
    for blk in 0..n.div_ceil(32) {
        for b in 0..32 {
            block[b] = lanes[b].get(blk).copied().unwrap_or(0);
        }
        transpose32(&mut block);
        let lo = blk * 32;
        let hi = (lo + 32).min(n);
        out[lo..hi].copy_from_slice(&block[..hi - lo]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn transpose_is_involution() {
        let mut rng = Rng::new(1);
        let mut a = [0u32; 32];
        for w in a.iter_mut() {
            *w = rng.next_u64() as u32;
        }
        let orig = a;
        transpose32(&mut a);
        transpose32(&mut a);
        assert_eq!(a, orig);
    }

    #[test]
    fn transpose_moves_bits_correctly() {
        let mut a = [0u32; 32];
        a[3] = 1 << 7; // row 3, column 7
        transpose32(&mut a);
        assert_eq!(a[7], 1 << 3); // row 7, column 3
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let mut rng = Rng::new(2);
        for n in [1usize, 31, 32, 33, 100, 256] {
            let elems: Vec<u32> = (0..n).map(|_| rng.next_u64() as u32).collect();
            let planes = pack_planes(&elems, 256);
            assert_eq!(planes.len(), 32);
            let back = unpack_planes(&planes, n);
            assert_eq!(back, elems, "n={n}");
        }
    }

    #[test]
    fn pack_matches_naive_definition() {
        let mut rng = Rng::new(3);
        let elems: Vec<u32> = (0..77).map(|_| rng.next_u64() as u32).collect();
        let planes = pack_planes(&elems, 128);
        for (e, &v) in elems.iter().enumerate() {
            for b in 0..32 {
                assert_eq!(
                    planes[b].get(e),
                    (v >> b) & 1 == 1,
                    "elem {e} bit {b}"
                );
            }
        }
    }
}
