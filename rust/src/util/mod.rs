//! Small self-contained utilities (the crates that would normally provide
//! these — `rand`, `clap`, `criterion`, `proptest` — are not vendored in
//! this offline environment, so we carry minimal, well-tested equivalents).

pub mod bench;
pub mod bitplane;
pub mod bitrow;
pub mod cli;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
