//! Minimal property-testing harness (proptest is not vendored).
//!
//! `check(name, cases, |rng| ...)` runs a closure over `cases` seeded RNGs;
//! on failure it reports the failing seed so the case can be replayed as a
//! deterministic regression (`replay(seed, f)`).

use crate::util::rng::Rng;

/// Result of one property case: Ok or a human-readable counterexample.
pub type CaseResult = Result<(), String>;

/// Run `f` for `cases` deterministic seeds. Panics with the failing seed on
/// the first counterexample.
pub fn check(name: &str, cases: u64, mut f: impl FnMut(&mut Rng) -> CaseResult) {
    for case in 0..cases {
        let seed = 0xDB1A_5EED ^ (case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!(
                "property `{name}` failed at case {case} (replay seed {seed:#x}): {msg}"
            );
        }
    }
}

/// Replay a single failing case by seed.
pub fn replay(seed: u64, mut f: impl FnMut(&mut Rng) -> CaseResult) {
    let mut rng = Rng::new(seed);
    if let Err(msg) = f(&mut rng) {
        panic!("replay seed {seed:#x}: {msg}");
    }
}

/// Run `f` once per explicitly-listed seed — a fixed seed matrix. The
/// concurrency stress suites use this instead of [`check`] so every CI
/// run exercises the same interleaving-provoking seeds, and a failure
/// still reports which seed to replay.
pub fn check_seeds(name: &str, seeds: &[u64], mut f: impl FnMut(&mut Rng) -> CaseResult) {
    for &seed in seeds {
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!("property `{name}` failed (replay seed {seed:#x}): {msg}");
        }
    }
}

/// Assert helper producing `CaseResult`s.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check("trivial", 25, |rng| {
            n += 1;
            let x = rng.below(100);
            if x < 100 {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
        assert_eq!(n, 25);
    }

    #[test]
    #[should_panic(expected = "property `fails`")]
    fn failing_property_panics_with_seed() {
        check("fails", 10, |rng| {
            let x = rng.below(10);
            if x < 9 {
                Ok(())
            } else {
                Err(format!("x={x}"))
            }
        });
    }

    #[test]
    fn seed_matrix_runs_each_seed_once() {
        let mut seen = Vec::new();
        check_seeds("matrix", &[7, 11, 13], |rng| {
            seen.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(seen.len(), 3);
        assert_eq!(seen[0], Rng::new(7).next_u64());
        assert_eq!(seen[2], Rng::new(13).next_u64());
    }

    #[test]
    #[should_panic(expected = "replay seed 0x2a")]
    fn seed_matrix_failure_names_the_seed() {
        check_seeds("names_seed", &[42], |_| Err("boom".into()));
    }
}
