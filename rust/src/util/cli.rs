//! Minimal CLI argument parser (clap is not vendored).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional args.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    /// Every `--key value` occurrence in argv order; `flags` keeps the
    /// last occurrence for `get()`, this keeps them all for `get_all()`
    /// (repeatable flags like `bench --param k=v --param k2=v2`).
    occurrences: Vec<(String, String)>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Self {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flag(k, v.to_string());
                } else {
                    // --key value (if next token isn't another flag), else boolean
                    let is_val = it
                        .peek()
                        .map(|n| !n.starts_with("--"))
                        .unwrap_or(false);
                    if is_val {
                        let v = it.next().unwrap();
                        out.flag(stripped, v);
                    } else {
                        out.flag(stripped, "true".to_string());
                    }
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    fn flag(&mut self, key: &str, value: String) {
        self.flags.insert(key.to_string(), value.clone());
        self.occurrences.push((key.to_string(), value));
    }

    pub fn from_env() -> Self {
        Self::parse(std::env::args().skip(1))
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    /// All values given for a repeatable flag, in argv order
    /// (`--param a=1 --param b=2` → `["a=1", "b=2"]`).
    pub fn get_all(&self, key: &str) -> Vec<&str> {
        self.occurrences
            .iter()
            .filter(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
            .collect()
    }

    pub fn usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn u64(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects an integer, got {v:?}")))
            .unwrap_or(default)
    }

    pub fn f64(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{key} expects a number, got {v:?}")))
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|s| s.to_string()))
    }

    #[test]
    fn positional_and_flags() {
        // note: a bare `--flag` followed by a non-flag token consumes it as
        // a value (documented ambiguity) — boolean flags should use
        // `--flag=true` or come after positionals.
        let a = parse(&["run", "file.txt", "--n", "5", "--fast", "--k=v"]);
        assert_eq!(a.positional, vec!["run", "file.txt"]);
        assert_eq!(a.usize("n", 0), 5);
        assert!(a.has("fast"));
        assert_eq!(a.get("k"), Some("v"));
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.usize("n", 3), 3);
        assert_eq!(a.f64("x", 1.5), 1.5);
        assert_eq!(a.get_or("s", "d"), "d");
        assert!(!a.has("missing"));
    }

    #[test]
    fn boolean_flag_at_end() {
        let a = parse(&["--verbose"]);
        assert!(a.has("verbose"));
    }

    #[test]
    fn repeated_flags_keep_every_occurrence() {
        let a = parse(&["--param", "a=1", "--param=b=2", "--param", "a=3"]);
        assert_eq!(a.get("param"), Some("a=3")); // last wins for get()
        assert_eq!(a.get_all("param"), vec!["a=1", "b=2", "a=3"]);
        assert!(a.get_all("missing").is_empty());
    }
}
