//! `BitRow`: a DRAM row's worth of bit-lines, packed 64 per word.
//!
//! This is the hot data structure of the functional simulator: every AAP
//! charge-sharing evaluation is a handful of word-wise loops over `BitRow`s.
//! All logic ops are branch-free word-parallel.

/// One DRAM row (or sense-amplifier latch row): `bits` bit-lines.
#[derive(Clone, PartialEq, Eq, Debug)]
pub struct BitRow {
    bits: usize,
    words: Vec<u64>,
}

#[inline]
fn words_for(bits: usize) -> usize {
    bits.div_ceil(64)
}

impl BitRow {
    pub fn zeros(bits: usize) -> Self {
        BitRow {
            bits,
            words: vec![0; words_for(bits)],
        }
    }

    pub fn ones(bits: usize) -> Self {
        let mut r = BitRow {
            bits,
            words: vec![!0u64; words_for(bits)],
        };
        r.mask_tail();
        r
    }

    pub fn random(bits: usize, rng: &mut crate::util::rng::Rng) -> Self {
        let mut r = Self::zeros(bits);
        rng.fill(&mut r.words);
        r.mask_tail();
        r
    }

    pub fn from_words(bits: usize, words: Vec<u64>) -> Self {
        assert_eq!(words.len(), words_for(bits));
        let mut r = BitRow { bits, words };
        r.mask_tail();
        r
    }

    /// Build from bools (tests / small examples). Word-wise: each chunk
    /// of 64 bools folds into one word, so construction costs one store
    /// per word instead of a read-modify-write per bit.
    pub fn from_bits(bits: &[bool]) -> Self {
        let words = bits
            .chunks(64)
            .map(|chunk| {
                chunk
                    .iter()
                    .enumerate()
                    .fold(0u64, |w, (i, &b)| w | ((b as u64) << i))
            })
            .collect();
        BitRow {
            bits: bits.len(),
            words,
        }
    }

    /// Zero the unused tail of the last word so Eq/popcount stay exact.
    #[inline]
    fn mask_tail(&mut self) {
        let tail = self.bits % 64;
        if tail != 0 {
            if let Some(last) = self.words.last_mut() {
                *last &= (1u64 << tail) - 1;
            }
        }
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.bits
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.bits == 0
    }

    #[inline]
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    #[inline]
    pub fn words_mut(&mut self) -> &mut [u64] {
        &mut self.words
    }

    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.bits);
        (self.words[i / 64] >> (i % 64)) & 1 == 1
    }

    #[inline]
    pub fn set(&mut self, i: usize, v: bool) {
        debug_assert!(i < self.bits);
        let (w, b) = (i / 64, i % 64);
        if v {
            self.words[w] |= 1 << b;
        } else {
            self.words[w] &= !(1 << b);
        }
    }

    pub fn popcount(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// dst = f(a, b) word-wise, writing into self.
    #[inline]
    pub fn apply2(&mut self, a: &BitRow, b: &BitRow, f: impl Fn(u64, u64) -> u64) {
        debug_assert!(a.bits == self.bits && b.bits == self.bits);
        for ((d, &x), &y) in self.words.iter_mut().zip(&a.words).zip(&b.words) {
            *d = f(x, y);
        }
        self.mask_tail();
    }

    /// dst = f(a, b, c) word-wise, writing into self.
    #[inline]
    pub fn apply3(
        &mut self,
        a: &BitRow,
        b: &BitRow,
        c: &BitRow,
        f: impl Fn(u64, u64, u64) -> u64,
    ) {
        debug_assert!(a.bits == self.bits && b.bits == self.bits && c.bits == self.bits);
        for (((d, &x), &y), &z) in self
            .words
            .iter_mut()
            .zip(&a.words)
            .zip(&b.words)
            .zip(&c.words)
        {
            *d = f(x, y, z);
        }
        self.mask_tail();
    }

    pub fn copy_from(&mut self, src: &BitRow) {
        debug_assert_eq!(self.bits, src.bits);
        self.words.copy_from_slice(&src.words);
    }

    /// Copy `len` bits from `src[src_off..]` into `self[dst_off..]`.
    /// Word-aligned offsets take the memcpy fast path (the router always
    /// slices on row boundaries, which are 64-bit aligned); the general
    /// case falls back to bit loops at the ragged edges only.
    pub fn copy_bits_from(
        &mut self,
        src: &BitRow,
        src_off: usize,
        dst_off: usize,
        len: usize,
    ) {
        debug_assert!(src_off + len <= src.bits);
        debug_assert!(dst_off + len <= self.bits);
        if src_off % 64 == 0 && dst_off % 64 == 0 {
            let whole = len / 64;
            let (sw, dw) = (src_off / 64, dst_off / 64);
            self.words[dw..dw + whole].copy_from_slice(&src.words[sw..sw + whole]);
            for b in whole * 64..len {
                self.set(dst_off + b, src.get(src_off + b));
            }
        } else {
            for b in 0..len {
                self.set(dst_off + b, src.get(src_off + b));
            }
        }
    }

    pub fn not_from(&mut self, src: &BitRow) {
        debug_assert_eq!(self.bits, src.bits);
        for (d, &s) in self.words.iter_mut().zip(&src.words) {
            *d = !s;
        }
        self.mask_tail();
    }

    /// Pack little-endian: bit i of element k (width w) lives at row index
    /// `k*w + i` — the layout `apps::vecadd` and the converters use.
    pub fn to_u32_lanes(&self) -> Vec<u32> {
        let mut out = Vec::with_capacity(self.bits.div_ceil(32));
        for i in 0..self.bits.div_ceil(32) {
            let w = self.words[i / 2];
            out.push(if i % 2 == 0 { w as u32 } else { (w >> 32) as u32 });
        }
        out
    }

    pub fn from_u32_lanes(bits: usize, lanes: &[u32]) -> Self {
        assert!(lanes.len() * 32 >= bits);
        let mut words = vec![0u64; words_for(bits)];
        for (i, &l) in lanes.iter().enumerate() {
            if i / 2 < words.len() {
                words[i / 2] |= (l as u64) << (32 * (i % 2));
            }
        }
        let mut r = BitRow { bits, words };
        r.mask_tail();
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn zeros_ones() {
        let z = BitRow::zeros(100);
        let o = BitRow::ones(100);
        assert_eq!(z.popcount(), 0);
        assert_eq!(o.popcount(), 100);
        assert_eq!(z.len(), 100);
    }

    #[test]
    fn set_get_roundtrip() {
        let mut r = BitRow::zeros(130);
        r.set(0, true);
        r.set(64, true);
        r.set(129, true);
        assert!(r.get(0) && r.get(64) && r.get(129));
        assert!(!r.get(1) && !r.get(128));
        assert_eq!(r.popcount(), 3);
        r.set(64, false);
        assert_eq!(r.popcount(), 2);
    }

    #[test]
    fn tail_masked_after_ops() {
        let mut rng = Rng::new(1);
        let a = BitRow::random(70, &mut rng);
        let b = BitRow::random(70, &mut rng);
        let mut d = BitRow::zeros(70);
        d.apply2(&a, &b, |x, y| !(x ^ y)); // XNOR sets tail bits w/o mask
        assert_eq!(d.words()[1] >> 6, 0, "tail must stay zero");
        assert_eq!(d.popcount(), (0..70).filter(|&i| a.get(i) == b.get(i)).count());
    }

    #[test]
    fn apply3_maj() {
        let mut rng = Rng::new(2);
        let (a, b, c) = (
            BitRow::random(256, &mut rng),
            BitRow::random(256, &mut rng),
            BitRow::random(256, &mut rng),
        );
        let mut d = BitRow::zeros(256);
        d.apply3(&a, &b, &c, |x, y, z| (x & y) | (x & z) | (y & z));
        for i in 0..256 {
            let n = a.get(i) as u8 + b.get(i) as u8 + c.get(i) as u8;
            assert_eq!(d.get(i), n >= 2);
        }
    }

    #[test]
    fn u32_lane_roundtrip() {
        let mut rng = Rng::new(3);
        let r = BitRow::random(8192, &mut rng);
        let lanes = r.to_u32_lanes();
        assert_eq!(lanes.len(), 256);
        let back = BitRow::from_u32_lanes(8192, &lanes);
        assert_eq!(r, back);
    }

    #[test]
    fn from_bits() {
        let r = BitRow::from_bits(&[true, false, true, true]);
        assert_eq!(r.len(), 4);
        assert!(r.get(0) && !r.get(1) && r.get(2) && r.get(3));
    }

    #[test]
    fn from_bits_matches_per_bit_set() {
        // word-wise construction must agree with the per-bit reference at
        // every word-boundary-straddling length
        let mut rng = Rng::new(7);
        for bits in [0usize, 1, 63, 64, 65, 127, 128, 129, 191] {
            let v: Vec<bool> = (0..bits).map(|_| rng.next_u64() & 1 == 1).collect();
            let fast = BitRow::from_bits(&v);
            let mut slow = BitRow::zeros(bits);
            for (i, &b) in v.iter().enumerate() {
                slow.set(i, b);
            }
            assert_eq!(fast, slow, "bits={bits}");
            assert_eq!(fast.words().len(), bits.div_ceil(64), "bits={bits}");
        }
    }

    /// Property: u32-lane pack/unpack round-trips at ragged lengths where
    /// the final u64 word is only partially covered by lanes — the half-
    /// word tail cases (bits % 64 in 33..=63) exercise the `i % 2 == 1`
    /// high-half extraction against a partially masked word.
    #[test]
    fn u32_lane_roundtrip_ragged_tails() {
        let mut rng = Rng::new(11);
        for &bits in &[33usize, 41, 47, 63, 97, 111, 127, 161, 8191] {
            for seed_extra in 0..8u64 {
                let mut r2 = Rng::new(11 + bits as u64 * 31 + seed_extra);
                let r = BitRow::random(bits, &mut r2);
                let lanes = r.to_u32_lanes();
                assert_eq!(lanes.len(), bits.div_ceil(32), "bits={bits}");
                let back = BitRow::from_u32_lanes(bits, &lanes);
                assert_eq!(r, back, "bits={bits} seed_extra={seed_extra}");
                // every bit beyond `bits` in the last lane must be zero:
                // to_u32_lanes reads from a tail-masked word
                let tail = bits % 32;
                if tail != 0 {
                    let last = *lanes.last().unwrap();
                    assert_eq!(last >> tail, 0, "bits={bits}");
                }
            }
        }
        // and a straight sweep of every tail in 33..=63 at one word + tail
        for tail in 33usize..=63 {
            let bits = 64 + tail;
            let r = BitRow::random(bits, &mut rng);
            let back = BitRow::from_u32_lanes(bits, &r.to_u32_lanes());
            assert_eq!(r, back, "bits={bits}");
        }
    }
}
