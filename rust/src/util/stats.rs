//! Summary statistics & unit helpers shared by benches and metrics.

/// Online mean/min/max/stddev accumulator (Welford).
#[derive(Debug, Clone, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Smallest sample, or `0.0` before any [`Self::add`]. An empty
    /// summary previously leaked the `+INFINITY` sentinel, which JSON
    /// cannot represent (`serde_json`-free writers emit `inf`, breaking
    /// downstream parsers) — zero-count summaries report 0.
    pub fn min(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.min
        }
    }

    /// Largest sample, or `0.0` before any [`Self::add`] (see
    /// [`Self::min`] for why the `-INFINITY` sentinel must not escape).
    pub fn max(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.max
        }
    }

    pub fn stddev(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }
}

/// Percentile over a sorted-in-place sample buffer.
pub fn percentile(samples: &mut [f64], p: f64) -> f64 {
    assert!(!samples.is_empty());
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((samples.len() - 1) as f64 * p / 100.0).round() as usize;
    samples[idx]
}

/// Human-readable ops/s (bit-ops per second here).
pub fn fmt_rate(per_sec: f64) -> String {
    const UNITS: &[(&str, f64)] = &[
        ("T", 1e12),
        ("G", 1e9),
        ("M", 1e6),
        ("K", 1e3),
    ];
    for (u, s) in UNITS {
        if per_sec >= *s {
            return format!("{:.2} {u}", per_sec / s);
        }
    }
    format!("{per_sec:.2} ")
}

/// Human-readable duration from nanoseconds.
pub fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.2} s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.2} ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.2} µs", ns / 1e3)
    } else {
        format!("{ns:.0} ns")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_matches_closed_form() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.add(x);
        }
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.stddev() - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
    }

    /// Golden: a zero-count summary serializes as finite zeros, never the
    /// ±INFINITY accumulator sentinels (which are unrepresentable in JSON
    /// and previously leaked into empty-metric reports).
    #[test]
    fn empty_summary_is_finite_zero() {
        let s = Summary::new();
        assert_eq!(s.count(), 0);
        assert_eq!(s.min(), 0.0);
        assert_eq!(s.max(), 0.0);
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.stddev(), 0.0);
        assert!(s.min().is_finite() && s.max().is_finite());
        // one sample restores real extrema (the sentinel still works
        // internally)
        let mut s = Summary::new();
        s.add(-3.5);
        assert_eq!(s.min(), -3.5);
        assert_eq!(s.max(), -3.5);
    }

    #[test]
    fn percentiles() {
        let mut v: Vec<f64> = (1..=101).map(|i| i as f64).collect();
        assert_eq!(percentile(&mut v, 50.0), 51.0);
        assert_eq!(percentile(&mut v, 100.0), 101.0);
        assert_eq!(percentile(&mut v, 0.0), 1.0);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_rate(2.5e12), "2.50 T");
        assert_eq!(fmt_ns(1500.0), "1.50 µs");
    }
}
