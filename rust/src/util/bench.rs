//! Minimal benchmark harness (criterion is not vendored).
//!
//! Measures wall time with warm-up, reports mean ± stddev and derived
//! throughput. Benches run with `cargo bench` via `harness = false` targets.
//!
//! [`BenchReport`] is the machine-readable side: every ablation bench
//! writes a `BENCH_<name>.json` artifact at the repo root (schema below)
//! so CI can archive a perf trajectory per commit and diff runs without
//! scraping stdout:
//!
//! ```json
//! {"schema": 1, "bench": "<name>", "config": {...},
//!  "metrics": {...}, "gates": {"<gate>": true, ...}, "ok": true}
//! ```

use std::path::PathBuf;
use std::time::Instant;

use crate::obs::Json;
use crate::util::stats::{fmt_ns, fmt_rate, Summary};

pub struct Bencher {
    pub warmup_iters: u32,
    pub iters: u32,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup_iters: 3,
            iters: 10,
        }
    }
}

#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub mean_ns: f64,
    pub stddev_ns: f64,
    pub min_ns: f64,
    /// user-supplied work units per iteration (e.g. bit-ops) for throughput
    pub units_per_iter: f64,
}

impl Measurement {
    pub fn rate(&self) -> f64 {
        self.units_per_iter / (self.mean_ns / 1e9)
    }

    pub fn report(&self) -> String {
        if self.units_per_iter > 0.0 {
            format!(
                "{:40} {:>12} ± {:>10}   {:>12}ops/s",
                self.name,
                fmt_ns(self.mean_ns),
                fmt_ns(self.stddev_ns),
                fmt_rate(self.rate()),
            )
        } else {
            format!(
                "{:40} {:>12} ± {:>10}",
                self.name,
                fmt_ns(self.mean_ns),
                fmt_ns(self.stddev_ns)
            )
        }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher {
            warmup_iters: 1,
            iters: 3,
        }
    }

    /// Benchmark `f`, which performs `units` work-units per call.
    pub fn run<R>(&self, name: &str, units: f64, mut f: impl FnMut() -> R) -> Measurement {
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        let mut s = Summary::new();
        for _ in 0..self.iters {
            let t = Instant::now();
            std::hint::black_box(f());
            s.add(t.elapsed().as_nanos() as f64);
        }
        let m = Measurement {
            name: name.to_string(),
            mean_ns: s.mean(),
            stddev_ns: s.stddev(),
            min_ns: s.min(),
            units_per_iter: units,
        };
        println!("{}", m.report());
        m
    }
}

/// Simple section header for bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Machine-readable bench artifact: accumulated config, metrics, and gate
/// verdicts, written as `BENCH_<name>.json` at the repo root.
///
/// Gates are the bench's pass/fail assertions recorded *before* the
/// `assert!` fires, so a failing run still leaves an artifact saying
/// which gate broke.
pub struct BenchReport {
    name: String,
    config: Vec<(String, Json)>,
    metrics: Vec<(String, Json)>,
    gates: Vec<(String, bool)>,
}

impl BenchReport {
    pub fn new(name: &str) -> Self {
        BenchReport {
            name: name.to_string(),
            config: Vec::new(),
            metrics: Vec::new(),
            gates: Vec::new(),
        }
    }

    /// Record a workload-configuration value (devices, requests, bits…).
    /// Panics on a duplicate key — a config recorded twice means the
    /// driver overwrote itself and the artifact would silently lie.
    pub fn config(&mut self, key: &str, value: impl Into<Json>) -> &mut Self {
        assert!(
            !self.config.iter().any(|(k, _)| k == key),
            "BenchReport `{}`: duplicate config key `{key}`",
            self.name
        );
        self.config.push((key.to_string(), value.into()));
        self
    }

    /// Record a measured metric (throughput, makespan, waves saved…).
    /// Panics on a duplicate key (same contract as [`Self::config`]: JSON
    /// objects with repeated keys are ambiguous to every consumer).
    pub fn metric(&mut self, key: &str, value: impl Into<Json>) -> &mut Self {
        assert!(
            !self.metrics.iter().any(|(k, _)| k == key),
            "BenchReport `{}`: duplicate metric key `{key}`",
            self.name
        );
        self.metrics.push((key.to_string(), value.into()));
        self
    }

    /// Record a [`Measurement`] under `metrics` as a nested object
    /// (duplicate-key checked like [`Self::metric`]).
    pub fn measurement(&mut self, m: &Measurement) -> &mut Self {
        let mut obj = Json::obj()
            .field("mean_ns", m.mean_ns)
            .field("stddev_ns", m.stddev_ns)
            .field("min_ns", m.min_ns);
        if m.units_per_iter > 0.0 {
            obj = obj.field("rate_per_sec", m.rate());
        }
        self.metric(&m.name, obj)
    }

    /// Record a gate verdict. Call with the boolean *before* asserting it
    /// so the artifact survives a failing run.
    pub fn gate(&mut self, key: &str, pass: bool) -> &mut Self {
        self.gates.push((key.to_string(), pass));
        self
    }

    /// All recorded gates passed (vacuously true with no gates).
    pub fn ok(&self) -> bool {
        self.gates.iter().all(|(_, p)| *p)
    }

    pub fn to_json(&self) -> Json {
        let fields =
            |v: &[(String, Json)]| Json::Obj(v.to_vec());
        Json::obj()
            .field("schema", 1u64)
            .field("bench", self.name.as_str())
            .field("config", fields(&self.config))
            .field("metrics", fields(&self.metrics))
            .field(
                "gates",
                Json::Obj(
                    self.gates
                        .iter()
                        .map(|(k, p)| (k.clone(), Json::Bool(*p)))
                        .collect(),
                ),
            )
            .field("ok", self.ok())
    }

    /// Repo-root path of this report's artifact (`BENCH_<name>.json`).
    pub fn path(&self) -> PathBuf {
        PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/.."))
            .join(format!("BENCH_{}.json", self.name))
    }

    /// Write the artifact; prints where it went. Panics on I/O failure
    /// (bench drivers want loud breakage, not silent missing artifacts).
    pub fn write(&self) {
        let path = self.path();
        self.write_to(&path);
        println!("\nwrote {}", path.display());
    }

    /// Write the artifact to an explicit path, silently — the variant
    /// `drim bench --json` uses so stdout stays pure JSON.
    pub fn write_to(&self, path: &std::path::Path) {
        std::fs::write(path, self.to_json().to_string_pretty() + "\n")
            .unwrap_or_else(|e| panic!("write {}: {e}", path.display()));
    }
}

// ---------------------------------------------------------------------------
// Perf trajectory: parsing and diffing BENCH_*.json artifacts (`drim perf`)
// ---------------------------------------------------------------------------

/// Which way a metric regresses, inferred from its (dotted) key.
/// Wall-time-style keys regress upward, throughput-style keys regress
/// downward; everything else is informational — rendered in diffs but
/// never gated (counts, digests-as-numbers, schema constants).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MetricDirection {
    LowerIsBetter,
    HigherIsBetter,
    Informational,
}

impl MetricDirection {
    /// Short arrow label for tables (`↓`, `↑`, `·`).
    pub fn glyph(self) -> &'static str {
        match self {
            MetricDirection::LowerIsBetter => "↓",
            MetricDirection::HigherIsBetter => "↑",
            MetricDirection::Informational => "·",
        }
    }
}

/// Classify a flattened metric key. Lower-is-better patterns are checked
/// first so compound names like `shed_rate` resolve to the harm they
/// measure, not the unit they carry.
pub fn metric_direction(key: &str) -> MetricDirection {
    let k = key.to_ascii_lowercase();
    let any = |pats: &[&str]| pats.iter().any(|p| k.contains(p));
    if k.ends_with("_ns")
        || any(&["makespan", "latency", "sojourn", "ratio", "shed", "dropped", "burn"])
    {
        MetricDirection::LowerIsBetter
    } else if any(&["throughput", "per_sec", "rate"]) {
        MetricDirection::HigherIsBetter
    } else {
        MetricDirection::Informational
    }
}

/// A `BENCH_*.json` artifact reduced to the perf-trajectory view: numeric
/// metrics flattened to dotted keys, plus the gate verdicts. `stddev_ns`
/// leaves are dropped — they measure run noise, not trajectory.
#[derive(Clone, Debug)]
pub struct PerfArtifact {
    pub bench: String,
    pub metrics: Vec<(String, f64)>,
    pub gates: Vec<(String, bool)>,
}

impl PerfArtifact {
    /// Parse artifact JSON text (strict: must carry a `bench` name).
    pub fn parse(text: &str) -> Result<PerfArtifact, String> {
        let doc = Json::parse(text)?;
        let bench = doc
            .get("bench")
            .and_then(Json::as_str)
            .ok_or_else(|| "artifact has no `bench` name".to_string())?
            .to_string();
        let mut metrics = Vec::new();
        if let Some(m) = doc.get("metrics") {
            flatten_numeric("", m, &mut metrics);
        }
        let mut gates = Vec::new();
        if let Some(Json::Obj(fields)) = doc.get("gates") {
            for (k, v) in fields {
                if let Json::Bool(p) = v {
                    gates.push((k.clone(), *p));
                }
            }
        }
        Ok(PerfArtifact {
            bench,
            metrics,
            gates,
        })
    }

    /// Value of one flattened metric key.
    pub fn metric(&self, key: &str) -> Option<f64> {
        self.metrics
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| *v)
    }
}

/// Flatten nested metric objects to dotted keys, keeping numeric leaves
/// only (strings — digests, labels — and booleans are not a trajectory).
fn flatten_numeric(prefix: &str, node: &Json, out: &mut Vec<(String, f64)>) {
    match node {
        Json::Obj(fields) => {
            for (k, v) in fields {
                let key = if prefix.is_empty() {
                    k.clone()
                } else {
                    format!("{prefix}.{k}")
                };
                flatten_numeric(&key, v, out);
            }
        }
        _ => {
            if prefix.ends_with("stddev_ns") {
                return;
            }
            if let Some(x) = node.as_f64() {
                out.push((prefix.to_string(), x));
            }
        }
    }
}

/// Per-metric regression tolerance: a default percentage plus substring
/// overrides (`--tolerance 25 --tolerance ratio=2` → 2% for keys
/// containing "ratio", 25% otherwise). First matching override wins.
#[derive(Clone, Debug)]
pub struct Tolerance {
    pub default_pct: f64,
    pub overrides: Vec<(String, f64)>,
}

impl Default for Tolerance {
    fn default() -> Self {
        Tolerance {
            default_pct: 10.0,
            overrides: Vec::new(),
        }
    }
}

impl Tolerance {
    /// The allowed harmful movement, in percent, for `key`.
    pub fn pct_for(&self, key: &str) -> f64 {
        self.overrides
            .iter()
            .find(|(pat, _)| key.contains(pat.as_str()))
            .map(|(_, pct)| *pct)
            .unwrap_or(self.default_pct)
    }
}

/// One metric's movement between a baseline artifact and a current one.
#[derive(Clone, Debug)]
pub struct PerfDelta {
    pub key: String,
    pub baseline: f64,
    pub current: f64,
    /// Signed relative change in percent ((current−baseline)/|baseline|);
    /// ±∞ when the baseline is zero and the value moved.
    pub change_pct: f64,
    pub direction: MetricDirection,
    /// Movement exceeds the tolerance in the harmful direction.
    pub regressed: bool,
}

/// The diff of two artifacts: per-metric deltas (baseline key order),
/// key-set drift, and gate-verdict regressions.
#[derive(Clone, Debug, Default)]
pub struct PerfComparison {
    pub deltas: Vec<PerfDelta>,
    /// Baseline metrics with no counterpart in the current run.
    pub missing: Vec<String>,
    /// Current metrics the baseline doesn't know about.
    pub added: Vec<String>,
    /// Gates that passed in the baseline and fail (or vanished) now.
    pub gate_regressions: Vec<String>,
}

impl PerfComparison {
    /// The deltas that breached tolerance.
    pub fn regressions(&self) -> impl Iterator<Item = &PerfDelta> {
        self.deltas.iter().filter(|d| d.regressed)
    }

    /// No metric breached tolerance and no gate went from pass to fail.
    /// Key-set drift alone (missing/added) does not fail a comparison —
    /// metrics get renamed; the gates are the contract.
    pub fn ok(&self) -> bool {
        self.gate_regressions.is_empty() && self.deltas.iter().all(|d| !d.regressed)
    }
}

/// Diff `current` against `baseline` under a per-metric [`Tolerance`].
/// Direction-aware: a faster wall time or higher throughput never
/// regresses no matter how large the swing.
pub fn compare_artifacts(
    baseline: &PerfArtifact,
    current: &PerfArtifact,
    tol: &Tolerance,
) -> PerfComparison {
    let mut cmp = PerfComparison::default();
    for (key, base) in &baseline.metrics {
        let Some(cur) = current.metric(key) else {
            cmp.missing.push(key.clone());
            continue;
        };
        let change_pct = if *base != 0.0 {
            (cur - *base) / base.abs() * 100.0
        } else if cur == 0.0 {
            0.0
        } else if cur > 0.0 {
            f64::INFINITY
        } else {
            f64::NEG_INFINITY
        };
        let direction = metric_direction(key);
        let allowed = tol.pct_for(key);
        let regressed = match direction {
            MetricDirection::LowerIsBetter => change_pct > allowed,
            MetricDirection::HigherIsBetter => change_pct < -allowed,
            MetricDirection::Informational => false,
        };
        cmp.deltas.push(PerfDelta {
            key: key.clone(),
            baseline: *base,
            current: cur,
            change_pct,
            direction,
            regressed,
        });
    }
    for (key, _) in &current.metrics {
        if baseline.metric(key).is_none() {
            cmp.added.push(key.clone());
        }
    }
    for (gate, passed) in &baseline.gates {
        if !passed {
            continue; // a baseline that already failed can't regress
        }
        match current.gates.iter().find(|(g, _)| g == gate) {
            Some((_, true)) => {}
            Some((_, false)) => cmp
                .gate_regressions
                .push(format!("{gate}: passed in baseline, fails now")),
            None => cmp
                .gate_regressions
                .push(format!("{gate}: passed in baseline, missing now")),
        }
    }
    cmp
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let b = Bencher::quick();
        let m = b.run("spin", 1000.0, || {
            let mut x = 0u64;
            for i in 0..1000u64 {
                x = x.wrapping_add(std::hint::black_box(i));
            }
            x
        });
        assert!(m.mean_ns > 0.0);
        assert!(m.rate() > 0.0);
    }

    #[test]
    fn report_round_trips_through_json_parser() {
        let mut r = BenchReport::new("roundtrip");
        r.config("devices", 4u64)
            .config("label", "abc")
            .metric("throughput", 1.5f64)
            .metric("waves", 7u64)
            .gate("fast_enough", true)
            .gate("no_regression", false);
        let text = r.to_json().to_string_compact();
        let parsed = Json::parse(&text).expect("artifact must re-parse");
        assert_eq!(parsed.get("schema").and_then(Json::as_f64), Some(1.0));
        assert_eq!(parsed.get("bench").and_then(Json::as_str), Some("roundtrip"));
        let cfg = parsed.get("config").expect("config object");
        assert_eq!(cfg.get("devices").and_then(Json::as_f64), Some(4.0));
        assert_eq!(cfg.get("label").and_then(Json::as_str), Some("abc"));
        let met = parsed.get("metrics").expect("metrics object");
        assert_eq!(met.get("throughput").and_then(Json::as_f64), Some(1.5));
        assert_eq!(met.get("waves").and_then(Json::as_f64), Some(7.0));
        let gates = parsed.get("gates").expect("gates object");
        assert_eq!(gates.get("fast_enough"), Some(&Json::Bool(true)));
        assert_eq!(gates.get("no_regression"), Some(&Json::Bool(false)));
        assert_eq!(parsed.get("ok"), Some(&Json::Bool(false)));
    }

    #[test]
    #[should_panic(expected = "duplicate metric key `throughput`")]
    fn duplicate_metric_key_panics() {
        let mut r = BenchReport::new("dup");
        r.metric("throughput", 1.0f64).metric("throughput", 2.0f64);
    }

    #[test]
    #[should_panic(expected = "duplicate config key `devices`")]
    fn duplicate_config_key_panics() {
        let mut r = BenchReport::new("dup");
        r.config("devices", 1u64).config("devices", 2u64);
    }

    #[test]
    fn direction_heuristic_is_pinned() {
        use MetricDirection::*;
        for (key, want) in [
            ("pump_idle.mean_ns", LowerIsBetter),
            ("default.sim_makespan_ns", LowerIsBetter),
            ("default.tenant.a.mean_sojourn_ns", LowerIsBetter),
            ("sampled_over_idle_ratio", LowerIsBetter),
            ("default.shed", LowerIsBetter),
            ("telemetry.dropped", LowerIsBetter),
            ("slo.floor.max_burn", LowerIsBetter),
            ("default.throughput_bits_per_sec", HigherIsBetter),
            ("pump_idle.rate_per_sec", HigherIsBetter),
            ("default.completed", Informational),
            ("routed_submit_scaling_8dev_over_1dev", Informational),
        ] {
            assert_eq!(metric_direction(key), want, "key `{key}`");
        }
    }

    /// Build a minimal artifact through BenchReport so the parser is
    /// exercised against exactly what the writer emits.
    fn artifact(mean_ns: f64, rate: f64, gate: bool) -> PerfArtifact {
        let mut r = BenchReport::new("probe");
        r.measurement(&Measurement {
            name: "work".into(),
            mean_ns,
            stddev_ns: 17.0,
            min_ns: mean_ns * 0.9,
            units_per_iter: 0.0,
        })
        .metric("throughput_bits_per_sec", rate)
        .metric("digest", "0xabc") // non-numeric leaf: not a trajectory
        .gate("fast_enough", gate);
        PerfArtifact::parse(&r.to_json().to_string_compact()).unwrap()
    }

    #[test]
    fn parse_flattens_and_drops_noise() {
        let a = artifact(1000.0, 5.0e6, true);
        assert_eq!(a.bench, "probe");
        assert_eq!(a.metric("work.mean_ns"), Some(1000.0));
        assert_eq!(a.metric("work.min_ns"), Some(900.0));
        assert_eq!(a.metric("work.stddev_ns"), None, "stddev is noise");
        assert_eq!(a.metric("digest"), None, "strings are not metrics");
        assert_eq!(a.gates, vec![("fast_enough".to_string(), true)]);
    }

    #[test]
    fn identical_artifacts_compare_clean() {
        let a = artifact(1000.0, 5.0e6, true);
        let cmp = compare_artifacts(&a, &a, &Tolerance::default());
        assert!(cmp.ok());
        assert_eq!(cmp.regressions().count(), 0);
        assert!(cmp.missing.is_empty() && cmp.added.is_empty());
        assert!(cmp.deltas.iter().all(|d| d.change_pct == 0.0));
    }

    #[test]
    fn regression_is_direction_aware() {
        let base = artifact(1000.0, 5.0e6, true);
        let tol = Tolerance::default(); // 10%
        // 50% slower wall time: regression on mean_ns (lower-is-better)
        let slow = artifact(1500.0, 5.0e6, true);
        let cmp = compare_artifacts(&base, &slow, &tol);
        assert!(!cmp.ok());
        let keys: Vec<&str> = cmp.regressions().map(|d| d.key.as_str()).collect();
        assert!(keys.contains(&"work.mean_ns"), "{keys:?}");
        // 50% *faster* is an improvement, never a regression
        let fast = artifact(500.0, 5.0e6, true);
        assert!(compare_artifacts(&base, &fast, &tol).ok());
        // throughput collapse: regression on the higher-is-better key
        let starved = artifact(1000.0, 1.0e6, true);
        let cmp = compare_artifacts(&base, &starved, &tol);
        let keys: Vec<&str> = cmp.regressions().map(|d| d.key.as_str()).collect();
        assert_eq!(keys, vec!["throughput_bits_per_sec"]);
        // ...and a throughput gain is fine
        assert!(compare_artifacts(&base, &artifact(1000.0, 9.0e6, true), &tol).ok());
    }

    #[test]
    fn tolerance_overrides_match_by_substring() {
        let base = artifact(1000.0, 5.0e6, true);
        let slow = artifact(1080.0, 5.0e6, true); // +8%
        let loose = Tolerance {
            default_pct: 10.0,
            overrides: Vec::new(),
        };
        assert!(compare_artifacts(&base, &slow, &loose).ok());
        let tight = Tolerance {
            default_pct: 10.0,
            overrides: vec![("mean_ns".to_string(), 5.0)],
        };
        assert!(!compare_artifacts(&base, &slow, &tight).ok());
        assert_eq!(tight.pct_for("work.mean_ns"), 5.0);
        assert_eq!(tight.pct_for("work.min_ns"), 10.0);
    }

    #[test]
    fn newly_failing_gate_regresses_even_with_flat_metrics() {
        let base = artifact(1000.0, 5.0e6, true);
        let broken = artifact(1000.0, 5.0e6, false);
        let cmp = compare_artifacts(&base, &broken, &Tolerance::default());
        assert!(!cmp.ok());
        assert_eq!(cmp.gate_regressions.len(), 1);
        assert!(cmp.gate_regressions[0].contains("fast_enough"));
        // the reverse — a failing baseline — can't regress further
        assert!(compare_artifacts(&broken, &base, &Tolerance::default()).ok());
    }

    #[test]
    fn key_set_drift_is_reported_but_not_fatal() {
        let base = artifact(1000.0, 5.0e6, true);
        let mut r = BenchReport::new("probe");
        r.metric("brand_new_ns", 1.0f64).gate("fast_enough", true);
        let renamed = PerfArtifact::parse(&r.to_json().to_string_compact()).unwrap();
        let cmp = compare_artifacts(&base, &renamed, &Tolerance::default());
        assert!(cmp.ok(), "drift alone must not fail the comparison");
        assert_eq!(cmp.missing.len(), base.metrics.len());
        assert_eq!(cmp.added, vec!["brand_new_ns".to_string()]);
    }

    #[test]
    fn zero_baseline_movement_is_flagged_when_harmful() {
        let mk = |shed: u64| {
            let mut r = BenchReport::new("z");
            r.metric("default.shed", shed);
            PerfArtifact::parse(&r.to_json().to_string_compact()).unwrap()
        };
        let cmp = compare_artifacts(&mk(0), &mk(3), &Tolerance::default());
        assert!(!cmp.ok(), "0 → 3 on a lower-is-better key is a regression");
        assert!(compare_artifacts(&mk(0), &mk(0), &Tolerance::default()).ok());
    }
}
