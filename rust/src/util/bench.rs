//! Minimal benchmark harness (criterion is not vendored).
//!
//! Measures wall time with warm-up, reports mean ± stddev and derived
//! throughput. Benches run with `cargo bench` via `harness = false` targets.

use std::time::Instant;

use crate::util::stats::{fmt_ns, fmt_rate, Summary};

pub struct Bencher {
    pub warmup_iters: u32,
    pub iters: u32,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup_iters: 3,
            iters: 10,
        }
    }
}

#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub mean_ns: f64,
    pub stddev_ns: f64,
    pub min_ns: f64,
    /// user-supplied work units per iteration (e.g. bit-ops) for throughput
    pub units_per_iter: f64,
}

impl Measurement {
    pub fn rate(&self) -> f64 {
        self.units_per_iter / (self.mean_ns / 1e9)
    }

    pub fn report(&self) -> String {
        if self.units_per_iter > 0.0 {
            format!(
                "{:40} {:>12} ± {:>10}   {:>12}ops/s",
                self.name,
                fmt_ns(self.mean_ns),
                fmt_ns(self.stddev_ns),
                fmt_rate(self.rate()),
            )
        } else {
            format!(
                "{:40} {:>12} ± {:>10}",
                self.name,
                fmt_ns(self.mean_ns),
                fmt_ns(self.stddev_ns)
            )
        }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher {
            warmup_iters: 1,
            iters: 3,
        }
    }

    /// Benchmark `f`, which performs `units` work-units per call.
    pub fn run<R>(&self, name: &str, units: f64, mut f: impl FnMut() -> R) -> Measurement {
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        let mut s = Summary::new();
        for _ in 0..self.iters {
            let t = Instant::now();
            std::hint::black_box(f());
            s.add(t.elapsed().as_nanos() as f64);
        }
        let m = Measurement {
            name: name.to_string(),
            mean_ns: s.mean(),
            stddev_ns: s.stddev(),
            min_ns: s.min(),
            units_per_iter: units,
        };
        println!("{}", m.report());
        m
    }
}

/// Simple section header for bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let b = Bencher::quick();
        let m = b.run("spin", 1000.0, || {
            let mut x = 0u64;
            for i in 0..1000u64 {
                x = x.wrapping_add(std::hint::black_box(i));
            }
            x
        });
        assert!(m.mean_ns > 0.0);
        assert!(m.rate() > 0.0);
    }
}
