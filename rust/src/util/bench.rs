//! Minimal benchmark harness (criterion is not vendored).
//!
//! Measures wall time with warm-up, reports mean ± stddev and derived
//! throughput. Benches run with `cargo bench` via `harness = false` targets.
//!
//! [`BenchReport`] is the machine-readable side: every ablation bench
//! writes a `BENCH_<name>.json` artifact at the repo root (schema below)
//! so CI can archive a perf trajectory per commit and diff runs without
//! scraping stdout:
//!
//! ```json
//! {"schema": 1, "bench": "<name>", "config": {...},
//!  "metrics": {...}, "gates": {"<gate>": true, ...}, "ok": true}
//! ```

use std::path::PathBuf;
use std::time::Instant;

use crate::obs::Json;
use crate::util::stats::{fmt_ns, fmt_rate, Summary};

pub struct Bencher {
    pub warmup_iters: u32,
    pub iters: u32,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher {
            warmup_iters: 3,
            iters: 10,
        }
    }
}

#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub mean_ns: f64,
    pub stddev_ns: f64,
    pub min_ns: f64,
    /// user-supplied work units per iteration (e.g. bit-ops) for throughput
    pub units_per_iter: f64,
}

impl Measurement {
    pub fn rate(&self) -> f64 {
        self.units_per_iter / (self.mean_ns / 1e9)
    }

    pub fn report(&self) -> String {
        if self.units_per_iter > 0.0 {
            format!(
                "{:40} {:>12} ± {:>10}   {:>12}ops/s",
                self.name,
                fmt_ns(self.mean_ns),
                fmt_ns(self.stddev_ns),
                fmt_rate(self.rate()),
            )
        } else {
            format!(
                "{:40} {:>12} ± {:>10}",
                self.name,
                fmt_ns(self.mean_ns),
                fmt_ns(self.stddev_ns)
            )
        }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher {
            warmup_iters: 1,
            iters: 3,
        }
    }

    /// Benchmark `f`, which performs `units` work-units per call.
    pub fn run<R>(&self, name: &str, units: f64, mut f: impl FnMut() -> R) -> Measurement {
        for _ in 0..self.warmup_iters {
            std::hint::black_box(f());
        }
        let mut s = Summary::new();
        for _ in 0..self.iters {
            let t = Instant::now();
            std::hint::black_box(f());
            s.add(t.elapsed().as_nanos() as f64);
        }
        let m = Measurement {
            name: name.to_string(),
            mean_ns: s.mean(),
            stddev_ns: s.stddev(),
            min_ns: s.min(),
            units_per_iter: units,
        };
        println!("{}", m.report());
        m
    }
}

/// Simple section header for bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Machine-readable bench artifact: accumulated config, metrics, and gate
/// verdicts, written as `BENCH_<name>.json` at the repo root.
///
/// Gates are the bench's pass/fail assertions recorded *before* the
/// `assert!` fires, so a failing run still leaves an artifact saying
/// which gate broke.
pub struct BenchReport {
    name: String,
    config: Vec<(String, Json)>,
    metrics: Vec<(String, Json)>,
    gates: Vec<(String, bool)>,
}

impl BenchReport {
    pub fn new(name: &str) -> Self {
        BenchReport {
            name: name.to_string(),
            config: Vec::new(),
            metrics: Vec::new(),
            gates: Vec::new(),
        }
    }

    /// Record a workload-configuration value (devices, requests, bits…).
    /// Panics on a duplicate key — a config recorded twice means the
    /// driver overwrote itself and the artifact would silently lie.
    pub fn config(&mut self, key: &str, value: impl Into<Json>) -> &mut Self {
        assert!(
            !self.config.iter().any(|(k, _)| k == key),
            "BenchReport `{}`: duplicate config key `{key}`",
            self.name
        );
        self.config.push((key.to_string(), value.into()));
        self
    }

    /// Record a measured metric (throughput, makespan, waves saved…).
    /// Panics on a duplicate key (same contract as [`Self::config`]: JSON
    /// objects with repeated keys are ambiguous to every consumer).
    pub fn metric(&mut self, key: &str, value: impl Into<Json>) -> &mut Self {
        assert!(
            !self.metrics.iter().any(|(k, _)| k == key),
            "BenchReport `{}`: duplicate metric key `{key}`",
            self.name
        );
        self.metrics.push((key.to_string(), value.into()));
        self
    }

    /// Record a [`Measurement`] under `metrics` as a nested object
    /// (duplicate-key checked like [`Self::metric`]).
    pub fn measurement(&mut self, m: &Measurement) -> &mut Self {
        let mut obj = Json::obj()
            .field("mean_ns", m.mean_ns)
            .field("stddev_ns", m.stddev_ns)
            .field("min_ns", m.min_ns);
        if m.units_per_iter > 0.0 {
            obj = obj.field("rate_per_sec", m.rate());
        }
        self.metric(&m.name, obj)
    }

    /// Record a gate verdict. Call with the boolean *before* asserting it
    /// so the artifact survives a failing run.
    pub fn gate(&mut self, key: &str, pass: bool) -> &mut Self {
        self.gates.push((key.to_string(), pass));
        self
    }

    /// All recorded gates passed (vacuously true with no gates).
    pub fn ok(&self) -> bool {
        self.gates.iter().all(|(_, p)| *p)
    }

    pub fn to_json(&self) -> Json {
        let fields =
            |v: &[(String, Json)]| Json::Obj(v.to_vec());
        Json::obj()
            .field("schema", 1u64)
            .field("bench", self.name.as_str())
            .field("config", fields(&self.config))
            .field("metrics", fields(&self.metrics))
            .field(
                "gates",
                Json::Obj(
                    self.gates
                        .iter()
                        .map(|(k, p)| (k.clone(), Json::Bool(*p)))
                        .collect(),
                ),
            )
            .field("ok", self.ok())
    }

    /// Repo-root path of this report's artifact (`BENCH_<name>.json`).
    pub fn path(&self) -> PathBuf {
        PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/.."))
            .join(format!("BENCH_{}.json", self.name))
    }

    /// Write the artifact; prints where it went. Panics on I/O failure
    /// (bench drivers want loud breakage, not silent missing artifacts).
    pub fn write(&self) {
        let path = self.path();
        self.write_to(&path);
        println!("\nwrote {}", path.display());
    }

    /// Write the artifact to an explicit path, silently — the variant
    /// `drim bench --json` uses so stdout stays pure JSON.
    pub fn write_to(&self, path: &std::path::Path) {
        std::fs::write(path, self.to_json().to_string_pretty() + "\n")
            .unwrap_or_else(|e| panic!("write {}: {e}", path.display()));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let b = Bencher::quick();
        let m = b.run("spin", 1000.0, || {
            let mut x = 0u64;
            for i in 0..1000u64 {
                x = x.wrapping_add(std::hint::black_box(i));
            }
            x
        });
        assert!(m.mean_ns > 0.0);
        assert!(m.rate() > 0.0);
    }

    #[test]
    fn report_round_trips_through_json_parser() {
        let mut r = BenchReport::new("roundtrip");
        r.config("devices", 4u64)
            .config("label", "abc")
            .metric("throughput", 1.5f64)
            .metric("waves", 7u64)
            .gate("fast_enough", true)
            .gate("no_regression", false);
        let text = r.to_json().to_string_compact();
        let parsed = Json::parse(&text).expect("artifact must re-parse");
        assert_eq!(parsed.get("schema").and_then(Json::as_f64), Some(1.0));
        assert_eq!(parsed.get("bench").and_then(Json::as_str), Some("roundtrip"));
        let cfg = parsed.get("config").expect("config object");
        assert_eq!(cfg.get("devices").and_then(Json::as_f64), Some(4.0));
        assert_eq!(cfg.get("label").and_then(Json::as_str), Some("abc"));
        let met = parsed.get("metrics").expect("metrics object");
        assert_eq!(met.get("throughput").and_then(Json::as_f64), Some(1.5));
        assert_eq!(met.get("waves").and_then(Json::as_f64), Some(7.0));
        let gates = parsed.get("gates").expect("gates object");
        assert_eq!(gates.get("fast_enough"), Some(&Json::Bool(true)));
        assert_eq!(gates.get("no_regression"), Some(&Json::Bool(false)));
        assert_eq!(parsed.get("ok"), Some(&Json::Bool(false)));
    }

    #[test]
    #[should_panic(expected = "duplicate metric key `throughput`")]
    fn duplicate_metric_key_panics() {
        let mut r = BenchReport::new("dup");
        r.metric("throughput", 1.0f64).metric("throughput", 2.0f64);
    }

    #[test]
    #[should_panic(expected = "duplicate config key `devices`")]
    fn duplicate_config_key_panics() {
        let mut r = BenchReport::new("dup");
        r.config("devices", 1u64).config("devices", 2u64);
    }
}
