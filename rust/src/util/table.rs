//! Fixed-width text tables + CSV writer for bench/report output.

use std::fmt::Write as _;
use std::io::Write as _;
use std::path::Path;

pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let line = |out: &mut String, cells: &[String]| {
            for (i, c) in cells.iter().enumerate() {
                let _ = write!(out, "{:>w$}  ", c, w = widths[i]);
            }
            out.push('\n');
        };
        line(&mut out, &self.headers);
        let total: usize = widths.iter().sum::<usize>() + 2 * widths.len();
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for r in &self.rows {
            line(&mut out, r);
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }

    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "{}", self.headers.join(","))?;
        for r in &self.rows {
            writeln!(f, "{}", r.join(","))?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["longer".into(), "22".into()]);
        let s = t.render();
        assert!(s.contains("name"));
        assert!(s.lines().count() == 4);
    }

    #[test]
    #[should_panic(expected = "column count mismatch")]
    fn wrong_arity_panics() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn csv_roundtrip() {
        let mut t = Table::new(&["x", "y"]);
        t.row(&["1".into(), "2".into()]);
        let p = std::env::temp_dir().join("drim_table_test.csv");
        t.write_csv(&p).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        assert_eq!(s, "x,y\n1,2\n");
        let _ = std::fs::remove_file(&p);
    }
}
