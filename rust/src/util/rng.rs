//! Deterministic PRNG (xoshiro256**) + Gaussian sampling.
//!
//! Used by the Monte-Carlo analog mirror, the property-test harness and the
//! workload generators. Seeded explicitly everywhere — simulation runs are
//! reproducible bit-for-bit.

/// xoshiro256** by Blackman & Vigna (public domain reference algorithm).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via splitmix64 so that any u64 (including 0) is a valid seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        // multiply-shift; bias is negligible for simulation purposes
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Standard normal via Box-Muller (one value per call; simple > fast here).
    pub fn gaussian(&mut self) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-12 {
                let u2 = self.f64();
                return (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Gaussian with σ = `bound`/3, clamped to ±`bound` (fab-binning model,
    /// mirrors `_trunc_normal` in python/compile/model.py).
    pub fn trunc_gaussian(&mut self, bound: f64) -> f64 {
        (self.gaussian() * bound / 3.0).clamp(-bound, bound)
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Fill a u64 slice with random bits.
    pub fn fill(&mut self, words: &mut [u64]) {
        for w in words {
            *w = self.next_u64();
        }
    }

    /// Sample an index from a cumulative distribution (ascending, last
    /// element ≈ 1.0) by inverse-CDF binary search — pair with
    /// [`zipf_cdf`] for skewed-popularity workloads.
    pub fn sample_cdf(&mut self, cdf: &[f64]) -> usize {
        assert!(!cdf.is_empty());
        let u = self.f64();
        let i = cdf.partition_point(|&c| c <= u);
        i.min(cdf.len() - 1)
    }
}

/// Cumulative distribution of a Zipf(`theta`) popularity law over ranks
/// `0..n` (rank 0 most popular): weight(k) ∝ 1/(k+1)^theta. `theta = 0`
/// is uniform; larger values skew harder toward the head — the shape the
/// capacity/replication ablations use to model hot operand regions.
pub fn zipf_cdf(n: usize, theta: f64) -> Vec<f64> {
    assert!(n > 0, "a Zipf law needs at least one rank");
    let mut cdf = Vec::with_capacity(n);
    let mut acc = 0.0;
    for k in 0..n {
        acc += 1.0 / ((k + 1) as f64).powf(theta);
        cdf.push(acc);
    }
    for c in &mut cdf {
        *c /= acc;
    }
    cdf
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.gaussian();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn trunc_gaussian_bounded() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            assert!(r.trunc_gaussian(0.2).abs() <= 0.2);
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(5);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn zipf_cdf_is_normalized_and_monotone() {
        let cdf = zipf_cdf(8, 1.2);
        assert_eq!(cdf.len(), 8);
        assert!((cdf[7] - 1.0).abs() < 1e-12);
        for w in cdf.windows(2) {
            assert!(w[0] < w[1]);
        }
        // theta = 0 degenerates to uniform
        let flat = zipf_cdf(4, 0.0);
        assert!((flat[0] - 0.25).abs() < 1e-12);
        assert!((flat[1] - 0.5).abs() < 1e-12);
    }

    #[test]
    fn zipf_sampling_skews_toward_the_head() {
        let cdf = zipf_cdf(16, 1.5);
        let mut r = Rng::new(9);
        let mut counts = [0usize; 16];
        for _ in 0..20_000 {
            let i = r.sample_cdf(&cdf);
            assert!(i < 16);
            counts[i] += 1;
        }
        assert!(counts[0] > counts[1], "{counts:?}");
        assert!(counts[1] > counts[4], "{counts:?}");
        // head mass: rank 0 holds ≈ 42% of a 16-rank Zipf(1.5) law
        assert!(counts[0] > 7000, "{counts:?}");
    }
}
