//! Per-command DRAM energy model (Fig. 9).
//!
//! Constants are derived in the Rambus-power-model style the paper cites
//! [28], for a 45 nm-class device with 8 Kb rows, and validated against the
//! paper's own calibration points (asserted in tests and reported next to
//! the paper's numbers by `cargo bench fig9_energy`):
//!
//! * in-DRAM copy vs DDR4-interface copy: ~69× (paper §1)
//! * DRIM vs Ambit XNOR2: ~2.4×, vs DRISA-1T1C: ~1.6× (paper §3.4)
//! * DRIM vs CPU add: ~27× (paper §3.4)
//!
//! Energy scales linearly with activated row width (`cols`); constants are
//! quoted for the reference 8192-bit row.
#![warn(missing_docs)]

pub mod model;

pub use model::EnergyModel;
