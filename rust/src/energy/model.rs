//! The energy model proper. All values in picojoules.

use crate::dram::command::AapKind;

/// Reference row width the constants are quoted for.
pub const REF_ROW_BITS: f64 = 8192.0;

/// Per-command DRAM energy constants (picojoules) and the derived costs of
/// AAP primitives and off-chip transfers — the substrate behind Fig. 9.
#[derive(Clone, Debug)]
pub struct EnergyModel {
    /// single-row ACTIVATE (charge restore of one 8 Kb row)
    pub e_act_pj: f64,
    /// each additional simultaneously-activated row (charge sharing across
    /// more cells moves less charge per cell — cheaper than a full ACT)
    pub e_act_extra_row_pj: f64,
    /// PRECHARGE of the bit-lines
    pub e_pre_pj: f64,
    /// DRIM's add-on SA circuitry (two shifted-VTC inverters + AND gate)
    /// switching during a DRA sense (per row-operation)
    pub e_dra_addon_pj: f64,
    /// DRISA-1T1C's add-on gate + latch per compute cycle (≥12 T per SA)
    pub e_1t1c_gate_pj: f64,
    /// DDR4 interface transfer, per bit (I/O + termination)
    pub e_interface_pj_per_bit: f64,
    /// DRAM core access (array → I/O) per bit, paid on any off-chip path
    pub e_core_pj_per_bit: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        EnergyModel {
            e_act_pj: 900.0,
            e_act_extra_row_pj: 1000.0,
            e_pre_pj: 600.0,
            e_dra_addon_pj: 300.0,
            e_1t1c_gate_pj: 2000.0,
            e_interface_pj_per_bit: 10.0,
            e_core_pj_per_bit: 15.0,
        }
    }
}

impl EnergyModel {
    /// Energy of one ACTIVATE phase opening `rows` word-lines at once.
    pub fn activate_pj(&self, rows: usize) -> f64 {
        assert!(rows >= 1);
        self.e_act_pj + (rows - 1) as f64 * self.e_act_extra_row_pj
    }

    /// Energy of one full AAP primitive on a `cols`-bit row.
    pub fn aap_pj(&self, kind: AapKind, cols: usize) -> f64 {
        let src = self.activate_pj(kind.source_rows());
        let dst = self.activate_pj(kind.dest_rows());
        let addon = if kind == AapKind::Dra {
            self.e_dra_addon_pj
        } else {
            0.0
        };
        (src + dst + self.e_pre_pj + addon) * (cols as f64 / REF_ROW_BITS)
    }

    /// Energy to move `bits` across the DDR4 interface (one direction),
    /// including the core access.
    pub fn offchip_pj(&self, bits: f64) -> f64 {
        bits * (self.e_interface_pj_per_bit + self.e_core_pj_per_bit)
    }

    /// DDR4 copy of `bits`: read out over the interface + write back, plus
    /// the row activations on both ends. (The *core* per-bit energy is not
    /// double-charged here — the row activation term covers the array
    /// access for the full row.)
    pub fn ddr4_copy_pj(&self, bits: f64) -> f64 {
        2.0 * bits * self.e_interface_pj_per_bit
            + 2.0 * (self.e_act_pj + self.e_pre_pj)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const KB_BITS: f64 = 8192.0;

    fn m() -> EnergyModel {
        EnergyModel::default()
    }

    #[test]
    fn aap1_copy_energy() {
        // AAP type-1 on a full row: ACT + ACT + PRE = 0.9 + 0.9 + 0.6 nJ
        let e = m().aap_pj(AapKind::Copy, 8192);
        assert!((e - 2400.0).abs() < 1e-9, "{e}");
    }

    #[test]
    fn dra_and_tra_aap_energy() {
        let dra = m().aap_pj(AapKind::Dra, 8192);
        // (0.9+1.0) + 0.9 + 0.6 + 0.3 = 3.7 nJ
        assert!((dra - 3700.0).abs() < 1e-9, "{dra}");
        let tra = m().aap_pj(AapKind::Tra, 8192);
        // (0.9+2.0) + 0.9 + 0.6 = 4.4 nJ
        assert!((tra - 4400.0).abs() < 1e-9, "{tra}");
    }

    #[test]
    fn energy_scales_with_row_width() {
        let full = m().aap_pj(AapKind::Copy, 8192);
        let half = m().aap_pj(AapKind::Copy, 4096);
        assert!((half * 2.0 - full).abs() < 1e-9);
    }

    #[test]
    fn paper_calibration_copy_vs_ddr4() {
        // paper §1: "reduces the DRAM chip energy by ... 69× compared with
        // copying data through the DDR4 interface"
        let in_dram = m().aap_pj(AapKind::Copy, 8192);
        let ddr4 = m().ddr4_copy_pj(KB_BITS);
        let ratio = ddr4 / in_dram;
        assert!((60.0..80.0).contains(&ratio), "ratio {ratio:.1}");
    }
}
