//! Scalar sense-amplification models — mirror of
//! `python/compile/kernels/ref.py::{dra_sense, tra_sense}`.

use super::params as P;

/// One DRA instance: returns (XNOR on BL, XOR on BL̄) as booleans.
///
/// `qi`/`qj` cell charges, `ci`/`cj` cell capacitances, `cp` sense-node
/// parasitic, `vsl`/`vsh` the shifted inverter thresholds, `vnoise`
/// additive node noise.
#[allow(clippy::too_many_arguments)]
pub fn dra_sense(
    qi: f64,
    qj: f64,
    ci: f64,
    cj: f64,
    cp: f64,
    vsl: f64,
    vsh: f64,
    vnoise: f64,
) -> (bool, bool) {
    let v = (qi + qj + cp * (P::VDD / 2.0)) / (ci + cj + cp) + vnoise;
    let nor_out = v < vsl; // low-Vs inverter: NOR2
    let nand_out = v < vsh; // high-Vs inverter: NAND2
    let xor = nand_out && !nor_out; // CMOS AND gate (Eq. 1)
    (!xor, xor)
}

/// One TRA instance on the conventional SA: MAJ3 decision.
#[allow(clippy::too_many_arguments)]
pub fn tra_sense(
    q: [f64; 3],
    c: [f64; 3],
    cb: f64,
    vsa: f64,
    vnoise: f64,
) -> bool {
    let v = (q[0] + q[1] + q[2] + cb * (P::VDD / 2.0))
        / (c[0] + c[1] + c[2] + cb)
        + vnoise;
    v > vsa
}

/// Ideal DRA sense-node levels for n∈{0,1,2} cells storing '1'.
pub fn dra_ideal_levels() -> [f64; 3] {
    let c = 2.0 + P::CP_RATIO;
    [0, 1, 2].map(|n| (n as f64 * P::VDD + P::CP_RATIO * P::VDD / 2.0) / c)
}

/// Ideal TRA bit-line levels for n∈{0..3}.
pub fn tra_ideal_levels() -> [f64; 4] {
    let c = 3.0 + P::CB_RATIO;
    [0, 1, 2, 3].map(|n| (n as f64 * P::VDD + P::CB_RATIO * P::VDD / 2.0) / c)
}

/// Worst-case noise margin of each mechanism (drives Table 3's ordering).
pub fn dra_worst_margin() -> f64 {
    let lv = dra_ideal_levels();
    [
        (lv[0] - P::VS_LOW).abs(),
        (lv[1] - P::VS_LOW).abs(),
        (lv[1] - P::VS_HIGH).abs(),
        (lv[2] - P::VS_HIGH).abs(),
    ]
    .into_iter()
    .fold(f64::INFINITY, f64::min)
}

pub fn tra_worst_margin() -> f64 {
    tra_ideal_levels()
        .into_iter()
        .map(|v| (v - P::VSA).abs())
        .fold(f64::INFINITY, f64::min)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn noiseless_dra_truth_table() {
        for (di, dj) in [(0., 0.), (0., 1.), (1., 0.), (1., 1.)] {
            let (xnor, xor) = dra_sense(
                di * P::VDD,
                dj * P::VDD,
                1.0,
                1.0,
                P::CP_RATIO,
                P::VS_LOW,
                P::VS_HIGH,
                0.0,
            );
            assert_eq!(xnor, di == dj);
            assert_eq!(xor, di != dj);
        }
    }

    #[test]
    fn noiseless_tra_truth_table() {
        for n in 0..8u8 {
            let bits = [(n >> 2) & 1, (n >> 1) & 1, n & 1].map(f64::from);
            let maj = tra_sense(
                [bits[0] * P::VDD, bits[1] * P::VDD, bits[2] * P::VDD],
                [1.0; 3],
                P::CB_RATIO,
                P::VSA,
                0.0,
            );
            assert_eq!(maj, bits.iter().sum::<f64>() >= 2.0);
        }
    }

    #[test]
    fn level_midpoints_preserved() {
        // single-'1' DRA level sits exactly at Vdd/2 (cp precharge)
        assert!((dra_ideal_levels()[1] - P::VDD / 2.0).abs() < 1e-12);
    }

    #[test]
    fn dra_margin_exceeds_tra_margin() {
        // the paper's reliability claim in one inequality
        assert!(dra_worst_margin() > tra_worst_margin());
        // TRA margin is 0.1 V at Cb/Cc = 3 (Ambit operating point)
        assert!((tra_worst_margin() - 0.1).abs() < 1e-9);
    }

    #[test]
    fn noise_flips_decisions() {
        // push the node past the high threshold: XNOR(1,0) misreads as 1
        let (xnor, _) = dra_sense(
            P::VDD,
            0.0,
            1.0,
            1.0,
            P::CP_RATIO,
            P::VS_LOW,
            P::VS_HIGH,
            0.5,
        );
        assert!(xnor, "large positive noise must flip the decision");
    }
}
