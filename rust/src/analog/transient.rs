//! Fig. 6 transient waveforms — Rust mirror of
//! `python/compile/kernels/transient.py` (same forward-Euler RC network,
//! same constants). The JAX artifact is the reference; this mirror exists
//! so benches and the CLI work without the PJRT runtime, and the two are
//! compared point-wise in `it_runtime_golden` (they must agree to float
//! tolerance since the integration scheme is identical).

use super::params as P;

/// Per-step sample: (BL, BL̄, Vcap-Di, Vcap-Dj).
pub type Sample = [f64; 4];

/// Integrate the DRA transient for one input case.
pub fn waveform(di: bool, dj: bool) -> Vec<Sample> {
    let steps = P::transient_steps();
    let p_end = (P::T_PRECHARGE_NS / P::DT_NS).round() as usize;
    let s_end = ((P::T_PRECHARGE_NS + P::T_SHARE_NS) / P::DT_NS).round() as usize;

    let rail = if di == dj { P::VDD } else { 0.0 };
    let a_share = P::DT_NS / P::TAU_SHARE_NS;
    let a_sense = P::DT_NS / P::TAU_SENSE_NS;
    let a_cell = P::DT_NS / P::TAU_CELL_NS;

    let mut v_bl = P::VDD / 2.0;
    let mut v_blb = P::VDD / 2.0;
    let mut v_ci = if di { P::VDD } else { 0.0 };
    let mut v_cj = if dj { P::VDD } else { 0.0 };

    let csum = 2.0 + P::CP_RATIO;
    let mut out = Vec::with_capacity(steps);
    for t in 0..steps {
        if t >= s_end {
            // S.A.S.: regenerate BL to the XNOR rail, restore cells
            let bl_prev = v_bl;
            v_bl += a_sense * (rail - v_bl);
            v_blb += a_sense * ((P::VDD - rail) - v_blb);
            v_ci += a_cell * (bl_prev - v_ci);
            v_cj += a_cell * (bl_prev - v_cj);
        } else if t >= p_end {
            // C.S.S.: relax toward the charge-sharing equilibrium
            let veq = (v_ci + v_cj + P::CP_RATIO * v_bl) / csum;
            v_bl += a_share * (veq - v_bl);
            v_ci += a_share * (veq - v_ci);
            v_cj += a_share * (veq - v_cj);
        }
        out.push([v_bl, v_blb, v_ci, v_cj]);
    }
    out
}

/// All four Fig. 6 input cases: 00, 01, 10, 11.
pub fn all_cases() -> [(bool, bool, Vec<Sample>); 4] {
    [(false, false), (false, true), (true, false), (true, true)]
        .map(|(di, dj)| (di, dj, waveform(di, dj)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reaches_xnor_rail() {
        for (di, dj, w) in all_cases() {
            let last = w.last().unwrap();
            let want = if di == dj { P::VDD } else { 0.0 };
            assert!((last[0] - want).abs() < 0.01, "BL case {di}{dj}");
            assert!((last[1] - (P::VDD - want)).abs() < 0.01, "BL̄");
            assert!((last[2] - want).abs() < 0.05, "Vcap-Di restored");
            assert!((last[3] - want).abs() < 0.05, "Vcap-Dj restored");
        }
    }

    #[test]
    fn precharge_phase_is_flat() {
        let w = waveform(true, false);
        let p_end = (P::T_PRECHARGE_NS / P::DT_NS) as usize;
        for s in &w[..p_end - 1] {
            assert!((s[0] - P::VDD / 2.0).abs() < 1e-12);
            assert!((s[2] - P::VDD).abs() < 1e-12);
        }
    }

    #[test]
    fn charge_share_hits_paper_equation() {
        // end of C.S.S.: V ≈ n·Vdd/C with the parasitic term (params.py)
        let w = waveform(true, false); // n = 1
        let s_end = ((P::T_PRECHARGE_NS + P::T_SHARE_NS) / P::DT_NS) as usize;
        let veq = (P::VDD + P::CP_RATIO * P::VDD / 2.0) / (2.0 + P::CP_RATIO);
        assert!(
            (w[s_end - 1][0] - veq).abs() < 0.02,
            "{} vs {veq}",
            w[s_end - 1][0]
        );
    }

    #[test]
    fn sample_count_matches_params() {
        assert_eq!(waveform(false, false).len(), P::transient_steps());
    }
}
