//! Behavioural circuit models: the Rust mirror of the L1/L2 analog
//! kernels (python/compile/kernels/{dra_analog,transient}.py).
//!
//! Two implementations of the same circuit exist on purpose:
//! * the JAX/Pallas artifacts (AOT-lowered, executed through `runtime`) —
//!   the *reference* used for Table 3 / Fig. 6;
//! * this Rust mirror — used on paths where the PJRT runtime is not loaded
//!   (fast benches, property tests), and cross-checked against the
//!   artifacts in `it_runtime_golden`.
//!
//! Constants must match `python/compile/params.py`; `params::check_manifest`
//! verifies that against the generated artifact manifest at runtime.

pub mod model;
pub mod montecarlo;
pub mod params;
pub mod transient;

pub use model::{dra_sense, tra_sense};
pub use montecarlo::{run_montecarlo, McResult};
