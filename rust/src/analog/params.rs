//! Physical constants — mirror of `python/compile/params.py` (see there for
//! the derivation of each value and the margin geometry discussion).

pub const VDD: f64 = 1.2;
pub const VS_LOW: f64 = VDD / 4.0;
pub const VS_HIGH: f64 = 3.0 * VDD / 4.0;
pub const VSA: f64 = VDD / 2.0;

pub const CP_RATIO: f64 = 0.6;
pub const CB_RATIO: f64 = 3.0;

pub const SIGMA_FRACTION: f64 = 1.0 / 3.0;
pub const NOISE_LIN: f64 = 0.05;
pub const NOISE_QUAD: f64 = 2.5;

pub const MC_TRIALS: usize = 10_000;

pub const DT_NS: f64 = 0.05;
pub const T_PRECHARGE_NS: f64 = 10.0;
pub const T_SHARE_NS: f64 = 10.0;
pub const T_SENSE_NS: f64 = 40.0;
pub const TAU_SHARE_NS: f64 = 1.5;
pub const TAU_SENSE_NS: f64 = 3.0;
pub const TAU_CELL_NS: f64 = 4.0;

pub fn transient_steps() -> usize {
    ((T_PRECHARGE_NS + T_SHARE_NS + T_SENSE_NS) / DT_NS).round() as usize
}

/// σ of the additive sense-node noise at variation corner ±`variation`.
pub fn noise_sigma(variation: f64) -> f64 {
    (NOISE_LIN + NOISE_QUAD * variation) * variation
}

/// Parse the `# vdd=... cp_ratio=...` header of artifacts/manifest.txt and
/// confirm the Python constants match this mirror. Returns the mismatched
/// keys (empty = consistent).
pub fn check_manifest(manifest_text: &str) -> Vec<String> {
    let mut mismatches = Vec::new();
    let expect = [
        ("vdd", VDD),
        ("cp_ratio", CP_RATIO),
        ("cb_ratio", CB_RATIO),
        ("noise_lin", NOISE_LIN),
        ("noise_quad", NOISE_QUAD),
        ("trials", MC_TRIALS as f64),
        ("transient_steps", transient_steps() as f64),
        ("dt_ns", DT_NS),
    ];
    let header = manifest_text
        .lines()
        .find(|l| l.starts_with('#') && l.contains("vdd="))
        .unwrap_or("");
    for (key, want) in expect {
        let found = header.split_whitespace().find_map(|tok| {
            tok.strip_prefix(&format!("{key}="))
                .and_then(|v| v.parse::<f64>().ok())
        });
        match found {
            Some(v) if (v - want).abs() < 1e-9 => {}
            Some(v) => mismatches.push(format!("{key}: rust={want} python={v}")),
            None => mismatches.push(format!("{key}: missing from manifest")),
        }
    }
    mismatches
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thresholds_bracket_midlevel() {
        assert!(VS_LOW < VDD / 2.0 && VDD / 2.0 < VS_HIGH);
    }

    #[test]
    fn noise_grows_superlinearly() {
        assert!(noise_sigma(0.30) > 2.0 * noise_sigma(0.15));
        assert_eq!(noise_sigma(0.0), 0.0);
    }

    #[test]
    fn manifest_check_detects_good_and_bad() {
        let good = format!(
            "# DRIM manifest\n# vdd={VDD} cp_ratio={CP_RATIO} cb_ratio={CB_RATIO} \
             noise_lin={NOISE_LIN} noise_quad={NOISE_QUAD} trials={MC_TRIALS} \
             transient_steps={} dt_ns={DT_NS}\n",
            transient_steps()
        );
        assert!(check_manifest(&good).is_empty());
        let bad = good.replace("vdd=1.2", "vdd=1.0");
        assert_eq!(check_manifest(&bad).len(), 1);
        assert!(check_manifest("")
            .iter()
            .all(|m| m.contains("missing")));
    }

    #[test]
    fn steps_count() {
        assert_eq!(transient_steps(), 1200);
    }
}
