//! Monte-Carlo process-variation analysis (Table 3) — Rust mirror of
//! `python/compile/model.py::mc_variation`.
//!
//! Same sampling model (truncated Gaussians at σ = bound/3 for component
//! variation, additive node noise σ = noise_sigma(X)), different PRNG —
//! the two implementations agree *statistically* (asserted within Monte-
//! Carlo tolerance in `it_runtime_golden`), while both are exact for the
//! zero-variation corner.

use crate::util::rng::Rng;

use super::model;
use super::params as P;

#[derive(Clone, Copy, Debug, Default)]
pub struct McResult {
    pub dra_errors: u64,
    pub dra_evals: u64,
    pub tra_errors: u64,
    pub tra_evals: u64,
}

impl McResult {
    pub fn dra_pct(&self) -> f64 {
        100.0 * self.dra_errors as f64 / self.dra_evals.max(1) as f64
    }

    pub fn tra_pct(&self) -> f64 {
        100.0 * self.tra_errors as f64 / self.tra_evals.max(1) as f64
    }
}

/// Run `trials` Monte-Carlo instances of every DRA input case (4) and TRA
/// input case (8) at variation corner ±`variation`.
pub fn run_montecarlo(variation: f64, trials: usize, seed: u64) -> McResult {
    let mut rng = Rng::new(seed);
    let sigma_n = P::noise_sigma(variation);
    let mut res = McResult::default();

    for _ in 0..trials {
        // --- DRA: (Di,Dj) ∈ {00,01,10,11} -------------------------------
        for case in 0..4u8 {
            let di = f64::from(case >> 1);
            let dj = f64::from(case & 1);
            let ci = 1.0 + rng.trunc_gaussian(variation);
            let cj = 1.0 + rng.trunc_gaussian(variation);
            let cp = P::CP_RATIO * (1.0 + rng.trunc_gaussian(variation));
            let vsl = P::VS_LOW * (1.0 + rng.trunc_gaussian(variation));
            let vsh = P::VS_HIGH * (1.0 + rng.trunc_gaussian(variation));
            let vn = rng.gaussian() * sigma_n;
            let (xnor, _) = model::dra_sense(
                ci * di * P::VDD,
                cj * dj * P::VDD,
                ci,
                cj,
                cp,
                vsl,
                vsh,
                vn,
            );
            res.dra_evals += 1;
            if xnor != (di == dj) {
                res.dra_errors += 1;
            }
        }

        // --- TRA: (D1,D2,D3) ∈ {000..111} --------------------------------
        for case in 0..8u8 {
            let d = [
                f64::from((case >> 2) & 1),
                f64::from((case >> 1) & 1),
                f64::from(case & 1),
            ];
            let c = [
                1.0 + rng.trunc_gaussian(variation),
                1.0 + rng.trunc_gaussian(variation),
                1.0 + rng.trunc_gaussian(variation),
            ];
            let cb = P::CB_RATIO * (1.0 + rng.trunc_gaussian(variation));
            let vsa = P::VSA * (1.0 + rng.trunc_gaussian(variation));
            let vn = rng.gaussian() * sigma_n;
            let maj = model::tra_sense(
                [c[0] * d[0] * P::VDD, c[1] * d[1] * P::VDD, c[2] * d[2] * P::VDD],
                c,
                cb,
                vsa,
                vn,
            );
            res.tra_evals += 1;
            if maj != (d.iter().sum::<f64>() >= 2.0) {
                res.tra_errors += 1;
            }
        }
    }
    res
}

/// The five variation corners of Table 3.
pub const TABLE3_CORNERS: [f64; 5] = [0.05, 0.10, 0.15, 0.20, 0.30];

/// Paper's Table 3 values (%, DRA/TRA) for side-by-side reporting.
pub const TABLE3_PAPER: [(f64, f64); 5] = [
    (0.00, 0.00),
    (0.00, 0.18),
    (1.2, 5.5),
    (9.6, 17.1),
    (16.4, 28.4),
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_variation_is_error_free() {
        let r = run_montecarlo(0.0, 2000, 1);
        assert_eq!(r.dra_errors, 0);
        assert_eq!(r.tra_errors, 0);
    }

    #[test]
    fn dra_beats_tra_at_every_corner() {
        for v in TABLE3_CORNERS {
            let r = run_montecarlo(v, 4000, 7);
            assert!(
                r.dra_pct() <= r.tra_pct(),
                "±{v}: DRA {:.2}% vs TRA {:.2}%",
                r.dra_pct(),
                r.tra_pct()
            );
        }
    }

    #[test]
    fn dra_clean_at_ten_percent() {
        let r = run_montecarlo(0.10, P::MC_TRIALS, 11);
        assert!(r.dra_pct() < 0.05, "{:.3}%", r.dra_pct());
    }

    #[test]
    fn error_rates_monotone_in_variation() {
        let mut last = (0.0, 0.0);
        for v in TABLE3_CORNERS {
            let r = run_montecarlo(v, 6000, 13);
            assert!(r.dra_pct() >= last.0 - 0.3, "DRA not monotone at ±{v}");
            assert!(r.tra_pct() >= last.1 - 0.3, "TRA not monotone at ±{v}");
            last = (r.dra_pct(), r.tra_pct());
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = run_montecarlo(0.2, 500, 42);
        let b = run_montecarlo(0.2, 500, 42);
        assert_eq!(a.dra_errors, b.dra_errors);
        assert_eq!(a.tra_errors, b.tra_errors);
    }
}
