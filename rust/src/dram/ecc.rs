//! Row-level ECC (paper §4 "Reliability", left as future work there —
//! implemented here): conventional DIMM ECC is computed at the memory
//! controller, which never sees PIM-generated data, so DRIM must compute
//! and verify ECC *at the module level*. We augment each row with SEC-DED
//! Hamming(72,64) check bits per 64-bit word, recomputed after every
//! in-memory operation's write-back and verified on read-out.

use crate::util::bitrow::BitRow;

/// Check bits per 64-bit data word: 7 Hamming parity bits + 1 overall
/// parity bit → single-error correction, double-error detection.
pub const CHECK_BITS_PER_WORD: usize = 8;

/// Compute the 8 SEC-DED check bits of one 64-bit word.
///
/// Parity bit `i` (i < 7) covers the data-bit positions whose (1-based,
/// check-bit-skipping) Hamming index has bit `i` set; bit 7 is overall
/// parity over data + check bits.
pub fn encode_word(data: u64) -> u8 {
    // per-parity-bit data masks, derived once from the Hamming indices
    static MASKS: std::sync::OnceLock<[u64; 7]> = std::sync::OnceLock::new();
    let masks = MASKS.get_or_init(|| {
        let mut m = [0u64; 7];
        for (p, mask) in m.iter_mut().enumerate() {
            for d in 0..64u32 {
                if hamming_index(d) & (1 << p) != 0 {
                    *mask |= 1u64 << d;
                }
            }
        }
        m
    });
    let mut check = 0u8;
    for (p, mask) in masks.iter().enumerate() {
        check |= (((data & mask).count_ones() & 1) as u8) << p;
    }
    // overall parity over the data bits (the check-bit sidecar itself is
    // modelled as incorruptible — it lives in the module-level ECC store)
    let overall = data.count_ones() & 1;
    check | ((overall as u8) << 7)
}

/// Hamming code position of data bit `d` (skipping power-of-two slots,
/// 1-based).
fn hamming_index(d: u32) -> u32 {
    // the (d+1)-th position that is not a power of two, starting from 3
    let mut pos = 0u32;
    let mut seen = 0u32;
    for candidate in 3.. {
        if (candidate & (candidate - 1)) != 0 {
            // not a power of two
            if seen == d {
                pos = candidate;
                break;
            }
            seen += 1;
        }
    }
    pos
}

/// Decode result of one word.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Decode {
    Clean(u64),
    Corrected { data: u64, bit: u32 },
    /// double-bit (or worse) error — uncorrectable
    Detected,
}

/// Verify/correct one word against its stored check bits.
pub fn decode_word(data: u64, stored_check: u8) -> Decode {
    let fresh = encode_word(data);
    let syndrome = (fresh ^ stored_check) & 0x7F;
    let overall_mismatch = ((fresh ^ stored_check) >> 7) & 1 == 1;
    match (syndrome, overall_mismatch) {
        (0, false) => Decode::Clean(data),
        // parity disagrees but the Hamming syndrome is clean → ≥3 bits
        (0, true) => Decode::Detected,
        // syndrome without a parity flip → an even (≥2) number of flips
        (_, false) => Decode::Detected,
        (s, true) => {
            // single data-bit error at Hamming position s
            for d in 0..64u32 {
                if hamming_index(d) == s as u32 {
                    let fixed = data ^ (1u64 << d);
                    // consistency: fixed word must re-encode cleanly
                    if encode_word(fixed) == stored_check {
                        return Decode::Corrected { data: fixed, bit: d };
                    }
                }
            }
            // no data position carries this syndrome → multi-bit damage
            Decode::Detected
        }
    }
}

/// ECC sidecar for a full row: one check byte per 64-bit word.
#[derive(Clone, Debug, PartialEq)]
pub struct RowEcc {
    pub check: Vec<u8>,
}

impl RowEcc {
    pub fn encode(row: &BitRow) -> Self {
        RowEcc {
            check: row.words().iter().map(|&w| encode_word(w)).collect(),
        }
    }

    /// Verify a row; corrects single-bit upsets in place. Returns the
    /// number of corrected bits, or Err on an uncorrectable word.
    pub fn verify_and_correct(&self, row: &mut BitRow) -> Result<usize, usize> {
        let mut corrected = 0;
        for (i, c) in self.check.iter().enumerate() {
            match decode_word(row.words()[i], *c) {
                Decode::Clean(_) => {}
                Decode::Corrected { data, .. } => {
                    row.words_mut()[i] = data;
                    corrected += 1;
                }
                Decode::Detected => return Err(i),
            }
        }
        Ok(corrected)
    }

    /// Storage overhead relative to the protected data.
    pub fn overhead() -> f64 {
        CHECK_BITS_PER_WORD as f64 / 64.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn clean_words_decode_clean() {
        let mut rng = Rng::new(1);
        for _ in 0..200 {
            let w = rng.next_u64();
            assert_eq!(decode_word(w, encode_word(w)), Decode::Clean(w));
        }
    }

    #[test]
    fn every_single_bit_flip_is_corrected() {
        let mut rng = Rng::new(2);
        for _ in 0..20 {
            let w = rng.next_u64();
            let check = encode_word(w);
            for b in 0..64 {
                let corrupted = w ^ (1u64 << b);
                match decode_word(corrupted, check) {
                    Decode::Corrected { data, bit } => {
                        assert_eq!(data, w);
                        assert_eq!(bit, b);
                    }
                    other => panic!("bit {b}: {other:?}"),
                }
            }
        }
    }

    #[test]
    fn double_bit_flips_are_detected_not_miscorrected() {
        prop::check("secded_double", 200, |rng| {
            let w = rng.next_u64();
            let check = encode_word(w);
            let b1 = rng.below(64) as u64;
            let mut b2 = rng.below(64) as u64;
            if b1 == b2 {
                b2 = (b2 + 1) % 64;
            }
            let corrupted = w ^ (1 << b1) ^ (1 << b2);
            match decode_word(corrupted, check) {
                Decode::Detected => Ok(()),
                Decode::Corrected { data, .. } if data == w => {
                    Err("double error silently mis-corrected to original?".into())
                }
                Decode::Corrected { .. } => {
                    Err(format!("double error {b1},{b2} mis-corrected"))
                }
                Decode::Clean(_) => Err(format!("double error {b1},{b2} missed")),
            }
        });
    }

    #[test]
    fn row_level_roundtrip_with_upsets() {
        let mut rng = Rng::new(3);
        let row = BitRow::random(8192, &mut rng);
        let ecc = RowEcc::encode(&row);
        let mut clean = row.clone();
        assert_eq!(ecc.verify_and_correct(&mut clean), Ok(0));
        // flip one bit in each of 5 different words
        let mut hit = row.clone();
        for w in [0usize, 17, 63, 100, 127] {
            hit.words_mut()[w] ^= 1 << (w % 64);
        }
        assert_eq!(ecc.verify_and_correct(&mut hit), Ok(5));
        assert_eq!(hit, row);
    }

    #[test]
    fn overhead_is_12_5_percent() {
        assert!((RowEcc::overhead() - 0.125).abs() < 1e-12);
    }
}
