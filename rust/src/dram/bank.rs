//! A DRAM bank: a set of computational sub-arrays sharing the global row
//! buffer and bank-level command sequencing.
//!
//! Sub-arrays within a bank can compute *concurrently* (sub-array-level
//! parallelism, limited by `DramGeometry::active_subarrays`) because each
//! has its own local SA row; the bank serializes only the command issue,
//! which is pipelined and not the bottleneck (RowClone/Ambit convention).

use crate::isa::program::{CTRL_ONES, CTRL_ZEROS};
use crate::subarray::SubArray;
use crate::util::bitrow::BitRow;

use super::geometry::DramGeometry;

#[derive(Clone, Debug)]
pub struct Bank {
    pub subarrays: Vec<SubArray>,
}

impl Bank {
    /// Build a bank with preset control rows (zeros/ones) in every
    /// sub-array — done once at power-up, RowClone-refreshed thereafter.
    pub fn new(g: &DramGeometry) -> Self {
        let mut subarrays = Vec::with_capacity(g.subarrays_per_bank);
        for _ in 0..g.subarrays_per_bank {
            let mut sa = SubArray::new(g.cols);
            sa.write_row(CTRL_ZEROS, &BitRow::zeros(g.cols));
            sa.write_row(CTRL_ONES, &BitRow::ones(g.cols));
            subarrays.push(sa);
        }
        Bank { subarrays }
    }

    pub fn subarray(&self, i: usize) -> &SubArray {
        &self.subarrays[i]
    }

    pub fn subarray_mut(&mut self, i: usize) -> &mut SubArray {
        &mut self.subarrays[i]
    }

    /// Total AAPs executed across all sub-arrays (stats).
    pub fn aap_count(&self) -> u64 {
        self.subarrays.iter().map(|s| s.aap_count).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dram::command::RowId;

    #[test]
    fn control_rows_preset() {
        let g = DramGeometry::tiny();
        let b = Bank::new(&g);
        for sa in &b.subarrays {
            assert_eq!(sa.read_row(CTRL_ZEROS).popcount(), 0);
            assert_eq!(sa.read_row(CTRL_ONES).popcount(), g.cols);
        }
    }

    #[test]
    fn subarray_count_matches_geometry() {
        let g = DramGeometry::tiny();
        let b = Bank::new(&g);
        assert_eq!(b.subarrays.len(), g.subarrays_per_bank);
        assert_eq!(b.subarray(0).cols(), g.cols);
    }

    #[test]
    fn data_rows_start_zeroed() {
        let g = DramGeometry::tiny();
        let b = Bank::new(&g);
        assert_eq!(b.subarray(0).read_row(RowId::Data(0)).popcount(), 0);
    }
}
