//! DRAM substrate: geometry, timing, commands and banks.
//!
//! This is the memory system everything else is built on — the functional
//! *and* timing model of a DDR4-class device extended with DRIM's
//! computational sub-arrays (paper Fig. 3). The paper evaluates on "8 banks
//! with 512×256 computational sub-arrays"; geometry is configurable and the
//! defaults (8 banks × 64 sub-arrays × 512 rows × 8192 bit-lines) follow
//! the Ambit/DRISA evaluation convention of an 8 Kb row.

pub mod bank;
pub mod command;
pub mod ecc;
pub mod geometry;
pub mod timing;

pub use bank::Bank;
pub use command::{AapKind, DramCommand, RowId};
pub use geometry::{DramGeometry, PhysAddr};
pub use timing::{MovementTier, TimingParams, MOVEMENT_TIERS};
