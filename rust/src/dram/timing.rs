//! DDR4-class timing parameters and derived command latencies.
//!
//! All latencies in nanoseconds. Values follow the DDR4-2133 speed grade the
//! paper's CPU baseline uses (and the RowClone/Ambit evaluation convention):
//! tRCD ≈ 14 ns, tRAS ≈ 33 ns, tRP ≈ 14 ns, and the RowClone-FPM figure of
//! ~90 ns for a full AAP (two back-to-back ACTIVATEs + PRECHARGE) [17].
//!
//! The paper's own calibration points:
//!   * "This operation takes only 90ns" — RowClone-FPM copy (one AAP).
//!   * "TRA method needs averagely 360ns" for a 4-AAP AND2/OR2 → 4 × 90 ns.

/// Bits moved per DDR burst: a 64-byte transfer (8 beats over the x64
/// interface), the granularity every off-chip or inter-device copy is
/// streamed in.
pub const BURST_BITS: u64 = 512;

/// Endpoint tier of a bulk data movement inside the fleet, ordered from
/// cheapest to most expensive. The intra-device tiers model the
/// RowClone/Ambit in-DRAM copy primitives: when source and destination rows
/// share a sub-array the copy is a single AAP (FPM, ~90 ns per row) and
/// never touches the data bus; crossing a bank or the chip boundary adds
/// activations and (for `SameDevice`) half-rate internal streaming, but the
/// external DDR bus stays free. Only `CrossDevice` pays bus occupancy.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum MovementTier {
    /// Source and destination rows share a sub-array: RowClone-FPM, one AAP
    /// per row.
    SameSubarray,
    /// Same bank, different sub-array: two AAPs per row through the bank's
    /// shared sense amplifiers (RowClone-PSM within the bank).
    SameBank,
    /// Same device, different bank: two AAPs per row plus a half-rate hop
    /// over the chip's internal global bus.
    SameDevice,
    /// Different devices: the full external DDR burst stream (the only tier
    /// that occupies channel bus cycles).
    CrossDevice,
}

/// All movement tiers, cheapest first — the iteration order metrics and
/// JSON reports use.
pub const MOVEMENT_TIERS: [MovementTier; 4] = [
    MovementTier::SameSubarray,
    MovementTier::SameBank,
    MovementTier::SameDevice,
    MovementTier::CrossDevice,
];

impl MovementTier {
    /// Stable lowercase label used in JSON reports and traces.
    pub fn name(self) -> &'static str {
        match self {
            MovementTier::SameSubarray => "same_subarray",
            MovementTier::SameBank => "same_bank",
            MovementTier::SameDevice => "same_device",
            MovementTier::CrossDevice => "cross_device",
        }
    }

    /// Dense index into per-tier counter arrays (`MOVEMENT_TIERS` order).
    pub fn index(self) -> usize {
        self as usize
    }

    /// Whether the tier is priced by the in-DRAM copy primitives (no
    /// external bus occupancy).
    pub fn is_in_dram(self) -> bool {
        self != MovementTier::CrossDevice
    }
}

#[derive(Clone, Debug, PartialEq)]
pub struct TimingParams {
    pub t_rcd_ns: f64,
    pub t_ras_ns: f64,
    pub t_rp_ns: f64,
    /// one full ACTIVATE→ACTIVATE→PRECHARGE primitive
    pub t_aap_ns: f64,
    /// single ACTIVATE→PRECHARGE (used by DRISA-1T1C latch cycles)
    pub t_ap_ns: f64,
    /// column read/write burst (64 B over the DDR interface)
    pub t_burst_ns: f64,
    /// DDR command-clock period (DDR4-2133: 1066 MHz → one 8-beat burst
    /// occupies exactly 4 clocks = `t_burst_ns`)
    pub t_ck_ns: f64,
}

impl Default for TimingParams {
    fn default() -> Self {
        TimingParams {
            t_rcd_ns: 14.16,
            t_ras_ns: 33.0,
            t_rp_ns: 14.16,
            t_aap_ns: 90.0,
            t_ap_ns: 47.16, // tRAS + tRP
            t_burst_ns: 3.75, // 8 beats @ DDR4-2133
            t_ck_ns: 0.9375, // 1066 MHz command clock
        }
    }
}

impl TimingParams {
    /// Latency of an n-AAP command sequence.
    pub fn seq_ns(&self, aaps: usize) -> f64 {
        self.t_aap_ns * aaps as f64
    }

    /// Number of DDR bursts needed to move `bits` (64 B granularity).
    pub fn bursts(bits: u64) -> u64 {
        bits.div_ceil(BURST_BITS)
    }

    /// Time to stream `bits` over one channel's data bus, back-to-back
    /// bursts (the cluster's inter-device copy-cost model builds on this).
    pub fn stream_ns(&self, bits: u64) -> f64 {
        Self::bursts(bits) as f64 * self.t_burst_ns
    }

    /// Bus clock cycles occupied by streaming `bits` (the unit the fleet
    /// metrics report copy traffic in).
    pub fn stream_cycles(&self, bits: u64) -> u64 {
        self.cycles_for_ns(self.stream_ns(bits))
    }

    /// Convert a bus-time duration to whole command-clock cycles.
    pub fn cycles_for_ns(&self, ns: f64) -> u64 {
        (ns / self.t_ck_ns).round() as u64
    }

    /// Rows a `bits`-sized region spans at `row_bits` bits per DRAM row.
    pub fn rows(bits: u64, row_bits: u64) -> u64 {
        bits.div_ceil(row_bits.max(1))
    }

    /// RowClone-FPM copy: source and destination share a sub-array, one AAP
    /// per row, zero bus occupancy.
    pub fn subarray_copy_ns(&self, bits: u64, row_bits: u64) -> f64 {
        Self::rows(bits, row_bits) as f64 * self.t_aap_ns
    }

    /// Same-bank, cross-sub-array copy: two AAPs per row (copy to the bank's
    /// sense amplifiers, then to the destination row), zero bus occupancy.
    pub fn bank_copy_ns(&self, bits: u64, row_bits: u64) -> f64 {
        Self::rows(bits, row_bits) as f64 * 2.0 * self.t_aap_ns
    }

    /// Same-device, cross-bank copy: two AAPs per row plus a half-rate hop
    /// over the chip's internal global bus; the external channel stays idle.
    pub fn device_copy_ns(&self, bits: u64, row_bits: u64) -> f64 {
        self.bank_copy_ns(bits, row_bits) + self.stream_ns(bits) / 2.0
    }

    /// Price a movement by its endpoint tier. Intra-device tiers come from
    /// the RowClone primitives above and occupy zero channel bus cycles;
    /// `CrossDevice` is the full external stream (ns and bus cycles).
    /// Returns `(ns, bus_cycles)`.
    pub fn tier_copy(&self, tier: MovementTier, bits: u64, row_bits: u64) -> (f64, u64) {
        match tier {
            MovementTier::SameSubarray => (self.subarray_copy_ns(bits, row_bits), 0),
            MovementTier::SameBank => (self.bank_copy_ns(bits, row_bits), 0),
            MovementTier::SameDevice => (self.device_copy_ns(bits, row_bits), 0),
            MovementTier::CrossDevice => (self.stream_ns(bits), self.stream_cycles(bits)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_calibration_points() {
        let t = TimingParams::default();
        // RowClone-FPM copy = 1 AAP ≈ 90 ns (paper §2.1)
        assert_eq!(t.seq_ns(1), 90.0);
        // TRA-based AND2/OR2 = 4 AAPs ≈ 360 ns (paper §2.2 Challenge-2)
        assert_eq!(t.seq_ns(4), 360.0);
    }

    #[test]
    fn ap_is_ras_plus_rp() {
        let t = TimingParams::default();
        assert!((t.t_ap_ns - (t.t_ras_ns + t.t_rp_ns)).abs() < 1e-9);
    }

    #[test]
    fn burst_is_four_clocks() {
        let t = TimingParams::default();
        assert!((t.t_burst_ns - 4.0 * t.t_ck_ns).abs() < 1e-9);
    }

    #[test]
    fn stream_rounds_up_to_whole_bursts() {
        let t = TimingParams::default();
        assert_eq!(TimingParams::bursts(0), 0);
        assert_eq!(TimingParams::bursts(1), 1);
        assert_eq!(TimingParams::bursts(512), 1);
        assert_eq!(TimingParams::bursts(513), 2);
        // 2048 bits = 4 bursts = 15 ns = 16 clocks
        assert!((t.stream_ns(2048) - 15.0).abs() < 1e-9);
        assert_eq!(t.stream_cycles(2048), 16);
        assert_eq!(t.stream_cycles(0), 0);
    }

    #[test]
    fn rowclone_fpm_copy_is_one_aap_per_row() {
        let t = TimingParams::default();
        // One full 65536-bit (8 KiB) row copies in a single AAP ≈ 90 ns —
        // the RowClone-FPM calibration point.
        assert_eq!(t.subarray_copy_ns(65_536, 65_536), 90.0);
        assert_eq!(t.subarray_copy_ns(3 * 65_536, 65_536), 270.0);
        // Partial rows round up to whole-row activations.
        assert_eq!(TimingParams::rows(1, 65_536), 1);
        assert_eq!(TimingParams::rows(65_537, 65_536), 2);
    }

    #[test]
    fn movement_tiers_are_ns_monotone_for_full_rows() {
        let t = TimingParams::default();
        let (bits, row) = (65_536, 65_536);
        let sub = t.tier_copy(MovementTier::SameSubarray, bits, row).0;
        let bank = t.tier_copy(MovementTier::SameBank, bits, row).0;
        let dev = t.tier_copy(MovementTier::SameDevice, bits, row).0;
        let cross = t.tier_copy(MovementTier::CrossDevice, bits, row).0;
        assert!(sub < bank, "{sub} !< {bank}");
        assert!(bank < dev, "{bank} !< {dev}");
        assert!(dev < cross, "{dev} !< {cross}");
    }

    #[test]
    fn intra_device_tiers_never_occupy_the_bus() {
        let t = TimingParams::default();
        for tier in [
            MovementTier::SameSubarray,
            MovementTier::SameBank,
            MovementTier::SameDevice,
        ] {
            assert!(tier.is_in_dram());
            assert_eq!(t.tier_copy(tier, 65_536, 8192).1, 0, "{tier:?}");
        }
        assert!(!MovementTier::CrossDevice.is_in_dram());
        assert!(t.tier_copy(MovementTier::CrossDevice, 65_536, 8192).1 > 0);
    }

    #[test]
    fn tier_labels_and_indices_are_stable() {
        let names: Vec<&str> = MOVEMENT_TIERS.iter().map(|t| t.name()).collect();
        assert_eq!(
            names,
            ["same_subarray", "same_bank", "same_device", "cross_device"]
        );
        for (i, tier) in MOVEMENT_TIERS.iter().enumerate() {
            assert_eq!(tier.index(), i);
        }
    }
}
