//! DDR4-class timing parameters and derived command latencies.
//!
//! All latencies in nanoseconds. Values follow the DDR4-2133 speed grade the
//! paper's CPU baseline uses (and the RowClone/Ambit evaluation convention):
//! tRCD ≈ 14 ns, tRAS ≈ 33 ns, tRP ≈ 14 ns, and the RowClone-FPM figure of
//! ~90 ns for a full AAP (two back-to-back ACTIVATEs + PRECHARGE) [17].
//!
//! The paper's own calibration points:
//!   * "This operation takes only 90ns" — RowClone-FPM copy (one AAP).
//!   * "TRA method needs averagely 360ns" for a 4-AAP AND2/OR2 → 4 × 90 ns.

#[derive(Clone, Debug, PartialEq)]
pub struct TimingParams {
    pub t_rcd_ns: f64,
    pub t_ras_ns: f64,
    pub t_rp_ns: f64,
    /// one full ACTIVATE→ACTIVATE→PRECHARGE primitive
    pub t_aap_ns: f64,
    /// single ACTIVATE→PRECHARGE (used by DRISA-1T1C latch cycles)
    pub t_ap_ns: f64,
    /// column read/write burst (64 B over the DDR interface)
    pub t_burst_ns: f64,
}

impl Default for TimingParams {
    fn default() -> Self {
        TimingParams {
            t_rcd_ns: 14.16,
            t_ras_ns: 33.0,
            t_rp_ns: 14.16,
            t_aap_ns: 90.0,
            t_ap_ns: 47.16, // tRAS + tRP
            t_burst_ns: 3.75, // 8 beats @ DDR4-2133
        }
    }
}

impl TimingParams {
    /// Latency of an n-AAP command sequence.
    pub fn seq_ns(&self, aaps: usize) -> f64 {
        self.t_aap_ns * aaps as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_calibration_points() {
        let t = TimingParams::default();
        // RowClone-FPM copy = 1 AAP ≈ 90 ns (paper §2.1)
        assert_eq!(t.seq_ns(1), 90.0);
        // TRA-based AND2/OR2 = 4 AAPs ≈ 360 ns (paper §2.2 Challenge-2)
        assert_eq!(t.seq_ns(4), 360.0);
    }

    #[test]
    fn ap_is_ras_plus_rp() {
        let t = TimingParams::default();
        assert!((t.t_ap_ns - (t.t_ras_ns + t.t_rp_ns)).abs() < 1e-9);
    }
}
