//! DDR4-class timing parameters and derived command latencies.
//!
//! All latencies in nanoseconds. Values follow the DDR4-2133 speed grade the
//! paper's CPU baseline uses (and the RowClone/Ambit evaluation convention):
//! tRCD ≈ 14 ns, tRAS ≈ 33 ns, tRP ≈ 14 ns, and the RowClone-FPM figure of
//! ~90 ns for a full AAP (two back-to-back ACTIVATEs + PRECHARGE) [17].
//!
//! The paper's own calibration points:
//!   * "This operation takes only 90ns" — RowClone-FPM copy (one AAP).
//!   * "TRA method needs averagely 360ns" for a 4-AAP AND2/OR2 → 4 × 90 ns.

/// Bits moved per DDR burst: a 64-byte transfer (8 beats over the x64
/// interface), the granularity every off-chip or inter-device copy is
/// streamed in.
pub const BURST_BITS: u64 = 512;

#[derive(Clone, Debug, PartialEq)]
pub struct TimingParams {
    pub t_rcd_ns: f64,
    pub t_ras_ns: f64,
    pub t_rp_ns: f64,
    /// one full ACTIVATE→ACTIVATE→PRECHARGE primitive
    pub t_aap_ns: f64,
    /// single ACTIVATE→PRECHARGE (used by DRISA-1T1C latch cycles)
    pub t_ap_ns: f64,
    /// column read/write burst (64 B over the DDR interface)
    pub t_burst_ns: f64,
    /// DDR command-clock period (DDR4-2133: 1066 MHz → one 8-beat burst
    /// occupies exactly 4 clocks = `t_burst_ns`)
    pub t_ck_ns: f64,
}

impl Default for TimingParams {
    fn default() -> Self {
        TimingParams {
            t_rcd_ns: 14.16,
            t_ras_ns: 33.0,
            t_rp_ns: 14.16,
            t_aap_ns: 90.0,
            t_ap_ns: 47.16, // tRAS + tRP
            t_burst_ns: 3.75, // 8 beats @ DDR4-2133
            t_ck_ns: 0.9375, // 1066 MHz command clock
        }
    }
}

impl TimingParams {
    /// Latency of an n-AAP command sequence.
    pub fn seq_ns(&self, aaps: usize) -> f64 {
        self.t_aap_ns * aaps as f64
    }

    /// Number of DDR bursts needed to move `bits` (64 B granularity).
    pub fn bursts(bits: u64) -> u64 {
        bits.div_ceil(BURST_BITS)
    }

    /// Time to stream `bits` over one channel's data bus, back-to-back
    /// bursts (the cluster's inter-device copy-cost model builds on this).
    pub fn stream_ns(&self, bits: u64) -> f64 {
        Self::bursts(bits) as f64 * self.t_burst_ns
    }

    /// Bus clock cycles occupied by streaming `bits` (the unit the fleet
    /// metrics report copy traffic in).
    pub fn stream_cycles(&self, bits: u64) -> u64 {
        self.cycles_for_ns(self.stream_ns(bits))
    }

    /// Convert a bus-time duration to whole command-clock cycles.
    pub fn cycles_for_ns(&self, ns: f64) -> u64 {
        (ns / self.t_ck_ns).round() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_calibration_points() {
        let t = TimingParams::default();
        // RowClone-FPM copy = 1 AAP ≈ 90 ns (paper §2.1)
        assert_eq!(t.seq_ns(1), 90.0);
        // TRA-based AND2/OR2 = 4 AAPs ≈ 360 ns (paper §2.2 Challenge-2)
        assert_eq!(t.seq_ns(4), 360.0);
    }

    #[test]
    fn ap_is_ras_plus_rp() {
        let t = TimingParams::default();
        assert!((t.t_ap_ns - (t.t_ras_ns + t.t_rp_ns)).abs() < 1e-9);
    }

    #[test]
    fn burst_is_four_clocks() {
        let t = TimingParams::default();
        assert!((t.t_burst_ns - 4.0 * t.t_ck_ns).abs() < 1e-9);
    }

    #[test]
    fn stream_rounds_up_to_whole_bursts() {
        let t = TimingParams::default();
        assert_eq!(TimingParams::bursts(0), 0);
        assert_eq!(TimingParams::bursts(1), 1);
        assert_eq!(TimingParams::bursts(512), 1);
        assert_eq!(TimingParams::bursts(513), 2);
        // 2048 bits = 4 bursts = 15 ns = 16 clocks
        assert!((t.stream_ns(2048) - 15.0).abs() < 1e-9);
        assert_eq!(t.stream_cycles(2048), 16);
        assert_eq!(t.stream_cycles(0), 0);
    }
}
