//! DRAM organization & physical address mapping (paper Fig. 3).

/// Row-space split of a computational sub-array (paper §3: "Data rows (500
/// rows out of 512) ... and Computation rows (12)").
pub const SUBARRAY_ROWS: usize = 512;
pub const DATA_ROWS: usize = 500;
pub const NUM_X_ROWS: usize = 8; // x1..x8, typical cells on the MRD
pub const NUM_DCC_WLS: usize = 4; // dcc1..dcc4 word-lines (2 DCC cells × 2 WLs)

/// Geometry of one DRIM device (chip-level view; chips in a rank operate in
/// lock-step, so the simulator models one chip with rank-wide rows).
#[derive(Clone, Debug, PartialEq)]
pub struct DramGeometry {
    pub banks: usize,
    pub subarrays_per_bank: usize,
    /// bit-lines per sub-array row (= bits moved by one AAP per sub-array)
    pub cols: usize,
    /// sub-arrays per bank that may compute simultaneously (power budget —
    /// Ambit-style sub-array-level parallelism; see platforms/drim.rs)
    pub active_subarrays: usize,
}

impl Default for DramGeometry {
    fn default() -> Self {
        DramGeometry {
            banks: 8,
            subarrays_per_bank: 64,
            cols: 8192,
            active_subarrays: 32,
        }
    }
}

impl DramGeometry {
    /// Small geometry for unit tests (fast to simulate exhaustively).
    pub fn tiny() -> Self {
        DramGeometry {
            banks: 2,
            subarrays_per_bank: 2,
            cols: 256,
            active_subarrays: 2,
        }
    }

    /// 3D-stacked DRIM-S organization (HMC-2.0-like: 4 GB, 256 banks;
    /// paper §3.4 "DRIM-S").
    pub fn stacked() -> Self {
        DramGeometry {
            banks: 256,
            subarrays_per_bank: 32,
            cols: 8192,
            // tighter per-bank power budget in the stack: 2 computing
            // sub-arrays per bank (×256 banks still = 2× DRIM-R's wave)
            active_subarrays: 2,
        }
    }

    pub fn data_bits_per_bank(&self) -> usize {
        self.subarrays_per_bank * DATA_ROWS * self.cols
    }

    pub fn data_bits_total(&self) -> usize {
        self.banks * self.data_bits_per_bank()
    }

    /// Bits processed by one array-wide computational step (all banks ×
    /// active sub-arrays × one row).
    pub fn compute_width_bits(&self) -> usize {
        self.banks * self.active_subarrays * self.cols
    }
}

/// Residency capacity of one device: how many operand bits the cluster's
/// residency layer may keep resident on it.
///
/// Derived from the device's data space ([`DramGeometry::data_bits_total`],
/// i.e. banks × [`DramGeometry::data_bits_per_bank`]) minus a configurable
/// fraction reserved for staging/wave rows — operands mid-flight through
/// the X(N)OR pipeline are written into rows the residency layer must not
/// hand out.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DeviceCapacity {
    /// resident operand bits the device may hold (`u64::MAX` = unbounded)
    pub resident_bits: u64,
}

impl DeviceCapacity {
    /// No enforcement (the pre-capacity behaviour; standalone registries).
    pub fn unbounded() -> Self {
        DeviceCapacity {
            resident_bits: u64::MAX,
        }
    }

    /// Explicit bit budget (tests and capacity ablations).
    pub fn of_bits(bits: u64) -> Self {
        DeviceCapacity {
            resident_bits: bits,
        }
    }

    /// Derive from a geometry, reserving `staging_fraction` ∈ [0, 1) of
    /// the data space for staging/wave rows.
    pub fn from_geometry(g: &DramGeometry, staging_fraction: f64) -> Self {
        assert!(
            (0.0..1.0).contains(&staging_fraction),
            "staging fraction must be in [0, 1), got {staging_fraction}"
        );
        let usable = g.data_bits_total() as f64 * (1.0 - staging_fraction);
        DeviceCapacity {
            resident_bits: usable as u64,
        }
    }

    /// True when no bound is enforced.
    pub fn is_unbounded(&self) -> bool {
        self.resident_bits == u64::MAX
    }
}

/// Physical location of a data row.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct PhysAddr {
    pub bank: usize,
    pub subarray: usize,
    pub row: usize,
}

impl PhysAddr {
    /// Flat index over data rows: bank-major, then sub-array, then row.
    /// Bijective with `from_flat` (property-tested).
    pub fn to_flat(self, g: &DramGeometry) -> usize {
        debug_assert!(self.bank < g.banks);
        debug_assert!(self.subarray < g.subarrays_per_bank);
        debug_assert!(self.row < DATA_ROWS);
        (self.bank * g.subarrays_per_bank + self.subarray) * DATA_ROWS + self.row
    }

    pub fn from_flat(g: &DramGeometry, flat: usize) -> Self {
        let row = flat % DATA_ROWS;
        let sa = (flat / DATA_ROWS) % g.subarrays_per_bank;
        let bank = flat / (DATA_ROWS * g.subarrays_per_bank);
        debug_assert!(bank < g.banks, "flat index out of range");
        PhysAddr {
            bank,
            subarray: sa,
            row,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn defaults_match_paper_scale() {
        let g = DramGeometry::default();
        assert_eq!(g.banks, 8); // paper: "implemented with 8 banks"
        assert_eq!(SUBARRAY_ROWS, 512);
        assert_eq!(DATA_ROWS, 500);
        assert_eq!(NUM_X_ROWS + NUM_DCC_WLS, 12); // "Computation rows (12)"
    }

    #[test]
    fn stacked_is_hmc_like() {
        let g = DramGeometry::stacked();
        assert_eq!(g.banks, 256);
        // ≈ 4 GB of data space (paper: "256 banks in 4GB capacity")
        let bytes = g.data_bits_total() / 8;
        assert!(bytes > 3 << 30 && bytes <= 5 << 30, "{bytes}");
    }

    #[test]
    fn flat_mapping_bijective() {
        let g = DramGeometry::tiny();
        prop::check("addr_bijective", 200, |rng| {
            let a = PhysAddr {
                bank: rng.below(g.banks as u64) as usize,
                subarray: rng.below(g.subarrays_per_bank as u64) as usize,
                row: rng.below(DATA_ROWS as u64) as usize,
            };
            let back = PhysAddr::from_flat(&g, a.to_flat(&g));
            if back == a {
                Ok(())
            } else {
                Err(format!("{a:?} -> {back:?}"))
            }
        });
    }

    #[test]
    fn flat_mapping_dense() {
        let g = DramGeometry::tiny();
        let total = g.banks * g.subarrays_per_bank * DATA_ROWS;
        let mut seen = vec![false; total];
        for b in 0..g.banks {
            for s in 0..g.subarrays_per_bank {
                for r in 0..DATA_ROWS {
                    let f = PhysAddr {
                        bank: b,
                        subarray: s,
                        row: r,
                    }
                    .to_flat(&g);
                    assert!(!seen[f]);
                    seen[f] = true;
                }
            }
        }
        assert!(seen.iter().all(|&x| x));
    }

    #[test]
    fn compute_width() {
        let g = DramGeometry::default();
        assert_eq!(g.compute_width_bits(), 8 * 32 * 8192);
    }

    #[test]
    fn device_capacity_reserves_staging_fraction() {
        let g = DramGeometry::tiny();
        let total = g.data_bits_total() as u64;
        let full = DeviceCapacity::from_geometry(&g, 0.0);
        assert_eq!(full.resident_bits, total);
        assert!(!full.is_unbounded());
        let quarter_reserved = DeviceCapacity::from_geometry(&g, 0.25);
        assert_eq!(quarter_reserved.resident_bits, total * 3 / 4);
        assert!(DeviceCapacity::unbounded().is_unbounded());
        assert_eq!(DeviceCapacity::of_bits(512).resident_bits, 512);
    }

    #[test]
    #[should_panic(expected = "staging fraction")]
    fn device_capacity_rejects_full_reservation() {
        DeviceCapacity::from_geometry(&DramGeometry::tiny(), 1.0);
    }
}
