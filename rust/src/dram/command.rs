//! DRAM command vocabulary, row identifiers, and the AAP primitive kinds.

use std::fmt;

use super::geometry::{DATA_ROWS, NUM_DCC_WLS, NUM_X_ROWS, SUBARRAY_ROWS};

/// A word-line within one sub-array's row space (paper Fig. 3).
///
/// * `Data(r)` — one of the 500 regular data rows (regular row decoder).
/// * `X(i)`    — computation row x1..x8 (modified row decoder, may be
///               co-activated with other computation rows).
/// * `Dcc(i)`  — one of the 4 dual-contact-cell *word-lines* dcc1..dcc4.
///               dcc1/dcc2 are the normal/complement word-lines of DCC cell
///               A; dcc3/dcc4 of DCC cell B. Activating the complement
///               word-line reads/writes the cell through BL̄, i.e. inverted.
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug, PartialOrd, Ord)]
pub enum RowId {
    Data(u16),
    X(u8),
    Dcc(u8),
}

impl RowId {
    /// Word-line index in the physical row space 0..512.
    pub fn wordline(self) -> usize {
        match self {
            RowId::Data(r) => {
                assert!((r as usize) < DATA_ROWS, "data row {r} out of range");
                r as usize
            }
            RowId::X(i) => {
                assert!((1..=NUM_X_ROWS as u8).contains(&i), "x{i} out of range");
                DATA_ROWS + (i as usize - 1)
            }
            RowId::Dcc(i) => {
                assert!((1..=NUM_DCC_WLS as u8).contains(&i), "dcc{i} out of range");
                DATA_ROWS + NUM_X_ROWS + (i as usize - 1)
            }
        }
    }

    /// Rows reachable by the Modified Row Decoder (multi-activation capable).
    pub fn is_compute(self) -> bool {
        !matches!(self, RowId::Data(_))
    }

    /// For DCC word-lines: (cell index 0/1, through-complement?).
    pub fn dcc_cell(self) -> Option<(usize, bool)> {
        match self {
            RowId::Dcc(i) => Some((((i - 1) / 2) as usize, (i - 1) % 2 == 1)),
            _ => None,
        }
    }

    pub fn parse(s: &str) -> Option<RowId> {
        if let Some(n) = s.strip_prefix('x') {
            return n.parse().ok().map(RowId::X);
        }
        if let Some(n) = s.strip_prefix("dcc") {
            return n.parse().ok().map(RowId::Dcc);
        }
        if let Some(n) = s.strip_prefix('d') {
            return n.parse().ok().map(RowId::Data);
        }
        None
    }

    pub fn total_wordlines() -> usize {
        SUBARRAY_ROWS
    }
}

impl fmt::Display for RowId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RowId::Data(r) => write!(f, "d{r}"),
            RowId::X(i) => write!(f, "x{i}"),
            RowId::Dcc(i) => write!(f, "dcc{i}"),
        }
    }
}

/// The four AAP instruction types of DRIM's ISA (paper §3.2), as bare DRAM
/// command micro-ops. `size` is carried at the `isa::Program` level.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum AapKind {
    /// AAP(src, des): copy / NOT (through DCC word-lines)
    Copy,
    /// AAP(src, des1, des2): double-copy
    DoubleCopy,
    /// AAP(src1, src2, des): Dual-Row Activation — X(N)OR2
    Dra,
    /// AAP(src1, src2, src3, des): Triple-Row Activation — MAJ3
    Tra,
}

impl AapKind {
    /// ACTIVATE count of the primitive (for the energy model): activations
    /// happen in two phases — source activation (1, 2 or 3 word-lines) and
    /// destination activation (1 or 2 word-lines) — followed by PRECHARGE.
    pub fn source_rows(self) -> usize {
        match self {
            AapKind::Copy | AapKind::DoubleCopy => 1,
            AapKind::Dra => 2,
            AapKind::Tra => 3,
        }
    }

    pub fn dest_rows(self) -> usize {
        match self {
            AapKind::DoubleCopy => 2,
            _ => 1,
        }
    }
}

/// Raw command stream element (what the memory controller actually issues).
#[derive(Clone, Debug, PartialEq)]
pub enum DramCommand {
    /// simultaneous activation of 1..=3 word-lines (MRD handles >1)
    Activate(Vec<RowId>),
    Precharge,
    /// column read/write of one 64-byte burst (addressing elided)
    ReadBurst,
    WriteBurst,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wordline_layout_is_dense_and_disjoint() {
        let mut seen = vec![false; RowId::total_wordlines()];
        for r in 0..DATA_ROWS as u16 {
            let w = RowId::Data(r).wordline();
            assert!(!seen[w]);
            seen[w] = true;
        }
        for i in 1..=NUM_X_ROWS as u8 {
            let w = RowId::X(i).wordline();
            assert!(!seen[w]);
            seen[w] = true;
        }
        for i in 1..=NUM_DCC_WLS as u8 {
            let w = RowId::Dcc(i).wordline();
            assert!(!seen[w]);
            seen[w] = true;
        }
        assert!(seen.iter().all(|&x| x), "512 word-lines covered");
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn data_row_bounds_enforced() {
        RowId::Data(500).wordline();
    }

    #[test]
    fn dcc_cells() {
        assert_eq!(RowId::Dcc(1).dcc_cell(), Some((0, false)));
        assert_eq!(RowId::Dcc(2).dcc_cell(), Some((0, true)));
        assert_eq!(RowId::Dcc(3).dcc_cell(), Some((1, false)));
        assert_eq!(RowId::Dcc(4).dcc_cell(), Some((1, true)));
        assert_eq!(RowId::X(1).dcc_cell(), None);
    }

    #[test]
    fn compute_region() {
        assert!(!RowId::Data(3).is_compute());
        assert!(RowId::X(1).is_compute());
        assert!(RowId::Dcc(4).is_compute());
    }

    #[test]
    fn parse_display_roundtrip() {
        for r in [RowId::Data(17), RowId::X(3), RowId::Dcc(2)] {
            assert_eq!(RowId::parse(&r.to_string()), Some(r));
        }
        assert_eq!(RowId::parse("bogus"), None);
    }

    #[test]
    fn aap_row_counts() {
        assert_eq!(AapKind::Copy.source_rows(), 1);
        assert_eq!(AapKind::DoubleCopy.dest_rows(), 2);
        assert_eq!(AapKind::Dra.source_rows(), 2);
        assert_eq!(AapKind::Tra.source_rows(), 3);
    }
}
