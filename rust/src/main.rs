//! `drim` — CLI for the DRIM reproduction.
//!
//! Subcommands map 1:1 onto the paper's artifacts (see DESIGN.md):
//!   isa          Table 1 (enable bits) + Table 2 (command sequences)
//!   area         §3.4 area-overhead breakdown
//!   montecarlo   Table 3 (process variation; --jax uses the PJRT artifact)
//!   transient    Fig. 6 waveforms (--csv FILE; --jax uses the artifact)
//!   fig8         Fig. 8 throughput table across all platforms
//!   fig9         Fig. 9 energy table
//!   demo         run a bulk op through the service and golden-check it
//!   serve        synthetic serving workload through the coordinator
//!   cluster      multi-device scale-out workload through the fleet layer

use drim::analog::montecarlo::{run_montecarlo, TABLE3_CORNERS, TABLE3_PAPER};
use drim::analog::params as aparams;
use drim::analog::transient as rtransient;
use drim::cluster::{
    AdmissionConfig, CapacityConfig, ClusterConfig, CoalesceConfig, DeviceCapacity,
    DrimCluster, EvictionPolicy, FleetSnapshot, MovementConfig, ReplicationPolicy,
    Topology,
};
use drim::controller::enables;
use drim::coordinator::{BatchPolicy, BulkRequest, DrimService, Payload, ServiceConfig};
use drim::dram::geometry::DramGeometry;
use drim::isa::program::BulkOp;
use drim::isa::{assemble, program};
use drim::obs::Json;
use drim::platforms::{all_platforms, FIG8_OPS};
use drim::scenario::{parse_source, run_scenario, ScenarioSpec};
use drim::subarray::area::AreaBreakdown;
use drim::util::bench::BenchReport;
use drim::util::bitrow::BitRow;
use drim::util::cli::Args;
use drim::util::rng::Rng;
use drim::util::stats::{fmt_ns, fmt_rate};
use drim::util::table::Table;

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "isa" => cmd_isa(&args),
        "area" => cmd_area(),
        "montecarlo" | "mc" => cmd_montecarlo(&args),
        "transient" => cmd_transient(&args),
        "fig8" => cmd_fig8(&args),
        "fig9" => cmd_fig9(),
        "demo" => cmd_demo(&args),
        "serve" => cmd_serve(&args),
        "cluster" => cmd_cluster(&args),
        "bench" => cmd_bench(&args),
        "perf" => cmd_perf(&args),
        "trace" => cmd_trace(&args),
        _ => {
            println!("{}", HELP);
        }
    }
}

const HELP: &str = "\
drim — processing-in-DRAM X(N)OR accelerator (paper reproduction)

USAGE: drim <COMMAND> [flags]

COMMANDS:
  isa [--table1] [--table2]   print the paper's Table 1 / Table 2
  area                        §3.4 area overhead breakdown
  montecarlo [--trials N] [--seed S] [--jax]
                              Table 3 process-variation analysis
  transient [--csv FILE] [--jax]
                              Fig. 6 DRA transient waveforms
  fig8 [--bits LOG2]          Fig. 8 throughput comparison
  fig9                        Fig. 9 energy comparison
  demo [--op OP] [--bits N] [--golden]
                              run one bulk op end-to-end (+PJRT check)
  serve [--requests N] [--bits N] [--policy immediate|coalesce] [--seed S]
        [--devices N] [--queue-cap N] [--no-steal]
                              synthetic serving workload + metrics
                              (--devices > 1 routes through the fleet layer;
                               the fleet honors --queue-cap / --no-steal)
  cluster [--devices N] [--requests N] [--bits N] [--seed S] [--queue-cap N]
          [--no-steal] [--movement MODE] [--sweep] [--json] [--locality]
          [--capacity] [--regions N] [--theta X] [--coalesce]
                              multi-device scale-out workload + fleet
                              metrics (--sweep ablates 1/2/4/8 devices;
                               --json emits the machine-readable snapshot
                               with fleet + per-device latency/sojourn
                               percentiles instead of the tables;
                               --locality ablates resident vs carried
                               operand placement and the copy traffic;
                               --capacity ablates footprint enforcement,
                               eviction and hot-region replication under a
                               Zipf(--theta) popularity law;
                               --coalesce ablates fleet-wide wave
                               coalescing of sub-wave requests;
                               --movement off|external|in_dram|prefetch
                               prices placement landing hops through the
                               in-DRAM movement fabric)
  bench --scenario FILE|NAME [--param KEY=VALUE]... [--seed S]
        [--dry-run] [--json] [--out DIR]
                              trace-driven scenario benchmark: validate a
                              declarative TOML/JSON scenario, replay its
                              seeded deterministic arrival stream through
                              the fleet, evaluate the metric gates, and
                              write BENCH_<name>.json at the repo root
                              (NAME resolves to scenarios/NAME.toml;
                               --param overrides any dotted key, e.g.
                               --param arrival.requests=256;
                               --seed overrides the scenario seed;
                               --dry-run validates and prints the resolved
                               cases without executing; --json emits the
                               artifact JSON on stdout and nothing else;
                               --out DIR keeps an extra timestamped copy;
                               exit 1 = gate failure, 2 = invalid scenario)
  perf list [DIR]             list the BENCH_*.json artifacts in DIR
                              (default: the repo root, where benches and
                               `bench --scenario` write them)
  perf diff BASELINE CURRENT [--tolerance PCT | --tolerance KEY=PCT]...
                              render per-metric deltas between two
                              artifacts; direction-aware (slower wall
                              time / lower throughput = regression);
                              exit 1 if any delta breaches tolerance or
                              a gate went pass→fail (default 10%;
                              KEY=PCT overrides keys containing KEY)
  perf check --baseline DIR [--dir DIR] [--tolerance ...]
                              compare every baseline artifact against
                              the current artifact of the same name in
                              --dir (default: repo root); exit 1 on any
                              regression — the CI perf-trajectory gate
  trace [--devices N] [--requests N] [--bits N] [--seed S] [--sample K]
        [--top N] [--coalesce] [--chrome FILE] [--json]
                              run the fleet workload with the structured
                              tracer on and render the merged timeline:
                              per-stage breakdown + top-N slowest waves
                              (--sample K records every Kth request;
                               --chrome writes a chrome://tracing /
                               Perfetto trace_event file; --json emits
                               the machine-readable summary)
";

fn cmd_isa(args: &Args) {
    let both = !args.has("table1") && !args.has("table2");
    if args.has("table1") || both {
        println!("Table 1: control bits in the Sense Amplification state\n");
        println!("{}", enables::table1());
    }
    if args.has("table2") || both {
        use drim::dram::command::RowId::*;
        println!("Table 2: basic functions supported by DRIM\n");
        for (label, p) in [
            ("copy", program::copy(Data(10), Data(20))),
            ("NOT", program::not(Data(10), Data(20))),
            ("MAJ3", program::maj3(Data(10), Data(11), Data(12), Data(20))),
            ("XNOR2", program::xnor2(Data(10), Data(11), Data(20))),
            ("XOR2", program::xor2(Data(10), Data(11), Data(20))),
            (
                "Add",
                program::full_adder(Data(10), Data(11), Data(12), Data(20), Data(21)),
            ),
            (
                "Sub",
                program::full_subtractor(Data(10), Data(11), Data(12), Data(20), Data(21)),
            ),
        ] {
            println!("-- {label} ({} AAPs)", p.aap_count());
            print!("{}", assemble::format_program(&p));
            println!();
        }
    }
}

fn cmd_area() {
    println!("DRIM area overhead (paper §3.4):\n");
    println!("{}", AreaBreakdown::drim().report());
}

fn cmd_montecarlo(args: &Args) {
    let trials = args.usize("trials", aparams::MC_TRIALS);
    let seed = args.u64("seed", 7);
    let use_jax = args.has("jax");
    let mut t = Table::new(&[
        "variation",
        "TRA err% (paper)",
        "TRA err%",
        "DRA err% (paper)",
        "DRA err%",
    ]);
    let mut rt = if use_jax {
        Some(
            drim::runtime::Runtime::load_default()
                .expect("artifacts missing — run `make artifacts`"),
        )
    } else {
        None
    };
    for (i, &v) in TABLE3_CORNERS.iter().enumerate() {
        let (dra, tra) = if let Some(rt) = rt.as_mut() {
            let (de, te, dn, tn) = rt
                .mc_variation([seed as u32, i as u32], v as f32)
                .expect("mc artifact failed");
            (
                100.0 * de as f64 / dn as f64,
                100.0 * te as f64 / tn as f64,
            )
        } else {
            let r = run_montecarlo(v, trials, seed + i as u64);
            (r.dra_pct(), r.tra_pct())
        };
        let (pd, pt) = TABLE3_PAPER[i];
        t.row(&[
            format!("±{:.0}%", v * 100.0),
            format!("{pt}"),
            format!("{tra:.2}"),
            format!("{pd}"),
            format!("{dra:.2}"),
        ]);
    }
    println!(
        "Table 3: Monte-Carlo process variation ({} trials, {})\n",
        trials,
        if use_jax {
            "JAX artifact via PJRT"
        } else {
            "rust mirror"
        }
    );
    t.print();
}

fn cmd_transient(args: &Args) {
    let use_jax = args.has("jax");
    let steps = aparams::transient_steps();
    // per case: flat [t][k] with k ∈ (BL, BL̄, Vcap-Di, Vcap-Dj)
    let data: Vec<Vec<f64>> = if use_jax {
        let mut rt =
            drim::runtime::Runtime::load_default().expect("artifacts missing");
        let flat = rt
            .transient([[0., 0.], [0., 1.], [1., 0.], [1., 1.]])
            .expect("transient artifact failed");
        (0..4)
            .map(|c| {
                (0..steps * 4)
                    .map(|i| flat[c * steps * 4 + i] as f64)
                    .collect()
            })
            .collect()
    } else {
        rtransient::all_cases()
            .into_iter()
            .map(|(_, _, w)| w.into_iter().flatten().collect())
            .collect()
    };
    if let Some(path) = args.get("csv") {
        let mut out = String::from(
            "t_ns,bl_00,blb_00,ci_00,cj_00,bl_01,blb_01,ci_01,cj_01,\
             bl_10,blb_10,ci_10,cj_10,bl_11,blb_11,ci_11,cj_11\n",
        );
        for t in 0..steps {
            let mut row = vec![format!("{:.3}", t as f64 * aparams::DT_NS)];
            for case in &data {
                for k in 0..4 {
                    row.push(format!("{:.5}", case[t * 4 + k]));
                }
            }
            out.push_str(&row.join(","));
            out.push('\n');
        }
        std::fs::write(path, out).expect("write csv");
        println!("wrote {steps}-step waveforms to {path}");
    }
    println!(
        "\nFig. 6 transient end-states ({}):",
        if use_jax { "JAX artifact" } else { "rust mirror" }
    );
    for (i, name) in ["Di=0,Dj=0", "Di=0,Dj=1", "Di=1,Dj=0", "Di=1,Dj=1"]
        .iter()
        .enumerate()
    {
        let last = &data[i][(steps - 1) * 4..];
        println!(
            "  {name}:  BL={:.3} V  BL̄={:.3} V  Vcap-Di={:.3} V  Vcap-Dj={:.3} V   (XNOR={})",
            last[0],
            last[1],
            last[2],
            last[3],
            (last[0] > 0.6) as u8
        );
    }
}

fn cmd_fig8(args: &Args) {
    let log2 = args.usize("bits", 29);
    let bits = 1u64 << log2;
    println!("Fig. 8: raw throughput, 2^{log2}-bit vectors (result bits/s)\n");
    let mut t = Table::new(&["platform", "NOT", "XNOR2", "ADD"]);
    let plats = all_platforms();
    for p in &plats {
        t.row(&[
            p.name().to_string(),
            fmt_rate(p.throughput_bits_per_sec(BulkOp::Not, bits)),
            fmt_rate(p.throughput_bits_per_sec(BulkOp::Xnor2, bits)),
            fmt_rate(p.throughput_bits_per_sec(BulkOp::Add, bits)),
        ]);
    }
    t.print();
    let get = |n: &str, op: BulkOp| {
        plats
            .iter()
            .find(|p| p.name() == n)
            .unwrap()
            .throughput_bits_per_sec(op, bits)
    };
    let avg = |n: &str| {
        FIG8_OPS
            .iter()
            .map(|&op| get("DRIM-R", op) / get(n, op))
            .sum::<f64>()
            / FIG8_OPS.len() as f64
    };
    println!("\nHeadline ratios (measured | paper):");
    println!("  DRIM-R / CPU  (avg):    {:6.1}x | 71x", avg("CPU"));
    println!("  DRIM-R / GPU  (avg):    {:6.1}x | 8.4x", avg("GPU"));
    println!(
        "  DRIM-R / Ambit (XNOR2):  {:6.1}x | 2.3x",
        get("DRIM-R", BulkOp::Xnor2) / get("Ambit", BulkOp::Xnor2)
    );
    println!(
        "  DRIM-R / DRISA-1T1C:     {:6.1}x | 1.9x",
        get("DRIM-R", BulkOp::Xnor2) / get("DRISA-1T1C", BulkOp::Xnor2)
    );
    println!(
        "  DRIM-R / DRISA-3T1C:     {:6.1}x | 3.7x",
        get("DRIM-R", BulkOp::Xnor2) / get("DRISA-3T1C", BulkOp::Xnor2)
    );
    let hmc_avg = FIG8_OPS
        .iter()
        .map(|&op| get("DRIM-S", op) / get("HMC", op))
        .sum::<f64>()
        / FIG8_OPS.len() as f64;
    println!("  DRIM-S / HMC  (avg):    {hmc_avg:6.1}x | 13.5x");
}

fn cmd_fig9() {
    println!("Fig. 9: DRAM energy per KB of result (nJ)\n");
    let mut t = Table::new(&["platform", "copy", "NOT", "XNOR2", "ADD"]);
    for p in all_platforms() {
        let cell = |op: BulkOp| {
            p.energy_pj_per_kb(op)
                .map(|e| format!("{:.1}", e / 1000.0))
                .unwrap_or_else(|| "-".into())
        };
        t.row(&[
            p.name().to_string(),
            cell(BulkOp::Copy),
            cell(BulkOp::Not),
            cell(BulkOp::Xnor2),
            cell(BulkOp::Add),
        ]);
    }
    t.print();
    let m = drim::energy::EnergyModel::default();
    let ddr4 = m.ddr4_copy_pj(8192.0);
    let in_dram = m.aap_pj(drim::dram::command::AapKind::Copy, 8192);
    println!(
        "\nDDR4-interface copy: {:.1} nJ/KB → in-DRAM copy is {:.0}x cheaper (paper: 69x)",
        ddr4 / 1000.0,
        ddr4 / in_dram
    );
}

fn cmd_demo(args: &Args) {
    let op = BulkOp::parse(args.get_or("op", "xnor2")).expect("unknown --op");
    let bits = args.usize("bits", 100_000);
    let service = DrimService::new(ServiceConfig::default());
    let mut rng = Rng::new(args.u64("seed", 1));
    println!("demo: {} over {bits} bits", op.name());

    let operands: Vec<BitRow> = (0..op.arity())
        .map(|_| BitRow::random(bits, &mut rng))
        .collect();
    let resp = service.run(BulkRequest::bitwise(op, operands.clone()));
    let result = match &resp.result {
        Payload::Bits(b) => b.clone(),
        _ => unreachable!(),
    };
    println!(
        "  executed {} AAPs for {} result bytes, simulated latency {:.2} µs, \
         DRAM energy {:.2} µJ",
        resp.stats.aaps,
        resp.result.bytes(),
        resp.sim_latency_ns / 1e3,
        resp.stats.energy_pj / 1e6
    );
    if args.has("golden") {
        let mut rt = drim::runtime::Runtime::load_default()
            .expect("artifacts missing — run `make artifacts`");
        let refs: Vec<&BitRow> = operands.iter().collect();
        let n = drim::runtime::golden::verify_bulk(&mut rt, op.name(), &refs, &result)
            .expect("golden check FAILED");
        println!("  golden check vs JAX artifact: {n} bits OK");
    }
    println!("{}", service.metrics.snapshot().report());
}

fn cmd_serve(args: &Args) {
    let n = args.usize("requests", 64);
    let bits = args.usize("bits", 65_536);
    let policy = match args.get_or("policy", "coalesce") {
        "immediate" => BatchPolicy::Immediate,
        _ => BatchPolicy::Coalesce,
    };
    let cfg = ServiceConfig {
        geometry: DramGeometry::default(),
        policy,
        ..ServiceConfig::default()
    };
    let devices = args.usize("devices", 1);
    if devices > 1 {
        serve_fleet(args, cfg, devices, n, bits);
        return;
    }
    let service = DrimService::new(cfg);
    let mut rng = Rng::new(args.u64("seed", 3));
    println!("serving {n} requests × {bits} bits (policy {policy:?})");
    let t0 = std::time::Instant::now();
    let pending: Vec<_> = synth_workload(n, bits, &mut rng)
        .into_iter()
        .map(|req| service.submit(req))
        .collect();
    for p in pending {
        p.recv().expect("response");
    }
    let wall = t0.elapsed();
    println!("\ncompleted in {wall:?} (host)\n");
    println!("{}", service.metrics.snapshot().report());
}

/// The standard synthetic serving mix (4 ops cycled, fixed sizes) used by
/// `serve` (single-device and fleet) and `cluster` — one definition so the
/// paths measure the same workload.
fn synth_workload(n: usize, bits: usize, rng: &mut Rng) -> Vec<BulkRequest> {
    (0..n)
        .map(|i| {
            let op = [BulkOp::Xnor2, BulkOp::Xor2, BulkOp::And2, BulkOp::Not][i % 4];
            let operands: Vec<BitRow> = (0..op.arity())
                .map(|_| BitRow::random(bits, rng))
                .collect();
            BulkRequest::bitwise(op, operands)
        })
        .collect()
}

/// The `--movement MODE` flag: how placement landing hops are priced
/// (mirrors the scenario schema's `movement` knob).
fn movement_mode(args: &Args) -> MovementConfig {
    match args.get_or("movement", "off") {
        "off" => MovementConfig::Off,
        "external" => MovementConfig::External,
        "in_dram" => MovementConfig::InDram,
        "prefetch" => MovementConfig::Prefetch,
        other => panic!("--movement expects off|external|in_dram|prefetch, got {other:?}"),
    }
}

/// Build a fleet from the shared CLI flags (`--queue-cap`, `--no-steal`,
/// `--movement`, `--seed`), pump the synthetic workload through it, and
/// return the host wall time plus the final fleet snapshot. Shared by
/// `serve --devices N` and `cluster` so the two paths cannot drift.
fn pump_fleet(
    args: &Args,
    devices: usize,
    per_device: ServiceConfig,
    requests: usize,
    bits: usize,
) -> (std::time::Duration, FleetSnapshot) {
    let cluster = DrimCluster::new(ClusterConfig {
        admission: AdmissionConfig {
            max_inflight_per_device: args.usize("queue-cap", 64),
        },
        steal: !args.has("no-steal"),
        movement: movement_mode(args),
        ..ClusterConfig::uniform(devices, per_device)
    });
    let mut rng = Rng::new(args.u64("seed", 3));
    let t0 = std::time::Instant::now();
    let pending: Vec<_> = synth_workload(requests, bits, &mut rng)
        .into_iter()
        .map(|req| cluster.submit_blocking(req))
        .collect();
    for p in pending {
        p.recv().expect("response");
    }
    (t0.elapsed(), cluster.shutdown())
}

/// `serve --devices N`: the same synthetic workload, spread over a fleet.
fn serve_fleet(args: &Args, per_device: ServiceConfig, devices: usize, n: usize, bits: usize) {
    println!("serving {n} requests × {bits} bits over {devices} devices");
    let (wall, snap) = pump_fleet(args, devices, per_device, n, bits);
    println!("\ncompleted in {wall:?} (host)\n");
    println!("{}", snap.report());
}

fn cmd_cluster(args: &Args) {
    if args.has("locality") {
        cmd_cluster_locality(args);
        return;
    }
    if args.has("capacity") {
        cmd_cluster_capacity(args);
        return;
    }
    if args.has("coalesce") {
        cmd_cluster_coalesce(args);
        return;
    }
    let requests = args.usize("requests", 128);
    let bits = args.usize("bits", 262_144);
    let device_counts: Vec<usize> = if args.has("sweep") {
        vec![1, 2, 4, 8]
    } else {
        vec![args.usize("devices", 4)]
    };
    let runs: Vec<(usize, std::time::Duration, FleetSnapshot)> = device_counts
        .iter()
        .map(|&devices| {
            let (wall, snap) =
                pump_fleet(args, devices, ServiceConfig::default(), requests, bits);
            (devices, wall, snap)
        })
        .collect();
    if args.has("json") {
        let base_tp = runs
            .first()
            .map(|(_, _, s)| s.sim_throughput_bits_per_sec())
            .unwrap_or(0.0);
        let entries = runs
            .iter()
            .map(|(devices, wall, snap)| {
                let tp = snap.sim_throughput_bits_per_sec();
                Json::obj()
                    .field("devices", *devices as u64)
                    .field("host_wall_ns", wall.as_nanos() as u64)
                    .field("throughput_bits_per_sec", tp)
                    .field(
                        "scaling",
                        if base_tp > 0.0 {
                            Json::from(tp / base_tp)
                        } else {
                            Json::Null
                        },
                    )
                    .field("snapshot", snap.to_json())
            })
            .collect::<Vec<_>>();
        let out = Json::obj()
            .field("schema", 1u64)
            .field("command", "cluster")
            .field(
                "config",
                Json::obj()
                    .field("requests", requests as u64)
                    .field("bits", bits as u64)
                    .field("steal", !args.has("no-steal"))
                    .field("queue_cap", args.usize("queue-cap", 64) as u64)
                    .field("movement", movement_mode(args).name()),
            )
            .field("runs", Json::Arr(entries));
        println!("{}", out.to_string_pretty());
        return;
    }
    let mut t = Table::new(&[
        "devices",
        "host wall",
        "sim makespan",
        "fleet throughput",
        "scaling",
    ]);
    let mut base_tp = 0.0;
    for (devices, wall, snap) in &runs {
        let tp = snap.sim_throughput_bits_per_sec();
        if base_tp == 0.0 {
            base_tp = tp;
        }
        t.row(&[
            format!("{devices}"),
            format!("{wall:?}"),
            format!("{:.2} µs", snap.merged.sim_ns as f64 / 1e3),
            format!("{}bit/s", fmt_rate(tp)),
            // an all-zero workload (--requests 0 / --bits 0) has no
            // baseline to scale against
            if base_tp > 0.0 {
                format!("{:.2}x", tp / base_tp)
            } else {
                "-".to_string()
            },
        ]);
    }
    println!(
        "fleet scale-out: {requests} requests × {bits} bits \
         (steal={}, queue cap {})\n",
        !args.has("no-steal"),
        args.usize("queue-cap", 64)
    );
    t.print();
    if let Some((_, _, snap)) = runs.last() {
        println!("\nlast fleet in detail:\n{}", snap.report());
    }
}

/// `cluster --locality`: the same workload with operands (a) carried
/// inline and spread round-robin vs (b) resident on their owning device
/// and placement-routed, at several hit rates. Surfaces the copy traffic
/// the residency layer models: copied bytes, DDR bus copy cycles, and the
/// makespan including operand movement. The workload itself is
/// `DrimCluster::pump_locality`, shared with benches/ablate_locality.rs.
fn cmd_cluster_locality(args: &Args) {
    let devices = args.usize("devices", 4);
    let requests = args.usize("requests", 64);
    let bits = args.usize("bits", 262_144);
    let seed = args.u64("seed", 3);
    println!(
        "locality ablation: {requests} requests × 2 × {bits} bits over \
         {devices} devices (steal off)\n"
    );
    let mut t = Table::new(&[
        "placement",
        "hits",
        "misses",
        "copied KB",
        "copy cycles",
        "makespan (compute)",
        "makespan (+copy)",
    ]);
    // policy: None → carried; Some(k) → resident with every k-th request
    // a forced miss; Some(0) → no misses (pump_locality's convention)
    for (label, policy) in [
        ("carried (round-robin)", None),
        ("resident 50%", Some(2usize)),
        ("resident 80%", Some(5)),
        ("resident 100%", Some(0)),
    ] {
        let cluster = DrimCluster::new(ClusterConfig {
            admission: AdmissionConfig {
                max_inflight_per_device: args.usize("queue-cap", 64),
            },
            steal: false,
            ..ClusterConfig::uniform(devices, ServiceConfig::default())
        });
        cluster.pump_locality(requests, bits, policy, seed);
        let snap = cluster.shutdown();
        t.row(&[
            label.to_string(),
            format!("{}", snap.resident_hits),
            format!("{}", snap.resident_misses),
            format!("{:.1}", snap.copied_bytes as f64 / 1024.0),
            format!("{}", snap.copy_cycles),
            format!("{:.2} µs", snap.merged.sim_ns as f64 / 1e3),
            format!("{:.2} µs", snap.makespan_with_copy_ns() as f64 / 1e3),
        ]);
    }
    t.print();
    println!(
        "\n→ resident placement eliminates operand movement; carried \
         payloads pay the host→device stream on every request, and \
         misses pay the inter-device copy (2× on a shared channel)"
    );
}

/// `cluster --coalesce`: fleet-wide wave coalescing of sub-wave requests
/// — the same burst of one-chunk requests with the coalescer off
/// (every request burns a private wave) vs on (compatible requests pack
/// into full waves). Surfaces the wave economy: waves issued, slot
/// occupancy, waves saved, and the simulated makespan. The workload
/// driver is `DrimCluster::pump_coalesce`, shared with
/// benches/ablate_coalesce.rs.
fn cmd_cluster_coalesce(args: &Args) {
    let devices = args.usize("devices", 4);
    let requests = args.usize("requests", 96);
    // one row chunk per request on the default geometry → sub-wave
    let bits = args.usize("bits", 8192);
    let seed = args.u64("seed", 3);
    let service = ServiceConfig::default();
    let slots = Topology::uniform(devices, service.clone()).total_wave_slots();
    println!(
        "coalescing ablation: {requests} requests × 2 × {bits} bits over \
         {devices} devices ({slots} fleet wave slots, steal off)\n"
    );
    let mut t = Table::new(&[
        "mode",
        "waves",
        "occupancy",
        "coalesced",
        "waves saved",
        "makespan",
    ]);
    for (label, coalesce) in [
        ("coalesce off", CoalesceConfig::off()),
        ("coalesce on", CoalesceConfig::strict(u64::MAX)),
    ] {
        let cluster = DrimCluster::new(ClusterConfig {
            admission: AdmissionConfig {
                max_inflight_per_device: args.usize("queue-cap", 64),
            },
            steal: false,
            coalesce,
            ..ClusterConfig::uniform(devices, service.clone())
        });
        cluster.pump_coalesce(requests, bits, seed);
        let snap = cluster.shutdown();
        t.row(&[
            label.to_string(),
            format!("{}", snap.merged.waves),
            format!("{:.1}%", 100.0 * snap.slot_occupancy()),
            format!("{}", snap.coalesced_requests),
            format!("{}", snap.waves_saved),
            format!("{:.2} µs", snap.merged.sim_ns as f64 / 1e3),
        ]);
    }
    t.print();
    println!(
        "\n→ coalescing packs sub-wave requests from the whole burst into \
         full waves: same results, same copy accounting, a fraction of \
         the wave count — the utilization the paper's wave model says the \
         fleet was leaving on the table"
    );
}

/// `cluster --capacity`: footprint enforcement, eviction and hot-region
/// replication under a Zipf-skewed popularity law. Per-device capacity is
/// expressed relative to each device's share of the working set; the
/// workload driver is `DrimCluster::pump_capacity`, shared with
/// benches/ablate_capacity.rs.
fn cmd_cluster_capacity(args: &Args) {
    let devices = args.usize("devices", 4);
    let regions = args.usize("regions", 24);
    let requests = args.usize("requests", 96);
    let bits = args.usize("bits", 65_536);
    let theta = args.f64("theta", 1.2);
    let seed = args.u64("seed", 3);
    let working_set_bits = (regions * bits) as u64;
    let share = working_set_bits / devices as u64;
    println!(
        "capacity ablation: {requests} requests over {regions} Zipf({theta}) \
         regions × {bits} bits, {devices} devices \
         (working set {} KB, per-device share {} KB, steal off)\n",
        working_set_bits / 8192,
        share / 8192,
    );
    let mut t = Table::new(&[
        "capacity",
        "policy",
        "evictions",
        "requeues",
        "hits",
        "misses",
        "copied KB",
        "makespan (+copy)",
    ]);
    // (capacity label, policy label, per-device capacity as a fraction of
    // the share, eviction policy, run the replication policy mid-run)
    type Row = (&'static str, &'static str, f64, EvictionPolicy, bool);
    let rows: &[Row] = &[
        ("unbounded", "single-copy", f64::INFINITY, EvictionPolicy::FailFast, false),
        ("unbounded", "replicate", f64::INFINITY, EvictionPolicy::FailFast, true),
        ("1.0x share", "lru evict", 1.0, EvictionPolicy::Lru, false),
        ("0.5x share", "lru evict", 0.5, EvictionPolicy::Lru, false),
    ];
    for &(label, policy_label, frac, policy, replicate) in rows {
        let capacity = if frac.is_finite() {
            DeviceCapacity::of_bits((share as f64 * frac) as u64)
        } else {
            DeviceCapacity::unbounded()
        };
        let cluster = DrimCluster::new(ClusterConfig {
            admission: AdmissionConfig {
                max_inflight_per_device: args.usize("queue-cap", 64),
            },
            steal: false,
            capacity: CapacityConfig { capacity, policy },
            ..ClusterConfig::uniform(devices, ServiceConfig::default())
        });
        let rep = ReplicationPolicy::default();
        let rebalance = replicate.then_some((&rep, 16));
        let requeues = cluster.pump_capacity(regions, requests, bits, theta, rebalance, seed);
        let snap = cluster.shutdown();
        t.row(&[
            label.to_string(),
            policy_label.to_string(),
            format!("{}", snap.evictions),
            format!("{requeues}"),
            format!("{}", snap.resident_hits),
            format!("{}", snap.resident_misses),
            format!("{:.1}", snap.copied_bytes as f64 / 1024.0),
            format!("{:.2} µs", snap.makespan_with_copy_ns() as f64 / 1e3),
        ]);
    }
    t.print();
    println!(
        "\n→ replication spreads hot regions across channels once the \
         window's traffic amortizes the stream; bounded capacity evicts \
         LRU regions and requeues their requests instead of collapsing"
    );
}

/// Resolve the `--scenario` argument: a literal path, a bare name looked
/// up under `scenarios/` in the working directory, or the same relative
/// to the repo root (so `drim bench --scenario coalesce` works from
/// anywhere). Falls through to the literal path so the read error names
/// what the user typed.
fn resolve_scenario_path(arg: &str) -> std::path::PathBuf {
    let literal = std::path::PathBuf::from(arg);
    if literal.exists() {
        return literal;
    }
    let cwd = std::path::PathBuf::from(format!("scenarios/{arg}.toml"));
    if cwd.exists() {
        return cwd;
    }
    let repo = std::path::PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/.."))
        .join(format!("scenarios/{arg}.toml"));
    if repo.exists() {
        return repo;
    }
    literal
}

/// Parse a `--param` override value as the narrowest JSON scalar.
fn param_value(v: &str) -> Json {
    match v {
        "true" => return Json::Bool(true),
        "false" => return Json::Bool(false),
        _ => {}
    }
    if let Ok(n) = v.parse::<u64>() {
        return Json::U64(n);
    }
    if let Ok(x) = v.parse::<f64>() {
        return Json::F64(x);
    }
    Json::Str(v.to_string())
}

/// `drim bench --scenario FILE`: the trace-driven scenario harness.
/// Validates the declarative scenario, replays its seeded deterministic
/// arrival stream through the fleet layer case by case, evaluates the
/// metric gates, and writes the `BENCH_<name>.json` artifact. Exit code 2
/// on an invalid scenario, 1 on a gate failure.
fn cmd_bench(args: &Args) {
    fn fail(msg: String) -> ! {
        eprintln!("{msg}");
        std::process::exit(2);
    }
    let Some(which) = args.get("scenario") else {
        fail("bench: --scenario FILE|NAME is required (see `drim help`)".into());
    };
    let path = resolve_scenario_path(which);
    let shown = path.display();
    let src = std::fs::read_to_string(&path).unwrap_or_else(|e| fail(format!("{shown}: {e}")));
    let mut doc = parse_source(&src).unwrap_or_else(|e| fail(format!("{shown}: {e}")));
    for p in args.get_all("param") {
        let Some((key, value)) = p.split_once('=') else {
            fail(format!("bench: --param expects KEY=VALUE, got `{p}`"));
        };
        doc.set_path(key, param_value(value)).unwrap_or_else(|e| fail(format!("bench: {e}")));
    }
    if let Some(seed) = args.get("seed") {
        let seed: u64 = seed
            .parse()
            .unwrap_or_else(|_| fail(format!("bench: --seed expects an integer, got `{seed}`")));
        doc.set_path("seed", Json::U64(seed)).unwrap_or_else(|e| fail(format!("bench: {e}")));
    }
    let spec = ScenarioSpec::from_doc(&doc).unwrap_or_else(|e| fail(format!("{shown}: {e}")));

    if args.has("dry-run") {
        println!("scenario `{}`: {}", spec.name, spec.description);
        println!(
            "  seed {:#x}, {} case(s), {} gate(s)\n",
            spec.seed,
            spec.cases.len().max(1),
            spec.gates.len()
        );
        let mut t = Table::new(&[
            "case",
            "devices",
            "requests",
            "window",
            "tenants",
            "wave units",
            "capacity",
        ]);
        for case in spec.resolved_cases() {
            let quotas = case.tenant_requests();
            let tenants = case
                .tenants
                .iter()
                .zip(&quotas)
                .map(|(ten, n)| format!("{}×{}", ten.name, n))
                .collect::<Vec<_>>()
                .join(" ");
            t.row(&[
                case.name.clone(),
                format!("{}", case.devices),
                format!("{}", case.requests),
                format!("{}", case.window),
                tenants,
                format!("{}", case.declared_wave_units()),
                case.capacity_bits()
                    .map(|b| format!("{b} bits/dev"))
                    .unwrap_or_else(|| "unbounded".to_string()),
            ]);
        }
        t.print();
        return;
    }

    let outcome = run_scenario(&spec);
    let mut report = BenchReport::new(&spec.name);
    report
        .config("scenario", format!("{shown}"))
        .config("seed", spec.seed)
        .config(
            "cases",
            Json::Arr(
                outcome
                    .cases
                    .iter()
                    .map(|c| Json::from(c.name.as_str()))
                    .collect(),
            ),
        );
    let params = args.get_all("param");
    if !params.is_empty() {
        report.config(
            "params",
            Json::Arr(params.iter().map(|p| Json::from(*p)).collect()),
        );
    }
    for case in &outcome.cases {
        for (key, value) in &case.metrics {
            report.metric(&format!("{}.{key}", case.name), value.clone());
        }
    }
    for gate in &outcome.gates {
        report.gate(&gate.name, gate.pass);
    }

    let artifact = report.path();
    report.write_to(&artifact);
    if let Some(dir) = args.get("out") {
        let dir = std::path::Path::new(dir);
        std::fs::create_dir_all(dir)
            .unwrap_or_else(|e| fail(format!("bench: create {}: {e}", dir.display())));
        let stamp = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs())
            .unwrap_or(0);
        let copy = dir.join(format!("BENCH_{}_{stamp}.json", spec.name));
        report.write_to(&copy);
        if !args.has("json") {
            println!("wrote {}", copy.display());
        }
    }

    if args.has("json") {
        println!("{}", report.to_json().to_string_pretty());
    } else {
        println!("scenario `{}`: {}\n", spec.name, spec.description);
        let mut t = Table::new(&[
            "case",
            "offered",
            "shed",
            "completed",
            "waves",
            "sim makespan",
            "throughput",
        ]);
        for case in &outcome.cases {
            let m = |k: &str| case.metric_f64(k).unwrap_or(0.0);
            t.row(&[
                case.name.clone(),
                format!("{}", m("offered") as u64),
                format!("{}", m("shed") as u64),
                format!("{}", m("completed") as u64),
                format!("{}", m("waves") as u64),
                format!("{:.2} µs", m("sim_makespan_ns") / 1e3),
                format!("{}bit/s", fmt_rate(m("throughput_bits_per_sec"))),
            ]);
        }
        t.print();
        for case in &outcome.cases {
            if case.snapshot.fairness.is_empty() {
                continue;
            }
            println!("\nper-tenant fairness — case `{}`:", case.name);
            let mut t = Table::new(&[
                "tenant",
                "offered",
                "shed",
                "completed",
                "mean sojourn",
                "max sojourn",
                "inflation",
            ]);
            for b in &case.snapshot.fairness {
                t.row(&[
                    b.tenant.clone(),
                    format!("{}", b.offered),
                    format!("{}", b.shed),
                    format!("{}", b.completed),
                    fmt_ns(b.mean_sojourn_ns),
                    fmt_ns(b.max_sojourn_ns),
                    format!("{:.2}x", b.sojourn_inflation),
                ]);
            }
            t.print();
        }
        if !outcome.gates.is_empty() {
            println!("\ngates:");
            for g in &outcome.gates {
                println!(
                    "  {} {}: {}",
                    if g.pass { "PASS" } else { "FAIL" },
                    g.name,
                    g.detail
                );
            }
        }
        println!("\nwrote {}", artifact.display());
    }
    if !outcome.ok() {
        std::process::exit(1);
    }
}

/// `drim perf`: the perf-trajectory toolkit over `BENCH_*.json`
/// artifacts. `list` inventories a directory, `diff` renders the
/// direction-aware per-metric deltas between two artifacts, and `check`
/// compares every checked-in baseline against the current artifact of
/// the same name — the CI regression gate. Exit 1 = regression beyond
/// tolerance, 2 = usage or I/O error.
fn cmd_perf(args: &Args) {
    use drim::util::bench::{
        compare_artifacts, PerfArtifact, PerfComparison, Tolerance,
    };
    use std::path::{Path, PathBuf};

    fn fail(msg: String) -> ! {
        eprintln!("{msg}");
        std::process::exit(2);
    }

    fn repo_root() -> PathBuf {
        PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/.."))
    }

    fn load(path: &Path) -> PerfArtifact {
        let text = std::fs::read_to_string(path)
            .unwrap_or_else(|e| fail(format!("perf: {}: {e}", path.display())));
        PerfArtifact::parse(&text)
            .unwrap_or_else(|e| fail(format!("perf: {}: {e}", path.display())))
    }

    /// The `BENCH_*.json` files directly under `dir`, sorted by name so
    /// every listing and check runs in a stable order.
    fn artifacts_in(dir: &Path) -> Vec<PathBuf> {
        let entries = std::fs::read_dir(dir)
            .unwrap_or_else(|e| fail(format!("perf: {}: {e}", dir.display())));
        let mut out: Vec<PathBuf> = entries
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .filter(|p| {
                p.file_name()
                    .and_then(|n| n.to_str())
                    .map(|n| n.starts_with("BENCH_") && n.ends_with(".json"))
                    .unwrap_or(false)
            })
            .collect();
        out.sort();
        out
    }

    /// `--tolerance PCT` sets the default; `--tolerance KEY=PCT` adds a
    /// substring override. Repeatable, applied in argv order.
    fn tolerance_from(args: &Args) -> Tolerance {
        let mut tol = Tolerance::default();
        for t in args.get_all("tolerance") {
            if let Some((pat, pct)) = t.split_once('=') {
                let pct: f64 = pct.parse().unwrap_or_else(|_| {
                    fail(format!("perf: --tolerance {t}: `{pct}` is not a number"))
                });
                tol.overrides.push((pat.to_string(), pct));
            } else {
                tol.default_pct = t.parse().unwrap_or_else(|_| {
                    fail(format!("perf: --tolerance expects PCT or KEY=PCT, got `{t}`"))
                });
            }
        }
        tol
    }

    /// Compact value rendering across nine orders of magnitude.
    fn fmt_val(v: f64) -> String {
        if v == 0.0 {
            "0".to_string()
        } else if v.abs() >= 1e6 || v.abs() < 1e-3 {
            format!("{v:.3e}")
        } else {
            format!("{v:.3}")
        }
    }

    fn fmt_pct(pct: f64) -> String {
        if pct.is_infinite() {
            (if pct > 0.0 { "new" } else { "-new" }).to_string()
        } else {
            format!("{pct:+.2}%")
        }
    }

    /// Print one comparison's regressions and drift; returns its verdict.
    fn verdict(name: &str, cmp: &PerfComparison, tol: &Tolerance) -> bool {
        let ok = cmp.ok();
        println!(
            "{} {name}: {} metric(s), {} regression(s), {} gate regression(s)",
            if ok { "PASS" } else { "FAIL" },
            cmp.deltas.len(),
            cmp.regressions().count(),
            cmp.gate_regressions.len(),
        );
        for d in cmp.regressions() {
            println!(
                "    {} {}  {} → {}  ({}, tolerance {}%)",
                d.direction.glyph(),
                d.key,
                fmt_val(d.baseline),
                fmt_val(d.current),
                fmt_pct(d.change_pct),
                tol.pct_for(&d.key),
            );
        }
        for g in &cmp.gate_regressions {
            println!("    gate {g}");
        }
        if !cmp.missing.is_empty() {
            println!("    note: {} baseline metric(s) missing now", cmp.missing.len());
        }
        if !cmp.added.is_empty() {
            println!("    note: {} new metric(s) not in baseline", cmp.added.len());
        }
        ok
    }

    let sub = args.positional.get(1).map(|s| s.as_str()).unwrap_or("");
    match sub {
        "list" => {
            let dir = args
                .positional
                .get(2)
                .map(PathBuf::from)
                .unwrap_or_else(repo_root);
            let paths = artifacts_in(&dir);
            if paths.is_empty() {
                println!("no BENCH_*.json artifacts in {}", dir.display());
                return;
            }
            let mut t = Table::new(&["artifact", "bench", "metrics", "gates", "ok"]);
            for p in &paths {
                let a = load(p);
                let passed = a.gates.iter().filter(|(_, ok)| *ok).count();
                t.row(&[
                    p.file_name().unwrap().to_string_lossy().into_owned(),
                    a.bench.clone(),
                    format!("{}", a.metrics.len()),
                    format!("{passed}/{}", a.gates.len()),
                    format!("{}", passed == a.gates.len()),
                ]);
            }
            t.print();
        }
        "diff" => {
            let (Some(base_path), Some(cur_path)) =
                (args.positional.get(2), args.positional.get(3))
            else {
                fail("perf diff: expects BASELINE and CURRENT artifact paths".into());
            };
            let tol = tolerance_from(args);
            let base = load(Path::new(base_path));
            let cur = load(Path::new(cur_path));
            let cmp = compare_artifacts(&base, &cur, &tol);
            println!(
                "perf diff `{}`: {} vs {}\n",
                base.bench, base_path, cur_path
            );
            let mut t = Table::new(&["metric", "dir", "baseline", "current", "change", "verdict"]);
            for d in &cmp.deltas {
                t.row(&[
                    d.key.clone(),
                    d.direction.glyph().to_string(),
                    fmt_val(d.baseline),
                    fmt_val(d.current),
                    fmt_pct(d.change_pct),
                    if d.regressed { "REGRESSED" } else { "ok" }.to_string(),
                ]);
            }
            t.print();
            for key in &cmp.missing {
                println!("missing in current: {key}");
            }
            for key in &cmp.added {
                println!("new in current: {key}");
            }
            for g in &cmp.gate_regressions {
                println!("gate regression: {g}");
            }
            println!();
            if !verdict(&base.bench, &cmp, &tol) {
                std::process::exit(1);
            }
        }
        "check" => {
            let Some(bdir) = args.get("baseline") else {
                fail("perf check: --baseline DIR is required".into());
            };
            let bdir = Path::new(bdir);
            let cdir = args
                .get("dir")
                .map(PathBuf::from)
                .unwrap_or_else(repo_root);
            let tol = tolerance_from(args);
            let baselines = artifacts_in(bdir);
            if baselines.is_empty() {
                fail(format!("perf check: no BENCH_*.json baselines in {}", bdir.display()));
            }
            println!(
                "perf check: {} baseline(s) from {} vs {} (default tolerance {}%)\n",
                baselines.len(),
                bdir.display(),
                cdir.display(),
                tol.default_pct,
            );
            let mut failed = false;
            for bpath in &baselines {
                let name = bpath.file_name().unwrap().to_string_lossy();
                let cpath = cdir.join(name.as_ref());
                if !cpath.exists() {
                    println!("SKIP {name}: no current artifact at {}", cpath.display());
                    continue;
                }
                let base = load(bpath);
                let cur = load(&cpath);
                if base.bench != cur.bench {
                    fail(format!(
                        "perf check: {name}: baseline bench `{}` vs current `{}`",
                        base.bench, cur.bench
                    ));
                }
                let cmp = compare_artifacts(&base, &cur, &tol);
                if !verdict(&base.bench, &cmp, &tol) {
                    failed = true;
                }
            }
            if failed {
                std::process::exit(1);
            }
        }
        other => {
            fail(format!(
                "perf: expects a subcommand `list`, `diff A B` or `check --baseline DIR`, got `{other}` (see `drim help`)"
            ));
        }
    }
}

/// `drim trace`: the synthetic fleet workload with the structured tracer
/// enabled, rendered as a per-stage breakdown plus the top-N slowest wave
/// executions. `--chrome FILE` exports the timeline in Chrome
/// `trace_event` format (chrome://tracing / Perfetto); `--json` emits the
/// machine-readable summary instead of the tables.
fn cmd_trace(args: &Args) {
    use drim::obs::Stage;
    let devices = args.usize("devices", 4);
    let requests = args.usize("requests", 64);
    let bits = args.usize("bits", 65_536);
    let seed = args.u64("seed", 3);
    let top = args.usize("top", 5);
    let sample = args.usize("sample", 1).max(1) as u32;
    let coalesce = if args.has("coalesce") {
        // strand-free staging: safe with blocking submission (strict
        // staging would hold the whole burst until an explicit flush)
        CoalesceConfig::opportunistic()
    } else {
        CoalesceConfig::off()
    };
    let cluster = DrimCluster::new(ClusterConfig {
        admission: AdmissionConfig {
            max_inflight_per_device: args.usize("queue-cap", 64),
        },
        steal: !args.has("no-steal"),
        coalesce,
        ..ClusterConfig::uniform(devices, ServiceConfig::default())
    });
    let tracer = cluster.trace_handle();
    tracer.set_sampling(sample);
    if !tracer.active() {
        println!(
            "note: the `trace` cargo feature is compiled out — \
             no events will be recorded"
        );
    }
    let mut rng = Rng::new(seed);
    let t0 = std::time::Instant::now();
    let pending: Vec<_> = synth_workload(requests, bits, &mut rng)
        .into_iter()
        .map(|req| cluster.submit_blocking(req))
        .collect();
    for p in pending {
        p.recv().expect("response");
    }
    let wall = t0.elapsed();
    let snap = cluster.shutdown();
    // collect only after shutdown: the workers have joined, so every
    // span of the run (including the final reassembles) is in the merge
    let trace = tracer.collect();
    if let Some(path) = args.get("chrome") {
        std::fs::write(path, trace.to_chrome_json().to_string_compact())
            .expect("write chrome trace");
        println!("wrote {} trace events to {path}", trace.events.len());
    }
    if args.has("json") {
        let out = Json::obj()
            .field("schema", 1u64)
            .field("command", "trace")
            .field(
                "config",
                Json::obj()
                    .field("devices", devices as u64)
                    .field("requests", requests as u64)
                    .field("bits", bits as u64)
                    .field("sample", sample as u64)
                    .field("coalesce", args.has("coalesce")),
            )
            .field("host_wall_ns", wall.as_nanos() as u64)
            .field("trace", trace.summary_json(top))
            .field("snapshot", snap.to_json());
        println!("{}", out.to_string_pretty());
        return;
    }
    println!(
        "trace: {requests} requests × {bits} bits over {devices} devices \
         (sampling 1/{sample}, {} events, {} dropped)\n",
        trace.events.len(),
        trace.dropped
    );
    let mut t = Table::new(&["stage", "events", "total", "mean", "max"]);
    for (stage, s) in trace.stage_breakdown() {
        t.row(&[
            stage.name().to_string(),
            format!("{}", s.count),
            fmt_ns(s.total_dur_ns as f64),
            fmt_ns(s.total_dur_ns as f64 / s.count as f64),
            fmt_ns(s.max_dur_ns as f64),
        ]);
    }
    t.print();
    let slowest = trace.slowest(Stage::WaveExecute, top);
    if !slowest.is_empty() {
        println!("\nslowest wave executions:");
        let mut t = Table::new(&["seq", "device", "start", "duration", "waves"]);
        for e in slowest {
            t.row(&[
                format!("{}", e.seq),
                format!("dev{}", e.lane),
                fmt_ns(e.ts_ns as f64),
                fmt_ns(e.dur_ns as f64),
                format!("{}", e.detail),
            ]);
        }
        t.print();
    }
    println!("\nfleet after the run:\n{}", snap.report());
}
