//! Von-Neumann baselines: bandwidth-roofline models.
//!
//! Bulk bit-wise operations on these machines are strictly memory-bound:
//! every result byte costs `traffic_factor` bytes of DRAM traffic (2 for
//! NOT: read A + write R; 3 for two-operand ops and add: read A, read B,
//! write R). Throughput = effective_bandwidth × 8 / traffic_factor.
//!
//! Published link widths (paper §3.4):
//! * CPU — Core-i7 6700, two 64-bit DDR4-1866/2133 channels → 34.1 GB/s
//!   peak, 85 % streaming efficiency.
//! * GPU — GTX 1080Ti, 352-bit GDDR5X @ 11 Gbps → 484 GB/s peak; bulk
//!   byte-wise kernels on Pascal sustain ≈50 % on this access pattern
//!   (three concurrent streams thrash the partition/channel mapping).
//! * HMC 2.0 — 32 vaults × 10 GB/s vault bandwidth; near-memory atomics
//!   make it *result*-bound (operands never cross the external links), so
//!   the 320 GB/s aggregate applies to the result stream; 16-byte atomic
//!   request granularity bounds the add-rate.
//!
//! Fixed per-call setup (dispatch/launch) differentiates the paper's three
//! vector lengths slightly, as in Fig. 8.

use crate::isa::program::BulkOp;

use super::Platform;

fn traffic_factor(op: BulkOp) -> f64 {
    match op {
        BulkOp::Copy => 2.0,
        BulkOp::Not => 2.0,
        BulkOp::Add | BulkOp::Sub | BulkOp::Maj3 | BulkOp::Min3 => 3.0,
        _ => 3.0, // two-operand bit-wise: read 2, write 1
    }
}

fn roofline(bw_bytes: f64, eff: f64, op: BulkOp, vec_bits: u64, setup_ns: f64) -> f64 {
    let result_bits = vec_bits as f64;
    let traffic_bytes = result_bits / 8.0 * traffic_factor(op);
    let t = traffic_bytes / (bw_bytes * eff) + setup_ns * 1e-9;
    result_bits / t
}

// ---------------------------------------------------------------------------

/// Core-i7-class CPU baseline: a two-channel DDR4 bandwidth roofline.
pub struct Cpu {
    /// peak DRAM bandwidth, bytes/s
    pub peak_bw: f64,
    /// sustained streaming efficiency (0..1)
    pub eff: f64,
}

impl Default for Cpu {
    fn default() -> Self {
        Cpu {
            peak_bw: 34.1e9,
            eff: 0.85,
        }
    }
}

impl Platform for Cpu {
    fn name(&self) -> &'static str {
        "CPU"
    }

    fn throughput_bits_per_sec(&self, op: BulkOp, vec_bits: u64) -> f64 {
        roofline(self.peak_bw, self.eff, op, vec_bits, 2_000.0)
    }

    fn energy_pj_per_kb(&self, op: BulkOp) -> Option<f64> {
        // DRAM-side energy only (paper footnote 1): traffic through the
        // DDR4 interface + core accesses. 1 KB of result = 8192 bits.
        let m = crate::energy::EnergyModel::default();
        Some(m.offchip_pj(8192.0 * traffic_factor(op)))
    }
}

/// GTX-1080Ti-class GPU baseline: a GDDR5X bandwidth roofline.
pub struct Gpu {
    /// peak DRAM bandwidth, bytes/s
    pub peak_bw: f64,
    /// sustained efficiency on this access pattern (0..1)
    pub eff: f64,
}

impl Default for Gpu {
    fn default() -> Self {
        Gpu {
            peak_bw: 484.0e9,
            eff: 0.50,
        }
    }
}

impl Platform for Gpu {
    fn name(&self) -> &'static str {
        "GPU"
    }

    fn throughput_bits_per_sec(&self, op: BulkOp, vec_bits: u64) -> f64 {
        roofline(self.peak_bw, self.eff, op, vec_bits, 10_000.0)
    }

    fn energy_pj_per_kb(&self, _op: BulkOp) -> Option<f64> {
        None // not in Fig. 9
    }
}

/// HMC 2.0 baseline: near-memory atomics, result-stream bound.
pub struct Hmc {
    /// number of vaults
    pub vaults: usize,
    /// per-vault bandwidth, bytes/s
    pub vault_bw: f64,
    /// sustained efficiency (0..1)
    pub eff: f64,
}

impl Default for Hmc {
    fn default() -> Self {
        Hmc {
            vaults: 32,
            vault_bw: 10.0e9,
            eff: 0.70,
        }
    }
}

impl Platform for Hmc {
    fn name(&self) -> &'static str {
        "HMC"
    }

    fn throughput_bits_per_sec(&self, op: BulkOp, vec_bits: u64) -> f64 {
        let agg = self.vaults as f64 * self.vault_bw * self.eff;
        let result_bits = vec_bits as f64;
        let t = match op {
            // near-memory bit-wise: result stream bound
            BulkOp::Not | BulkOp::Copy => result_bits / 8.0 / agg,
            BulkOp::Add | BulkOp::Sub => {
                // 16-byte atomic per 32-bit add → request-rate bound
                let adds = result_bits / 32.0;
                adds * 16.0 / agg
            }
            _ => result_bits / 8.0 / agg,
        } + 3_000.0e-9;
        result_bits / t
    }

    fn energy_pj_per_kb(&self, _op: BulkOp) -> Option<f64> {
        None // not in Fig. 9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const V: u64 = 1 << 29;

    #[test]
    fn cpu_xnor_near_roofline() {
        let c = Cpu::default();
        let t = c.throughput_bits_per_sec(BulkOp::Xnor2, V);
        // 34.1 GB/s × 0.85 × 8 / 3 ≈ 77 Gbit/s
        assert!((70e9..85e9).contains(&t), "{t:e}");
    }

    #[test]
    fn not_is_faster_than_xnor_on_bandwidth_bound_machines() {
        // CPU/GPU pay per-operand traffic; HMC is result-bound, so NOT and
        // XNOR2 tie there (both stream one result).
        for p in [&Cpu::default() as &dyn Platform, &Gpu::default()] {
            assert!(
                p.throughput_bits_per_sec(BulkOp::Not, V)
                    > p.throughput_bits_per_sec(BulkOp::Xnor2, V)
            );
        }
        let h = Hmc::default();
        assert!(
            h.throughput_bits_per_sec(BulkOp::Not, V)
                >= h.throughput_bits_per_sec(BulkOp::Xnor2, V)
        );
    }

    #[test]
    fn hmc_beats_gpu_beats_cpu_for_xnor() {
        let (c, g, h) = (Cpu::default(), Gpu::default(), Hmc::default());
        let tc = c.throughput_bits_per_sec(BulkOp::Xnor2, V);
        let tg = g.throughput_bits_per_sec(BulkOp::Xnor2, V);
        let th = h.throughput_bits_per_sec(BulkOp::Xnor2, V);
        assert!(tc < tg && tg < th, "{tc:e} {tg:e} {th:e}");
    }

    #[test]
    fn larger_vectors_amortize_setup() {
        let g = Gpu::default();
        assert!(
            g.throughput_bits_per_sec(BulkOp::Xnor2, 1 << 29)
                > g.throughput_bits_per_sec(BulkOp::Xnor2, 1 << 20)
        );
    }

    #[test]
    fn cpu_energy_is_traffic_times_offchip() {
        let c = Cpu::default();
        // 3 KB of traffic per result-KB × 25 pJ/bit = 614 nJ
        let e = c.energy_pj_per_kb(BulkOp::Xnor2).unwrap();
        assert!((e - 3.0 * 8192.0 * 25.0).abs() < 1.0, "{e}");
    }
}
