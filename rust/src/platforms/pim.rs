//! Command-sequence-accurate PIM platform models.
//!
//! Each design is characterized by (a) its command sequence per result row
//! for every bulk op, on the shared DRAM timing substrate, and (b) its
//! array-level parallelism (banks × simultaneously-computing sub-arrays),
//! which the add-on circuitry constrains:
//!
//! * **Ambit** [2]  — TRA + DCC on unmodified SAs: full parallelism, but
//!   X(N)OR needs a 7-AAP majority/NOT composition and AND/OR need row
//!   initialization (the paper's Challenge-2).
//! * **DRISA-3T1C** [3] — NOR on the read bit-line; 3T cells ≈ 2× cell
//!   area → half the active sub-arrays per power/area budget; X(N)OR is a
//!   6-NOR composition (each NOR ≈ one AAP-class cycle).
//! * **DRISA-1T1C** [3] — add-on XNOR gate + latch per SA (≥12 T): each op
//!   is a multi-cycle latch/compute/write sequence with a stretched cycle
//!   (logic in the sense path), and the fat SA stripe halves the active
//!   sub-arrays.
//! * **DRIM-R / DRIM-S** — this paper: Table 2 sequences on the default /
//!   3D-stacked geometry.
//!
//! Add/Sub are bit-serial over 32-bit elements: the per-plane slice cost is
//! paid once per bit, and one "result row" of sum bits is produced per
//! slice (carry rows are internal).

use crate::dram::geometry::DramGeometry;
use crate::dram::command::AapKind;
use crate::dram::timing::TimingParams;
use crate::energy::EnergyModel;
use crate::isa::program::BulkOp;

use super::Platform;

/// Per-result-row command sequence of one op on one design.
#[derive(Clone, Copy, Debug, Default)]
pub struct SeqCost {
    /// AAP type-1/2 (single-source) primitives
    pub copies: usize,
    /// AAP type-2 double-copies
    pub double_copies: usize,
    /// DRA primitives (DRIM only)
    pub dra: usize,
    /// TRA primitives
    pub tra: usize,
    /// DRISA-1T1C latch/compute cycles (stretched ACT+PRE)
    pub latch_cycles: usize,
    /// DRISA-3T1C NOR cycles (AAP-class)
    pub nor_cycles: usize,
}

/// A processing-in-DRAM design characterized by its per-op command
/// sequence and its array-level parallelism (see the module docs).
pub struct PimPlatform {
    name: &'static str,
    geometry: DramGeometry,
    timing: TimingParams,
    energy: EnergyModel,
    /// stretched cycle for latch designs (logic in the sense path)
    latch_cycle_ns: f64,
    seq: fn(BulkOp) -> SeqCost,
    in_fig9: bool,
}

impl PimPlatform {
    /// Wall-clock of one per-result-row sequence.
    pub fn seq_ns(&self, op: BulkOp) -> f64 {
        let s = (self.seq)(op);
        let aaps = s.copies + s.double_copies + s.dra + s.tra + s.nor_cycles;
        aaps as f64 * self.timing.t_aap_ns + s.latch_cycles as f64 * self.latch_cycle_ns
    }

    /// DRAM energy of one per-result-row sequence (full 8 Kb row).
    pub fn seq_pj(&self, op: BulkOp) -> f64 {
        let s = (self.seq)(op);
        let cols = self.geometry.cols;
        s.copies as f64 * self.energy.aap_pj(AapKind::Copy, cols)
            + s.double_copies as f64 * self.energy.aap_pj(AapKind::DoubleCopy, cols)
            + s.dra as f64 * self.energy.aap_pj(AapKind::Dra, cols)
            + s.tra as f64 * self.energy.aap_pj(AapKind::Tra, cols)
            + s.nor_cycles as f64 * self.energy.aap_pj(AapKind::Dra, cols) // dual-row NOR read
            + s.latch_cycles as f64
                * ((self.energy.e_act_pj + self.energy.e_pre_pj
                    + self.energy.e_1t1c_gate_pj)
                    * (cols as f64 / crate::energy::model::REF_ROW_BITS))
    }

    /// Rows processed per wave (banks × simultaneously-computing
    /// sub-arrays).
    pub fn parallel_rows(&self) -> f64 {
        (self.geometry.banks * self.geometry.active_subarrays) as f64
    }
}

impl Platform for PimPlatform {
    fn name(&self) -> &'static str {
        self.name
    }

    fn throughput_bits_per_sec(&self, op: BulkOp, vec_bits: u64) -> f64 {
        let row_bits = self.geometry.cols as f64;
        let result_bits = vec_bits as f64;
        let rows_needed = result_bits / row_bits;
        // waves of (banks × active sub-arrays) rows; command-issue is
        // pipelined across banks (RowClone convention)
        let waves = (rows_needed / self.parallel_rows()).max(1.0);
        let t = waves * self.seq_ns(op) * 1e-9;
        result_bits / t
    }

    fn energy_pj_per_kb(&self, op: BulkOp) -> Option<f64> {
        if !self.in_fig9 {
            return None;
        }
        // per KB of result = per 8192 result bits = one reference row
        Some(self.seq_pj(op) * (8192.0 / self.geometry.cols as f64))
    }
}

// ---------------------------------------------------------------------------
// sequence tables
// ---------------------------------------------------------------------------

/// DRIM — Table 2 verbatim.
fn drim_seq(op: BulkOp) -> SeqCost {
    match op {
        BulkOp::Copy => SeqCost { copies: 1, ..Default::default() },
        BulkOp::Not => SeqCost { copies: 2, ..Default::default() },
        BulkOp::Xnor2 => SeqCost { copies: 2, dra: 1, ..Default::default() },
        BulkOp::Xor2 => SeqCost { copies: 3, dra: 1, ..Default::default() },
        BulkOp::And2 | BulkOp::Or2 | BulkOp::Maj3 => {
            SeqCost { copies: 3, tra: 1, ..Default::default() }
        }
        BulkOp::Nand2 | BulkOp::Nor2 | BulkOp::Min3 => {
            SeqCost { copies: 4, tra: 1, ..Default::default() }
        }
        // full-adder slice: 3 double-copies + 2 DRA + 1 copy + 1 TRA
        BulkOp::Add => SeqCost {
            copies: 1,
            double_copies: 3,
            dra: 2,
            tra: 1,
            ..Default::default()
        },
        BulkOp::Sub => SeqCost {
            copies: 2,
            double_copies: 3,
            dra: 2,
            tra: 1,
            ..Default::default()
        },
    }
}

/// Ambit — TRA/DCC compositions with row initialization (its §2.2 cost):
/// X(N)OR = (A·B) + (Ā·B̄) via two TRAs + DCC NOTs ≈ 7 AAPs (the count the
/// paper's 2.3× speedup implies; Ambit's own Table reports the same class).
fn ambit_seq(op: BulkOp) -> SeqCost {
    match op {
        BulkOp::Copy => SeqCost { copies: 1, ..Default::default() },
        BulkOp::Not => SeqCost { copies: 2, ..Default::default() },
        BulkOp::Xnor2 | BulkOp::Xor2 => {
            SeqCost { copies: 5, tra: 2, ..Default::default() }
        }
        BulkOp::And2 | BulkOp::Or2 | BulkOp::Maj3 => {
            SeqCost { copies: 3, tra: 1, ..Default::default() }
        }
        BulkOp::Nand2 | BulkOp::Nor2 | BulkOp::Min3 => {
            SeqCost { copies: 4, tra: 1, ..Default::default() }
        }
        // FA slice: carry = 4-AAP MAJ; sum = two 7-AAP XORs sharing the
        // operand copies already in place (−2) → 16 AAPs total
        BulkOp::Add | BulkOp::Sub => {
            SeqCost { copies: 13, tra: 3, ..Default::default() }
        }
    }
}

/// DRISA-1T1C with the XNOR add-on gate: latch A (1), compute against B
/// (1), write back through the result latch (2 — the gate output is not on
/// the restore path). AND/OR-class ops need extra passes through the
/// single gate; adds compose XNOR passes for sum and gate passes for carry.
fn drisa_1t1c_seq(op: BulkOp) -> SeqCost {
    let cycles = match op {
        BulkOp::Copy => 2,
        BulkOp::Not => 2,
        BulkOp::Xnor2 | BulkOp::Xor2 => 4,
        BulkOp::And2 | BulkOp::Or2 => 6,
        BulkOp::Nand2 | BulkOp::Nor2 => 6,
        BulkOp::Maj3 | BulkOp::Min3 => 10,
        BulkOp::Add | BulkOp::Sub => 12,
    };
    SeqCost { latch_cycles: cycles, ..Default::default() }
}

/// DRISA-3T1C: native dual-row NOR on the read bit-line; everything else is
/// a NOR composition (XOR = 5 NORs, XNOR = 6; NOR-only full adder ≈ 13).
fn drisa_3t1c_seq(op: BulkOp) -> SeqCost {
    let nors = match op {
        BulkOp::Copy => 1,
        BulkOp::Not => 1, // NOR(a, a)
        BulkOp::Nor2 => 1,
        BulkOp::Or2 => 2,
        BulkOp::And2 => 3,
        BulkOp::Nand2 => 4,
        BulkOp::Xor2 => 5,
        BulkOp::Xnor2 => 6,
        BulkOp::Maj3 | BulkOp::Min3 => 7,
        BulkOp::Add | BulkOp::Sub => 13,
    };
    SeqCost { nor_cycles: nors, ..Default::default() }
}

// ---------------------------------------------------------------------------
// constructors
// ---------------------------------------------------------------------------

/// DRIM on the default commodity-DIMM geometry (the paper's DRIM-R).
pub fn drim_r() -> PimPlatform {
    drim_r_with_geometry(DramGeometry::default())
}

/// DRIM on a custom geometry (parallelism ablations).
pub fn drim_r_with_geometry(geometry: DramGeometry) -> PimPlatform {
    PimPlatform {
        name: "DRIM-R",
        geometry,
        timing: TimingParams::default(),
        energy: EnergyModel::default(),
        latch_cycle_ns: 0.0,
        seq: drim_seq,
        in_fig9: true,
    }
}

/// DRIM on the 3D-stacked organization (the paper's DRIM-S).
pub fn drim_s() -> PimPlatform {
    PimPlatform {
        name: "DRIM-S",
        geometry: DramGeometry::stacked(),
        timing: TimingParams::default(),
        energy: EnergyModel::default(),
        latch_cycle_ns: 0.0,
        seq: drim_seq,
        in_fig9: false,
    }
}

/// Ambit: TRA + DCC on unmodified sense amplifiers.
pub fn ambit() -> PimPlatform {
    PimPlatform {
        name: "Ambit",
        geometry: DramGeometry::default(),
        timing: TimingParams::default(),
        energy: EnergyModel::default(),
        latch_cycle_ns: 0.0,
        seq: ambit_seq,
        in_fig9: true,
    }
}

/// DRISA-1T1C: add-on XNOR gate + latch per sense amplifier.
pub fn drisa_1t1c() -> PimPlatform {
    PimPlatform {
        name: "DRISA-1T1C",
        geometry: DramGeometry {
            active_subarrays: 16, // ≥12T per SA → fat stripe → half budget
            ..DramGeometry::default()
        },
        timing: TimingParams::default(),
        energy: EnergyModel::default(),
        latch_cycle_ns: 70.0, // logic in the sense path stretches the cycle
        seq: drisa_1t1c_seq,
        in_fig9: true,
    }
}

/// DRISA-3T1C: native dual-row NOR on the read bit-line.
pub fn drisa_3t1c() -> PimPlatform {
    PimPlatform {
        name: "DRISA-3T1C",
        geometry: DramGeometry {
            active_subarrays: 16, // 3T cell ≈ 2× area
            ..DramGeometry::default()
        },
        timing: TimingParams::default(),
        energy: EnergyModel::default(),
        latch_cycle_ns: 0.0,
        seq: drisa_3t1c_seq,
        in_fig9: false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const V: u64 = 1 << 29;

    #[test]
    fn drim_xnor_is_3_aaps_270ns() {
        assert!((drim_r().seq_ns(BulkOp::Xnor2) - 270.0).abs() < 1e-9);
    }

    #[test]
    fn drim_add_slice_is_7_aaps() {
        assert!((drim_r().seq_ns(BulkOp::Add) - 630.0).abs() < 1e-9);
    }

    #[test]
    fn ambit_xnor_is_7_aaps() {
        assert!((ambit().seq_ns(BulkOp::Xnor2) - 630.0).abs() < 1e-9);
    }

    #[test]
    fn paper_speedups_xnor() {
        // paper §3.4: 2.3× vs Ambit, 1.9× vs DRISA-1T1C, 3.7× vs 3T1C
        let d = drim_r().throughput_bits_per_sec(BulkOp::Xnor2, V);
        let a = ambit().throughput_bits_per_sec(BulkOp::Xnor2, V);
        let d1 = drisa_1t1c().throughput_bits_per_sec(BulkOp::Xnor2, V);
        let d3 = drisa_3t1c().throughput_bits_per_sec(BulkOp::Xnor2, V);
        let (ra, r1, r3) = (d / a, d / d1, d / d3);
        assert!((2.0..2.7).contains(&ra), "vs Ambit {ra:.2}");
        assert!((1.4..2.4).contains(&r1), "vs 1T1C {r1:.2}");
        assert!((2.9..4.6).contains(&r3), "vs 3T1C {r3:.2}");
    }

    #[test]
    fn not_parity_across_pims() {
        // paper: "almost the same performance on ... NOT"
        let d = drim_r().throughput_bits_per_sec(BulkOp::Not, V);
        let a = ambit().throughput_bits_per_sec(BulkOp::Not, V);
        let d3 = drisa_3t1c().throughput_bits_per_sec(BulkOp::Not, V);
        assert!((d / a - 1.0).abs() < 0.05);
        assert!(d / d3 < 2.0 && d3 / d < 2.0);
    }

    #[test]
    fn paper_energy_ratios_xnor() {
        // paper §3.4: DRIM 2.4× below Ambit, 1.6× below DRISA-1T1C
        let d = drim_r().energy_pj_per_kb(BulkOp::Xnor2).unwrap();
        let a = ambit().energy_pj_per_kb(BulkOp::Xnor2).unwrap();
        let d1 = drisa_1t1c().energy_pj_per_kb(BulkOp::Xnor2).unwrap();
        assert!((2.0..2.9).contains(&(a / d)), "Ambit/DRIM {:.2}", a / d);
        assert!((1.3..2.0).contains(&(d1 / d)), "1T1C/DRIM {:.2}", d1 / d);
    }

    #[test]
    fn paper_energy_ratio_add_vs_cpu() {
        // paper §3.4: ~27× vs CPU for add
        let d = drim_r().energy_pj_per_kb(BulkOp::Add).unwrap();
        let cpu = crate::platforms::vonneumann::Cpu::default()
            .energy_pj_per_kb(BulkOp::Add)
            .unwrap();
        let r = cpu / d;
        assert!((20.0..34.0).contains(&r), "CPU/DRIM add {r:.1}");
    }

    #[test]
    fn drim_s_boosts_drim_r() {
        let s = drim_s().throughput_bits_per_sec(BulkOp::Xnor2, V);
        let r = drim_r().throughput_bits_per_sec(BulkOp::Xnor2, V);
        assert!(s > 1.5 * r, "{:.2}", s / r);
    }

    #[test]
    fn small_vectors_still_finish_one_wave() {
        let p = drim_r();
        let t = p.throughput_bits_per_sec(BulkOp::Xnor2, 8192);
        assert!(t > 0.0 && t < p.throughput_bits_per_sec(BulkOp::Xnor2, V));
    }

    #[test]
    fn fig9_membership() {
        assert!(drim_s().energy_pj_per_kb(BulkOp::Xnor2).is_none());
        assert!(drisa_3t1c().energy_pj_per_kb(BulkOp::Xnor2).is_none());
        assert!(ambit().energy_pj_per_kb(BulkOp::Add).is_some());
    }
}
