//! Platform models for the paper's evaluation (Fig. 8 throughput, Fig. 9
//! energy): Von-Neumann baselines (CPU / GPU / HMC), prior processing-in-
//! DRAM designs (Ambit, DRISA-1T1C, DRISA-3T1C), and DRIM-R / DRIM-S.
//!
//! Von-Neumann platforms are bandwidth-roofline models with the paper's
//! published link widths; PIM platforms are *command-sequence-accurate*:
//! their throughput/energy derive from the exact AAP/NOR/latch sequences
//! each design needs per operation, on the shared DRAM timing/energy
//! substrate. See DESIGN.md's substitution ledger.
//!
//! Throughput metric: **result bits per second** (the paper's "Operations"
//! normalized to bit-operations) on `2^27..2^29`-bit input vectors.
#![warn(missing_docs)]

pub mod pim;
pub mod vonneumann;

use crate::isa::program::BulkOp;

/// The three bulk operations of Fig. 8/9.
pub const FIG8_OPS: [BulkOp; 3] = [BulkOp::Not, BulkOp::Xnor2, BulkOp::Add];

/// One evaluated platform.
pub trait Platform {
    /// Display name, as printed in Fig. 8/9.
    fn name(&self) -> &'static str;

    /// Sustained throughput in result-bits/s for vectors of `vec_bits`.
    fn throughput_bits_per_sec(&self, op: BulkOp, vec_bits: u64) -> f64;

    /// DRAM-side energy per KB of result (pJ); None where the paper does
    /// not report the platform in Fig. 9.
    fn energy_pj_per_kb(&self, op: BulkOp) -> Option<f64>;
}

/// All platforms in the paper's Fig. 8, in its display order.
pub fn all_platforms() -> Vec<Box<dyn Platform>> {
    vec![
        Box::new(vonneumann::Cpu::default()),
        Box::new(vonneumann::Gpu::default()),
        Box::new(vonneumann::Hmc::default()),
        Box::new(pim::ambit()),
        Box::new(pim::drisa_1t1c()),
        Box::new(pim::drisa_3t1c()),
        Box::new(pim::drim_r()),
        Box::new(pim::drim_s()),
    ]
}

/// Fetch one platform by (lowercase) name.
pub fn by_name(name: &str) -> Option<Box<dyn Platform>> {
    all_platforms()
        .into_iter()
        .find(|p| p.name().eq_ignore_ascii_case(name))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roster_matches_fig8() {
        let names: Vec<_> = all_platforms().iter().map(|p| p.name()).collect();
        assert_eq!(
            names,
            vec![
                "CPU",
                "GPU",
                "HMC",
                "Ambit",
                "DRISA-1T1C",
                "DRISA-3T1C",
                "DRIM-R",
                "DRIM-S"
            ]
        );
    }

    #[test]
    fn lookup_by_name() {
        assert!(by_name("drim-r").is_some());
        assert!(by_name("abacus").is_none());
    }

    #[test]
    fn fig8_ordering_holds_for_xnor2() {
        // the paper's qualitative result: CPU < GPU < HMC < DRISA-3T1C <
        // Ambit < DRISA-1T1C < DRIM-R ≤ DRIM-S for X(N)OR2
        let t: Vec<(String, f64)> = all_platforms()
            .iter()
            .map(|p| {
                (
                    p.name().to_string(),
                    p.throughput_bits_per_sec(BulkOp::Xnor2, 1 << 29),
                )
            })
            .collect();
        let get = |n: &str| t.iter().find(|(m, _)| m == n).unwrap().1;
        assert!(get("CPU") < get("GPU"));
        assert!(get("GPU") < get("HMC"));
        assert!(get("HMC") < get("DRISA-3T1C"));
        assert!(get("DRISA-3T1C") < get("Ambit"));
        assert!(get("Ambit") < get("DRISA-1T1C"));
        assert!(get("DRISA-1T1C") < get("DRIM-R"));
        assert!(get("DRIM-R") <= get("DRIM-S"));
    }
}
