//! PJRT runtime: loads the AOT-lowered JAX artifacts (`artifacts/*.hlo.txt`)
//! and executes them on the embedded CPU PJRT client.
//!
//! This is the only place the Rust system touches XLA. Python never runs at
//! request time: `make artifacts` lowers the L1/L2 graphs once, and this
//! module replays them for (a) golden verification of in-DRAM results,
//! (b) the Table 3 Monte-Carlo reference, (c) the Fig. 6 transients.
//!
//! Interchange is HLO *text* — see python/compile/aot.py for why.

pub mod client;
pub mod golden;
pub mod manifest;

pub use client::Runtime;
pub use manifest::{ArtifactSpec, Manifest};

/// Default artifact directory: honor `$DRIM_ARTIFACTS`, else walk up from
/// the current directory looking for `artifacts/manifest.txt`.
pub fn default_artifact_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("DRIM_ARTIFACTS") {
        return p.into();
    }
    let mut dir = std::env::current_dir().unwrap_or_else(|_| ".".into());
    loop {
        let cand = dir.join("artifacts");
        if cand.join("manifest.txt").exists() {
            return cand;
        }
        if !dir.pop() {
            return "artifacts".into();
        }
    }
}
