//! The PJRT client wrapper: compile-once, execute-many.
//!
//! Pattern follows /opt/xla-example/load_hlo.rs: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. Artifacts are compiled lazily and cached.

use std::collections::HashMap;
use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use super::manifest::{ArtifactSpec, DType, Manifest};

/// Typed host-side tensor for artifact I/O.
#[derive(Clone, Debug, PartialEq)]
pub enum Tensor {
    I32(Vec<i32>),
    U32(Vec<u32>),
    F32(Vec<f32>),
}

impl Tensor {
    pub fn len(&self) -> usize {
        match self {
            Tensor::I32(v) => v.len(),
            Tensor::U32(v) => v.len(),
            Tensor::F32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            Tensor::I32(v) => Ok(v),
            _ => bail!("tensor is not i32"),
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }

    fn dtype(&self) -> DType {
        match self {
            Tensor::I32(_) => DType::I32,
            Tensor::U32(_) => DType::U32,
            Tensor::F32(_) => DType::F32,
        }
    }

    fn to_literal(&self, dims: &[i64]) -> Result<xla::Literal> {
        let lit = match self {
            Tensor::I32(v) => xla::Literal::vec1(v),
            Tensor::U32(v) => xla::Literal::vec1(v),
            Tensor::F32(v) => xla::Literal::vec1(v),
        };
        Ok(lit.reshape(dims)?)
    }

    fn from_literal(lit: &xla::Literal, dtype: DType) -> Result<Tensor> {
        Ok(match dtype {
            DType::I32 => Tensor::I32(lit.to_vec::<i32>()?),
            DType::U32 => Tensor::U32(lit.to_vec::<u32>()?),
            DType::F32 => Tensor::F32(lit.to_vec::<f32>()?),
        })
    }
}

/// Compile-once execute-many runtime over the artifact manifest.
pub struct Runtime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    pub dir: PathBuf,
}

impl Runtime {
    /// Load the manifest from `dir` (see `default_artifact_dir`) and start
    /// a CPU PJRT client. Fails fast if the Python/Rust physical constants
    /// have diverged (analog::params::check_manifest).
    pub fn load(dir: PathBuf) -> Result<Self> {
        let manifest = Manifest::load(&dir)?;
        let mismatches = crate::analog::params::check_manifest(&manifest.header);
        if !mismatches.is_empty() {
            bail!(
                "artifact manifest constants diverge from rust mirror: {mismatches:?} \
                 — re-run `make artifacts`"
            );
        }
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime {
            manifest,
            client,
            cache: HashMap::new(),
            dir,
        })
    }

    pub fn load_default() -> Result<Self> {
        Self::load(super::default_artifact_dir())
    }

    fn compiled(&mut self, name: &str) -> Result<(&xla::PjRtLoadedExecutable, ArtifactSpec)> {
        let spec = self.manifest.get(name)?.clone();
        if !self.cache.contains_key(name) {
            let path = spec
                .path
                .to_str()
                .context("non-utf8 artifact path")?
                .to_string();
            let proto = xla::HloModuleProto::from_text_file(&path)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.cache.insert(name.to_string(), exe);
        }
        Ok((&self.cache[name], spec))
    }

    /// Execute an artifact with shape/dtype-checked inputs; returns one
    /// tensor per manifest output (aot.py lowers with return_tuple=True).
    pub fn execute(&mut self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let (exe, spec) = self.compiled(name)?;
        if inputs.len() != spec.inputs.len() {
            bail!(
                "{name}: expected {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            );
        }
        let mut lits = Vec::with_capacity(inputs.len());
        for (i, (t, s)) in inputs.iter().zip(&spec.inputs).enumerate() {
            if t.dtype() != s.dtype {
                bail!("{name}: input {i} dtype mismatch ({:?} vs {:?})", t.dtype(), s.dtype);
            }
            if t.len() != s.elements() {
                bail!(
                    "{name}: input {i} has {} elements, manifest says {}",
                    t.len(),
                    s.elements()
                );
            }
            let dims: Vec<i64> = s.dims.iter().map(|&d| d as i64).collect();
            lits.push(t.to_literal(&dims)?);
        }
        let result = exe.execute::<xla::Literal>(&lits)?[0][0].to_literal_sync()?;
        let parts = result.to_tuple()?;
        if parts.len() != spec.outputs.len() {
            bail!(
                "{name}: {} outputs returned, manifest says {}",
                parts.len(),
                spec.outputs.len()
            );
        }
        parts
            .iter()
            .zip(&spec.outputs)
            .map(|(l, s)| Tensor::from_literal(l, s.dtype))
            .collect()
    }

    // ---- typed convenience wrappers ------------------------------------

    /// One Monte-Carlo batch (Table 3): returns (dra_err, tra_err,
    /// dra_evals, tra_evals).
    pub fn mc_variation(&mut self, key: [u32; 2], variation: f32) -> Result<(u64, u64, u64, u64)> {
        let out = self.execute(
            "mc_variation",
            &[Tensor::U32(key.to_vec()), Tensor::F32(vec![variation])],
        )?;
        let g = |i: usize| -> Result<u64> { Ok(out[i].as_i32()?[0] as u64) };
        Ok((g(0)?, g(1)?, g(2)?, g(3)?))
    }

    /// Fig. 6 transient: input 4 (Di, Dj) cases, output [4, steps, 4] f32.
    pub fn transient(&mut self, cases: [[f32; 2]; 4]) -> Result<Vec<f32>> {
        let flat: Vec<f32> = cases.iter().flatten().copied().collect();
        let out = self.execute("transient", &[Tensor::F32(flat)])?;
        Ok(out[0].as_f32()?.to_vec())
    }

    /// Golden bulk op at the artifact shape (65 536 i32 words/operand).
    pub fn bulk(&mut self, op: &str, operands: &[&[i32]]) -> Result<Vec<i32>> {
        let name = format!("bulk_{op}");
        let ins: Vec<Tensor> = operands.iter().map(|o| Tensor::I32(o.to_vec())).collect();
        let out = self.execute(&name, &ins)?;
        Ok(out[0].as_i32()?.to_vec())
    }

    /// Golden bit-plane adder: (sum_planes, carry).
    pub fn bitplane_add(
        &mut self,
        a: &[i32],
        b: &[i32],
        carry_in: &[i32],
    ) -> Result<(Vec<i32>, Vec<i32>)> {
        let out = self.execute(
            "bitplane_add",
            &[
                Tensor::I32(a.to_vec()),
                Tensor::I32(b.to_vec()),
                Tensor::I32(carry_in.to_vec()),
            ],
        )?;
        Ok((out[0].as_i32()?.to_vec(), out[1].as_i32()?.to_vec()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_dtype_guards() {
        let t = Tensor::I32(vec![1, 2, 3]);
        assert_eq!(t.len(), 3);
        assert!(t.as_i32().is_ok());
        assert!(t.as_f32().is_err());
    }

    // PJRT-backed tests live in rust/tests/it_runtime_golden.rs (they need
    // generated artifacts); here we only check the pure plumbing.
    #[test]
    fn runtime_load_fails_cleanly_without_artifacts() {
        let r = Runtime::load(PathBuf::from("/nonexistent/dir"));
        assert!(r.is_err());
    }
}
