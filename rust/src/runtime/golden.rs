//! Golden verification: DRIM's in-array functional results vs the
//! AOT-lowered JAX reference kernels, chunked to the artifact shape.
//!
//! `BULK_WORDS` (= 512×128 i32 words = 2 Mbit) is the static shape the
//! bulk artifacts were lowered at; arbitrary-size payloads are verified in
//! zero-padded chunks.

use anyhow::Result;

use crate::util::bitrow::BitRow;

use super::client::Runtime;

/// Words per bulk-artifact call (python/compile/params.py BITWISE_*).
pub const BULK_WORDS: usize = 512 * 128;

/// Pack a `BitRow` into i32 lanes padded to a whole number of chunks.
pub fn row_to_chunks(row: &BitRow) -> Vec<Vec<i32>> {
    let lanes = row.to_u32_lanes();
    lanes
        .chunks(BULK_WORDS)
        .map(|c| {
            let mut v: Vec<i32> = c.iter().map(|&x| x as i32).collect();
            v.resize(BULK_WORDS, 0);
            v
        })
        .collect()
}

/// Verify `result = op(operands...)` against the JAX artifact. Returns the
/// number of verified bits.
pub fn verify_bulk(
    rt: &mut Runtime,
    op: &str,
    operands: &[&BitRow],
    result: &BitRow,
) -> Result<usize> {
    assert!(!operands.is_empty());
    let bits = result.len();
    let op_chunks: Vec<Vec<Vec<i32>>> = operands.iter().map(|o| row_to_chunks(o)).collect();
    let res_chunks = row_to_chunks(result);
    for ci in 0..res_chunks.len() {
        let ins: Vec<&[i32]> = op_chunks.iter().map(|o| o[ci].as_slice()).collect();
        let golden = rt.bulk(op, &ins)?;
        // compare only the live words of this chunk
        let live_words = ((bits - ci * BULK_WORDS * 32).min(BULK_WORDS * 32) + 31) / 32;
        for w in 0..live_words {
            let mask = if (ci * BULK_WORDS + w + 1) * 32 <= bits {
                !0u32
            } else {
                let live = bits - (ci * BULK_WORDS + w) * 32;
                (1u32 << live) - 1
            };
            let got = res_chunks[ci][w] as u32 & mask;
            let want = golden[w] as u32 & mask;
            if got != want {
                anyhow::bail!(
                    "golden mismatch for {op} at chunk {ci} word {w}: \
                     drim={got:#010x} jax={want:#010x}"
                );
            }
        }
    }
    Ok(bits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn chunking_pads_and_splits() {
        let mut rng = Rng::new(1);
        let row = BitRow::random(BULK_WORDS * 32 + 1000, &mut rng);
        let chunks = row_to_chunks(&row);
        assert_eq!(chunks.len(), 2);
        assert!(chunks.iter().all(|c| c.len() == BULK_WORDS));
    }

    #[test]
    fn small_row_is_one_chunk() {
        let row = BitRow::zeros(64);
        let chunks = row_to_chunks(&row);
        assert_eq!(chunks.len(), 1);
        assert_eq!(chunks[0].len(), BULK_WORDS);
    }
}
