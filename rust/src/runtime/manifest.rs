//! Parser for `artifacts/manifest.txt` (written by python/compile/aot.py).
//!
//! Line format:
//! `name \t file \t in=<dtype[dims],...> \t out=<dtype[dims],...> \t sha256=<16 hex>`

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum DType {
    I32,
    U32,
    F32,
}

impl DType {
    fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "int32" => DType::I32,
            "uint32" => DType::U32,
            "float32" => DType::F32,
            other => bail!("unsupported dtype {other:?}"),
        })
    }
}

#[derive(Clone, PartialEq, Debug)]
pub struct TensorSpec {
    pub dtype: DType,
    pub dims: Vec<usize>,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.dims.iter().product::<usize>().max(1)
    }

    fn parse(s: &str) -> Result<Self> {
        let (d, rest) = s
            .split_once('[')
            .with_context(|| format!("bad tensor spec {s:?}"))?;
        let dims_s = rest.strip_suffix(']').context("missing ]")?;
        let dims = if dims_s.is_empty() {
            vec![]
        } else {
            dims_s
                .split(',')
                .map(|d| d.parse::<usize>().context("bad dim"))
                .collect::<Result<_>>()?
        };
        Ok(TensorSpec {
            dtype: DType::parse(d)?,
            dims,
        })
    }
}

/// Split a comma-separated spec list, where commas also appear inside
/// `[...]` dims.
fn split_specs(s: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut cur = String::new();
    for ch in s.chars() {
        match ch {
            '[' => {
                depth += 1;
                cur.push(ch);
            }
            ']' => {
                depth -= 1;
                cur.push(ch);
            }
            ',' if depth == 0 => {
                out.push(std::mem::take(&mut cur));
            }
            _ => cur.push(ch),
        }
    }
    if !cur.is_empty() {
        out.push(cur);
    }
    out
}

#[derive(Clone, PartialEq, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub path: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub sha256_prefix: String,
}

#[derive(Debug, Default)]
pub struct Manifest {
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub header: String,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(dir.join("manifest.txt"))
            .with_context(|| format!("reading manifest in {dir:?} — run `make artifacts`"))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: &Path) -> Result<Self> {
        let mut m = Manifest {
            header: text
                .lines()
                .filter(|l| l.starts_with('#'))
                .collect::<Vec<_>>()
                .join("\n"),
            ..Default::default()
        };
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let fields: Vec<&str> = line.split('\t').collect();
            if fields.len() != 5 {
                bail!("manifest line has {} fields: {line:?}", fields.len());
            }
            let name = fields[0].to_string();
            let ins = fields[2].strip_prefix("in=").context("missing in=")?;
            let outs = fields[3].strip_prefix("out=").context("missing out=")?;
            let sha = fields[4].strip_prefix("sha256=").context("missing sha")?;
            let spec = ArtifactSpec {
                name: name.clone(),
                path: dir.join(fields[1]),
                inputs: split_specs(ins)
                    .iter()
                    .map(|s| TensorSpec::parse(s))
                    .collect::<Result<_>>()?,
                outputs: split_specs(outs)
                    .iter()
                    .map(|s| TensorSpec::parse(s))
                    .collect::<Result<_>>()?,
                sha256_prefix: sha.to_string(),
            };
            m.artifacts.insert(name, spec);
        }
        Ok(m)
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .with_context(|| format!("artifact {name:?} not in manifest"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# DRIM AOT artifact manifest\n\
# vdd=1.2 cp_ratio=0.6\n\
bulk_xnor2\tbulk_xnor2.hlo.txt\tin=int32[512,128],int32[512,128]\tout=int32[512,128]\tsha256=0123456789abcdef\n\
mc_variation\tmc_variation.hlo.txt\tin=uint32[2],float32[]\tout=int32[],int32[],int32[],int32[]\tsha256=fedcba9876543210\n";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        let x = m.get("bulk_xnor2").unwrap();
        assert_eq!(x.inputs.len(), 2);
        assert_eq!(x.inputs[0].dims, vec![512, 128]);
        assert_eq!(x.inputs[0].dtype, DType::I32);
        assert_eq!(x.inputs[0].elements(), 65536);
        let mc = m.get("mc_variation").unwrap();
        assert_eq!(mc.inputs[1].dims, Vec::<usize>::new());
        assert_eq!(mc.inputs[1].elements(), 1);
        assert_eq!(mc.outputs.len(), 4);
        assert_eq!(mc.path, Path::new("/tmp/a/mc_variation.hlo.txt"));
    }

    #[test]
    fn missing_artifact_errors() {
        let m = Manifest::parse(SAMPLE, Path::new(".")).unwrap();
        assert!(m.get("nope").is_err());
    }

    #[test]
    fn header_captured() {
        let m = Manifest::parse(SAMPLE, Path::new(".")).unwrap();
        assert!(m.header.contains("vdd=1.2"));
    }

    #[test]
    fn real_manifest_matches_rust_params_if_present() {
        let dir = crate::runtime::default_artifact_dir();
        if let Ok(m) = Manifest::load(&dir) {
            let mismatches = crate::analog::params::check_manifest(&m.header);
            assert!(mismatches.is_empty(), "{mismatches:?}");
            assert!(m.artifacts.contains_key("bulk_xnor2"));
            assert!(m.artifacts.contains_key("mc_variation"));
            assert!(m.artifacts.contains_key("transient"));
            assert!(m.artifacts.contains_key("bitplane_add"));
        }
    }
}
