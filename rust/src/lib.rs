//! # DRIM — processing-in-DRAM for bulk bit-wise X(N)OR
//!
//! Full-system reproduction of *"Accelerating Bulk Bit-Wise X(N)OR
//! Operation in Processing-in-DRAM Platform"* (Angizi & Fan, 2019).
//!
//! The crate is organized bottom-up (see DESIGN.md for the complete map):
//!
//! * [`dram`] — DDR4-class functional + timing substrate.
//! * [`subarray`] — the computational sub-array: modified row decoder,
//!   reconfigurable sense amplifier, DRA/TRA charge-sharing execution.
//! * [`isa`] — the four AAP instruction types and the Table 2
//!   micro-programs.
//! * [`controller`] — instruction dispatch, enable signals, row
//!   allocation, cycle/energy accounting.
//! * [`coordinator`] — the serving layer: bulk-op requests sharded across
//!   banks × sub-arrays with dynamic batching; exposes the
//!   [`coordinator::Device`] abstraction (one chip = one `DrimService`).
//! * [`cluster`] — the scale-out layer above the coordinator: N devices
//!   (channels/ranks) behind one fleet scheduler with work stealing,
//!   admission-control load shedding, operand-residency routing with an
//!   inter-device copy-cost model, and merged fleet metrics.
//! * [`analog`] — behavioural circuit models (margins, Monte-Carlo
//!   variation) mirrored against the JAX/Pallas artifacts.
//! * [`energy`] — per-command energy model (Fig. 9).
//! * [`platforms`] — baseline models (CPU, GPU, HMC, Ambit, DRISA) and
//!   DRIM-R/DRIM-S for the Fig. 8 throughput comparison.
//! * [`runtime`] — PJRT bridge executing the AOT-lowered JAX artifacts
//!   (golden checks, Monte-Carlo, Fig. 6 transients).
//! * [`apps`] — library-level applications (DNA matching, XOR cipher,
//!   bit-serial vector math).
//! * [`obs`] — observability: structured pipeline tracing (feature
//!   `trace`, on by default), mergeable latency histograms, and the
//!   dependency-free JSON exporter behind `drim cluster --json`,
//!   `drim trace`, and the `BENCH_*.json` trajectory artifacts.
//! * [`scenario`] — the trace-driven benchmark harness behind
//!   `drim bench --scenario`: declarative TOML/JSON multi-tenant
//!   scenarios with deterministic seeded replay, per-tenant fairness
//!   breakdowns, and CI-gated metric comparisons.

pub mod analog;
pub mod apps;
pub mod cluster;
pub mod controller;
pub mod coordinator;
pub mod dram;
pub mod energy;
pub mod isa;
pub mod obs;
pub mod platforms;
pub mod runtime;
pub mod scenario;
pub mod subarray;
pub mod util;
