//! The DRIM computational sub-array: 512 word-lines (500 data + x1..x8 +
//! dcc1..dcc4), a Modified Row Decoder, and the reconfigurable sense
//! amplifier row (paper Fig. 3/4).
//!
//! This is the *functional* (bit-accurate) model used on the hot path; the
//! *analog* fidelity of the same operations (voltages, margins, variation)
//! lives in `analog/` and the L1/L2 JAX artifacts, and the two are
//! cross-validated in tests.

pub mod area;
pub mod decoder;
pub mod sense;

use crate::dram::command::{AapKind, RowId};
use crate::util::bitrow::BitRow;
use crate::util::rng::Rng;

use decoder::validate_aap;
use sense::SenseAmp;

/// One computational sub-array: cell matrix + SA row.
#[derive(Clone, Debug)]
pub struct SubArray {
    cols: usize,
    /// data rows + x rows (cells addressed by word-line index)
    rows: Vec<BitRow>,
    /// the two dual-contact cells (cell A: dcc1/dcc2, cell B: dcc3/dcc4)
    dcc: [BitRow; 2],
    /// sense amplifier row (latch after amplification)
    sa: SenseAmp,
    /// AAPs executed (for stats/ablations)
    pub aap_count: u64,
}

impl SubArray {
    pub fn new(cols: usize) -> Self {
        use crate::dram::geometry::{DATA_ROWS, NUM_X_ROWS};
        SubArray {
            cols,
            rows: vec![BitRow::zeros(cols); DATA_ROWS + NUM_X_ROWS],
            dcc: [BitRow::zeros(cols), BitRow::zeros(cols)],
            sa: SenseAmp::new(cols),
            aap_count: 0,
        }
    }

    pub fn randomize(&mut self, rng: &mut Rng) {
        for r in &mut self.rows {
            *r = BitRow::random(self.cols, rng);
        }
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Cell contents as seen on BL when `row`'s word-line is activated
    /// alone: DCC complement word-lines present the *inverted* cell value
    /// (the cell's second access transistor connects it to BL̄).
    fn bl_view(&self, row: RowId) -> BitRow {
        match row.dcc_cell() {
            Some((cell, through_complement)) => {
                if through_complement {
                    let mut v = BitRow::zeros(self.cols);
                    v.not_from(&self.dcc[cell]);
                    v
                } else {
                    self.dcc[cell].clone()
                }
            }
            None => self.rows[row.wordline()].clone(),
        }
    }

    /// Drive the (amplified) BL value into an open row: normal cells take
    /// BL, DCC-complement word-lines take BL̄ (i.e. store the inverse).
    fn drive_into(&mut self, row: RowId, bl: &BitRow) {
        match row.dcc_cell() {
            Some((cell, through_complement)) => {
                if through_complement {
                    self.dcc[cell].not_from(bl);
                } else {
                    self.dcc[cell].copy_from(bl);
                }
            }
            None => self.rows[row.wordline()].copy_from(bl),
        }
    }

    /// Direct cell access for host load/readback (models a column-granular
    /// WRITE/READ through the global row buffer).
    pub fn write_row(&mut self, row: RowId, value: &BitRow) {
        assert_eq!(value.len(), self.cols);
        self.drive_into(row, value);
    }

    pub fn read_row(&self, row: RowId) -> BitRow {
        self.bl_view(row)
    }

    /// Execute one AAP primitive: source activation (charge sharing + sense
    /// amplification), destination activation (drive SA value into the
    /// destination cells), precharge. Returns the SA latch value after the
    /// operation (what landed on BL).
    ///
    /// Reference: paper §3.1 (DRA), §2.1 (RowClone-FPM, TRA), Table 1/2.
    pub fn execute_aap(
        &mut self,
        kind: AapKind,
        srcs: &[RowId],
        dests: &[RowId],
    ) -> BitRow {
        validate_aap(kind, srcs, dests);
        self.aap_count += 1;

        // --- first ACTIVATE: charge share + amplify --------------------
        //
        // The all-plain-row case (no DCC word-line involved) is the hot
        // path of every Fig.-8-class workload and runs clone-free: the SA
        // latches straight from the cell rows (§Perf iteration 3).
        let plain = srcs.iter().all(|s| s.dcc_cell().is_none());
        match kind {
            AapKind::Copy | AapKind::DoubleCopy => {
                if plain {
                    self.sa.latch_single(&self.rows[srcs[0].wordline()]);
                } else {
                    let v = self.bl_view(srcs[0]);
                    self.sa.latch_single(&v);
                }
                // activation is restorative for the source cell
            }
            AapKind::Dra => {
                if plain {
                    self.sa.latch_dra(
                        &self.rows[srcs[0].wordline()],
                        &self.rows[srcs[1].wordline()],
                    );
                } else {
                    let a = self.bl_view(srcs[0]);
                    let b = self.bl_view(srcs[1]);
                    self.sa.latch_dra(&a, &b);
                }
                // DRA is destructive: both open cells are overwritten with
                // the amplified BL value (visible in Fig. 6's Vcap traces).
                let bl = self.sa.bl().clone();
                self.drive_into(srcs[0], &bl);
                self.drive_into(srcs[1], &bl);
            }
            AapKind::Tra => {
                if plain {
                    self.sa.latch_tra(
                        &self.rows[srcs[0].wordline()],
                        &self.rows[srcs[1].wordline()],
                        &self.rows[srcs[2].wordline()],
                    );
                } else {
                    let a = self.bl_view(srcs[0]);
                    let b = self.bl_view(srcs[1]);
                    let c = self.bl_view(srcs[2]);
                    self.sa.latch_tra(&a, &b, &c);
                }
                let bl = self.sa.bl().clone();
                self.drive_into(srcs[0], &bl);
                self.drive_into(srcs[1], &bl);
                self.drive_into(srcs[2], &bl);
            }
        }

        // --- second ACTIVATE: drive result into destination(s) ---------
        let bl = self.sa.bl().clone();
        for &d in dests {
            self.drive_into(d, &bl);
        }

        // --- PRECHARGE: SA released, bit-lines return to Vdd/2 ----------
        // (latch content is consumed; nothing persists in the SA model)
        bl
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dram::command::RowId::*;

    fn sa_with(cols: usize, pairs: &[(RowId, &BitRow)]) -> SubArray {
        let mut s = SubArray::new(cols);
        for (r, v) in pairs {
            s.write_row(*r, v);
        }
        s
    }

    fn rand_row(cols: usize, seed: u64) -> BitRow {
        BitRow::random(cols, &mut Rng::new(seed))
    }

    #[test]
    fn copy_aap_copies() {
        let a = rand_row(256, 1);
        let mut s = sa_with(256, &[(Data(3), &a)]);
        s.execute_aap(AapKind::Copy, &[Data(3)], &[X(1)]);
        assert_eq!(s.read_row(X(1)), a);
        assert_eq!(s.read_row(Data(3)), a, "activation is restorative");
    }

    #[test]
    fn double_copy_reaches_both_dests() {
        let a = rand_row(256, 2);
        let mut s = sa_with(256, &[(Data(0), &a)]);
        s.execute_aap(AapKind::DoubleCopy, &[Data(0)], &[X(1), X(2)]);
        assert_eq!(s.read_row(X(1)), a);
        assert_eq!(s.read_row(X(2)), a);
    }

    #[test]
    fn dra_computes_xnor_and_is_destructive() {
        let a = rand_row(512, 3);
        let b = rand_row(512, 4);
        let mut s = sa_with(512, &[(X(1), &a), (X(2), &b)]);
        let out = s.execute_aap(AapKind::Dra, &[X(1), X(2)], &[Data(9)]);
        let mut want = BitRow::zeros(512);
        want.apply2(&a, &b, |x, y| !(x ^ y));
        assert_eq!(out, want);
        assert_eq!(s.read_row(Data(9)), want);
        // Fig. 6: the source cells end at the BL rail (the XNOR result)
        assert_eq!(s.read_row(X(1)), want);
        assert_eq!(s.read_row(X(2)), want);
    }

    #[test]
    fn tra_computes_maj3() {
        let (a, b, c) = (rand_row(128, 5), rand_row(128, 6), rand_row(128, 7));
        let mut s = sa_with(128, &[(X(1), &a), (X(2), &b), (X(3), &c)]);
        let out = s.execute_aap(AapKind::Tra, &[X(1), X(2), X(3)], &[Data(0)]);
        let mut want = BitRow::zeros(128);
        want.apply3(&a, &b, &c, |x, y, z| (x & y) | (x & z) | (y & z));
        assert_eq!(out, want);
        assert_eq!(s.read_row(Data(0)), want);
    }

    #[test]
    fn dcc_complement_wordline_inverts_on_write_and_read() {
        let a = rand_row(64, 8);
        let mut s = sa_with(64, &[(Data(1), &a)]);
        // Table 2 NOT: AAP(Di, dcc2); AAP(dcc1, Dr)
        s.execute_aap(AapKind::Copy, &[Data(1)], &[Dcc(2)]);
        s.execute_aap(AapKind::Copy, &[Dcc(1)], &[Data(2)]);
        let mut want = BitRow::zeros(64);
        want.not_from(&a);
        assert_eq!(s.read_row(Data(2)), want, "NOT via DCC");
    }

    #[test]
    fn dra_over_dcc_source_gives_xnor_of_complement() {
        // the Add sequence uses AAP(x6, dcc1, dcc4): DRA over an x row and
        // the DCC normal word-line
        let a = rand_row(64, 9);
        let b = rand_row(64, 10);
        let mut s = SubArray::new(64);
        s.write_row(X(6), &a);
        s.write_row(Dcc(1), &b);
        s.execute_aap(AapKind::Dra, &[X(6), Dcc(1)], &[Dcc(4)]);
        // BL gets XNOR(a,b); dcc4 is cell B's complement WL → cell B = XOR
        let mut xor = BitRow::zeros(64);
        xor.apply2(&a, &b, |x, y| x ^ y);
        assert_eq!(s.read_row(Dcc(3)), xor, "cell B holds XOR(a,b)");
    }

    #[test]
    fn aap_count_increments() {
        let mut s = SubArray::new(64);
        assert_eq!(s.aap_count, 0);
        s.execute_aap(AapKind::Copy, &[Data(0)], &[X(1)]);
        s.execute_aap(AapKind::Dra, &[X(1), X(2)], &[Data(1)]);
        assert_eq!(s.aap_count, 2);
    }
}
