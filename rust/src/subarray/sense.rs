//! The reconfigurable sense amplifier (paper Fig. 4) — digital model.
//!
//! The SA row holds one latch per bit-line. Three enable signals (Table 1)
//! select the operating mode:
//!
//! | operation              | En_M | En_x | En_C |
//! |------------------------|------|------|------|
//! | W/R – Copy – NOT – TRA |  1   |  1   |  0   |
//! | DRA                    |  0   |  1   |  1   |
//!
//! In DRA mode the two shifted-VTC inverters act as threshold detectors on
//! the isolated charge-sharing node (n = #cells storing '1', levels
//! n·Vdd/2): the low-Vs inverter realizes NOR2, the high-Vs inverter NAND2,
//! and the add-on AND gate produces XOR2 on BL̄ — hence XNOR2 on BL
//! (paper Eq. 1). The digital decision table below is exactly what the
//! analog model in `analog/` resolves to with zero variation (asserted by
//! `it_functional::digital_matches_analog_decisions`).

use crate::util::bitrow::BitRow;

/// Enable-signal values (Table 1).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct EnableBits {
    pub en_m: bool,
    pub en_x: bool,
    pub en_c: bool,
}

/// SA operating mode, selecting the charge-sharing interpretation.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum SenseMode {
    /// conventional: W/R, Copy, NOT, TRA
    Conventional,
    /// dual-row activation through the add-on inverters
    Dra,
}

impl SenseMode {
    /// Table 1, verbatim.
    pub fn enables(self) -> EnableBits {
        match self {
            SenseMode::Conventional => EnableBits {
                en_m: true,
                en_x: true,
                en_c: false,
            },
            SenseMode::Dra => EnableBits {
                en_m: false,
                en_x: true,
                en_c: true,
            },
        }
    }
}

/// The SA latch row.
#[derive(Clone, Debug)]
pub struct SenseAmp {
    bl: BitRow,
    blbar: BitRow,
}

impl SenseAmp {
    pub fn new(cols: usize) -> Self {
        SenseAmp {
            bl: BitRow::zeros(cols),
            blbar: BitRow::ones(cols),
        }
    }

    /// Amplified BL value (the latch).
    pub fn bl(&self) -> &BitRow {
        &self.bl
    }

    /// Complement bit-line (XOR2 during DRA — paper Eq. 1).
    pub fn blbar(&self) -> &BitRow {
        &self.blbar
    }

    /// Single-row activation: conventional read (En_M/En_x high).
    pub fn latch_single(&mut self, v: &BitRow) {
        self.bl.copy_from(v);
        self.blbar.not_from(v);
    }

    /// Dual-row activation (En_x/En_C high): BL ← XNOR2, BL̄ ← XOR2.
    pub fn latch_dra(&mut self, a: &BitRow, b: &BitRow) {
        self.bl.apply2(a, b, |x, y| !(x ^ y));
        self.blbar.apply2(a, b, |x, y| x ^ y);
    }

    /// Triple-row activation (conventional SA): BL ← MAJ3.
    pub fn latch_tra(&mut self, a: &BitRow, b: &BitRow, c: &BitRow) {
        self.bl
            .apply3(a, b, c, |x, y, z| (x & y) | (x & z) | (y & z));
        // BL̄ is ¬MAJ3 computed directly from the operands — no clone of
        // the freshly latched BL row on this hot path
        self.blbar
            .apply3(a, b, c, |x, y, z| !((x & y) | (x & z) | (y & z)));
    }
}

/// Truth-table form of the DRA decision as a function of n (number of
/// activated cells storing '1') — Fig. 4b. Used to cross-check the analog
/// threshold model.
pub fn dra_decision(n: usize) -> (bool, bool) {
    // (XNOR on BL, XOR on BL̄)
    match n {
        0 => (true, false),  // NOR fires → OR=0 → XOR=0
        1 => (false, true),  // between thresholds → XOR=1
        2 => (true, false),  // NAND off → XOR=0
        _ => panic!("DRA connects exactly 2 cells"),
    }
}

/// TRA decision (conventional SA against Vdd/2): MAJ3.
pub fn tra_decision(n: usize) -> bool {
    assert!(n <= 3);
    n >= 2
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn table1_enables() {
        let c = SenseMode::Conventional.enables();
        assert!(c.en_m && c.en_x && !c.en_c);
        let d = SenseMode::Dra.enables();
        assert!(!d.en_m && d.en_x && d.en_c);
    }

    #[test]
    fn dra_truth_table() {
        assert_eq!(dra_decision(0), (true, false));
        assert_eq!(dra_decision(1), (false, true));
        assert_eq!(dra_decision(2), (true, false));
    }

    #[test]
    fn latch_dra_matches_decision_table() {
        let a = BitRow::from_bits(&[false, false, true, true]);
        let b = BitRow::from_bits(&[false, true, false, true]);
        let mut sa = SenseAmp::new(4);
        sa.latch_dra(&a, &b);
        for i in 0..4 {
            let n = a.get(i) as usize + b.get(i) as usize;
            let (xnor, xor) = dra_decision(n);
            assert_eq!(sa.bl().get(i), xnor);
            assert_eq!(sa.blbar().get(i), xor);
        }
    }

    #[test]
    fn latch_tra_matches_decision_table() {
        let mut rng = Rng::new(1);
        let a = BitRow::random(128, &mut rng);
        let b = BitRow::random(128, &mut rng);
        let c = BitRow::random(128, &mut rng);
        let mut sa = SenseAmp::new(128);
        sa.latch_tra(&a, &b, &c);
        for i in 0..128 {
            let n = a.get(i) as usize + b.get(i) as usize + c.get(i) as usize;
            assert_eq!(sa.bl().get(i), tra_decision(n));
        }
    }

    #[test]
    fn blbar_is_complement_outside_dra() {
        let mut rng = Rng::new(2);
        let v = BitRow::random(64, &mut rng);
        let mut sa = SenseAmp::new(64);
        sa.latch_single(&v);
        for i in 0..64 {
            assert_eq!(sa.bl().get(i), !sa.blbar().get(i));
        }
    }
}
