//! Modified Row Decoder (MRD) legality rules.
//!
//! The paper's MRD drives only the 12 computation word-lines (x1..x8,
//! dcc1..dcc4) and is the only decoder capable of simultaneous multi-row
//! activation; the 500 data rows hang off the regular decoder which
//! activates exactly one word-line at a time. These invariants are enforced
//! on every AAP (violations are architecture bugs, hence panics, not
//! recoverable errors).

use crate::dram::command::{AapKind, RowId};

/// Panics if the (srcs, dests) combination is not issuable on DRIM hardware.
pub fn validate_aap(kind: AapKind, srcs: &[RowId], dests: &[RowId]) {
    assert_eq!(srcs.len(), kind.source_rows(), "{kind:?}: wrong source arity");
    assert_eq!(dests.len(), kind.dest_rows(), "{kind:?}: wrong dest arity");

    // Multi-row *source* activation requires every word-line on the MRD.
    if srcs.len() > 1 {
        for s in srcs {
            assert!(
                s.is_compute(),
                "{kind:?}: multi-row activation of data row {s} needs the MRD \
                 — RowClone operands into x rows first (paper Table 2)"
            );
        }
    }
    // Dual-destination activation (AAP type-2) likewise.
    if dests.len() > 1 {
        for d in dests {
            assert!(
                d.is_compute(),
                "{kind:?}: simultaneous dual-destination {d} must be a \
                 computation row"
            );
        }
    }

    // No word-line may appear twice in one activation phase.
    for (i, a) in srcs.iter().enumerate() {
        for b in &srcs[i + 1..] {
            assert_ne!(a, b, "{kind:?}: duplicate source word-line {a}");
        }
    }
    for (i, a) in dests.iter().enumerate() {
        for b in &dests[i + 1..] {
            assert_ne!(a, b, "{kind:?}: duplicate destination word-line {a}");
        }
    }

    // Both word-lines of the same DCC cell would short BL to BL̄ through
    // the cell — electrically illegal.
    let same_dcc_cell = |a: RowId, b: RowId| match (a.dcc_cell(), b.dcc_cell()) {
        (Some((ca, _)), Some((cb, _))) => ca == cb,
        _ => false,
    };
    for (i, a) in srcs.iter().enumerate() {
        for b in &srcs[i + 1..] {
            assert!(
                !same_dcc_cell(*a, *b),
                "{kind:?}: {a} and {b} are the two contacts of one DCC cell"
            );
        }
    }
    for (i, a) in dests.iter().enumerate() {
        for b in &dests[i + 1..] {
            assert!(
                !same_dcc_cell(*a, *b),
                "{kind:?}: {a} and {b} are the two contacts of one DCC cell"
            );
        }
    }

    // A row cannot be simultaneously source and destination (the second
    // ACTIVATE of an AAP opens the destination while the SA still drives
    // the source's value — re-opening the same word-line is a no-op but
    // indicates a malformed program).
    for s in srcs {
        for d in dests {
            assert_ne!(s, d, "{kind:?}: {s} is both source and destination");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dram::command::RowId::*;

    #[test]
    fn legal_sequences_pass() {
        validate_aap(AapKind::Copy, &[Data(0)], &[X(1)]);
        validate_aap(AapKind::Copy, &[Data(0)], &[Dcc(2)]);
        validate_aap(AapKind::DoubleCopy, &[Data(0)], &[X(1), X(2)]);
        validate_aap(AapKind::Dra, &[X(1), X(2)], &[Data(0)]);
        validate_aap(AapKind::Dra, &[X(6), Dcc(1)], &[Dcc(4)]);
        validate_aap(AapKind::Tra, &[X(1), X(2), X(3)], &[Data(7)]);
    }

    #[test]
    #[should_panic(expected = "needs the MRD")]
    fn dra_on_data_rows_rejected() {
        validate_aap(AapKind::Dra, &[Data(0), Data(1)], &[Data(2)]);
    }

    #[test]
    #[should_panic(expected = "dual-destination")]
    fn double_copy_to_data_rows_rejected() {
        validate_aap(AapKind::DoubleCopy, &[Data(0)], &[Data(1), Data(2)]);
    }

    #[test]
    #[should_panic(expected = "duplicate source")]
    fn duplicate_sources_rejected() {
        validate_aap(AapKind::Dra, &[X(1), X(1)], &[Data(0)]);
    }

    #[test]
    #[should_panic(expected = "DCC cell")]
    fn dcc_short_rejected() {
        validate_aap(AapKind::Dra, &[Dcc(1), Dcc(2)], &[Data(0)]);
    }

    #[test]
    #[should_panic(expected = "both source and destination")]
    fn src_dest_overlap_rejected() {
        validate_aap(AapKind::Copy, &[X(1)], &[X(1)]);
    }

    #[test]
    #[should_panic(expected = "wrong source arity")]
    fn arity_checked() {
        validate_aap(AapKind::Tra, &[X(1), X(2)], &[Data(0)]);
    }
}
