//! Area-overhead model (paper §3.4 "Area").
//!
//! Four cost sources, each expressed in *equivalent DRAM rows per
//! sub-array* (a periphery transistor on the bit-line pitch occupies about
//! half a cell-row of silicon in the folded 6F² layout, the estimation
//! convention the paper inherits from [18]):
//!
//! 1. 22 add-on transistors per SA per bit-line          → 11 rows
//! 2. two DCC rows, two word-lines each, +1 AT per BL    →  5 rows
//! 3. 4:12 MRD (two extra transistors per WL driver)     →  6 rows
//! 4. ctrl enable-bit MUXes (6 transistors)              →  2 rows
//!
//! Total 24 rows / 512-row sub-array; with the cell matrix occupying ≈half
//! of DRAM chip area, that is the paper's "~9.3 % of DRAM chip area".

use crate::dram::geometry::SUBARRAY_ROWS;

pub const ROWS_PER_PERIPHERY_TRANSISTOR: f64 = 0.5;

#[derive(Clone, Copy, Debug)]
pub struct AreaBreakdown {
    pub sa_addon_rows: f64,
    pub dcc_rows: f64,
    pub mrd_rows: f64,
    pub ctrl_rows: f64,
}

impl AreaBreakdown {
    pub fn drim() -> Self {
        AreaBreakdown {
            // 22 transistors on the BL pitch (Fig. 4a add-on circuits)
            sa_addon_rows: 22.0 * ROWS_PER_PERIPHERY_TRANSISTOR,
            // 2 cell rows at double word-line pitch + 1 extra AT per BL
            dcc_rows: 2.0 * 2.0 + 1.0,
            // 12 MRD drivers × 2 extra buffer-chain transistors, laid out
            // along the row decoder edge → amortized per sub-array
            mrd_rows: 12.0 * ROWS_PER_PERIPHERY_TRANSISTOR,
            // 6-transistor MUX per enable signal (En_M, En_x, En_C) in ctrl
            ctrl_rows: 2.0,
        }
    }

    pub fn total_rows(&self) -> f64 {
        self.sa_addon_rows + self.dcc_rows + self.mrd_rows + self.ctrl_rows
    }

    /// Fraction of the cell-matrix area.
    pub fn array_fraction(&self) -> f64 {
        self.total_rows() / SUBARRAY_ROWS as f64
    }

    /// Fraction of total chip area, given the cell-matrix share of the die.
    pub fn chip_fraction(&self, cell_matrix_share: f64) -> f64 {
        self.array_fraction() / cell_matrix_share
    }

    pub fn report(&self) -> String {
        format!(
            "SA add-on (22T/BL): {:>5.1} rows\n\
             DCC rows (2×2WL+AT): {:>4.1} rows\n\
             4:12 MRD drivers:   {:>5.1} rows\n\
             ctrl enable MUXes:  {:>5.1} rows\n\
             total: {:.0} rows/sub-array = {:.1}% of array = {:.1}% of chip",
            self.sa_addon_rows,
            self.dcc_rows,
            self.mrd_rows,
            self.ctrl_rows,
            self.total_rows(),
            self.array_fraction() * 100.0,
            self.chip_fraction(0.505) * 100.0
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals_match_paper() {
        let a = AreaBreakdown::drim();
        // paper: "DRIM roughly imposes 24 DRAM rows per sub-array"
        assert_eq!(a.total_rows(), 24.0);
        // paper: "~9.3% of DRAM chip area"
        let chip = a.chip_fraction(0.505) * 100.0;
        assert!((chip - 9.3).abs() < 0.2, "chip overhead {chip:.2}%");
    }

    #[test]
    fn all_sources_positive() {
        let a = AreaBreakdown::drim();
        assert!(a.sa_addon_rows > 0.0);
        assert!(a.dcc_rows > 0.0);
        assert!(a.mrd_rows > 0.0);
        assert!(a.ctrl_rows > 0.0);
    }
}
