//! The fleet scheduler: per-device FIFO queues behind one shared ready
//! list, with an atomic Idle→Pending→Running shard state machine.
//!
//! Why a state machine instead of pushing tasks onto one global queue: a
//! device queue must be *drained by exactly one worker at a time* (each
//! drain batches tasks onto one `DrimService`, preserving per-device FIFO
//! order and batching opportunities), yet any idle worker may pick up any
//! backlogged device (work stealing). The classic bug in that design is
//! double-enqueueing a device on the ready list — two workers then drain
//! the same queue concurrently. Here the only transition that enqueues a
//! shard is a successful `Idle → Pending` CAS, so each shard is on the
//! ready list at most once:
//!
//! ```text
//!            submit: CAS Idle→Pending  ──────────► on ready list
//!   Idle ───────────────────────────────► Pending
//!    ▲                                       │ acquire: pop + store Running
//!    │ release: store Idle,                  ▼
//!    └────── re-check queue ───────────── Running   (exactly one owner)
//! ```
//!
//! `release` first publishes `Idle` and *then* re-checks the queue,
//! re-enqueueing itself if a racing `submit` landed between the drain and
//! the release — no lost wakeups, no dedicated dispatcher thread.
//!
//! Interaction with placement-aware routing: the residency layer decides
//! which *queue* a request enters (the operand owner's, when it can), but
//! a stolen shard still executes on the stealer's device — the copy-cost
//! accounting therefore lives with the worker, which charges operand
//! movement against its own device id, not the queue's.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::{Condvar, Mutex};

/// Shard (device queue) states. `u8` representation for the atomic.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
#[repr(u8)]
pub enum ShardState {
    /// queue may be empty or not; shard is not on the ready list
    Idle = 0,
    /// shard has work and sits on the shared ready list exactly once
    Pending = 1,
    /// one worker owns the shard and is draining its queue
    Running = 2,
}

impl ShardState {
    fn from_u8(v: u8) -> ShardState {
        match v {
            0 => ShardState::Idle,
            1 => ShardState::Pending,
            _ => ShardState::Running,
        }
    }
}

struct Shard<T> {
    queue: Mutex<VecDeque<T>>,
    state: AtomicU8,
}

struct Ready {
    fifo: VecDeque<usize>,
    open: bool,
}

/// Multi-queue FIFO scheduler, generic over the task type (the cluster
/// uses `ClusterTask`; unit tests use plain integers).
pub struct Scheduler<T> {
    shards: Vec<Shard<T>>,
    ready: Mutex<Ready>,
    cv: Condvar,
}

impl<T> Scheduler<T> {
    pub fn new(n_shards: usize) -> Self {
        assert!(n_shards > 0);
        Scheduler {
            shards: (0..n_shards)
                .map(|_| Shard {
                    queue: Mutex::new(VecDeque::new()),
                    state: AtomicU8::new(ShardState::Idle as u8),
                })
                .collect(),
            ready: Mutex::new(Ready {
                fifo: VecDeque::new(),
                open: true,
            }),
            cv: Condvar::new(),
        }
    }

    pub fn shards(&self) -> usize {
        self.shards.len()
    }

    pub fn state(&self, shard: usize) -> ShardState {
        ShardState::from_u8(self.shards[shard].state.load(Ordering::SeqCst))
    }

    /// Tasks currently queued on `shard` (racy; for metrics/tests).
    pub fn depth(&self, shard: usize) -> usize {
        self.shards[shard].queue.lock().unwrap().len()
    }

    /// Per-shard queue depths (racy snapshot; the replication policy uses
    /// them as a load tie-breaker when picking placement targets).
    pub fn depths(&self) -> Vec<usize> {
        (0..self.shards.len()).map(|i| self.depth(i)).collect()
    }

    /// Enqueue a task on a device queue and mark the shard ready.
    pub fn submit(&self, shard: usize, task: T) {
        self.shards[shard].queue.lock().unwrap().push_back(task);
        self.mark_pending(shard);
    }

    /// `Idle → Pending` — the *only* path onto the ready list. The CAS
    /// guarantees one enqueue per drain cycle even under racing submitters.
    fn mark_pending(&self, shard: usize) {
        if self.shards[shard]
            .state
            .compare_exchange(
                ShardState::Idle as u8,
                ShardState::Pending as u8,
                Ordering::SeqCst,
                Ordering::SeqCst,
            )
            .is_ok()
        {
            let mut r = self.ready.lock().unwrap();
            r.fifo.push_back(shard);
            // notify_all: workers wait selectively (own shard vs steal),
            // so a single targeted wakeup could land on the wrong worker.
            self.cv.notify_all();
        }
    }

    fn take(&self, r: &mut Ready, own: usize, steal: bool) -> Option<usize> {
        let picked = if let Some(i) = r.fifo.iter().position(|&s| s == own) {
            r.fifo.remove(i)
        } else if steal {
            r.fifo.pop_front()
        } else {
            None
        };
        if let Some(s) = picked {
            self.shards[s]
                .state
                .store(ShardState::Running as u8, Ordering::SeqCst);
        }
        picked
    }

    /// Block until a shard is ready and claim it (`Pending → Running`).
    /// Prefers `own`; with `steal` set, falls back to the oldest ready
    /// shard. Returns `None` once the scheduler is closed and (from this
    /// worker's point of view) no claimable work remains.
    pub fn acquire(&self, own: usize, steal: bool) -> Option<usize> {
        let mut r = self.ready.lock().unwrap();
        loop {
            if let Some(s) = self.take(&mut r, own, steal) {
                return Some(s);
            }
            if !r.open {
                return None;
            }
            r = self.cv.wait(r).unwrap();
        }
    }

    /// Non-blocking [`Self::acquire`] (tests and opportunistic polling).
    pub fn try_acquire(&self, own: usize, steal: bool) -> Option<usize> {
        self.take(&mut self.ready.lock().unwrap(), own, steal)
    }

    /// Pop up to `max` tasks from a shard the caller has acquired.
    pub fn drain(&self, shard: usize, max: usize) -> Vec<T> {
        let mut q = self.shards[shard].queue.lock().unwrap();
        let n = q.len().min(max);
        q.drain(..n).collect()
    }

    /// Batch-aware drain: pop tasks in FIFO order while the running
    /// `cost` total stays within `budget`, up to `max` tasks — but always
    /// at least one, so an oversized task can never wedge its queue. The
    /// fleet workers budget drains in *wave units*, bounding the chunk
    /// footprint one acquisition puts in flight on a device regardless of
    /// how many requests the coalescer packed per task.
    pub fn drain_budgeted<F>(&self, shard: usize, max: usize, budget: usize, cost: F) -> Vec<T>
    where
        F: Fn(&T) -> usize,
    {
        let mut out = Vec::new();
        self.drain_budgeted_into(shard, max, budget, cost, &mut out);
        out
    }

    /// [`Self::drain_budgeted`] appending into a caller-owned buffer —
    /// the fleet workers keep one drain buffer per thread and reuse its
    /// capacity across acquisitions, so the steady-state drain path
    /// allocates nothing. Drained tasks are appended after whatever the
    /// buffer already holds (workers clear it between acquisitions); the
    /// `max`/`budget` bounds apply to the newly drained tasks only.
    pub fn drain_budgeted_into<F>(
        &self,
        shard: usize,
        max: usize,
        budget: usize,
        cost: F,
        out: &mut Vec<T>,
    ) where
        F: Fn(&T) -> usize,
    {
        let mut q = self.shards[shard].queue.lock().unwrap();
        let mut spent = 0usize;
        let mut taken = 0usize;
        while taken < max {
            let Some(front) = q.front() else { break };
            let c = cost(front);
            if taken > 0 && spent + c > budget {
                break;
            }
            spent += c;
            taken += 1;
            out.push(q.pop_front().expect("front() just succeeded"));
        }
    }

    /// `Running → Idle`, re-enqueueing the shard if tasks arrived after the
    /// drain. Must be called by the worker that acquired the shard.
    pub fn release(&self, shard: usize) {
        self.shards[shard]
            .state
            .store(ShardState::Idle as u8, Ordering::SeqCst);
        // Re-check under the queue lock: a submit that lost the CAS while
        // we were Running relies on this re-enqueue.
        if !self.shards[shard].queue.lock().unwrap().is_empty() {
            self.mark_pending(shard);
        }
    }

    /// Stop accepting blocking waits: workers drain the remaining ready
    /// shards and then exit. Tasks on queues whose shard never went
    /// Pending again are dropped with the scheduler.
    pub fn close(&self) {
        self.ready.lock().unwrap().open = false;
        self.cv.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        !self.ready.lock().unwrap().open
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::sync::Arc;

    #[test]
    fn fifo_across_shards() {
        let s: Scheduler<u32> = Scheduler::new(3);
        s.submit(1, 10);
        s.submit(2, 20);
        s.submit(0, 30);
        // no preference match → steal in ready order
        assert_eq!(s.try_acquire(9, true), Some(1));
        assert_eq!(s.try_acquire(9, true), Some(2));
        assert_eq!(s.try_acquire(9, true), Some(0));
        assert_eq!(s.try_acquire(9, true), None);
    }

    #[test]
    fn depths_snapshot_all_shards() {
        let s: Scheduler<u32> = Scheduler::new(3);
        s.submit(1, 10);
        s.submit(1, 11);
        s.submit(2, 20);
        assert_eq!(s.depths(), vec![0, 2, 1]);
    }

    #[test]
    fn own_shard_preferred_over_fifo_order() {
        let s: Scheduler<u32> = Scheduler::new(3);
        s.submit(0, 1);
        s.submit(2, 2);
        assert_eq!(s.try_acquire(2, true), Some(2));
        assert_eq!(s.try_acquire(2, true), Some(0)); // then steals
    }

    #[test]
    fn no_steal_only_claims_own() {
        let s: Scheduler<u32> = Scheduler::new(2);
        s.submit(1, 5);
        assert_eq!(s.try_acquire(0, false), None);
        assert_eq!(s.try_acquire(1, false), Some(1));
    }

    #[test]
    fn never_double_enqueued() {
        let s: Scheduler<u32> = Scheduler::new(1);
        s.submit(0, 1);
        s.submit(0, 2); // second submit must NOT enqueue shard 0 again
        assert_eq!(s.try_acquire(0, true), Some(0));
        assert_eq!(s.state(0), ShardState::Running);
        // while Running, new submits still don't re-enqueue
        s.submit(0, 3);
        assert_eq!(s.try_acquire(0, true), None);
        assert_eq!(s.drain(0, 16), vec![1, 2, 3]);
        s.release(0);
        // queue empty → back to Idle, not ready
        assert_eq!(s.state(0), ShardState::Idle);
        assert_eq!(s.try_acquire(0, true), None);
    }

    #[test]
    fn budgeted_drain_stops_at_the_cost_bound() {
        let s: Scheduler<u32> = Scheduler::new(1);
        for t in [3u32, 2, 2, 1] {
            s.submit(0, t);
        }
        assert_eq!(s.try_acquire(0, true), Some(0));
        // cost = the task value itself; budget 5 fits 3 + 2, not the next 2
        assert_eq!(s.drain_budgeted(0, 16, 5, |&t| t as usize), vec![3, 2]);
        // FIFO continues where the budget stopped
        assert_eq!(s.drain_budgeted(0, 16, 5, |&t| t as usize), vec![2, 1]);
        s.release(0);
        assert_eq!(s.state(0), ShardState::Idle);
    }

    #[test]
    fn budgeted_drain_always_takes_one_oversized_task() {
        let s: Scheduler<u32> = Scheduler::new(1);
        s.submit(0, 100);
        s.submit(0, 1);
        assert_eq!(s.try_acquire(0, true), Some(0));
        // 100 > budget 4, but the head must move anyway
        assert_eq!(s.drain_budgeted(0, 16, 4, |&t| t as usize), vec![100]);
        assert_eq!(s.drain_budgeted(0, 16, 4, |&t| t as usize), vec![1]);
        assert!(s.drain_budgeted(0, 16, 4, |&t| t as usize).is_empty());
        s.release(0);
    }

    #[test]
    fn budgeted_drain_respects_max_items() {
        let s: Scheduler<u32> = Scheduler::new(1);
        for _ in 0..5 {
            s.submit(0, 0);
        }
        assert_eq!(s.try_acquire(0, true), Some(0));
        assert_eq!(s.drain_budgeted(0, 3, usize::MAX, |_| 0).len(), 3);
        assert_eq!(s.drain_budgeted(0, 3, usize::MAX, |_| 0).len(), 2);
        s.release(0);
    }

    #[test]
    fn release_requeues_leftover_work() {
        let s: Scheduler<u32> = Scheduler::new(1);
        s.submit(0, 1);
        s.submit(0, 2);
        assert_eq!(s.try_acquire(0, true), Some(0));
        assert_eq!(s.drain(0, 1), vec![1]); // partial drain
        s.release(0);
        assert_eq!(s.state(0), ShardState::Pending);
        assert_eq!(s.try_acquire(0, true), Some(0));
        assert_eq!(s.drain(0, 1), vec![2]);
        s.release(0);
    }

    #[test]
    fn closed_scheduler_drains_then_exits() {
        let s: Scheduler<u32> = Scheduler::new(2);
        s.submit(0, 1);
        s.close();
        // acquire still hands out the ready shard before reporting None
        assert_eq!(s.acquire(0, true), Some(0));
        assert_eq!(s.drain(0, 8), vec![1]);
        s.release(0);
        assert_eq!(s.acquire(0, true), None);
    }

    /// Hammer one scheduler from many producers and consumers; every task
    /// must be delivered exactly once (counted), with no shard ever drained
    /// by two workers at once (guarded by an owner flag per shard).
    #[test]
    fn concurrent_delivery_exactly_once() {
        const SHARDS: usize = 4;
        const PRODUCERS: usize = 4;
        const PER_PRODUCER: usize = 500;
        let s: Arc<Scheduler<usize>> = Arc::new(Scheduler::new(SHARDS));
        let delivered = Arc::new(AtomicUsize::new(0));
        let owners: Arc<Vec<AtomicUsize>> =
            Arc::new((0..SHARDS).map(|_| AtomicUsize::new(0)).collect());

        let consumers: Vec<_> = (0..SHARDS)
            .map(|me| {
                let s = Arc::clone(&s);
                let delivered = Arc::clone(&delivered);
                let owners = Arc::clone(&owners);
                std::thread::spawn(move || {
                    while let Some(shard) = s.acquire(me, true) {
                        // exactly-one-owner invariant
                        assert_eq!(
                            owners[shard].fetch_add(1, Ordering::SeqCst),
                            0,
                            "shard {shard} drained concurrently"
                        );
                        let batch = s.drain(shard, 7);
                        delivered.fetch_add(batch.len(), Ordering::SeqCst);
                        owners[shard].fetch_sub(1, Ordering::SeqCst);
                        s.release(shard);
                    }
                })
            })
            .collect();

        let producers: Vec<_> = (0..PRODUCERS)
            .map(|p| {
                let s = Arc::clone(&s);
                std::thread::spawn(move || {
                    for i in 0..PER_PRODUCER {
                        s.submit((p + i) % SHARDS, p * PER_PRODUCER + i);
                    }
                })
            })
            .collect();
        for p in producers {
            p.join().unwrap();
        }
        // wait for the fleet to drain, then close
        while delivered.load(Ordering::SeqCst) < PRODUCERS * PER_PRODUCER {
            std::thread::yield_now();
        }
        s.close();
        for c in consumers {
            c.join().unwrap();
        }
        assert_eq!(delivered.load(Ordering::SeqCst), PRODUCERS * PER_PRODUCER);
        for sh in 0..SHARDS {
            assert_eq!(s.depth(sh), 0);
        }
    }
}
