//! Fleet workers: one OS thread per device, each exclusively owning a
//! [`Device`] (a `DrimService` in the default fleet) and draining device
//! queues from the shared [`Scheduler`].
//!
//! A worker prefers its own device's queue; when that queue is empty it
//! steals the oldest backlogged device queue (if stealing is enabled) and
//! executes those requests on *its own* device — materialized payloads
//! travel with the task, so any device can serve any admitted request, and
//! stealing converts fleet-level imbalance into extra utilization instead
//! of tail latency.
//!
//! Tasks are *wave groups*: one or more requests the submission pipeline
//! decided should execute as one co-scheduled wave set (a singleton group
//! is the uncoalesced case). Drains are batch-aware — budgeted in wave
//! units rather than task count — and each group is dispatched through
//! [`Device::submit_batch`], so a coalesced group's chunks pack into
//! shared waves and every member's response reports the shared wave set's
//! completion.
//!
//! Copy accounting happens here, not at submit time: a placement-routed
//! item carries its [`Placement`] summary, and the worker charges the
//! [`LocalityModel`] against *its own* device id — so a stolen task is
//! charged for the operands its new executor has to pull, and a task that
//! landed on its operands' owner is charged nothing. This holds per item
//! inside a wave group: coalescing never changes what a request pays for
//! operand movement.

use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::Instant;

use crate::coordinator::{BatchPolicy, BulkRequest, BulkResponse, Device};
use crate::obs::trace::{Stage, Tracer};

use super::admission::AdmissionController;
use super::coalescer::Coalescer;
use super::metrics::FleetMetrics;
use super::movement::MovementFabric;
use super::residency::{LocalityModel, Placement, ResidencyRegistry};
use super::scheduler::Scheduler;
use super::topology::DeviceId;

/// One admitted request flowing through the fleet (a member of a
/// [`ClusterTask`] wave group).
pub struct TaskItem {
    /// fleet-wide submission sequence number
    pub seq: u64,
    /// the materialized request
    pub req: BulkRequest,
    /// operand-residency summary for placement-routed requests (`None`
    /// for the legacy payload-carrying paths, which are not copy-charged)
    pub placement: Option<Placement>,
    /// where the response goes
    pub reply: Sender<ClusterResponse>,
    /// when the admission ticket was bought (queue-wait accounting; for a
    /// coalesced item this includes time staged in the coalescer)
    pub admitted_at: Instant,
}

/// One schedulable unit on a device queue: a group of admitted requests
/// that execute as one co-scheduled wave set. A singleton group is the
/// ordinary uncoalesced request; a larger group was packed by the fleet
/// [`Coalescer`] (same op, co-resident or inline operands, one home).
pub struct ClusterTask {
    /// device whose admission tickets every item in the group holds
    pub home: DeviceId,
    /// the grouped requests, in admission order (never empty)
    pub items: Vec<TaskItem>,
}

impl ClusterTask {
    /// Wrap a single request as its own wave group.
    pub fn single(home: DeviceId, item: TaskItem) -> Self {
        ClusterTask {
            home,
            items: vec![item],
        }
    }

    /// Requests in the group.
    pub fn requests(&self) -> usize {
        self.items.len()
    }

    /// Total wave units (row chunks on a `cols`-column device) the group
    /// occupies — the cost the batch-aware drain budgets against.
    pub fn wave_units(&self, cols: usize) -> usize {
        self.items.iter().map(|i| i.req.wave_units(cols)).sum()
    }
}

/// A fleet response: the single-device [`BulkResponse`] plus where it ran.
#[derive(Clone, Debug)]
pub struct ClusterResponse {
    /// fleet-wide submission sequence number
    pub seq: u64,
    /// device that executed the request (≠ `home` when stolen)
    pub device: DeviceId,
    /// device whose queue the request entered
    pub home: DeviceId,
    /// the device-level response (`inner.batched_with > 1` ⇔ coalesced)
    pub inner: BulkResponse,
}

/// Wave groups drained per scheduler acquisition. Small enough that a
/// stolen batch doesn't starve the home worker when it comes back, large
/// enough to amortize ready-list traffic.
pub const DRAIN_BATCH: usize = 8;

/// Wave-unit budget per drain, in multiples of the executor's wave slots:
/// a drain stops early once the drained groups would occupy this many
/// waves, so one acquisition's in-flight chunk footprint stays bounded no
/// matter how many requests were packed per group.
pub const DRAIN_WAVE_BUDGET: usize = 8;

/// Shared fleet handles a worker drives its device with (grouped so the
/// thread spawn site stays readable).
pub(crate) struct WorkerCtx {
    pub sched: Arc<Scheduler<ClusterTask>>,
    pub admission: Arc<AdmissionController>,
    pub fleet: Arc<FleetMetrics>,
    pub locality: Arc<LocalityModel>,
    pub registry: Arc<ResidencyRegistry>,
    pub coalescer: Arc<Coalescer>,
    pub fabric: Arc<MovementFabric>,
    pub tracer: Arc<Tracer>,
    pub steal: bool,
}

/// Body of a fleet worker thread. Runs until the scheduler is closed and
/// drained, then shuts the device down.
pub(crate) fn worker_loop<D: Device>(me: DeviceId, mut device: D, ctx: WorkerCtx) {
    let geom = device.service_config().geometry.clone();
    // an Immediate-policy device never shares waves (its submit_batch
    // degrades to per-request attribution), so no saving may be recorded
    let shares_waves = device.service_config().policy == BatchPolicy::Coalesce;
    let cols = geom.cols;
    let slots = (geom.banks * geom.active_subarrays).max(1);
    // Per-worker scratch, reused across acquisitions: once these reach
    // steady-state capacity the drain → submit → reassemble cycle
    // allocates nothing of its own (the per-group request/meta vectors
    // are the exception — ownership moves into the device with them).
    let mut batch: Vec<ClusterTask> = Vec::with_capacity(DRAIN_BATCH);
    let mut inflight = Vec::with_capacity(DRAIN_BATCH);
    let mut counts: Vec<usize> = Vec::new();
    let mut responses: Vec<BulkResponse> = Vec::new();
    while let Some(shard) = ctx.sched.acquire(me.0, ctx.steal) {
        if shard != me.0 {
            ctx.fleet.record_steal();
        }
        // Settle prefetched landing hops queued for the device whose
        // queue is being drained: the copy engine finished warming its
        // rows up behind execution, so the nanoseconds stay hidden, and
        // the traffic is attributed to the *owning* device — the shard
        // drained, not the thread draining it — exactly the discipline
        // copy charging uses under stealing.
        for m in ctx.fabric.drain_for(DeviceId(shard)) {
            ctx.fleet.record_movement(shard, m.tier, &m.charge, true);
            ctx.tracer.instant_with_dur(
                shard as u32,
                Stage::Copy,
                m.region.0,
                m.charge.ns.round() as u64,
                m.charge.bytes,
            );
        }
        // Submit every drained group before collecting: the device sees
        // the whole drain in flight at once, so its internal workers
        // overlap chunk execution across requests (blocking run() per
        // group would serialize them and waste the device's own
        // parallelism). Collecting in drain order keeps per-queue FIFO
        // responses.
        let t_drain = if ctx.tracer.active() { ctx.tracer.now_ns() } else { 0 };
        batch.clear();
        ctx.sched.drain_budgeted_into(
            shard,
            DRAIN_BATCH,
            DRAIN_WAVE_BUDGET * slots,
            |t: &ClusterTask| t.wave_units(cols),
            &mut batch,
        );
        if let Some(first) = batch.first().and_then(|t| t.items.first()) {
            // the drain span is correlated with its first member so it
            // samples together with that request's other stages
            ctx.tracer
                .span(me.0 as u32, Stage::Drain, first.seq, t_drain, batch.len() as u64);
        }
        for task in batch.drain(..) {
            if shares_waves && task.items.len() > 1 {
                // the group shares one wave set on *this* executor:
                // account the waves its members' private round-ups
                // would have burned
                counts.clear();
                counts.extend(task.items.iter().map(|i| i.req.wave_units(cols)));
                let separate: u64 =
                    counts.iter().map(|&c| c.div_ceil(slots) as u64).sum();
                let packed = counts.iter().sum::<usize>().div_ceil(slots) as u64;
                ctx.fleet.record_coalesced(
                    task.items.len() as u64,
                    separate.saturating_sub(packed),
                );
            }
            let home = task.home;
            let group_seq = task.items[0].seq;
            let group_waves = task.wave_units(cols).div_ceil(slots) as u64;
            let mut reqs = Vec::with_capacity(task.items.len());
            let mut metas = Vec::with_capacity(task.items.len());
            for item in task.items {
                // sojourn attributes queueing pressure to the *home*
                // queue (not the executor — a stolen task waited on its
                // home device's backlog)
                ctx.fleet.record_queue_wait_ns(
                    home.0,
                    item.admitted_at.elapsed().as_nanos() as f64,
                );
                if let Some(p) = &item.placement {
                    // charge operand movement against the device that
                    // actually executes (correct under stealing)
                    let charge = ctx.locality.charge(p, me);
                    if !charge.is_free() {
                        // dur is the *simulated* transfer time, stamped
                        // at the host instant the copy was charged
                        ctx.tracer.instant_with_dur(
                            me.0 as u32,
                            Stage::Copy,
                            item.seq,
                            charge.ns.round() as u64,
                            charge.bytes,
                        );
                    }
                    ctx.fleet.record_copy(me.0, &charge);
                    // per-region traffic feeds the replication policy's
                    // observation window (hit = a replica was here)
                    for span in &p.resident {
                        ctx.fleet
                            .record_region_use(span.region, span.replicas.contains(&me));
                    }
                }
                reqs.push(item.req);
                metas.push((item.seq, item.placement, item.reply));
            }
            let t_submit = if ctx.tracer.active() { ctx.tracer.now_ns() } else { 0 };
            let rxs = device.submit_batch(reqs);
            inflight.push((home, metas, rxs, t_submit, group_seq, group_waves));
        }
        for (home, metas, rxs, t_submit, group_seq, group_waves) in inflight.drain(..) {
            // collect the whole group before forwarding, so the
            // wave-execute span ends at the group's last response and the
            // reassemble span covers only the forwarding work
            let members = metas.len();
            responses.clear();
            for rx in rxs {
                responses.push(rx.recv().expect("device dropped mid-request"));
            }
            ctx.tracer
                .span(me.0 as u32, Stage::WaveExecute, group_seq, t_submit, group_waves);
            let t_reassemble = if ctx.tracer.active() { ctx.tracer.now_ns() } else { 0 };
            for ((seq, placement, reply), inner) in metas.into_iter().zip(responses.drain(..)) {
                if let Some(p) = &placement {
                    // the request no longer pins its resident regions
                    // against admission-aware eviction
                    ctx.registry.release_queued(p);
                }
                ctx.admission.complete(home);
                ctx.fleet.record_completed();
                // a dropped receiver just means the client went away
                let _ = reply.send(ClusterResponse {
                    seq,
                    device: me,
                    home,
                    inner,
                });
            }
            ctx.tracer.span(
                me.0 as u32,
                Stage::Reassemble,
                group_seq,
                t_reassemble,
                members as u64,
            );
        }
        ctx.sched.release(shard);
        // The drained queue ran dry: anything still staged for this
        // device would otherwise sit while the device idles — the eager
        // leg of the coalescer's flush policy dispatches it now. (Strict
        // staging leaves holds to the horizon / an explicit flush so
        // burst drivers get deterministic packing.)
        if ctx.coalescer.config().enabled
            && ctx.coalescer.config().eager_when_idle
            && ctx.sched.depth(shard) == 0
        {
            for task in ctx.coalescer.flush_device(DeviceId(shard)) {
                ctx.sched.submit(shard, task);
            }
        }
    }
    device.shutdown();
}
