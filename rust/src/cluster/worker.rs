//! Fleet workers: one OS thread per device, each exclusively owning a
//! [`Device`] (a `DrimService` in the default fleet) and draining device
//! queues from the shared [`Scheduler`].
//!
//! A worker prefers its own device's queue; when that queue is empty it
//! steals the oldest backlogged device queue (if stealing is enabled) and
//! executes those requests on *its own* device — materialized payloads
//! travel with the task, so any device can serve any admitted request, and
//! stealing converts fleet-level imbalance into extra utilization instead
//! of tail latency.
//!
//! Copy accounting happens here, not at submit time: a placement-routed
//! task carries its [`Placement`] summary, and the worker charges the
//! [`LocalityModel`] against *its own* device id — so a stolen task is
//! charged for the operands its new executor has to pull, and a task that
//! landed on its operands' owner is charged nothing.

use std::sync::mpsc::Sender;
use std::sync::Arc;
use std::time::Instant;

use crate::coordinator::{BulkRequest, BulkResponse, Device};

use super::admission::AdmissionController;
use super::metrics::FleetMetrics;
use super::residency::{LocalityModel, Placement};
use super::scheduler::Scheduler;
use super::topology::DeviceId;

/// One admitted request in flight through the fleet.
pub struct ClusterTask {
    /// fleet-wide submission sequence number
    pub seq: u64,
    /// device whose admission ticket this request holds
    pub home: DeviceId,
    pub req: BulkRequest,
    /// operand-residency summary for placement-routed requests (`None`
    /// for the legacy payload-carrying paths, which are not copy-charged)
    pub placement: Option<Placement>,
    pub reply: Sender<ClusterResponse>,
    pub admitted_at: Instant,
}

/// A fleet response: the single-device [`BulkResponse`] plus where it ran.
#[derive(Clone, Debug)]
pub struct ClusterResponse {
    pub seq: u64,
    /// device that executed the request (≠ `home` when stolen)
    pub device: DeviceId,
    pub home: DeviceId,
    pub inner: BulkResponse,
}

/// Tasks drained per scheduler acquisition. Small enough that a stolen
/// batch doesn't starve the home worker when it comes back, large enough
/// to amortize ready-list traffic.
pub const DRAIN_BATCH: usize = 8;

/// Body of a fleet worker thread. Runs until the scheduler is closed and
/// drained, then shuts the device down.
pub(crate) fn worker_loop<D: Device>(
    me: DeviceId,
    mut device: D,
    sched: Arc<Scheduler<ClusterTask>>,
    admission: Arc<AdmissionController>,
    fleet: Arc<FleetMetrics>,
    locality: Arc<LocalityModel>,
    steal: bool,
) {
    while let Some(shard) = sched.acquire(me.0, steal) {
        if shard != me.0 {
            fleet.record_steal();
        }
        // Submit the whole batch before collecting: the device sees up to
        // DRAIN_BATCH requests in flight at once, so its internal workers
        // overlap chunk execution across requests (blocking run() per task
        // would serialize them and waste the device's own parallelism).
        // Collecting in drain order keeps per-queue FIFO responses.
        let batch = sched.drain(shard, DRAIN_BATCH);
        let inflight: Vec<_> = batch
            .into_iter()
            .map(|task| {
                fleet.record_queue_wait_ns(task.admitted_at.elapsed().as_nanos() as f64);
                if let Some(p) = &task.placement {
                    // charge operand movement against the device that
                    // actually executes (correct under stealing)
                    fleet.record_copy(me.0, &locality.charge(p, me));
                    // per-region traffic feeds the replication policy's
                    // observation window (hit = a replica was here)
                    for span in &p.resident {
                        fleet.record_region_use(span.region, span.replicas.contains(&me));
                    }
                }
                let rx = device.submit(task.req);
                (task.seq, task.home, task.reply, rx)
            })
            .collect();
        for (seq, home, reply, rx) in inflight {
            let inner = rx.recv().expect("device dropped mid-request");
            admission.complete(home);
            fleet.record_completed();
            // a dropped receiver just means the client went away
            let _ = reply.send(ClusterResponse {
                seq,
                device: me,
                home,
                inner,
            });
        }
        sched.release(shard);
    }
    device.shutdown();
}
