//! The fleet-level wave coalescer: stage 2 of the submission pipeline
//! (admission → **coalesce** → drain → reassemble).
//!
//! DRIM's throughput comes from filling every bank × sub-array row slot
//! each wave, but a stream of sub-wave requests dispatched one per wave
//! set leaves most of the fleet's `Topology::total_wave_slots` empty —
//! exactly the utilization loss the wave model penalizes (SIMDRAM makes
//! the same point for bit-serial operation packing, Ambit for rows
//! activated per command). The coalescer closes the gap *before*
//! dispatch: admitted requests are normalized into wave units
//! (`BulkRequest::wave_units`) and compatible sub-wave items are packed
//! into full waves, one [`ClusterTask`] group per wave, which the worker
//! then executes through `Device::submit_batch` as a single co-scheduled
//! wave set.
//!
//! **Compatibility.** Items pack together only when they share the same
//! home device and the same [`BulkOp`], and every resident operand holds
//! a replica on that home (inline operands always qualify). An
//! incompatible or wave-filling item bypasses staging as a singleton
//! group — in particular a placement miss executes uncoalesced and is
//! charged its copy cost exactly as before. Groups never exceed one
//! wave's slots, so *packed items ≤ wave slots* is an invariant the
//! property suite checks.
//!
//! **Flush policy** — a staged item leaves the coalescer when:
//! 1. its bucket reaches a full wave (`Σ chunks == wave_slots`, or the
//!    next item would overflow it);
//! 2. the queue-depth trigger fires: the home device's whole admission
//!    ticket pool is claimed (staging must never sit on the fleet's last
//!    tickets while an `admit_wait` caller is parked), or — in eager
//!    mode — the home's queue is empty, so holding would idle the device;
//! 3. the max-hold horizon expires: every fleet submission ticks a
//!    logical clock, and no bucket may hold an item for more than
//!    `max_hold_submissions` ticks — latency never degrades unboundedly;
//! 4. the owner flushes explicitly (`DrimCluster::flush_coalesced`, used
//!    by burst drivers for deterministic packing, and by shutdown).
//!
//! In eager mode ([`CoalesceConfig::opportunistic`]) the fleet workers
//! add a safety leg: a worker that drains its queue dry dispatches the
//! device's staged items before parking, so a held item can never
//! outlive the backlog that justified holding it. Strict mode
//! ([`CoalesceConfig::strict`]) disables both eager legs for burst
//! drivers that flush explicitly — group membership then depends only on
//! submission order, which is what the ablation gates pin.

use std::collections::HashMap;
use std::sync::Mutex;

use crate::isa::program::BulkOp;

use super::topology::DeviceId;
use super::worker::{ClusterTask, TaskItem};

/// Staging knobs for the fleet coalescer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CoalesceConfig {
    /// route admitted sub-wave requests through the staging buckets at
    /// all (off = the pre-coalescing pipeline: every request is its own
    /// singleton group)
    pub enabled: bool,
    /// max fleet submissions a staged item may wait before its bucket
    /// force-flushes (the hold horizon; ≥ 1)
    pub max_hold_submissions: u64,
    /// flush a device's buckets whenever holding would idle it: at push
    /// when its queue is empty, and from its worker when the queue runs
    /// dry. Disable (strict mode) for burst drivers that flush
    /// explicitly and want fully deterministic packing.
    pub eager_when_idle: bool,
}

impl CoalesceConfig {
    /// Coalescing disabled (the default; every request dispatches alone).
    pub fn off() -> Self {
        CoalesceConfig {
            enabled: false,
            max_hold_submissions: 32,
            eager_when_idle: true,
        }
    }

    /// Strand-free staging for live traffic: holds only while the home
    /// device has backlog, bounded by the default hold horizon.
    pub fn opportunistic() -> Self {
        CoalesceConfig {
            enabled: true,
            max_hold_submissions: 32,
            eager_when_idle: true,
        }
    }

    /// Deterministic staging for burst drivers: items are held until a
    /// full wave, the hold horizon, admission saturation, or an explicit
    /// `DrimCluster::flush_coalesced` — never flushed early by idleness.
    pub fn strict(max_hold_submissions: u64) -> Self {
        CoalesceConfig {
            enabled: true,
            max_hold_submissions,
            eager_when_idle: false,
        }
    }
}

impl Default for CoalesceConfig {
    fn default() -> Self {
        CoalesceConfig::off()
    }
}

/// One staging bucket: compatible items bound for the same (device, op),
/// never holding more than one wave's worth of chunks.
#[derive(Default)]
struct Bucket {
    items: Vec<TaskItem>,
    chunks: usize,
    /// logical-clock reading when the oldest held item entered
    oldest_tick: u64,
}

struct Inner {
    /// logical clock: one tick per fleet submission routed through the
    /// coalescer (the hold horizon's time base)
    tick: u64,
    buckets: HashMap<(usize, BulkOp), Bucket>,
}

/// The staging stage itself: per-(device, op) buckets of admitted
/// sub-wave items, flushed as [`ClusterTask`] wave groups. Thread-safe;
/// owned by the `DrimCluster` and shared with its workers.
pub struct Coalescer {
    cfg: CoalesceConfig,
    /// wave slots per device (index = `DeviceId`)
    slots: Vec<usize>,
    inner: Mutex<Inner>,
}

impl Coalescer {
    /// Coalescer for a fleet whose device `d` exposes `wave_slots[d]`
    /// row slots per wave.
    pub fn new(cfg: CoalesceConfig, wave_slots: Vec<usize>) -> Self {
        assert!(
            cfg.max_hold_submissions >= 1,
            "a zero hold horizon would flush every push"
        );
        assert!(
            wave_slots.iter().all(|&s| s > 0),
            "every device needs at least one wave slot"
        );
        Coalescer {
            cfg,
            slots: wave_slots,
            inner: Mutex::new(Inner {
                tick: 0,
                buckets: HashMap::new(),
            }),
        }
    }

    /// The staging knobs this coalescer runs under.
    pub fn config(&self) -> CoalesceConfig {
        self.cfg
    }

    /// Wave slots of one device.
    pub fn wave_slots(&self, device: DeviceId) -> usize {
        self.slots[device.0]
    }

    /// Stage one admitted item bound for `home` (`chunks` = its wave
    /// units there) and return every wave group that became due — the
    /// caller submits them to the scheduler. `flush_home` is the
    /// saturation leg of the queue-depth trigger: when set, `home`'s
    /// buckets flush after the item lands (the cluster passes admission
    /// saturation here; eager mode's idle-home leg instead re-checks the
    /// queue depth *after* the push and calls [`Self::flush_device`], so
    /// it can never race a worker's drain-dry flush into stranding the
    /// item).
    ///
    /// An item bypasses staging as a singleton group when coalescing is
    /// disabled, the item is empty or wave-filling (`chunks == 0` or
    /// `chunks ≥ wave_slots(home)` — packing cannot save it a wave), or a
    /// resident operand has no replica on `home` (a miss keeps its
    /// private wave set and its copy charge).
    pub fn push(
        &self,
        home: DeviceId,
        item: TaskItem,
        chunks: usize,
        flush_home: bool,
    ) -> Vec<ClusterTask> {
        let mut due = Vec::new();
        self.push_into(home, item, chunks, flush_home, &mut due);
        due
    }

    /// [`Self::push`] appending the due groups to a caller-owned scratch
    /// vector instead of allocating one — the submission hot path reuses
    /// the same scratch across pushes, so a steady-state push allocates
    /// nothing of its own. The scratch is appended to, never cleared.
    pub fn push_into(
        &self,
        home: DeviceId,
        item: TaskItem,
        chunks: usize,
        flush_home: bool,
        due: &mut Vec<ClusterTask>,
    ) {
        let slots = self.slots[home.0];
        let co_resident = match &item.placement {
            Some(p) => p.co_resident_on(home),
            None => true,
        };
        let eligible = self.cfg.enabled && chunks > 0 && chunks < slots && co_resident;
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let now = inner.tick;
        if !eligible {
            due.push(ClusterTask::single(home, item));
        } else {
            let bucket = inner.buckets.entry((home.0, item.req.op)).or_default();
            // slot conservation: a bucket never holds more than one wave
            if !bucket.items.is_empty() && bucket.chunks + chunks > slots {
                due.push(Self::seal(home, bucket));
            }
            if bucket.items.is_empty() {
                bucket.oldest_tick = now;
            }
            bucket.chunks += chunks;
            bucket.items.push(item);
            if bucket.chunks == slots {
                due.push(Self::seal(home, bucket));
            }
        }
        if flush_home {
            Self::flush_device_locked(&mut inner, home, due);
        }
        // hold horizon: no bucket may hold an item older than the bound
        let horizon = self.cfg.max_hold_submissions;
        for (&(dev, _), bucket) in inner.buckets.iter_mut() {
            if !bucket.items.is_empty() && now - bucket.oldest_tick >= horizon {
                due.push(Self::seal(DeviceId(dev), bucket));
            }
        }
    }

    /// Flush every bucket staged for `device` (the worker's idle leg).
    pub fn flush_device(&self, device: DeviceId) -> Vec<ClusterTask> {
        let mut inner = self.inner.lock().unwrap();
        let mut due = Vec::new();
        Self::flush_device_locked(&mut inner, device, &mut due);
        due
    }

    /// Flush everything (shutdown, and burst drivers' end-of-burst
    /// `DrimCluster::flush_coalesced`).
    pub fn flush_all(&self) -> Vec<ClusterTask> {
        let mut inner = self.inner.lock().unwrap();
        let mut due = Vec::new();
        for (&(dev, _), bucket) in inner.buckets.iter_mut() {
            if !bucket.items.is_empty() {
                due.push(Self::seal(DeviceId(dev), bucket));
            }
        }
        due
    }

    /// Wave units currently staged in `device`'s bucket for `op` (0 when
    /// nothing is staged or coalescing is off). The routed-admission
    /// tiebreak probes this: among replica holders at equal queue depth,
    /// landing a request where its op's bucket is closest to a full wave
    /// finishes that wave instead of opening another one elsewhere.
    pub fn bucket_fill(&self, device: DeviceId, op: BulkOp) -> usize {
        if !self.cfg.enabled {
            return 0;
        }
        let inner = self.inner.lock().unwrap();
        inner
            .buckets
            .get(&(device.0, op))
            .map(|b| b.chunks)
            .unwrap_or(0)
    }

    /// Items currently staged (diagnostics and the property suite).
    pub fn held(&self) -> usize {
        let inner = self.inner.lock().unwrap();
        inner.buckets.values().map(|b| b.items.len()).sum()
    }

    /// Age of the oldest staged item in submission ticks (0 when empty) —
    /// the quantity the hold-horizon property bounds.
    pub fn max_held_age(&self) -> u64 {
        let inner = self.inner.lock().unwrap();
        inner
            .buckets
            .values()
            .filter(|b| !b.items.is_empty())
            .map(|b| inner.tick - b.oldest_tick)
            .max()
            .unwrap_or(0)
    }

    fn flush_device_locked(inner: &mut Inner, device: DeviceId, due: &mut Vec<ClusterTask>) {
        for (&(dev, _), bucket) in inner.buckets.iter_mut() {
            if dev == device.0 && !bucket.items.is_empty() {
                due.push(Self::seal(device, bucket));
            }
        }
    }

    /// Empty a bucket into one wave-group task.
    fn seal(home: DeviceId, bucket: &mut Bucket) -> ClusterTask {
        bucket.chunks = 0;
        ClusterTask {
            home,
            items: std::mem::take(&mut bucket.items),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::residency::Placement;
    use crate::coordinator::BulkRequest;
    use crate::util::bitrow::BitRow;
    use std::sync::mpsc::channel;
    use std::time::Instant;

    const COLS: usize = 256;
    const SLOTS: usize = 4;

    fn item(seq: u64, chunks: usize) -> TaskItem {
        item_op(seq, chunks, BulkOp::Not)
    }

    fn item_op(seq: u64, chunks: usize, op: BulkOp) -> TaskItem {
        let (tx, _rx) = channel();
        let operands: Vec<BitRow> = (0..op.arity())
            .map(|_| BitRow::zeros(chunks * COLS))
            .collect();
        TaskItem {
            seq,
            req: BulkRequest::bitwise(op, operands),
            placement: None,
            reply: tx,
            admitted_at: Instant::now(),
        }
    }

    fn coalescer(cfg: CoalesceConfig, devices: usize) -> Coalescer {
        Coalescer::new(cfg, vec![SLOTS; devices])
    }

    #[test]
    fn packs_sub_wave_items_into_one_full_wave() {
        let c = coalescer(CoalesceConfig::strict(64), 1);
        let d = DeviceId(0);
        assert!(c.push(d, item(1, 1), 1, false).is_empty());
        assert!(c.push(d, item(2, 1), 1, false).is_empty());
        assert!(c.push(d, item(3, 1), 1, false).is_empty());
        assert_eq!(c.held(), 3);
        // the fourth chunk completes the wave
        let due = c.push(d, item(4, 1), 1, false);
        assert_eq!(due.len(), 1);
        assert_eq!(due[0].home, d);
        assert_eq!(due[0].requests(), 4);
        assert_eq!(due[0].wave_units(COLS), SLOTS);
        assert_eq!(c.held(), 0);
    }

    #[test]
    fn overflow_seals_the_bucket_before_adding() {
        let c = coalescer(CoalesceConfig::strict(64), 1);
        let d = DeviceId(0);
        assert!(c.push(d, item(1, 3), 3, false).is_empty());
        // 3 + 2 > 4: the held 3-chunk group flushes, the 2-chunk stays
        let due = c.push(d, item(2, 2), 2, false);
        assert_eq!(due.len(), 1);
        assert_eq!(due[0].wave_units(COLS), 3);
        assert_eq!(c.held(), 1);
    }

    #[test]
    fn wave_filling_and_empty_items_bypass_staging() {
        let c = coalescer(CoalesceConfig::strict(64), 1);
        let d = DeviceId(0);
        for chunks in [SLOTS, SLOTS + 3, 0] {
            let due = c.push(d, item(9, chunks), chunks, false);
            assert_eq!(due.len(), 1, "{chunks} chunks must bypass");
            assert_eq!(due[0].requests(), 1);
        }
        assert_eq!(c.held(), 0);
    }

    #[test]
    fn disabled_coalescer_dispatches_singletons() {
        let c = coalescer(CoalesceConfig::off(), 1);
        let due = c.push(DeviceId(0), item(1, 1), 1, false);
        assert_eq!(due.len(), 1);
        assert_eq!(due[0].requests(), 1);
        assert_eq!(c.held(), 0);
    }

    #[test]
    fn ops_and_devices_bucket_separately() {
        let c = coalescer(CoalesceConfig::strict(64), 2);
        c.push(DeviceId(0), item_op(1, 1, BulkOp::Not), 1, false);
        c.push(DeviceId(0), item_op(2, 1, BulkOp::Xnor2), 1, false);
        c.push(DeviceId(1), item_op(3, 1, BulkOp::Not), 1, false);
        assert_eq!(c.held(), 3);
        // flushing one device leaves the other's staging intact
        let due = c.flush_device(DeviceId(0));
        assert_eq!(due.len(), 2, "one group per op bucket");
        assert!(due.iter().all(|t| t.home == DeviceId(0)));
        assert!(due.iter().all(|t| t.requests() == 1));
        assert_eq!(c.held(), 1);
        let rest = c.flush_all();
        assert_eq!(rest.len(), 1);
        assert_eq!(rest[0].home, DeviceId(1));
    }

    #[test]
    fn non_co_resident_items_are_never_staged() {
        let c = coalescer(CoalesceConfig::strict(64), 2);
        // resident on dev1 only, routed home dev0: a miss — bypasses
        let mut p = Placement::default();
        p.add_resident(
            crate::cluster::residency::RegionId(7),
            COLS as u64,
            vec![DeviceId(1)],
        );
        let mut it = item(1, 1);
        it.placement = Some(p);
        let due = c.push(DeviceId(0), it, 1, false);
        assert_eq!(due.len(), 1);
        assert_eq!(due[0].requests(), 1);
        assert_eq!(c.held(), 0);
    }

    #[test]
    fn hold_horizon_bounds_staging_age() {
        let c = coalescer(CoalesceConfig::strict(3), 2);
        // one lonely item on dev0, then unrelated traffic on dev1
        assert!(c.push(DeviceId(0), item(1, 1), 1, false).is_empty());
        assert!(c.push(DeviceId(1), item(2, 1), 1, false).is_empty());
        assert!(c.push(DeviceId(1), item(3, 1), 1, false).is_empty());
        assert!(c.max_held_age() < 3);
        // the fourth submission pushes dev0's item to age 3 = horizon:
        // it flushes even though its own bucket saw no traffic
        let due = c.push(DeviceId(1), item(4, 1), 1, false);
        assert_eq!(due.len(), 1);
        assert_eq!(due[0].home, DeviceId(0));
        assert_eq!(due[0].requests(), 1);
        assert!(c.max_held_age() < 3);
        assert_eq!(c.held(), 3, "dev1's younger items stay staged");
    }

    #[test]
    fn queue_depth_trigger_flushes_the_home_bucket() {
        let c = coalescer(CoalesceConfig::opportunistic(), 2);
        assert!(c.push(DeviceId(0), item(1, 1), 1, false).is_empty());
        // saturation / idle-home hint: the bucket flushes with the item
        let due = c.push(DeviceId(0), item(2, 1), 1, true);
        assert_eq!(due.len(), 1);
        assert_eq!(due[0].requests(), 2);
        assert_eq!(c.held(), 0);
    }

    #[test]
    fn bucket_fill_tracks_staged_chunks_per_device_and_op() {
        let c = coalescer(CoalesceConfig::strict(64), 2);
        assert_eq!(c.bucket_fill(DeviceId(0), BulkOp::Not), 0);
        c.push(DeviceId(0), item_op(1, 2, BulkOp::Not), 2, false);
        c.push(DeviceId(0), item_op(2, 1, BulkOp::Xnor2), 1, false);
        assert_eq!(c.bucket_fill(DeviceId(0), BulkOp::Not), 2);
        assert_eq!(c.bucket_fill(DeviceId(0), BulkOp::Xnor2), 1);
        assert_eq!(c.bucket_fill(DeviceId(1), BulkOp::Not), 0, "per-device");
        // sealing the bucket resets its fill
        c.flush_device(DeviceId(0));
        assert_eq!(c.bucket_fill(DeviceId(0), BulkOp::Not), 0);
        // a disabled coalescer always probes as empty
        let off = coalescer(CoalesceConfig::off(), 1);
        assert_eq!(off.bucket_fill(DeviceId(0), BulkOp::Not), 0);
    }

    #[test]
    #[should_panic(expected = "hold horizon")]
    fn zero_horizon_rejected() {
        Coalescer::new(
            CoalesceConfig {
                enabled: true,
                max_hold_submissions: 0,
                eager_when_idle: false,
            },
            vec![4],
        );
    }
}
