//! Admission control: bounded per-device queues with load-shedding
//! backpressure.
//!
//! Every request must buy a ticket before it may enter the fleet. The
//! controller spreads tickets round-robin across devices, skipping devices
//! whose in-flight count (admitted − completed) has reached the bound; if
//! *every* device is saturated the request is shed with
//! [`AdmissionError::Overloaded`] — the caller decides whether to retry,
//! degrade, or surface 503-style backpressure. Shedding at the door keeps
//! queue depth (and therefore tail latency) bounded no matter how hard the
//! front end pushes, which is the production behaviour the ROADMAP's
//! "heavy traffic" north star needs.

use std::fmt;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};

use super::topology::DeviceId;

#[derive(Clone, Copy, Debug)]
pub struct AdmissionConfig {
    /// Max in-flight (admitted, not yet completed) requests per device.
    pub max_inflight_per_device: usize,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig {
            max_inflight_per_device: 64,
        }
    }
}

/// Why a request was refused.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum AdmissionError {
    /// Every device queue is at its in-flight bound.
    Overloaded {
        devices: usize,
        max_inflight_per_device: usize,
    },
    /// The one device a pinned request targeted is at its bound (the rest
    /// of the fleet may be idle — rerouting is the caller's decision).
    DeviceSaturated {
        device: DeviceId,
        max_inflight_per_device: usize,
    },
}

impl fmt::Display for AdmissionError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            AdmissionError::Overloaded {
                devices,
                max_inflight_per_device,
            } => write!(
                f,
                "fleet overloaded: all {devices} devices at their \
                 {max_inflight_per_device}-request in-flight bound"
            ),
            AdmissionError::DeviceSaturated {
                device,
                max_inflight_per_device,
            } => write!(
                f,
                "{device} at its {max_inflight_per_device}-request \
                 in-flight bound (pinned request; fleet may have capacity)"
            ),
        }
    }
}

pub struct AdmissionController {
    cfg: AdmissionConfig,
    /// admitted − completed, per device
    inflight: Vec<AtomicUsize>,
    rr: AtomicUsize,
    pub admitted: AtomicU64,
    /// requests refused outright by `try_admit`/`try_admit_to` (one per
    /// refusal — blocking admits wait instead and are never counted here)
    pub shed: AtomicU64,
    /// requests that had to park in `admit_wait` before a slot freed
    pub waited: AtomicU64,
    /// parking lot for `admit_wait`: `complete` takes the lock before
    /// notifying so a waiter is either parked or sees the freed slot
    gate: Mutex<()>,
    cv: Condvar,
}

impl AdmissionController {
    pub fn new(devices: usize, cfg: AdmissionConfig) -> Self {
        assert!(devices > 0);
        assert!(cfg.max_inflight_per_device > 0);
        AdmissionController {
            cfg,
            inflight: (0..devices).map(|_| AtomicUsize::new(0)).collect(),
            rr: AtomicUsize::new(0),
            admitted: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            waited: AtomicU64::new(0),
            gate: Mutex::new(()),
            cv: Condvar::new(),
        }
    }

    pub fn config(&self) -> AdmissionConfig {
        self.cfg
    }

    pub fn devices(&self) -> usize {
        self.inflight.len()
    }

    /// Bounded increment of one device's in-flight count. Lock-free, so
    /// concurrent admitters can never push a device past its bound.
    fn claim(&self, device: usize) -> bool {
        self.inflight[device]
            .fetch_update(Ordering::SeqCst, Ordering::SeqCst, |v| {
                (v < self.cfg.max_inflight_per_device).then_some(v + 1)
            })
            .is_ok()
    }

    /// Is `device`'s whole ticket pool claimed right now? The submission
    /// pipeline's coalescer uses this as its queue-depth flush trigger:
    /// staged items hold admission tickets, and staging must never sit on
    /// a device's *last* tickets while an `admit_wait` caller is parked —
    /// the parked caller generates no submissions, so nothing else would
    /// ever advance the hold horizon. (Racy snapshot, like
    /// [`Self::inflight`]: a false reading only flushes early or one push
    /// late, never strands.)
    pub fn is_saturated(&self, device: DeviceId) -> bool {
        self.inflight(device) >= self.cfg.max_inflight_per_device
    }

    /// Claim a slot on the first unsaturated device, starting from the
    /// round-robin cursor. No counters touched.
    fn claim_any(&self) -> Option<DeviceId> {
        let n = self.inflight.len();
        let start = self.rr.fetch_add(1, Ordering::Relaxed) % n;
        (0..n)
            .map(|k| (start + k) % n)
            .find(|&d| self.claim(d))
            .map(DeviceId)
    }

    /// Try to admit one request; refusal is counted as a shed event.
    pub fn try_admit(&self) -> Result<DeviceId, AdmissionError> {
        match self.claim_any() {
            Some(d) => {
                self.admitted.fetch_add(1, Ordering::Relaxed);
                Ok(d)
            }
            None => {
                self.shed.fetch_add(1, Ordering::Relaxed);
                Err(AdmissionError::Overloaded {
                    devices: self.inflight.len(),
                    max_inflight_per_device: self.cfg.max_inflight_per_device,
                })
            }
        }
    }

    /// Admit with a placement preference: claim `device` if it has a free
    /// slot, otherwise fall back to any unsaturated device (round-robin).
    /// Sheds — and counts one shed — only when the *whole fleet* is full.
    /// This is the routed-submission path: residency makes `device` the
    /// cheapest executor, but a saturated owner should not refuse work the
    /// rest of the fleet can absorb (at a copy cost the worker will
    /// charge).
    pub fn try_admit_prefer(&self, device: DeviceId) -> Result<DeviceId, AdmissionError> {
        self.try_admit_prefer_any(&[device])
    }

    /// Claim a slot on the least-loaded of `candidates`, or `None` when
    /// every candidate is saturated. Ties on in-flight count break toward
    /// the *fullest* staged coalescer bucket (`fill`, higher = closer to
    /// dispatching a full wave — landing there finishes a wave instead of
    /// opening a new one), then toward the lowest id.
    fn claim_least_loaded(
        &self,
        candidates: &[DeviceId],
        fill: &dyn Fn(DeviceId) -> usize,
    ) -> Option<DeviceId> {
        let mut order: Vec<DeviceId> = candidates.to_vec();
        order.sort_by_key(|d| (self.inflight(*d), std::cmp::Reverse(fill(*d)), d.0));
        order.into_iter().find(|d| self.claim(d.0))
    }

    /// Admit preferring the least-loaded of several equally-cheap
    /// executors — the routed path when a request's operands are
    /// replicated, so any replica holder serves at zero copy cost.
    /// Falls back to any unsaturated device when every candidate is
    /// full; sheds only when the whole fleet is.
    pub fn try_admit_prefer_any(
        &self,
        candidates: &[DeviceId],
    ) -> Result<DeviceId, AdmissionError> {
        self.try_admit_prefer_any_with(candidates, &|_| 0)
    }

    /// [`Self::try_admit_prefer_any`] with a coalescer-awareness probe:
    /// `fill(d)` is how many wave units device `d` has staged for the
    /// request's op, and equal queue depth breaks toward the bucket
    /// closest to a full wave.
    pub fn try_admit_prefer_any_with(
        &self,
        candidates: &[DeviceId],
        fill: &dyn Fn(DeviceId) -> usize,
    ) -> Result<DeviceId, AdmissionError> {
        if let Some(d) = self.claim_least_loaded(candidates, fill) {
            self.admitted.fetch_add(1, Ordering::Relaxed);
            return Ok(d);
        }
        self.try_admit()
    }

    /// Blocking analogue of [`Self::try_admit_prefer_any`]: park until
    /// one of `candidates` frees a slot (never falls back to a
    /// non-candidate — the caller picked them because executing anywhere
    /// else pays a copy).
    pub fn admit_wait_any(&self, candidates: &[DeviceId]) -> DeviceId {
        self.admit_wait_any_with(candidates, &|_| 0)
    }

    /// [`Self::admit_wait_any`] with the coalescer-awareness probe of
    /// [`Self::try_admit_prefer_any_with`].
    pub fn admit_wait_any_with(
        &self,
        candidates: &[DeviceId],
        fill: &dyn Fn(DeviceId) -> usize,
    ) -> DeviceId {
        assert!(!candidates.is_empty(), "admit_wait_any needs a candidate");
        if let Some(d) = self.claim_least_loaded(candidates, fill) {
            self.admitted.fetch_add(1, Ordering::Relaxed);
            return d;
        }
        self.waited.fetch_add(1, Ordering::Relaxed);
        let mut g = self.gate.lock().unwrap();
        loop {
            if let Some(d) = self.claim_least_loaded(candidates, fill) {
                self.admitted.fetch_add(1, Ordering::Relaxed);
                return d;
            }
            g = self.cv.wait(g).unwrap();
        }
    }

    /// Like [`Self::try_admit`] but pinned to one device (data-residency
    /// style routing); still bounded and shed-counted.
    pub fn try_admit_to(&self, device: DeviceId) -> Result<DeviceId, AdmissionError> {
        if self.claim(device.0) {
            self.admitted.fetch_add(1, Ordering::Relaxed);
            Ok(device)
        } else {
            self.shed.fetch_add(1, Ordering::Relaxed);
            Err(AdmissionError::DeviceSaturated {
                device,
                max_inflight_per_device: self.cfg.max_inflight_per_device,
            })
        }
    }

    /// Admit, parking until a slot frees. Never sheds: callers that would
    /// rather wait than be refused are counted in `waited` (at most once
    /// per request) instead of inflating the shed metric.
    pub fn admit_wait(&self) -> DeviceId {
        if let Some(d) = self.claim_any() {
            self.admitted.fetch_add(1, Ordering::Relaxed);
            return d;
        }
        self.waited.fetch_add(1, Ordering::Relaxed);
        let mut g = self.gate.lock().unwrap();
        loop {
            if let Some(d) = self.claim_any() {
                self.admitted.fetch_add(1, Ordering::Relaxed);
                return d;
            }
            g = self.cv.wait(g).unwrap();
        }
    }

    /// Admit pinned to one device, parking until that device frees a slot.
    /// The blocking analogue of [`Self::try_admit_to`], used by routed
    /// submissions that must land on a specific executor (residency tests,
    /// forced-miss ablations).
    pub fn admit_wait_to(&self, device: DeviceId) -> DeviceId {
        if self.claim(device.0) {
            self.admitted.fetch_add(1, Ordering::Relaxed);
            return device;
        }
        self.waited.fetch_add(1, Ordering::Relaxed);
        let mut g = self.gate.lock().unwrap();
        loop {
            if self.claim(device.0) {
                self.admitted.fetch_add(1, Ordering::Relaxed);
                return device;
            }
            g = self.cv.wait(g).unwrap();
        }
    }

    /// Release the ticket owned by a finished (or abandoned) request.
    pub fn complete(&self, device: DeviceId) {
        let prev = self.inflight[device.0].fetch_sub(1, Ordering::SeqCst);
        debug_assert!(prev > 0, "complete() without a matching admit");
        // Lock-then-notify: a waiter holding the gate either re-checks
        // after this decrement or is already parked when the notify lands.
        drop(self.gate.lock().unwrap());
        self.cv.notify_all();
    }

    pub fn inflight(&self, device: DeviceId) -> usize {
        self.inflight[device.0].load(Ordering::SeqCst)
    }

    pub fn total_inflight(&self) -> usize {
        self.inflight.iter().map(|d| d.load(Ordering::SeqCst)).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_spreads_admissions() {
        let a = AdmissionController::new(4, AdmissionConfig::default());
        let targets: Vec<usize> = (0..8).map(|_| a.try_admit().unwrap().0).collect();
        assert_eq!(targets, vec![0, 1, 2, 3, 0, 1, 2, 3]);
        assert_eq!(a.total_inflight(), 8);
        for &t in &targets {
            a.complete(DeviceId(t));
        }
        assert_eq!(a.total_inflight(), 0);
    }

    #[test]
    fn sheds_only_when_every_device_is_full() {
        let a = AdmissionController::new(
            2,
            AdmissionConfig {
                max_inflight_per_device: 2,
            },
        );
        for _ in 0..4 {
            a.try_admit().unwrap();
        }
        let e = a.try_admit().unwrap_err();
        assert!(matches!(e, AdmissionError::Overloaded { devices: 2, .. }));
        assert_eq!(a.shed.load(Ordering::Relaxed), 1);
        // freeing one slot re-opens admission, on the freed device
        a.complete(DeviceId(1));
        assert_eq!(a.try_admit().unwrap(), DeviceId(1));
    }

    #[test]
    fn skips_saturated_devices() {
        let a = AdmissionController::new(
            2,
            AdmissionConfig {
                max_inflight_per_device: 1,
            },
        );
        assert_eq!(a.try_admit().unwrap(), DeviceId(0));
        // device 0 full → next round-robin start is 1 anyway; fill it
        assert_eq!(a.try_admit().unwrap(), DeviceId(1));
        a.complete(DeviceId(0));
        // cursor points at 0 after wrap; device 0 is the only free one
        assert_eq!(a.try_admit().unwrap(), DeviceId(0));
    }

    #[test]
    fn pinned_admission_bounds_single_device() {
        let a = AdmissionController::new(
            3,
            AdmissionConfig {
                max_inflight_per_device: 1,
            },
        );
        assert!(a.try_admit_to(DeviceId(2)).is_ok());
        let e = a.try_admit_to(DeviceId(2)).unwrap_err();
        // pinned saturation must not masquerade as fleet-wide overload
        assert!(matches!(
            e,
            AdmissionError::DeviceSaturated {
                device: DeviceId(2),
                ..
            }
        ));
        assert!(e.to_string().contains("dev2"), "{e}");
        assert_eq!(a.inflight(DeviceId(2)), 1);
        assert_eq!(a.inflight(DeviceId(0)), 0);
    }

    #[test]
    fn admit_wait_parks_until_a_slot_frees_and_never_sheds() {
        let a = std::sync::Arc::new(AdmissionController::new(
            1,
            AdmissionConfig {
                max_inflight_per_device: 1,
            },
        ));
        assert_eq!(a.admit_wait(), DeviceId(0)); // fast path, no wait
        assert_eq!(a.waited.load(Ordering::Relaxed), 0);
        let waiter = {
            let a = std::sync::Arc::clone(&a);
            std::thread::spawn(move || a.admit_wait())
        };
        // the waiter can't get a slot until we complete; give it time to
        // park so the completion path's wakeup is what releases it
        std::thread::sleep(std::time::Duration::from_millis(20));
        a.complete(DeviceId(0));
        assert_eq!(waiter.join().unwrap(), DeviceId(0));
        assert_eq!(a.shed.load(Ordering::Relaxed), 0, "waiting is not shedding");
        assert_eq!(a.waited.load(Ordering::Relaxed), 1);
        assert_eq!(a.admitted.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn prefer_claims_target_then_falls_back_without_shedding() {
        let a = AdmissionController::new(
            2,
            AdmissionConfig {
                max_inflight_per_device: 1,
            },
        );
        // preferred device free → claimed directly
        assert_eq!(a.try_admit_prefer(DeviceId(1)).unwrap(), DeviceId(1));
        // preferred full, fleet not → falls back, no shed counted
        assert_eq!(a.try_admit_prefer(DeviceId(1)).unwrap(), DeviceId(0));
        assert_eq!(a.shed.load(Ordering::Relaxed), 0);
        // whole fleet full → sheds exactly once
        let e = a.try_admit_prefer(DeviceId(1)).unwrap_err();
        assert!(matches!(e, AdmissionError::Overloaded { .. }));
        assert_eq!(a.shed.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn admit_wait_to_parks_until_the_pinned_device_frees() {
        let a = std::sync::Arc::new(AdmissionController::new(
            2,
            AdmissionConfig {
                max_inflight_per_device: 1,
            },
        ));
        assert_eq!(a.admit_wait_to(DeviceId(1)), DeviceId(1));
        let waiter = {
            let a = std::sync::Arc::clone(&a);
            std::thread::spawn(move || a.admit_wait_to(DeviceId(1)))
        };
        std::thread::sleep(std::time::Duration::from_millis(20));
        // freeing the *other* device must not release a pinned waiter
        assert_eq!(a.try_admit_to(DeviceId(0)).unwrap(), DeviceId(0));
        a.complete(DeviceId(0));
        std::thread::sleep(std::time::Duration::from_millis(10));
        assert_eq!(a.inflight(DeviceId(1)), 1, "waiter still parked");
        a.complete(DeviceId(1));
        assert_eq!(waiter.join().unwrap(), DeviceId(1));
        assert_eq!(a.waited.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn prefer_any_picks_least_loaded_candidate() {
        let a = AdmissionController::new(
            3,
            AdmissionConfig {
                max_inflight_per_device: 2,
            },
        );
        // load dev0 so the replica set {0, 2} resolves to dev2
        assert!(a.try_admit_to(DeviceId(0)).is_ok());
        let cands = [DeviceId(0), DeviceId(2)];
        assert_eq!(a.try_admit_prefer_any(&cands).unwrap(), DeviceId(2));
        // now both carry 1 → tie breaks toward the lowest id
        assert_eq!(a.try_admit_prefer_any(&cands).unwrap(), DeviceId(0));
        // candidates full → falls back to the rest of the fleet
        assert_eq!(a.try_admit_prefer_any(&cands).unwrap(), DeviceId(2));
        assert_eq!(a.try_admit_prefer_any(&cands).unwrap(), DeviceId(1));
        assert_eq!(a.shed.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn bucket_fill_breaks_equal_depth_ties_toward_the_fuller_wave() {
        let a = AdmissionController::new(
            3,
            AdmissionConfig {
                max_inflight_per_device: 2,
            },
        );
        let cands = [DeviceId(0), DeviceId(2)];
        // equal (zero) in-flight everywhere: dev2's staged bucket is one
        // chunk from a full wave, so it wins over the lower id
        let fill = |d: DeviceId| if d == DeviceId(2) { 3 } else { 1 };
        assert_eq!(a.try_admit_prefer_any_with(&cands, &fill).unwrap(), DeviceId(2));
        // load is still the primary key: dev2 now carries 1 in-flight,
        // so the emptier dev0 wins despite its emptier bucket
        assert_eq!(a.try_admit_prefer_any_with(&cands, &fill).unwrap(), DeviceId(0));
        // the zero-fill probe preserves the legacy lowest-id tiebreak
        assert_eq!(a.try_admit_prefer_any(&cands).unwrap(), DeviceId(0));
        // blocking analogue sees the same ordering
        assert_eq!(a.admit_wait_any_with(&cands, &fill), DeviceId(2));
        assert_eq!(a.shed.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn admit_wait_any_parks_until_a_candidate_frees() {
        let a = std::sync::Arc::new(AdmissionController::new(
            3,
            AdmissionConfig {
                max_inflight_per_device: 1,
            },
        ));
        assert_eq!(a.admit_wait_any(&[DeviceId(1), DeviceId(2)]), DeviceId(1));
        assert_eq!(a.admit_wait_any(&[DeviceId(1), DeviceId(2)]), DeviceId(2));
        let waiter = {
            let a = std::sync::Arc::clone(&a);
            std::thread::spawn(move || a.admit_wait_any(&[DeviceId(1), DeviceId(2)]))
        };
        std::thread::sleep(std::time::Duration::from_millis(20));
        // freeing a non-candidate must not release the waiter
        assert_eq!(a.try_admit_to(DeviceId(0)).unwrap(), DeviceId(0));
        a.complete(DeviceId(0));
        std::thread::sleep(std::time::Duration::from_millis(10));
        assert_eq!(a.inflight(DeviceId(1)), 1, "waiter still parked");
        a.complete(DeviceId(2));
        assert_eq!(waiter.join().unwrap(), DeviceId(2));
        assert_eq!(a.waited.load(Ordering::Relaxed), 1);
        assert_eq!(a.shed.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn saturation_probe_tracks_the_ticket_pool() {
        let a = AdmissionController::new(
            2,
            AdmissionConfig {
                max_inflight_per_device: 2,
            },
        );
        assert!(!a.is_saturated(DeviceId(0)));
        a.try_admit_to(DeviceId(0)).unwrap();
        assert!(!a.is_saturated(DeviceId(0)));
        a.try_admit_to(DeviceId(0)).unwrap();
        assert!(a.is_saturated(DeviceId(0)));
        assert!(!a.is_saturated(DeviceId(1)), "per-device, not fleet-wide");
        a.complete(DeviceId(0));
        assert!(!a.is_saturated(DeviceId(0)));
    }

    #[test]
    fn error_message_is_actionable() {
        let a = AdmissionController::new(
            1,
            AdmissionConfig {
                max_inflight_per_device: 1,
            },
        );
        a.try_admit().unwrap();
        let msg = a.try_admit().unwrap_err().to_string();
        assert!(msg.contains("overloaded"), "{msg}");
        assert!(msg.contains("1 devices"), "{msg}");
    }
}
