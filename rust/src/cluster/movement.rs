//! The in-DRAM movement fabric: how bulk placement movement (replication,
//! migration, eviction re-staging) is priced and when it is charged.
//!
//! The RowClone/Ambit line showed that bulk row copy inside DRAM costs
//! roughly one activation pair when source and destination share a
//! sub-array, and never touches the external bus — yet a fleet that prices
//! every movement as a DDR burst stream pays von-Neumann prices for data
//! that never left the chip. This module adds two orthogonal switches on
//! top of the tier model in `dram::timing`:
//!
//! * **Pricing** ([`MovementConfig::in_dram`]): the landing hop of a
//!   placement movement (staging row → the region's pinned row, see
//!   `ResidencyRegistry` pins) is priced either as an external read-out +
//!   write-in round trip over the bus, or by the RowClone tier of its
//!   pinned coordinate at zero bus cycles.
//! * **Overlap** ([`MovementConfig::prefetch`]): landing hops are either
//!   charged synchronously where they are issued, or enqueued on the
//!   [`MovementFabric`] and settled later by the worker that next drains
//!   the destination device's queue — modelling a copy engine that warms
//!   rows up behind execution. Settled hops attribute their traffic to the
//!   *owning* device (the queue drained, not the thread draining it — the
//!   same discipline worker-side copy charging uses under stealing) and
//!   their nanoseconds to a fleet-wide hidden-prefetch counter instead of
//!   any device's visible copy time.
//!
//! Everything is off by default: with [`MovementConfig::Off`] no landing
//! hop is issued at all and the fleet behaves bit-identically to the
//! pre-fabric cost model.

use std::sync::Mutex;

use crate::dram::timing::MovementTier;

use super::residency::{CopyCharge, RegionId};
use super::topology::DeviceId;

/// How the movement fabric prices and schedules placement movement.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum MovementConfig {
    /// No landing hops are modeled at all — the pre-fabric behaviour
    /// (movement is priced by the inbound stream alone).
    #[default]
    Off,
    /// Landing hops are modeled and priced as external bus round trips,
    /// charged synchronously — the von-Neumann baseline the ablation
    /// compares against.
    External,
    /// Landing hops are priced by the RowClone tier of the destination
    /// pin (zero bus cycles), still charged synchronously.
    InDram,
    /// In-DRAM pricing, and hops overlap execution: enqueued on the
    /// [`MovementFabric`], settled by workers, nanoseconds hidden behind
    /// compute.
    Prefetch,
}

impl MovementConfig {
    /// Whether landing hops are modeled at all.
    pub fn enabled(self) -> bool {
        self != MovementConfig::Off
    }

    /// Whether hops are priced by the in-DRAM tiers (vs the external bus).
    pub fn in_dram(self) -> bool {
        matches!(self, MovementConfig::InDram | MovementConfig::Prefetch)
    }

    /// Whether hops overlap execution via the [`MovementFabric`].
    pub fn prefetch(self) -> bool {
        self == MovementConfig::Prefetch
    }

    /// Stable lowercase label (scenario knob values, JSON reports).
    pub fn name(self) -> &'static str {
        match self {
            MovementConfig::Off => "off",
            MovementConfig::External => "external",
            MovementConfig::InDram => "in_dram",
            MovementConfig::Prefetch => "prefetch",
        }
    }
}

/// Why a landing hop was issued (trace detail / debugging).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MovementKind {
    /// The `Evicted` → requeue path re-staged an operand region.
    Restage,
    /// The rebalancer added a replica.
    Replicate,
    /// The rebalancer re-homed a region.
    Migrate,
}

/// One landing hop waiting to be settled by the destination device's next
/// worker drain.
#[derive(Clone, Debug)]
pub struct PendingMovement {
    /// region whose rows are being landed
    pub region: RegionId,
    /// device the rows land on (traffic is attributed here)
    pub dest: DeviceId,
    /// pricing tier the hop was charged at
    pub tier: MovementTier,
    /// the priced charge (bytes, ns, bus cycles)
    pub charge: CopyCharge,
    /// which placement path issued the hop
    pub kind: MovementKind,
}

/// Per-device queues of landing hops issued ahead of execution
/// ([`MovementConfig::Prefetch`] only). Issue sites enqueue; the worker
/// that next drains a device's task queue settles that device's hops (so
/// attribution follows the owning device even when the drain was a steal),
/// and shutdown settles whatever never overlapped.
pub struct MovementFabric {
    queues: Mutex<Vec<Vec<PendingMovement>>>,
}

impl MovementFabric {
    /// Fabric for a `devices`-wide fleet.
    pub fn new(devices: usize) -> Self {
        MovementFabric {
            queues: Mutex::new((0..devices).map(|_| Vec::new()).collect()),
        }
    }

    /// Queue a landing hop for its destination device.
    pub fn enqueue(&self, movement: PendingMovement) {
        let mut q = self.queues.lock().unwrap();
        let dest = movement.dest.0;
        q[dest].push(movement);
    }

    /// Take every hop queued for `device` (the worker settle path).
    /// Allocation-free when the queue is empty.
    pub fn drain_for(&self, device: DeviceId) -> Vec<PendingMovement> {
        let mut q = self.queues.lock().unwrap();
        if q[device.0].is_empty() {
            return Vec::new();
        }
        std::mem::take(&mut q[device.0])
    }

    /// Take every queued hop, in device order (shutdown settle).
    pub fn drain_all(&self) -> Vec<PendingMovement> {
        let mut q = self.queues.lock().unwrap();
        let mut out = Vec::new();
        for queue in q.iter_mut() {
            out.append(queue);
        }
        out
    }

    /// Hops issued but not yet settled, fleet-wide.
    pub fn pending(&self) -> usize {
        self.queues.lock().unwrap().iter().map(Vec::len).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hop(region: u64, dest: usize) -> PendingMovement {
        PendingMovement {
            region: RegionId(region),
            dest: DeviceId(dest),
            tier: MovementTier::SameBank,
            charge: CopyCharge {
                bytes: 8,
                ns: 180.0,
                cycles: 0,
            },
            kind: MovementKind::Restage,
        }
    }

    #[test]
    fn config_switches_compose() {
        assert_eq!(MovementConfig::default(), MovementConfig::Off);
        assert!(!MovementConfig::Off.enabled());
        assert!(MovementConfig::External.enabled());
        assert!(!MovementConfig::External.in_dram());
        assert!(MovementConfig::InDram.in_dram());
        assert!(!MovementConfig::InDram.prefetch());
        assert!(MovementConfig::Prefetch.in_dram());
        assert!(MovementConfig::Prefetch.prefetch());
        let names: Vec<&str> = [
            MovementConfig::Off,
            MovementConfig::External,
            MovementConfig::InDram,
            MovementConfig::Prefetch,
        ]
        .iter()
        .map(|m| m.name())
        .collect();
        assert_eq!(names, ["off", "external", "in_dram", "prefetch"]);
    }

    #[test]
    fn fabric_drains_per_device_and_counts_pending() {
        let fabric = MovementFabric::new(3);
        assert_eq!(fabric.pending(), 0);
        fabric.enqueue(hop(1, 0));
        fabric.enqueue(hop(2, 2));
        fabric.enqueue(hop(3, 2));
        assert_eq!(fabric.pending(), 3);

        let d2 = fabric.drain_for(DeviceId(2));
        assert_eq!(d2.len(), 2);
        assert!(d2.iter().all(|m| m.dest == DeviceId(2)));
        assert_eq!(fabric.pending(), 1);
        assert!(fabric.drain_for(DeviceId(2)).is_empty());

        fabric.enqueue(hop(4, 1));
        let rest = fabric.drain_all();
        assert_eq!(rest.len(), 2);
        // device order: dev0's hop before dev1's
        assert_eq!(rest[0].dest, DeviceId(0));
        assert_eq!(rest[1].dest, DeviceId(1));
        assert_eq!(fabric.pending(), 0);
    }
}
