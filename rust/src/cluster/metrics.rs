//! Fleet-level metrics: merge per-device [`MetricsSnapshot`]s and add the
//! cluster-only counters (admission, shedding, stealing, queue wait, and
//! operand-copy traffic).
//!
//! Merge semantics: counters (requests, chunks, bits, AAPs) sum across
//! devices, and host wall time sums (workers really do burn those host
//! nanoseconds). Simulated DRAM time does *not* sum — devices run in
//! parallel, so the fleet's simulated makespan is the busiest device's
//! `sim_ns`, and fleet throughput is total result bits over that makespan.
//! That is exactly the quantity the 1→N scaling ablation compares.
//!
//! Copy accounting: placement-routed requests
//! ([`crate::cluster::ClusterRequest`]) are charged for every operand that
//! was not already resident on the executing device. Copied bytes and DDR
//! bus copy cycles sum fleet-wide; simulated copy *time* accrues per
//! executing device, and [`FleetSnapshot::makespan_with_copy_ns`] reports
//! the busiest device including that movement — the quantity the locality
//! ablation compares against pure compute makespan.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::coordinator::MetricsSnapshot;
use crate::dram::timing::{MovementTier, MOVEMENT_TIERS};
use crate::obs::json::Json;
use crate::obs::{Histogram, TelemetrySummary};
use crate::util::stats::{fmt_ns, fmt_rate};

use super::residency::{CopyCharge, RegionId};

/// One region's routed traffic within the current observation window —
/// the signal the replication policy plans from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RegionUse {
    /// the region referenced by routed requests
    pub region: RegionId,
    /// routed requests that referenced the region in the window
    pub uses: u64,
    /// uses that executed on a device holding no replica (copy-charged).
    /// The default policy amortizes against the *worst-case* miss stream
    /// rather than this observed count (spreading hot hit-traffic is as
    /// valuable as cutting misses); surfaced for observability and for
    /// miss-driven custom policies.
    pub misses: u64,
}

/// Merge per-device snapshots into one fleet view (see module docs for
/// which fields sum vs max).
pub fn merge_snapshots(parts: &[MetricsSnapshot]) -> MetricsSnapshot {
    let mut out = MetricsSnapshot {
        requests: 0,
        chunks: 0,
        result_bits: 0,
        aaps: 0,
        sim_ns: 0,
        wall_ns: 0,
        waves: 0,
        wave_slots_filled: 0,
        wave_slots_total: 0,
        mean_latency_ns: 0.0,
        max_latency_ns: 0.0,
        sim_throughput_bits_per_sec: 0.0,
        latency: Histogram::new(),
    };
    let mut latency_mass = 0.0;
    for p in parts {
        out.requests += p.requests;
        out.chunks += p.chunks;
        out.result_bits += p.result_bits;
        out.aaps += p.aaps;
        out.sim_ns = out.sim_ns.max(p.sim_ns);
        out.wall_ns += p.wall_ns;
        // waves and their slots sum: occupancy of the merged view is
        // filled-over-exposed across every device's wave sets
        out.waves += p.waves;
        out.wave_slots_filled += p.wave_slots_filled;
        out.wave_slots_total += p.wave_slots_total;
        // the histogram folds bucket-wise; mean/max stay derived from the
        // scalar fields so hand-built snapshots (tests, tools) merge
        // consistently even without a populated histogram
        out.latency.merge(&p.latency);
        latency_mass += p.mean_latency_ns * p.requests as f64;
        out.max_latency_ns = out.max_latency_ns.max(p.max_latency_ns);
    }
    if out.requests > 0 {
        out.mean_latency_ns = latency_mass / out.requests as f64;
    }
    if out.sim_ns > 0 {
        out.sim_throughput_bits_per_sec =
            out.result_bits as f64 / (out.sim_ns as f64 * 1e-9);
    }
    out
}

/// Cluster-only live counters (the per-device counters live inside each
/// device's `Metrics`).
pub struct FleetMetrics {
    pub completed: AtomicU64,
    /// batches a worker drained from another device's queue
    pub steals: AtomicU64,
    /// operand bytes moved for placement-routed requests (host→device and
    /// device→device)
    pub copied_bytes: AtomicU64,
    /// DDR bus clock cycles those moves occupied
    pub copy_cycles: AtomicU64,
    /// placement-routed requests whose operands were all already resident
    /// on the executing device (zero copy charge)
    pub resident_hits: AtomicU64,
    /// placement-routed requests charged a non-zero copy cost
    pub resident_misses: AtomicU64,
    /// replicas created by the replication policy
    pub replications: AtomicU64,
    /// migrations performed by the replication policy
    pub migrations: AtomicU64,
    /// requests that executed inside a shared wave group (≥ 2 members)
    pub coalesced_requests: AtomicU64,
    /// waves the coalescer's packing saved vs. per-request round-ups,
    /// evaluated against the executing device's wave slots
    pub waves_saved: AtomicU64,
    /// movement events per tier (`MOVEMENT_TIERS` order): operand pulls
    /// and placement streams count as `CrossDevice`, landing hops count at
    /// their pricing tier — so the tier decomposition always sums to the
    /// fleet totals
    tier_moves: [AtomicU64; 4],
    /// operand bytes moved per tier (`MOVEMENT_TIERS` order)
    tier_copied_bytes: [AtomicU64; 4],
    /// DDR bus clock cycles occupied per tier (in-DRAM tiers are always 0)
    tier_copy_cycles: [AtomicU64; 4],
    /// landing-hop nanoseconds hidden behind execution by the movement
    /// fabric's prefetch overlap (never charged to any device's visible
    /// copy time)
    prefetch_hidden_ns: AtomicU64,
    /// simulated copy nanoseconds charged to each device (index = DeviceId)
    copy_ns: Vec<AtomicU64>,
    /// host-side admission→pickup sojourn per *home* device (index =
    /// DeviceId of the queue the task was admitted to)
    queue_wait: Vec<Mutex<Histogram>>,
    /// per-region `(uses, misses)` since the window was last drained
    region_window: Mutex<HashMap<u64, (u64, u64)>>,
}

impl FleetMetrics {
    /// Counters for a fleet of `devices` devices.
    pub fn new(devices: usize) -> Self {
        FleetMetrics {
            completed: AtomicU64::new(0),
            steals: AtomicU64::new(0),
            copied_bytes: AtomicU64::new(0),
            copy_cycles: AtomicU64::new(0),
            resident_hits: AtomicU64::new(0),
            resident_misses: AtomicU64::new(0),
            replications: AtomicU64::new(0),
            migrations: AtomicU64::new(0),
            coalesced_requests: AtomicU64::new(0),
            waves_saved: AtomicU64::new(0),
            tier_moves: Default::default(),
            tier_copied_bytes: Default::default(),
            tier_copy_cycles: Default::default(),
            prefetch_hidden_ns: AtomicU64::new(0),
            copy_ns: (0..devices).map(|_| AtomicU64::new(0)).collect(),
            queue_wait: (0..devices.max(1))
                .map(|_| Mutex::new(Histogram::new()))
                .collect(),
            region_window: Mutex::new(HashMap::new()),
        }
    }

    pub fn record_completed(&self) {
        self.completed.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_steal(&self) {
        self.steals.fetch_add(1, Ordering::Relaxed);
    }

    /// Account one executed wave group of `requests` (≥ 2) members that
    /// saved `waves_saved` waves over per-request round-ups.
    pub fn record_coalesced(&self, requests: u64, waves_saved: u64) {
        self.coalesced_requests.fetch_add(requests, Ordering::Relaxed);
        self.waves_saved.fetch_add(waves_saved, Ordering::Relaxed);
    }

    /// Account one placement-routed request's copy charge against the
    /// device that executed it.
    pub fn record_copy(&self, device: usize, charge: &CopyCharge) {
        if charge.is_free() {
            self.resident_hits.fetch_add(1, Ordering::Relaxed);
        } else {
            self.resident_misses.fetch_add(1, Ordering::Relaxed);
            self.tier_account(MovementTier::CrossDevice, charge);
            self.copied_bytes.fetch_add(charge.bytes, Ordering::Relaxed);
            self.copy_cycles.fetch_add(charge.cycles, Ordering::Relaxed);
            self.copy_ns[device].fetch_add(charge.ns.round() as u64, Ordering::Relaxed);
        }
    }

    /// Account a policy-driven placement stream (replication/migration
    /// copy) against the destination device: copy traffic, but *not* a
    /// resident miss — placement copies are investments, not penalties,
    /// and must not dilute the hit-rate signal.
    pub fn record_placement_copy(&self, device: usize, charge: &CopyCharge) {
        if charge.is_free() {
            return;
        }
        self.tier_account(MovementTier::CrossDevice, charge);
        self.copied_bytes.fetch_add(charge.bytes, Ordering::Relaxed);
        self.copy_cycles.fetch_add(charge.cycles, Ordering::Relaxed);
        self.copy_ns[device].fetch_add(charge.ns.round() as u64, Ordering::Relaxed);
    }

    /// Bump the per-tier movement decomposition for one charged movement.
    fn tier_account(&self, tier: MovementTier, charge: &CopyCharge) {
        let i = tier.index();
        self.tier_moves[i].fetch_add(1, Ordering::Relaxed);
        self.tier_copied_bytes[i].fetch_add(charge.bytes, Ordering::Relaxed);
        self.tier_copy_cycles[i].fetch_add(charge.cycles, Ordering::Relaxed);
    }

    /// Account one movement-fabric landing hop against the *owning*
    /// destination device at its pricing `tier`. A `hidden` hop (prefetch
    /// overlap) banks its nanoseconds in the fleet-wide hidden counter
    /// instead of the device's visible copy time — bytes and bus cycles
    /// are real traffic either way and always count.
    pub fn record_movement(
        &self,
        device: usize,
        tier: MovementTier,
        charge: &CopyCharge,
        hidden: bool,
    ) {
        if charge.is_free() {
            return;
        }
        self.tier_account(tier, charge);
        self.copied_bytes.fetch_add(charge.bytes, Ordering::Relaxed);
        self.copy_cycles.fetch_add(charge.cycles, Ordering::Relaxed);
        let ns = charge.ns.round() as u64;
        if hidden {
            self.prefetch_hidden_ns.fetch_add(ns, Ordering::Relaxed);
        } else {
            self.copy_ns[device].fetch_add(ns, Ordering::Relaxed);
        }
    }

    /// Point-in-time per-tier movement decomposition.
    pub fn movement_snapshot(&self) -> MovementSnapshot {
        let load = |a: &[AtomicU64; 4]| {
            [
                a[0].load(Ordering::Relaxed),
                a[1].load(Ordering::Relaxed),
                a[2].load(Ordering::Relaxed),
                a[3].load(Ordering::Relaxed),
            ]
        };
        MovementSnapshot {
            moves: load(&self.tier_moves),
            copied_bytes: load(&self.tier_copied_bytes),
            copy_cycles: load(&self.tier_copy_cycles),
            prefetch_hidden_ns: self.prefetch_hidden_ns.load(Ordering::Relaxed),
        }
    }

    /// Count one routed use of `region` by its executing device (`hit` =
    /// a replica was already there). Feeds the replication policy's
    /// observation window.
    pub fn record_region_use(&self, region: RegionId, hit: bool) {
        let mut w = self.region_window.lock().unwrap();
        let e = w.entry(region.0).or_insert((0, 0));
        e.0 += 1;
        if !hit {
            e.1 += 1;
        }
    }

    /// Drain the observation window: per-region traffic since the last
    /// call, hottest first (ties toward the lowest region id, so policy
    /// decisions are deterministic).
    pub fn take_region_window(&self) -> Vec<RegionUse> {
        let mut w = self.region_window.lock().unwrap();
        let mut out: Vec<RegionUse> = w
            .drain()
            .map(|(r, (uses, misses))| RegionUse {
                region: RegionId(r),
                uses,
                misses,
            })
            .collect();
        out.sort_by(|a, b| b.uses.cmp(&a.uses).then(a.region.cmp(&b.region)));
        out
    }

    /// Simulated copy nanoseconds charged per device so far.
    pub fn copy_ns_per_device(&self) -> Vec<u64> {
        self.copy_ns
            .iter()
            .map(|c| c.load(Ordering::Relaxed))
            .collect()
    }

    /// Record one admission→pickup sojourn against the task's home
    /// device (the queue it was admitted to, not the worker that drained
    /// it — sojourn attributes queueing pressure, not execution).
    pub fn record_queue_wait_ns(&self, home: usize, ns: f64) {
        self.queue_wait[home.min(self.queue_wait.len() - 1)]
            .lock()
            .unwrap()
            .record(ns.max(0.0).round() as u64);
    }

    /// Per-home-device sojourn distributions (index = DeviceId).
    pub fn queue_wait_histograms(&self) -> Vec<Histogram> {
        self.queue_wait
            .iter()
            .map(|h| h.lock().unwrap().clone())
            .collect()
    }

    /// Fleet-wide sojourn distribution (all devices folded together).
    pub fn queue_wait_merged(&self) -> Histogram {
        let mut out = Histogram::new();
        for h in &self.queue_wait {
            out.merge(&h.lock().unwrap());
        }
        out
    }

    pub fn mean_queue_wait_ns(&self) -> f64 {
        self.queue_wait_merged().mean()
    }
}

/// Per-tier decomposition of the fleet's movement traffic, in
/// [`MOVEMENT_TIERS`] order (same-subarray, same-bank, same-device,
/// cross-device). Operand pulls and placement streams land in the
/// cross-device bucket; movement-fabric landing hops land at their pricing
/// tier — so each array sums to the corresponding fleet total.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct MovementSnapshot {
    /// charged movement events per tier
    pub moves: [u64; 4],
    /// operand bytes moved per tier
    pub copied_bytes: [u64; 4],
    /// DDR bus clock cycles occupied per tier (always 0 for in-DRAM tiers)
    pub copy_cycles: [u64; 4],
    /// landing-hop nanoseconds hidden behind execution by prefetch overlap
    pub prefetch_hidden_ns: u64,
}

impl MovementSnapshot {
    /// Movements priced by the in-DRAM tiers (everything but cross-device).
    pub fn in_dram_moves(&self) -> u64 {
        MOVEMENT_TIERS
            .iter()
            .filter(|t| t.is_in_dram())
            .map(|t| self.moves[t.index()])
            .sum()
    }

    /// Bytes moved by the in-DRAM tiers.
    pub fn in_dram_bytes(&self) -> u64 {
        MOVEMENT_TIERS
            .iter()
            .filter(|t| t.is_in_dram())
            .map(|t| self.copied_bytes[t.index()])
            .sum()
    }

    /// Charged movement events across every tier.
    pub fn total_moves(&self) -> u64 {
        self.moves.iter().sum()
    }

    /// Stable JSON form: `prefetch_hidden_ns` plus one object per tier in
    /// [`MOVEMENT_TIERS`] order.
    pub fn to_json(&self) -> Json {
        let tiers = MOVEMENT_TIERS
            .iter()
            .map(|t| {
                let i = t.index();
                Json::obj()
                    .field("tier", t.name())
                    .field("moves", self.moves[i])
                    .field("copied_bytes", self.copied_bytes[i])
                    .field("copy_cycles", self.copy_cycles[i])
            })
            .collect::<Vec<_>>();
        Json::obj()
            .field("prefetch_hidden_ns", self.prefetch_hidden_ns)
            .field("tiers", Json::Arr(tiers))
    }
}

/// Per-tenant admission/shed/sojourn breakdown — the fairness section of
/// fleet metrics. Recorded by the scenario executor's *virtual clock*
/// (arrival vtimes + simulated service), so the numbers are deterministic
/// for a fixed seed and safe to gate in CI, unlike host wall-clock
/// sojourn.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TenantBreakdown {
    pub tenant: String,
    /// requests the arrival stream generated for this tenant
    pub offered: u64,
    /// requests actually submitted (offered − shed)
    pub admitted: u64,
    /// requests refused by the tenant's inflight quota
    pub shed: u64,
    pub completed: u64,
    /// requests requeued after an eviction (degrade path)
    pub requeues: u64,
    /// requests that completed via the degrade-to-carried fallback after
    /// their resident region was evicted mid-stream (a subset of
    /// `completed`; conservation: `offered == admitted + shed` and
    /// `admitted == completed` with `degraded <= completed`)
    pub degraded: u64,
    /// mean simulated service time per completed request (coalesced
    /// groups charge each member its share)
    pub mean_service_ns: f64,
    /// mean virtual-clock sojourn: arrival → completion on the device's
    /// virtual timeline
    pub mean_sojourn_ns: f64,
    pub max_sojourn_ns: f64,
    /// `mean_sojourn / mean_service` — 1.0 means no queueing delay; the
    /// fairness gates bound this for light tenants sharing the fleet
    /// with heavy ones
    pub sojourn_inflation: f64,
}

impl TenantBreakdown {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .field("tenant", self.tenant.clone())
            .field("offered", self.offered)
            .field("admitted", self.admitted)
            .field("shed", self.shed)
            .field("completed", self.completed)
            .field("requeues", self.requeues)
            .field("degraded", self.degraded)
            .field("mean_service_ns", self.mean_service_ns)
            .field("mean_sojourn_ns", self.mean_sojourn_ns)
            .field("max_sojourn_ns", self.max_sojourn_ns)
            .field("sojourn_inflation", self.sojourn_inflation)
    }
}

/// Point-in-time view of the whole fleet.
#[derive(Clone, Debug)]
pub struct FleetSnapshot {
    pub per_device: Vec<MetricsSnapshot>,
    pub merged: MetricsSnapshot,
    pub admitted: u64,
    /// requests refused outright (`try_submit` backpressure)
    pub shed: u64,
    /// blocking submissions that had to park for a free slot
    pub waited: u64,
    pub completed: u64,
    pub steals: u64,
    /// operand bytes moved for placement-routed requests
    pub copied_bytes: u64,
    /// DDR bus clock cycles those moves occupied
    pub copy_cycles: u64,
    /// placement-routed requests with zero copy charge
    pub resident_hits: u64,
    /// placement-routed requests charged a non-zero copy cost
    pub resident_misses: u64,
    /// replica evictions performed by the registry's capacity policy
    pub evictions: u64,
    /// registrations/replications/migrations refused by capacity limits
    pub capacity_refusals: u64,
    /// replicas created by the replication policy
    pub replications: u64,
    /// migrations performed by the replication policy
    pub migrations: u64,
    /// requests that executed inside a shared wave group (≥ 2 members)
    pub coalesced_requests: u64,
    /// waves the coalescer's packing saved vs. per-request round-ups
    pub waves_saved: u64,
    /// per-tier movement decomposition (the in-DRAM movement fabric)
    pub movement: MovementSnapshot,
    /// simulated copy nanoseconds charged per device (index = DeviceId)
    pub copy_ns_per_device: Vec<u64>,
    /// host-side wait between admission and a worker picking the task up
    /// (for a coalesced request this includes time staged in the
    /// coalescer — the hold the flush horizon bounds)
    pub mean_queue_wait_ns: f64,
    /// fleet-wide sojourn distribution (all home devices folded)
    pub queue_wait: Histogram,
    /// sojourn distribution per home device (index = DeviceId)
    pub queue_wait_per_device: Vec<Histogram>,
    /// acknowledged eviction tombstones reclaimed by the residency
    /// registry's compaction (see `cluster/residency.rs`)
    pub tombstones_compacted: u64,
    /// per-tenant fairness breakdown — empty unless a scenario executor
    /// attached one via [`FleetSnapshot::with_fairness`]
    pub fairness: Vec<TenantBreakdown>,
    /// continuous-telemetry summary — all-zero/disabled unless a scenario
    /// executor attached its recorder via
    /// [`FleetSnapshot::with_telemetry`]
    pub telemetry: TelemetrySummary,
}

impl FleetSnapshot {
    pub fn devices(&self) -> usize {
        self.per_device.len()
    }

    /// Fleet simulated throughput (total bits / busiest-device makespan).
    pub fn sim_throughput_bits_per_sec(&self) -> f64 {
        self.merged.sim_throughput_bits_per_sec
    }

    /// Fleet-wide wave slot occupancy: chunks carried over row slots
    /// exposed, across every device's executed wave sets — the
    /// utilization the coalescing ablation gates on.
    pub fn slot_occupancy(&self) -> f64 {
        self.merged.slot_occupancy()
    }

    /// Fleet makespan including operand movement: the busiest device's
    /// compute time plus the copy time charged to it. Equals
    /// `merged.sim_ns` when every placement-routed request was a resident
    /// hit (the `it_residency` zero-copy gate).
    pub fn makespan_with_copy_ns(&self) -> u64 {
        self.per_device
            .iter()
            .zip(self.copy_ns_per_device.iter())
            .map(|(d, c)| d.sim_ns + c)
            .max()
            .unwrap_or(0)
    }

    /// Attach a per-tenant fairness breakdown (the scenario executor's
    /// virtual-clock accounting) to this snapshot.
    pub fn with_fairness(mut self, fairness: Vec<TenantBreakdown>) -> Self {
        self.fairness = fairness;
        self
    }

    /// Attach a continuous-telemetry summary (the scenario executor's
    /// virtual-clock time-series recorder) to this snapshot.
    pub fn with_telemetry(mut self, telemetry: TelemetrySummary) -> Self {
        self.telemetry = telemetry;
        self
    }

    /// The deterministic subset of [`FleetSnapshot::to_json`]: everything
    /// derived from the simulated timeline and counters, with every
    /// host-wall-clock quantity (`wall_ns`, `waited`, queue-sojourn
    /// distributions) stripped. Two runs of the same seeded scenario must
    /// produce byte-identical output here — the replay contract the CI
    /// determinism job diffs.
    pub fn to_deterministic_json(&self) -> Json {
        let per_device = self
            .per_device
            .iter()
            .enumerate()
            .map(|(i, d)| {
                Json::obj()
                    .field("device", i)
                    .field("requests", d.requests)
                    .field("chunks", d.chunks)
                    .field("result_bits", d.result_bits)
                    .field("aaps", d.aaps)
                    .field("sim_ns", d.sim_ns)
                    .field("waves", d.waves)
                    .field("copy_ns", *self.copy_ns_per_device.get(i).unwrap_or(&0))
            })
            .collect::<Vec<_>>();
        let fairness = self
            .fairness
            .iter()
            .map(TenantBreakdown::to_json)
            .collect::<Vec<_>>();
        Json::obj()
            .field("schema", 1u64)
            .field("devices", self.devices())
            .field("admitted", self.admitted)
            .field("shed", self.shed)
            .field("completed", self.completed)
            .field("copied_bytes", self.copied_bytes)
            .field("copy_cycles", self.copy_cycles)
            .field("resident_hits", self.resident_hits)
            .field("resident_misses", self.resident_misses)
            .field("evictions", self.evictions)
            .field("capacity_refusals", self.capacity_refusals)
            .field("replications", self.replications)
            .field("migrations", self.migrations)
            .field("coalesced_requests", self.coalesced_requests)
            .field("waves_saved", self.waves_saved)
            .field("movement", self.movement.to_json())
            .field("tombstones_compacted", self.tombstones_compacted)
            .field("makespan_ns", self.merged.sim_ns)
            .field("makespan_with_copy_ns", self.makespan_with_copy_ns())
            .field("waves", self.merged.waves)
            .field("wave_slots_filled", self.merged.wave_slots_filled)
            .field("wave_slots_total", self.merged.wave_slots_total)
            .field("telemetry", self.telemetry.to_json())
            .field("fairness", Json::Arr(fairness))
            .field("per_device", Json::Arr(per_device))
    }

    /// Stable JSON form — the payload behind `drim cluster --json`
    /// (schema: see docs/ARCHITECTURE.md § Observability).
    pub fn to_json(&self) -> Json {
        let per_device = self
            .per_device
            .iter()
            .enumerate()
            .map(|(i, d)| {
                let sojourn = self
                    .queue_wait_per_device
                    .get(i)
                    .cloned()
                    .unwrap_or_default();
                d.to_json()
                    .field("device", i)
                    .field("copy_ns", *self.copy_ns_per_device.get(i).unwrap_or(&0))
                    .field("queue_sojourn_ns", sojourn.summary_json())
            })
            .collect::<Vec<_>>();
        let mut doc = Json::obj()
            .field("schema", 1u64)
            .field("devices", self.devices())
            .field("admitted", self.admitted)
            .field("shed", self.shed)
            .field("waited", self.waited)
            .field("completed", self.completed)
            .field("steals", self.steals)
            .field("copied_bytes", self.copied_bytes)
            .field("copy_cycles", self.copy_cycles)
            .field("resident_hits", self.resident_hits)
            .field("resident_misses", self.resident_misses)
            .field("evictions", self.evictions)
            .field("capacity_refusals", self.capacity_refusals)
            .field("replications", self.replications)
            .field("migrations", self.migrations)
            .field("coalesced_requests", self.coalesced_requests)
            .field("waves_saved", self.waves_saved)
            .field("movement", self.movement.to_json())
            .field("tombstones_compacted", self.tombstones_compacted)
            .field("makespan_ns", self.merged.sim_ns)
            .field("makespan_with_copy_ns", self.makespan_with_copy_ns())
            .field("queue_sojourn_ns", self.queue_wait.summary_json())
            .field("telemetry", self.telemetry.to_json())
            .field("fleet", self.merged.to_json());
        // fairness rides along only when a scenario executor attached a
        // breakdown — plain `drim cluster` output keeps its pinned schema
        if !self.fairness.is_empty() {
            doc = doc.field(
                "fairness",
                Json::Arr(self.fairness.iter().map(TenantBreakdown::to_json).collect()),
            );
        }
        doc.field("per_device", Json::Arr(per_device))
    }

    pub fn report(&self) -> String {
        let (qp50, qp95, qp99) = self.queue_wait.p50_p95_p99();
        let mut s = format!(
            "fleet: {} devices  admitted: {}  shed: {}  waited: {}  \
             completed: {}  steals: {}  mean queue wait: {}\n\
             queue sojourn p50: {}  p95: {}  p99: {}\n\
             copy traffic: {} B  ({} bus cycles)  resident hits: {}  \
             misses: {}  makespan incl copy: {}\n\
             residency: evictions: {}  refusals: {}  replications: {}  \
             migrations: {}  tombstones compacted: {}\n\
             movement: {} in-DRAM moves ({} B) of {} total  \
             prefetch hidden: {}\n\
             waves: {}  slot occupancy: {:.1}%  coalesced requests: {}  \
             waves saved: {}\n",
            self.devices(),
            self.admitted,
            self.shed,
            self.waited,
            self.completed,
            self.steals,
            fmt_ns(self.mean_queue_wait_ns),
            fmt_ns(qp50),
            fmt_ns(qp95),
            fmt_ns(qp99),
            self.copied_bytes,
            self.copy_cycles,
            self.resident_hits,
            self.resident_misses,
            fmt_ns(self.makespan_with_copy_ns() as f64),
            self.evictions,
            self.capacity_refusals,
            self.replications,
            self.migrations,
            self.tombstones_compacted,
            self.movement.in_dram_moves(),
            self.movement.in_dram_bytes(),
            self.movement.total_moves(),
            fmt_ns(self.movement.prefetch_hidden_ns as f64),
            self.merged.waves,
            100.0 * self.slot_occupancy(),
            self.coalesced_requests,
            self.waves_saved,
        );
        for (i, d) in self.per_device.iter().enumerate() {
            s.push_str(&format!(
                "  dev{i}: {:>6} req  {:>8} chunks  sim {}  ({}bit/s)\n",
                d.requests,
                d.chunks,
                fmt_ns(d.sim_ns as f64),
                fmt_rate(d.sim_throughput_bits_per_sec),
            ));
        }
        s.push_str(&format!(
            "  fleet merged (makespan = busiest device):\n  {}",
            self.merged.report().replace('\n', "\n  ")
        ));
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(requests: u64, bits: u64, sim_ns: u64, mean_lat: f64) -> MetricsSnapshot {
        let mut latency = Histogram::new();
        for _ in 0..requests {
            latency.record(mean_lat.round() as u64);
        }
        MetricsSnapshot {
            requests,
            chunks: requests * 2,
            result_bits: bits,
            aaps: requests * 3,
            sim_ns,
            wall_ns: 10,
            waves: requests,
            wave_slots_filled: requests * 2,
            wave_slots_total: requests * 4,
            mean_latency_ns: mean_lat,
            max_latency_ns: mean_lat * 2.0,
            sim_throughput_bits_per_sec: 0.0,
            latency,
        }
    }

    #[test]
    fn merge_sums_counters_and_maxes_sim_time() {
        let m = merge_snapshots(&[snap(4, 4000, 100, 50.0), snap(12, 8000, 300, 150.0)]);
        assert_eq!(m.requests, 16);
        assert_eq!(m.chunks, 32);
        assert_eq!(m.result_bits, 12_000);
        assert_eq!(m.aaps, 48);
        assert_eq!(m.sim_ns, 300); // max, not sum: devices run in parallel
        assert_eq!(m.wall_ns, 20); // sum: host really spent it
        // wave counters sum; occupancy is filled over exposed fleet-wide
        assert_eq!(m.waves, 16);
        assert_eq!(m.wave_slots_filled, 32);
        assert_eq!(m.wave_slots_total, 64);
        assert!((m.slot_occupancy() - 0.5).abs() < 1e-12);
        // request-weighted mean: (4·50 + 12·150) / 16
        assert!((m.mean_latency_ns - 125.0).abs() < 1e-9);
        assert!((m.max_latency_ns - 300.0).abs() < 1e-9);
        // the distribution merged bucket-wise alongside the scalars
        assert_eq!(m.latency.count(), 16);
        assert!((m.latency.mean() - 125.0).abs() < 1e-9);
        // throughput over the makespan
        let want = 12_000.0 / (300.0 * 1e-9);
        assert!((m.sim_throughput_bits_per_sec - want).abs() / want < 1e-12);
    }

    #[test]
    fn merge_of_nothing_is_empty() {
        let m = merge_snapshots(&[]);
        assert_eq!(m.requests, 0);
        assert_eq!(m.sim_throughput_bits_per_sec, 0.0);
        assert_eq!(m.mean_latency_ns, 0.0);
    }

    #[test]
    fn merge_with_an_idle_device_is_unpolluted() {
        // A device that completed nothing must not skew the fleet view:
        // zero requests contribute zero latency mass (no NaN from the
        // 0-weighted mean), zero time, zero counters.
        let idle = snap(0, 0, 0, 0.0);
        let busy = snap(8, 6400, 200, 90.0);
        let m = merge_snapshots(&[idle.clone(), busy.clone(), idle]);
        assert_eq!(m.requests, 8);
        assert_eq!(m.result_bits, 6400);
        assert_eq!(m.sim_ns, 200);
        // mean is the busy device's mean, not dragged down by idle zeros
        assert!((m.mean_latency_ns - 90.0).abs() < 1e-9);
        assert!(m.mean_latency_ns.is_finite());
        let only_idle = merge_snapshots(&[snap(0, 0, 0, 0.0)]);
        assert_eq!(only_idle.requests, 0);
        assert_eq!(only_idle.mean_latency_ns, 0.0);
        assert_eq!(only_idle.sim_throughput_bits_per_sec, 0.0);
    }

    #[test]
    fn fleet_counters_and_report() {
        let f = FleetMetrics::new(1);
        f.record_completed();
        f.record_steal();
        f.record_queue_wait_ns(0, 500.0);
        f.record_queue_wait_ns(0, 1500.0);
        assert!((f.mean_queue_wait_ns() - 1000.0).abs() < 1e-9);
        assert_eq!(f.queue_wait_merged().count(), 2);
        let snapshot = FleetSnapshot {
            per_device: vec![snap(1, 100, 10, 5.0)],
            merged: merge_snapshots(&[snap(1, 100, 10, 5.0)]),
            admitted: 1,
            shed: 2,
            waited: 3,
            completed: 1,
            steals: 1,
            copied_bytes: 64,
            copy_cycles: 8,
            resident_hits: 4,
            resident_misses: 1,
            evictions: 3,
            capacity_refusals: 1,
            replications: 2,
            migrations: 1,
            coalesced_requests: 4,
            waves_saved: 3,
            movement: MovementSnapshot {
                moves: [2, 1, 0, 1],
                copied_bytes: [16, 8, 0, 40],
                copy_cycles: [0, 0, 0, 8],
                prefetch_hidden_ns: 270,
            },
            copy_ns_per_device: vec![30],
            mean_queue_wait_ns: 1000.0,
            queue_wait: f.queue_wait_merged(),
            queue_wait_per_device: f.queue_wait_histograms(),
            tombstones_compacted: 5,
            fairness: Vec::new(),
            telemetry: TelemetrySummary::default(),
        };
        let r = snapshot.report();
        assert!(r.contains("shed: 2"), "{r}");
        assert!(r.contains("dev0"), "{r}");
        assert!(r.contains("resident hits: 4"), "{r}");
        assert!(r.contains("evictions: 3"), "{r}");
        assert!(r.contains("replications: 2"), "{r}");
        assert!(r.contains("coalesced requests: 4"), "{r}");
        assert!(r.contains("waves saved: 3"), "{r}");
        assert!(r.contains("queue sojourn p50"), "{r}");
        assert!(r.contains("tombstones compacted: 5"), "{r}");
        assert!(r.contains("movement: 3 in-DRAM moves (24 B) of 4 total"), "{r}");
        // makespan incl copy = sim 10 + copy 30
        assert_eq!(snapshot.makespan_with_copy_ns(), 40);

        // --json payload: parseable, schema-tagged, percentiles present
        let doc = Json::parse(&snapshot.to_json().to_string_compact()).unwrap();
        assert_eq!(doc.get("schema").unwrap().as_f64(), Some(1.0));
        assert_eq!(doc.get("devices").unwrap().as_f64(), Some(1.0));
        assert_eq!(doc.get("tombstones_compacted").unwrap().as_f64(), Some(5.0));
        let movement = doc.get("movement").unwrap();
        assert_eq!(
            movement.get("prefetch_hidden_ns").unwrap().as_f64(),
            Some(270.0)
        );
        let tiers = movement.get("tiers").unwrap().as_arr().unwrap();
        assert_eq!(tiers.len(), 4);
        assert_eq!(tiers[0].get("tier").unwrap().as_str(), Some("same_subarray"));
        assert_eq!(tiers[0].get("moves").unwrap().as_f64(), Some(2.0));
        assert_eq!(tiers[3].get("tier").unwrap().as_str(), Some("cross_device"));
        assert_eq!(tiers[3].get("copy_cycles").unwrap().as_f64(), Some(8.0));
        let sojourn = doc.get("queue_sojourn_ns").unwrap();
        assert_eq!(sojourn.get("count").unwrap().as_f64(), Some(2.0));
        assert!(sojourn.get("p99").unwrap().as_f64().unwrap() >= 500.0);
        // the telemetry block is always present; plain cluster snapshots
        // carry the disabled all-zero form
        let telemetry = doc.get("telemetry").unwrap();
        assert!(matches!(telemetry.get("enabled"), Some(Json::Bool(false))));
        assert_eq!(telemetry.get("samples").unwrap().as_f64(), Some(0.0));
        assert_eq!(telemetry.get("interval_ns").unwrap().as_f64(), Some(0.0));
        assert_eq!(telemetry.get("last_sample_ns").unwrap().as_f64(), Some(0.0));
        let devs = doc.get("per_device").unwrap().as_arr().unwrap();
        assert_eq!(devs.len(), 1);
        assert!(devs[0].get("latency_ns").unwrap().get("p50").is_some());
        assert!(devs[0].get("queue_sojourn_ns").unwrap().get("p95").is_some());
    }

    #[test]
    fn coalesced_counters_accumulate() {
        let f = FleetMetrics::new(1);
        f.record_coalesced(4, 3);
        f.record_coalesced(2, 1);
        assert_eq!(f.coalesced_requests.load(Ordering::Relaxed), 6);
        assert_eq!(f.waves_saved.load(Ordering::Relaxed), 4);
    }

    #[test]
    fn copy_charges_accumulate_per_device() {
        let f = FleetMetrics::new(2);
        f.record_copy(
            0,
            &CopyCharge {
                bytes: 0,
                ns: 0.0,
                cycles: 0,
            },
        );
        f.record_copy(
            1,
            &CopyCharge {
                bytes: 256,
                ns: 30.0,
                cycles: 32,
            },
        );
        f.record_copy(
            1,
            &CopyCharge {
                bytes: 128,
                ns: 15.0,
                cycles: 16,
            },
        );
        assert_eq!(f.resident_hits.load(Ordering::Relaxed), 1);
        assert_eq!(f.resident_misses.load(Ordering::Relaxed), 2);
        assert_eq!(f.copied_bytes.load(Ordering::Relaxed), 384);
        assert_eq!(f.copy_cycles.load(Ordering::Relaxed), 48);
        assert_eq!(f.copy_ns_per_device(), vec![0, 45]);
    }

    #[test]
    fn placement_copies_count_as_traffic_not_misses() {
        let f = FleetMetrics::new(2);
        f.record_placement_copy(
            1,
            &CopyCharge {
                bytes: 256,
                ns: 15.0,
                cycles: 16,
            },
        );
        // a free charge (already-resident target) records nothing
        f.record_placement_copy(0, &CopyCharge::free());
        assert_eq!(f.copied_bytes.load(Ordering::Relaxed), 256);
        assert_eq!(f.copy_cycles.load(Ordering::Relaxed), 16);
        assert_eq!(f.copy_ns_per_device(), vec![0, 15]);
        assert_eq!(f.resident_hits.load(Ordering::Relaxed), 0);
        assert_eq!(f.resident_misses.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn movements_split_visible_vs_hidden_and_decompose_by_tier() {
        let f = FleetMetrics::new(2);
        // synchronous landing hop: visible copy time on the owning device
        f.record_movement(
            1,
            MovementTier::SameBank,
            &CopyCharge {
                bytes: 64,
                ns: 180.0,
                cycles: 0,
            },
            false,
        );
        // prefetch landing hop: traffic counts, ns hidden fleet-wide
        f.record_movement(
            0,
            MovementTier::SameSubarray,
            &CopyCharge {
                bytes: 32,
                ns: 90.0,
                cycles: 0,
            },
            true,
        );
        // a free charge records nothing
        f.record_movement(0, MovementTier::SameDevice, &CopyCharge::free(), true);
        // an operand pull decomposes into the cross-device bucket
        f.record_copy(
            0,
            &CopyCharge {
                bytes: 128,
                ns: 15.0,
                cycles: 16,
            },
        );
        let m = f.movement_snapshot();
        assert_eq!(m.moves, [1, 1, 0, 1]);
        assert_eq!(m.copied_bytes, [32, 64, 0, 128]);
        assert_eq!(m.copy_cycles, [0, 0, 0, 16]);
        assert_eq!(m.prefetch_hidden_ns, 90);
        assert_eq!(m.in_dram_moves(), 2);
        assert_eq!(m.in_dram_bytes(), 96);
        assert_eq!(m.total_moves(), 3);
        // the tier decomposition sums to the fleet totals
        assert_eq!(
            m.copied_bytes.iter().sum::<u64>(),
            f.copied_bytes.load(Ordering::Relaxed)
        );
        assert_eq!(
            m.copy_cycles.iter().sum::<u64>(),
            f.copy_cycles.load(Ordering::Relaxed)
        );
        // visible ns went to dev1 only; hidden ns to neither device
        assert_eq!(f.copy_ns_per_device(), vec![15, 180]);
    }

    #[test]
    fn region_window_accumulates_and_drains_hottest_first() {
        let f = FleetMetrics::new(1);
        assert!(f.take_region_window().is_empty());
        f.record_region_use(RegionId(7), true);
        f.record_region_use(RegionId(7), false);
        f.record_region_use(RegionId(3), true);
        f.record_region_use(RegionId(9), true);
        f.record_region_use(RegionId(9), true);
        let w = f.take_region_window();
        assert_eq!(
            w,
            vec![
                RegionUse {
                    region: RegionId(7),
                    uses: 2,
                    misses: 1
                },
                RegionUse {
                    region: RegionId(9),
                    uses: 2,
                    misses: 0
                },
                RegionUse {
                    region: RegionId(3),
                    uses: 1,
                    misses: 0
                },
            ]
        );
        // draining resets the window
        assert!(f.take_region_window().is_empty());
    }
}
