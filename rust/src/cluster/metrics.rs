//! Fleet-level metrics: merge per-device [`MetricsSnapshot`]s and add the
//! cluster-only counters (admission, shedding, stealing, queue wait).
//!
//! Merge semantics: counters (requests, chunks, bits, AAPs) sum across
//! devices, and host wall time sums (workers really do burn those host
//! nanoseconds). Simulated DRAM time does *not* sum — devices run in
//! parallel, so the fleet's simulated makespan is the busiest device's
//! `sim_ns`, and fleet throughput is total result bits over that makespan.
//! That is exactly the quantity the 1→N scaling ablation compares.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::coordinator::MetricsSnapshot;
use crate::util::stats::{fmt_ns, fmt_rate, Summary};

/// Merge per-device snapshots into one fleet view (see module docs for
/// which fields sum vs max).
pub fn merge_snapshots(parts: &[MetricsSnapshot]) -> MetricsSnapshot {
    let mut out = MetricsSnapshot {
        requests: 0,
        chunks: 0,
        result_bits: 0,
        aaps: 0,
        sim_ns: 0,
        wall_ns: 0,
        mean_latency_ns: 0.0,
        max_latency_ns: 0.0,
        sim_throughput_bits_per_sec: 0.0,
    };
    let mut latency_mass = 0.0;
    for p in parts {
        out.requests += p.requests;
        out.chunks += p.chunks;
        out.result_bits += p.result_bits;
        out.aaps += p.aaps;
        out.sim_ns = out.sim_ns.max(p.sim_ns);
        out.wall_ns += p.wall_ns;
        latency_mass += p.mean_latency_ns * p.requests as f64;
        out.max_latency_ns = out.max_latency_ns.max(p.max_latency_ns);
    }
    if out.requests > 0 {
        out.mean_latency_ns = latency_mass / out.requests as f64;
    }
    if out.sim_ns > 0 {
        out.sim_throughput_bits_per_sec =
            out.result_bits as f64 / (out.sim_ns as f64 * 1e-9);
    }
    out
}

/// Cluster-only live counters (the per-device counters live inside each
/// device's `Metrics`).
#[derive(Default)]
pub struct FleetMetrics {
    pub completed: AtomicU64,
    /// batches a worker drained from another device's queue
    pub steals: AtomicU64,
    queue_wait_ns: Mutex<Summary>,
}

impl FleetMetrics {
    pub fn new() -> Self {
        FleetMetrics::default()
    }

    pub fn record_completed(&self) {
        self.completed.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_steal(&self) {
        self.steals.fetch_add(1, Ordering::Relaxed);
    }

    pub fn record_queue_wait_ns(&self, ns: f64) {
        self.queue_wait_ns.lock().unwrap().add(ns);
    }

    pub fn mean_queue_wait_ns(&self) -> f64 {
        self.queue_wait_ns.lock().unwrap().mean()
    }
}

/// Point-in-time view of the whole fleet.
#[derive(Clone, Debug)]
pub struct FleetSnapshot {
    pub per_device: Vec<MetricsSnapshot>,
    pub merged: MetricsSnapshot,
    pub admitted: u64,
    /// requests refused outright (`try_submit` backpressure)
    pub shed: u64,
    /// blocking submissions that had to park for a free slot
    pub waited: u64,
    pub completed: u64,
    pub steals: u64,
    /// host-side wait between admission and a worker picking the task up
    pub mean_queue_wait_ns: f64,
}

impl FleetSnapshot {
    pub fn devices(&self) -> usize {
        self.per_device.len()
    }

    /// Fleet simulated throughput (total bits / busiest-device makespan).
    pub fn sim_throughput_bits_per_sec(&self) -> f64 {
        self.merged.sim_throughput_bits_per_sec
    }

    pub fn report(&self) -> String {
        let mut s = format!(
            "fleet: {} devices  admitted: {}  shed: {}  waited: {}  \
             completed: {}  steals: {}  mean queue wait: {}\n",
            self.devices(),
            self.admitted,
            self.shed,
            self.waited,
            self.completed,
            self.steals,
            fmt_ns(self.mean_queue_wait_ns),
        );
        for (i, d) in self.per_device.iter().enumerate() {
            s.push_str(&format!(
                "  dev{i}: {:>6} req  {:>8} chunks  sim {}  ({}bit/s)\n",
                d.requests,
                d.chunks,
                fmt_ns(d.sim_ns as f64),
                fmt_rate(d.sim_throughput_bits_per_sec),
            ));
        }
        s.push_str(&format!(
            "  fleet merged (makespan = busiest device):\n  {}",
            self.merged.report().replace('\n', "\n  ")
        ));
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(requests: u64, bits: u64, sim_ns: u64, mean_lat: f64) -> MetricsSnapshot {
        MetricsSnapshot {
            requests,
            chunks: requests * 2,
            result_bits: bits,
            aaps: requests * 3,
            sim_ns,
            wall_ns: 10,
            mean_latency_ns: mean_lat,
            max_latency_ns: mean_lat * 2.0,
            sim_throughput_bits_per_sec: 0.0,
        }
    }

    #[test]
    fn merge_sums_counters_and_maxes_sim_time() {
        let m = merge_snapshots(&[snap(4, 4000, 100, 50.0), snap(12, 8000, 300, 150.0)]);
        assert_eq!(m.requests, 16);
        assert_eq!(m.chunks, 32);
        assert_eq!(m.result_bits, 12_000);
        assert_eq!(m.aaps, 48);
        assert_eq!(m.sim_ns, 300); // max, not sum: devices run in parallel
        assert_eq!(m.wall_ns, 20); // sum: host really spent it
        // request-weighted mean: (4·50 + 12·150) / 16
        assert!((m.mean_latency_ns - 125.0).abs() < 1e-9);
        assert!((m.max_latency_ns - 300.0).abs() < 1e-9);
        // throughput over the makespan
        let want = 12_000.0 / (300.0 * 1e-9);
        assert!((m.sim_throughput_bits_per_sec - want).abs() / want < 1e-12);
    }

    #[test]
    fn merge_of_nothing_is_empty() {
        let m = merge_snapshots(&[]);
        assert_eq!(m.requests, 0);
        assert_eq!(m.sim_throughput_bits_per_sec, 0.0);
        assert_eq!(m.mean_latency_ns, 0.0);
    }

    #[test]
    fn fleet_counters_and_report() {
        let f = FleetMetrics::new();
        f.record_completed();
        f.record_steal();
        f.record_queue_wait_ns(500.0);
        f.record_queue_wait_ns(1500.0);
        assert!((f.mean_queue_wait_ns() - 1000.0).abs() < 1e-9);
        let snapshot = FleetSnapshot {
            per_device: vec![snap(1, 100, 10, 5.0)],
            merged: merge_snapshots(&[snap(1, 100, 10, 5.0)]),
            admitted: 1,
            shed: 2,
            waited: 3,
            completed: 1,
            steals: 1,
            mean_queue_wait_ns: 1000.0,
        };
        let r = snapshot.report();
        assert!(r.contains("shed: 2"), "{r}");
        assert!(r.contains("dev0"), "{r}");
    }
}
