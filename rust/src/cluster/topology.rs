//! Fleet topology: which DRIM devices exist and where they sit on the
//! memory interface.
//!
//! One *device* is one lock-step DRIM rank (the chip-level view
//! [`crate::dram::geometry::DramGeometry`] models — chips in a rank issue
//! the same AAP in lock-step, cf. Ambit's rank-level operation). Devices
//! are grouped into DDR channels; the channel/rank coordinates are the
//! axis the inter-device copy-cost model
//! ([`crate::cluster::residency`]) hangs off: ranks sharing a channel
//! share its data bus, so copies between them serialize.

use std::fmt;

use crate::coordinator::ServiceConfig;

/// Index of a device within the fleet (dense, 0-based).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug, PartialOrd, Ord)]
pub struct DeviceId(pub usize);

impl fmt::Display for DeviceId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "dev{}", self.0)
    }
}

/// One DRIM device slot: its interface coordinates and the serving
/// configuration (geometry, intra-device workers, batching policy) its
/// `DrimService` is built with.
#[derive(Clone, Debug)]
pub struct DeviceDesc {
    pub id: DeviceId,
    pub channel: usize,
    pub rank: usize,
    pub service: ServiceConfig,
}

/// The whole fleet.
#[derive(Clone, Debug)]
pub struct Topology {
    pub ranks_per_channel: usize,
    pub devices: Vec<DeviceDesc>,
}

impl Topology {
    /// `n` identical devices, filled channel-major (`ranks_per_channel`
    /// ranks per channel before moving to the next channel).
    pub fn homogeneous(n: usize, service: ServiceConfig, ranks_per_channel: usize) -> Self {
        assert!(n > 0, "a fleet needs at least one device");
        assert!(ranks_per_channel > 0);
        let devices = (0..n)
            .map(|i| DeviceDesc {
                id: DeviceId(i),
                channel: i / ranks_per_channel,
                rank: i % ranks_per_channel,
                service: service.clone(),
            })
            .collect();
        Topology {
            ranks_per_channel,
            devices,
        }
    }

    /// `n` identical devices, two ranks per channel (commodity DDR4 DIMM).
    pub fn uniform(n: usize, service: ServiceConfig) -> Self {
        Self::homogeneous(n, service, 2)
    }

    /// `n` test-sized devices (unit/integration tests, fast exhaustive
    /// simulation).
    pub fn tiny(n: usize) -> Self {
        Self::uniform(n, ServiceConfig::tiny())
    }

    pub fn len(&self) -> usize {
        self.devices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }

    /// Number of populated channels.
    pub fn channels(&self) -> usize {
        self.devices
            .iter()
            .map(|d| d.channel + 1)
            .max()
            .unwrap_or(0)
    }

    /// Channel coordinate of one device (the axis the inter-device
    /// copy-cost model prices: same-channel copies serialize on the shared
    /// data bus, cross-channel copies overlap).
    pub fn channel_of(&self, d: DeviceId) -> usize {
        self.devices[d.0].channel
    }

    /// Do two devices share a DDR channel?
    pub fn same_channel(&self, a: DeviceId, b: DeviceId) -> bool {
        self.channel_of(a) == self.channel_of(b)
    }

    /// Fleet-wide parallel row slots per wave (sum of per-device
    /// banks × active sub-arrays) — the scale-out analogue of
    /// `Router::wave_slots`.
    pub fn total_wave_slots(&self) -> usize {
        self.devices
            .iter()
            .map(|d| d.service.geometry.banks * d.service.geometry.active_subarrays)
            .sum()
    }

    /// Bits processed by one fleet-wide computational step.
    pub fn compute_width_bits(&self) -> usize {
        self.devices
            .iter()
            .map(|d| d.service.geometry.compute_width_bits())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn homogeneous_fills_channels_rank_major() {
        let t = Topology::homogeneous(5, ServiceConfig::tiny(), 2);
        assert_eq!(t.len(), 5);
        assert_eq!(t.channels(), 3);
        let coords: Vec<(usize, usize)> =
            t.devices.iter().map(|d| (d.channel, d.rank)).collect();
        assert_eq!(coords, vec![(0, 0), (0, 1), (1, 0), (1, 1), (2, 0)]);
        assert_eq!(t.devices[3].id, DeviceId(3));
    }

    #[test]
    fn wave_slots_scale_linearly() {
        let one = Topology::tiny(1);
        let four = Topology::tiny(4);
        assert_eq!(four.total_wave_slots(), 4 * one.total_wave_slots());
        assert_eq!(four.compute_width_bits(), 4 * one.compute_width_bits());
        // tiny geometry: 2 banks × 2 active sub-arrays
        assert_eq!(one.total_wave_slots(), 4);
    }

    #[test]
    #[should_panic(expected = "at least one device")]
    fn empty_fleet_rejected() {
        Topology::tiny(0);
    }

    #[test]
    fn device_id_display() {
        assert_eq!(DeviceId(3).to_string(), "dev3");
    }

    #[test]
    fn single_device_fleet_is_degenerate_but_valid() {
        let t = Topology::homogeneous(1, ServiceConfig::tiny(), 2);
        assert_eq!(t.len(), 1);
        assert!(!t.is_empty());
        assert_eq!(t.channels(), 1);
        assert_eq!((t.devices[0].channel, t.devices[0].rank), (0, 0));
        assert_eq!(t.channel_of(DeviceId(0)), 0);
        assert!(t.same_channel(DeviceId(0), DeviceId(0)));
        // fleet-wide aggregates equal the single device's own
        assert_eq!(t.total_wave_slots(), 4);
        assert_eq!(t.compute_width_bits(), Topology::tiny(1).compute_width_bits());
    }

    #[test]
    fn more_ranks_per_channel_than_devices_stays_on_one_channel() {
        // ranks_per_channel larger than the fleet: everything packs onto
        // channel 0, rank index dense — no phantom channels appear.
        let t = Topology::homogeneous(3, ServiceConfig::tiny(), 8);
        assert_eq!(t.channels(), 1);
        let coords: Vec<(usize, usize)> =
            t.devices.iter().map(|d| (d.channel, d.rank)).collect();
        assert_eq!(coords, vec![(0, 0), (0, 1), (0, 2)]);
        assert!(t.same_channel(DeviceId(0), DeviceId(2)));
    }

    #[test]
    #[should_panic]
    fn zero_ranks_per_channel_rejected() {
        Topology::homogeneous(2, ServiceConfig::tiny(), 0);
    }
}
