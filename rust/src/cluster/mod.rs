//! Multi-device scale-out: N independent DRIM devices served as one fleet.
//!
//! The paper's platform wins by exploiting bank × sub-array parallelism
//! *inside* one chip; this layer takes the step SIMDRAM frames as going
//! from a compute-capable sub-array to an end-to-end multi-unit framework:
//! scheduling bulk X(N)OR traffic *across* devices (channels/ranks in
//! lock-step, as Ambit's rank-level operation motivates).
//!
//! Submission is a staged pipeline — **admission → coalesce → drain →
//! reassemble**: every request buys an admission ticket, is normalized
//! into wave units, optionally staged in the fleet coalescer (which
//! packs compatible sub-wave requests into full waves), drained from its
//! device queue in wave-unit-budgeted batches, executed as a shared wave
//! set, and reassembled into per-request responses whose simulated
//! latency is the wave set's completion.
//!
//! * [`topology`]  — which devices exist (channel/rank coordinates, per-
//!   device [`ServiceConfig`]).
//! * [`scheduler`] — per-device FIFO queues behind one shared ready list,
//!   with an atomic Idle→Pending→Running shard state machine so a device
//!   queue is never double-enqueued (and never drained by two workers).
//! * [`coalescer`] — the fleet-level wave coalescer: packs admitted
//!   sub-wave requests (same op, co-resident or inline operands, one
//!   home) into full-wave groups before dispatch, under a flush policy
//!   (full wave / queue-depth trigger / max-hold horizon) that bounds
//!   added latency.
//! * [`worker`]    — one OS thread per device, each owning a
//!   [`Device`] (a [`DrimService`] by default), draining its own queue
//!   first and work-stealing backlogged ones; wave groups dispatch
//!   through `Device::submit_batch` so packed requests really share
//!   waves.
//! * [`admission`] — bounded per-device in-flight tickets with load
//!   shedding: when every queue is full the fleet says so instead of
//!   letting latency grow without bound.
//! * [`residency`] — operand residency and placement-aware routing: a
//!   registry mapping operand regions to the devices holding replicas,
//!   requests that reference operands by resident handle instead of
//!   carrying them, an inter-device copy-cost model (derived from the DDR
//!   burst/channel timing) charged whenever operands must move to the
//!   executor, per-device capacity enforcement with pluggable eviction
//!   (LRU / cost-aware / fail-fast), and a cost-driven replication/
//!   migration policy that spreads hot regions across channels.
//! * [`metrics`]   — fleet aggregation: merge per-device
//!   [`crate::coordinator::MetricsSnapshot`]s (counters sum, simulated
//!   makespan is the busiest device) plus cluster-only counters (shed,
//!   steals, queue wait, copied bytes / copy cycles).
//!
//! [`DrimCluster`] is the facade gluing these together; `drim serve
//! --devices N`, `drim cluster` (and its `--locality`, `--capacity` and
//! `--coalesce` sweeps), examples/e2e_cluster.rs,
//! benches/ablate_devices.rs, benches/ablate_locality.rs,
//! benches/ablate_capacity.rs and benches/ablate_coalesce.rs all sit on
//! it.

pub mod admission;
pub mod coalescer;
pub mod metrics;
pub mod movement;
pub mod residency;
pub mod scheduler;
pub mod topology;
pub mod worker;

pub use admission::{AdmissionConfig, AdmissionController, AdmissionError};
pub use coalescer::{CoalesceConfig, Coalescer};
pub use metrics::{
    merge_snapshots, FleetMetrics, FleetSnapshot, MovementSnapshot, RegionUse,
    TenantBreakdown,
};
pub use movement::{MovementConfig, MovementFabric, MovementKind, PendingMovement};
pub use residency::{
    CapacityConfig, CapacityError, ClusterRequest, CopyCharge, CopyCostModel,
    EvictOutcome, EvictionPolicy, LocalityModel, OperandRef, Placement,
    PlacementAction, RegionId, ReplicationConfig, ReplicationPolicy,
    ResidencyRegistry, ResidentSpan, RouteError, RowCoord,
};
pub use scheduler::{Scheduler, ShardState};
pub use topology::{DeviceDesc, DeviceId, Topology};
pub use worker::{ClusterResponse, ClusterTask, TaskItem};

pub use crate::dram::geometry::DeviceCapacity;
pub use crate::dram::timing::MovementTier;

use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use worker::WorkerCtx;

use crate::coordinator::{
    BulkRequest, Device, DrimService, Metrics, Payload, ServiceConfig,
};
use crate::dram::timing::TimingParams;
use crate::isa::program::BulkOp;
use crate::obs::trace::{Stage, Tracer};
use crate::util::bitrow::BitRow;
use crate::util::rng::{zipf_cdf, Rng};

/// Trace ring capacity per lane (one lane per device + one frontend
/// lane). Big enough to hold a full ablation run at sampling 1; overflow
/// drops oldest events and is reported in the collected trace.
const TRACE_LANE_CAPACITY: usize = 8192;

/// Fleet construction knobs.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    pub topology: Topology,
    pub admission: AdmissionConfig,
    /// Per-device residency capacity and the eviction policy applied
    /// when a registration does not fit (unbounded + fail-fast by
    /// default, the pre-capacity behaviour).
    pub capacity: CapacityConfig,
    /// Fleet-level wave coalescing: pack admitted sub-wave requests into
    /// full waves before dispatch (off by default — every request keeps
    /// its own wave set; the coalescing ablation turns it on).
    pub coalesce: CoalesceConfig,
    /// Fleet-owned background rebalancing: a maintenance thread sweeping
    /// [`DrimCluster::rebalance`] on an epoch/queue-depth trigger instead
    /// of caller-driven pumping. Off (`None`) by default.
    pub rebalance: Option<RebalanceConfig>,
    /// The in-DRAM movement fabric: how the landing hop of placement
    /// movement (replication, migration, eviction re-staging) is priced
    /// and scheduled. Off by default — the pre-fabric cost model.
    pub movement: MovementConfig,
    /// Allow idle workers to drain other devices' queues. On by default;
    /// the scaling ablation turns it off to measure pure sharding.
    pub steal: bool,
}

impl ClusterConfig {
    /// `n` identical devices with the given per-device service config.
    pub fn uniform(n: usize, service: ServiceConfig) -> Self {
        ClusterConfig {
            topology: Topology::uniform(n, service),
            admission: AdmissionConfig::default(),
            capacity: CapacityConfig::default(),
            coalesce: CoalesceConfig::off(),
            rebalance: None,
            movement: MovementConfig::Off,
            steal: true,
        }
    }

    /// `n` test-sized devices.
    pub fn tiny(n: usize) -> Self {
        Self::uniform(n, ServiceConfig::tiny())
    }
}

/// Background rebalancing knobs (see [`ClusterConfig::rebalance`]): the
/// fleet owns a maintenance thread that wakes every `epoch`, checks the
/// queue-depth trigger, and applies one [`DrimCluster::rebalance`] round
/// under `policy`. Caller-driven `rebalance` calls keep working alongside
/// it — both funnel through the same registry bookkeeping.
#[derive(Clone, Debug)]
pub struct RebalanceConfig {
    /// the replication/migration policy each sweep plans with
    pub policy: ReplicationPolicy,
    /// how often the maintenance thread wakes to consider a sweep
    pub epoch: Duration,
    /// skip the sweep unless some device queue is at least this deep —
    /// rebalancing is worth a bus stream only when backlog exists
    /// (0 = sweep every epoch)
    pub min_queue_depth: usize,
}

impl Default for RebalanceConfig {
    fn default() -> Self {
        RebalanceConfig {
            policy: ReplicationPolicy::default(),
            epoch: Duration::from_millis(5),
            min_queue_depth: 0,
        }
    }
}

/// N DRIM devices behind one submit interface.
pub struct DrimCluster {
    cfg: ClusterConfig,
    sched: Arc<Scheduler<ClusterTask>>,
    admission: Arc<AdmissionController>,
    fleet: Arc<FleetMetrics>,
    registry: Arc<ResidencyRegistry>,
    locality: Arc<LocalityModel>,
    coalescer: Arc<Coalescer>,
    fabric: Arc<MovementFabric>,
    tracer: Arc<Tracer>,
    /// per-device metrics handles (outlive the devices themselves)
    device_metrics: Vec<Arc<Metrics>>,
    workers: Vec<JoinHandle<()>>,
    /// the background rebalancer, when configured
    maintenance: Option<JoinHandle<()>>,
    /// stop flag + wakeup for the maintenance thread
    maintenance_stop: Arc<(Mutex<bool>, Condvar)>,
    next_seq: AtomicU64,
}

impl DrimCluster {
    /// Build the default fleet: one [`DrimService`] per topology entry.
    pub fn new(cfg: ClusterConfig) -> Self {
        let devices: Vec<DrimService> = cfg
            .topology
            .devices
            .iter()
            .map(|d| DrimService::new(d.service.clone()))
            .collect();
        Self::with_devices(cfg, devices)
    }

    pub fn with_default_config(n_devices: usize) -> Self {
        Self::new(ClusterConfig::uniform(n_devices, ServiceConfig::default()))
    }

    /// Build a fleet over caller-supplied devices (tests inject mocks or
    /// heterogeneous services). `devices.len()` must match the topology.
    pub fn with_devices<D: Device + 'static>(cfg: ClusterConfig, devices: Vec<D>) -> Self {
        assert_eq!(
            devices.len(),
            cfg.topology.len(),
            "one device per topology entry"
        );
        let n = devices.len();
        let sched = Arc::new(Scheduler::new(n));
        let admission = Arc::new(AdmissionController::new(n, cfg.admission));
        let fleet = Arc::new(FleetMetrics::new(n));
        // pin slots decode against the fleet's device geometry, so the
        // movement fabric's tier pricing sees the simulated row size
        let geometry = cfg
            .topology
            .devices
            .first()
            .map(|d| d.service.geometry.clone())
            .unwrap_or_default();
        let registry = Arc::new(
            ResidencyRegistry::with_capacity(
                n,
                cfg.capacity,
                CopyCostModel::new(TimingParams::default()),
            )
            .with_geometry(geometry),
        );
        let locality = Arc::new(LocalityModel::from_topology(
            &cfg.topology,
            TimingParams::default(),
        ));
        let coalescer = Arc::new(Coalescer::new(
            cfg.coalesce,
            cfg.topology
                .devices
                .iter()
                .map(|d| d.service.geometry.banks * d.service.geometry.active_subarrays)
                .collect(),
        ));
        let fabric = Arc::new(MovementFabric::new(n));
        let tracer = Arc::new(Tracer::new(n + 1, TRACE_LANE_CAPACITY));
        registry.set_tracer(Arc::clone(&tracer));
        let device_metrics: Vec<Arc<Metrics>> =
            devices.iter().map(|d| d.metrics()).collect();
        let workers = devices
            .into_iter()
            .enumerate()
            .map(|(i, dev)| {
                let ctx = WorkerCtx {
                    sched: Arc::clone(&sched),
                    admission: Arc::clone(&admission),
                    fleet: Arc::clone(&fleet),
                    locality: Arc::clone(&locality),
                    registry: Arc::clone(&registry),
                    coalescer: Arc::clone(&coalescer),
                    fabric: Arc::clone(&fabric),
                    tracer: Arc::clone(&tracer),
                    steal: cfg.steal,
                };
                std::thread::spawn(move || worker::worker_loop(DeviceId(i), dev, ctx))
            })
            .collect();
        let maintenance_stop = Arc::new((Mutex::new(false), Condvar::new()));
        let maintenance = cfg.rebalance.clone().map(|rb| {
            let stop = Arc::clone(&maintenance_stop);
            let fleet = Arc::clone(&fleet);
            let sched = Arc::clone(&sched);
            let registry = Arc::clone(&registry);
            let locality = Arc::clone(&locality);
            let fabric = Arc::clone(&fabric);
            let tracer = Arc::clone(&tracer);
            let movement = cfg.movement;
            std::thread::spawn(move || {
                let (lock, cv) = &*stop;
                loop {
                    let stopped = lock.lock().unwrap();
                    // re-check before parking: a stop raised mid-sweep
                    // must not cost another whole epoch
                    if *stopped {
                        break;
                    }
                    let (stopped, timeout) = cv.wait_timeout(stopped, rb.epoch).unwrap();
                    if *stopped {
                        break;
                    }
                    drop(stopped);
                    if !timeout.timed_out() {
                        // spurious wakeup: re-park for a fresh epoch
                        continue;
                    }
                    let depths = sched.depths();
                    if depths.iter().copied().max().unwrap_or(0) < rb.min_queue_depth {
                        continue;
                    }
                    rebalance_parts(
                        &fleet, &sched, &registry, &locality, &fabric, &tracer, movement,
                        &rb.policy,
                    );
                }
            })
        });
        DrimCluster {
            cfg,
            sched,
            admission,
            fleet,
            registry,
            locality,
            coalescer,
            fabric,
            tracer,
            device_metrics,
            workers,
            maintenance,
            maintenance_stop,
            next_seq: AtomicU64::new(1),
        }
    }

    pub fn config(&self) -> &ClusterConfig {
        &self.cfg
    }

    pub fn devices(&self) -> usize {
        self.device_metrics.len()
    }

    /// The fleet's operand-residency registry.
    pub fn registry(&self) -> &ResidencyRegistry {
        &self.registry
    }

    /// The copy-cost model bound to this fleet's topology.
    pub fn locality(&self) -> &LocalityModel {
        &self.locality
    }

    /// The fleet's wave coalescer (staging stage of the submission
    /// pipeline).
    pub fn coalescer(&self) -> &Coalescer {
        &self.coalescer
    }

    /// The fleet's structured event tracer. Recording is off until
    /// [`Tracer::set_sampling`] enables it (and compiles out entirely
    /// without the `trace` cargo feature); `drim trace` turns it on and
    /// renders the collected timeline.
    pub fn tracer(&self) -> &Tracer {
        &self.tracer
    }

    /// A shared handle on the tracer that survives [`Self::shutdown`] —
    /// `drim trace` collects the timeline after the workers have joined,
    /// so every span of the run (including the final reassembles) is
    /// present in the merge.
    pub fn trace_handle(&self) -> Arc<Tracer> {
        Arc::clone(&self.tracer)
    }

    /// Dispatch everything still staged in the coalescer. Burst drivers
    /// running under [`CoalesceConfig::strict`] call this at the end of
    /// a burst (packing then depends only on submission order); a no-op
    /// when nothing is staged.
    pub fn flush_coalesced(&self) {
        for task in self.coalescer.flush_all() {
            self.sched.submit(task.home.0, task);
        }
    }

    /// Register a payload as resident on `device`; the returned handle can
    /// be used in [`ClusterRequest`] operands from then on. Panics if
    /// `device` is outside the fleet (the registry is fleet-bounded) or
    /// if a capacity-bounded fleet refuses the registration — capacity-
    /// aware callers use [`Self::try_register_resident`].
    pub fn register_resident(&self, device: DeviceId, payload: Payload) -> RegionId {
        self.registry.register(device, payload)
    }

    /// Capacity-checked registration: fits, evicts under the fleet's
    /// [`EvictionPolicy`], or fails fast with the [`CapacityError`].
    pub fn try_register_resident(
        &self,
        device: DeviceId,
        payload: Payload,
    ) -> Result<RegionId, CapacityError> {
        self.registry.try_register(device, payload)
    }

    /// Capacity-checked *re*-registration on the `Evicted` → requeue
    /// path: like [`Self::try_register_resident`], but the landing hop —
    /// moving the rows from the device's staging row into the region's
    /// pinned row — goes through the movement fabric, so an enabled
    /// [`MovementConfig`] prices it (and, under prefetch, overlaps it
    /// with execution) instead of treating the re-stage as free.
    pub fn try_restage_resident(
        &self,
        device: DeviceId,
        payload: Payload,
    ) -> Result<RegionId, CapacityError> {
        let region = self.registry.try_register(device, payload)?;
        issue_landing(
            &self.fleet,
            &self.registry,
            &self.fabric,
            &self.tracer,
            self.cfg.movement,
            region,
            device,
            MovementKind::Restage,
        );
        Ok(region)
    }

    /// The fleet's movement fabric (pending prefetch landing hops).
    pub fn movement_fabric(&self) -> &MovementFabric {
        &self.fabric
    }

    /// Stage 2+3 of the submission pipeline: wrap the admitted request as
    /// a wave-unit task item and either stage it in the coalescer or
    /// enqueue it directly as a singleton wave group. The flush hint
    /// implements the queue-depth trigger — a saturated ticket pool (or,
    /// in eager mode, an idle home queue) dispatches the home's staged
    /// items immediately rather than holding them.
    fn enqueue(
        &self,
        home: DeviceId,
        req: BulkRequest,
        placement: Option<Placement>,
    ) -> Receiver<ClusterResponse> {
        let seq = self.next_seq.fetch_add(1, Ordering::Relaxed);
        let lane = self.tracer.frontend_lane();
        self.tracer.instant(lane, Stage::Admit, seq, home.0 as u64);
        let (tx, rx) = channel();
        let item = TaskItem {
            seq,
            req,
            placement,
            reply: tx,
            admitted_at: Instant::now(),
        };
        if self.coalescer.config().enabled {
            let cols = self.cfg.topology.devices[home.0].service.geometry.cols;
            let chunks = item.req.wave_units(cols);
            self.tracer.instant(lane, Stage::Coalesce, seq, chunks as u64);
            let flush_home = self.admission.is_saturated(home);
            // Submission runs on the caller's thread, so the dispatch
            // scratch is thread-local: a steady-state submitter reuses
            // one buffer's capacity instead of allocating a Vec per
            // request for the (usually empty) due-task list.
            thread_local! {
                static DUE: RefCell<Vec<ClusterTask>> =
                    const { RefCell::new(Vec::new()) };
            }
            DUE.with(|due| {
                let mut due = due.borrow_mut();
                self.coalescer
                    .push_into(home, item, chunks, flush_home, &mut due);
                for task in due.drain(..) {
                    self.sched.submit(task.home.0, task);
                }
            });
            // Eager queue-depth trigger, checked AFTER the item is staged:
            // checking before the push races the worker's drain-dry flush
            // (the worker could drain, flush an empty coalescer, and park
            // between a pre-push depth read and the push, stranding the
            // item). Post-push, either this sees the empty queue and
            // flushes, or a task observed here is drained later and the
            // worker's own idle flush runs after our item is visible.
            if self.coalescer.config().eager_when_idle
                && self.sched.depth(home.0) == 0
            {
                for task in self.coalescer.flush_device(home) {
                    self.sched.submit(task.home.0, task);
                }
            }
        } else {
            self.sched.submit(home.0, ClusterTask::single(home, item));
        }
        rx
    }

    /// Admit-or-shed submission: `Err` is the backpressure signal.
    pub fn try_submit(
        &self,
        req: BulkRequest,
    ) -> Result<Receiver<ClusterResponse>, AdmissionError> {
        let home = self.admission.try_admit()?;
        Ok(self.enqueue(home, req, None))
    }

    /// Pin a request to one device's queue (still admission-bounded).
    pub fn try_submit_to(
        &self,
        device: DeviceId,
        req: BulkRequest,
    ) -> Result<Receiver<ClusterResponse>, AdmissionError> {
        let home = self.admission.try_admit_to(device)?;
        Ok(self.enqueue(home, req, None))
    }

    /// Submit, parking through backpressure (clients that would rather
    /// wait than be refused). Never sheds; time spent waiting shows up in
    /// the fleet `waited` counter instead.
    pub fn submit_blocking(&self, req: BulkRequest) -> Receiver<ClusterResponse> {
        let home = self.admission.admit_wait();
        self.enqueue(home, req, None)
    }

    /// Submit and wait for the response.
    pub fn run(&self, req: BulkRequest) -> ClusterResponse {
        self.submit_blocking(req)
            .recv()
            .expect("cluster shut down mid-request")
    }

    /// Where the router would *prefer* to execute `req`: the device owning
    /// the most resident operand bits, or `None` when every operand is
    /// carried inline (round-robin admission decides then). Placement-only
    /// — no payload is cloned.
    pub fn route(&self, req: &ClusterRequest) -> Result<Option<DeviceId>, RouteError> {
        Ok(self.registry.placement_of(req)?.preferred())
    }

    /// Materialize a routed request *after* an admission ticket was won,
    /// returning the ticket if materialization fails (a region removed
    /// between the placement check and here). Keeps payload cloning off
    /// the shed path: routing/admission run on the clone-free
    /// [`ResidencyRegistry::placement_of`], and operands are only cloned
    /// out of the registry once the request is definitely entering a
    /// queue.
    fn resolve_admitted(
        &self,
        home: DeviceId,
        req: &ClusterRequest,
    ) -> Result<(BulkRequest, Placement), RouteError> {
        self.registry.resolve(req).map_err(|e| {
            self.admission.complete(home);
            e
        })
    }

    /// Placement-aware admit-or-shed submission: resident operands pull
    /// the request toward the devices holding their replicas — the
    /// least-loaded replica holder wins, so replicated hot regions spread
    /// over their copies (falling back to any unsaturated device when
    /// every holder is full — the worker then charges the copy), and the
    /// executing worker records the copy cost in the fleet metrics.
    pub fn try_submit_routed(
        &self,
        req: ClusterRequest,
    ) -> Result<Receiver<ClusterResponse>, RouteError> {
        let placement = self.registry.placement_of(&req)?;
        let candidates = placement.candidates();
        let home = if candidates.is_empty() {
            self.admission.try_admit()?
        } else {
            // coalescer-aware tiebreak: replica holders at equal queue
            // depth resolve toward the device whose staged bucket for
            // this op is closest to dispatching a full wave
            self.admission
                .try_admit_prefer_any_with(&candidates, &|d| {
                    self.coalescer.bucket_fill(d, req.op)
                })?
        };
        let (bulk, placement) = self.resolve_admitted(home, &req)?;
        Ok(self.enqueue(home, bulk, Some(placement)))
    }

    /// Routed submission pinned to one executor (still copy-charged
    /// against that executor — the forced-miss path the residency tests
    /// and the locality ablation use).
    pub fn try_submit_routed_to(
        &self,
        device: DeviceId,
        req: ClusterRequest,
    ) -> Result<Receiver<ClusterResponse>, RouteError> {
        self.registry.placement_of(&req)?;
        let home = self.admission.try_admit_to(device)?;
        let (bulk, placement) = self.resolve_admitted(home, &req)?;
        Ok(self.enqueue(home, bulk, Some(placement)))
    }

    /// Placement-aware blocking submission: parks on the replica holders'
    /// admission (least-loaded holder wins; or anywhere, for all-inline
    /// requests) instead of shedding.
    pub fn submit_routed_blocking(
        &self,
        req: ClusterRequest,
    ) -> Result<Receiver<ClusterResponse>, RouteError> {
        let placement = self.registry.placement_of(&req)?;
        let candidates = placement.candidates();
        let home = if candidates.is_empty() {
            self.admission.admit_wait()
        } else {
            self.admission.admit_wait_any_with(&candidates, &|d| {
                self.coalescer.bucket_fill(d, req.op)
            })
        };
        let (bulk, placement) = self.resolve_admitted(home, &req)?;
        Ok(self.enqueue(home, bulk, Some(placement)))
    }

    /// Blocking routed submission pinned to one executor.
    pub fn submit_routed_blocking_to(
        &self,
        device: DeviceId,
        req: ClusterRequest,
    ) -> Result<Receiver<ClusterResponse>, RouteError> {
        self.registry.placement_of(&req)?;
        let home = self.admission.admit_wait_to(device);
        let (bulk, placement) = self.resolve_admitted(home, &req)?;
        Ok(self.enqueue(home, bulk, Some(placement)))
    }

    /// Routed submit-and-wait.
    pub fn run_routed(&self, req: ClusterRequest) -> Result<ClusterResponse, RouteError> {
        Ok(self
            .submit_routed_blocking(req)?
            .recv()
            .expect("cluster shut down mid-request"))
    }

    /// Drive the shared locality-ablation workload and block until every
    /// response arrives: `requests` XNOR2 requests of 2 × `bits` random
    /// operand bits each, operand owners assigned round-robin across the
    /// fleet.
    ///
    /// `policy`: `None` — operands are carried inline (the
    /// payload-carrying baseline, placed by round-robin admission);
    /// `Some(k)` — operands are pre-registered on their owner and the
    /// request routed there, except every `k`-th request, which is pinned
    /// to the next device as a forced miss (`Some(0)` = no misses).
    ///
    /// One definition shared by `drim cluster --locality` and
    /// benches/ablate_locality.rs so the two ablations measure the same
    /// workload and cannot drift.
    pub fn pump_locality(
        &self,
        requests: usize,
        bits: usize,
        policy: Option<usize>,
        seed: u64,
    ) {
        let devices = self.devices();
        let mut rng = Rng::new(seed);
        let pending: Vec<_> = (0..requests)
            .map(|i| {
                let owner = DeviceId(i % devices);
                let a = BitRow::random(bits, &mut rng);
                let b = BitRow::random(bits, &mut rng);
                match policy {
                    None => self
                        .submit_routed_blocking(ClusterRequest::carried(
                            BulkRequest::bitwise(BulkOp::Xnor2, vec![a, b]),
                        ))
                        .expect("carried requests always resolve"),
                    Some(miss_every) => {
                        let ra = self.register_resident(owner, Payload::Bits(a));
                        let rb = self.register_resident(owner, Payload::Bits(b));
                        let req =
                            ClusterRequest::resident(BulkOp::Xnor2, vec![ra, rb]);
                        if miss_every > 0 && i % miss_every == miss_every - 1 {
                            let elsewhere = DeviceId((owner.0 + 1) % devices);
                            self.submit_routed_blocking_to(elsewhere, req)
                                .expect("registered regions always resolve")
                        } else {
                            self.submit_routed_blocking(req)
                                .expect("registered regions always resolve")
                        }
                    }
                }
            })
            .collect();
        for p in pending {
            p.recv().expect("response");
        }
    }

    /// Drive the shared coalescing-ablation workload: `requests` XNOR2
    /// requests of 2 × `bits` random operand bits each, submitted as one
    /// burst through the blocking admission path, the coalescer flushed
    /// at the end of the burst, and every response collected. Returns
    /// the result payloads in submission order — the byte-exactness gate
    /// compares them across coalescing modes.
    ///
    /// One definition shared by `drim cluster --coalesce` and
    /// benches/ablate_coalesce.rs so the two ablations measure the same
    /// workload and cannot drift.
    pub fn pump_coalesce(&self, requests: usize, bits: usize, seed: u64) -> Vec<Payload> {
        let mut rng = Rng::new(seed);
        let pending: Vec<_> = (0..requests)
            .map(|_| {
                let a = BitRow::random(bits, &mut rng);
                let b = BitRow::random(bits, &mut rng);
                self.submit_blocking(BulkRequest::bitwise(BulkOp::Xnor2, vec![a, b]))
            })
            .collect();
        self.flush_coalesced();
        pending
            .into_iter()
            .map(|rx| rx.recv().expect("response").inner.result)
            .collect()
    }

    /// Apply one round of the replication/migration `policy`: drain the
    /// per-region traffic window, plan placement actions against the
    /// current footprints and queue depths, and execute them through the
    /// registry — charging every replica/migration stream to the
    /// destination device at the modeled copy cost. Returns the actions
    /// taken. Call sites may sweep this periodically, or configure
    /// [`ClusterConfig::rebalance`] to let a fleet-owned maintenance
    /// thread do the sweeping (both funnel through the same bookkeeping).
    pub fn rebalance(&self, policy: &ReplicationPolicy) -> Vec<PlacementAction> {
        rebalance_parts(
            &self.fleet,
            &self.sched,
            &self.registry,
            &self.locality,
            &self.fabric,
            &self.tracer,
            self.cfg.movement,
            policy,
        )
    }

    /// Drive the shared capacity/replication workload: `regions` resident
    /// operand rows registered round-robin across the fleet, then
    /// `requests` bulk NOT requests sampling regions by a Zipf(`theta`)
    /// popularity law (rank 0 hottest), placement-routed and blocking.
    /// With `rebalance = Some((policy, every))` the fleet re-plans
    /// placement after every `every` completed requests, so hot regions
    /// replicate across channels mid-run.
    ///
    /// Capacity is enforced throughout: a registration beyond capacity
    /// evicts under the fleet's policy or fails fast, in which case the
    /// affected slot degrades to carried payloads. A request whose region
    /// was evicted mid-flight observes the defined [`RouteError::Evicted`]
    /// signal and is requeued — re-registered and resubmitted, falling
    /// back to a carried payload after repeated evictions (degrade, don't
    /// collapse). Returns the number of requeues.
    ///
    /// One definition shared by `drim cluster --capacity` and
    /// benches/ablate_capacity.rs so the two ablations measure the same
    /// workload and cannot drift.
    pub fn pump_capacity(
        &self,
        regions: usize,
        requests: usize,
        bits: usize,
        theta: f64,
        rebalance: Option<(&ReplicationPolicy, usize)>,
        seed: u64,
    ) -> u64 {
        assert!(regions > 0, "the Zipf workload needs at least one region");
        let devices = self.devices();
        let mut rng = Rng::new(seed);
        let mut values: Vec<BitRow> = Vec::with_capacity(regions);
        let mut slots: Vec<Option<RegionId>> = Vec::with_capacity(regions);
        for i in 0..regions {
            let row = BitRow::random(bits, &mut rng);
            let slot = self
                .registry
                .try_register(DeviceId(i % devices), Payload::Bits(row.clone()))
                .ok();
            values.push(row);
            slots.push(slot);
        }
        let cdf = zipf_cdf(regions, theta);
        let batch = match rebalance {
            Some((_, every)) => every.max(1),
            None => requests.max(1),
        };
        let mut requeues = 0u64;
        let mut done = 0usize;
        while done < requests {
            let n = batch.min(requests - done);
            let mut pending = Vec::with_capacity(n);
            for _ in 0..n {
                let rank = rng.sample_cdf(&cdf);
                let mut attempts = 0;
                let rx = loop {
                    match slots[rank] {
                        Some(r) if attempts < 3 => {
                            let req = ClusterRequest::resident(BulkOp::Not, vec![r]);
                            match self.submit_routed_blocking(req) {
                                Ok(rx) => break rx,
                                Err(RouteError::Evicted(_) | RouteError::UnknownRegion(_)) => {
                                    // the defined shed/requeue path:
                                    // re-register and resubmit
                                    requeues += 1;
                                    attempts += 1;
                                    slots[rank] = self
                                        .try_restage_resident(
                                            DeviceId(rank % devices),
                                            Payload::Bits(values[rank].clone()),
                                        )
                                        .ok();
                                }
                                Err(RouteError::Admission(_)) => {
                                    unreachable!("blocking routed submit never sheds")
                                }
                            }
                        }
                        // no resident slot (capacity refused it, or it
                        // keeps getting evicted): degrade to carried
                        _ => {
                            let req = ClusterRequest::carried(BulkRequest::bitwise(
                                BulkOp::Not,
                                vec![values[rank].clone()],
                            ));
                            break self
                                .submit_routed_blocking(req)
                                .expect("carried requests always resolve");
                        }
                    }
                };
                pending.push(rx);
            }
            for rx in pending {
                rx.recv().expect("response");
            }
            done += n;
            if let Some((policy, _)) = rebalance {
                if done < requests {
                    self.rebalance(policy);
                }
            }
        }
        requeues
    }

    pub fn snapshot(&self) -> FleetSnapshot {
        let per_device: Vec<_> =
            self.device_metrics.iter().map(|m| m.snapshot()).collect();
        FleetSnapshot {
            merged: merge_snapshots(&per_device),
            per_device,
            admitted: self.admission.admitted.load(Ordering::Relaxed),
            shed: self.admission.shed.load(Ordering::Relaxed),
            waited: self.admission.waited.load(Ordering::Relaxed),
            completed: self.fleet.completed.load(Ordering::Relaxed),
            steals: self.fleet.steals.load(Ordering::Relaxed),
            copied_bytes: self.fleet.copied_bytes.load(Ordering::Relaxed),
            copy_cycles: self.fleet.copy_cycles.load(Ordering::Relaxed),
            resident_hits: self.fleet.resident_hits.load(Ordering::Relaxed),
            resident_misses: self.fleet.resident_misses.load(Ordering::Relaxed),
            evictions: self.registry.evictions(),
            capacity_refusals: self.registry.capacity_refusals(),
            replications: self.fleet.replications.load(Ordering::Relaxed),
            migrations: self.fleet.migrations.load(Ordering::Relaxed),
            coalesced_requests: self.fleet.coalesced_requests.load(Ordering::Relaxed),
            waves_saved: self.fleet.waves_saved.load(Ordering::Relaxed),
            movement: self.fleet.movement_snapshot(),
            copy_ns_per_device: self.fleet.copy_ns_per_device(),
            mean_queue_wait_ns: self.fleet.mean_queue_wait_ns(),
            queue_wait: self.fleet.queue_wait_merged(),
            queue_wait_per_device: self.fleet.queue_wait_histograms(),
            tombstones_compacted: self.registry.tombstones_compacted(),
            fairness: Vec::new(),
            telemetry: Default::default(),
        }
    }

    /// Close the scheduler, let workers drain the ready backlog, and join
    /// them. Requests never admitted keep their receivers alive; requests
    /// still queued on a never-reacquired shard are dropped (their
    /// receivers observe disconnection).
    pub fn shutdown(mut self) -> FleetSnapshot {
        self.shutdown_now();
        self.snapshot()
    }

    fn shutdown_now(&mut self) {
        // stop the maintenance thread first so a mid-sweep rebalance
        // never races device teardown
        {
            let (lock, cv) = &*self.maintenance_stop;
            *lock.lock().unwrap() = true;
            cv.notify_all();
        }
        if let Some(m) = self.maintenance.take() {
            let _ = m.join();
        }
        // dispatch anything still staged in the coalescer so its clients'
        // receivers resolve during the drain instead of disconnecting
        self.flush_coalesced();
        self.sched.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        // settle prefetch landing hops that never overlapped a drain —
        // still hidden (the fabric's copy engine finished them off the
        // critical path), still attributed to their destination device
        for m in self.fabric.drain_all() {
            self.fleet
                .record_movement(m.dest.0, m.tier, &m.charge, true);
            self.tracer.instant_with_dur(
                m.dest.0 as u32,
                Stage::Copy,
                m.region.0,
                m.charge.ns.round() as u64,
                m.charge.bytes,
            );
        }
    }
}

impl Drop for DrimCluster {
    fn drop(&mut self) {
        self.shutdown_now();
    }
}

/// One rebalance round over explicit fleet parts — shared by the
/// caller-driven [`DrimCluster::rebalance`] and the background
/// maintenance thread (which holds only the `Arc`ed parts, not the
/// cluster itself).
#[allow(clippy::too_many_arguments)]
fn rebalance_parts(
    fleet: &FleetMetrics,
    sched: &Scheduler<ClusterTask>,
    registry: &ResidencyRegistry,
    locality: &LocalityModel,
    fabric: &MovementFabric,
    tracer: &Tracer,
    movement: MovementConfig,
    policy: &ReplicationPolicy,
) -> Vec<PlacementAction> {
    let window = fleet.take_region_window();
    let depths = sched.depths();
    let actions = policy.plan(&window, registry, locality, &depths);
    for a in &actions {
        match *a {
            PlacementAction::Replicate { region, to } => {
                let (Some(sources), Some(bits)) =
                    (registry.replicas(region), registry.bits(region))
                else {
                    continue;
                };
                // A concurrent sweep (background rebalancer + a caller-
                // driven round) may have landed this replica already:
                // `replicate` is idempotent-Ok then, but counting it
                // again would over-report replications. (`cheapest_copy`
                // is already free when `to` holds a replica, so no
                // phantom stream is charged either way.)
                if sources.contains(&to) {
                    continue;
                }
                let charge = locality.cheapest_copy(bits as u64, &sources, to);
                if registry.replicate(region, to) == Ok(true) {
                    fleet.record_placement_copy(to.0, &charge);
                    fleet.replications.fetch_add(1, Ordering::Relaxed);
                    tracer.instant_with_dur(
                        tracer.frontend_lane(),
                        Stage::Replicate,
                        region.0,
                        charge.ns.round() as u64,
                        to.0 as u64,
                    );
                    issue_landing(
                        fleet,
                        registry,
                        fabric,
                        tracer,
                        movement,
                        region,
                        to,
                        MovementKind::Replicate,
                    );
                }
            }
            PlacementAction::Migrate { region, to } => {
                let (Some(sources), Some(bits)) =
                    (registry.replicas(region), registry.bits(region))
                else {
                    continue;
                };
                let charge = locality.cheapest_copy(bits as u64, &sources, to);
                if registry.migrate(region, to) == Ok(true) {
                    fleet.record_placement_copy(to.0, &charge);
                    fleet.migrations.fetch_add(1, Ordering::Relaxed);
                    tracer.instant_with_dur(
                        tracer.frontend_lane(),
                        Stage::Migrate,
                        region.0,
                        charge.ns.round() as u64,
                        to.0 as u64,
                    );
                    issue_landing(
                        fleet,
                        registry,
                        fabric,
                        tracer,
                        movement,
                        region,
                        to,
                        MovementKind::Migrate,
                    );
                }
            }
        }
    }
    actions
}

/// Issue the landing hop of a placement movement: the rows arriving on
/// `dest` (off the inter-device stream or the eviction requeue path) must
/// still move from the device's staging row into the region's pinned row.
/// [`MovementConfig::Off`] models no hop (the pre-fabric behaviour);
/// external pricing charges a bus read-out + write-in round trip;
/// in-DRAM pricing charges the RowClone tier of the pinned coordinate at
/// zero bus cycles; prefetch enqueues the hop on the fabric so the worker
/// that next drains `dest` settles it behind execution.
#[allow(clippy::too_many_arguments)]
fn issue_landing(
    fleet: &FleetMetrics,
    registry: &ResidencyRegistry,
    fabric: &MovementFabric,
    tracer: &Tracer,
    movement: MovementConfig,
    region: RegionId,
    dest: DeviceId,
    kind: MovementKind,
) {
    if !movement.enabled() {
        return;
    }
    let Some(bits) = registry.bits(region) else {
        // the region vanished between the placement move and here (a
        // concurrent remove/evict): nothing is left to land
        return;
    };
    let bits = bits as u64;
    let (tier, charge) = if movement.in_dram() {
        // the hop is priced by where the pin landed; a pin racing an
        // eviction falls back to the conservative external tier
        let tier = registry
            .pin_of(region, dest)
            .map(|c| c.landing_tier())
            .unwrap_or(MovementTier::CrossDevice);
        let row_bits = registry.geometry().cols as u64;
        (
            tier,
            registry.cost_model().in_dram_landing(bits, tier, row_bits),
        )
    } else {
        (
            MovementTier::CrossDevice,
            registry.cost_model().external_landing(bits),
        )
    };
    if movement.prefetch() {
        fabric.enqueue(PendingMovement {
            region,
            dest,
            tier,
            charge,
            kind,
        });
    } else {
        fleet.record_movement(dest.0, tier, &charge, false);
        tracer.instant_with_dur(
            dest.0 as u32,
            Stage::Copy,
            region.0,
            charge.ns.round() as u64,
            charge.bytes,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Payload;
    use crate::isa::program::BulkOp;
    use crate::util::bitrow::BitRow;
    use crate::util::rng::Rng;

    #[test]
    fn two_device_fleet_roundtrip() {
        let c = DrimCluster::new(ClusterConfig::tiny(2));
        let mut rng = Rng::new(21);
        let a = BitRow::random(1000, &mut rng);
        let b = BitRow::random(1000, &mut rng);
        let mut want = BitRow::zeros(1000);
        want.apply2(&a, &b, |x, y| !(x ^ y));
        let resp = c.run(BulkRequest::bitwise(BulkOp::Xnor2, vec![a, b]));
        match resp.inner.result {
            Payload::Bits(got) => assert_eq!(got, want),
            _ => panic!("wrong payload kind"),
        }
        let snap = c.shutdown();
        assert_eq!(snap.admitted, 1);
        assert_eq!(snap.completed, 1);
        assert_eq!(snap.merged.requests, 1);
    }

    #[test]
    fn round_robin_lands_on_both_devices() {
        let c = DrimCluster::new(ClusterConfig::tiny(2));
        let mut rng = Rng::new(22);
        let pending: Vec<_> = (0..6)
            .map(|_| {
                let a = BitRow::random(512, &mut rng);
                c.try_submit(BulkRequest::bitwise(BulkOp::Not, vec![a]))
                    .expect("admission open")
            })
            .collect();
        let homes: Vec<usize> =
            pending.into_iter().map(|p| p.recv().unwrap().home.0).collect();
        assert!(homes.contains(&0) && homes.contains(&1), "{homes:?}");
        let snap = c.shutdown();
        assert_eq!(snap.completed, 6);
        // every request ran on some device and the merged view saw it
        assert_eq!(snap.merged.requests, 6);
    }

    #[test]
    fn shutdown_is_clean_with_no_traffic() {
        let c = DrimCluster::new(ClusterConfig::tiny(3));
        let snap = c.shutdown();
        assert_eq!(snap.devices(), 3);
        assert_eq!(snap.admitted, 0);
        assert_eq!(snap.merged.requests, 0);
        assert_eq!(snap.copied_bytes, 0);
        assert_eq!(snap.makespan_with_copy_ns(), 0);
    }

    #[test]
    fn routed_request_lands_on_owner_and_is_free() {
        let c = DrimCluster::new(ClusterConfig {
            steal: false,
            ..ClusterConfig::tiny(2)
        });
        let mut rng = Rng::new(23);
        let a = BitRow::random(1000, &mut rng);
        let b = BitRow::random(1000, &mut rng);
        let ra = c.register_resident(DeviceId(1), Payload::Bits(a.clone()));
        let rb = c.register_resident(DeviceId(1), Payload::Bits(b.clone()));
        let req = ClusterRequest::resident(BulkOp::Xnor2, vec![ra, rb]);
        assert_eq!(c.route(&req).unwrap(), Some(DeviceId(1)));
        let resp = c.run_routed(req).unwrap();
        assert_eq!(resp.home, DeviceId(1));
        assert_eq!(resp.device, DeviceId(1));
        let mut want = BitRow::zeros(1000);
        want.apply2(&a, &b, |x, y| !(x ^ y));
        match resp.inner.result {
            Payload::Bits(got) => assert_eq!(got, want),
            _ => panic!("wrong payload kind"),
        }
        let snap = c.shutdown();
        assert_eq!(snap.resident_hits, 1);
        assert_eq!(snap.resident_misses, 0);
        assert_eq!(snap.copied_bytes, 0);
        assert_eq!(snap.copy_cycles, 0);
        assert_eq!(snap.makespan_with_copy_ns(), snap.merged.sim_ns);
    }

    #[test]
    fn unknown_region_is_refused_without_burning_a_ticket() {
        let c = DrimCluster::new(ClusterConfig::tiny(2));
        let req = ClusterRequest::resident(BulkOp::Not, vec![RegionId(12345)]);
        match c.try_submit_routed(req) {
            Err(RouteError::UnknownRegion(r)) => assert_eq!(r, RegionId(12345)),
            other => panic!("expected UnknownRegion, got {other:?}"),
        }
        let snap = c.shutdown();
        assert_eq!(snap.admitted, 0, "no admission ticket may leak");
        assert_eq!(snap.shed, 0);
    }

    #[test]
    fn any_replica_is_a_zero_copy_hit() {
        let c = DrimCluster::new(ClusterConfig {
            steal: false,
            ..ClusterConfig::tiny(4)
        });
        let mut rng = Rng::new(61);
        let a = BitRow::random(1024, &mut rng);
        let r = c.register_resident(DeviceId(0), Payload::Bits(a.clone()));
        assert!(c.registry().replicate(r, DeviceId(2)).unwrap());
        // pinned to the replica, not the primary: still free
        let req = ClusterRequest::resident(BulkOp::Not, vec![r]);
        let resp = c
            .submit_routed_blocking_to(DeviceId(2), req)
            .unwrap()
            .recv()
            .expect("routed response");
        assert_eq!(resp.device, DeviceId(2));
        let mut want = BitRow::zeros(1024);
        want.not_from(&a);
        match resp.inner.result {
            Payload::Bits(got) => assert_eq!(got, want),
            _ => panic!("wrong payload kind"),
        }
        let snap = c.shutdown();
        assert_eq!(snap.resident_hits, 1, "a replica holder is a hit");
        assert_eq!(snap.resident_misses, 0);
        assert_eq!(snap.copied_bytes, 0);
    }

    #[test]
    fn rebalance_replicates_hot_region_and_charges_the_stream() {
        let c = DrimCluster::new(ClusterConfig {
            steal: false,
            ..ClusterConfig::tiny(4)
        });
        let mut rng = Rng::new(62);
        let a = BitRow::random(2048, &mut rng);
        let r = c.register_resident(DeviceId(0), Payload::Bits(a));
        // drive routed traffic so the window sees a hot region
        for _ in 0..4 {
            c.run_routed(ClusterRequest::resident(BulkOp::Not, vec![r]))
                .unwrap();
        }
        let policy = ReplicationPolicy::new(ReplicationConfig {
            hot_uses: 3,
            amortize_factor: 1.0,
            ..ReplicationConfig::default()
        });
        let actions = c.rebalance(&policy);
        assert!(
            actions
                .iter()
                .any(|x| matches!(x, PlacementAction::Replicate { region, .. } if *region == r)),
            "{actions:?}"
        );
        let reps = c.registry().replicas(r).unwrap();
        assert_eq!(reps.len(), 2, "{reps:?}");
        // replica landed on the other channel, and the stream was charged
        let loc = c.locality();
        assert!(!loc.same_channel(reps[0], reps[1]));
        let snap = c.shutdown();
        assert_eq!(snap.replications, 1);
        assert!(snap.copied_bytes > 0, "replication stream must be charged");
        assert_eq!(snap.resident_hits, 4, "placement copies are not misses");
        assert_eq!(snap.resident_misses, 0);
    }

    #[test]
    fn coalesced_subwave_burst_shares_waves_and_stays_correct() {
        let c = DrimCluster::new(ClusterConfig {
            coalesce: CoalesceConfig::strict(64),
            steal: false,
            ..ClusterConfig::tiny(2)
        });
        // tiny geometry: 4 slots per wave; 8 one-chunk requests split
        // round-robin over 2 devices = exactly one full wave per device
        let mut rng = Rng::new(77);
        let operands: Vec<(BitRow, BitRow)> = (0..8)
            .map(|_| (BitRow::random(200, &mut rng), BitRow::random(200, &mut rng)))
            .collect();
        let pending: Vec<_> = operands
            .iter()
            .map(|(a, b)| {
                c.submit_blocking(BulkRequest::bitwise(
                    BulkOp::Xnor2,
                    vec![a.clone(), b.clone()],
                ))
            })
            .collect();
        c.flush_coalesced();
        for (rx, (a, b)) in pending.into_iter().zip(&operands) {
            let resp = rx.recv().expect("coalesced response");
            assert_eq!(resp.inner.batched_with, 4, "four 1-chunk items per wave");
            let mut want = BitRow::zeros(200);
            want.apply2(a, b, |x, y| !(x ^ y));
            match resp.inner.result {
                Payload::Bits(got) => assert_eq!(got, want),
                _ => panic!("wrong payload kind"),
            }
        }
        let snap = c.shutdown();
        assert_eq!(snap.completed, 8);
        assert_eq!(snap.coalesced_requests, 8);
        // each device packed 4 private waves into 1: 3 saved apiece
        assert_eq!(snap.waves_saved, 6);
        assert_eq!(snap.merged.waves, 2);
        assert!((snap.slot_occupancy() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn coalescing_off_keeps_private_wave_sets() {
        let c = DrimCluster::new(ClusterConfig {
            steal: false,
            ..ClusterConfig::tiny(2)
        });
        let mut rng = Rng::new(78);
        let pending: Vec<_> = (0..4)
            .map(|_| {
                let a = BitRow::random(200, &mut rng);
                c.submit_blocking(BulkRequest::bitwise(BulkOp::Not, vec![a]))
            })
            .collect();
        for rx in pending {
            assert_eq!(rx.recv().unwrap().inner.batched_with, 1);
        }
        let snap = c.shutdown();
        assert_eq!(snap.coalesced_requests, 0);
        assert_eq!(snap.waves_saved, 0);
        assert_eq!(snap.merged.waves, 4, "one private wave per request");
    }

    #[test]
    fn background_rebalancer_replicates_hot_regions_unprompted() {
        let c = DrimCluster::new(ClusterConfig {
            steal: false,
            rebalance: Some(RebalanceConfig {
                policy: ReplicationPolicy::new(ReplicationConfig {
                    hot_uses: 3,
                    amortize_factor: 1.0,
                    ..ReplicationConfig::default()
                }),
                epoch: std::time::Duration::from_millis(2),
                min_queue_depth: 0,
            }),
            ..ClusterConfig::tiny(4)
        });
        let mut rng = Rng::new(91);
        let a = BitRow::random(2048, &mut rng);
        let r = c.register_resident(DeviceId(0), Payload::Bits(a));
        // keep the region hot until a background sweep replicates it —
        // no rebalance() call anywhere in this test
        let t0 = std::time::Instant::now();
        while c.registry().replicas(r).map(|v| v.len()).unwrap_or(0) < 2 {
            c.run_routed(ClusterRequest::resident(BulkOp::Not, vec![r]))
                .unwrap();
            assert!(
                t0.elapsed() < std::time::Duration::from_secs(20),
                "maintenance thread never replicated the hot region"
            );
        }
        let reps = c.registry().replicas(r).unwrap();
        assert!(!c.locality().same_channel(reps[0], reps[1]));
        let snap = c.shutdown();
        assert_eq!(snap.replications, 1);
        assert!(snap.copied_bytes > 0, "replication stream must be charged");
    }

    #[test]
    fn capacity_bounded_fleet_evicts_and_requeues_gracefully() {
        let bits = 1024usize;
        let c = DrimCluster::new(ClusterConfig {
            steal: false,
            capacity: CapacityConfig {
                // each device holds exactly one region: every extra
                // registration evicts the incumbent
                capacity: DeviceCapacity::of_bits(bits as u64),
                policy: EvictionPolicy::Lru,
            },
            ..ClusterConfig::tiny(2)
        });
        let requeues = c.pump_capacity(6, 24, bits, 1.2, None, 63);
        for d in 0..2 {
            assert!(c.registry().resident_bits_on(DeviceId(d)) <= bits as u64);
        }
        c.registry().check_invariants().expect("registry invariants");
        let snap = c.shutdown();
        assert_eq!(snap.completed, 24, "every request completes (no collapse)");
        assert!(snap.evictions > 0, "3 regions per 1-region device must evict");
        // requeues are the defined recovery path, not an error
        let _ = requeues;
    }

    #[test]
    fn external_restage_charges_the_owning_device_synchronously() {
        let c = DrimCluster::new(ClusterConfig {
            steal: false,
            movement: MovementConfig::External,
            ..ClusterConfig::tiny(2)
        });
        let mut rng = Rng::new(101);
        let a = BitRow::random(2048, &mut rng);
        c.try_restage_resident(DeviceId(1), Payload::Bits(a))
            .expect("unbounded fleet admits the restage");
        let snap = c.shutdown();
        assert_eq!(snap.movement.total_moves(), 1);
        assert_eq!(snap.movement.in_dram_moves(), 0, "external is off-chip");
        // the bus round trip lands on the device that owns the rows, and
        // it is visible copy time (nothing is hidden off-chip)
        assert_eq!(snap.copy_ns_per_device[0], 0);
        assert!(snap.copy_ns_per_device[1] > 0);
        assert!(snap.copy_cycles > 0, "off-chip hops burn bus cycles");
        assert_eq!(snap.movement.prefetch_hidden_ns, 0);
    }

    #[test]
    fn rebalancer_landing_hops_charge_the_destination_not_the_coordinator() {
        // Mirror of the worker-side copy-charging gate: the rebalance
        // round runs on the *coordinator* thread, but every nanosecond of
        // the landing hop must appear on the destination device's copy
        // clock — never on the region's old home, never on lane 0.
        let c = DrimCluster::new(ClusterConfig {
            steal: false,
            movement: MovementConfig::External,
            ..ClusterConfig::tiny(4)
        });
        let mut rng = Rng::new(102);
        let a = BitRow::random(2048, &mut rng);
        let r = c.register_resident(DeviceId(0), Payload::Bits(a));
        // routed hits on the owner are free, so device 0's copy clock
        // stays exactly zero unless attribution leaks
        for _ in 0..4 {
            c.run_routed(ClusterRequest::resident(BulkOp::Not, vec![r]))
                .unwrap();
        }
        let policy = ReplicationPolicy::new(ReplicationConfig {
            hot_uses: 3,
            amortize_factor: 1.0,
            ..ReplicationConfig::default()
        });
        let actions = c.rebalance(&policy);
        assert!(
            actions
                .iter()
                .any(|x| matches!(x, PlacementAction::Replicate { region, .. } if *region == r)),
            "{actions:?}"
        );
        let dest = *c
            .registry()
            .replicas(r)
            .unwrap()
            .iter()
            .find(|d| **d != DeviceId(0))
            .expect("replica landed somewhere else");
        let snap = c.shutdown();
        assert_eq!(snap.movement.total_moves(), 1, "one landing hop");
        for (d, ns) in snap.copy_ns_per_device.iter().enumerate() {
            if d == dest.0 {
                assert!(*ns > 0, "stream + landing charge the destination");
            } else {
                assert_eq!(*ns, 0, "device {d} executed nothing chargeable");
            }
        }
    }

    #[test]
    fn prefetched_restage_settles_hidden_and_never_burns_the_bus() {
        let c = DrimCluster::new(ClusterConfig {
            steal: true,
            movement: MovementConfig::Prefetch,
            ..ClusterConfig::tiny(2)
        });
        let mut rng = Rng::new(103);
        let a = BitRow::random(2048, &mut rng);
        let r = c
            .try_restage_resident(DeviceId(1), Payload::Bits(a.clone()))
            .expect("unbounded fleet admits the restage");
        // the hop was enqueued before this submit, so whichever worker
        // acquires device 1's queue (its own or a thief) settles the
        // warm-up before executing — correct attribution under stealing
        let resp = c
            .run_routed(ClusterRequest::resident(BulkOp::Not, vec![r]))
            .unwrap();
        let mut want = BitRow::zeros(2048);
        want.not_from(&a);
        match resp.inner.result {
            Payload::Bits(got) => assert_eq!(got, want),
            _ => panic!("wrong payload kind"),
        }
        assert_eq!(
            c.movement_fabric().pending(),
            0,
            "draining device 1's queue settles its pending hop"
        );
        let snap = c.shutdown();
        assert_eq!(snap.movement.total_moves(), 1);
        assert_eq!(snap.movement.in_dram_moves(), 1, "pinned row => in-DRAM tier");
        assert!(snap.movement.prefetch_hidden_ns > 0);
        // the warm-up is hidden and in-DRAM: zero bus cycles on every
        // movement tier (a stolen execution may still charge its own
        // operand pull, so only the movement decomposition is pinned)
        assert_eq!(snap.movement.copy_cycles, [0, 0, 0, 0]);
    }
}
