//! Multi-device scale-out: N independent DRIM devices served as one fleet.
//!
//! The paper's platform wins by exploiting bank × sub-array parallelism
//! *inside* one chip; this layer takes the step SIMDRAM frames as going
//! from a compute-capable sub-array to an end-to-end multi-unit framework:
//! scheduling bulk X(N)OR traffic *across* devices (channels/ranks in
//! lock-step, as Ambit's rank-level operation motivates).
//!
//! * [`topology`]  — which devices exist (channel/rank coordinates, per-
//!   device [`ServiceConfig`]).
//! * [`scheduler`] — per-device FIFO queues behind one shared ready list,
//!   with an atomic Idle→Pending→Running shard state machine so a device
//!   queue is never double-enqueued (and never drained by two workers).
//! * [`worker`]    — one OS thread per device, each owning a
//!   [`Device`] (a [`DrimService`] by default), draining its own queue
//!   first and work-stealing backlogged ones.
//! * [`admission`] — bounded per-device in-flight tickets with load
//!   shedding: when every queue is full the fleet says so instead of
//!   letting latency grow without bound.
//! * [`metrics`]   — fleet aggregation: merge per-device
//!   [`MetricsSnapshot`]s (counters sum, simulated makespan is the
//!   busiest device) plus cluster-only counters (shed, steals, queue
//!   wait).
//!
//! [`DrimCluster`] is the facade gluing these together; `drim serve
//! --devices N`, `drim cluster`, examples/e2e_cluster.rs and
//! benches/ablate_devices.rs all sit on it.

pub mod admission;
pub mod metrics;
pub mod scheduler;
pub mod topology;
pub mod worker;

pub use admission::{AdmissionConfig, AdmissionController, AdmissionError};
pub use metrics::{merge_snapshots, FleetMetrics, FleetSnapshot};
pub use scheduler::{Scheduler, ShardState};
pub use topology::{DeviceDesc, DeviceId, Topology};
pub use worker::{ClusterResponse, ClusterTask};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use crate::coordinator::{
    BulkRequest, Device, DrimService, Metrics, ServiceConfig,
};

/// Fleet construction knobs.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    pub topology: Topology,
    pub admission: AdmissionConfig,
    /// Allow idle workers to drain other devices' queues. On by default;
    /// the scaling ablation turns it off to measure pure sharding.
    pub steal: bool,
}

impl ClusterConfig {
    /// `n` identical devices with the given per-device service config.
    pub fn uniform(n: usize, service: ServiceConfig) -> Self {
        ClusterConfig {
            topology: Topology::uniform(n, service),
            admission: AdmissionConfig::default(),
            steal: true,
        }
    }

    /// `n` test-sized devices.
    pub fn tiny(n: usize) -> Self {
        Self::uniform(n, ServiceConfig::tiny())
    }
}

/// N DRIM devices behind one submit interface.
pub struct DrimCluster {
    cfg: ClusterConfig,
    sched: Arc<Scheduler<ClusterTask>>,
    admission: Arc<AdmissionController>,
    fleet: Arc<FleetMetrics>,
    /// per-device metrics handles (outlive the devices themselves)
    device_metrics: Vec<Arc<Metrics>>,
    workers: Vec<JoinHandle<()>>,
    next_seq: AtomicU64,
}

impl DrimCluster {
    /// Build the default fleet: one [`DrimService`] per topology entry.
    pub fn new(cfg: ClusterConfig) -> Self {
        let devices: Vec<DrimService> = cfg
            .topology
            .devices
            .iter()
            .map(|d| DrimService::new(d.service.clone()))
            .collect();
        Self::with_devices(cfg, devices)
    }

    pub fn with_default_config(n_devices: usize) -> Self {
        Self::new(ClusterConfig::uniform(n_devices, ServiceConfig::default()))
    }

    /// Build a fleet over caller-supplied devices (tests inject mocks or
    /// heterogeneous services). `devices.len()` must match the topology.
    pub fn with_devices<D: Device + 'static>(cfg: ClusterConfig, devices: Vec<D>) -> Self {
        assert_eq!(
            devices.len(),
            cfg.topology.len(),
            "one device per topology entry"
        );
        let n = devices.len();
        let sched = Arc::new(Scheduler::new(n));
        let admission = Arc::new(AdmissionController::new(n, cfg.admission));
        let fleet = Arc::new(FleetMetrics::new());
        let device_metrics: Vec<Arc<Metrics>> =
            devices.iter().map(|d| d.metrics()).collect();
        let workers = devices
            .into_iter()
            .enumerate()
            .map(|(i, dev)| {
                let sched = Arc::clone(&sched);
                let admission = Arc::clone(&admission);
                let fleet = Arc::clone(&fleet);
                let steal = cfg.steal;
                std::thread::spawn(move || {
                    worker::worker_loop(DeviceId(i), dev, sched, admission, fleet, steal)
                })
            })
            .collect();
        DrimCluster {
            cfg,
            sched,
            admission,
            fleet,
            device_metrics,
            workers,
            next_seq: AtomicU64::new(1),
        }
    }

    pub fn config(&self) -> &ClusterConfig {
        &self.cfg
    }

    pub fn devices(&self) -> usize {
        self.device_metrics.len()
    }

    fn enqueue(&self, home: DeviceId, req: BulkRequest) -> Receiver<ClusterResponse> {
        let seq = self.next_seq.fetch_add(1, Ordering::Relaxed);
        let (tx, rx) = channel();
        self.sched.submit(
            home.0,
            ClusterTask {
                seq,
                home,
                req,
                reply: tx,
                admitted_at: Instant::now(),
            },
        );
        rx
    }

    /// Admit-or-shed submission: `Err` is the backpressure signal.
    pub fn try_submit(
        &self,
        req: BulkRequest,
    ) -> Result<Receiver<ClusterResponse>, AdmissionError> {
        let home = self.admission.try_admit()?;
        Ok(self.enqueue(home, req))
    }

    /// Pin a request to one device's queue (still admission-bounded).
    pub fn try_submit_to(
        &self,
        device: DeviceId,
        req: BulkRequest,
    ) -> Result<Receiver<ClusterResponse>, AdmissionError> {
        let home = self.admission.try_admit_to(device)?;
        Ok(self.enqueue(home, req))
    }

    /// Submit, parking through backpressure (clients that would rather
    /// wait than be refused). Never sheds; time spent waiting shows up in
    /// the fleet `waited` counter instead.
    pub fn submit_blocking(&self, req: BulkRequest) -> Receiver<ClusterResponse> {
        let home = self.admission.admit_wait();
        self.enqueue(home, req)
    }

    /// Submit and wait for the response.
    pub fn run(&self, req: BulkRequest) -> ClusterResponse {
        self.submit_blocking(req)
            .recv()
            .expect("cluster shut down mid-request")
    }

    pub fn snapshot(&self) -> FleetSnapshot {
        let per_device: Vec<_> =
            self.device_metrics.iter().map(|m| m.snapshot()).collect();
        FleetSnapshot {
            merged: merge_snapshots(&per_device),
            per_device,
            admitted: self.admission.admitted.load(Ordering::Relaxed),
            shed: self.admission.shed.load(Ordering::Relaxed),
            waited: self.admission.waited.load(Ordering::Relaxed),
            completed: self.fleet.completed.load(Ordering::Relaxed),
            steals: self.fleet.steals.load(Ordering::Relaxed),
            mean_queue_wait_ns: self.fleet.mean_queue_wait_ns(),
        }
    }

    /// Close the scheduler, let workers drain the ready backlog, and join
    /// them. Requests never admitted keep their receivers alive; requests
    /// still queued on a never-reacquired shard are dropped (their
    /// receivers observe disconnection).
    pub fn shutdown(mut self) -> FleetSnapshot {
        self.shutdown_now();
        self.snapshot()
    }

    fn shutdown_now(&mut self) {
        self.sched.close();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for DrimCluster {
    fn drop(&mut self) {
        self.shutdown_now();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Payload;
    use crate::isa::program::BulkOp;
    use crate::util::bitrow::BitRow;
    use crate::util::rng::Rng;

    #[test]
    fn two_device_fleet_roundtrip() {
        let c = DrimCluster::new(ClusterConfig::tiny(2));
        let mut rng = Rng::new(21);
        let a = BitRow::random(1000, &mut rng);
        let b = BitRow::random(1000, &mut rng);
        let mut want = BitRow::zeros(1000);
        want.apply2(&a, &b, |x, y| !(x ^ y));
        let resp = c.run(BulkRequest::bitwise(BulkOp::Xnor2, vec![a, b]));
        match resp.inner.result {
            Payload::Bits(got) => assert_eq!(got, want),
            _ => panic!("wrong payload kind"),
        }
        let snap = c.shutdown();
        assert_eq!(snap.admitted, 1);
        assert_eq!(snap.completed, 1);
        assert_eq!(snap.merged.requests, 1);
    }

    #[test]
    fn round_robin_lands_on_both_devices() {
        let c = DrimCluster::new(ClusterConfig::tiny(2));
        let mut rng = Rng::new(22);
        let pending: Vec<_> = (0..6)
            .map(|_| {
                let a = BitRow::random(512, &mut rng);
                c.try_submit(BulkRequest::bitwise(BulkOp::Not, vec![a]))
                    .expect("admission open")
            })
            .collect();
        let homes: Vec<usize> =
            pending.into_iter().map(|p| p.recv().unwrap().home.0).collect();
        assert!(homes.contains(&0) && homes.contains(&1), "{homes:?}");
        let snap = c.shutdown();
        assert_eq!(snap.completed, 6);
        // every request ran on some device and the merged view saw it
        assert_eq!(snap.merged.requests, 6);
    }

    #[test]
    fn shutdown_is_clean_with_no_traffic() {
        let c = DrimCluster::new(ClusterConfig::tiny(3));
        let snap = c.shutdown();
        assert_eq!(snap.devices(), 3);
        assert_eq!(snap.admitted, 0);
        assert_eq!(snap.merged.requests, 0);
    }
}
