//! Operand residency: which devices hold which operand region, what it
//! costs to move operands that are not where the computation runs, and —
//! since capacity became first-class — which regions a full device must
//! evict and which hot regions are worth replicating.
//!
//! DRIM computes X(N)OR between operands stored *in the same bit-line*, so
//! which device holds an operand is not a scheduling detail — it is the
//! premise of the whole platform (cf. RowClone/Ambit in-DRAM copy,
//! SIMDRAM's allocation-aware framework). This module models the data:
//!
//! * [`ResidencyRegistry`] maps [`RegionId`] handles to the devices
//!   holding a replica (and holds the simulated payload so routed requests
//!   can be materialized for execution). Each device's resident footprint
//!   is enforced against a [`DeviceCapacity`] under a pluggable
//!   [`EvictionPolicy`]: registration, replication and migration either
//!   fit, evict colder regions to make room, or fail fast with a
//!   [`CapacityError`].
//! * [`ClusterRequest`] lets each operand be either carried
//!   ([`OperandRef::Inline`]) or referenced by resident handle
//!   ([`OperandRef::Resident`]).
//! * [`CopyCostModel`] prices operand movement from the DDR burst/channel
//!   timing parameters (`dram::timing`): a host-carried operand is one
//!   streamed transfer into the device; an operand resident elsewhere is a
//!   read-out plus write-in, which serializes (2×) when source and
//!   destination share a channel and overlaps when they do not.
//! * [`LocalityModel`] binds the cost model to a concrete fleet topology
//!   and computes the [`CopyCharge`] of executing a placed request on a
//!   given device. The charge is computed against the device that
//!   *actually executes* (fleet workers call it with their own id), so
//!   the accounting stays correct under work stealing. Any replica counts
//!   as a hit; a miss streams from the cheapest replica.
//! * [`ReplicationPolicy`] turns the fleet's per-region traffic window
//!   (`cluster::metrics`) into [`PlacementAction`]s: hot regions gain
//!   replicas on uncovered channels once the window's traffic amortizes
//!   the modeled copy, and overloaded devices shed cold regions.
//!
//! Eviction is tombstoned: a handle whose last replica was evicted yields
//! the *defined* [`RouteError::Evicted`] signal from every lookup — the
//! caller re-registers and resubmits (shed/requeue), never panics, and is
//! never silently downgraded to an inline payload. Requests already past
//! [`ResidencyRegistry::resolve`] carry materialized payloads, so eviction
//! can never dangle a queued request.
//!
//! Tombstones are *bounded*: once a lookup has observed a tombstone (the
//! routing layer acknowledged the eviction), the entry is compactable —
//! [`ResidencyRegistry::compact_tombstones`] reclaims acknowledged
//! tombstones, and the set self-compacts past a threshold so a
//! long-running fleet under eviction churn never grows it without bound.
//! After compaction a stale handle degrades from [`RouteError::Evicted`]
//! to [`RouteError::UnknownRegion`]; callers already treat the two
//! identically (both mean "re-register and resubmit").

use std::collections::{HashMap, HashSet};
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock, RwLock, RwLockReadGuard, RwLockWriteGuard};

use crate::obs::trace::{Stage, Tracer};

use crate::coordinator::{BulkRequest, Payload};
use crate::dram::geometry::{DeviceCapacity, DramGeometry};
use crate::dram::timing::{MovementTier, TimingParams};
use crate::isa::program::BulkOp;

use super::admission::AdmissionError;
use super::metrics::RegionUse;
use super::topology::{DeviceId, Topology};

/// Handle to a registered operand region (dense, fleet-wide, never reused).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug, PartialOrd, Ord)]
pub struct RegionId(pub u64);

impl fmt::Display for RegionId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "region{}", self.0)
    }
}

/// One operand of a [`ClusterRequest`].
#[derive(Clone, Debug)]
pub enum OperandRef {
    /// Payload carried with the request — charged as a host→device
    /// streamed transfer no matter where it executes.
    Inline(Payload),
    /// Operand resident on some device — free when the request executes
    /// on any replica holder, charged as an inter-device copy otherwise.
    Resident(RegionId),
}

/// A fleet-level request whose operands may be resident handles instead of
/// carried payloads. The placement-aware submission paths
/// (`DrimCluster::try_submit_routed` and friends) accept this type; the
/// legacy payload-carrying paths keep accepting plain [`BulkRequest`]s.
#[derive(Clone, Debug)]
pub struct ClusterRequest {
    /// the bulk operation to run
    pub op: BulkOp,
    /// operands, inline or resident, in operand order
    pub operands: Vec<OperandRef>,
}

impl ClusterRequest {
    /// Build a request, checking operand count against the op's arity.
    pub fn new(op: BulkOp, operands: Vec<OperandRef>) -> Self {
        assert_eq!(operands.len(), op.arity(), "{}", op.name());
        ClusterRequest { op, operands }
    }

    /// All-inline request: the payload-carrying baseline, now with its
    /// host→device transfer made explicit in the copy accounting.
    pub fn carried(req: BulkRequest) -> Self {
        ClusterRequest {
            op: req.op,
            operands: req.operands.into_iter().map(OperandRef::Inline).collect(),
        }
    }

    /// All-resident request: every operand referenced by handle.
    pub fn resident(op: BulkOp, regions: Vec<RegionId>) -> Self {
        Self::new(op, regions.into_iter().map(OperandRef::Resident).collect())
    }
}

/// Why a routed submission was refused.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum RouteError {
    /// A resident handle references a region the registry never issued,
    /// or one explicitly dropped by its owner (`remove`).
    UnknownRegion(RegionId),
    /// The region's last replica was evicted by the capacity policy —
    /// the defined shed/requeue signal: re-register the operand and
    /// resubmit (or degrade to a carried payload).
    Evicted(RegionId),
    /// Admission control refused the request (fleet or device saturated).
    Admission(AdmissionError),
}

impl fmt::Display for RouteError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RouteError::UnknownRegion(r) => {
                write!(f, "unknown operand {r}: not in the residency registry")
            }
            RouteError::Evicted(r) => {
                write!(f, "operand {r} evicted by the capacity policy: re-register and resubmit")
            }
            RouteError::Admission(e) => write!(f, "{e}"),
        }
    }
}

impl From<AdmissionError> for RouteError {
    fn from(e: AdmissionError) -> Self {
        RouteError::Admission(e)
    }
}

/// Why a registration, replication, or migration was refused by capacity
/// enforcement.
#[derive(Clone, Copy, PartialEq, Debug)]
pub enum CapacityError {
    /// The payload alone exceeds the per-device capacity — no amount of
    /// eviction can make it fit.
    RegionTooLarge {
        /// device the registration targeted
        device: DeviceId,
        /// payload size that was refused
        bits: u64,
        /// the per-device capacity it exceeded
        capacity_bits: u64,
    },
    /// The device is full and the eviction policy would not free enough
    /// (fail-fast policy, or cost-aware eviction refused every victim).
    DeviceFull {
        /// device the registration targeted
        device: DeviceId,
        /// bits the newcomer needed
        needed_bits: u64,
        /// the per-device capacity
        capacity_bits: u64,
    },
}

impl fmt::Display for CapacityError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CapacityError::RegionTooLarge {
                device,
                bits,
                capacity_bits,
            } => write!(
                f,
                "{bits}-bit region exceeds {device}'s {capacity_bits}-bit \
                 residency capacity outright"
            ),
            CapacityError::DeviceFull {
                device,
                needed_bits,
                capacity_bits,
            } => write!(
                f,
                "{device} full: {needed_bits} bits needed, {capacity_bits}-bit \
                 capacity and the eviction policy freed nothing"
            ),
        }
    }
}

/// How a full device makes room for a new registration.
#[derive(Clone, Copy, PartialEq, Debug)]
pub enum EvictionPolicy {
    /// Never evict: registrations beyond capacity fail fast with
    /// [`CapacityError::DeviceFull`].
    FailFast,
    /// Evict least-recently-hit regions (by last routed use) until the
    /// newcomer fits.
    Lru,
    /// LRU, but refuse to evict a region whose re-copy cost exceeds the
    /// idle savings it has accrued: a victim is only evictable once
    /// `idle_ticks × rent_ns_per_tick ≥ host_to_device_ns(bits)` — a
    /// region that would immediately be streamed back in is cheaper to
    /// keep resident than to thrash.
    CostAware {
        /// simulated nanoseconds of "rent" one idle logical tick earns
        /// toward paying off the region's re-copy stream
        rent_ns_per_tick: f64,
    },
}

/// Per-device residency capacity plus the policy applied when it runs out.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CapacityConfig {
    /// resident bits each device may hold
    pub capacity: DeviceCapacity,
    /// what to do when a registration does not fit
    pub policy: EvictionPolicy,
}

impl Default for CapacityConfig {
    fn default() -> Self {
        CapacityConfig {
            capacity: DeviceCapacity::unbounded(),
            policy: EvictionPolicy::FailFast,
        }
    }
}

/// Outcome of an explicit [`ResidencyRegistry::evict_from`].
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum EvictOutcome {
    /// One replica dropped; the region is still resident elsewhere.
    ReplicaDropped,
    /// That was the last replica: the region is gone and tombstoned, and
    /// later lookups get the defined [`RouteError::Evicted`] signal.
    RegionEvicted,
    /// The region is unknown or holds no replica on that device.
    NotResident,
}

/// One resident operand of a routed request: its size and every device
/// holding a replica.
#[derive(Clone, Debug, PartialEq)]
pub struct ResidentSpan {
    /// the registry handle (per-region traffic counters key off it)
    pub region: RegionId,
    /// operand size in bits
    pub bits: u64,
    /// devices holding a replica (never empty for registry-built spans)
    pub replicas: Vec<DeviceId>,
}

/// Where a routed request's operand bits live, summarized for routing and
/// for the worker that will execute it. Resident operands keep their full
/// replica set (any replica is a hit); inline bits are the payloads the
/// request carried from the host.
#[derive(Clone, Debug, Default)]
pub struct Placement {
    /// one span per resident operand, in operand order
    pub resident: Vec<ResidentSpan>,
    /// operand bits carried inline with the request
    pub inline_bits: u64,
}

impl Placement {
    /// Record one resident operand replicated on `replicas`.
    pub fn add_resident(&mut self, region: RegionId, bits: u64, replicas: Vec<DeviceId>) {
        self.resident.push(ResidentSpan {
            region,
            bits,
            replicas,
        });
    }

    /// Resident operand bits available per device — an operand counts
    /// toward every device holding one of its replicas. Sorted by device
    /// id.
    pub fn resident_bits_per_device(&self) -> Vec<(DeviceId, u64)> {
        let mut per: Vec<(DeviceId, u64)> = Vec::new();
        for span in &self.resident {
            for &d in &span.replicas {
                match per.iter_mut().find(|(e, _)| *e == d) {
                    Some(e) => e.1 += span.bits,
                    None => per.push((d, span.bits)),
                }
            }
        }
        per.sort_by_key(|&(d, _)| d);
        per
    }

    /// Devices tied for the most resident operand bits — the executors
    /// the router may pick freely (any replica is a hit; the admission
    /// layer picks the least-loaded). Empty when every operand is inline.
    pub fn candidates(&self) -> Vec<DeviceId> {
        let per = self.resident_bits_per_device();
        let Some(best) = per.iter().map(|&(_, b)| b).max() else {
            return Vec::new();
        };
        per.into_iter()
            .filter(|&(_, b)| b == best)
            .map(|(d, _)| d)
            .collect()
    }

    /// The lowest-id device among [`Self::candidates`], if any operand is
    /// resident at all: executing there moves the fewest bytes.
    pub fn preferred(&self) -> Option<DeviceId> {
        self.candidates().into_iter().next()
    }

    /// Total resident operand bits across all resident operands.
    pub fn total_resident_bits(&self) -> u64 {
        self.resident.iter().map(|s| s.bits).sum()
    }

    /// True when every resident operand holds a replica on `device`, so
    /// executing there pays no copy for resident spans (inline bits still
    /// stream from the host). The fleet coalescer's co-residency
    /// eligibility: only such items may pack into `device`'s shared
    /// waves — a placement miss keeps its private wave set and its copy
    /// charge. Vacuously true for all-inline placements.
    pub fn co_resident_on(&self, device: DeviceId) -> bool {
        self.resident.iter().all(|s| s.replicas.contains(&device))
    }
}

/// Pinned physical row coordinate of one replica on its device — where
/// the region's rows actually sit in the DRAM geometry. The movement
/// fabric prices a landing hop (staging row → pinned row) by the tier of
/// this coordinate relative to the device's staging row at bank 0,
/// sub-array 0.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct RowCoord {
    /// bank index within the device
    pub bank: usize,
    /// sub-array index within the bank
    pub subarray: usize,
    /// starting row index within the sub-array
    pub row: usize,
}

impl RowCoord {
    /// Movement tier of the hop from the device's staging row (bank 0,
    /// sub-array 0 — where inbound streams land) into this coordinate.
    pub fn landing_tier(self) -> MovementTier {
        if self.bank == 0 && self.subarray == 0 {
            MovementTier::SameSubarray
        } else if self.bank == 0 {
            MovementTier::SameBank
        } else {
            MovementTier::SameDevice
        }
    }
}

/// Per-device allocator of pinned row slots. Slots are dense integers
/// decoded into [`RowCoord`]s bank-first (consecutive allocations spread
/// across banks, then sub-arrays, then rows — the interleave a real
/// allocator would use to keep compute sub-arrays busy). Freed slots are
/// recycled LIFO, so allocation is deterministic for a deterministic
/// operation order.
#[derive(Default)]
struct PinAllocator {
    free: Vec<u64>,
    next: u64,
}

impl PinAllocator {
    fn alloc(&mut self) -> u64 {
        self.free.pop().unwrap_or_else(|| {
            let slot = self.next;
            self.next += 1;
            slot
        })
    }

    fn release(&mut self, slot: u64) {
        self.free.push(slot);
    }
}

struct Region {
    /// devices holding a replica; never empty, `homes[0]` is the primary
    homes: Vec<DeviceId>,
    /// pinned row slot per replica, in lock-step with `homes` (decode via
    /// the registry geometry)
    pins: Vec<u64>,
    payload: Payload,
    /// logical clock value at the last routed use (or registration);
    /// atomic so the routed-hit path bumps it under a shard *read* lock
    last_hit: AtomicU64,
    /// routed uses since registration
    hits: AtomicU64,
    /// resolved requests referencing this region that are still queued or
    /// executing (admission-aware eviction refuses such victims; the
    /// executing worker releases the pin on completion)
    queued: AtomicU64,
}

impl Region {
    fn new(homes: Vec<DeviceId>, pins: Vec<u64>, payload: Payload, now: u64) -> Self {
        debug_assert_eq!(homes.len(), pins.len());
        Region {
            homes,
            pins,
            payload,
            last_hit: AtomicU64::new(now),
            hits: AtomicU64::new(0),
            queued: AtomicU64::new(0),
        }
    }
}

/// Tombstones kept in the registry before a self-compaction sweep runs.
/// Acknowledged entries are reclaimed the next time an eviction pushes
/// the set past this size (explicit [`ResidencyRegistry::compact_tombstones`]
/// calls reclaim earlier).
const TOMBSTONE_COMPACT_THRESHOLD: usize = 256;

/// Number of independently locked shards the region map is split across.
/// Power of two so [`shard_of`] is a mask. Sixteen comfortably exceeds
/// the worker counts the fleet spawns, so concurrent writers touching
/// different regions almost never contend on the same lock.
const RESIDENCY_SHARDS: usize = 16;

/// Which shard holds region `id`. Ids are dense (a single atomic
/// counter), so consecutive registrations round-robin across shards.
fn shard_of(id: u64) -> usize {
    (id as usize) & (RESIDENCY_SHARDS - 1)
}

/// One shard of the region map. Everything a mutator needs for a single
/// region lives in the owning shard; per-region hit bookkeeping is atomic
/// so the hot read paths never upgrade to a write lock.
#[derive(Default)]
struct Shard {
    regions: HashMap<u64, Region>,
}

/// Registry mapping operand regions to the devices holding their replicas,
/// with per-device footprint enforcement.
///
/// In the simulator the registry also holds the payload itself, so a
/// routed request can be materialized into an executable [`BulkRequest`]
/// wherever it lands; on real hardware the payload would be the row range
/// and only the coordinates would live here.
///
/// # Locking discipline (sharded)
///
/// The region map is split across [`RESIDENCY_SHARDS`] independently
/// locked shards keyed by [`shard_of`]. Per-region hit bookkeeping
/// (`last_hit`, `hits`, `queued`) is atomic, so the routed-hit path —
/// [`Self::resolve`], [`Self::placement_of`], [`Self::release_queued`] —
/// takes only shard *read* locks: concurrent hits never serialize on a
/// writer, and hits on different shards share nothing at all. Per-device
/// footprints are atomics reserved by compare-and-swap, so "footprint ≤
/// capacity on every device" still holds at every instant, not just
/// between operations — the concurrency stress suite polls it mid-flight.
///
/// Writers come in two tiers. Fast paths (registration with room to
/// spare, replication, migration onto free space, explicit eviction,
/// removal) lock exactly one shard. Slow paths that must survey the whole
/// fleet to pick eviction victims (registration/migration into a full
/// device) take every shard's write lock in ascending index order. The
/// lock order is shards (ascending) → footprint → tombstones; fast paths
/// hold at most one shard lock and never acquire a second, so the tiers
/// cannot deadlock. Every footprint mutation happens while at least one
/// shard write lock is held, which is what makes the all-shards read view
/// of [`Self::check_invariants`] a consistent snapshot.
pub struct ResidencyRegistry {
    /// the region map, sharded by [`shard_of`]
    shards: Vec<RwLock<Shard>>,
    /// resident bits per device (index = `DeviceId`), maintained in
    /// lock-step with the shards so capacity checks never rescan a map;
    /// the outer lock only guards growth for unbounded registries —
    /// mutation is CAS on the atomics under a read lock
    footprint: RwLock<Vec<AtomicU64>>,
    /// per-device pinned-row slot allocators (index = `DeviceId`), in the
    /// lock order after `footprint` and before `tombstones`; every
    /// mutation happens while a shard write lock is held, so pin sets and
    /// replica sets move in lock-step
    pins: Mutex<Vec<PinAllocator>>,
    /// DRAM geometry pin slots decode against (banks / sub-arrays / row
    /// bits) — also the movement fabric's row size for tier pricing
    geometry: DramGeometry,
    /// ids evicted by the capacity policy (never reused), so a racing
    /// lookup gets the defined `Evicted` error instead of `UnknownRegion`.
    /// The value records acknowledgement: `true` once some lookup has
    /// observed the tombstone, making it safe to compact away.
    tombstones: Mutex<HashMap<u64, bool>>,
    next: AtomicU64,
    /// devices this registry may reference (`None` = standalone/unbounded)
    bound: Option<usize>,
    capacity: DeviceCapacity,
    policy: EvictionPolicy,
    /// prices the re-copy stream for cost-aware eviction decisions
    cost: CopyCostModel,
    /// logical LRU clock, bumped on registration and every resolve
    clock: AtomicU64,
    evictions: AtomicU64,
    capacity_refusals: AtomicU64,
    /// acknowledged tombstones reclaimed by compaction since construction
    tombstones_compacted: AtomicU64,
    /// fleet tracer for eviction events (absent in standalone use)
    tracer: OnceLock<Arc<Tracer>>,
}

impl Default for ResidencyRegistry {
    fn default() -> Self {
        ResidencyRegistry {
            shards: (0..RESIDENCY_SHARDS)
                .map(|_| RwLock::new(Shard::default()))
                .collect(),
            footprint: RwLock::new(Vec::new()),
            pins: Mutex::new(Vec::new()),
            geometry: DramGeometry::default(),
            tombstones: Mutex::new(HashMap::new()),
            next: AtomicU64::new(0),
            bound: None,
            capacity: DeviceCapacity::unbounded(),
            policy: EvictionPolicy::FailFast,
            cost: CopyCostModel::default(),
            clock: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            capacity_refusals: AtomicU64::new(0),
            tombstones_compacted: AtomicU64::new(0),
            tracer: OnceLock::new(),
        }
    }
}

impl ResidencyRegistry {
    /// Unbounded registry (standalone use; fleet-owned registries are
    /// created with [`Self::for_fleet`] or [`Self::with_capacity`] so a
    /// bad `DeviceId` fails at registration time, not deep inside
    /// routing).
    pub fn new() -> Self {
        ResidencyRegistry::default()
    }

    /// Registry whose regions may only reference devices `0..devices`,
    /// with unbounded capacity (the pre-capacity behaviour).
    pub fn for_fleet(devices: usize) -> Self {
        ResidencyRegistry {
            bound: Some(devices),
            footprint: RwLock::new((0..devices).map(|_| AtomicU64::new(0)).collect()),
            pins: Mutex::new((0..devices).map(|_| PinAllocator::default()).collect()),
            ..ResidencyRegistry::default()
        }
    }

    /// Fleet-bounded registry enforcing `cfg.capacity` per device under
    /// `cfg.policy`; `cost` prices the re-copy stream cost-aware eviction
    /// weighs against idle savings.
    pub fn with_capacity(devices: usize, cfg: CapacityConfig, cost: CopyCostModel) -> Self {
        ResidencyRegistry {
            bound: Some(devices),
            capacity: cfg.capacity,
            policy: cfg.policy,
            cost,
            footprint: RwLock::new((0..devices).map(|_| AtomicU64::new(0)).collect()),
            pins: Mutex::new((0..devices).map(|_| PinAllocator::default()).collect()),
            ..ResidencyRegistry::default()
        }
    }

    /// Replace the DRAM geometry pin slots decode against (builder style;
    /// fleets pass their device geometry so pinned coordinates and the
    /// movement fabric's row size match the simulated hardware).
    pub fn with_geometry(mut self, geometry: DramGeometry) -> Self {
        self.geometry = geometry;
        self
    }

    /// The DRAM geometry pin slots decode against.
    pub fn geometry(&self) -> &DramGeometry {
        &self.geometry
    }

    /// The copy-cost model this registry prices movement with (eviction
    /// re-copy weighing and the movement fabric's landing hops).
    pub fn cost_model(&self) -> &CopyCostModel {
        &self.cost
    }

    /// The per-device capacity this registry enforces.
    pub fn capacity(&self) -> DeviceCapacity {
        self.capacity
    }

    /// The eviction policy applied when a device runs out of capacity.
    pub fn eviction_policy(&self) -> EvictionPolicy {
        self.policy
    }

    /// Replica evictions performed by the capacity policy (including
    /// explicit [`Self::evict_from`] calls) since construction.
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// Registrations/replications/migrations refused by capacity
    /// enforcement since construction.
    pub fn capacity_refusals(&self) -> u64 {
        self.capacity_refusals.load(Ordering::Relaxed)
    }

    /// Acknowledged tombstones reclaimed by compaction since construction
    /// (explicit [`Self::compact_tombstones`] calls plus self-compaction).
    pub fn tombstones_compacted(&self) -> u64 {
        self.tombstones_compacted.load(Ordering::Relaxed)
    }

    /// Attach the fleet tracer so evictions emit [`Stage::Evict`] events.
    /// First caller wins; later calls are ignored (the registry is wired
    /// once at fleet construction).
    pub fn set_tracer(&self, tracer: Arc<Tracer>) {
        let _ = self.tracer.set(tracer);
    }

    /// Reclaim tombstones the routing layer has acknowledged (a lookup
    /// returned [`RouteError::Evicted`] for them). Returns how many were
    /// dropped. Unacknowledged tombstones always survive, so a racing
    /// lookup still gets the defined `Evicted` signal at least once.
    pub fn compact_tombstones(&self) -> usize {
        let mut tombs = self.tombstones.lock().unwrap();
        self.compact_tombstones_locked(&mut tombs)
    }

    /// Mark `id`'s tombstone as observed by the routing layer. Returns
    /// whether a tombstone existed — the lookup paths use this to pick
    /// between `Evicted` (tombstoned) and `UnknownRegion` (never issued,
    /// removed, or compacted away).
    fn ack_tombstone(&self, id: u64) -> bool {
        let mut tombs = self.tombstones.lock().unwrap();
        match tombs.get_mut(&id) {
            Some(acked) => {
                *acked = true;
                true
            }
            None => false,
        }
    }

    fn compact_tombstones_locked(&self, tombs: &mut HashMap<u64, bool>) -> usize {
        let before = tombs.len();
        tombs.retain(|_, acked| !*acked);
        let dropped = before - tombs.len();
        if dropped > 0 {
            self.tombstones_compacted
                .fetch_add(dropped as u64, Ordering::Relaxed);
        }
        dropped
    }

    fn check(&self, device: DeviceId) {
        if let Some(n) = self.bound {
            assert!(device.0 < n, "{device} outside the {n}-device fleet");
        }
    }

    fn tick(&self) -> u64 {
        self.clock.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Ensure the footprint vector covers `device`. Fleet-bounded
    /// registries are pre-sized; only unbounded (standalone) registries
    /// ever grow, and growth is the sole writer of the outer lock.
    fn grow(&self, device: DeviceId) {
        if self.footprint.read().unwrap().len() > device.0 {
            return;
        }
        let mut fp = self.footprint.write().unwrap();
        while fp.len() <= device.0 {
            fp.push(AtomicU64::new(0));
        }
        let mut pins = self.pins.lock().unwrap();
        while pins.len() <= device.0 {
            pins.push(PinAllocator::default());
        }
    }

    /// Allocate a pinned row slot on `device`. Call only while holding a
    /// shard write lock (same discipline as [`Self::try_reserve`]).
    fn pin_alloc(&self, device: DeviceId) -> u64 {
        self.pins.lock().unwrap()[device.0].alloc()
    }

    /// Return a pinned row slot to `device`'s allocator (same discipline).
    fn pin_release(&self, device: DeviceId, slot: u64) {
        self.pins.lock().unwrap()[device.0].release(slot);
    }

    /// Decode a pin slot into a physical row coordinate under the
    /// registry geometry: consecutive slots spread across banks first,
    /// then sub-arrays, then rows.
    fn coord_of(&self, slot: u64) -> RowCoord {
        let banks = self.geometry.banks.max(1) as u64;
        let subs = self.geometry.subarrays_per_bank.max(1) as u64;
        RowCoord {
            bank: (slot % banks) as usize,
            subarray: ((slot / banks) % subs) as usize,
            row: (slot / (banks * subs)) as usize,
        }
    }

    /// Atomically reserve `bits` of residency on `device` iff they fit
    /// under the capacity — a CAS loop, so the bound holds at every
    /// instant without a global lock. Call only while holding a shard
    /// write lock (see the locking discipline on the struct); `device`
    /// must already be covered by [`Self::grow`].
    fn try_reserve(&self, device: DeviceId, bits: u64) -> bool {
        let cap = self.capacity.resident_bits;
        let fp = self.footprint.read().unwrap();
        fp[device.0]
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |used| {
                if bits <= cap.saturating_sub(used) {
                    Some(used + bits)
                } else {
                    None
                }
            })
            .is_ok()
    }

    /// Return `bits` of residency on `device`. Call only while holding a
    /// shard write lock (same discipline as [`Self::try_reserve`]).
    fn footprint_sub(&self, device: DeviceId, bits: u64) {
        let fp = self.footprint.read().unwrap();
        fp[device.0].fetch_sub(bits, Ordering::Relaxed);
    }

    /// Write-lock every shard in ascending index order — the slow paths'
    /// whole-registry view. Deadlock-free against the fast paths, which
    /// hold at most one shard lock and never acquire a second.
    fn lock_all(&self) -> Vec<RwLockWriteGuard<'_, Shard>> {
        self.shards.iter().map(|s| s.write().unwrap()).collect()
    }

    /// Read-lock every shard in ascending index order (invariant checks).
    fn read_all(&self) -> Vec<RwLockReadGuard<'_, Shard>> {
        self.shards.iter().map(|s| s.read().unwrap()).collect()
    }

    /// Pick the policy's eviction victim among regions resident on
    /// `device` (excluding `exclude`), or `None` when nothing is
    /// evictable. LRU order: minimum `last_hit`, ties toward the lowest
    /// id for determinism.
    ///
    /// Admission-aware: a region with queued (resolved, not yet executed)
    /// requests is never a victim under `Lru`/`CostAware` — evicting it
    /// would only bounce the next lookup into the `Evicted` requeue path
    /// and stream the payload straight back in. This is a finer signal
    /// than the scheduler's per-device queue depths: it pins exactly the
    /// regions the queued work references, not everything on a busy
    /// device.
    fn pick_victim(
        &self,
        guards: &[RwLockWriteGuard<'_, Shard>],
        device: DeviceId,
        exclude: Option<u64>,
    ) -> Option<u64> {
        let now = self.clock.load(Ordering::Relaxed);
        guards
            .iter()
            .flat_map(|g| g.regions.iter())
            .filter(|(id, r)| {
                if Some(**id) == exclude
                    || !r.homes.contains(&device)
                    || r.queued.load(Ordering::Relaxed) > 0
                {
                    return false;
                }
                match self.policy {
                    EvictionPolicy::FailFast => false,
                    EvictionPolicy::Lru => true,
                    EvictionPolicy::CostAware { rent_ns_per_tick } => {
                        let idle =
                            now.saturating_sub(r.last_hit.load(Ordering::Relaxed)) as f64;
                        let recopy = self.cost.host_to_device_ns(r.payload.bits() as u64);
                        recopy <= idle * rent_ns_per_tick
                    }
                }
            })
            .min_by_key(|(id, r)| (r.last_hit.load(Ordering::Relaxed), **id))
            .map(|(id, _)| *id)
    }

    /// Drop `id`'s replica on `from` within its write-locked shard,
    /// tombstoning the region if that was its last replica. Counts one
    /// eviction event.
    fn evict_in(&self, shard: &mut Shard, id: u64, from: DeviceId) {
        let Some(r) = shard.regions.get_mut(&id) else {
            return;
        };
        let Some(pos) = r.homes.iter().position(|&h| h == from) else {
            return;
        };
        r.homes.remove(pos);
        let pin = r.pins.remove(pos);
        let bits = r.payload.bits() as u64;
        let emptied = r.homes.is_empty();
        self.footprint_sub(from, bits);
        self.pin_release(from, pin);
        if emptied {
            shard.regions.remove(&id);
            let mut tombs = self.tombstones.lock().unwrap();
            tombs.insert(id, false);
            if tombs.len() > TOMBSTONE_COMPACT_THRESHOLD {
                self.compact_tombstones_locked(&mut tombs);
            }
        }
        self.evictions.fetch_add(1, Ordering::Relaxed);
        if let Some(t) = self.tracer.get() {
            t.instant(t.frontend_lane(), Stage::Evict, id, from.0 as u64);
        }
    }

    /// Reserve `bits` on `device`, evicting under the policy until they
    /// fit. Requires the whole-registry write view from
    /// [`Self::lock_all`] — victim selection must see every shard. The
    /// region `exclude` (the one being placed) is never a victim.
    fn make_room_all(
        &self,
        guards: &mut [RwLockWriteGuard<'_, Shard>],
        device: DeviceId,
        bits: u64,
        exclude: Option<u64>,
    ) -> Result<(), CapacityError> {
        let cap = self.capacity.resident_bits;
        if bits > cap {
            self.capacity_refusals.fetch_add(1, Ordering::Relaxed);
            return Err(CapacityError::RegionTooLarge {
                device,
                bits,
                capacity_bits: cap,
            });
        }
        loop {
            if self.try_reserve(device, bits) {
                return Ok(());
            }
            match self.pick_victim(guards, device, exclude) {
                Some(victim) => {
                    self.evict_in(&mut guards[shard_of(victim)], victim, device)
                }
                None => {
                    self.capacity_refusals.fetch_add(1, Ordering::Relaxed);
                    return Err(CapacityError::DeviceFull {
                        device,
                        needed_bits: bits,
                        capacity_bits: cap,
                    });
                }
            }
        }
    }

    /// Register a payload as resident on `device`, evicting under the
    /// policy if the device is full; returns its handle or the capacity
    /// refusal. Panics if `device` is outside a fleet-bounded registry's
    /// range.
    ///
    /// Fast path (room available): one shard write lock plus a CAS
    /// footprint reservation. Only when the device is actually full does
    /// registration escalate to the whole-registry view to run eviction.
    pub fn try_register(
        &self,
        device: DeviceId,
        payload: Payload,
    ) -> Result<RegionId, CapacityError> {
        self.check(device);
        let bits = payload.bits() as u64;
        self.grow(device);
        let id = self.next.fetch_add(1, Ordering::Relaxed);
        {
            let mut shard = self.shards[shard_of(id)].write().unwrap();
            if self.try_reserve(device, bits) {
                let now = self.tick();
                let pin = self.pin_alloc(device);
                shard
                    .regions
                    .insert(id, Region::new(vec![device], vec![pin], payload, now));
                return Ok(RegionId(id));
            }
        }
        // slow path: the device is full — survey every shard for victims
        let mut guards = self.lock_all();
        self.make_room_all(&mut guards, device, bits, None)?;
        let now = self.tick();
        let pin = self.pin_alloc(device);
        guards[shard_of(id)]
            .regions
            .insert(id, Region::new(vec![device], vec![pin], payload, now));
        Ok(RegionId(id))
    }

    /// [`Self::try_register`] for callers that treat a capacity refusal
    /// as a bug (unbounded registries, tests): panics on refusal.
    pub fn register(&self, device: DeviceId, payload: Payload) -> RegionId {
        self.try_register(device, payload)
            .unwrap_or_else(|e| panic!("register: {e}"))
    }

    /// Primary owner of a region (its first replica), if registered.
    pub fn owner(&self, region: RegionId) -> Option<DeviceId> {
        self.shards[shard_of(region.0)]
            .read()
            .unwrap()
            .regions
            .get(&region.0)
            .map(|r| r.homes[0])
    }

    /// Every device holding a replica of `region`, if registered.
    pub fn replicas(&self, region: RegionId) -> Option<Vec<DeviceId>> {
        self.shards[shard_of(region.0)]
            .read()
            .unwrap()
            .regions
            .get(&region.0)
            .map(|r| r.homes.clone())
    }

    /// Pinned row coordinate of `region`'s replica on `device`, if it
    /// holds one — the physical landing target the movement fabric prices
    /// hops against.
    pub fn pin_of(&self, region: RegionId, device: DeviceId) -> Option<RowCoord> {
        self.shards[shard_of(region.0)]
            .read()
            .unwrap()
            .regions
            .get(&region.0)
            .and_then(|r| {
                r.homes
                    .iter()
                    .position(|&h| h == device)
                    .map(|pos| self.coord_of(r.pins[pos]))
            })
    }

    /// Every pinned coordinate on `device`, sorted by region id — the
    /// uniqueness surface the property suite checks (no two live regions
    /// may share a (bank, sub-array, row) on one device).
    pub fn pins_on(&self, device: DeviceId) -> Vec<(RegionId, RowCoord)> {
        let mut out: Vec<(RegionId, RowCoord)> = Vec::new();
        for s in &self.shards {
            let shard = s.read().unwrap();
            for (id, r) in &shard.regions {
                if let Some(pos) = r.homes.iter().position(|&h| h == device) {
                    out.push((RegionId(*id), self.coord_of(r.pins[pos])));
                }
            }
        }
        out.sort_by_key(|&(id, _)| id);
        out
    }

    /// Payload size of a region in bits, if registered.
    pub fn bits(&self, region: RegionId) -> Option<usize> {
        self.shards[shard_of(region.0)]
            .read()
            .unwrap()
            .regions
            .get(&region.0)
            .map(|r| r.payload.bits())
    }

    /// Routed uses and last-use clock of a region (LRU inputs), if
    /// registered.
    pub fn hit_stats(&self, region: RegionId) -> Option<(u64, u64)> {
        self.shards[shard_of(region.0)]
            .read()
            .unwrap()
            .regions
            .get(&region.0)
            .map(|r| {
                (
                    r.hits.load(Ordering::Relaxed),
                    r.last_hit.load(Ordering::Relaxed),
                )
            })
    }

    /// Resolved-but-not-yet-executed requests referencing `region` (the
    /// admission-aware eviction pin), if registered.
    pub fn queued_requests(&self, region: RegionId) -> Option<u64> {
        self.shards[shard_of(region.0)]
            .read()
            .unwrap()
            .regions
            .get(&region.0)
            .map(|r| r.queued.load(Ordering::Relaxed))
    }

    /// Release the queued-request pins a successful [`Self::resolve`]
    /// placed on `placement`'s resident regions. Fleet workers call this
    /// once the request has executed; a region evicted or removed in the
    /// meantime is skipped (its pin died with it). Shard read locks only —
    /// the pin is an atomic, so completion never contends with writers.
    pub fn release_queued(&self, placement: &Placement) {
        for span in &placement.resident {
            let shard = self.shards[shard_of(span.region.0)].read().unwrap();
            if let Some(r) = shard.regions.get(&span.region.0) {
                let _ = r.queued.fetch_update(Ordering::Relaxed, Ordering::Relaxed, |q| {
                    Some(q.saturating_sub(1))
                });
            }
        }
    }

    /// Primary owner and a copy of the payload, if registered.
    pub fn lookup(&self, region: RegionId) -> Option<(DeviceId, Payload)> {
        self.shards[shard_of(region.0)]
            .read()
            .unwrap()
            .regions
            .get(&region.0)
            .map(|r| (r.homes[0], r.payload.clone()))
    }

    /// Add a replica of `region` on `to`. Replication is opportunistic
    /// and **never evicts**: it only consumes free capacity, refusing
    /// with [`CapacityError::DeviceFull`] otherwise — a replica is an
    /// optimization and must not push out a region someone registered.
    /// `Ok(true)` = replicated (or already there), `Ok(false)` = unknown
    /// region. Panics if `to` is outside a fleet-bounded registry's
    /// range.
    pub fn replicate(&self, region: RegionId, to: DeviceId) -> Result<bool, CapacityError> {
        self.check(to);
        self.grow(to);
        let mut shard = self.shards[shard_of(region.0)].write().unwrap();
        let Some(r) = shard.regions.get_mut(&region.0) else {
            return Ok(false);
        };
        if r.homes.contains(&to) {
            return Ok(true);
        }
        let bits = r.payload.bits() as u64;
        if !self.try_reserve(to, bits) {
            self.capacity_refusals.fetch_add(1, Ordering::Relaxed);
            return Err(CapacityError::DeviceFull {
                device: to,
                needed_bits: bits,
                capacity_bits: self.capacity.resident_bits,
            });
        }
        r.homes.push(to);
        r.pins.push(self.pin_alloc(to));
        Ok(true)
    }

    /// Re-home a region onto exactly `to`, dropping every other replica —
    /// the coherence point: after a migration there is one authoritative
    /// copy, so stale replicas can never serve. `Ok(true)` = migrated,
    /// `Ok(false)` = unknown region; capacity on `to` is enforced under
    /// the policy. Panics if `to` is outside a fleet-bounded registry's
    /// range.
    pub fn migrate(&self, region: RegionId, to: DeviceId) -> Result<bool, CapacityError> {
        self.check(to);
        self.grow(to);
        // fast path: already a holder, or `to` has free space — the
        // collapse happens under the region's own shard lock alone
        {
            let mut shard = self.shards[shard_of(region.0)].write().unwrap();
            let Some(r) = shard.regions.get_mut(&region.0) else {
                return Ok(false);
            };
            let bits = r.payload.bits() as u64;
            if r.homes.contains(&to) || self.try_reserve(to, bits) {
                self.collapse_onto(r, to, bits);
                return Ok(true);
            }
        }
        // slow path: `to` is full — whole-registry view to run eviction
        let mut guards = self.lock_all();
        let (bits, already) = match guards[shard_of(region.0)].regions.get(&region.0) {
            None => return Ok(false),
            Some(r) => (r.payload.bits() as u64, r.homes.contains(&to)),
        };
        if !already {
            self.make_room_all(&mut guards, to, bits, Some(region.0))?;
        }
        let r = guards[shard_of(region.0)]
            .regions
            .get_mut(&region.0)
            .expect("excluded from eviction");
        self.collapse_onto(r, to, bits);
        Ok(true)
    }

    /// Collapse `r`'s replica set onto `to` alone, returning footprint and
    /// pins of every dropped replica. `to`'s existing pin (if it was
    /// already a holder) is kept — the region does not move on `to`;
    /// otherwise a fresh pin is allocated there. Call with `r`'s shard
    /// write-locked and `to`'s footprint already reserved when `to` was
    /// not a holder.
    fn collapse_onto(&self, r: &mut Region, to: DeviceId, bits: u64) {
        let homes = std::mem::take(&mut r.homes);
        let pins = std::mem::take(&mut r.pins);
        let mut kept = None;
        for (h, pin) in homes.into_iter().zip(pins) {
            if h == to && kept.is_none() {
                kept = Some(pin);
            } else {
                self.footprint_sub(h, bits);
                self.pin_release(h, pin);
            }
        }
        r.homes = vec![to];
        r.pins = vec![kept.unwrap_or_else(|| self.pin_alloc(to))];
    }

    /// Explicitly drop `region`'s replica on `from` (policy engines and
    /// tests; the capacity path evicts through the same bookkeeping).
    pub fn evict_from(&self, region: RegionId, from: DeviceId) -> EvictOutcome {
        let mut shard = self.shards[shard_of(region.0)].write().unwrap();
        let (present, last) = match shard.regions.get(&region.0) {
            None => return EvictOutcome::NotResident,
            Some(r) => (r.homes.contains(&from), r.homes.len() == 1),
        };
        if !present {
            return EvictOutcome::NotResident;
        }
        self.evict_in(&mut shard, region.0, from);
        if last {
            EvictOutcome::RegionEvicted
        } else {
            EvictOutcome::ReplicaDropped
        }
    }

    /// Drop a region everywhere; returns its payload if it was
    /// registered. An owner-initiated drop is *not* an eviction: later
    /// lookups see [`RouteError::UnknownRegion`].
    pub fn remove(&self, region: RegionId) -> Option<Payload> {
        let mut shard = self.shards[shard_of(region.0)].write().unwrap();
        let r = shard.regions.remove(&region.0)?;
        let bits = r.payload.bits() as u64;
        for (h, pin) in r.homes.iter().zip(&r.pins) {
            self.footprint_sub(*h, bits);
            self.pin_release(*h, *pin);
        }
        Some(r.payload)
    }

    /// Number of registered regions (sums the shards; a point-in-time
    /// figure under concurrent mutation).
    pub fn len(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.read().unwrap().regions.len())
            .sum()
    }

    /// True when no region is registered.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total bits resident on one device (capacity/balance reporting).
    /// O(1): one atomic load of the maintained footprint counter.
    pub fn resident_bits_on(&self, device: DeviceId) -> u64 {
        self.footprint
            .read()
            .unwrap()
            .get(device.0)
            .map(|a| a.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// `(region, bits, replica count)` for every region with a replica on
    /// `device`, sorted by id (deterministic input for policy decisions).
    /// Visits shards one at a time, so concurrent mutators on other
    /// shards are never blocked for the whole sweep.
    pub fn regions_on(&self, device: DeviceId) -> Vec<(RegionId, u64, usize)> {
        let mut out: Vec<(RegionId, u64, usize)> = Vec::new();
        for s in &self.shards {
            let shard = s.read().unwrap();
            out.extend(
                shard
                    .regions
                    .iter()
                    .filter(|(_, r)| r.homes.contains(&device))
                    .map(|(id, r)| (RegionId(*id), r.payload.bits() as u64, r.homes.len())),
            );
        }
        out.sort_by_key(|&(id, _, _)| id);
        out
    }

    /// Recompute the per-device footprint from the region map and verify
    /// the maintained counters match, every region has a non-empty
    /// duplicate-free in-bounds replica set, no live region is
    /// tombstoned, and no device exceeds its capacity. Returns the first
    /// violation. Debug aid for the concurrency and property suites.
    pub fn check_invariants(&self) -> Result<(), String> {
        // all shard read locks (ascending) block every footprint mutator
        // — each one holds a shard write lock — so the counters, region
        // maps, and tombstones below are one consistent snapshot
        let guards = self.read_all();
        let fp = self.footprint.read().unwrap();
        let tombs = self.tombstones.lock().unwrap();
        let cap = self.capacity.resident_bits;
        let mut recomputed = vec![0u64; fp.len()];
        let mut live_pins: HashSet<(usize, u64)> = HashSet::new();
        for g in &guards {
            for (id, r) in &g.regions {
                if r.homes.is_empty() {
                    return Err(format!("region{id} has no replica"));
                }
                let mut seen = r.homes.clone();
                seen.sort();
                seen.dedup();
                if seen.len() != r.homes.len() {
                    return Err(format!("region{id} lists a device twice: {:?}", r.homes));
                }
                if r.pins.len() != r.homes.len() {
                    return Err(format!(
                        "region{id} pin/replica mismatch: {} pins for {} homes",
                        r.pins.len(),
                        r.homes.len()
                    ));
                }
                for (h, pin) in r.homes.iter().zip(&r.pins) {
                    if !live_pins.insert((h.0, *pin)) {
                        let c = self.coord_of(*pin);
                        return Err(format!(
                            "region{id} pin collides on {h}: bank {} sub-array {} row {}",
                            c.bank, c.subarray, c.row
                        ));
                    }
                }
                if tombs.contains_key(id) {
                    return Err(format!("region{id} both live and tombstoned"));
                }
                for h in &r.homes {
                    if let Some(n) = self.bound {
                        if h.0 >= n {
                            return Err(format!("region{id} on out-of-fleet {h}"));
                        }
                    }
                    if h.0 >= recomputed.len() {
                        return Err(format!("region{id} on {h} beyond the footprint vector"));
                    }
                    recomputed[h.0] += r.payload.bits() as u64;
                }
            }
        }
        for (d, (&want, have)) in recomputed
            .iter()
            .zip(fp.iter().map(|a| a.load(Ordering::Relaxed)))
            .enumerate()
        {
            if want != have {
                return Err(format!("dev{d} footprint {have} != recomputed {want}"));
            }
            if have > cap {
                return Err(format!("dev{d} footprint {have} exceeds capacity {cap}"));
            }
        }
        Ok(())
    }

    /// Summarize where a request's operand bits live *without* cloning any
    /// payload — the cheap path for routing decisions ([`Placement`] only;
    /// use [`Self::resolve`] when the request is actually submitted).
    pub fn placement_of(&self, req: &ClusterRequest) -> Result<Placement, RouteError> {
        let mut placement = Placement::default();
        for o in &req.operands {
            match o {
                OperandRef::Inline(p) => placement.inline_bits += p.bits() as u64,
                OperandRef::Resident(r) => {
                    let shard = self.shards[shard_of(r.0)].read().unwrap();
                    match shard.regions.get(&r.0) {
                        Some(region) => placement.add_resident(
                            *r,
                            region.payload.bits() as u64,
                            region.homes.clone(),
                        ),
                        None => {
                            // a live region is never tombstoned, so
                            // region-then-tombstone is race-free; the
                            // routing layer has now observed the
                            // eviction, making the tombstone compactable
                            drop(shard);
                            return Err(if self.ack_tombstone(r.0) {
                                RouteError::Evicted(*r)
                            } else {
                                RouteError::UnknownRegion(*r)
                            });
                        }
                    }
                }
            }
        }
        Ok(placement)
    }

    /// Materialize a [`ClusterRequest`] into an executable [`BulkRequest`]
    /// plus the [`Placement`] summary the copy accounting charges from,
    /// bumping each resident region's LRU clock and hit counter (this is
    /// the one call per submitted request). Each resident region is also
    /// pinned as *queued* — admission-aware eviction refuses pinned
    /// victims — until the executing worker calls
    /// [`Self::release_queued`] with the returned placement.
    ///
    /// A region evicted between routing and here yields the defined
    /// [`RouteError::Evicted`]; once this returns `Ok`, the request
    /// carries materialized payloads and later evictions cannot dangle
    /// it.
    ///
    /// Panics if materialized operands disagree in bit length (the same
    /// contract `BulkRequest::bitwise` enforces for carried payloads).
    pub fn resolve(&self, req: &ClusterRequest) -> Result<(BulkRequest, Placement), RouteError> {
        let mut operands = Vec::with_capacity(req.operands.len());
        let mut placement = Placement::default();
        let now = self.tick();
        for o in &req.operands {
            match o {
                OperandRef::Inline(p) => {
                    placement.inline_bits += p.bits() as u64;
                    operands.push(p.clone());
                }
                OperandRef::Resident(r) => {
                    let shard = self.shards[shard_of(r.0)].read().unwrap();
                    match shard.regions.get(&r.0) {
                        Some(region) => {
                            region.last_hit.store(now, Ordering::Relaxed);
                            region.hits.fetch_add(1, Ordering::Relaxed);
                            // pin as we go; unwound below if a later
                            // operand fails, so a half-resolved request
                            // never leaves regions pinned forever
                            region.queued.fetch_add(1, Ordering::Relaxed);
                            placement.add_resident(
                                *r,
                                region.payload.bits() as u64,
                                region.homes.clone(),
                            );
                            operands.push(region.payload.clone());
                        }
                        None => {
                            drop(shard);
                            self.release_queued(&placement);
                            return Err(if self.ack_tombstone(r.0) {
                                RouteError::Evicted(*r)
                            } else {
                                RouteError::UnknownRegion(*r)
                            });
                        }
                    }
                }
            }
        }
        if let Some(first) = operands.first() {
            let bits = first.bits();
            assert!(
                operands.iter().all(|o| o.bits() == bits),
                "{}: operand sizes disagree",
                req.op.name()
            );
        }
        Ok((
            BulkRequest {
                op: req.op,
                operands,
            },
            placement,
        ))
    }
}

/// Inter-device copy-cost model derived from the DDR timing parameters.
///
/// All transfers are streamed in [`crate::dram::timing::BURST_BITS`]-bit
/// bursts at `t_burst_ns` each; cycle counts use the command-clock period
/// `t_ck_ns` (one burst = 4 clocks at DDR4-2133).
#[derive(Clone, Debug)]
pub struct CopyCostModel {
    /// the DDR timing parameters costs derive from
    pub timing: TimingParams,
}

impl CopyCostModel {
    /// Bind the model to `timing`.
    pub fn new(timing: TimingParams) -> Self {
        CopyCostModel { timing }
    }

    /// Nanoseconds to bring `bits` from the host into a device: one
    /// streamed pass over the destination channel.
    pub fn host_to_device_ns(&self, bits: u64) -> f64 {
        self.timing.stream_ns(bits)
    }

    /// Nanoseconds to move `bits` between two devices. When source and
    /// destination share a DDR channel the read-out and write-in serialize
    /// on the shared data bus (2× the stream time); across channels the
    /// two directions overlap and the stream time is paid once.
    pub fn device_to_device_ns(&self, bits: u64, same_channel: bool) -> f64 {
        let one = self.timing.stream_ns(bits);
        if same_channel {
            2.0 * one
        } else {
            one
        }
    }

    /// Bus clock cycles corresponding to `ns` of copy time.
    pub fn cycles_for(&self, ns: f64) -> u64 {
        self.timing.cycles_for_ns(ns)
    }

    /// Landing hop priced the von-Neumann way: after an inbound stream
    /// parks `bits` in the device's staging row, moving them into their
    /// pinned rows costs a full read-out + write-in over the external bus
    /// (2× the stream, and the bus is occupied the whole time). This is
    /// what every replication/migration/re-stage pays with the movement
    /// fabric's in-DRAM tiers disabled.
    pub fn external_landing(&self, bits: u64) -> CopyCharge {
        let ns = 2.0 * self.timing.stream_ns(bits);
        CopyCharge {
            bytes: bits.div_ceil(8),
            ns,
            cycles: self.timing.cycles_for_ns(ns),
        }
    }

    /// Landing hop priced by the RowClone in-DRAM tiers: the staging→pin
    /// move happens inside the device at `tier`'s activation cost
    /// (`row_bits` bits per row) and occupies **zero** external bus
    /// cycles.
    pub fn in_dram_landing(&self, bits: u64, tier: MovementTier, row_bits: u64) -> CopyCharge {
        let (ns, cycles) = self.timing.tier_copy(tier, bits, row_bits);
        CopyCharge {
            bytes: bits.div_ceil(8),
            ns,
            cycles,
        }
    }
}

impl Default for CopyCostModel {
    fn default() -> Self {
        CopyCostModel::new(TimingParams::default())
    }
}

/// What executing a placed request on a particular device costs in operand
/// movement. `bytes == 0` means a resident hit.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CopyCharge {
    /// operand bytes that had to move (host→device or device→device)
    pub bytes: u64,
    /// simulated copy time added to the executing device
    pub ns: f64,
    /// DDR bus clock cycles the movement occupied
    pub cycles: u64,
}

impl CopyCharge {
    /// True when no operand had to move — the resident-hit case.
    pub fn is_free(&self) -> bool {
        self.bytes == 0
    }

    /// The zero charge (hits, already-resident replicas).
    pub fn free() -> Self {
        CopyCharge {
            bytes: 0,
            ns: 0.0,
            cycles: 0,
        }
    }
}

/// The copy-cost model bound to a concrete fleet topology: knows which
/// devices share a channel and turns a [`Placement`] plus an executing
/// device into a [`CopyCharge`].
pub struct LocalityModel {
    channel_of: Vec<usize>,
    /// the underlying burst/clock cost model
    pub model: CopyCostModel,
}

impl LocalityModel {
    /// Bind `timing`-derived costs to the channel coordinates of `t`.
    pub fn from_topology(t: &Topology, timing: TimingParams) -> Self {
        LocalityModel {
            channel_of: (0..t.len()).map(|i| t.channel_of(DeviceId(i))).collect(),
            model: CopyCostModel::new(timing),
        }
    }

    /// Number of devices in the bound topology.
    pub fn devices(&self) -> usize {
        self.channel_of.len()
    }

    /// DDR channel coordinate of one device.
    pub fn channel(&self, d: DeviceId) -> usize {
        self.channel_of[d.0]
    }

    /// Do two devices sit on the same DDR channel?
    pub fn same_channel(&self, a: DeviceId, b: DeviceId) -> bool {
        self.channel_of[a.0] == self.channel_of[b.0]
    }

    /// Charge for landing one `bits`-sized copy on `to`, streamed from
    /// the cheapest of `sources`: free if `to` already holds one, a
    /// host→device stream if `sources` is empty (inline staging), else
    /// the cheapest device→device stream. Prices replication and
    /// migration as well as per-operand miss charges.
    pub fn cheapest_copy(&self, bits: u64, sources: &[DeviceId], to: DeviceId) -> CopyCharge {
        if bits == 0 || sources.contains(&to) {
            return CopyCharge::free();
        }
        let ns = sources
            .iter()
            .map(|&s| self.model.device_to_device_ns(bits, self.same_channel(s, to)))
            .fold(f64::INFINITY, f64::min);
        let ns = if ns.is_finite() {
            ns
        } else {
            self.model.host_to_device_ns(bits)
        };
        CopyCharge {
            bytes: bits.div_ceil(8),
            ns,
            cycles: self.model.cycles_for(ns),
        }
    }

    /// Charge for executing a request with placement `p` on `executor`:
    /// a resident operand with a replica on `executor` is free; one
    /// resident elsewhere streams from its cheapest replica; inline bits
    /// pay the host→device stream.
    pub fn charge(&self, p: &Placement, executor: DeviceId) -> CopyCharge {
        let mut ns = 0.0;
        let mut bytes = 0u64;
        for span in &p.resident {
            let c = self.cheapest_copy(span.bits, &span.replicas, executor);
            ns += c.ns;
            bytes += c.bytes;
        }
        if p.inline_bits > 0 {
            ns += self.model.host_to_device_ns(p.inline_bits);
            bytes += p.inline_bits.div_ceil(8);
        }
        CopyCharge {
            bytes,
            ns,
            cycles: self.model.cycles_for(ns),
        }
    }
}

/// Knobs for [`ReplicationPolicy`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ReplicationConfig {
    /// routed uses within one observation window before a region counts
    /// as hot (replication candidate)
    pub hot_uses: u64,
    /// the window's projected savings must cover this many times the
    /// one-time replica stream before the copy counts as amortized
    pub amortize_factor: f64,
    /// replicas per region, counting the primary (bounded by the channel
    /// count regardless — replicas only go to uncovered channels)
    pub max_replicas: usize,
    /// window uses at or below which a region counts as cold (migration
    /// candidate when its device runs hot)
    pub cold_uses: u64,
    /// footprint fraction above which a device sheds cold regions
    pub high_watermark: f64,
}

impl Default for ReplicationConfig {
    fn default() -> Self {
        ReplicationConfig {
            hot_uses: 3,
            amortize_factor: 2.0,
            max_replicas: 2,
            cold_uses: 0,
            high_watermark: 0.95,
        }
    }
}

/// One planned placement change (executed by `DrimCluster::rebalance`,
/// which streams the copy at the modeled cost).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlacementAction {
    /// Add a replica of `region` on `to` (hot-region spread across
    /// channels; routing then treats either copy as a hit).
    Replicate {
        /// region gaining a replica
        region: RegionId,
        /// destination device
        to: DeviceId,
    },
    /// Collapse `region` onto `to` alone (cold-region shed off an
    /// overloaded device; drops every other replica — the coherence
    /// point).
    Migrate {
        /// region being re-homed
        region: RegionId,
        /// destination device
        to: DeviceId,
    },
}

/// Cost-driven replication/migration policy over the fleet's per-region
/// traffic window (see [`Self::plan`] for the decision rules).
#[derive(Clone, Debug, Default)]
pub struct ReplicationPolicy {
    /// policy knobs
    pub cfg: ReplicationConfig,
}

impl ReplicationPolicy {
    /// Policy with explicit knobs.
    pub fn new(cfg: ReplicationConfig) -> Self {
        ReplicationPolicy { cfg }
    }

    /// Plan one rebalance round from the drained traffic `window`
    /// (hottest region first, as `FleetMetrics::take_region_window`
    /// returns it), the registry's current replica sets and footprints,
    /// and the per-device `queue_depths`.
    ///
    /// Decisions, applied against a local footprint model so one round
    /// never overshoots capacity:
    ///
    /// 1. **Replicate hot regions across channels.** A region with at
    ///    least `hot_uses` routed uses in the window gains a replica on a
    ///    channel that holds none, once the window's traffic amortizes
    ///    the stream: `uses × miss_stream_ns ≥ amortize_factor ×
    ///    replica_stream_ns`, where the miss stream is the worst-case
    ///    serialized same-channel pull and the replica stream comes from
    ///    the cheapest existing copy (both priced by the fleet's
    ///    [`CopyCostModel`]). The target is the device with the most free
    ///    capacity (ties: shallower queue, then lower id). Replication
    ///    only uses free space — it never evicts.
    /// 2. **Migrate cold regions off overloaded devices.** A device above
    ///    `high_watermark × capacity` sheds its largest single-replica
    ///    region with at most `cold_uses` window uses to the emptiest
    ///    device with room (ties: shallower queue, then lower id).
    pub fn plan(
        &self,
        window: &[RegionUse],
        registry: &ResidencyRegistry,
        locality: &LocalityModel,
        queue_depths: &[usize],
    ) -> Vec<PlacementAction> {
        let devices = locality.devices();
        let cap = registry.capacity().resident_bits;
        let mut footprint: Vec<u64> = (0..devices)
            .map(|d| registry.resident_bits_on(DeviceId(d)))
            .collect();
        let depth = |d: usize| queue_depths.get(d).copied().unwrap_or(0);
        let mut actions = Vec::new();
        let mut replicated: HashSet<u64> = HashSet::new();

        // 1. hot-region replication across channels
        for u in window {
            if u.uses < self.cfg.hot_uses {
                continue;
            }
            let Some(reps) = registry.replicas(u.region) else {
                continue;
            };
            if reps.len() >= self.cfg.max_replicas {
                continue;
            }
            let Some(bits) = registry.bits(u.region) else {
                continue;
            };
            let bits = bits as u64;
            let covered: Vec<usize> = reps.iter().map(|&d| locality.channel(d)).collect();
            let target = (0..devices)
                .map(DeviceId)
                .filter(|d| !covered.contains(&locality.channel(*d)))
                .filter(|d| bits <= cap.saturating_sub(footprint[d.0]))
                .min_by_key(|d| {
                    (
                        std::cmp::Reverse(cap.saturating_sub(footprint[d.0])),
                        depth(d.0),
                        d.0,
                    )
                });
            let Some(to) = target else {
                continue;
            };
            // amortization, both sides priced by the DDR burst model: a
            // use that cannot land on a replica holder pays the
            // worst-case serialized pull (same-channel read-out +
            // write-in), while the one-time replica stream comes from the
            // cheapest existing copy (usually a cross-channel overlap).
            // The window's traffic must cover the stream
            // `amortize_factor` times over before the copy is worth it.
            let miss_ns = locality.model.device_to_device_ns(bits, true);
            let copy = locality.cheapest_copy(bits, &reps, to);
            if (u.uses as f64) * miss_ns < self.cfg.amortize_factor * copy.ns {
                continue;
            }
            footprint[to.0] += bits;
            replicated.insert(u.region.0);
            actions.push(PlacementAction::Replicate {
                region: u.region,
                to,
            });
        }

        // 2. cold-region migration off overloaded devices
        if cap < u64::MAX {
            let uses_of: HashMap<u64, u64> =
                window.iter().map(|u| (u.region.0, u.uses)).collect();
            for d in 0..devices {
                if (footprint[d] as f64) <= self.cfg.high_watermark * cap as f64 {
                    continue;
                }
                let victim = registry
                    .regions_on(DeviceId(d))
                    .into_iter()
                    .filter(|&(id, _, replica_count)| {
                        replica_count == 1
                            && !replicated.contains(&id.0)
                            && uses_of.get(&id.0).copied().unwrap_or(0) <= self.cfg.cold_uses
                    })
                    .max_by_key(|&(id, bits, _)| (bits, std::cmp::Reverse(id)));
                let Some((region, bits, _)) = victim else {
                    continue;
                };
                let target = (0..devices)
                    .map(DeviceId)
                    .filter(|t| t.0 != d)
                    .filter(|t| bits <= cap.saturating_sub(footprint[t.0]))
                    .min_by_key(|t| (footprint[t.0], depth(t.0), t.0));
                if let Some(to) = target {
                    footprint[d] -= bits;
                    footprint[to.0] += bits;
                    actions.push(PlacementAction::Migrate { region, to });
                }
            }
        }
        actions
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bitrow::BitRow;

    fn payload(bits: usize) -> Payload {
        Payload::Bits(BitRow::zeros(bits))
    }

    fn lru_registry(devices: usize, cap_bits: u64) -> ResidencyRegistry {
        ResidencyRegistry::with_capacity(
            devices,
            CapacityConfig {
                capacity: DeviceCapacity::of_bits(cap_bits),
                policy: EvictionPolicy::Lru,
            },
            CopyCostModel::default(),
        )
    }

    #[test]
    fn register_lookup_migrate_remove() {
        let reg = ResidencyRegistry::new();
        assert!(reg.is_empty());
        let r = reg.register(DeviceId(1), payload(1000));
        assert_eq!(reg.owner(r), Some(DeviceId(1)));
        assert_eq!(reg.replicas(r), Some(vec![DeviceId(1)]));
        assert_eq!(reg.bits(r), Some(1000));
        assert_eq!(reg.len(), 1);
        assert_eq!(reg.resident_bits_on(DeviceId(1)), 1000);
        assert_eq!(reg.resident_bits_on(DeviceId(0)), 0);
        assert!(reg.migrate(r, DeviceId(0)).unwrap());
        assert_eq!(reg.owner(r), Some(DeviceId(0)));
        assert_eq!(reg.resident_bits_on(DeviceId(1)), 0);
        assert_eq!(reg.resident_bits_on(DeviceId(0)), 1000);
        assert!(reg.remove(r).is_some());
        assert_eq!(reg.owner(r), None);
        assert!(!reg.migrate(r, DeviceId(1)).unwrap());
        assert!(reg.remove(r).is_none());
        reg.check_invariants().unwrap();
    }

    #[test]
    fn fleet_bounded_registry_rejects_foreign_devices() {
        let reg = ResidencyRegistry::for_fleet(2);
        let r = reg.register(DeviceId(1), payload(8));
        assert!(reg.migrate(r, DeviceId(0)).unwrap());
        // unbounded registries accept anything (standalone use)
        let free = ResidencyRegistry::new();
        free.register(DeviceId(99), payload(8));
        free.check_invariants().unwrap();
    }

    #[test]
    #[should_panic(expected = "outside the 2-device fleet")]
    fn fleet_bounded_register_panics_out_of_range() {
        ResidencyRegistry::for_fleet(2).register(DeviceId(2), payload(8));
    }

    #[test]
    #[should_panic(expected = "outside the 2-device fleet")]
    fn fleet_bounded_migrate_panics_out_of_range() {
        let reg = ResidencyRegistry::for_fleet(2);
        let r = reg.register(DeviceId(0), payload(8));
        let _ = reg.migrate(r, DeviceId(5));
    }

    #[test]
    fn fail_fast_refuses_beyond_capacity() {
        let reg = ResidencyRegistry::with_capacity(
            2,
            CapacityConfig {
                capacity: DeviceCapacity::of_bits(1000),
                policy: EvictionPolicy::FailFast,
            },
            CopyCostModel::default(),
        );
        let a = reg.try_register(DeviceId(0), payload(600)).unwrap();
        // 600 + 600 > 1000 and fail-fast never evicts
        match reg.try_register(DeviceId(0), payload(600)) {
            Err(CapacityError::DeviceFull {
                device,
                needed_bits,
                capacity_bits,
            }) => {
                assert_eq!(device, DeviceId(0));
                assert_eq!(needed_bits, 600);
                assert_eq!(capacity_bits, 1000);
            }
            other => panic!("expected DeviceFull, got {other:?}"),
        }
        // the other device has its own budget
        reg.try_register(DeviceId(1), payload(600)).unwrap();
        // a region larger than the whole capacity is refused outright
        match reg.try_register(DeviceId(1), payload(2000)) {
            Err(CapacityError::RegionTooLarge { bits, .. }) => assert_eq!(bits, 2000),
            other => panic!("expected RegionTooLarge, got {other:?}"),
        }
        assert_eq!(reg.capacity_refusals(), 2);
        assert_eq!(reg.evictions(), 0);
        assert_eq!(reg.owner(a), Some(DeviceId(0)), "incumbent untouched");
        assert!(reg.resident_bits_on(DeviceId(0)) <= 1000);
        reg.check_invariants().unwrap();
    }

    #[test]
    fn lru_evicts_least_recently_hit_first() {
        let reg = lru_registry(1, 2048);
        let a = reg.register(DeviceId(0), payload(1024));
        let b = reg.register(DeviceId(0), payload(1024));
        // touch `a` so `b` becomes the LRU victim
        let _ = reg
            .resolve(&ClusterRequest::resident(BulkOp::Not, vec![a]))
            .unwrap();
        let c = reg.register(DeviceId(0), payload(1024));
        assert_eq!(reg.owner(a), Some(DeviceId(0)), "recently hit survives");
        assert_eq!(reg.owner(b), None, "LRU region evicted");
        assert_eq!(reg.owner(c), Some(DeviceId(0)));
        assert_eq!(reg.evictions(), 1);
        assert!(reg.resident_bits_on(DeviceId(0)) <= 2048);
        // the evicted handle yields the defined error, not UnknownRegion
        let stale = ClusterRequest::resident(BulkOp::Not, vec![b]);
        assert_eq!(
            reg.placement_of(&stale).unwrap_err(),
            RouteError::Evicted(b)
        );
        assert_eq!(reg.resolve(&stale).unwrap_err(), RouteError::Evicted(b));
        reg.check_invariants().unwrap();
    }

    #[test]
    fn acknowledged_tombstones_compact_and_degrade_to_unknown() {
        let reg = lru_registry(1, 1024);
        let a = reg.register(DeviceId(0), payload(1024));
        let _b = reg.register(DeviceId(0), payload(1024)); // evicts `a`
        assert_eq!(reg.owner(a), None);
        // unacknowledged tombstone: compaction must not touch it, so the
        // first lookup still sees the defined Evicted signal
        assert_eq!(reg.compact_tombstones(), 0);
        assert_eq!(reg.tombstones_compacted(), 0);
        let stale = ClusterRequest::resident(BulkOp::Not, vec![a]);
        assert_eq!(
            reg.placement_of(&stale).unwrap_err(),
            RouteError::Evicted(a)
        );
        // the lookup acknowledged it; now it is reclaimable
        assert_eq!(reg.compact_tombstones(), 1);
        assert_eq!(reg.tombstones_compacted(), 1);
        // post-compaction the stale handle degrades to UnknownRegion —
        // callers treat both as "re-register and resubmit"
        assert_eq!(
            reg.placement_of(&stale).unwrap_err(),
            RouteError::UnknownRegion(a)
        );
        assert_eq!(
            reg.resolve(&stale).unwrap_err(),
            RouteError::UnknownRegion(a)
        );
        reg.check_invariants().unwrap();
    }

    #[test]
    fn resolve_acknowledges_tombstones_too() {
        let reg = lru_registry(1, 1024);
        let a = reg.register(DeviceId(0), payload(1024));
        let _b = reg.register(DeviceId(0), payload(1024));
        let stale = ClusterRequest::resident(BulkOp::Not, vec![a]);
        assert_eq!(reg.resolve(&stale).unwrap_err(), RouteError::Evicted(a));
        assert_eq!(reg.compact_tombstones(), 1, "resolve acked the tombstone");
        assert_eq!(reg.tombstones_compacted(), 1);
        reg.check_invariants().unwrap();
    }

    #[test]
    fn tombstone_set_self_compacts_under_eviction_churn() {
        let reg = lru_registry(1, 1024);
        let mut handles = Vec::new();
        // each registration evicts its predecessor; acknowledging every
        // tombstone keeps the whole backlog reclaimable, so churn well
        // past the threshold must trigger self-compaction
        for i in 0..(2 * TOMBSTONE_COMPACT_THRESHOLD + 8) {
            let h = reg.register(DeviceId(0), payload(1024));
            if let Some(prev) = handles.last() {
                let stale = ClusterRequest::resident(BulkOp::Not, vec![*prev]);
                let err = reg.placement_of(&stale).unwrap_err();
                assert!(
                    matches!(err, RouteError::Evicted(_) | RouteError::UnknownRegion(_)),
                    "churn step {i}: {err:?}"
                );
            }
            handles.push(h);
        }
        assert!(
            reg.tombstones_compacted() > 0,
            "self-compaction never fired under churn"
        );
        reg.check_invariants().unwrap();
    }

    #[test]
    fn cost_aware_refuses_to_thrash_fresh_regions() {
        let reg = ResidencyRegistry::with_capacity(
            2,
            CapacityConfig {
                capacity: DeviceCapacity::of_bits(1024),
                policy: EvictionPolicy::CostAware {
                    rent_ns_per_tick: 2.0,
                },
            },
            CopyCostModel::default(),
        );
        let a = reg.register(DeviceId(0), payload(1024));
        // `a` has accrued no idle time: its re-copy cost (7.5 ns for two
        // bursts) exceeds 0 × rent, so eviction is refused
        assert!(matches!(
            reg.try_register(DeviceId(0), payload(1024)),
            Err(CapacityError::DeviceFull { .. })
        ));
        assert_eq!(reg.owner(a), Some(DeviceId(0)));
        // let the clock advance (registrations elsewhere tick it): after
        // enough idle ticks the rent covers the re-copy stream
        for _ in 0..4 {
            reg.register(DeviceId(1), payload(8));
        }
        let b = reg.try_register(DeviceId(0), payload(1024)).unwrap();
        assert_eq!(reg.owner(a), None, "idle region finally evictable");
        assert_eq!(reg.owner(b), Some(DeviceId(0)));
        reg.check_invariants().unwrap();
    }

    #[test]
    fn queued_regions_are_never_eviction_victims() {
        let reg = lru_registry(1, 2048);
        let a = reg.register(DeviceId(0), payload(1024));
        let b = reg.register(DeviceId(0), payload(1024));
        // resolve pins `a`; resolving and releasing `b` leaves `b` the
        // only unpinned victim even though `a` has the older last-hit
        let (_, pa) = reg
            .resolve(&ClusterRequest::resident(BulkOp::Not, vec![a]))
            .unwrap();
        let (_, pb) = reg
            .resolve(&ClusterRequest::resident(BulkOp::Not, vec![b]))
            .unwrap();
        reg.release_queued(&pb);
        assert_eq!(reg.queued_requests(a), Some(1));
        assert_eq!(reg.queued_requests(b), Some(0));
        let c = reg.register(DeviceId(0), payload(1024));
        assert_eq!(reg.owner(a), Some(DeviceId(0)), "pinned region survives");
        assert_eq!(reg.owner(b), None, "unpinned region evicted instead");
        assert_eq!(reg.owner(c), Some(DeviceId(0)));
        // once the worker releases the pin, `a` is evictable again
        reg.release_queued(&pa);
        assert_eq!(reg.queued_requests(a), Some(0));
        let d = reg.register(DeviceId(0), payload(1024));
        assert_eq!(reg.owner(a), None, "released region evicts normally");
        assert_eq!(reg.owner(d), Some(DeviceId(0)));
        reg.check_invariants().unwrap();
    }

    #[test]
    fn all_victims_queued_fails_fast_instead_of_thrashing() {
        let reg = lru_registry(1, 1024);
        let a = reg.register(DeviceId(0), payload(1024));
        let (_, pa) = reg
            .resolve(&ClusterRequest::resident(BulkOp::Not, vec![a]))
            .unwrap();
        // every byte of capacity is pinned by queued work: the newcomer
        // is refused instead of bouncing the queued request into the
        // Evicted requeue path
        assert!(matches!(
            reg.try_register(DeviceId(0), payload(1024)),
            Err(CapacityError::DeviceFull { .. })
        ));
        assert_eq!(reg.owner(a), Some(DeviceId(0)));
        reg.release_queued(&pa);
        reg.try_register(DeviceId(0), payload(1024)).unwrap();
        assert_eq!(reg.owner(a), None);
        reg.check_invariants().unwrap();
    }

    #[test]
    fn failed_resolve_leaves_no_pins_behind() {
        let reg = lru_registry(2, 4096);
        let a = reg.register(DeviceId(0), payload(512));
        let b = reg.register(DeviceId(1), payload(512));
        assert_eq!(reg.evict_from(b, DeviceId(1)), EvictOutcome::RegionEvicted);
        // `a` resolves first in operand order, then `b` errors: the
        // half-resolved request must not pin `a`
        let req = ClusterRequest::resident(BulkOp::Xnor2, vec![a, b]);
        assert_eq!(reg.resolve(&req).unwrap_err(), RouteError::Evicted(b));
        assert_eq!(reg.queued_requests(a), Some(0));
    }

    #[test]
    fn co_residency_follows_replicas() {
        let mut p = Placement::default();
        // all-inline: co-resident anywhere
        assert!(p.co_resident_on(DeviceId(0)));
        p.add_resident(RegionId(0), 100, vec![DeviceId(1), DeviceId(2)]);
        p.add_resident(RegionId(1), 100, vec![DeviceId(1)]);
        assert!(p.co_resident_on(DeviceId(1)), "replica on every span");
        assert!(!p.co_resident_on(DeviceId(2)), "span 1 misses on dev2");
        assert!(!p.co_resident_on(DeviceId(0)));
    }

    #[test]
    fn replicate_then_migrate_collapses_coherently() {
        let reg = ResidencyRegistry::for_fleet(4);
        let r = reg.register(DeviceId(0), payload(512));
        assert!(reg.replicate(r, DeviceId(2)).unwrap());
        // replicating twice is idempotent
        assert!(reg.replicate(r, DeviceId(2)).unwrap());
        assert_eq!(reg.replicas(r), Some(vec![DeviceId(0), DeviceId(2)]));
        assert_eq!(reg.resident_bits_on(DeviceId(0)), 512);
        assert_eq!(reg.resident_bits_on(DeviceId(2)), 512);
        // migration collapses every replica onto the target
        assert!(reg.migrate(r, DeviceId(3)).unwrap());
        assert_eq!(reg.replicas(r), Some(vec![DeviceId(3)]));
        assert_eq!(reg.resident_bits_on(DeviceId(0)), 0);
        assert_eq!(reg.resident_bits_on(DeviceId(2)), 0);
        assert_eq!(reg.resident_bits_on(DeviceId(3)), 512);
        // unknown regions replicate to Ok(false)
        assert!(!reg.replicate(RegionId(404), DeviceId(0)).unwrap());
        reg.check_invariants().unwrap();
    }

    #[test]
    fn replication_never_evicts_incumbents() {
        let reg = lru_registry(2, 1024);
        let incumbent = reg.register(DeviceId(1), payload(1024));
        let hot = reg.register(DeviceId(0), payload(512));
        // dev1 is full: replication must refuse rather than evict,
        // even under an eviction-capable policy
        match reg.replicate(hot, DeviceId(1)) {
            Err(CapacityError::DeviceFull { device, .. }) => assert_eq!(device, DeviceId(1)),
            other => panic!("expected DeviceFull, got {other:?}"),
        }
        assert_eq!(reg.owner(incumbent), Some(DeviceId(1)), "incumbent survives");
        assert_eq!(reg.replicas(hot), Some(vec![DeviceId(0)]));
        assert_eq!(reg.evictions(), 0);
        assert_eq!(reg.capacity_refusals(), 1);
        // registration (unlike replication) may evict to make room
        let fresh = reg.register(DeviceId(1), payload(1024));
        assert_eq!(reg.owner(incumbent), None);
        assert_eq!(reg.owner(fresh), Some(DeviceId(1)));
        assert_eq!(reg.evictions(), 1);
        reg.check_invariants().unwrap();
    }

    #[test]
    fn evict_from_drops_replicas_then_tombstones() {
        let reg = ResidencyRegistry::for_fleet(3);
        let r = reg.register(DeviceId(0), payload(256));
        assert!(reg.replicate(r, DeviceId(1)).unwrap());
        assert_eq!(reg.evict_from(r, DeviceId(2)), EvictOutcome::NotResident);
        assert_eq!(reg.evict_from(r, DeviceId(0)), EvictOutcome::ReplicaDropped);
        assert_eq!(reg.owner(r), Some(DeviceId(1)), "replica still serves");
        assert_eq!(reg.evict_from(r, DeviceId(1)), EvictOutcome::RegionEvicted);
        assert_eq!(reg.owner(r), None);
        assert_eq!(reg.evict_from(r, DeviceId(1)), EvictOutcome::NotResident);
        assert_eq!(reg.evictions(), 2);
        // tombstoned, not unknown
        let stale = ClusterRequest::resident(BulkOp::Not, vec![r]);
        assert_eq!(reg.resolve(&stale).unwrap_err(), RouteError::Evicted(r));
        // an owner-initiated remove is NOT an eviction
        let q = reg.register(DeviceId(0), payload(256));
        reg.remove(q);
        let gone = ClusterRequest::resident(BulkOp::Not, vec![q]);
        assert_eq!(
            reg.resolve(&gone).unwrap_err(),
            RouteError::UnknownRegion(q)
        );
        reg.check_invariants().unwrap();
    }

    #[test]
    fn placement_of_matches_resolve_without_cloning() {
        let reg = ResidencyRegistry::new();
        let ra = reg.register(DeviceId(1), payload(2048));
        let req = ClusterRequest::new(
            BulkOp::Xnor2,
            vec![
                OperandRef::Resident(ra),
                OperandRef::Inline(payload(2048)),
            ],
        );
        let cheap = reg.placement_of(&req).unwrap();
        let (_, full) = reg.resolve(&req).unwrap();
        assert_eq!(cheap.resident, full.resident);
        assert_eq!(cheap.inline_bits, full.inline_bits);
        assert_eq!(cheap.preferred(), full.preferred());
        let bogus = ClusterRequest::resident(BulkOp::Not, vec![RegionId(404)]);
        assert_eq!(
            reg.placement_of(&bogus).unwrap_err(),
            RouteError::UnknownRegion(RegionId(404))
        );
    }

    #[test]
    fn region_handles_are_never_reused() {
        let reg = ResidencyRegistry::new();
        let a = reg.register(DeviceId(0), payload(8));
        reg.remove(a);
        let b = reg.register(DeviceId(0), payload(8));
        assert_ne!(a, b);
    }

    #[test]
    fn resolve_materializes_and_summarizes() {
        let reg = ResidencyRegistry::new();
        let ra = reg.register(DeviceId(1), payload(2048));
        let req = ClusterRequest::new(
            BulkOp::Xnor2,
            vec![
                OperandRef::Resident(ra),
                OperandRef::Inline(payload(2048)),
            ],
        );
        let (bulk, place) = reg.resolve(&req).unwrap();
        assert_eq!(bulk.operands.len(), 2);
        assert_eq!(bulk.payload_bits(), 2048);
        assert_eq!(place.inline_bits, 2048);
        assert_eq!(
            place.resident_bits_per_device(),
            vec![(DeviceId(1), 2048)]
        );
        assert_eq!(place.preferred(), Some(DeviceId(1)));
        assert_eq!(place.total_resident_bits(), 2048);
        // resolve counted the routed use
        assert_eq!(reg.hit_stats(ra).unwrap().0, 1);
    }

    #[test]
    fn resolve_unknown_region_is_an_error() {
        let reg = ResidencyRegistry::new();
        let req = ClusterRequest::resident(BulkOp::Not, vec![RegionId(77)]);
        assert_eq!(
            reg.resolve(&req).unwrap_err(),
            RouteError::UnknownRegion(RegionId(77))
        );
    }

    #[test]
    #[should_panic(expected = "operand sizes disagree")]
    fn resolve_rejects_mismatched_sizes() {
        let reg = ResidencyRegistry::new();
        let ra = reg.register(DeviceId(0), payload(100));
        let rb = reg.register(DeviceId(0), payload(200));
        let req = ClusterRequest::resident(BulkOp::Xnor2, vec![ra, rb]);
        let _ = reg.resolve(&req);
    }

    #[test]
    #[should_panic]
    fn cluster_request_checks_arity() {
        ClusterRequest::resident(BulkOp::Xnor2, vec![RegionId(0)]);
    }

    #[test]
    fn placement_prefers_biggest_owner_and_spreads_over_replicas() {
        let mut p = Placement::default();
        assert_eq!(p.preferred(), None);
        assert!(p.candidates().is_empty());
        p.add_resident(RegionId(0), 100, vec![DeviceId(2)]);
        p.add_resident(RegionId(1), 300, vec![DeviceId(0)]);
        p.add_resident(RegionId(2), 100, vec![DeviceId(2)]);
        assert_eq!(p.preferred(), Some(DeviceId(0)));
        p.add_resident(RegionId(3), 100, vec![DeviceId(2)]);
        // tie at 300 → both are candidates, lowest id preferred
        assert_eq!(p.candidates(), vec![DeviceId(0), DeviceId(2)]);
        assert_eq!(p.preferred(), Some(DeviceId(0)));
        assert_eq!(p.total_resident_bits(), 600);
        // a replicated operand counts toward every holder
        let mut q = Placement::default();
        q.add_resident(RegionId(9), 512, vec![DeviceId(1), DeviceId(3)]);
        assert_eq!(q.candidates(), vec![DeviceId(1), DeviceId(3)]);
        assert_eq!(q.total_resident_bits(), 512);
    }

    #[test]
    fn copy_cost_calibration() {
        let m = CopyCostModel::default();
        // 2048 bits = 4 bursts = 15 ns host→device, 16 clocks
        assert!((m.host_to_device_ns(2048) - 15.0).abs() < 1e-9);
        assert_eq!(m.cycles_for(15.0), 16);
        // same channel serializes read-out + write-in
        assert!((m.device_to_device_ns(2048, true) - 30.0).abs() < 1e-9);
        // cross-channel overlaps
        assert!((m.device_to_device_ns(2048, false) - 15.0).abs() < 1e-9);
    }

    #[test]
    fn landing_charges_follow_the_tier_model() {
        let m = CopyCostModel::default();
        // external landing: a full staging→pin round trip over the bus
        let ext = m.external_landing(2048);
        assert_eq!(ext.bytes, 256);
        assert!((ext.ns - 30.0).abs() < 1e-9);
        assert_eq!(ext.cycles, 32);
        // in-DRAM landing never occupies the bus, whatever the tier
        for tier in [
            MovementTier::SameSubarray,
            MovementTier::SameBank,
            MovementTier::SameDevice,
        ] {
            let c = m.in_dram_landing(2048, tier, 1024);
            assert_eq!(c.bytes, 256, "{tier:?}");
            assert_eq!(c.cycles, 0, "{tier:?}");
            assert!(c.ns > 0.0, "{tier:?}");
        }
        // FPM calibration: 2 rows at 1024 bits/row = 2 AAPs = 180 ns
        let fpm = m.in_dram_landing(2048, MovementTier::SameSubarray, 1024);
        assert!((fpm.ns - 180.0).abs() < 1e-9);
    }

    #[test]
    fn pins_are_unique_and_recycled_across_the_lifecycle() {
        let reg = ResidencyRegistry::for_fleet(2)
            .with_geometry(crate::dram::geometry::DramGeometry::tiny());
        let a = reg.register(DeviceId(0), payload(64));
        let b = reg.register(DeviceId(0), payload(64));
        let pa = reg.pin_of(a, DeviceId(0)).unwrap();
        let pb = reg.pin_of(b, DeviceId(0)).unwrap();
        assert_ne!(pa, pb, "two live regions share a pinned row");
        assert_eq!(reg.pin_of(a, DeviceId(1)), None);
        // the first slot on a device is the staging sub-array itself
        assert_eq!(pa.landing_tier(), MovementTier::SameSubarray);
        // tiny geometry has 2 banks: the second slot lands in bank 1
        assert_eq!(pb.landing_tier(), MovementTier::SameDevice);

        // replication pins on the new device; migration re-pins
        assert!(reg.replicate(a, DeviceId(1)).unwrap());
        let p1 = reg.pin_of(a, DeviceId(1)).unwrap();
        assert!(reg.migrate(b, DeviceId(1)).unwrap());
        assert_ne!(reg.pin_of(b, DeviceId(1)).unwrap(), p1);
        assert_eq!(reg.pin_of(b, DeviceId(0)), None);
        reg.check_invariants().unwrap();

        // a freed slot is recycled by the next allocation on that device
        assert!(reg.remove(a).is_some());
        let c = reg.register(DeviceId(0), payload(64));
        assert_eq!(reg.pin_of(c, DeviceId(0)).unwrap(), pa);
        assert_eq!(reg.pins_on(DeviceId(0)), vec![(c, pa)]);
        reg.check_invariants().unwrap();
    }

    #[test]
    fn locality_charge_hits_and_misses() {
        let topo = Topology::tiny(4); // two ranks per channel
        let loc = LocalityModel::from_topology(&topo, TimingParams::default());
        assert_eq!(loc.devices(), 4);
        assert!(loc.same_channel(DeviceId(0), DeviceId(1)));
        assert!(!loc.same_channel(DeviceId(1), DeviceId(2)));
        assert_eq!(loc.channel(DeviceId(3)), 1);

        let mut p = Placement::default();
        p.add_resident(RegionId(0), 2048, vec![DeviceId(0)]);
        // executing on the owner: free
        let hit = loc.charge(&p, DeviceId(0));
        assert!(hit.is_free());
        assert_eq!(hit.cycles, 0);
        assert_eq!(hit.ns, 0.0);
        // executing on the same-channel neighbour: serialized transfer
        let near = loc.charge(&p, DeviceId(1));
        assert_eq!(near.bytes, 256);
        assert!((near.ns - 30.0).abs() < 1e-9);
        assert_eq!(near.cycles, 32);
        // executing across channels: overlapped transfer
        let far = loc.charge(&p, DeviceId(2));
        assert_eq!(far.bytes, 256);
        assert!((far.ns - 15.0).abs() < 1e-9);
        assert_eq!(far.cycles, 16);

        // inline bits are charged wherever the request runs
        p.inline_bits = 2048;
        let mixed = loc.charge(&p, DeviceId(0));
        assert_eq!(mixed.bytes, 256);
        assert!((mixed.ns - 15.0).abs() < 1e-9);
    }

    #[test]
    fn replicas_make_misses_cheaper_and_hits_wider() {
        let topo = Topology::tiny(4);
        let loc = LocalityModel::from_topology(&topo, TimingParams::default());
        let mut p = Placement::default();
        // replicated on both channels: dev0 (channel 0) and dev2 (channel 1)
        p.add_resident(RegionId(0), 2048, vec![DeviceId(0), DeviceId(2)]);
        // both replica holders are free
        assert!(loc.charge(&p, DeviceId(0)).is_free());
        assert!(loc.charge(&p, DeviceId(2)).is_free());
        // dev1 shares channel 0 with dev0 (30 ns serialized) but can pull
        // from dev2 across channels for 15 ns — the cheapest replica wins
        let c = loc.charge(&p, DeviceId(1));
        assert!((c.ns - 15.0).abs() < 1e-9);
        // replication/migration streams price the same way
        let rep = loc.cheapest_copy(2048, &[DeviceId(0)], DeviceId(2));
        assert!((rep.ns - 15.0).abs() < 1e-9);
        assert_eq!(rep.bytes, 256);
        // already resident → free; no sources → host stream
        assert!(loc
            .cheapest_copy(2048, &[DeviceId(0)], DeviceId(0))
            .is_free());
        let host = loc.cheapest_copy(2048, &[], DeviceId(1));
        assert!((host.ns - 15.0).abs() < 1e-9);
    }

    #[test]
    fn replication_policy_replicates_hot_and_migrates_cold() {
        let topo = Topology::tiny(4);
        let loc = LocalityModel::from_topology(&topo, TimingParams::default());
        let reg = lru_registry(4, 4096);
        let hot = reg.register(DeviceId(0), payload(1024));
        let cold = reg.register(DeviceId(0), payload(3000));
        let policy = ReplicationPolicy::new(ReplicationConfig {
            hot_uses: 3,
            amortize_factor: 1.0,
            max_replicas: 2,
            cold_uses: 0,
            high_watermark: 0.9,
        });
        let window = [RegionUse {
            region: hot,
            uses: 5,
            misses: 2,
        }];
        let actions = policy.plan(&window, &reg, &loc, &[0, 0, 0, 0]);
        // dev0 sits at 4024/4096 > 0.9 → sheds its cold region; the hot
        // one gains a replica on channel 1
        assert!(actions.iter().any(|a| matches!(
            a,
            PlacementAction::Replicate { region, to }
                if *region == hot && (to.0 == 2 || to.0 == 3)
        )));
        assert!(actions.iter().any(|a| matches!(
            a,
            PlacementAction::Migrate { region, .. } if *region == cold
        )));
        // below the hot threshold nothing replicates
        let quiet = [RegionUse {
            region: hot,
            uses: 1,
            misses: 0,
        }];
        reg.remove(cold);
        let none = policy.plan(&quiet, &reg, &loc, &[0, 0, 0, 0]);
        assert!(none.is_empty(), "{none:?}");
    }

    #[test]
    fn route_error_messages() {
        let e = RouteError::UnknownRegion(RegionId(9));
        assert!(e.to_string().contains("region9"), "{e}");
        let ev = RouteError::Evicted(RegionId(4));
        assert!(ev.to_string().contains("evicted"), "{ev}");
        let a: RouteError = AdmissionError::Overloaded {
            devices: 2,
            max_inflight_per_device: 1,
        }
        .into();
        assert!(a.to_string().contains("overloaded"), "{a}");
        let c = CapacityError::DeviceFull {
            device: DeviceId(1),
            needed_bits: 64,
            capacity_bits: 32,
        };
        assert!(c.to_string().contains("dev1"), "{c}");
        let big = CapacityError::RegionTooLarge {
            device: DeviceId(0),
            bits: 128,
            capacity_bits: 64,
        };
        assert!(big.to_string().contains("outright"), "{big}");
    }
}
