//! Operand residency: which device owns which operand region, and what it
//! costs to move operands that are not where the computation runs.
//!
//! DRIM computes X(N)OR between operands stored *in the same bit-line*, so
//! which device holds an operand is not a scheduling detail — it is the
//! premise of the whole platform (cf. RowClone/Ambit in-DRAM copy,
//! SIMDRAM's allocation-aware framework). PR 1's fleet routed requests
//! that *carry* their payloads, letting any device serve any request; this
//! module models the data instead:
//!
//! * [`ResidencyRegistry`] maps [`RegionId`] handles to the
//!   [`DeviceId`] that owns them (and holds the simulated payload so
//!   routed requests can be materialized for execution).
//! * [`ClusterRequest`] lets each operand be either carried
//!   ([`OperandRef::Inline`]) or referenced by resident handle
//!   ([`OperandRef::Resident`]).
//! * [`CopyCostModel`] prices the movement of operands that are *not*
//!   resident on the executing device, from the DDR burst/channel timing
//!   parameters (`dram::timing`): a host-carried operand is one streamed
//!   transfer into the device; an operand resident on another device is a
//!   read-out plus write-in, which serializes (2×) when source and
//!   destination share a channel and overlaps when they do not.
//! * [`LocalityModel`] binds the cost model to a concrete fleet topology
//!   and computes the [`CopyCharge`] of executing a placed request on a
//!   given device. The charge is computed against the device that
//!   *actually executes* (fleet workers call it with their own id), so
//!   the accounting stays correct under work stealing.
//!
//! A request whose operands are all resident on the executing device is a
//! *resident hit*: zero copied bytes, zero copy cycles. Everything else is
//! a miss and is charged; the fleet metrics surface copied bytes and copy
//! cycles alongside the makespan so the `ablate_locality` bench and the
//! `drim cluster --locality` sweep can ablate placement policies.

use std::collections::HashMap;
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::RwLock;

use crate::coordinator::{BulkRequest, Payload};
use crate::dram::timing::TimingParams;
use crate::isa::program::BulkOp;

use super::admission::AdmissionError;
use super::topology::{DeviceId, Topology};

/// Handle to a registered operand region (dense, fleet-wide, never reused).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug, PartialOrd, Ord)]
pub struct RegionId(pub u64);

impl fmt::Display for RegionId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "region{}", self.0)
    }
}

/// One operand of a [`ClusterRequest`].
#[derive(Clone, Debug)]
pub enum OperandRef {
    /// Payload carried with the request — charged as a host→device
    /// streamed transfer no matter where it executes.
    Inline(Payload),
    /// Operand resident on some device — free when the request executes
    /// there, charged as an inter-device copy otherwise.
    Resident(RegionId),
}

/// A fleet-level request whose operands may be resident handles instead of
/// carried payloads. The placement-aware submission paths
/// (`DrimCluster::try_submit_routed` and friends) accept this type; the
/// legacy payload-carrying paths keep accepting plain [`BulkRequest`]s.
#[derive(Clone, Debug)]
pub struct ClusterRequest {
    pub op: BulkOp,
    pub operands: Vec<OperandRef>,
}

impl ClusterRequest {
    /// Build a request, checking operand count against the op's arity.
    pub fn new(op: BulkOp, operands: Vec<OperandRef>) -> Self {
        assert_eq!(operands.len(), op.arity(), "{}", op.name());
        ClusterRequest { op, operands }
    }

    /// All-inline request: the payload-carrying baseline, now with its
    /// host→device transfer made explicit in the copy accounting.
    pub fn carried(req: BulkRequest) -> Self {
        ClusterRequest {
            op: req.op,
            operands: req.operands.into_iter().map(OperandRef::Inline).collect(),
        }
    }

    /// All-resident request: every operand referenced by handle.
    pub fn resident(op: BulkOp, regions: Vec<RegionId>) -> Self {
        Self::new(op, regions.into_iter().map(OperandRef::Resident).collect())
    }
}

/// Why a routed submission was refused.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum RouteError {
    /// A resident handle references a region the registry does not know
    /// (never registered, or dropped).
    UnknownRegion(RegionId),
    /// Admission control refused the request (fleet or device saturated).
    Admission(AdmissionError),
}

impl fmt::Display for RouteError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            RouteError::UnknownRegion(r) => {
                write!(f, "unknown operand {r}: not in the residency registry")
            }
            RouteError::Admission(e) => write!(f, "{e}"),
        }
    }
}

impl From<AdmissionError> for RouteError {
    fn from(e: AdmissionError) -> Self {
        RouteError::Admission(e)
    }
}

/// Where a routed request's operand bits live, summarized for the worker
/// that will execute it. Resident bits are grouped per owning device (one
/// streamed transfer per source device); inline bits are the payloads the
/// request carried from the host.
#[derive(Clone, Debug, Default)]
pub struct Placement {
    /// total resident operand bits per owning device
    pub resident_bits: Vec<(DeviceId, u64)>,
    /// operand bits carried inline with the request
    pub inline_bits: u64,
}

impl Placement {
    /// Accumulate `bits` of residency on `device`.
    pub fn add_resident(&mut self, device: DeviceId, bits: u64) {
        if let Some(e) = self.resident_bits.iter_mut().find(|(d, _)| *d == device) {
            e.1 += bits;
        } else {
            self.resident_bits.push((device, bits));
        }
    }

    /// The device owning the most resident operand bits (ties broken
    /// toward the lowest id), if any operand is resident at all. This is
    /// the placement the router prefers: executing there moves the fewest
    /// bytes.
    pub fn preferred(&self) -> Option<DeviceId> {
        self.resident_bits
            .iter()
            .max_by(|a, b| a.1.cmp(&b.1).then(b.0.cmp(&a.0)))
            .map(|&(d, _)| d)
    }

    /// Total resident operand bits across all owning devices.
    pub fn total_resident_bits(&self) -> u64 {
        self.resident_bits.iter().map(|&(_, b)| b).sum()
    }
}

struct Region {
    device: DeviceId,
    payload: Payload,
}

/// Registry mapping operand regions to the devices that own them.
///
/// In the simulator the registry also holds the payload itself, so a
/// routed request can be materialized into an executable [`BulkRequest`]
/// wherever it lands; on real hardware the payload would be the row range
/// and only the coordinates would live here.
#[derive(Default)]
pub struct ResidencyRegistry {
    inner: RwLock<HashMap<u64, Region>>,
    next: AtomicU64,
    /// devices this registry may reference (`None` = standalone/unbounded)
    bound: Option<usize>,
}

impl ResidencyRegistry {
    /// Unbounded registry (standalone use; fleet-owned registries are
    /// created with [`Self::for_fleet`] so a bad `DeviceId` fails at
    /// registration time, not deep inside routing).
    pub fn new() -> Self {
        ResidencyRegistry::default()
    }

    /// Registry whose regions may only reference devices `0..devices`.
    pub fn for_fleet(devices: usize) -> Self {
        ResidencyRegistry {
            bound: Some(devices),
            ..ResidencyRegistry::default()
        }
    }

    fn check(&self, device: DeviceId) {
        if let Some(n) = self.bound {
            assert!(device.0 < n, "{device} outside the {n}-device fleet");
        }
    }

    /// Register a payload as resident on `device`; returns its handle.
    /// Panics if `device` is outside a fleet-bounded registry's range.
    pub fn register(&self, device: DeviceId, payload: Payload) -> RegionId {
        self.check(device);
        let id = self.next.fetch_add(1, Ordering::Relaxed);
        self.inner
            .write()
            .unwrap()
            .insert(id, Region { device, payload });
        RegionId(id)
    }

    /// Owning device of a region, if registered.
    pub fn owner(&self, region: RegionId) -> Option<DeviceId> {
        self.inner.read().unwrap().get(&region.0).map(|r| r.device)
    }

    /// Payload size of a region in bits, if registered.
    pub fn bits(&self, region: RegionId) -> Option<usize> {
        self.inner
            .read()
            .unwrap()
            .get(&region.0)
            .map(|r| r.payload.bits())
    }

    /// Owner and a copy of the payload, if registered.
    pub fn lookup(&self, region: RegionId) -> Option<(DeviceId, Payload)> {
        self.inner
            .read()
            .unwrap()
            .get(&region.0)
            .map(|r| (r.device, r.payload.clone()))
    }

    /// Re-home a region onto another device (an explicit migration —
    /// future requests routed by this handle will prefer `to`). Returns
    /// false if the region is unknown; panics if `to` is outside a
    /// fleet-bounded registry's range.
    pub fn migrate(&self, region: RegionId, to: DeviceId) -> bool {
        self.check(to);
        match self.inner.write().unwrap().get_mut(&region.0) {
            Some(r) => {
                r.device = to;
                true
            }
            None => false,
        }
    }

    /// Drop a region; returns its payload if it was registered.
    pub fn remove(&self, region: RegionId) -> Option<Payload> {
        self.inner
            .write()
            .unwrap()
            .remove(&region.0)
            .map(|r| r.payload)
    }

    /// Number of registered regions.
    pub fn len(&self) -> usize {
        self.inner.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Total bits resident on one device (capacity/balance reporting).
    pub fn resident_bits_on(&self, device: DeviceId) -> u64 {
        self.inner
            .read()
            .unwrap()
            .values()
            .filter(|r| r.device == device)
            .map(|r| r.payload.bits() as u64)
            .sum()
    }

    /// Summarize where a request's operand bits live *without* cloning any
    /// payload — the cheap path for routing decisions ([`Placement`] only;
    /// use [`Self::resolve`] when the request is actually submitted).
    pub fn placement_of(&self, req: &ClusterRequest) -> Result<Placement, RouteError> {
        let mut placement = Placement::default();
        let inner = self.inner.read().unwrap();
        for o in &req.operands {
            match o {
                OperandRef::Inline(p) => placement.inline_bits += p.bits() as u64,
                OperandRef::Resident(r) => {
                    let region =
                        inner.get(&r.0).ok_or(RouteError::UnknownRegion(*r))?;
                    placement.add_resident(region.device, region.payload.bits() as u64);
                }
            }
        }
        Ok(placement)
    }

    /// Materialize a [`ClusterRequest`] into an executable [`BulkRequest`]
    /// plus the [`Placement`] summary the copy accounting charges from.
    ///
    /// Panics if materialized operands disagree in bit length (the same
    /// contract `BulkRequest::bitwise` enforces for carried payloads).
    pub fn resolve(
        &self,
        req: &ClusterRequest,
    ) -> Result<(BulkRequest, Placement), RouteError> {
        let mut operands = Vec::with_capacity(req.operands.len());
        let mut placement = Placement::default();
        for o in &req.operands {
            match o {
                OperandRef::Inline(p) => {
                    placement.inline_bits += p.bits() as u64;
                    operands.push(p.clone());
                }
                OperandRef::Resident(r) => {
                    let (device, payload) =
                        self.lookup(*r).ok_or(RouteError::UnknownRegion(*r))?;
                    placement.add_resident(device, payload.bits() as u64);
                    operands.push(payload);
                }
            }
        }
        if let Some(first) = operands.first() {
            let bits = first.bits();
            assert!(
                operands.iter().all(|o| o.bits() == bits),
                "{}: operand sizes disagree",
                req.op.name()
            );
        }
        Ok((
            BulkRequest {
                op: req.op,
                operands,
            },
            placement,
        ))
    }
}

/// Inter-device copy-cost model derived from the DDR timing parameters.
///
/// All transfers are streamed in [`crate::dram::timing::BURST_BITS`]-bit
/// bursts at `t_burst_ns` each; cycle counts use the command-clock period
/// `t_ck_ns` (one burst = 4 clocks at DDR4-2133).
#[derive(Clone, Debug)]
pub struct CopyCostModel {
    pub timing: TimingParams,
}

impl CopyCostModel {
    pub fn new(timing: TimingParams) -> Self {
        CopyCostModel { timing }
    }

    /// Nanoseconds to bring `bits` from the host into a device: one
    /// streamed pass over the destination channel.
    pub fn host_to_device_ns(&self, bits: u64) -> f64 {
        self.timing.stream_ns(bits)
    }

    /// Nanoseconds to move `bits` between two devices. When source and
    /// destination share a DDR channel the read-out and write-in serialize
    /// on the shared data bus (2× the stream time); across channels the
    /// two directions overlap and the stream time is paid once.
    pub fn device_to_device_ns(&self, bits: u64, same_channel: bool) -> f64 {
        let one = self.timing.stream_ns(bits);
        if same_channel {
            2.0 * one
        } else {
            one
        }
    }

    /// Bus clock cycles corresponding to `ns` of copy time.
    pub fn cycles_for(&self, ns: f64) -> u64 {
        self.timing.cycles_for_ns(ns)
    }
}

impl Default for CopyCostModel {
    fn default() -> Self {
        CopyCostModel::new(TimingParams::default())
    }
}

/// What executing a placed request on a particular device costs in operand
/// movement. `bytes == 0` means a resident hit.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CopyCharge {
    /// operand bytes that had to move (host→device or device→device)
    pub bytes: u64,
    /// simulated copy time added to the executing device
    pub ns: f64,
    /// DDR bus clock cycles the movement occupied
    pub cycles: u64,
}

impl CopyCharge {
    /// True when no operand had to move — the resident-hit case.
    pub fn is_free(&self) -> bool {
        self.bytes == 0
    }
}

/// The copy-cost model bound to a concrete fleet topology: knows which
/// devices share a channel and turns a [`Placement`] plus an executing
/// device into a [`CopyCharge`].
pub struct LocalityModel {
    channel_of: Vec<usize>,
    pub model: CopyCostModel,
}

impl LocalityModel {
    /// Bind `timing`-derived costs to the channel coordinates of `t`.
    pub fn from_topology(t: &Topology, timing: TimingParams) -> Self {
        LocalityModel {
            channel_of: (0..t.len()).map(|i| t.channel_of(DeviceId(i))).collect(),
            model: CopyCostModel::new(timing),
        }
    }

    /// Do two devices sit on the same DDR channel?
    pub fn same_channel(&self, a: DeviceId, b: DeviceId) -> bool {
        self.channel_of[a.0] == self.channel_of[b.0]
    }

    /// Charge for executing a request with placement `p` on `executor`:
    /// resident bits already on `executor` are free; resident bits on
    /// other devices pay the device→device stream (per source device);
    /// inline bits pay the host→device stream.
    pub fn charge(&self, p: &Placement, executor: DeviceId) -> CopyCharge {
        let mut ns = 0.0;
        let mut bytes = 0u64;
        for &(device, bits) in &p.resident_bits {
            if device != executor && bits > 0 {
                ns += self
                    .model
                    .device_to_device_ns(bits, self.same_channel(device, executor));
                bytes += bits.div_ceil(8);
            }
        }
        if p.inline_bits > 0 {
            ns += self.model.host_to_device_ns(p.inline_bits);
            bytes += p.inline_bits.div_ceil(8);
        }
        CopyCharge {
            bytes,
            ns,
            cycles: self.model.cycles_for(ns),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bitrow::BitRow;

    fn payload(bits: usize) -> Payload {
        Payload::Bits(BitRow::zeros(bits))
    }

    #[test]
    fn register_lookup_migrate_remove() {
        let reg = ResidencyRegistry::new();
        assert!(reg.is_empty());
        let r = reg.register(DeviceId(1), payload(1000));
        assert_eq!(reg.owner(r), Some(DeviceId(1)));
        assert_eq!(reg.bits(r), Some(1000));
        assert_eq!(reg.len(), 1);
        assert_eq!(reg.resident_bits_on(DeviceId(1)), 1000);
        assert_eq!(reg.resident_bits_on(DeviceId(0)), 0);
        assert!(reg.migrate(r, DeviceId(0)));
        assert_eq!(reg.owner(r), Some(DeviceId(0)));
        assert!(reg.remove(r).is_some());
        assert_eq!(reg.owner(r), None);
        assert!(!reg.migrate(r, DeviceId(1)));
        assert!(reg.remove(r).is_none());
    }

    #[test]
    fn fleet_bounded_registry_rejects_foreign_devices() {
        let reg = ResidencyRegistry::for_fleet(2);
        let r = reg.register(DeviceId(1), payload(8));
        assert!(reg.migrate(r, DeviceId(0)));
        // unbounded registries accept anything (standalone use)
        let free = ResidencyRegistry::new();
        free.register(DeviceId(99), payload(8));
    }

    #[test]
    #[should_panic(expected = "outside the 2-device fleet")]
    fn fleet_bounded_register_panics_out_of_range() {
        ResidencyRegistry::for_fleet(2).register(DeviceId(2), payload(8));
    }

    #[test]
    #[should_panic(expected = "outside the 2-device fleet")]
    fn fleet_bounded_migrate_panics_out_of_range() {
        let reg = ResidencyRegistry::for_fleet(2);
        let r = reg.register(DeviceId(0), payload(8));
        reg.migrate(r, DeviceId(5));
    }

    #[test]
    fn placement_of_matches_resolve_without_cloning() {
        let reg = ResidencyRegistry::new();
        let ra = reg.register(DeviceId(1), payload(2048));
        let req = ClusterRequest::new(
            BulkOp::Xnor2,
            vec![
                OperandRef::Resident(ra),
                OperandRef::Inline(payload(2048)),
            ],
        );
        let cheap = reg.placement_of(&req).unwrap();
        let (_, full) = reg.resolve(&req).unwrap();
        assert_eq!(cheap.resident_bits, full.resident_bits);
        assert_eq!(cheap.inline_bits, full.inline_bits);
        assert_eq!(cheap.preferred(), full.preferred());
        let bogus = ClusterRequest::resident(BulkOp::Not, vec![RegionId(404)]);
        assert_eq!(
            reg.placement_of(&bogus).unwrap_err(),
            RouteError::UnknownRegion(RegionId(404))
        );
    }

    #[test]
    fn region_handles_are_never_reused() {
        let reg = ResidencyRegistry::new();
        let a = reg.register(DeviceId(0), payload(8));
        reg.remove(a);
        let b = reg.register(DeviceId(0), payload(8));
        assert_ne!(a, b);
    }

    #[test]
    fn resolve_materializes_and_summarizes() {
        let reg = ResidencyRegistry::new();
        let ra = reg.register(DeviceId(1), payload(2048));
        let req = ClusterRequest::new(
            BulkOp::Xnor2,
            vec![
                OperandRef::Resident(ra),
                OperandRef::Inline(payload(2048)),
            ],
        );
        let (bulk, place) = reg.resolve(&req).unwrap();
        assert_eq!(bulk.operands.len(), 2);
        assert_eq!(bulk.payload_bits(), 2048);
        assert_eq!(place.inline_bits, 2048);
        assert_eq!(place.resident_bits, vec![(DeviceId(1), 2048)]);
        assert_eq!(place.preferred(), Some(DeviceId(1)));
        assert_eq!(place.total_resident_bits(), 2048);
    }

    #[test]
    fn resolve_unknown_region_is_an_error() {
        let reg = ResidencyRegistry::new();
        let req = ClusterRequest::resident(BulkOp::Not, vec![RegionId(77)]);
        assert_eq!(
            reg.resolve(&req).unwrap_err(),
            RouteError::UnknownRegion(RegionId(77))
        );
    }

    #[test]
    #[should_panic(expected = "operand sizes disagree")]
    fn resolve_rejects_mismatched_sizes() {
        let reg = ResidencyRegistry::new();
        let ra = reg.register(DeviceId(0), payload(100));
        let rb = reg.register(DeviceId(0), payload(200));
        let req = ClusterRequest::resident(BulkOp::Xnor2, vec![ra, rb]);
        let _ = reg.resolve(&req);
    }

    #[test]
    #[should_panic]
    fn cluster_request_checks_arity() {
        ClusterRequest::resident(BulkOp::Xnor2, vec![RegionId(0)]);
    }

    #[test]
    fn preferred_picks_biggest_owner_lowest_id_on_tie() {
        let mut p = Placement::default();
        assert_eq!(p.preferred(), None);
        p.add_resident(DeviceId(2), 100);
        p.add_resident(DeviceId(0), 300);
        p.add_resident(DeviceId(2), 100); // merges: dev2 now 200
        assert_eq!(p.resident_bits.len(), 2);
        assert_eq!(p.preferred(), Some(DeviceId(0)));
        p.add_resident(DeviceId(2), 100); // tie at 300 → lowest id wins
        assert_eq!(p.preferred(), Some(DeviceId(0)));
    }

    #[test]
    fn copy_cost_calibration() {
        let m = CopyCostModel::default();
        // 2048 bits = 4 bursts = 15 ns host→device, 16 clocks
        assert!((m.host_to_device_ns(2048) - 15.0).abs() < 1e-9);
        assert_eq!(m.cycles_for(15.0), 16);
        // same channel serializes read-out + write-in
        assert!((m.device_to_device_ns(2048, true) - 30.0).abs() < 1e-9);
        // cross-channel overlaps
        assert!((m.device_to_device_ns(2048, false) - 15.0).abs() < 1e-9);
    }

    #[test]
    fn locality_charge_hits_and_misses() {
        let topo = Topology::tiny(4); // two ranks per channel
        let loc = LocalityModel::from_topology(&topo, TimingParams::default());
        assert!(loc.same_channel(DeviceId(0), DeviceId(1)));
        assert!(!loc.same_channel(DeviceId(1), DeviceId(2)));

        let mut p = Placement::default();
        p.add_resident(DeviceId(0), 2048);
        // executing on the owner: free
        let hit = loc.charge(&p, DeviceId(0));
        assert!(hit.is_free());
        assert_eq!(hit.cycles, 0);
        assert_eq!(hit.ns, 0.0);
        // executing on the same-channel neighbour: serialized transfer
        let near = loc.charge(&p, DeviceId(1));
        assert_eq!(near.bytes, 256);
        assert!((near.ns - 30.0).abs() < 1e-9);
        assert_eq!(near.cycles, 32);
        // executing across channels: overlapped transfer
        let far = loc.charge(&p, DeviceId(2));
        assert_eq!(far.bytes, 256);
        assert!((far.ns - 15.0).abs() < 1e-9);
        assert_eq!(far.cycles, 16);

        // inline bits are charged wherever the request runs
        p.inline_bits = 2048;
        let mixed = loc.charge(&p, DeviceId(0));
        assert_eq!(mixed.bytes, 256);
        assert!((mixed.ns - 15.0).abs() < 1e-9);
    }

    #[test]
    fn route_error_messages() {
        let e = RouteError::UnknownRegion(RegionId(9));
        assert!(e.to_string().contains("region9"), "{e}");
        let a: RouteError = AdmissionError::Overloaded {
            devices: 2,
            max_inflight_per_device: 1,
        }
        .into();
        assert!(a.to_string().contains("overloaded"), "{a}");
    }
}
