//! Fleet observability: structured tracing, latency histograms, JSON export.
//!
//! This module is the instrumentation spine of the simulator. It owns
//! three building blocks, each usable on its own:
//!
//! - [`hist::Histogram`] — mergeable log-bucketed latency histograms
//!   (p50/p95/p99 with ≤12.5% relative error). These back the
//!   per-device sim-latency and queue-sojourn distributions in
//!   [`crate::coordinator::MetricsSnapshot`] and
//!   [`crate::cluster::FleetSnapshot`].
//! - [`trace::Tracer`] — a lock-cheap, runtime-sampled, compile-out-able
//!   (cargo feature `trace`, on by default) event recorder with one ring
//!   buffer per device plus a frontend lane. [`trace::Tracer::collect`]
//!   merges the lanes into a causally-ordered [`trace::Trace`] timeline
//!   with per-stage breakdowns, top-N slowest waves, and Chrome
//!   `trace_event` export.
//! - [`json::Json`] — a dependency-free JSON document type with stable
//!   key order (writer + strict parser), used by `drim cluster --json`,
//!   `drim trace`, and the `BENCH_*.json` trajectory artifacts written
//!   by [`crate::util::bench::BenchReport`].
//! - [`timeseries::TimeSeriesRecorder`] — bounded virtual-clock interval
//!   rings the scenario executor feeds (utilization, queue depth,
//!   admission/shed rate, sojourn histogram deltas), byte-deterministic
//!   under a fixed seed because no wall clock or live atomic is read.
//! - [`slo::SloConfig`] / [`slo::evaluate`] — declarative SLO specs
//!   (`[[slo]]` blocks in scenario TOML) evaluated as error-budget
//!   burn rates over the recorded series, reported as first-class gates
//!   by `drim bench --scenario`.
//!
//! See `docs/ARCHITECTURE.md` § Observability and § Continuous telemetry
//! & SLOs for the event taxonomy and the JSON schemas.

pub mod hist;
pub mod json;
pub mod slo;
pub mod timeseries;
pub mod trace;

pub use hist::Histogram;
pub use json::Json;
pub use slo::{SloConfig, SloKind, SloOutcome};
pub use timeseries::{TelemetrySummary, TimeSeriesRecorder};
pub use trace::{Stage, StageStats, Trace, TraceEvent, Tracer};
