//! Hand-rolled JSON: a writer with stable key order and a minimal parser.
//!
//! The exporter layer ([`crate::obs`]) promises a *schema-stable* JSON
//! surface (`drim cluster --json`, `drim trace --json`, `BENCH_*.json`
//! trajectory artifacts) without pulling a serialization dependency into
//! the offline build. Objects keep insertion order, so the emitted
//! documents are byte-stable for a given metric set — diffs across PRs
//! show metric drift, not key reshuffling.
//!
//! The parser exists for the golden-shape tests (and any tooling that
//! wants to read a `BENCH_*.json` back): a strict recursive-descent
//! reader over the subset the writer emits (no exponent-less `NaN`,
//! comments, or trailing commas).

use std::fmt;

/// A JSON document node. Numbers are split into `U64` (counters — kept
/// exact well past 2^53) and `F64` (measurements).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    U64(u64),
    F64(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Insertion-ordered object (stable output beats O(1) lookup here).
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Start an empty object.
    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Insert (or append) a field, returning `self` for chaining.
    pub fn field(mut self, key: &str, value: impl Into<Json>) -> Json {
        match &mut self {
            Json::Obj(fields) => fields.push((key.to_string(), value.into())),
            _ => panic!("field() on a non-object Json node"),
        }
        self
    }

    /// Member lookup on an object node.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Numeric value of a `U64`/`F64` node.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::U64(n) => Some(*n as f64),
            Json::F64(x) => Some(*x),
            _ => None,
        }
    }

    /// String value of a `Str` node.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Elements of an `Arr` node.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Serialize compactly (no whitespace) — the canonical machine form.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    /// Serialize with two-space indentation for human inspection.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write_pretty(&mut out, 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::U64(n) => out.push_str(&n.to_string()),
            Json::F64(x) => out.push_str(&fmt_f64(*x)),
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, depth: usize) {
        match self {
            Json::Arr(items) if !items.is_empty() => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    out.push_str(if i > 0 { ",\n" } else { "\n" });
                    indent(out, depth + 1);
                    v.write_pretty(out, depth + 1);
                }
                out.push('\n');
                indent(out, depth);
                out.push(']');
            }
            Json::Obj(fields) if !fields.is_empty() => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    out.push_str(if i > 0 { ",\n" } else { "\n" });
                    indent(out, depth + 1);
                    write_escaped(k, out);
                    out.push_str(": ");
                    v.write_pretty(out, depth + 1);
                }
                out.push('\n');
                indent(out, depth);
                out.push('}');
            }
            _ => self.write(out),
        }
    }

    /// Parse a JSON document (strict; the whole input must be consumed).
    pub fn parse(src: &str) -> Result<Json, String> {
        let bytes = src.as_bytes();
        let mut pos = 0;
        let v = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            return Err(format!("trailing garbage at byte {pos}"));
        }
        Ok(v)
    }
}

impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Json {
        Json::U64(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::U64(n as u64)
    }
}
impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::F64(x)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string_compact())
    }
}

fn indent(out: &mut String, depth: usize) {
    for _ in 0..depth {
        out.push_str("  ");
    }
}

/// Finite floats round-trip; NaN/inf have no JSON spelling and degrade to
/// null (metrics code never emits them, but a bench must not panic).
fn fmt_f64(x: f64) -> String {
    if x.is_finite() {
        // `{x:?}` keeps a trailing `.0` on integral floats, so F64 fields
        // never silently collapse into integer-looking tokens
        format!("{x:?}")
    } else {
        "null".to_string()
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect(b: &[u8], pos: &mut usize, lit: &str) -> Result<(), String> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(())
    } else {
        Err(format!("expected `{lit}` at byte {pos}", pos = *pos))
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    skip_ws(b, pos);
    match b.get(*pos) {
        None => Err("unexpected end of input".into()),
        Some(b'n') => expect(b, pos, "null").map(|()| Json::Null),
        Some(b't') => expect(b, pos, "true").map(|()| Json::Bool(true)),
        Some(b'f') => expect(b, pos, "false").map(|()| Json::Bool(false)),
        Some(b'"') => parse_string(b, pos).map(Json::Str),
        Some(b'[') => {
            *pos += 1;
            let mut items = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b']') {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            loop {
                items.push(parse_value(b, pos)?);
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b']') => {
                        *pos += 1;
                        return Ok(Json::Arr(items));
                    }
                    _ => return Err(format!("expected `,` or `]` at byte {pos}", pos = *pos)),
                }
            }
        }
        Some(b'{') => {
            *pos += 1;
            let mut fields = Vec::new();
            skip_ws(b, pos);
            if b.get(*pos) == Some(&b'}') {
                *pos += 1;
                return Ok(Json::Obj(fields));
            }
            loop {
                skip_ws(b, pos);
                let key = parse_string(b, pos)?;
                skip_ws(b, pos);
                expect(b, pos, ":")?;
                fields.push((key, parse_value(b, pos)?));
                skip_ws(b, pos);
                match b.get(*pos) {
                    Some(b',') => *pos += 1,
                    Some(b'}') => {
                        *pos += 1;
                        return Ok(Json::Obj(fields));
                    }
                    _ => return Err(format!("expected `,` or `}}` at byte {pos}", pos = *pos)),
                }
            }
        }
        Some(_) => parse_number(b, pos),
    }
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, String> {
    if b.get(*pos) != Some(&b'"') {
        return Err(format!("expected string at byte {pos}", pos = *pos));
    }
    *pos += 1;
    let mut out = String::new();
    loop {
        match b.get(*pos) {
            None => return Err("unterminated string".into()),
            Some(b'"') => {
                *pos += 1;
                return Ok(out);
            }
            Some(b'\\') => {
                *pos += 1;
                match b.get(*pos) {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let hex = b
                            .get(*pos + 1..*pos + 5)
                            .ok_or("truncated \\u escape")?;
                        let code = u32::from_str_radix(
                            std::str::from_utf8(hex).map_err(|e| e.to_string())?,
                            16,
                        )
                        .map_err(|e| e.to_string())?;
                        out.push(char::from_u32(code).ok_or("bad \\u code point")?);
                        *pos += 4;
                    }
                    other => return Err(format!("bad escape {other:?}")),
                }
                *pos += 1;
            }
            Some(_) => {
                // consume one UTF-8 scalar (input is a &str, so slicing on
                // a char boundary is safe via the chars iterator)
                let rest = std::str::from_utf8(&b[*pos..]).map_err(|e| e.to_string())?;
                let c = rest.chars().next().unwrap();
                out.push(c);
                *pos += c.len_utf8();
            }
        }
    }
}

fn parse_number(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    let start = *pos;
    if b.get(*pos) == Some(&b'-') {
        *pos += 1;
    }
    let mut float = false;
    while let Some(&c) = b.get(*pos) {
        match c {
            b'0'..=b'9' => *pos += 1,
            b'.' | b'e' | b'E' | b'+' | b'-' => {
                float = true;
                *pos += 1;
            }
            _ => break,
        }
    }
    let text = std::str::from_utf8(&b[start..*pos]).map_err(|e| e.to_string())?;
    if text.is_empty() || text == "-" {
        return Err(format!("expected number at byte {start}"));
    }
    if !float {
        if let Ok(n) = text.parse::<u64>() {
            return Ok(Json::U64(n));
        }
    }
    text.parse::<f64>()
        .map(Json::F64)
        .map_err(|e| format!("bad number `{text}`: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writer_emits_stable_key_order() {
        let doc = Json::obj()
            .field("b", 1u64)
            .field("a", 2u64)
            .field("s", "x\"y")
            .field("arr", Json::Arr(vec![Json::U64(1), Json::F64(0.5)]));
        assert_eq!(
            doc.to_string_compact(),
            r#"{"b":1,"a":2,"s":"x\"y","arr":[1,0.5]}"#
        );
    }

    #[test]
    fn roundtrip_through_parser() {
        let doc = Json::obj()
            .field("schema", 1u64)
            .field("nested", Json::obj().field("p99_ns", 1234.5))
            .field("flag", true)
            .field("none", Json::Null)
            .field("big", u64::MAX)
            .field("text", "line\nbreak\tand \\ quote \"");
        for s in [doc.to_string_compact(), doc.to_string_pretty()] {
            assert_eq!(Json::parse(&s).unwrap(), doc, "{s}");
        }
    }

    #[test]
    fn u64_counters_stay_exact_past_f64_precision() {
        let n = (1u64 << 53) + 1;
        let s = Json::U64(n).to_string_compact();
        match Json::parse(&s).unwrap() {
            Json::U64(got) => assert_eq!(got, n),
            other => panic!("expected U64, got {other:?}"),
        }
    }

    #[test]
    fn integral_floats_keep_their_marker() {
        // an F64 field must not degrade into an integer-looking token
        assert_eq!(Json::F64(3.0).to_string_compact(), "3.0");
        assert_eq!(Json::parse("3.0").unwrap(), Json::F64(3.0));
        assert_eq!(Json::F64(f64::NAN).to_string_compact(), "null");
    }

    #[test]
    fn parser_rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{} extra").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn lookup_helpers() {
        let doc = Json::parse(r#"{"a":{"b":[1,2.5,"s"]}}"#).unwrap();
        let arr = doc.get("a").unwrap().get("b").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[1].as_f64(), Some(2.5));
        assert_eq!(arr[2].as_str(), Some("s"));
        assert!(doc.get("missing").is_none());
    }
}
