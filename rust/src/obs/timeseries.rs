//! Virtual-clock time-series telemetry: bounded interval rings over the
//! scenario executor's simulated timeline.
//!
//! The end-of-run aggregates in [`crate::cluster::FleetSnapshot`] answer
//! *how the run finished*; they cannot answer *when* the fleet saturated,
//! started shedding, or burned its latency budget. The
//! [`TimeSeriesRecorder`] closes that gap: the scenario executor feeds it
//! every arrival, completion, and queue-depth observation stamped with a
//! **virtual-clock** timestamp, and the recorder folds them into
//! fixed-width interval buckets held in a bounded, pre-allocated ring.
//!
//! # Determinism contract
//!
//! The recorder never reads a wall clock and never samples live fleet
//! atomics (worker threads mutate those at host-dependent instants). Every
//! observation carries a timestamp computed by the executor on the
//! simulated timeline, so the same `(scenario, seed)` pair produces a
//! byte-identical series — the same replay contract the CI determinism
//! job diffs on `BENCH_*.json`.
//!
//! # Order independence
//!
//! Virtual timestamps do not arrive monotonically (an arrival at `t=5µs`
//! can be observed after a completion stamped `t=9µs` on another device's
//! virtual clock), so every per-bucket aggregate is **commutative**:
//! counters add, gauges take the max, and sojourn distributions are
//! mergeable [`Histogram`]s. Two recorders fed interleaved slices of the
//! same observation stream therefore [`TimeSeriesRecorder::merge`] into
//! the same series in either order — pinned by a property test.
//!
//! # Bounded memory
//!
//! The ring holds at most `capacity` buckets. When the simulated timeline
//! outruns it, the oldest buckets are folded into an *evicted prefix*
//! (keeping the cumulative counters of later samples exact) and counted
//! in [`TimeSeriesRecorder::dropped`], so a runaway scenario costs memory
//! proportional to `capacity`, never to its duration — the obs-overhead
//! gate prices exactly this.

use std::collections::VecDeque;

use super::hist::Histogram;
use super::json::Json;

/// Default sampling interval (virtual nanoseconds) when a scenario
/// enables telemetry without an explicit `interval_ns`.
pub const DEFAULT_INTERVAL_NS: u64 = 50_000;

/// Default ring capacity (buckets) when a scenario enables telemetry
/// without an explicit `capacity`.
pub const DEFAULT_CAPACITY: usize = 256;

/// One interval bucket. Every field is a commutative aggregate (sum, max,
/// or histogram merge) so bucket folding is observation-order-free.
#[derive(Clone, Debug)]
struct Bucket {
    offered: u64,
    admitted: u64,
    shed: u64,
    completed: u64,
    /// virtual busy nanoseconds attributed to this interval (service time
    /// of completions stamped inside it, summed over devices)
    busy_ns: u64,
    /// high-water queue depth observed inside the interval
    queue_depth_max: u64,
    /// per-lane sojourn distribution of completions stamped inside the
    /// interval (a *delta* histogram, not cumulative)
    sojourn: Vec<Histogram>,
}

impl Bucket {
    fn empty(lanes: usize) -> Self {
        Bucket {
            offered: 0,
            admitted: 0,
            shed: 0,
            completed: 0,
            busy_ns: 0,
            queue_depth_max: 0,
            sojourn: vec![Histogram::new(); lanes],
        }
    }

    fn absorb(&mut self, other: &Bucket) {
        self.offered += other.offered;
        self.admitted += other.admitted;
        self.shed += other.shed;
        self.completed += other.completed;
        self.busy_ns += other.busy_ns;
        self.queue_depth_max = self.queue_depth_max.max(other.queue_depth_max);
        for (dst, src) in self.sojourn.iter_mut().zip(other.sojourn.iter()) {
            dst.merge(src);
        }
    }

    fn is_empty(&self) -> bool {
        self.offered == 0
            && self.completed == 0
            && self.shed == 0
            && self.busy_ns == 0
            && self.queue_depth_max == 0
    }
}

/// One materialized sample: cumulative counters at the end boundary of an
/// interval, plus the interval's deltas and distributions.
#[derive(Clone, Debug)]
pub struct Sample {
    /// end boundary of the interval on the virtual clock
    pub t_ns: u64,
    /// cumulative counters at `t_ns` (evicted prefix included)
    pub offered: u64,
    pub admitted: u64,
    pub shed: u64,
    pub completed: u64,
    /// interval deltas
    pub d_offered: u64,
    pub d_admitted: u64,
    pub d_shed: u64,
    pub d_completed: u64,
    /// high-water queue depth inside the interval
    pub queue_depth_max: u64,
    /// busy-time fraction of the interval: `Σ service_ns / (devices ×
    /// interval_ns)`, may exceed 1.0 when completions of long requests
    /// cluster at one boundary
    pub utilization: f64,
    /// per-lane sojourn delta histograms (lane order =
    /// [`TimeSeriesRecorder::lanes`])
    pub sojourn: Vec<Histogram>,
}

impl Sample {
    /// Fleet-wide sojourn distribution for this interval: the merge of
    /// every lane's delta histogram.
    pub fn sojourn_merged(&self) -> Histogram {
        let mut all = Histogram::new();
        for h in &self.sojourn {
            all.merge(h);
        }
        all
    }
}

/// Compact description of a recorder for snapshot/trace JSON exports —
/// the `telemetry` block golden tests pin.
#[derive(Clone, Debug, Default)]
pub struct TelemetrySummary {
    /// false when the run had no recorder (plain `drim cluster` paths)
    pub enabled: bool,
    /// materialized samples still in the ring
    pub samples: u64,
    /// buckets evicted to keep the ring bounded
    pub dropped: u64,
    /// sampling interval (virtual ns); 0 when disabled
    pub interval_ns: u64,
    /// end boundary of the newest materialized sample (virtual ns)
    pub last_sample_ns: u64,
}

impl TelemetrySummary {
    /// Stable JSON schema: `enabled`, `samples`, `dropped`,
    /// `interval_ns`, `last_sample_ns`.
    pub fn to_json(&self) -> Json {
        Json::obj()
            .field("enabled", self.enabled)
            .field("samples", self.samples)
            .field("dropped", self.dropped)
            .field("interval_ns", self.interval_ns)
            .field("last_sample_ns", self.last_sample_ns)
    }
}

/// Bounded virtual-clock time-series recorder (see module docs).
#[derive(Clone, Debug)]
pub struct TimeSeriesRecorder {
    interval_ns: u64,
    capacity: usize,
    devices: usize,
    lanes: Vec<String>,
    /// buckets for absolute indices `first_index ..
    /// first_index + ring.len()`
    ring: VecDeque<Bucket>,
    first_index: u64,
    /// commutative fold of every evicted bucket — keeps the cumulative
    /// counters of surviving samples exact
    evicted: Bucket,
    evicted_buckets: u64,
}

impl TimeSeriesRecorder {
    /// New recorder sampling every `interval_ns` virtual nanoseconds into
    /// at most `capacity` buckets. `lanes` name the per-lane sojourn
    /// streams (scenario tenants); `devices` scales utilization.
    ///
    /// # Panics
    /// If `interval_ns` or `capacity` is zero.
    pub fn new(interval_ns: u64, capacity: usize, devices: usize, lanes: Vec<String>) -> Self {
        assert!(interval_ns > 0, "telemetry interval must be positive");
        assert!(capacity > 0, "telemetry capacity must be positive");
        let n = lanes.len();
        TimeSeriesRecorder {
            interval_ns,
            capacity,
            devices: devices.max(1),
            lanes,
            ring: VecDeque::with_capacity(capacity),
            first_index: 0,
            evicted: Bucket::empty(n),
            evicted_buckets: 0,
        }
    }

    pub fn interval_ns(&self) -> u64 {
        self.interval_ns
    }

    pub fn lanes(&self) -> &[String] {
        &self.lanes
    }

    /// Buckets evicted so far to keep the ring within `capacity`.
    pub fn dropped(&self) -> u64 {
        self.evicted_buckets
    }

    /// Materialized samples currently in the ring.
    pub fn len(&self) -> usize {
        self.ring.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ring.is_empty()
    }

    /// An arrival stamped `t_ns` on the virtual clock; `admitted = false`
    /// means it was shed at admission (per-tenant quota or fleet cap).
    pub fn record_arrival(&mut self, t_ns: u64, admitted: bool) {
        let b = self.bucket_mut(t_ns);
        b.offered += 1;
        if admitted {
            b.admitted += 1;
        } else {
            b.shed += 1;
        }
    }

    /// A completion stamped `t_ns` (the executing device's virtual clock
    /// after service): records the request's virtual sojourn into lane
    /// `lane` and attributes `busy_ns` of device busy time to the
    /// interval. Out-of-range lanes fold into lane 0.
    pub fn record_completion(&mut self, t_ns: u64, lane: usize, sojourn_ns: u64, busy_ns: u64) {
        let lane = if lane < self.lanes.len() { lane } else { 0 };
        let b = self.bucket_mut(t_ns);
        b.completed += 1;
        b.busy_ns += busy_ns;
        if let Some(h) = b.sojourn.get_mut(lane) {
            h.record(sojourn_ns);
        }
    }

    /// A queue-depth observation (submitted-but-unharvested requests) at
    /// `t_ns`; buckets keep the interval high-water mark.
    pub fn record_queue_depth(&mut self, t_ns: u64, depth: usize) {
        let b = self.bucket_mut(t_ns);
        b.queue_depth_max = b.queue_depth_max.max(depth as u64);
    }

    /// The bucket covering `t_ns`, materializing (and evicting, if the
    /// ring is full) as needed. Observations older than the evicted
    /// horizon fold into the evicted prefix.
    fn bucket_mut(&mut self, t_ns: u64) -> &mut Bucket {
        let idx = t_ns / self.interval_ns;
        if idx < self.first_index {
            // late observation for an already-evicted interval: keep the
            // cumulative totals exact, charge it to the prefix
            return &mut self.evicted;
        }
        while self.first_index + self.ring.len() as u64 <= idx {
            if self.ring.len() == self.capacity {
                let front = self.ring.pop_front().expect("non-empty full ring");
                self.evicted.absorb(&front);
                self.evicted_buckets += 1;
                self.first_index += 1;
            }
            self.ring.push_back(Bucket::empty(self.lanes.len()));
        }
        &mut self.ring[(idx - self.first_index) as usize]
    }

    /// Fold another recorder into this one, aligning buckets by absolute
    /// interval index. Commutative up to ring eviction: with enough
    /// capacity, `a.merge(b)` and `b.merge(a)` produce identical series
    /// (pinned by a property test).
    ///
    /// # Panics
    /// If the recorders disagree on interval or lane layout.
    pub fn merge(&mut self, other: &TimeSeriesRecorder) {
        assert_eq!(
            self.interval_ns, other.interval_ns,
            "cannot merge recorders with different intervals"
        );
        assert_eq!(
            self.lanes, other.lanes,
            "cannot merge recorders with different lanes"
        );
        self.evicted.absorb(&other.evicted);
        self.evicted_buckets += other.evicted_buckets;
        self.devices = self.devices.max(other.devices);
        for (i, bucket) in other.ring.iter().enumerate() {
            if bucket.is_empty() {
                continue;
            }
            let t_ns = (other.first_index + i as u64) * self.interval_ns;
            self.bucket_mut(t_ns).absorb(bucket);
        }
    }

    /// The materialized series: one [`Sample`] per ring bucket in
    /// timeline order, cumulative counters seeded from the evicted
    /// prefix. Trailing never-touched buckets are materialized too (they
    /// were paid for), so the series tiles `[first, last]` gaplessly.
    pub fn samples(&self) -> Vec<Sample> {
        let mut out = Vec::with_capacity(self.ring.len());
        let mut offered = self.evicted.offered;
        let mut admitted = self.evicted.admitted;
        let mut shed = self.evicted.shed;
        let mut completed = self.evicted.completed;
        let span = (self.devices as u64 * self.interval_ns) as f64;
        for (i, b) in self.ring.iter().enumerate() {
            offered += b.offered;
            admitted += b.admitted;
            shed += b.shed;
            completed += b.completed;
            out.push(Sample {
                t_ns: (self.first_index + i as u64 + 1) * self.interval_ns,
                offered,
                admitted,
                shed,
                completed,
                d_offered: b.offered,
                d_admitted: b.admitted,
                d_shed: b.shed,
                d_completed: b.completed,
                queue_depth_max: b.queue_depth_max,
                utilization: b.busy_ns as f64 / span,
                sojourn: b.sojourn.clone(),
            });
        }
        out
    }

    /// The compact summary exported as the `telemetry` block in snapshot
    /// and trace JSON.
    pub fn summary(&self) -> TelemetrySummary {
        TelemetrySummary {
            enabled: true,
            samples: self.ring.len() as u64,
            dropped: self.evicted_buckets,
            interval_ns: self.interval_ns,
            last_sample_ns: (self.first_index + self.ring.len() as u64) * self.interval_ns,
        }
    }

    /// Full series JSON (summary + per-sample points with fleet-merged
    /// sojourn summaries). Deterministic; used by tests and exporters.
    pub fn to_json(&self) -> Json {
        let points: Vec<Json> = self
            .samples()
            .iter()
            .map(|s| {
                Json::obj()
                    .field("t_ns", s.t_ns)
                    .field("offered", s.offered)
                    .field("admitted", s.admitted)
                    .field("shed", s.shed)
                    .field("completed", s.completed)
                    .field("queue_depth_max", s.queue_depth_max)
                    .field("utilization", s.utilization)
                    .field("sojourn_ns", s.sojourn_merged().summary_json())
            })
            .collect();
        Json::obj()
            .field("interval_ns", self.interval_ns)
            .field("dropped", self.evicted_buckets)
            .field(
                "lanes",
                Json::Arr(self.lanes.iter().map(|l| Json::from(l.as_str())).collect()),
            )
            .field("points", Json::Arr(points))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(interval: u64, cap: usize) -> TimeSeriesRecorder {
        TimeSeriesRecorder::new(interval, cap, 2, vec!["a".into(), "b".into()])
    }

    #[test]
    fn buckets_by_interval_and_accumulates() {
        let mut r = rec(100, 16);
        r.record_arrival(10, true);
        r.record_arrival(110, true);
        r.record_arrival(120, false);
        r.record_completion(150, 0, 140, 40);
        r.record_completion(250, 1, 200, 60);
        r.record_queue_depth(55, 3);
        r.record_queue_depth(60, 1); // lower: max sticks at 3

        let s = r.samples();
        assert_eq!(s.len(), 3);
        assert_eq!(s[0].t_ns, 100);
        assert_eq!((s[0].offered, s[0].admitted, s[0].shed), (1, 1, 0));
        assert_eq!(s[0].queue_depth_max, 3);
        assert_eq!((s[1].offered, s[1].admitted, s[1].shed), (3, 2, 1));
        assert_eq!(s[1].d_offered, 2);
        assert_eq!(s[1].completed, 1);
        // utilization: 40 busy ns over 2 devices × 100 ns = 0.2
        assert!((s[0].utilization - 0.2).abs() < 1e-12);
        assert_eq!(s[2].completed, 2);
        assert_eq!(s[2].sojourn[1].count(), 1);
        assert_eq!(r.summary().samples, 3);
        assert_eq!(r.summary().last_sample_ns, 300);
        assert_eq!(r.summary().dropped, 0);
    }

    #[test]
    fn ring_evicts_oldest_and_keeps_cumulative_exact() {
        let mut r = rec(10, 4);
        for i in 0..12u64 {
            r.record_arrival(i * 10, true);
        }
        // 12 buckets touched, capacity 4 → 8 evicted
        assert_eq!(r.len(), 4);
        assert_eq!(r.dropped(), 8);
        let s = r.samples();
        assert_eq!(s.first().unwrap().t_ns, 90);
        // cumulative offered at the last sample still counts everything
        assert_eq!(s.last().unwrap().offered, 12);
        // late observation behind the horizon folds into the prefix
        r.record_arrival(0, true);
        assert_eq!(r.samples().last().unwrap().offered, 13);
        assert_eq!(r.dropped(), 8);
    }

    #[test]
    fn merge_aligns_absolute_indices_in_either_order() {
        let obs: Vec<(u64, bool)> = (0..40u64).map(|i| (i * 7, i % 3 != 0)).collect();
        let mut a = rec(50, 64);
        let mut b = rec(50, 64);
        let mut whole = rec(50, 64);
        for (i, &(t, adm)) in obs.iter().enumerate() {
            whole.record_arrival(t, adm);
            whole.record_completion(t + 30, i % 2, 30 + t, 11);
            if i % 2 == 0 {
                a.record_arrival(t, adm);
                a.record_completion(t + 30, i % 2, 30 + t, 11);
            } else {
                b.record_arrival(t, adm);
                b.record_completion(t + 30, i % 2, 30 + t, 11);
            }
        }
        let mut ab = a.clone();
        ab.merge(&b);
        let mut ba = b.clone();
        ba.merge(&a);
        let whole_json = whole.to_json().to_string_compact();
        assert_eq!(ab.to_json().to_string_compact(), whole_json);
        assert_eq!(ba.to_json().to_string_compact(), whole_json);
    }

    #[test]
    fn disabled_summary_is_all_zero() {
        let s = TelemetrySummary::default();
        assert!(!s.enabled);
        assert_eq!(
            s.to_json().to_string_compact(),
            r#"{"enabled":false,"samples":0,"dropped":0,"interval_ns":0,"last_sample_ns":0}"#
        );
    }
}
