//! Declarative SLO evaluation with error-budget burn rates over a
//! recorded [`TimeSeriesRecorder`] series.
//!
//! An SLO here is SRE-shaped: an *objective* ("`objective_pct`% of units
//! must be good"), a per-unit goodness predicate ([`SloKind`]), and a
//! *burn-rate* gate. The allowed error budget is the complement of the
//! objective (`p99` ⇒ 1% of units may be bad); over every sliding window
//! of `window` consecutive samples the engine computes
//!
//! ```text
//! burn = (bad units in window / total units in window) / allowed_fraction
//! ```
//!
//! so `burn = 1.0` means the window consumed its budget exactly as fast
//! as the objective permits, and `burn = 14` is the classic "page now"
//! fast-burn signal. The SLO **passes** iff the worst window's burn rate
//! stays at or below `max_burn`.
//!
//! Everything is computed from the virtual-clock series, so evaluation is
//! deterministic and replayable: the same `(scenario, seed)` pair yields
//! byte-identical SLO outcomes in `BENCH_*.json`.
//!
//! Sojourn violation counts come from [`Histogram::count_ge`], which
//! resolves at bucket granularity (≤12.5% threshold error, never an
//! undercount) — budgets are latency *envelopes*, not exact cutoffs.

use super::hist::Histogram;
use super::timeseries::{Sample, TimeSeriesRecorder};

/// What a unit is and when it is good.
#[derive(Clone, Debug, PartialEq)]
pub enum SloKind {
    /// Units are completed requests; a unit is bad when its virtual
    /// sojourn exceeds `budget_ns`. `lane = None` evaluates the
    /// fleet-wide merge, `Some(name)` a single recorder lane (tenant).
    Sojourn {
        budget_ns: u64,
        lane: Option<String>,
    },
    /// Units are sample intervals; an interval is bad when its admission
    /// throughput (`d_admitted / interval`) falls below `min_per_sec`.
    /// Leading/trailing idle intervals (no offered traffic) are skipped —
    /// a throughput floor constrains the fleet while load exists, not the
    /// silence around it.
    AdmissionRate { min_per_sec: f64 },
}

/// One declarative SLO (a `[[slo]]` block in scenario TOML, minus the
/// case binding which the scenario layer owns).
#[derive(Clone, Debug)]
pub struct SloConfig {
    pub name: String,
    pub kind: SloKind,
    /// objective: this percentage of units must be good (0 < pct < 100)
    pub objective_pct: f64,
    /// burn-rate window in samples (clamped to the series length)
    pub window: usize,
    /// gate: worst sliding-window burn rate must stay ≤ this
    pub max_burn: f64,
}

/// One evaluated SLO — rendered as a first-class gate by
/// `drim bench --scenario`.
#[derive(Clone, Debug)]
pub struct SloOutcome {
    pub name: String,
    pub pass: bool,
    /// human-readable objective/burn rendering
    pub detail: String,
    /// worst sliding-window burn rate
    pub max_burn: f64,
    /// whole-series burn rate
    pub overall_burn: f64,
    /// bad units over the whole series
    pub bad: u64,
    /// total units over the whole series
    pub total: u64,
    /// sliding windows evaluated
    pub windows: usize,
}

/// Per-sample (bad, total) unit counts for one SLO kind.
fn sample_units(kind: &SloKind, s: &Sample, interval_ns: u64, lanes: &[String]) -> (u64, u64) {
    match kind {
        SloKind::Sojourn { budget_ns, lane } => {
            let hist: Histogram = match lane {
                None => s.sojourn_merged(),
                Some(name) => match lanes.iter().position(|l| l == name) {
                    Some(i) => s.sojourn[i].clone(),
                    None => Histogram::new(),
                },
            };
            // violation = sojourn strictly above the budget
            (hist.count_ge(budget_ns.saturating_add(1)), hist.count())
        }
        SloKind::AdmissionRate { min_per_sec } => {
            if s.d_offered == 0 {
                return (0, 0); // idle interval: not a unit
            }
            let rate = s.d_admitted as f64 * 1e9 / interval_ns as f64;
            ((rate < *min_per_sec) as u64, 1)
        }
    }
}

/// Evaluate one SLO against a recorded series.
pub fn evaluate(slo: &SloConfig, rec: &TimeSeriesRecorder) -> SloOutcome {
    let samples = rec.samples();
    let units: Vec<(u64, u64)> = samples
        .iter()
        .map(|s| sample_units(&slo.kind, s, rec.interval_ns(), rec.lanes()))
        .collect();
    // the complement of the objective, floored so a 100% objective yields
    // an astronomically-finite burn instead of ∞ (JSON-safe)
    let allowed = ((100.0 - slo.objective_pct) / 100.0).max(1e-12);

    let bad: u64 = units.iter().map(|u| u.0).sum();
    let total: u64 = units.iter().map(|u| u.1).sum();
    let overall_burn = if total == 0 {
        0.0
    } else {
        (bad as f64 / total as f64) / allowed
    };

    let window = slo.window.max(1).min(units.len().max(1));
    let mut max_burn = 0.0f64;
    let mut windows = 0usize;
    if !units.is_empty() {
        for w in units.windows(window) {
            let wbad: u64 = w.iter().map(|u| u.0).sum();
            let wtotal: u64 = w.iter().map(|u| u.1).sum();
            if wtotal == 0 {
                continue; // no units in view — nothing to burn
            }
            windows += 1;
            let burn = (wbad as f64 / wtotal as f64) / allowed;
            max_burn = max_burn.max(burn);
        }
    }

    let pass = max_burn <= slo.max_burn;
    let what = match &slo.kind {
        SloKind::Sojourn { budget_ns, lane } => match lane {
            Some(l) => format!("sojourn[{l}] <= {budget_ns}ns"),
            None => format!("sojourn <= {budget_ns}ns"),
        },
        SloKind::AdmissionRate { min_per_sec } => {
            format!("admission_rate >= {min_per_sec}/s")
        }
    };
    let detail = format!(
        "{what} for {}% of units: bad {bad}/{total}, max burn {:.3} (limit {}, \
         window {window} of {} samples)",
        slo.objective_pct,
        max_burn,
        slo.max_burn,
        samples.len(),
    );
    SloOutcome {
        name: slo.name.clone(),
        pass,
        detail,
        max_burn,
        overall_burn,
        bad,
        total,
        windows,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::timeseries::TimeSeriesRecorder;

    fn recorder_with_sojourns(per_bucket: &[&[u64]]) -> TimeSeriesRecorder {
        let mut r = TimeSeriesRecorder::new(100, 64, 1, vec!["t".into()]);
        for (i, bucket) in per_bucket.iter().enumerate() {
            let t = i as u64 * 100 + 1;
            for &sj in *bucket {
                r.record_completion(t, 0, sj, 10);
            }
        }
        r
    }

    fn sojourn_slo(budget: u64, pct: f64, window: usize, max_burn: f64) -> SloConfig {
        SloConfig {
            name: "s".into(),
            kind: SloKind::Sojourn {
                budget_ns: budget,
                lane: None,
            },
            objective_pct: pct,
            window,
            max_burn,
        }
    }

    #[test]
    fn perfect_compliance_burns_nothing() {
        let r = recorder_with_sojourns(&[&[10, 20], &[30], &[40, 50]]);
        let o = evaluate(&sojourn_slo(1_000, 99.0, 2, 1.0), &r);
        assert!(o.pass);
        assert_eq!((o.bad, o.total), (0, 5));
        assert_eq!(o.max_burn, 0.0);
        assert_eq!(o.overall_burn, 0.0);
    }

    #[test]
    fn total_violation_burns_fast_and_fails() {
        let r = recorder_with_sojourns(&[&[10_000], &[20_000]]);
        let o = evaluate(&sojourn_slo(100, 99.0, 1, 10.0), &r);
        assert!(!o.pass);
        assert_eq!((o.bad, o.total), (2, 2));
        // every unit bad: burn = 1.0 / 0.01 = 100
        assert!((o.max_burn - 100.0).abs() < 1e-9);
    }

    #[test]
    fn burn_localizes_to_the_bad_window() {
        // 9 good buckets of 10 fast requests, one bucket fully violating
        let good: Vec<u64> = vec![50; 10];
        let mut buckets: Vec<&[u64]> = vec![&good; 9];
        let bad = [5_000u64; 10];
        buckets.push(&bad);
        let r = recorder_with_sojourns(&buckets);
        // objective 90% → allowed 10%; worst window (the bad bucket alone)
        // is 100% bad → burn 10; overall is 10% bad → burn 1
        let o = evaluate(&sojourn_slo(1_000, 90.0, 1, 5.0), &r);
        assert!(!o.pass);
        assert!((o.max_burn - 10.0).abs() < 1e-9);
        assert!((o.overall_burn - 1.0).abs() < 1e-9);
        // a window spanning the whole series dilutes back to burn 1
        let o2 = evaluate(&sojourn_slo(1_000, 90.0, 10, 5.0), &r);
        assert!(o2.pass, "{}", o2.detail);
        assert!((o2.max_burn - 1.0).abs() < 1e-9);
    }

    #[test]
    fn lane_filter_scopes_the_objective() {
        let mut r = TimeSeriesRecorder::new(100, 16, 1, vec!["fast".into(), "slow".into()]);
        r.record_completion(10, 0, 50, 5);
        r.record_completion(20, 1, 9_999, 5);
        let mut slo = sojourn_slo(1_000, 50.0, 1, 1.0);
        slo.kind = SloKind::Sojourn {
            budget_ns: 1_000,
            lane: Some("fast".into()),
        };
        let o = evaluate(&slo, &r);
        assert!(o.pass);
        assert_eq!((o.bad, o.total), (0, 1));
        slo.kind = SloKind::Sojourn {
            budget_ns: 1_000,
            lane: Some("slow".into()),
        };
        let o = evaluate(&slo, &r);
        assert!(!o.pass);
        assert_eq!((o.bad, o.total), (1, 1));
    }

    #[test]
    fn admission_floor_skips_idle_intervals() {
        let mut r = TimeSeriesRecorder::new(1_000, 16, 1, vec!["t".into()]);
        // bucket 0: 5 admitted (5e6/s) · bucket 1 idle · bucket 2: 1
        // admitted + 3 shed (1e6/s)
        for _ in 0..5 {
            r.record_arrival(10, true);
        }
        r.record_arrival(2_100, true);
        for _ in 0..3 {
            r.record_arrival(2_200, false);
        }
        let slo = SloConfig {
            name: "floor".into(),
            kind: SloKind::AdmissionRate {
                min_per_sec: 2_000_000.0,
            },
            objective_pct: 60.0,
            window: 1,
            max_burn: 1.0,
        };
        let o = evaluate(&slo, &r);
        // 2 non-idle intervals, 1 below floor → 50% bad / 40% allowed
        assert_eq!((o.bad, o.total), (1, 2));
        assert!(!o.pass);
        assert!((o.max_burn - 2.5).abs() < 1e-9);

        let relaxed = SloConfig {
            max_burn: 3.0,
            ..slo.clone()
        };
        assert!(evaluate(&relaxed, &r).pass);
    }

    #[test]
    fn empty_series_passes_vacuously() {
        let r = TimeSeriesRecorder::new(100, 4, 1, vec!["t".into()]);
        let o = evaluate(&sojourn_slo(1, 99.9, 4, 0.5), &r);
        assert!(o.pass);
        assert_eq!((o.bad, o.total, o.windows), (0, 0, 0));
    }
}
