//! Log-linear (HDR-lite) latency histograms: mergeable, bounded error.
//!
//! The coordinator used to keep a single Welford [`crate::util::stats::Summary`]
//! per latency stream, which can answer "mean/max" but not "p99" — and the
//! paper's claims are tail-latency claims. This histogram records values
//! into log-spaced buckets subdivided linearly ([`SUB_BITS`] sub-buckets
//! per power of two), giving ≤ 1/2^SUB_BITS = 12.5% relative quantile
//! error over the full `u64` nanosecond range with a few KB of counters.
//!
//! Two properties the fleet layer depends on:
//! - **Mergeable**: bucket-wise addition, so per-device histograms fold
//!   into a fleet histogram without re-observing samples (merge is
//!   associative and commutative — pinned by tests).
//! - **Monotone percentiles**: `percentile(p)` is non-decreasing in `p`
//!   and clamped to the observed `[min, max]`, so `p50 ≤ p95 ≤ p99 ≤ max`
//!   always holds in reports.

use super::json::Json;

/// Linear sub-bucket resolution: 2^3 = 8 sub-buckets per octave.
const SUB_BITS: u32 = 3;
const SUBS: usize = 1 << SUB_BITS;

/// Mergeable log-linear histogram over `u64` values (nanoseconds here,
/// but the type is unit-agnostic).
#[derive(Debug, Clone)]
pub struct Histogram {
    /// Bucket counts, grown lazily to the highest touched index.
    counts: Vec<u64>,
    count: u64,
    sum: u128,
    min: u64,
    max: u64,
}

impl Default for Histogram {
    /// Same as [`Histogram::new`] (a derived default would start `min`
    /// at 0 and poison every later [`Histogram::record`]).
    fn default() -> Self {
        Histogram::new()
    }
}

/// Bucket index for a value: identity below `SUBS`, then 8 linear
/// sub-buckets per power of two.
fn bucket_index(n: u64) -> usize {
    if n < SUBS as u64 {
        return n as usize;
    }
    let exp = 63 - n.leading_zeros(); // n >= 8, so exp >= 3
    let sub = ((n >> (exp - SUB_BITS)) as usize) & (SUBS - 1);
    (((exp - SUB_BITS + 1) as usize) << SUB_BITS) + sub
}

/// Inclusive lower bound of a bucket (exact inverse of [`bucket_index`]
/// for the bucket's first member).
fn bucket_low(index: usize) -> u64 {
    if index < SUBS {
        return index as u64;
    }
    let base = (index >> SUB_BITS) as u32; // >= 1
    let sub = (index & (SUBS - 1)) as u64;
    (SUBS as u64 + sub) << (base - 1)
}

/// Exclusive upper bound of a bucket.
fn bucket_high(index: usize) -> u64 {
    if index < SUBS {
        return index as u64 + 1;
    }
    let base = (index >> SUB_BITS) as u32;
    bucket_low(index) + (1u64 << (base - 1))
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            counts: Vec::new(),
            count: 0,
            sum: 0,
            min: u64::MAX,
            max: 0,
        }
    }

    /// Record one value.
    pub fn record(&mut self, value: u64) {
        let idx = bucket_index(value);
        if idx >= self.counts.len() {
            self.counts.resize(idx + 1, 0);
        }
        self.counts[idx] += 1;
        self.count += 1;
        self.sum += value as u128;
        self.min = self.min.min(value);
        self.max = self.max.max(value);
    }

    /// Fold another histogram into this one (bucket-wise addition).
    pub fn merge(&mut self, other: &Histogram) {
        if other.count == 0 {
            return;
        }
        if other.counts.len() > self.counts.len() {
            self.counts.resize(other.counts.len(), 0);
        }
        for (dst, src) in self.counts.iter_mut().zip(other.counts.iter()) {
            *dst += src;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Exact mean (the sum is tracked exactly, not reconstructed from
    /// bucket midpoints).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    pub fn min(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    /// Quantile estimate for `p` in `[0, 100]`: walk the cumulative
    /// bucket counts to the bucket containing the p-th sample and return
    /// its midpoint, clamped to the observed `[min, max]` so estimates
    /// never exceed a value that was actually recorded.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((p / 100.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (idx, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                let mid = (bucket_low(idx) + bucket_high(idx)) as f64 / 2.0;
                return mid.clamp(self.min as f64, self.max as f64);
            }
        }
        self.max as f64
    }

    /// Number of recorded values at or above `threshold`, resolved at
    /// bucket granularity: the whole bucket containing `threshold` is
    /// counted, so the result may overcount by up to one bucket's
    /// population (≤12.5% threshold error) but never undercounts, and it
    /// is monotone non-increasing in `threshold`. Exact at the extremes
    /// (`threshold ≤ min` and `threshold > max`). Backs the SLO engine's
    /// deterministic violation counting.
    pub fn count_ge(&self, threshold: u64) -> u64 {
        if self.count == 0 || threshold > self.max {
            return 0;
        }
        if threshold <= self.min {
            return self.count;
        }
        self.counts.iter().skip(bucket_index(threshold)).sum()
    }

    /// The standard report triple.
    pub fn p50_p95_p99(&self) -> (f64, f64, f64) {
        (
            self.percentile(50.0),
            self.percentile(95.0),
            self.percentile(99.0),
        )
    }

    /// Summary JSON (count, mean, min/max, p50/p95/p99) — the stable
    /// schema every exporter emits for a latency distribution. Raw
    /// bucket counts deliberately stay internal.
    pub fn summary_json(&self) -> Json {
        let (p50, p95, p99) = self.p50_p95_p99();
        Json::obj()
            .field("count", self.count)
            .field("mean", self.mean())
            .field("min", self.min())
            .field("max", self.max())
            .field("p50", p50)
            .field("p95", p95)
            .field("p99", p99)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries_are_exact_inverses() {
        // every value maps into a bucket whose [low, high) range holds it,
        // and bucket bounds tile the line without gaps or overlaps
        for n in (0u64..4096).chain([u64::MAX, u64::MAX - 1, 1 << 40, (1 << 40) + 1]) {
            let idx = bucket_index(n);
            assert!(
                bucket_low(idx) <= n && (idx < SUBS || n < bucket_high(idx)),
                "n={n} idx={idx} low={} high={}",
                bucket_low(idx),
                bucket_high(idx)
            );
        }
        for idx in 1..2000 {
            assert_eq!(
                bucket_high(idx - 1),
                bucket_low(idx),
                "gap between buckets {} and {}",
                idx - 1,
                idx
            );
            assert_eq!(bucket_index(bucket_low(idx)), idx);
        }
    }

    #[test]
    fn small_values_are_exact() {
        let mut h = Histogram::new();
        for v in 0..8u64 {
            h.record(v);
        }
        // below SUBS each value has its own bucket → percentiles are exact
        assert_eq!(h.percentile(100.0), 7.0);
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), 7);
        assert_eq!(h.mean(), 3.5);
    }

    #[test]
    fn relative_error_is_bounded() {
        let mut h = Histogram::new();
        let v = 1_000_000u64;
        h.record(v);
        let p = h.percentile(50.0);
        // single sample: estimate is clamped to [min,max] = [v,v]
        assert_eq!(p, v as f64);

        let mut h2 = Histogram::new();
        for x in [900_000u64, 1_000_000, 1_100_000] {
            h2.record(x);
        }
        let p50 = h2.percentile(50.0);
        assert!(
            (p50 - 1_000_000.0).abs() / 1_000_000.0 <= 0.125,
            "p50={p50} off by more than one sub-bucket"
        );
    }

    #[test]
    fn merge_is_associative_and_matches_combined_stream() {
        let streams: [&[u64]; 3] = [
            &[1, 5, 9, 130, 70_000],
            &[2, 2, 2, 1_000_000_000],
            &[42, 43, 44, 45, 12_345_678],
        ];
        let mut hists: Vec<Histogram> = streams
            .iter()
            .map(|s| {
                let mut h = Histogram::new();
                for &v in *s {
                    h.record(v);
                }
                h
            })
            .collect();

        // (a ⊕ b) ⊕ c
        let mut left = hists[0].clone();
        left.merge(&hists[1]);
        left.merge(&hists[2]);
        // a ⊕ (b ⊕ c)
        let mut bc = hists[1].clone();
        bc.merge(&hists[2]);
        let mut right = hists[0].clone();
        right.merge(&bc);

        // one histogram fed the concatenated stream
        let mut all = Histogram::new();
        for s in streams {
            for &v in s {
                all.record(v);
            }
        }

        for h in [&left, &right] {
            assert_eq!(h.count(), all.count());
            assert_eq!(h.min(), all.min());
            assert_eq!(h.max(), all.max());
            assert_eq!(h.mean(), all.mean());
            for p in [50.0, 95.0, 99.0, 100.0] {
                assert_eq!(h.percentile(p), all.percentile(p), "p{p}");
            }
        }
        hists.clear();
    }

    #[test]
    fn percentiles_are_monotone() {
        let mut h = Histogram::new();
        let mut x = 3u64;
        for _ in 0..500 {
            x = x.wrapping_mul(2862933555777941757).wrapping_add(3037000493);
            h.record(x >> 34); // spread over ~2^30 range
        }
        let mut prev = 0.0;
        for p in [0.0, 10.0, 25.0, 50.0, 75.0, 90.0, 95.0, 99.0, 99.9, 100.0] {
            let v = h.percentile(p);
            assert!(v >= prev, "p{p}={v} < previous {prev}");
            prev = v;
        }
        assert!(prev <= h.max() as f64 + 0.5);
        let (p50, p95, p99) = h.p50_p95_p99();
        assert!(p50 <= p95 && p95 <= p99 && p99 <= h.max() as f64);
    }

    #[test]
    fn count_ge_is_monotone_and_exact_at_extremes() {
        let mut h = Histogram::new();
        for v in [3u64, 7, 100, 1_000, 1_000_000] {
            h.record(v);
        }
        assert_eq!(h.count_ge(0), 5);
        assert_eq!(h.count_ge(3), 5);
        assert_eq!(h.count_ge(1_000_001), 0);
        assert_eq!(h.count_ge(u64::MAX), 0);
        // exact where buckets are exact (values < SUBS)
        assert_eq!(h.count_ge(4), 4);
        // never undercounts, monotone non-increasing
        let mut prev = u64::MAX;
        for t in 0..2_000u64 {
            let c = h.count_ge(t);
            let exact = [3u64, 7, 100, 1_000, 1_000_000]
                .iter()
                .filter(|&&v| v >= t)
                .count() as u64;
            assert!(c >= exact, "t={t}: count_ge={c} < exact {exact}");
            assert!(c <= prev, "t={t}: not monotone");
            prev = c;
        }
        assert_eq!(Histogram::new().count_ge(0), 0);
    }

    #[test]
    fn empty_histogram_reports_zeros() {
        let h = Histogram::new();
        assert!(h.is_empty());
        assert_eq!(h.percentile(99.0), 0.0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), 0);
    }
}
