//! Structured pipeline tracer: fixed-capacity per-lane ring buffers of
//! typed span events, merged into a causally-ordered fleet timeline.
//!
//! Every stage of the submission pipeline (admit → coalesce-stage →
//! drain → wave-execute → reassemble) and every residency action (copy,
//! evict, replicate, migrate) can emit a [`TraceEvent`] tagged with the
//! request/wave sequence number it belongs to, so a single request can
//! be followed across the frontend, the scheduler queue, and the worker
//! that executed it.
//!
//! Cost model — the tracer must be safe to leave compiled in:
//! - **Compile-out**: with the `trace` cargo feature disabled every
//!   record call degenerates to a statically-false branch and the event
//!   body is never evaluated.
//! - **Runtime sampling**: recording is keyed on the event's sequence
//!   number (`seq % sample_every == 0`), not a global counter, so all
//!   stages of a sampled request are kept together and spans stay
//!   coherent. `sample_every == 0` disables recording entirely behind a
//!   single relaxed atomic load — the only hot-path cost when idle.
//! - **Bounded memory**: each lane (one per device, plus one frontend
//!   lane) is a fixed-capacity ring; overflow drops the *oldest* events
//!   and counts the drops rather than blocking or reallocating.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU32, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use super::json::Json;

/// Pipeline / residency stage a trace event belongs to.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Stage {
    /// Request accepted by fleet admission (instant, frontend lane).
    Admit,
    /// Request parked in a coalescer staging bucket (instant, frontend).
    Coalesce,
    /// Worker pulled a wave group off its queue (span: queue drain).
    Drain,
    /// Device executed a wave set (span: submit → response).
    WaveExecute,
    /// Responses forwarded back to submitters (span).
    Reassemble,
    /// Operand bytes copied onto a device (duration = *simulated* ns).
    Copy,
    /// Region replica evicted by capacity enforcement (instant).
    Evict,
    /// Region replicated to an additional device (instant).
    Replicate,
    /// Region migrated between devices (instant).
    Migrate,
}

/// All stages, in pipeline order — used by reports so the per-stage
/// breakdown always renders in causal order.
pub const STAGES: [Stage; 9] = [
    Stage::Admit,
    Stage::Coalesce,
    Stage::Drain,
    Stage::WaveExecute,
    Stage::Reassemble,
    Stage::Copy,
    Stage::Evict,
    Stage::Replicate,
    Stage::Migrate,
];

impl Stage {
    /// Stable lowercase name used in reports and JSON.
    pub fn name(self) -> &'static str {
        match self {
            Stage::Admit => "admit",
            Stage::Coalesce => "coalesce",
            Stage::Drain => "drain",
            Stage::WaveExecute => "wave_execute",
            Stage::Reassemble => "reassemble",
            Stage::Copy => "copy",
            Stage::Evict => "evict",
            Stage::Replicate => "replicate",
            Stage::Migrate => "migrate",
        }
    }
}

/// One recorded event. `dur_ns == 0` marks an instant; otherwise the
/// event is a span covering `[ts_ns, ts_ns + dur_ns)` in host time
/// relative to the tracer's epoch (except [`Stage::Copy`], whose
/// duration is simulated device time — see the field docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceEvent {
    /// Host-clock offset from [`Tracer`] creation, nanoseconds.
    pub ts_ns: u64,
    /// Span length in ns (0 = instant). For `Copy` events this is the
    /// *simulated* transfer time, recorded at the host instant the copy
    /// was charged.
    pub dur_ns: u64,
    /// Writer lane: device index, or the frontend lane (last index).
    pub lane: u32,
    /// Pipeline stage.
    pub stage: Stage,
    /// Correlation id: request sequence number, or region id for
    /// residency events (`Copy`/`Evict`/`Replicate`/`Migrate`).
    pub seq: u64,
    /// Stage-specific payload: bytes for `Admit`/`Copy`, wave count for
    /// `WaveExecute`, batch size for `Drain`, device for residency moves.
    pub detail: u64,
}

struct Ring {
    buf: VecDeque<TraceEvent>,
    cap: usize,
    dropped: u64,
}

impl Ring {
    fn push(&mut self, ev: TraceEvent) {
        if self.buf.len() == self.cap {
            self.buf.pop_front();
            self.dropped += 1;
        }
        self.buf.push_back(ev);
    }
}

/// Lock-cheap multi-lane event recorder. One `Mutex<Ring>` per lane:
/// each worker writes only its own lane, so the mutex is uncontended in
/// steady state and exists only to make `collect()` safe.
pub struct Tracer {
    epoch: Instant,
    sample_every: AtomicU32,
    lanes: Vec<Mutex<Ring>>,
}

impl Tracer {
    /// `lanes` ring buffers of `capacity` events each. Convention in the
    /// cluster: lane `d` belongs to device `d`, the final lane to the
    /// submission frontend ([`Tracer::frontend_lane`]).
    pub fn new(lanes: usize, capacity: usize) -> Self {
        Tracer {
            epoch: Instant::now(),
            sample_every: AtomicU32::new(0),
            lanes: (0..lanes.max(1))
                .map(|_| {
                    Mutex::new(Ring {
                        buf: VecDeque::with_capacity(capacity.min(1024)),
                        cap: capacity.max(1),
                        dropped: 0,
                    })
                })
                .collect(),
        }
    }

    /// Index of the frontend (submission-side) lane.
    pub fn frontend_lane(&self) -> u32 {
        (self.lanes.len() - 1) as u32
    }

    /// Set the sampling interval: record events whose `seq % every == 0`.
    /// `0` disables recording; `1` records everything.
    pub fn set_sampling(&self, every: u32) {
        self.sample_every.store(every, Ordering::Relaxed);
    }

    /// Whether an event with this correlation id should be recorded.
    /// This is the hot-path gate: one relaxed load, and statically false
    /// when the `trace` feature is compiled out.
    #[inline]
    pub fn sampled(&self, seq: u64) -> bool {
        if !cfg!(feature = "trace") {
            return false;
        }
        let every = self.sample_every.load(Ordering::Relaxed);
        every != 0 && seq % every as u64 == 0
    }

    /// Whether any recording is enabled at all — callers use this to skip
    /// clock reads and other span bookkeeping when tracing is idle.
    #[inline]
    pub fn active(&self) -> bool {
        cfg!(feature = "trace") && self.sample_every.load(Ordering::Relaxed) != 0
    }

    /// Host-clock nanoseconds since tracer creation — capture this
    /// before a stage to later record it as a span.
    #[inline]
    pub fn now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    /// Record an instant event (dur = 0) at the current time, if sampled.
    #[inline]
    pub fn instant(&self, lane: u32, stage: Stage, seq: u64, detail: u64) {
        if self.sampled(seq) {
            let ts = self.now_ns();
            self.push(lane, stage, seq, ts, 0, detail);
        }
    }

    /// Record a span that began at `start_ns` (from [`Tracer::now_ns`])
    /// and ends now, if sampled.
    #[inline]
    pub fn span(&self, lane: u32, stage: Stage, seq: u64, start_ns: u64, detail: u64) {
        if self.sampled(seq) {
            let now = self.now_ns();
            self.push(lane, stage, seq, start_ns, now.saturating_sub(start_ns), detail);
        }
    }

    /// Record an event with an explicit duration (used for simulated
    /// durations, e.g. copy cost), if sampled.
    #[inline]
    pub fn instant_with_dur(&self, lane: u32, stage: Stage, seq: u64, dur_ns: u64, detail: u64) {
        if self.sampled(seq) {
            let ts = self.now_ns();
            self.push(lane, stage, seq, ts, dur_ns, detail);
        }
    }

    fn push(&self, lane: u32, stage: Stage, seq: u64, ts_ns: u64, dur_ns: u64, detail: u64) {
        let lane_idx = (lane as usize).min(self.lanes.len() - 1);
        let ev = TraceEvent {
            ts_ns,
            dur_ns,
            lane,
            stage,
            seq,
            detail,
        };
        // Uncontended in steady state: each worker owns its lane.
        self.lanes[lane_idx].lock().unwrap().push(ev);
    }

    /// Merge every lane into one causally-ordered timeline (sorted by
    /// start timestamp, ties broken by lane then stage order). Buffers
    /// are snapshotted, not drained, so repeated collects are additive.
    pub fn collect(&self) -> Trace {
        let mut events = Vec::new();
        let mut dropped = 0u64;
        for lane in &self.lanes {
            let ring = lane.lock().unwrap();
            events.extend(ring.buf.iter().copied());
            dropped += ring.dropped;
        }
        events.sort_by_key(|e| (e.ts_ns, e.lane, e.stage));
        Trace {
            events,
            dropped,
            telemetry: Default::default(),
        }
    }
}

/// A merged fleet timeline: the `TraceSink` output.
#[derive(Debug, Clone)]
pub struct Trace {
    /// Events sorted by start timestamp.
    pub events: Vec<TraceEvent>,
    /// Events lost to ring overflow across all lanes (oldest-first).
    pub dropped: u64,
    /// Continuous-telemetry summary — disabled/all-zero unless a
    /// scenario-driven time-series recorder was attached via
    /// [`Trace::with_telemetry`] (tracer-only collections have no
    /// virtual-clock series).
    pub telemetry: super::TelemetrySummary,
}

/// Aggregate time attribution for one stage across a [`Trace`].
#[derive(Debug, Clone, Copy, Default)]
pub struct StageStats {
    pub count: u64,
    pub total_dur_ns: u64,
    pub max_dur_ns: u64,
}

impl Trace {
    /// Attach a continuous-telemetry summary to this trace (the scenario
    /// executor's recorder; see [`crate::obs::timeseries`]).
    pub fn with_telemetry(mut self, telemetry: super::TelemetrySummary) -> Self {
        self.telemetry = telemetry;
        self
    }

    /// Per-stage event counts and span-time attribution, in pipeline
    /// order; stages with no events are omitted.
    pub fn stage_breakdown(&self) -> Vec<(Stage, StageStats)> {
        let mut stats = [StageStats::default(); STAGES.len()];
        for ev in &self.events {
            let slot = STAGES.iter().position(|&s| s == ev.stage).unwrap();
            stats[slot].count += 1;
            stats[slot].total_dur_ns += ev.dur_ns;
            stats[slot].max_dur_ns = stats[slot].max_dur_ns.max(ev.dur_ns);
        }
        STAGES
            .iter()
            .zip(stats)
            .filter(|(_, s)| s.count > 0)
            .map(|(&st, s)| (st, s))
            .collect()
    }

    /// The `n` longest spans of `stage`, slowest first.
    pub fn slowest(&self, stage: Stage, n: usize) -> Vec<TraceEvent> {
        let mut evs: Vec<TraceEvent> = self
            .events
            .iter()
            .filter(|e| e.stage == stage)
            .copied()
            .collect();
        evs.sort_by_key(|e| std::cmp::Reverse(e.dur_ns));
        evs.truncate(n);
        evs
    }

    /// Chrome `trace_event` JSON (load in `chrome://tracing` or
    /// Perfetto): complete (`ph:"X"`) events, µs timestamps, one thread
    /// row per lane.
    pub fn to_chrome_json(&self) -> Json {
        let events = self
            .events
            .iter()
            .map(|e| {
                Json::obj()
                    .field("name", e.stage.name())
                    .field("ph", "X")
                    .field("ts", e.ts_ns as f64 / 1e3)
                    .field("dur", e.dur_ns as f64 / 1e3)
                    .field("pid", 0u64)
                    .field("tid", e.lane as u64)
                    .field(
                        "args",
                        Json::obj().field("seq", e.seq).field("detail", e.detail),
                    )
            })
            .collect::<Vec<_>>();
        Json::obj()
            .field("traceEvents", Json::Arr(events))
            .field("displayTimeUnit", "ns")
    }

    /// Trace summary as stable JSON (stage breakdown + slowest waves).
    pub fn summary_json(&self, top_n: usize) -> Json {
        let stages = self
            .stage_breakdown()
            .into_iter()
            .map(|(stage, s)| {
                Json::obj()
                    .field("stage", stage.name())
                    .field("count", s.count)
                    .field("total_dur_ns", s.total_dur_ns)
                    .field("max_dur_ns", s.max_dur_ns)
            })
            .collect::<Vec<_>>();
        let slowest = self
            .slowest(Stage::WaveExecute, top_n)
            .into_iter()
            .map(|e| {
                Json::obj()
                    .field("seq", e.seq)
                    .field("lane", e.lane as u64)
                    .field("ts_ns", e.ts_ns)
                    .field("dur_ns", e.dur_ns)
                    .field("waves", e.detail)
            })
            .collect::<Vec<_>>();
        Json::obj()
            .field("events", self.events.len())
            .field("dropped", self.dropped)
            .field("stages", Json::Arr(stages))
            .field("slowest_waves", Json::Arr(slowest))
            .field("telemetry", self.telemetry.to_json())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(lanes: usize, cap: usize) -> Tracer {
        let t = Tracer::new(lanes, cap);
        t.set_sampling(1);
        t
    }

    #[test]
    fn overflow_drops_oldest_without_corrupting_events() {
        let t = mk(1, 8);
        for seq in 0..20u64 {
            t.instant(0, Stage::Admit, seq, seq * 10);
        }
        let trace = t.collect();
        if cfg!(feature = "trace") {
            assert_eq!(trace.events.len(), 8, "ring must stay at capacity");
            assert_eq!(trace.dropped, 12);
            // the newest events survive, intact and in order
            let seqs: Vec<u64> = trace.events.iter().map(|e| e.seq).collect();
            assert_eq!(seqs, (12..20).collect::<Vec<_>>());
            for e in &trace.events {
                assert_eq!(e.detail, e.seq * 10, "payload corrupted: {e:?}");
                assert_eq!(e.stage, Stage::Admit);
            }
        } else {
            assert!(trace.events.is_empty());
        }
    }

    #[test]
    fn sampling_keys_on_seq_so_spans_stay_coherent() {
        let t = Tracer::new(2, 64);
        t.set_sampling(4);
        for seq in 0..16u64 {
            // two stages of the same request must sample identically
            t.instant(1, Stage::Admit, seq, 0);
            t.instant(0, Stage::WaveExecute, seq, 0);
        }
        let trace = t.collect();
        if cfg!(feature = "trace") {
            // seqs 0,4,8,12 × 2 stages
            assert_eq!(trace.events.len(), 8);
            for e in &trace.events {
                assert_eq!(e.seq % 4, 0);
            }
            let admits = trace.events.iter().filter(|e| e.stage == Stage::Admit).count();
            assert_eq!(admits, 4);
        }
        // sampling off → nothing records, regardless of feature
        t.set_sampling(0);
        t.instant(0, Stage::Admit, 0, 0);
        assert_eq!(t.collect().events.len(), trace.events.len());
    }

    #[test]
    fn collect_merges_lanes_in_timestamp_order() {
        let t = mk(3, 16);
        for i in 0..12u64 {
            t.instant((i % 3) as u32, Stage::Drain, i, 0);
        }
        let trace = t.collect();
        if cfg!(feature = "trace") {
            assert_eq!(trace.events.len(), 12);
            for w in trace.events.windows(2) {
                assert!(w[0].ts_ns <= w[1].ts_ns, "timeline out of order");
            }
        }
    }

    #[test]
    fn stage_breakdown_and_slowest() {
        let t = mk(1, 32);
        let s0 = t.now_ns();
        t.span(0, Stage::WaveExecute, 1, s0, 3);
        t.instant_with_dur(0, Stage::Copy, 2, 500, 4096);
        t.instant(0, Stage::Admit, 3, 0);
        let trace = t.collect();
        if cfg!(feature = "trace") {
            let bd = trace.stage_breakdown();
            let names: Vec<&str> = bd.iter().map(|(s, _)| s.name()).collect();
            // pipeline order, empty stages omitted
            assert_eq!(names, vec!["admit", "wave_execute", "copy"]);
            let copy = bd.iter().find(|(s, _)| *s == Stage::Copy).unwrap().1;
            assert_eq!(copy.total_dur_ns, 500);
            let top = trace.slowest(Stage::WaveExecute, 5);
            assert_eq!(top.len(), 1);
            assert_eq!(top[0].detail, 3);
            // chrome export shape
            let chrome = trace.to_chrome_json();
            let evs = chrome.get("traceEvents").unwrap().as_arr().unwrap();
            assert_eq!(evs.len(), 3);
            assert_eq!(evs[0].get("ph").unwrap().as_str(), Some("X"));
            // summary json is parseable and carries the stage table
            let summary = trace.summary_json(3).to_string_compact();
            let parsed = super::super::json::Json::parse(&summary).unwrap();
            assert_eq!(parsed.get("events").unwrap().as_f64(), Some(3.0));
        }
    }

    #[test]
    fn disabled_tracer_records_nothing() {
        let t = Tracer::new(2, 8);
        // sample_every defaults to 0 → off
        assert!(!t.sampled(0));
        t.instant(0, Stage::Admit, 0, 0);
        assert!(t.collect().events.is_empty());
    }
}
