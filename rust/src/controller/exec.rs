//! Program execution engine: AAP dispatch + cycle/energy accounting.

use crate::dram::command::RowId;
use crate::dram::{Bank, DramGeometry, TimingParams};
use crate::energy::EnergyModel;
use crate::isa::program::{self, BulkOp};
use crate::isa::{AapInstr, Program};
use crate::util::bitrow::BitRow;

use super::enables;

/// Scratch data rows the controller reserves for multi-plane carry/borrow
/// chaining (ping-pong). Data rows 0..496 remain allocatable.
pub const SCRATCH0: RowId = RowId::Data(496);
pub const SCRATCH1: RowId = RowId::Data(497);

/// Cycle/energy accounting for a stretch of execution.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ExecStats {
    pub aaps: u64,
    pub time_ns: f64,
    pub energy_pj: f64,
}

impl ExecStats {
    pub fn accumulate(&mut self, other: ExecStats) {
        self.aaps += other.aaps;
        self.time_ns += other.time_ns;
        self.energy_pj += other.energy_pj;
    }
}

/// The DRIM memory controller: owns the banks and executes AAP programs
/// against (bank, sub-array) targets.
pub struct Controller {
    pub geometry: DramGeometry,
    pub banks: Vec<Bank>,
    pub timing: TimingParams,
    pub energy: EnergyModel,
    /// cumulative since construction
    pub total: ExecStats,
}

impl Controller {
    pub fn new(geometry: DramGeometry) -> Self {
        let banks = (0..geometry.banks).map(|_| Bank::new(&geometry)).collect();
        Controller {
            geometry,
            banks,
            timing: TimingParams::default(),
            energy: EnergyModel::default(),
            total: ExecStats::default(),
        }
    }

    /// Host-side load of a data row (through the global row buffer).
    pub fn write_row(&mut self, bank: usize, sa: usize, row: RowId, v: &BitRow) {
        self.banks[bank].subarray_mut(sa).write_row(row, v);
    }

    pub fn read_row(&self, bank: usize, sa: usize, row: RowId) -> BitRow {
        self.banks[bank].subarray(sa).read_row(row)
    }

    /// Execute one AAP: drive the Table 1 enables for its kind, run the
    /// charge-sharing primitive, account time and energy.
    pub fn step(&mut self, bank: usize, sa: usize, instr: &AapInstr) -> ExecStats {
        let kind = instr.kind();
        // the SA mode the ctrl selects for this primitive (Table 1); the
        // functional sub-array derives the same mode from the activation
        // arity — asserted equivalent in tests
        let _en = enables::enable_bits(kind);
        self.banks[bank].subarray_mut(sa).execute_aap(
            kind,
            &instr.sources(),
            &instr.dests(),
        );
        let s = ExecStats {
            aaps: 1,
            time_ns: self.timing.t_aap_ns,
            energy_pj: self.energy.aap_pj(kind, self.geometry.cols),
        };
        self.total.accumulate(s);
        s
    }

    /// Execute a straight-line program on one sub-array.
    pub fn run_program(&mut self, bank: usize, sa: usize, p: &Program) -> ExecStats {
        let mut stats = ExecStats::default();
        for i in &p.instrs {
            stats.accumulate(self.step(bank, sa, i));
        }
        stats
    }

    /// Single-result-row bulk op (everything except Add/Sub).
    pub fn exec_op(
        &mut self,
        op: BulkOp,
        bank: usize,
        sa: usize,
        srcs: &[RowId],
        dest: RowId,
    ) -> ExecStats {
        assert!(!matches!(op, BulkOp::Add | BulkOp::Sub), "use add_planes/sub_planes");
        assert_eq!(srcs.len(), op.arity());
        let p = op.program(srcs, &[dest]);
        self.run_program(bank, sa, &p)
    }

    /// Multi-plane ripple-carry addition: `sum = a + b` over bit-plane rows
    /// (LSB first), carry chained through the scratch rows; the final
    /// carry-out lands in `carry_out`.
    ///
    /// This is the paper's In-Memory Adder (§3.1) iterated by the ctrl:
    /// per plane, Sum via two DRA XOR2s and carry via one TRA (Table 2).
    pub fn add_planes(
        &mut self,
        bank: usize,
        sa: usize,
        a: &[RowId],
        b: &[RowId],
        sum: &[RowId],
        carry_out: RowId,
    ) -> ExecStats {
        assert_eq!(a.len(), b.len());
        assert_eq!(a.len(), sum.len());
        assert!(!a.is_empty());
        let mut stats = ExecStats::default();
        let mut carry_in = program::CTRL_ZEROS;
        for i in 0..a.len() {
            let cout = if i == a.len() - 1 {
                carry_out
            } else if carry_in == SCRATCH0 {
                SCRATCH1
            } else {
                SCRATCH0
            };
            let p = program::full_adder(a[i], b[i], carry_in, sum[i], cout);
            stats.accumulate(self.run_program(bank, sa, &p));
            carry_in = cout;
        }
        stats
    }

    /// Multi-plane subtraction `diff = a - b` (two's complement: borrow-in
    /// seeded from the ones control row).
    pub fn sub_planes(
        &mut self,
        bank: usize,
        sa: usize,
        a: &[RowId],
        b: &[RowId],
        diff: &[RowId],
        borrow_out: RowId,
    ) -> ExecStats {
        assert_eq!(a.len(), b.len());
        assert_eq!(a.len(), diff.len());
        assert!(!a.is_empty());
        let mut stats = ExecStats::default();
        let mut carry_in = program::CTRL_ONES; // +1 of the two's complement
        for i in 0..a.len() {
            let cout = if i == a.len() - 1 {
                borrow_out
            } else if carry_in == SCRATCH0 {
                SCRATCH1
            } else {
                SCRATCH0
            };
            let p = program::full_subtractor(a[i], b[i], carry_in, diff[i], cout);
            stats.accumulate(self.run_program(bank, sa, &p));
            carry_in = cout;
        }
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dram::command::RowId::*;
    use crate::util::rng::Rng;

    fn tiny() -> Controller {
        Controller::new(DramGeometry::tiny())
    }

    fn rand_row(c: &Controller, seed: u64) -> BitRow {
        BitRow::random(c.geometry.cols, &mut Rng::new(seed))
    }

    #[test]
    fn xnor_op_end_to_end() {
        let mut c = tiny();
        let (a, b) = (rand_row(&c, 1), rand_row(&c, 2));
        c.write_row(0, 0, Data(0), &a);
        c.write_row(0, 0, Data(1), &b);
        let s = c.exec_op(BulkOp::Xnor2, 0, 0, &[Data(0), Data(1)], Data(2));
        assert_eq!(s.aaps, 3); // Table 2
        assert!((s.time_ns - 270.0).abs() < 1e-9);
        let mut want = BitRow::zeros(c.geometry.cols);
        want.apply2(&a, &b, |x, y| !(x ^ y));
        assert_eq!(c.read_row(0, 0, Data(2)), want);
    }

    #[test]
    fn every_logic_op_matches_word_semantics() {
        let mut c = tiny();
        let (a, b, k) = (rand_row(&c, 3), rand_row(&c, 4), rand_row(&c, 5));
        for op in [
            BulkOp::Copy,
            BulkOp::Not,
            BulkOp::Xnor2,
            BulkOp::Xor2,
            BulkOp::And2,
            BulkOp::Or2,
            BulkOp::Nand2,
            BulkOp::Nor2,
            BulkOp::Maj3,
            BulkOp::Min3,
        ] {
            c.write_row(0, 1, Data(0), &a);
            c.write_row(0, 1, Data(1), &b);
            c.write_row(0, 1, Data(2), &k);
            let srcs: Vec<RowId> = [Data(0), Data(1), Data(2)][..op.arity()].to_vec();
            c.exec_op(op, 0, 1, &srcs, Data(3));
            let got = c.read_row(0, 1, Data(3));
            let mut want = BitRow::zeros(c.geometry.cols);
            match op {
                BulkOp::Copy => want.copy_from(&a),
                BulkOp::Not => want.not_from(&a),
                BulkOp::Xnor2 => want.apply2(&a, &b, |x, y| !(x ^ y)),
                BulkOp::Xor2 => want.apply2(&a, &b, |x, y| x ^ y),
                BulkOp::And2 => want.apply2(&a, &b, |x, y| x & y),
                BulkOp::Or2 => want.apply2(&a, &b, |x, y| x | y),
                BulkOp::Nand2 => want.apply2(&a, &b, |x, y| !(x & y)),
                BulkOp::Nor2 => want.apply2(&a, &b, |x, y| !(x | y)),
                BulkOp::Maj3 => {
                    want.apply3(&a, &b, &k, |x, y, z| (x & y) | (x & z) | (y & z))
                }
                BulkOp::Min3 => {
                    want.apply3(&a, &b, &k, |x, y, z| !((x & y) | (x & z) | (y & z)))
                }
                _ => unreachable!(),
            }
            assert_eq!(got, want, "op {}", op.name());
        }
    }

    #[test]
    fn add_planes_adds_integers() {
        let mut c = tiny();
        let bits = 8;
        let n = c.geometry.cols; // one element per bit-line
        let mut rng = Rng::new(9);
        let av: Vec<u16> = (0..n).map(|_| (rng.below(256)) as u16).collect();
        let bv: Vec<u16> = (0..n).map(|_| (rng.below(256)) as u16).collect();
        // plane i = bit i of every element
        let (mut ar, mut br, mut sr) = (vec![], vec![], vec![]);
        for i in 0..bits {
            let mut pa = BitRow::zeros(n);
            let mut pb = BitRow::zeros(n);
            for e in 0..n {
                pa.set(e, (av[e] >> i) & 1 == 1);
                pb.set(e, (bv[e] >> i) & 1 == 1);
            }
            c.write_row(1, 0, Data(10 + i as u16), &pa);
            c.write_row(1, 0, Data(30 + i as u16), &pb);
            ar.push(Data(10 + i as u16));
            br.push(Data(30 + i as u16));
            sr.push(Data(50 + i as u16));
        }
        let stats = c.add_planes(1, 0, &ar, &br, &sr, Data(70));
        assert_eq!(stats.aaps, 7 * bits as u64); // Table 2: 7 AAPs per slice
        let carry = c.read_row(1, 0, Data(70));
        for e in 0..n {
            let want = av[e] as u32 + bv[e] as u32;
            let mut got = 0u32;
            for (i, s) in sr.iter().enumerate() {
                got |= (c.read_row(1, 0, *s).get(e) as u32) << i;
            }
            got |= (carry.get(e) as u32) << bits;
            assert_eq!(got, want, "element {e}");
        }
    }

    #[test]
    fn sub_planes_subtracts_integers() {
        let mut c = tiny();
        let bits = 8;
        let n = c.geometry.cols;
        let mut rng = Rng::new(10);
        let av: Vec<u16> = (0..n).map(|_| (rng.below(256)) as u16).collect();
        let bv: Vec<u16> = (0..n).map(|_| (rng.below(256)) as u16).collect();
        let (mut ar, mut br, mut dr) = (vec![], vec![], vec![]);
        for i in 0..bits {
            let mut pa = BitRow::zeros(n);
            let mut pb = BitRow::zeros(n);
            for e in 0..n {
                pa.set(e, (av[e] >> i) & 1 == 1);
                pb.set(e, (bv[e] >> i) & 1 == 1);
            }
            c.write_row(0, 0, Data(10 + i as u16), &pa);
            c.write_row(0, 0, Data(30 + i as u16), &pb);
            ar.push(Data(10 + i as u16));
            br.push(Data(30 + i as u16));
            dr.push(Data(50 + i as u16));
        }
        c.sub_planes(0, 0, &ar, &br, &dr, Data(70));
        for e in 0..n {
            let want = (av[e] as i32 - bv[e] as i32).rem_euclid(256) as u32;
            let mut got = 0u32;
            for (i, d) in dr.iter().enumerate() {
                got |= (c.read_row(0, 0, *d).get(e) as u32) << i;
            }
            assert_eq!(got, want, "element {e}: {} - {}", av[e], bv[e]);
        }
    }

    #[test]
    fn stats_accumulate_globally() {
        let mut c = tiny();
        let a = rand_row(&c, 11);
        c.write_row(0, 0, Data(0), &a);
        c.exec_op(BulkOp::Not, 0, 0, &[Data(0)], Data(1));
        c.exec_op(BulkOp::Copy, 0, 0, &[Data(1)], Data(2));
        assert_eq!(c.total.aaps, 3);
        assert!((c.total.time_ns - 270.0).abs() < 1e-9);
        assert!(c.total.energy_pj > 0.0);
    }

    #[test]
    fn energy_of_xnor_below_tra_composed_and() {
        // DRA's whole point: X(N)OR2 costs less than TRA-composed ops
        let mut c = tiny();
        let (a, b) = (rand_row(&c, 12), rand_row(&c, 13));
        c.write_row(0, 0, Data(0), &a);
        c.write_row(0, 0, Data(1), &b);
        let xnor = c.exec_op(BulkOp::Xnor2, 0, 0, &[Data(0), Data(1)], Data(2));
        let and = c.exec_op(BulkOp::And2, 0, 0, &[Data(0), Data(1)], Data(3));
        assert!(xnor.energy_pj < and.energy_pj);
        assert!(xnor.time_ns < and.time_ns);
    }
}
