//! Virtual-address translation for DRIM instructions (paper §4 "Virtual
//! Memory"): the memory controller intercepts instructions written to the
//! DRIM instruction registers and translates their virtual row addresses
//! to physical rows *before* issue — the near-memory-controller
//! translation path the paper recommends over giving DRIM a page-table
//! walker (the page table may span DIMMs; coherence on it is hard).
//!
//! The §4 constraint is enforced here: "some operations are appropriate
//! only if the resulting physical addresses are within specific plane,
//! e.g., within the same bank" — for AAP operands, the same *sub-array*
//! (they must share bit-lines). Violations are reported, mirroring the
//! compiler/OS contract the paper describes.

use std::collections::BTreeMap;

use crate::dram::geometry::{DramGeometry, PhysAddr};

/// A virtual row number (one page = one DRAM row in this model).
pub type VRow = u64;

#[derive(Debug, PartialEq)]
pub enum TranslateError {
    Unmapped(VRow),
    /// operands landed in different sub-arrays — illegal for one AAP
    PlaneMismatch { a: PhysAddr, b: PhysAddr },
}

impl std::fmt::Display for TranslateError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TranslateError::Unmapped(v) => write!(f, "virtual row {v} unmapped"),
            TranslateError::PlaneMismatch { a, b } => write!(
                f,
                "operands map to different sub-arrays: {a:?} vs {b:?} \
                 (the OS/compiler must co-locate AAP operands — paper §4)"
            ),
        }
    }
}

/// Controller-resident page table: virtual row → physical row.
#[derive(Debug, Default)]
pub struct PageTable {
    map: BTreeMap<VRow, PhysAddr>,
}

impl PageTable {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn map(&mut self, v: VRow, p: PhysAddr) {
        self.map.insert(v, p);
    }

    pub fn unmap(&mut self, v: VRow) -> Option<PhysAddr> {
        self.map.remove(&v)
    }

    pub fn translate(&self, v: VRow) -> Result<PhysAddr, TranslateError> {
        self.map
            .get(&v)
            .copied()
            .ok_or(TranslateError::Unmapped(v))
    }

    /// Translate the operand set of one DRIM instruction, enforcing the
    /// same-sub-array plane constraint.
    pub fn translate_operands(
        &self,
        vrows: &[VRow],
    ) -> Result<Vec<PhysAddr>, TranslateError> {
        let phys: Vec<PhysAddr> = vrows
            .iter()
            .map(|&v| self.translate(v))
            .collect::<Result<_, _>>()?;
        for w in phys.windows(2) {
            if (w[0].bank, w[0].subarray) != (w[1].bank, w[1].subarray) {
                return Err(TranslateError::PlaneMismatch { a: w[0], b: w[1] });
            }
        }
        Ok(phys)
    }

    /// OS-side allocation helper implementing the paper's contract: map a
    /// contiguous virtual range so all rows share one sub-array (returns
    /// None if the range doesn't fit a sub-array's data space).
    pub fn map_colocated(
        &mut self,
        g: &DramGeometry,
        base: VRow,
        rows: usize,
        bank: usize,
        subarray: usize,
        first_row: usize,
    ) -> Option<()> {
        if first_row + rows > crate::controller::alloc::ALLOCATABLE_ROWS as usize {
            return None;
        }
        debug_assert!(bank < g.banks && subarray < g.subarrays_per_bank);
        for i in 0..rows {
            self.map(
                base + i as u64,
                PhysAddr {
                    bank,
                    subarray,
                    row: first_row + i,
                },
            );
        }
        Some(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pa(bank: usize, subarray: usize, row: usize) -> PhysAddr {
        PhysAddr {
            bank,
            subarray,
            row,
        }
    }

    #[test]
    fn translate_roundtrip() {
        let mut pt = PageTable::new();
        pt.map(100, pa(1, 2, 3));
        assert_eq!(pt.translate(100), Ok(pa(1, 2, 3)));
        assert_eq!(pt.translate(101), Err(TranslateError::Unmapped(101)));
        pt.unmap(100);
        assert!(pt.translate(100).is_err());
    }

    #[test]
    fn colocated_operands_pass_plane_check() {
        let mut pt = PageTable::new();
        pt.map(0, pa(0, 4, 10));
        pt.map(1, pa(0, 4, 11));
        pt.map(2, pa(0, 4, 12));
        let phys = pt.translate_operands(&[0, 1, 2]).unwrap();
        assert_eq!(phys.len(), 3);
    }

    #[test]
    fn cross_subarray_operands_rejected() {
        let mut pt = PageTable::new();
        pt.map(0, pa(0, 4, 10));
        pt.map(1, pa(0, 5, 10));
        match pt.translate_operands(&[0, 1]) {
            Err(TranslateError::PlaneMismatch { .. }) => {}
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn map_colocated_respects_reserved_rows() {
        let g = DramGeometry::tiny();
        let mut pt = PageTable::new();
        // fits
        assert!(pt.map_colocated(&g, 0, 10, 0, 0, 0).is_some());
        // would spill into scratch/control rows
        assert!(pt.map_colocated(&g, 100, 10, 0, 0, 490).is_none());
        let phys = pt.translate_operands(&[0, 5, 9]).unwrap();
        assert!(phys.iter().all(|p| p.bank == 0 && p.subarray == 0));
    }
}
