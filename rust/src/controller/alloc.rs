//! Row allocator: places operand/result rows so that every computation's
//! rows are co-located in one sub-array (paper §4 "Memory Layout and
//! Interleaving" — DRIM maximizes spatial locality instead of channel
//! interleaving; operands of an AAP must share bit-lines).

use crate::dram::geometry::DramGeometry;
use crate::dram::command::RowId;
use crate::isa::program::{FIRST_FREE_DATA_ROW, LAST_FREE_DATA_ROW};

/// Rows 496/497 are controller scratch (carry chain), 498/499 control rows.
pub const ALLOCATABLE_ROWS: u16 = 496;

/// A group of co-located row allocations inside one sub-array.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RowGroup {
    pub bank: usize,
    pub subarray: usize,
    pub rows: Vec<RowId>,
}

#[derive(Clone, Debug)]
struct SubFree {
    free: Vec<u16>, // stack of free data-row indices
}

/// Free-list allocator over every (bank, sub-array) in the device.
pub struct RowAllocator {
    geometry: DramGeometry,
    state: Vec<SubFree>, // bank-major
    /// round-robin cursor so groups spread across sub-arrays (parallelism)
    cursor: usize,
}

impl RowAllocator {
    pub fn new(geometry: DramGeometry) -> Self {
        let per = geometry.banks * geometry.subarrays_per_bank;
        let fresh = SubFree {
            free: (FIRST_FREE_DATA_ROW..ALLOCATABLE_ROWS.min(LAST_FREE_DATA_ROW))
                .rev()
                .collect(),
        };
        RowAllocator {
            geometry,
            state: vec![fresh; per],
            cursor: 0,
        }
    }

    fn idx(&self, bank: usize, sa: usize) -> usize {
        bank * self.geometry.subarrays_per_bank + sa
    }

    pub fn free_rows_in(&self, bank: usize, sa: usize) -> usize {
        self.state[self.idx(bank, sa)].free.len()
    }

    /// Allocate `n` rows together in one sub-array, round-robin across the
    /// device. Returns None when no sub-array has `n` free rows.
    pub fn alloc_group(&mut self, n: usize) -> Option<RowGroup> {
        let total = self.state.len();
        for probe in 0..total {
            let i = (self.cursor + probe) % total;
            if self.state[i].free.len() >= n {
                let rows: Vec<RowId> = (0..n)
                    .map(|_| RowId::Data(self.state[i].free.pop().unwrap()))
                    .collect();
                self.cursor = (i + 1) % total;
                let bank = i / self.geometry.subarrays_per_bank;
                let subarray = i % self.geometry.subarrays_per_bank;
                return Some(RowGroup {
                    bank,
                    subarray,
                    rows,
                });
            }
        }
        None
    }

    /// Allocate `n` rows in a *specific* sub-array (e.g. to co-locate with
    /// existing operands).
    pub fn alloc_in(&mut self, bank: usize, sa: usize, n: usize) -> Option<Vec<RowId>> {
        let i = self.idx(bank, sa);
        if self.state[i].free.len() < n {
            return None;
        }
        Some(
            (0..n)
                .map(|_| RowId::Data(self.state[i].free.pop().unwrap()))
                .collect(),
        )
    }

    /// Return rows to the free list.
    pub fn free_group(&mut self, g: &RowGroup) {
        let i = self.idx(g.bank, g.subarray);
        for r in &g.rows {
            if let RowId::Data(d) = r {
                debug_assert!(
                    !self.state[i].free.contains(d),
                    "double free of {r} in bank {} sa {}",
                    g.bank,
                    g.subarray
                );
                self.state[i].free.push(*d);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn groups_are_colocated_and_disjoint() {
        let mut a = RowAllocator::new(DramGeometry::tiny());
        let g1 = a.alloc_group(10).unwrap();
        let g2 = a.alloc_group(10).unwrap();
        assert_eq!(g1.rows.len(), 10);
        // round-robin: second group goes to a different sub-array
        assert_ne!((g1.bank, g1.subarray), (g2.bank, g2.subarray));
        let mut all: Vec<_> = g1.rows.clone();
        all.extend(g2.rows.clone());
        // distinctness within each sub-array group
        let mut r1 = g1.rows.clone();
        r1.sort();
        r1.dedup();
        assert_eq!(r1.len(), 10);
    }

    #[test]
    fn exhaustion_returns_none_then_free_restores() {
        let g = DramGeometry::tiny();
        let cap = g.banks * g.subarrays_per_bank * ALLOCATABLE_ROWS as usize;
        let mut a = RowAllocator::new(g);
        let mut groups = Vec::new();
        while let Some(grp) = a.alloc_group(100) {
            groups.push(grp);
        }
        assert!(groups.len() * 100 <= cap);
        assert!(a.alloc_group(100).is_none());
        for g in &groups {
            a.free_group(g);
        }
        assert!(a.alloc_group(100).is_some());
    }

    #[test]
    fn alloc_in_respects_subarray() {
        let mut a = RowAllocator::new(DramGeometry::tiny());
        let rows = a.alloc_in(1, 1, 5).unwrap();
        assert_eq!(rows.len(), 5);
        assert_eq!(a.free_rows_in(1, 1), ALLOCATABLE_ROWS as usize - 5);
        assert_eq!(a.free_rows_in(0, 0), ALLOCATABLE_ROWS as usize);
    }

    #[test]
    fn never_hands_out_reserved_rows() {
        prop::check("no_reserved_rows", 50, |rng| {
            let mut a = RowAllocator::new(DramGeometry::tiny());
            let n = 1 + rng.below(64) as usize;
            for _ in 0..8 {
                if let Some(g) = a.alloc_group(n) {
                    for r in &g.rows {
                        if let RowId::Data(d) = r {
                            if *d >= ALLOCATABLE_ROWS {
                                return Err(format!("reserved row {r} allocated"));
                            }
                        } else {
                            return Err(format!("non-data row {r} allocated"));
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn no_row_allocated_twice_property() {
        prop::check("no_double_alloc", 30, |rng| {
            let mut a = RowAllocator::new(DramGeometry::tiny());
            let mut live: std::collections::HashSet<(usize, usize, RowId)> =
                Default::default();
            let mut groups = Vec::new();
            for _ in 0..50 {
                if rng.bool() || groups.is_empty() {
                    let n = 1 + rng.below(20) as usize;
                    if let Some(g) = a.alloc_group(n) {
                        for r in &g.rows {
                            if !live.insert((g.bank, g.subarray, *r)) {
                                return Err(format!("row {r} double-allocated"));
                            }
                        }
                        groups.push(g);
                    }
                } else {
                    let i = rng.below(groups.len() as u64) as usize;
                    let g = groups.swap_remove(i);
                    for r in &g.rows {
                        live.remove(&(g.bank, g.subarray, *r));
                    }
                    a.free_group(&g);
                }
            }
            Ok(())
        });
    }
}
