//! Enable-signal generation — Table 1, as the controller drives it.
//!
//! The ctrl generates the three SA enable bits from the decoded AAP kind
//! with 6-transistor MUX units (accounted in `subarray::area`). This module
//! is the single source of truth for Table 1; the CLI prints it and tests
//! assert it against `subarray::sense`.

use crate::dram::command::AapKind;
use crate::subarray::sense::{EnableBits, SenseMode};

/// SA mode for each AAP kind during the *source* activation phase.
pub fn sense_mode(kind: AapKind) -> SenseMode {
    match kind {
        // W/R, Copy (incl. NOT through DCC), TRA → conventional path
        AapKind::Copy | AapKind::DoubleCopy | AapKind::Tra => SenseMode::Conventional,
        AapKind::Dra => SenseMode::Dra,
    }
}

pub fn enable_bits(kind: AapKind) -> EnableBits {
    sense_mode(kind).enables()
}

/// Render Table 1 exactly as the paper prints it.
pub fn table1() -> String {
    let c = SenseMode::Conventional.enables();
    let d = SenseMode::Dra.enables();
    let b = |x: bool| if x { "1" } else { "0" };
    format!(
        "In-memory operations      | EN_M | EN_x | EN_C\n\
         --------------------------+------+------+-----\n\
         W/R - Copy - NOT - TRA    |  {}   |  {}   |  {}\n\
         DRA                       |  {}   |  {}   |  {}\n",
        b(c.en_m),
        b(c.en_x),
        b(c.en_c),
        b(d.en_m),
        b(d.en_x),
        b(d.en_c),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn enables_table() {
        // Table 1: W/R-Copy-NOT-TRA → (1,1,0); DRA → (0,1,1)
        let c = enable_bits(AapKind::Copy);
        assert_eq!((c.en_m, c.en_x, c.en_c), (true, true, false));
        let t = enable_bits(AapKind::Tra);
        assert_eq!((t.en_m, t.en_x, t.en_c), (true, true, false));
        let d = enable_bits(AapKind::Dra);
        assert_eq!((d.en_m, d.en_x, d.en_c), (false, true, true));
    }

    #[test]
    fn table1_renders_both_rows() {
        let t = table1();
        assert!(t.contains("W/R - Copy - NOT - TRA    |  1   |  1   |  0"));
        assert!(t.contains("DRA                       |  0   |  1   |  1"));
    }
}
