//! The DRIM controller (paper Fig. 3 "Ctrl"): decodes AAP programs into
//! sub-array operations, drives the Table 1 enable signals, allocates data
//! rows, and accounts cycles + energy.

pub mod alloc;
pub mod enables;
pub mod exec;
pub mod translate;

pub use alloc::RowAllocator;
pub use exec::{Controller, ExecStats};
