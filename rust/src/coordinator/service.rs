//! The DRIM service: worker threads executing chunk jobs on their own bank
//! slices, a shared queue with dynamic batching, and response reassembly.
//!
//! Leader/worker layout: `submit` (leader side) shards a request into row
//! chunks and enqueues them; each worker owns an independent `Controller`
//! over a slice of the device's banks and processes chunks by streaming
//! them through staging rows (load operands → run the Table 2 program →
//! read the result row). A per-request collector thread reassembles chunk
//! results in order and computes the simulated batch latency from the
//! router's wave model.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::controller::{Controller, ExecStats};
use crate::dram::command::RowId;
use crate::dram::geometry::DramGeometry;
use crate::isa::program::BulkOp;
use crate::util::bitrow::BitRow;

use super::metrics::Metrics;
use super::request::{BulkRequest, BulkResponse, Payload};
use super::router::{BatchPolicy, Router, ServiceConfig};

/// Staging rows used by the streaming path (outside the allocator range is
/// unnecessary — streaming rows are scratch and recycled per chunk).
const STAGE_A: RowId = RowId::Data(0);
const STAGE_B: RowId = RowId::Data(1);
const STAGE_C: RowId = RowId::Data(2);
const STAGE_R: RowId = RowId::Data(3);
/// Plane staging base rows for add32 (32 planes each).
const PLANES_A: u16 = 8;
const PLANES_B: u16 = 40;
const PLANES_S: u16 = 72;
const PLANE_CARRY: RowId = RowId::Data(104);

/// One schedulable unit of work: a group of row chunks (grouping amortizes
/// queue/lock traffic — §Perf iteration 2 in EXPERIMENTS.md).
struct ChunkJob {
    op: BulkOp,
    operands: Vec<BitRow>,
    chunk_idx: usize,
    /// elements for add32 chunks (bits for bit-wise)
    add32: bool,
}

enum Job {
    Group {
        chunks: Vec<ChunkJob>,
        reply: Sender<(usize, BitRow, ExecStats)>,
    },
    Stop,
}

/// Chunks per queue message.
const JOB_GROUP: usize = 16;

/// Latency attribution of one request within its wave set. A solo request
/// owns its wave set (`record_sim_ns == sim_latency_ns`, `batched_with ==
/// 1`); a coalesced request reports the shared wave set's completion, and
/// exactly one member of the batch advances the device makespan counter.
#[derive(Clone, Copy, Debug)]
struct Attribution {
    /// simulated completion reported in the response (and the latency
    /// summary)
    sim_latency_ns: f64,
    /// contribution to the device's cumulative `sim_ns` makespan counter
    record_sim_ns: f64,
    /// requests sharing the wave set (≥ 1)
    batched_with: usize,
}

pub struct DrimService {
    cfg: ServiceConfig,
    router: Router,
    tx: Sender<Job>,
    workers: Vec<JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
    next_id: AtomicU64,
}

impl DrimService {
    pub fn new(cfg: ServiceConfig) -> Self {
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let metrics = Arc::new(Metrics::new());
        let mut workers = Vec::new();
        let banks_per_worker =
            (cfg.geometry.banks / cfg.workers.max(1)).max(1);
        for _ in 0..cfg.workers.max(1) {
            let rx = Arc::clone(&rx);
            let metrics = Arc::clone(&metrics);
            let g = DramGeometry {
                banks: banks_per_worker,
                ..cfg.geometry.clone()
            };
            workers.push(std::thread::spawn(move || worker_loop(g, rx, metrics)));
        }
        let router = Router::new(cfg.clone());
        DrimService {
            cfg,
            router,
            tx,
            workers,
            metrics,
            next_id: AtomicU64::new(1),
        }
    }

    pub fn with_default_config() -> Self {
        Self::new(ServiceConfig::default())
    }

    pub fn config(&self) -> &ServiceConfig {
        &self.cfg
    }

    /// Submit a request; returns a receiver for the response.
    pub fn submit(&self, req: BulkRequest) -> Receiver<BulkResponse> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let (done_tx, done_rx) = channel();
        let units = req.wave_units(self.cfg.geometry.cols);
        let plan = self.router.plan(&[units]);
        self.metrics
            .record_waves(plan.waves, plan.slots_filled, plan.slots_total);
        let latency = self.router.sim_latency_ns(req.op, &[units]);
        self.dispatch(
            id,
            req,
            done_tx,
            Attribution {
                sim_latency_ns: latency,
                record_sim_ns: latency,
                batched_with: 1,
            },
        );
        done_rx
    }

    /// Submit a group of same-op requests that execute as *one*
    /// co-scheduled wave set: chunks from every request pack into shared
    /// waves, each response reports the wave set's completion as its
    /// simulated latency (the coalesced attribution — not a private
    /// `ceil(chunks/slots)` round-up), the device's makespan advances by
    /// the shared wave time exactly once, and `batched_with` tells each
    /// caller how many requests shared the set. Receivers are returned in
    /// request order. A mixed-op or single-request batch degrades to
    /// per-request submission.
    pub fn submit_batch(&self, reqs: Vec<BulkRequest>) -> Vec<Receiver<BulkResponse>> {
        let same_op = reqs.windows(2).all(|w| w[0].op == w[1].op);
        // An Immediate-policy device never shares waves: under that router
        // the "shared" latency would be the SUM of every member's private
        // round-up — so degrade to honest per-request attribution.
        if reqs.len() <= 1 || !same_op || self.cfg.policy == BatchPolicy::Immediate {
            return reqs.into_iter().map(|r| self.submit(r)).collect();
        }
        let cols = self.cfg.geometry.cols;
        let op = reqs[0].op;
        let counts: Vec<usize> = reqs.iter().map(|r| r.wave_units(cols)).collect();
        let plan = self.router.plan(&counts);
        self.metrics
            .record_waves(plan.waves, plan.slots_filled, plan.slots_total);
        let shared = self.router.sim_latency_ns(op, &counts);
        let batched_with = reqs.len();
        reqs.into_iter()
            .enumerate()
            .map(|(i, req)| {
                let id = self.next_id.fetch_add(1, Ordering::Relaxed);
                let (done_tx, done_rx) = channel();
                self.dispatch(
                    id,
                    req,
                    done_tx,
                    Attribution {
                        sim_latency_ns: shared,
                        // the batch's wave time advances the makespan once
                        record_sim_ns: if i == 0 { shared } else { 0.0 },
                        batched_with,
                    },
                );
                done_rx
            })
            .collect()
    }

    /// Submit and wait.
    pub fn run(&self, req: BulkRequest) -> BulkResponse {
        self.submit(req).recv().expect("service dropped")
    }

    fn dispatch(
        &self,
        id: u64,
        req: BulkRequest,
        done: Sender<BulkResponse>,
        attr: Attribution,
    ) {
        match (&req.op, &req.operands[0]) {
            (BulkOp::Add | BulkOp::Sub, Payload::U32(_)) => {
                self.submit_add32(id, req, done, attr)
            }
            _ => self.submit_bitwise(id, req, done, attr),
        }
    }

    fn submit_bitwise(
        &self,
        id: u64,
        req: BulkRequest,
        done: Sender<BulkResponse>,
        attr: Attribution,
    ) {
        let cols = self.cfg.geometry.cols;
        let bits = req.payload_bits();
        let chunks = self.router.shard(id, bits);
        let n_chunks = chunks.len();
        let (ctx, crx) = channel();
        let rows: Vec<&BitRow> = req
            .operands
            .iter()
            .map(|p| match p {
                Payload::Bits(b) => b,
                Payload::U32(_) => unreachable!(),
            })
            .collect();
        for group in chunks.chunks(JOB_GROUP) {
            let jobs: Vec<ChunkJob> = group
                .iter()
                .map(|c| ChunkJob {
                    op: req.op,
                    operands: rows
                        .iter()
                        .map(|r| slice_bits(r, c.bit_offset, c.bits, cols))
                        .collect(),
                    chunk_idx: c.chunk_idx,
                    add32: false,
                })
                .collect();
            self.tx
                .send(Job::Group {
                    chunks: jobs,
                    reply: ctx.clone(),
                })
                .expect("workers gone");
        }
        drop(ctx);
        let metrics = Arc::clone(&self.metrics);
        std::thread::spawn(move || {
            let t0 = Instant::now();
            let mut parts: Vec<Option<(BitRow, ExecStats)>> = vec![None; n_chunks];
            let mut total = ExecStats::default();
            for (idx, row, stats) in crx {
                total.accumulate(stats);
                parts[idx] = Some((row, stats));
            }
            let mut out = BitRow::zeros(bits);
            for (i, p) in parts.into_iter().enumerate() {
                let (row, _) = p.expect("missing chunk");
                let off = i * cols;
                let live = cols.min(bits - off);
                out.copy_bits_from(&row, 0, off, live);
            }
            let wall = t0.elapsed().as_nanos() as u64;
            metrics.record_request(bits as u64, n_chunks as u64, total.aaps);
            metrics.record_sim_ns(attr.record_sim_ns);
            metrics.record_wall_ns(wall);
            metrics.record_latency_ns(attr.sim_latency_ns);
            let _ = done.send(BulkResponse {
                id,
                result: Payload::Bits(out),
                stats: total,
                sim_latency_ns: attr.sim_latency_ns,
                wall_ns: wall,
                batched_with: attr.batched_with,
            });
        });
    }

    fn submit_add32(
        &self,
        id: u64,
        req: BulkRequest,
        done: Sender<BulkResponse>,
        attr: Attribution,
    ) {
        let cols = self.cfg.geometry.cols;
        let (a, b) = match (&req.operands[0], &req.operands[1]) {
            (Payload::U32(a), Payload::U32(b)) => (a.clone(), b.clone()),
            _ => panic!("add32 needs u32 payloads"),
        };
        let n = a.len();
        let elems_per_chunk = cols;
        let n_chunks = n.div_ceil(elems_per_chunk);
        let (ctx, crx) = channel();
        for ci in 0..n_chunks {
            let lo = ci * elems_per_chunk;
            let hi = (lo + elems_per_chunk).min(n);
            // bit-planes of this element span via 32×32 bit-matrix
            // transpose (util::bitplane) — one BitRow per bit of a and b
            let mut operands =
                crate::util::bitplane::pack_planes(&a[lo..hi], cols);
            operands.extend(crate::util::bitplane::pack_planes(&b[lo..hi], cols));
            self.tx
                .send(Job::Group {
                    chunks: vec![ChunkJob {
                        op: req.op,
                        operands,
                        chunk_idx: ci,
                        add32: true,
                    }],
                    reply: ctx.clone(),
                })
                .expect("workers gone");
        }
        drop(ctx);
        let metrics = Arc::clone(&self.metrics);
        std::thread::spawn(move || {
            let t0 = Instant::now();
            // each chunk replies with 32 sum planes packed into one BitRow
            // of 32×cols bits (plane-major)
            let mut parts: Vec<Option<(BitRow, ExecStats)>> = vec![None; n_chunks];
            let mut total = ExecStats::default();
            for (idx, row, stats) in crx {
                total.accumulate(stats);
                parts[idx] = Some((row, stats));
            }
            let mut out = vec![0u32; n];
            for (ci, p) in parts.into_iter().enumerate() {
                let (wide, _) = p.expect("missing chunk");
                let lo = ci * elems_per_chunk;
                let hi = (lo + elems_per_chunk).min(n);
                // split the plane-major wide row back into 32 planes
                // (aligned word copies), then transpose to elements
                let planes: Vec<BitRow> = (0..32)
                    .map(|bit| {
                        let mut p = BitRow::zeros(elems_per_chunk);
                        p.copy_bits_from(
                            &wide,
                            bit * elems_per_chunk,
                            0,
                            elems_per_chunk,
                        );
                        p
                    })
                    .collect();
                let vals =
                    crate::util::bitplane::unpack_planes(&planes, hi - lo);
                out[lo..hi].copy_from_slice(&vals);
            }
            let wall = t0.elapsed().as_nanos() as u64;
            metrics.record_request((n * 32) as u64, n_chunks as u64, total.aaps);
            metrics.record_sim_ns(attr.record_sim_ns);
            metrics.record_wall_ns(wall);
            metrics.record_latency_ns(attr.sim_latency_ns);
            let _ = done.send(BulkResponse {
                id,
                result: Payload::U32(out),
                stats: total,
                sim_latency_ns: attr.sim_latency_ns,
                wall_ns: wall,
                batched_with: attr.batched_with,
            });
        });
    }

    pub fn shutdown(mut self) {
        self.shutdown_now();
    }

    /// Stop and join the worker threads. Idempotent (the worker list is
    /// drained on the first call); shared by [`Self::shutdown`], `Drop`,
    /// and the [`super::device::Device`] impl.
    pub(crate) fn shutdown_now(&mut self) {
        for _ in 0..self.workers.len() {
            let _ = self.tx.send(Job::Stop);
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for DrimService {
    fn drop(&mut self) {
        self.shutdown_now();
    }
}

/// Extract `bits` bits of `src` starting at `off` into a `cols`-wide row.
/// Chunk offsets are row-aligned (multiples of `cols`), so this hits the
/// word-copy fast path (§Perf in EXPERIMENTS.md).
fn slice_bits(src: &BitRow, off: usize, bits: usize, cols: usize) -> BitRow {
    let mut out = BitRow::zeros(cols);
    out.copy_bits_from(src, off, 0, bits);
    out
}

fn worker_loop(
    geometry: DramGeometry,
    rx: Arc<Mutex<Receiver<Job>>>,
    metrics: Arc<Metrics>,
) {
    let mut ctrl = Controller::new(geometry);
    let mut next_sa = 0usize;
    loop {
        let job = {
            let guard = rx.lock().unwrap();
            guard.recv()
        };
        match job {
            Ok(Job::Group { chunks, reply }) => {
                let t0 = Instant::now();
                for ChunkJob {
                    op,
                    operands,
                    chunk_idx,
                    add32,
                } in chunks
                {
                    // rotate across this worker's (bank, sub-array) grid
                    let sa_total =
                        ctrl.geometry.banks * ctrl.geometry.subarrays_per_bank;
                    let slot = next_sa % sa_total;
                    next_sa = next_sa.wrapping_add(1);
                    let bank = slot / ctrl.geometry.subarrays_per_bank;
                    let sa = slot % ctrl.geometry.subarrays_per_bank;
                    let (result, stats) = if add32 {
                        exec_add32_chunk(&mut ctrl, bank, sa, op, &operands)
                    } else {
                        exec_bitwise_chunk(&mut ctrl, bank, sa, op, &operands)
                    };
                    let _ = reply.send((chunk_idx, result, stats));
                }
                metrics.record_wall_ns(t0.elapsed().as_nanos() as u64);
            }
            Ok(Job::Stop) | Err(_) => break,
        }
    }
}

fn exec_bitwise_chunk(
    ctrl: &mut Controller,
    bank: usize,
    sa: usize,
    op: BulkOp,
    operands: &[BitRow],
) -> (BitRow, ExecStats) {
    let stage = [STAGE_A, STAGE_B, STAGE_C];
    for (i, o) in operands.iter().enumerate() {
        ctrl.write_row(bank, sa, stage[i], o);
    }
    let stats = ctrl.exec_op(op, bank, sa, &stage[..operands.len()], STAGE_R);
    (ctrl.read_row(bank, sa, STAGE_R), stats)
}

fn exec_add32_chunk(
    ctrl: &mut Controller,
    bank: usize,
    sa: usize,
    op: BulkOp,
    operands: &[BitRow],
) -> (BitRow, ExecStats) {
    let cols = ctrl.geometry.cols;
    debug_assert_eq!(operands.len(), 64);
    let (mut ar, mut br, mut sr) = (vec![], vec![], vec![]);
    for bit in 0..32u16 {
        let (ra, rb, rs) = (
            RowId::Data(PLANES_A + bit),
            RowId::Data(PLANES_B + bit),
            RowId::Data(PLANES_S + bit),
        );
        ctrl.write_row(bank, sa, ra, &operands[bit as usize]);
        ctrl.write_row(bank, sa, rb, &operands[32 + bit as usize]);
        ar.push(ra);
        br.push(rb);
        sr.push(rs);
    }
    let stats = match op {
        BulkOp::Add => ctrl.add_planes(bank, sa, &ar, &br, &sr, PLANE_CARRY),
        BulkOp::Sub => ctrl.sub_planes(bank, sa, &ar, &br, &sr, PLANE_CARRY),
        _ => unreachable!(),
    };
    // pack the 32 sum planes plane-major into one wide BitRow
    // (cols is a multiple of 64 in every geometry → aligned word copies)
    let mut out = BitRow::zeros(32 * cols);
    for (bit, rs) in sr.iter().enumerate() {
        let plane = ctrl.read_row(bank, sa, *rs);
        out.copy_bits_from(&plane, 0, bit * cols, cols);
    }
    (out, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn service() -> DrimService {
        DrimService::new(ServiceConfig::tiny())
    }

    #[test]
    fn xnor_request_roundtrip() {
        let s = service();
        let mut rng = Rng::new(1);
        let bits = 3000; // multiple chunks on tiny geometry (cols=256)
        let a = BitRow::random(bits, &mut rng);
        let b = BitRow::random(bits, &mut rng);
        let resp = s.run(BulkRequest::bitwise(
            BulkOp::Xnor2,
            vec![a.clone(), b.clone()],
        ));
        let got = match resp.result {
            Payload::Bits(r) => r,
            _ => panic!(),
        };
        let mut want = BitRow::zeros(bits);
        want.apply2(&a, &b, |x, y| !(x ^ y));
        assert_eq!(got, want);
        assert!(resp.stats.aaps > 0);
        assert!(resp.sim_latency_ns > 0.0);
    }

    #[test]
    fn add32_request_roundtrip() {
        let s = service();
        let mut rng = Rng::new(2);
        let n = 600; // spans 3 chunks of 256 elements
        let a: Vec<u32> = (0..n).map(|_| rng.next_u64() as u32).collect();
        let b: Vec<u32> = (0..n).map(|_| rng.next_u64() as u32).collect();
        let resp = s.run(BulkRequest::add32(a.clone(), b.clone()));
        let got = match resp.result {
            Payload::U32(v) => v,
            _ => panic!(),
        };
        for i in 0..n {
            assert_eq!(got[i], a[i].wrapping_add(b[i]), "elem {i}");
        }
    }

    #[test]
    fn concurrent_requests_all_complete() {
        let s = service();
        let mut rng = Rng::new(3);
        let mut pending = Vec::new();
        for _ in 0..8 {
            let a = BitRow::random(1000, &mut rng);
            let r = BulkRequest::bitwise(BulkOp::Not, vec![a]);
            pending.push(s.submit(r));
        }
        for p in pending {
            let resp = p.recv().unwrap();
            assert!(matches!(resp.result, Payload::Bits(_)));
        }
        let snap = s.metrics.snapshot();
        assert_eq!(snap.requests, 8);
    }

    #[test]
    fn batch_shares_one_wave_set_and_stays_correct() {
        // tiny geometry: 2 banks × 2 active sub-arrays = 4 slots per wave,
        // cols = 256 → four 256-bit requests pack into exactly one wave
        let s = service();
        let mut rng = Rng::new(7);
        let operands: Vec<(BitRow, BitRow)> = (0..4)
            .map(|_| (BitRow::random(256, &mut rng), BitRow::random(256, &mut rng)))
            .collect();
        let reqs: Vec<BulkRequest> = operands
            .iter()
            .map(|(a, b)| {
                BulkRequest::bitwise(BulkOp::Xnor2, vec![a.clone(), b.clone()])
            })
            .collect();
        let pending = s.submit_batch(reqs);
        assert_eq!(pending.len(), 4);
        for (rx, (a, b)) in pending.into_iter().zip(&operands) {
            let resp = rx.recv().unwrap();
            // shared attribution: one wave's time, reported by everyone
            assert!((resp.sim_latency_ns - 270.0).abs() < 1e-9);
            assert_eq!(resp.batched_with, 4);
            let got = match resp.result {
                Payload::Bits(r) => r,
                _ => panic!("wrong payload kind"),
            };
            let mut want = BitRow::zeros(256);
            want.apply2(a, b, |x, y| !(x ^ y));
            assert_eq!(got, want);
        }
        let snap = s.metrics.snapshot();
        assert_eq!(snap.requests, 4);
        // the batch advanced the makespan by ONE wave, not four
        assert_eq!(snap.sim_ns, 270);
        assert_eq!(snap.waves, 1);
        assert!((snap.slot_occupancy() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn solo_submission_owns_its_wave_set() {
        let s = service();
        let mut rng = Rng::new(8);
        let a = BitRow::random(100, &mut rng);
        let resp = s.run(BulkRequest::bitwise(BulkOp::Not, vec![a]));
        assert_eq!(resp.batched_with, 1);
        let snap = s.metrics.snapshot();
        // one sub-wave request = one wave, 1 of 4 slots filled
        assert_eq!(snap.waves, 1);
        assert!((snap.slot_occupancy() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn mixed_op_batch_degrades_to_solo_attribution() {
        let s = service();
        let mut rng = Rng::new(9);
        let a = BitRow::random(100, &mut rng);
        let b = BitRow::random(100, &mut rng);
        let reqs = vec![
            BulkRequest::bitwise(BulkOp::Not, vec![a.clone()]),
            BulkRequest::bitwise(BulkOp::Xnor2, vec![a, b]),
        ];
        for rx in s.submit_batch(reqs) {
            let resp = rx.recv().unwrap();
            assert_eq!(resp.batched_with, 1, "mixed ops cannot share a wave");
        }
    }

    #[test]
    fn metrics_track_throughput() {
        let s = service();
        let mut rng = Rng::new(4);
        let a = BitRow::random(5000, &mut rng);
        let b = BitRow::random(5000, &mut rng);
        s.run(BulkRequest::bitwise(BulkOp::Xor2, vec![a, b]));
        let snap = s.metrics.snapshot();
        assert!(snap.sim_throughput_bits_per_sec > 0.0);
        assert!(snap.aaps > 0);
        s.shutdown();
    }
}
