//! Sharding and wave scheduling.
//!
//! A payload of `B` bits is cut into chunks of one sub-array row (`cols`
//! bits). The device executes chunks in *waves*: one wave = every bank ×
//! every active sub-array runs the op's AAP sequence once, in lock-step
//! (command issue is pipelined across banks). Simulated batch latency is
//! therefore `ceil(chunks / wave_slots) × seq_ns`.
//!
//! `BatchPolicy` is the knob the `ablate_batching` bench studies:
//! * `Immediate` — each request is dispatched alone; a trailing partial
//!   wave wastes its empty slots.
//! * `Coalesce`  — chunks from queued requests are packed into shared
//!   waves (the router's dynamic batching), recovering that utilization.

use crate::dram::geometry::DramGeometry;
use crate::isa::program::BulkOp;

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum BatchPolicy {
    Immediate,
    Coalesce,
}

#[derive(Clone, Debug)]
pub struct ServiceConfig {
    pub geometry: DramGeometry,
    pub workers: usize,
    pub policy: BatchPolicy,
}

/// Bounds for the auto-detected worker count (see [`auto_workers`]).
pub const MIN_AUTO_WORKERS: usize = 2;
pub const MAX_AUTO_WORKERS: usize = 8;

/// Clamp a detected CPU count to a sane worker count.
///
/// Floor of [`MIN_AUTO_WORKERS`]: `available_parallelism()` legitimately
/// returns 1 on constrained CI runners (single-vCPU containers, cgroup
/// cpu quotas), and a single worker would serialize chunk execution
/// against the per-request collector thread — two workers keep the
/// pipeline overlapped even there. Ceiling of [`MAX_AUTO_WORKERS`]: the
/// simulated device has 8 banks, so extra workers only shrink each
/// worker's bank slice without adding parallel rows.
pub fn auto_workers(detected: usize) -> usize {
    detected.clamp(MIN_AUTO_WORKERS, MAX_AUTO_WORKERS)
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig {
            geometry: DramGeometry::default(),
            workers: auto_workers(
                std::thread::available_parallelism()
                    .map(|n| n.get())
                    .unwrap_or(4),
            ),
            policy: BatchPolicy::Coalesce,
        }
    }
}

impl ServiceConfig {
    pub fn tiny() -> Self {
        ServiceConfig {
            geometry: DramGeometry::tiny(),
            workers: 2,
            policy: BatchPolicy::Coalesce,
        }
    }
}

/// One schedulable chunk of a request (a single result row's worth).
#[derive(Clone, Debug)]
pub struct Chunk {
    pub req_id: u64,
    pub chunk_idx: usize,
    /// first bit of this chunk within the request payload
    pub bit_offset: usize,
    /// live bits in this chunk (≤ cols)
    pub bits: usize,
}

/// Wave-packing summary of a co-scheduled queue of chunk counts: how many
/// waves the device issues, how many row slots those waves expose, and how
/// many of them are filled. [`Router::plan`] computes it under the
/// configured [`BatchPolicy`]; the service records it per executed wave
/// set so slot occupancy is observable end to end.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WavePlan {
    /// waves the device issues for the queue
    pub waves: u64,
    /// row slots actually carrying a chunk
    pub slots_filled: u64,
    /// row slots the issued waves expose (`waves × wave_slots`)
    pub slots_total: u64,
}

impl WavePlan {
    /// Fraction of exposed row slots that carried work (0..1). An empty
    /// plan (no waves) is vacuously fully utilized, matching
    /// [`Router::utilization`]'s convention.
    pub fn occupancy(&self) -> f64 {
        if self.slots_total == 0 {
            return 1.0;
        }
        self.slots_filled as f64 / self.slots_total as f64
    }
}

/// Pure sharding/wave math (the part worth unit-testing exhaustively).
pub struct Router {
    pub cfg: ServiceConfig,
}

impl Router {
    pub fn new(cfg: ServiceConfig) -> Self {
        Router { cfg }
    }

    /// Device-wide parallel row slots per wave.
    pub fn wave_slots(&self) -> usize {
        self.cfg.geometry.banks * self.cfg.geometry.active_subarrays
    }

    /// Cut a payload into row chunks.
    pub fn shard(&self, req_id: u64, payload_bits: usize) -> Vec<Chunk> {
        let mut out = Vec::new();
        self.shard_into(req_id, payload_bits, &mut out);
        out
    }

    /// [`Self::shard`] appending into a caller-owned buffer, so the
    /// service hot path can reuse one chunk buffer's capacity across
    /// requests instead of allocating per submission.
    pub fn shard_into(&self, req_id: u64, payload_bits: usize, out: &mut Vec<Chunk>) {
        let cols = self.cfg.geometry.cols;
        let n = payload_bits.div_ceil(cols);
        out.extend((0..n).map(|i| Chunk {
            req_id,
            chunk_idx: i,
            bit_offset: i * cols,
            bits: cols.min(payload_bits - i * cols),
        }));
    }

    /// Wave-packing plan for a queue of chunk counts under the configured
    /// policy: `Immediate` rounds every request up to whole waves on its
    /// own; `Coalesce` packs the queue's chunks into shared waves.
    pub fn plan(&self, queue: &[usize]) -> WavePlan {
        let slots = self.wave_slots();
        let work: usize = queue.iter().sum();
        let waves: u64 = match self.cfg.policy {
            BatchPolicy::Immediate => {
                queue.iter().map(|&c| c.div_ceil(slots) as u64).sum()
            }
            BatchPolicy::Coalesce => work.div_ceil(slots) as u64,
        };
        WavePlan {
            waves,
            slots_filled: work as u64,
            slots_total: waves * slots as u64,
        }
    }

    /// Simulated latency of executing `chunks` row-operations of `op`,
    /// given the batching policy. `queue` is the list of chunk counts of
    /// the co-scheduled requests (Coalesce packs them together).
    pub fn sim_latency_ns(&self, op: BulkOp, queue: &[usize]) -> f64 {
        let seq = crate::platforms::pim::drim_r().seq_ns(op)
            * if matches!(op, BulkOp::Add | BulkOp::Sub) {
                32.0 // bit-serial over 32 planes
            } else {
                1.0
            };
        self.plan(queue).waves as f64 * seq
    }

    /// Wave utilization (0..1) for a queue under the configured policy.
    pub fn utilization(&self, queue: &[usize]) -> f64 {
        self.plan(queue).occupancy()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn tiny_router(policy: BatchPolicy) -> Router {
        Router::new(ServiceConfig {
            policy,
            ..ServiceConfig::tiny()
        })
    }

    #[test]
    fn shard_covers_payload_exactly() {
        let r = tiny_router(BatchPolicy::Coalesce);
        let cols = r.cfg.geometry.cols;
        for bits in [1, cols - 1, cols, cols + 1, 10 * cols + 17] {
            let chunks = r.shard(1, bits);
            assert_eq!(chunks.iter().map(|c| c.bits).sum::<usize>(), bits);
            assert!(chunks.iter().all(|c| c.bits <= cols));
            // offsets are dense and ordered
            let mut off = 0;
            for c in &chunks {
                assert_eq!(c.bit_offset, off);
                off += c.bits;
            }
        }
    }

    #[test]
    fn coalesce_never_slower_than_immediate() {
        prop::check("coalesce_dominates", 100, |rng| {
            let cfg_q: Vec<usize> =
                (0..1 + rng.below(6)).map(|_| 1 + rng.below(40) as usize).collect();
            let im = tiny_router(BatchPolicy::Immediate);
            let co = tiny_router(BatchPolicy::Coalesce);
            let op = BulkOp::Xnor2;
            let (ti, tc) = (im.sim_latency_ns(op, &cfg_q), co.sim_latency_ns(op, &cfg_q));
            if tc <= ti + 1e-9 {
                Ok(())
            } else {
                Err(format!("coalesce {tc} > immediate {ti} for {cfg_q:?}"))
            }
        });
    }

    #[test]
    fn utilization_bounds() {
        prop::check("util_bounds", 100, |rng| {
            let q: Vec<usize> =
                (0..1 + rng.below(5)).map(|_| 1 + rng.below(30) as usize).collect();
            for pol in [BatchPolicy::Immediate, BatchPolicy::Coalesce] {
                let u = tiny_router(pol).utilization(&q);
                if !(0.0..=1.0 + 1e-12).contains(&u) {
                    return Err(format!("util {u} out of range for {q:?}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn add_is_32x_slower_than_xnor_per_wave() {
        let r = tiny_router(BatchPolicy::Coalesce);
        let x = r.sim_latency_ns(BulkOp::Xnor2, &[1]);
        let a = r.sim_latency_ns(BulkOp::Add, &[1]);
        // 7 AAPs × 32 planes vs 3 AAPs
        assert!((a / x - (7.0 * 32.0) / 3.0).abs() < 1e-9, "{}", a / x);
    }

    #[test]
    fn single_full_wave_latency_is_seq_time() {
        let r = tiny_router(BatchPolicy::Coalesce);
        let slots = r.wave_slots();
        let t = r.sim_latency_ns(BulkOp::Xnor2, &[slots]);
        assert!((t - 270.0).abs() < 1e-9);
    }

    #[test]
    fn wave_plan_counts_waves_and_slots() {
        // tiny geometry: 2 banks × 2 active sub-arrays = 4 slots per wave
        let co = tiny_router(BatchPolicy::Coalesce);
        let im = tiny_router(BatchPolicy::Immediate);
        // four sub-wave requests: Coalesce packs one full wave
        let p = co.plan(&[1, 1, 1, 1]);
        assert_eq!(p, WavePlan { waves: 1, slots_filled: 4, slots_total: 4 });
        assert!((p.occupancy() - 1.0).abs() < 1e-12);
        // Immediate burns a wave each
        let p = im.plan(&[1, 1, 1, 1]);
        assert_eq!(p, WavePlan { waves: 4, slots_filled: 4, slots_total: 16 });
        assert!((p.occupancy() - 0.25).abs() < 1e-12);
        // empty plan: vacuously full (no waves issued)
        let p = co.plan(&[]);
        assert_eq!(p.waves, 0);
        assert!((p.occupancy() - 1.0).abs() < 1e-12);
        // ragged tail: 5 chunks over 4 slots → 2 waves, 5/8 filled
        let p = co.plan(&[5]);
        assert_eq!(p, WavePlan { waves: 2, slots_filled: 5, slots_total: 8 });
    }

    #[test]
    fn auto_workers_clamps_detected_parallelism() {
        // single-vCPU CI runner: floor keeps executor + collector overlapped
        assert_eq!(auto_workers(1), MIN_AUTO_WORKERS);
        // defensive: a hypothetical 0 still yields a working pool
        assert_eq!(auto_workers(0), MIN_AUTO_WORKERS);
        // in-range values pass through
        assert_eq!(auto_workers(4), 4);
        assert_eq!(auto_workers(8), 8);
        // many-core hosts cap at the bank count
        assert_eq!(auto_workers(64), MAX_AUTO_WORKERS);
        let d = ServiceConfig::default();
        assert!((MIN_AUTO_WORKERS..=MAX_AUTO_WORKERS).contains(&d.workers));
    }

    #[test]
    fn empty_payload_shards_to_nothing() {
        let r = tiny_router(BatchPolicy::Coalesce);
        let chunks = r.shard(1, 0);
        assert!(chunks.is_empty());
        // and the wave math agrees: no chunks, no waves, no time
        assert_eq!(r.sim_latency_ns(BulkOp::Xnor2, &[0]), 0.0);
        assert_eq!(r.sim_latency_ns(BulkOp::Xnor2, &[]), 0.0);
    }

    #[test]
    fn sub_row_payload_is_one_partial_chunk() {
        let r = tiny_router(BatchPolicy::Coalesce);
        let cols = r.cfg.geometry.cols;
        for bits in [1usize, 2, cols / 2, cols - 1] {
            let chunks = r.shard(7, bits);
            assert_eq!(chunks.len(), 1, "{bits} bits");
            assert_eq!(chunks[0].bits, bits);
            assert_eq!(chunks[0].bit_offset, 0);
            assert_eq!(chunks[0].req_id, 7);
            // still costs one full wave
            assert!((r.sim_latency_ns(BulkOp::Xnor2, &[1]) - 270.0).abs() < 1e-9);
        }
    }

    #[test]
    fn non_multiple_payload_has_one_ragged_tail_chunk() {
        let r = tiny_router(BatchPolicy::Coalesce);
        let cols = r.cfg.geometry.cols;
        let bits = 5 * cols + 17;
        let chunks = r.shard(1, bits);
        assert_eq!(chunks.len(), 6);
        for c in &chunks[..5] {
            assert_eq!(c.bits, cols);
        }
        assert_eq!(chunks[5].bits, 17);
        assert_eq!(chunks[5].bit_offset, 5 * cols);
    }

    #[test]
    fn immediate_vs_coalesce_slot_utilization_accounting() {
        // tiny geometry: 2 banks × 2 active sub-arrays = 4 slots per wave
        let im = tiny_router(BatchPolicy::Immediate);
        let co = tiny_router(BatchPolicy::Coalesce);
        assert_eq!(im.wave_slots(), 4);
        // four 1-chunk requests: Immediate burns one wave each (3 empty
        // slots per wave), Coalesce packs them into a single full wave.
        let q = [1usize, 1, 1, 1];
        assert!((im.utilization(&q) - 0.25).abs() < 1e-12);
        assert!((co.utilization(&q) - 1.0).abs() < 1e-12);
        assert!((im.sim_latency_ns(BulkOp::Xnor2, &q) - 4.0 * 270.0).abs() < 1e-9);
        assert!((co.sim_latency_ns(BulkOp::Xnor2, &q) - 270.0).abs() < 1e-9);
        // 5 chunks in one request: both policies need two waves, 5/8 full
        let q5 = [5usize];
        assert!((im.utilization(&q5) - 0.625).abs() < 1e-12);
        assert!((co.utilization(&q5) - 0.625).abs() < 1e-12);
        // empty queue is vacuously fully utilized (documented edge)
        assert_eq!(im.utilization(&[]), 1.0);
        assert_eq!(co.utilization(&[]), 1.0);
    }
}
