//! Service request/response vocabulary.

use crate::controller::ExecStats;
use crate::isa::program::BulkOp;
use crate::util::bitrow::BitRow;

/// Request payload: either flat bit-vectors (bit-wise ops) or 32-bit
/// element vectors (in-memory add/sub, processed bit-serially).
#[derive(Clone, Debug, PartialEq)]
pub enum Payload {
    Bits(BitRow),
    U32(Vec<u32>),
}

impl Payload {
    pub fn bits(&self) -> usize {
        match self {
            Payload::Bits(b) => b.len(),
            Payload::U32(v) => v.len() * 32,
        }
    }

    /// Size in whole bytes — the unit the cluster's residency layer
    /// meters copy traffic and capacity footprints in.
    pub fn bytes(&self) -> u64 {
        (self.bits() as u64).div_ceil(8)
    }
}

/// One bulk in-memory operation over arbitrary-size payloads.
#[derive(Clone, Debug)]
pub struct BulkRequest {
    pub op: BulkOp,
    pub operands: Vec<Payload>,
}

impl BulkRequest {
    /// Bit-wise request (`not`, `xnor2`, ..., `maj3`).
    pub fn bitwise(op: BulkOp, operands: Vec<BitRow>) -> Self {
        assert!(
            !matches!(op, BulkOp::Add | BulkOp::Sub),
            "use BulkRequest::add32/sub32"
        );
        assert_eq!(operands.len(), op.arity(), "{}", op.name());
        let bits = operands[0].len();
        assert!(operands.iter().all(|o| o.len() == bits));
        BulkRequest {
            op,
            operands: operands.into_iter().map(Payload::Bits).collect(),
        }
    }

    /// Element-wise 32-bit addition (bit-serial in the array).
    pub fn add32(a: Vec<u32>, b: Vec<u32>) -> Self {
        assert_eq!(a.len(), b.len());
        BulkRequest {
            op: BulkOp::Add,
            operands: vec![Payload::U32(a), Payload::U32(b)],
        }
    }

    /// Element-wise 32-bit subtraction.
    pub fn sub32(a: Vec<u32>, b: Vec<u32>) -> Self {
        assert_eq!(a.len(), b.len());
        BulkRequest {
            op: BulkOp::Sub,
            operands: vec![Payload::U32(a), Payload::U32(b)],
        }
    }

    pub fn payload_bits(&self) -> usize {
        self.operands[0].bits()
    }

    /// Wave-unit form of the request: how many wave slots (row chunks)
    /// its payload occupies on a device with `cols`-bit rows — the
    /// quantity the fleet coalescer packs against `Router::wave_slots`
    /// and the scheduler budgets drains in. Bit-wise payloads occupy one
    /// slot per `cols` bits; 32-bit element payloads occupy one slot per
    /// `cols` elements (each slot runs the bit-serial plane program).
    /// Empty payloads occupy zero slots.
    pub fn wave_units(&self, cols: usize) -> usize {
        match &self.operands[0] {
            Payload::Bits(b) => b.len().div_ceil(cols),
            Payload::U32(v) => v.len().div_ceil(cols),
        }
    }

    /// Total bits across *all* operands — the quantity that has to move
    /// when none of them is resident where the request executes (the
    /// cluster's locality ablation charges carried requests exactly this).
    pub fn operand_bits(&self) -> usize {
        self.operands.iter().map(|o| o.bits()).sum()
    }
}

/// Completed request.
#[derive(Clone, Debug)]
pub struct BulkResponse {
    pub id: u64,
    pub result: Payload,
    /// simulated DRAM cost (sums the per-chunk command streams)
    pub stats: ExecStats,
    /// simulated wall-clock of the *batched* execution (waves × seq time)
    pub sim_latency_ns: f64,
    /// host wall-clock spent simulating
    pub wall_ns: u64,
    /// requests that shared this request's wave set (1 = executed alone;
    /// >1 = the request was coalesced and `sim_latency_ns` is the shared
    /// wave set's completion, not a private `ceil(chunks/slots)` round-up)
    pub batched_with: usize,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn bitwise_request_checks_arity() {
        let mut rng = Rng::new(1);
        let a = BitRow::random(100, &mut rng);
        let b = BitRow::random(100, &mut rng);
        let r = BulkRequest::bitwise(BulkOp::Xnor2, vec![a, b]);
        assert_eq!(r.payload_bits(), 100);
    }

    #[test]
    #[should_panic]
    fn wrong_arity_panics() {
        let a = BitRow::zeros(8);
        BulkRequest::bitwise(BulkOp::Xnor2, vec![a]);
    }

    #[test]
    #[should_panic(expected = "add32")]
    fn add_via_bitwise_rejected() {
        BulkRequest::bitwise(BulkOp::Add, vec![BitRow::zeros(8)]);
    }

    #[test]
    fn add32_payload_bits() {
        let r = BulkRequest::add32(vec![1, 2, 3], vec![4, 5, 6]);
        assert_eq!(r.payload_bits(), 96);
        assert_eq!(r.operand_bits(), 192);
    }

    #[test]
    fn wave_units_round_up_per_payload_kind() {
        let cols = 256;
        let bitwise = |bits: usize| {
            BulkRequest::bitwise(BulkOp::Not, vec![BitRow::zeros(bits)])
        };
        assert_eq!(bitwise(1).wave_units(cols), 1);
        assert_eq!(bitwise(cols).wave_units(cols), 1);
        assert_eq!(bitwise(cols + 1).wave_units(cols), 2);
        assert_eq!(bitwise(5 * cols).wave_units(cols), 5);
        // element vectors: one slot per `cols` elements, not per bit
        let add = BulkRequest::add32(vec![0; cols + 1], vec![0; cols + 1]);
        assert_eq!(add.wave_units(cols), 2);
        assert_eq!(BulkRequest::add32(vec![1], vec![2]).wave_units(cols), 1);
    }

    #[test]
    fn payload_bytes_round_up() {
        assert_eq!(Payload::Bits(BitRow::zeros(9)).bytes(), 2);
        assert_eq!(Payload::Bits(BitRow::zeros(16)).bytes(), 2);
        assert_eq!(Payload::U32(vec![0; 2]).bytes(), 8);
    }
}
