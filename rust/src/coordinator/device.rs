//! The reusable device abstraction the scale-out layer schedules over.
//!
//! A [`Device`] is anything that can execute [`BulkRequest`]s and report
//! [`Metrics`]: today the in-process [`DrimService`] simulator, tomorrow a
//! remote DRIM channel behind an RPC stub. The `cluster` subsystem owns one
//! `Device` per fleet worker and drives it exclusively from that worker's
//! OS thread, so implementations only need `&self` request submission from
//! a single thread at a time (plus `Send` to move onto the thread).

use std::sync::mpsc::Receiver;
use std::sync::Arc;

use super::metrics::{Metrics, MetricsSnapshot};
use super::request::{BulkRequest, BulkResponse};
use super::router::ServiceConfig;
use super::service::DrimService;

pub trait Device: Send {
    /// Enqueue a request; the receiver yields exactly one response.
    fn submit(&self, req: BulkRequest) -> Receiver<BulkResponse>;

    /// Enqueue a group of requests intended to execute as one
    /// co-scheduled wave set (the fleet coalescer's dispatch unit).
    /// Receivers are returned in request order. The default falls back to
    /// per-request submission — correct everywhere, but without shared
    /// wave attribution; `DrimService` overrides it to pack the group's
    /// chunks into shared waves and report each response's latency as the
    /// wave set's completion.
    fn submit_batch(&self, reqs: Vec<BulkRequest>) -> Vec<Receiver<BulkResponse>> {
        reqs.into_iter().map(|r| self.submit(r)).collect()
    }

    /// Submit and block for the response.
    fn run(&self, req: BulkRequest) -> BulkResponse {
        self.submit(req).recv().expect("device dropped mid-request")
    }

    /// Live counters for this device (shared handle; cheap to clone).
    fn metrics(&self) -> Arc<Metrics>;

    /// Point-in-time view of the counters.
    fn snapshot(&self) -> MetricsSnapshot {
        self.metrics().snapshot()
    }

    /// The device's serving configuration (geometry, workers, batching).
    fn service_config(&self) -> &ServiceConfig;

    /// Drain in-flight work and join internal workers. Idempotent; called
    /// by fleet workers before the device is dropped.
    fn shutdown(&mut self);
}

impl Device for DrimService {
    fn submit(&self, req: BulkRequest) -> Receiver<BulkResponse> {
        DrimService::submit(self, req)
    }

    fn submit_batch(&self, reqs: Vec<BulkRequest>) -> Vec<Receiver<BulkResponse>> {
        DrimService::submit_batch(self, reqs)
    }

    fn metrics(&self) -> Arc<Metrics> {
        Arc::clone(&self.metrics)
    }

    fn service_config(&self) -> &ServiceConfig {
        self.config()
    }

    fn shutdown(&mut self) {
        self.shutdown_now();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::program::BulkOp;
    use crate::util::bitrow::BitRow;
    use crate::util::rng::Rng;

    /// Exercise DrimService purely through the trait object surface the
    /// cluster workers use.
    #[test]
    fn drim_service_through_trait_object() {
        let mut dev: Box<dyn Device> =
            Box::new(DrimService::new(ServiceConfig::tiny()));
        let mut rng = Rng::new(11);
        let a = BitRow::random(500, &mut rng);
        let b = BitRow::random(500, &mut rng);
        let mut want = BitRow::zeros(500);
        want.apply2(&a, &b, |x, y| !(x ^ y));
        let resp = dev.run(BulkRequest::bitwise(BulkOp::Xnor2, vec![a, b]));
        match resp.result {
            crate::coordinator::Payload::Bits(got) => assert_eq!(got, want),
            _ => panic!("wrong payload kind"),
        }
        assert_eq!(dev.snapshot().requests, 1);
        assert_eq!(dev.service_config().geometry.cols, 256);
        dev.shutdown();
        dev.shutdown(); // idempotent
    }
}
