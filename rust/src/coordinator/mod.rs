//! The DRIM coordinator: the serving layer that turns the raw array into a
//! bulk-bit-wise accelerator service (the role a request router plays for a
//! model server — cf. vllm-project/router).
//!
//! * [`request`] — the service vocabulary: bit-wise bulk requests and
//!   32-bit element-wise adds, with arbitrary payload sizes.
//! * [`router`]  — sharding: payloads are cut into row-sized chunks and
//!   scheduled in *waves* across banks × active sub-arrays.
//! * [`service`] — worker threads (each owning a slice of banks), dynamic
//!   batching with a configurable policy, response reassembly.
//! * [`metrics`] — throughput/latency/utilization counters (simulated DRAM
//!   time and wall time are tracked separately).
//! * [`device`] — the [`Device`] trait: the one-chip abstraction
//!   (`submit`/`run`/metrics/shutdown) that [`crate::cluster`] schedules
//!   over to scale the service across many DRIM devices.
//!
//! One `DrimService` is one device. Multi-device serving (topology,
//! fleet scheduling, admission control, work stealing, operand residency
//! and copy-cost accounting) lives one layer up in [`crate::cluster`] and
//! consumes this module only through [`Device`] — a device always receives
//! fully materialized payloads; resolving resident operand handles is the
//! cluster's job.

pub mod coherence;
pub mod device;
pub mod metrics;
pub mod request;
pub mod router;
pub mod service;

pub use device::Device;
pub use metrics::{Metrics, MetricsSnapshot};
pub use request::{BulkRequest, BulkResponse, Payload};
pub use router::{BatchPolicy, Router, ServiceConfig, WavePlan};
pub use service::DrimService;
