//! Host-cache coherence protocol (paper §4 "Cache Coherence"): when DRIM
//! updates memory in place, stale copies may live in host caches, and the
//! host may hold dirty lines DRIM would read stale. The paper's chosen
//! mechanism — "rely on the OS to unmap the physical pages accessible by
//! DRIM from any process that can run while computing in DRIM" — is
//! modelled here as an epoch/lease protocol the router consults before
//! dispatching a request over a row range.

use std::collections::BTreeMap;

use crate::dram::geometry::PhysAddr;

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum RowState {
    /// host may cache this row; DRIM must not touch it
    HostOwned,
    /// unmapped from host page tables; DRIM may read/write
    DrimOwned,
}

#[derive(Debug, PartialEq)]
pub enum CoherenceError {
    /// DRIM op targeted a row the host still owns
    NotAcquired(PhysAddr),
    /// host access to a row leased to DRIM
    LeasedToDrim(PhysAddr),
}

/// Ownership tracker for the rows DRIM operates on. Rows default to
/// HostOwned; `acquire` models the OS unmap + cache flush (writeback +
/// invalidate) of the page, `release` returns it to the host.
#[derive(Debug, Default)]
pub struct CoherenceDirectory {
    state: BTreeMap<PhysAddr, RowState>,
    pub flushes: u64,
}

impl CoherenceDirectory {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn state(&self, row: PhysAddr) -> RowState {
        *self.state.get(&row).unwrap_or(&RowState::HostOwned)
    }

    /// OS unmaps + flushes the row's lines; DRIM may now compute on it.
    pub fn acquire(&mut self, row: PhysAddr) {
        if self.state(row) == RowState::HostOwned {
            self.flushes += 1; // writeback+invalidate of the page's lines
        }
        self.state.insert(row, RowState::DrimOwned);
    }

    pub fn acquire_all(&mut self, rows: &[PhysAddr]) {
        for &r in rows {
            self.acquire(r);
        }
    }

    /// DRIM finished; page is remappable by the host.
    pub fn release(&mut self, row: PhysAddr) {
        self.state.insert(row, RowState::HostOwned);
    }

    /// Gate for DRIM-side access (the router calls this per chunk range).
    pub fn check_drim_access(&self, rows: &[PhysAddr]) -> Result<(), CoherenceError> {
        for &r in rows {
            if self.state(r) != RowState::DrimOwned {
                return Err(CoherenceError::NotAcquired(r));
            }
        }
        Ok(())
    }

    /// Gate for host-side access while DRIM computes.
    pub fn check_host_access(&self, row: PhysAddr) -> Result<(), CoherenceError> {
        if self.state(row) == RowState::DrimOwned {
            return Err(CoherenceError::LeasedToDrim(row));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pa(row: usize) -> PhysAddr {
        PhysAddr {
            bank: 0,
            subarray: 0,
            row,
        }
    }

    #[test]
    fn drim_access_requires_acquire() {
        let mut d = CoherenceDirectory::new();
        assert_eq!(
            d.check_drim_access(&[pa(1)]),
            Err(CoherenceError::NotAcquired(pa(1)))
        );
        d.acquire(pa(1));
        assert_eq!(d.check_drim_access(&[pa(1)]), Ok(()));
    }

    #[test]
    fn host_access_blocked_while_leased() {
        let mut d = CoherenceDirectory::new();
        d.acquire(pa(2));
        assert_eq!(
            d.check_host_access(pa(2)),
            Err(CoherenceError::LeasedToDrim(pa(2)))
        );
        d.release(pa(2));
        assert_eq!(d.check_host_access(pa(2)), Ok(()));
    }

    #[test]
    fn acquire_is_idempotent_but_flushes_once() {
        let mut d = CoherenceDirectory::new();
        d.acquire(pa(3));
        d.acquire(pa(3));
        assert_eq!(d.flushes, 1);
        d.release(pa(3));
        d.acquire(pa(3));
        assert_eq!(d.flushes, 2, "re-acquire after host ownership flushes again");
    }

    #[test]
    fn bulk_acquire_release_cycle() {
        let mut d = CoherenceDirectory::new();
        let rows: Vec<PhysAddr> = (0..10).map(pa).collect();
        d.acquire_all(&rows);
        assert_eq!(d.check_drim_access(&rows), Ok(()));
        assert_eq!(d.flushes, 10);
        for r in &rows {
            d.release(*r);
        }
        assert!(d.check_drim_access(&rows).is_err());
    }
}
