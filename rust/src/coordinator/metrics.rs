//! Service metrics: requests, bits, simulated vs wall time, utilization.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::util::stats::Summary;

#[derive(Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub chunks: AtomicU64,
    pub result_bits: AtomicU64,
    pub aaps: AtomicU64,
    /// simulated DRAM nanoseconds (batched wave time)
    pub sim_ns: AtomicU64,
    /// host nanoseconds spent in workers
    pub wall_ns: AtomicU64,
    latency: Mutex<Summary>,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics::default()
    }

    pub fn record_request(&self, result_bits: u64, chunks: u64, aaps: u64) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.result_bits.fetch_add(result_bits, Ordering::Relaxed);
        self.chunks.fetch_add(chunks, Ordering::Relaxed);
        self.aaps.fetch_add(aaps, Ordering::Relaxed);
    }

    pub fn record_sim_ns(&self, ns: f64) {
        self.sim_ns.fetch_add(ns as u64, Ordering::Relaxed);
    }

    pub fn record_wall_ns(&self, ns: u64) {
        self.wall_ns.fetch_add(ns, Ordering::Relaxed);
    }

    pub fn record_latency_ns(&self, ns: f64) {
        self.latency.lock().unwrap().add(ns);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let lat = self.latency.lock().unwrap();
        let sim_ns = self.sim_ns.load(Ordering::Relaxed);
        let bits = self.result_bits.load(Ordering::Relaxed);
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            chunks: self.chunks.load(Ordering::Relaxed),
            result_bits: bits,
            aaps: self.aaps.load(Ordering::Relaxed),
            sim_ns,
            wall_ns: self.wall_ns.load(Ordering::Relaxed),
            mean_latency_ns: lat.mean(),
            max_latency_ns: if lat.count() > 0 { lat.max() } else { 0.0 },
            sim_throughput_bits_per_sec: if sim_ns > 0 {
                bits as f64 / (sim_ns as f64 * 1e-9)
            } else {
                0.0
            },
        }
    }
}

#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub chunks: u64,
    pub result_bits: u64,
    pub aaps: u64,
    pub sim_ns: u64,
    pub wall_ns: u64,
    pub mean_latency_ns: f64,
    pub max_latency_ns: f64,
    pub sim_throughput_bits_per_sec: f64,
}

impl MetricsSnapshot {
    pub fn report(&self) -> String {
        use crate::util::stats::{fmt_ns, fmt_rate};
        format!(
            "requests: {}  chunks: {}  result bits: {}  AAPs: {}\n\
             simulated time: {}  (throughput {}bit/s)\n\
             host wall time: {}  mean sim latency: {}  max: {}",
            self.requests,
            self.chunks,
            self.result_bits,
            self.aaps,
            fmt_ns(self.sim_ns as f64),
            fmt_rate(self.sim_throughput_bits_per_sec),
            fmt_ns(self.wall_ns as f64),
            fmt_ns(self.mean_latency_ns),
            fmt_ns(self.max_latency_ns),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.record_request(8192, 1, 3);
        m.record_request(8192, 1, 3);
        m.record_sim_ns(540.0);
        m.record_latency_ns(270.0);
        m.record_latency_ns(810.0);
        let s = m.snapshot();
        assert_eq!(s.requests, 2);
        assert_eq!(s.result_bits, 16384);
        assert_eq!(s.aaps, 6);
        assert!((s.mean_latency_ns - 540.0).abs() < 1e-9);
        assert!(s.sim_throughput_bits_per_sec > 0.0);
        assert!(s.report().contains("requests: 2"));
    }

    #[test]
    fn empty_snapshot_is_sane() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.requests, 0);
        assert_eq!(s.sim_throughput_bits_per_sec, 0.0);
    }
}
