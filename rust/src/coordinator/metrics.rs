//! Service metrics: requests, bits, simulated vs wall time, utilization.
//!
//! Latency is tracked in a mergeable log-bucketed
//! [`Histogram`](crate::obs::Histogram) (not a flat mean/max
//! accumulator), so snapshots carry the full sim-latency distribution —
//! p50/p95/p99 per device, and fleet-wide after
//! [`crate::cluster::merge_snapshots`] folds the buckets together.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::obs::json::Json;
use crate::obs::Histogram;

#[derive(Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub chunks: AtomicU64,
    pub result_bits: AtomicU64,
    pub aaps: AtomicU64,
    /// simulated DRAM nanoseconds (batched wave time)
    pub sim_ns: AtomicU64,
    /// host nanoseconds spent in workers
    pub wall_ns: AtomicU64,
    /// waves issued by executed wave sets
    pub waves: AtomicU64,
    /// row slots that carried a chunk across those waves
    pub wave_slots_filled: AtomicU64,
    /// row slots the issued waves exposed (waves × wave_slots)
    pub wave_slots_total: AtomicU64,
    latency: Mutex<Histogram>,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics::default()
    }

    pub fn record_request(&self, result_bits: u64, chunks: u64, aaps: u64) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.result_bits.fetch_add(result_bits, Ordering::Relaxed);
        self.chunks.fetch_add(chunks, Ordering::Relaxed);
        self.aaps.fetch_add(aaps, Ordering::Relaxed);
    }

    pub fn record_sim_ns(&self, ns: f64) {
        self.sim_ns.fetch_add(ns as u64, Ordering::Relaxed);
    }

    /// Account one executed wave set (solo request or coalesced batch):
    /// how many waves it issued, how many row slots they exposed, and how
    /// many carried a chunk. Recorded at submission time — the wave plan
    /// is fixed the moment the set is scheduled.
    pub fn record_waves(&self, waves: u64, slots_filled: u64, slots_total: u64) {
        self.waves.fetch_add(waves, Ordering::Relaxed);
        self.wave_slots_filled.fetch_add(slots_filled, Ordering::Relaxed);
        self.wave_slots_total.fetch_add(slots_total, Ordering::Relaxed);
    }

    pub fn record_wall_ns(&self, ns: u64) {
        self.wall_ns.fetch_add(ns, Ordering::Relaxed);
    }

    pub fn record_latency_ns(&self, ns: f64) {
        self.latency.lock().unwrap().record(ns.max(0.0).round() as u64);
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let lat = self.latency.lock().unwrap().clone();
        let sim_ns = self.sim_ns.load(Ordering::Relaxed);
        let bits = self.result_bits.load(Ordering::Relaxed);
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            chunks: self.chunks.load(Ordering::Relaxed),
            result_bits: bits,
            aaps: self.aaps.load(Ordering::Relaxed),
            sim_ns,
            wall_ns: self.wall_ns.load(Ordering::Relaxed),
            waves: self.waves.load(Ordering::Relaxed),
            wave_slots_filled: self.wave_slots_filled.load(Ordering::Relaxed),
            wave_slots_total: self.wave_slots_total.load(Ordering::Relaxed),
            mean_latency_ns: lat.mean(),
            max_latency_ns: lat.max() as f64,
            sim_throughput_bits_per_sec: if sim_ns > 0 {
                bits as f64 / (sim_ns as f64 * 1e-9)
            } else {
                0.0
            },
            latency: lat,
        }
    }
}

#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub chunks: u64,
    pub result_bits: u64,
    pub aaps: u64,
    pub sim_ns: u64,
    pub wall_ns: u64,
    /// waves issued by executed wave sets
    pub waves: u64,
    /// row slots that carried a chunk across those waves
    pub wave_slots_filled: u64,
    /// row slots the issued waves exposed
    pub wave_slots_total: u64,
    pub mean_latency_ns: f64,
    pub max_latency_ns: f64,
    pub sim_throughput_bits_per_sec: f64,
    /// full sim-latency distribution (per request, nanoseconds); merge
    /// with other devices' histograms for a fleet-wide view
    pub latency: Histogram,
}

impl MetricsSnapshot {
    /// Fraction of exposed wave row slots that carried work (0..1). A
    /// device that issued no waves is vacuously fully occupied — the
    /// counters viewed as one aggregate [`super::router::WavePlan`], so
    /// the convention stays defined in exactly one place.
    pub fn slot_occupancy(&self) -> f64 {
        super::router::WavePlan {
            waves: self.waves,
            slots_filled: self.wave_slots_filled,
            slots_total: self.wave_slots_total,
        }
        .occupancy()
    }

    /// Stable JSON form (schema: see docs/ARCHITECTURE.md § Observability).
    pub fn to_json(&self) -> Json {
        Json::obj()
            .field("requests", self.requests)
            .field("chunks", self.chunks)
            .field("result_bits", self.result_bits)
            .field("aaps", self.aaps)
            .field("sim_ns", self.sim_ns)
            .field("wall_ns", self.wall_ns)
            .field("waves", self.waves)
            .field("slot_occupancy", self.slot_occupancy())
            .field("throughput_bits_per_sec", self.sim_throughput_bits_per_sec)
            .field("latency_ns", self.latency.summary_json())
    }

    pub fn report(&self) -> String {
        use crate::util::stats::{fmt_ns, fmt_rate};
        let (p50, p95, p99) = self.latency.p50_p95_p99();
        format!(
            "requests: {}  chunks: {}  result bits: {}  AAPs: {}\n\
             simulated time: {}  (throughput {}bit/s)\n\
             waves: {}  slot occupancy: {:.1}%\n\
             host wall time: {}  mean sim latency: {}  max: {}\n\
             sim latency p50: {}  p95: {}  p99: {}",
            self.requests,
            self.chunks,
            self.result_bits,
            self.aaps,
            fmt_ns(self.sim_ns as f64),
            fmt_rate(self.sim_throughput_bits_per_sec),
            self.waves,
            100.0 * self.slot_occupancy(),
            fmt_ns(self.wall_ns as f64),
            fmt_ns(self.mean_latency_ns),
            fmt_ns(self.max_latency_ns),
            fmt_ns(p50),
            fmt_ns(p95),
            fmt_ns(p99),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.record_request(8192, 1, 3);
        m.record_request(8192, 1, 3);
        m.record_sim_ns(540.0);
        m.record_latency_ns(270.0);
        m.record_latency_ns(810.0);
        let s = m.snapshot();
        assert_eq!(s.requests, 2);
        assert_eq!(s.result_bits, 16384);
        assert_eq!(s.aaps, 6);
        assert!((s.mean_latency_ns - 540.0).abs() < 1e-9);
        assert!((s.max_latency_ns - 810.0).abs() < 1e-9);
        assert_eq!(s.latency.count(), 2);
        assert!(s.sim_throughput_bits_per_sec > 0.0);
        assert!(s.report().contains("requests: 2"));
        assert!(s.report().contains("p99"), "{}", s.report());
    }

    #[test]
    fn empty_snapshot_is_sane() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.requests, 0);
        assert_eq!(s.sim_throughput_bits_per_sec, 0.0);
        assert_eq!(s.max_latency_ns, 0.0);
        assert!(s.latency.is_empty());
        // no waves issued → vacuously fully occupied (utilization convention)
        assert_eq!(s.waves, 0);
        assert!((s.slot_occupancy() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn wave_counters_accumulate_into_occupancy() {
        let m = Metrics::new();
        // one full wave of 4 slots, then a lone chunk in its own wave
        m.record_waves(1, 4, 4);
        m.record_waves(1, 1, 4);
        let s = m.snapshot();
        assert_eq!(s.waves, 2);
        assert_eq!(s.wave_slots_filled, 5);
        assert_eq!(s.wave_slots_total, 8);
        assert!((s.slot_occupancy() - 0.625).abs() < 1e-12);
        assert!(s.report().contains("slot occupancy"), "{}", s.report());
    }

    #[test]
    fn snapshot_json_is_parseable_and_stable() {
        let m = Metrics::new();
        m.record_request(1024, 1, 3);
        m.record_sim_ns(270.0);
        m.record_latency_ns(270.0);
        let doc = m.snapshot().to_json();
        let parsed = Json::parse(&doc.to_string_compact()).unwrap();
        assert_eq!(parsed.get("requests").unwrap().as_f64(), Some(1.0));
        let lat = parsed.get("latency_ns").unwrap();
        assert_eq!(lat.get("count").unwrap().as_f64(), Some(1.0));
        assert!(lat.get("p99").unwrap().as_f64().unwrap() >= 1.0);
    }
}
